"""Test bootstrap: make `compile.*` importable without an install step."""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
