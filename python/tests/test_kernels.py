"""Kernel-vs-reference correctness: the core L1 signal.

Hypothesis sweeps shapes (and seeds) for every Pallas kernel against the
pure-jnp oracle in ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import attention
from compile.kernels.expert_ffn import expert_ffn, mxu_utilization_estimate, vmem_bytes
from compile.kernels.gating import gating, gating_topk
from compile.kernels import ref


def rand(key, *shape, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(
        jnp.float32
    )


# dims kept multiples-of-8-ish and small so interpret mode stays fast
dims = st.sampled_from([8, 16, 32, 64])
tokens = st.sampled_from([1, 4, 16, 64, 128, 256])
seeds = st.integers(min_value=0, max_value=2**31 - 1)


@settings(max_examples=20, deadline=None)
@given(t=tokens, h=dims, f=dims, seed=seeds)
def test_expert_ffn_matches_ref(t, h, f, seed):
    x = rand(seed, t, h)
    w1 = rand(seed + 1, h, f, scale=h**-0.5)
    b1 = rand(seed + 2, f, scale=0.01)
    w2 = rand(seed + 3, f, h, scale=f**-0.5)
    b2 = rand(seed + 4, h, scale=0.01)
    got = expert_ffn(x, w1, b1, w2, b2)
    want = ref.expert_ffn_ref(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(t=tokens, h=dims, e=st.sampled_from([2, 4, 8, 16]), seed=seeds)
def test_gating_matches_ref(t, h, e, seed):
    x = rand(seed, t, h)
    wg = rand(seed + 9, h, e, scale=0.2)
    got = gating(x, wg)
    want = ref.gating_ref(x, wg)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    # probabilities
    np.testing.assert_allclose(np.asarray(got).sum(-1), 1.0, rtol=1e-5)
    assert (np.asarray(got) >= 0).all()


@settings(max_examples=15, deadline=None)
@given(s=st.sampled_from([2, 8, 32, 64]), h=dims, seed=seeds)
def test_attention_matches_ref(s, h, seed):
    x = rand(seed, s, h)
    wq, wk, wv, wo = (rand(seed + i, h, h, scale=h**-0.5) for i in range(1, 5))
    y, amax = attention(x, wq, wk, wv, wo)
    y_ref, scores = ref.attention_ref(x, wq, wk, wv, wo)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(amax), np.argmax(scores, axis=-1))


@settings(max_examples=10, deadline=None)
@given(t=st.sampled_from([4, 16, 64]), seed=seeds, k=st.sampled_from([1, 2]))
def test_gating_topk_consistent(t, seed, k):
    x = rand(seed, t, 32)
    wg = rand(seed + 7, 32, 4, scale=0.2)
    probs, idx = gating_topk(x, wg, k)
    probs = np.asarray(probs)
    idx = np.asarray(idx)
    assert idx.shape == (t, k)
    for row in range(t):
        # top-k indices really are the k largest probs
        topk = set(np.argsort(-probs[row])[:k].tolist())
        assert set(idx[row].tolist()) == topk


def test_attention_id_maps_positions_to_tokens():
    token_ids = jnp.array([5, 9, 2, 7], dtype=jnp.int32)
    scores = jnp.array(
        [
            [0.1, 0.7, 0.1, 0.1],
            [0.6, 0.2, 0.1, 0.1],
            [0.1, 0.1, 0.1, 0.7],
            [0.25, 0.25, 0.3, 0.2],
        ]
    )
    ids = ref.attention_id_ref(scores, token_ids)
    np.testing.assert_array_equal(np.asarray(ids), [9, 5, 7, 2])


def test_vmem_estimate_within_budget():
    # The tiny config's kernel block must fit VMEM with big margin.
    assert vmem_bytes(128, 64, 256) < 1 * 1024 * 1024
    # And a scaled config (H=512, F=2048) should still fit ~16MB VMEM.
    assert vmem_bytes(128, 512, 2048) < 16 * 1024 * 1024


def test_mxu_utilization_dominated_by_matmul():
    assert mxu_utilization_estimate(128, 64, 256) > 0.95


def test_expert_ffn_rejects_unaligned_large_batch():
    x = rand(0, 130, 16)  # >TILE_T and not a multiple
    w1 = rand(1, 16, 16)
    b1 = rand(2, 16)
    w2 = rand(3, 16, 16)
    b2 = rand(4, 16)
    with pytest.raises(AssertionError):
        expert_ffn(x, w1, b1, w2, b2)
