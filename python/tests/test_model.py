"""L2 model tests: stage shapes, determinism, and kernel-composition vs the
dense pure-jnp reference for the whole tiny MoE model."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    CONFIG,
    attention_block,
    embed,
    expert_stage,
    forward_kernels,
    forward_reference,
    gating_stage,
    init_weights,
)


def ids(seed=0, s=None):
    s = s or CONFIG.max_seq
    return jax.random.randint(jax.random.PRNGKey(seed), (s,), 0, CONFIG.vocab).astype(
        jnp.int32
    )


def test_weights_deterministic():
    a = init_weights(seed=3)
    b = init_weights(seed=3)
    np.testing.assert_array_equal(a["wte"], b["wte"])
    np.testing.assert_array_equal(
        a["layers"][1]["experts"][2][0], b["layers"][1]["experts"][2][0]
    )
    c = init_weights(seed=4)
    assert not np.array_equal(a["wte"], c["wte"])


def test_stage_shapes():
    w = init_weights()
    x = embed(ids(), w["wte"], w["wpe"])
    assert x.shape == (CONFIG.max_seq, CONFIG.hidden)
    y, amax = attention_block(
        x, w["layers"][0]["wq"], w["layers"][0]["wk"], w["layers"][0]["wv"], w["layers"][0]["wo"]
    )
    assert y.shape == x.shape
    assert amax.shape == (CONFIG.max_seq,)
    assert amax.dtype == jnp.int32
    probs = gating_stage(y, w["layers"][0]["wg"])
    assert probs.shape == (CONFIG.max_seq, CONFIG.experts)
    e_out = expert_stage(y, *w["layers"][0]["experts"][0])
    assert e_out.shape == y.shape


def test_forward_kernels_matches_reference():
    w = init_weights()
    i = ids(7)
    got = forward_kernels(i, w)
    want = forward_reference(i, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_forward_deterministic():
    w = init_weights()
    i = ids(9)
    a = forward_reference(i, w)
    b = forward_reference(i, w)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_routing_is_skewed():
    """The tiny model's gate should produce non-uniform expert loads on a
    skewed token stream — the premise of the whole paper."""
    w = init_weights()
    i = ids(11)
    x = embed(i, w["wte"], w["wpe"])
    y, _ = attention_block(
        x, w["layers"][0]["wq"], w["layers"][0]["wk"], w["layers"][0]["wv"], w["layers"][0]["wo"]
    )
    probs = gating_stage(y, w["layers"][0]["wg"])
    counts = np.bincount(np.asarray(jnp.argmax(probs, -1)), minlength=CONFIG.experts)
    assert counts.max() > counts.min(), counts
