"""AOT pipeline tests: HLO text emission and manifest consistency."""

import json
import os
import subprocess
import sys

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_lowering_smoke():
    """Lower one stage in-process and sanity-check the HLO text."""
    import jax
    import jax.numpy as jnp
    from compile.aot import lower_stage, spec
    from compile.model import gating_stage

    text = lower_stage(gating_stage, (spec([16, 64]), spec([64, 4])))
    assert "HloModule" in text
    assert "f32[16,64]" in text
    # return_tuple=True wraps outputs in a tuple
    assert "(f32[16,4])" in text or "tuple" in text


@pytest.mark.skipif(not os.path.isdir(ART), reason="artifacts not built")
def test_manifest_matches_files():
    with open(os.path.join(ART, "manifest.json")) as fh:
        manifest = json.load(fh)
    assert manifest["config"]["hidden"] == 64
    assert manifest["token_buckets"] == [16, 64, 128, 256]
    for name, stage in manifest["stages"].items():
        path = os.path.join(ART, stage["file"])
        assert os.path.isfile(path), f"{name}: missing {stage['file']}"
        with open(path) as fh:
            head = fh.read(2000)
        assert "HloModule" in head, name


@pytest.mark.skipif(not os.path.isdir(ART), reason="artifacts not built")
def test_weight_blobs_match_manifest():
    import numpy as np

    with open(os.path.join(ART, "weights", "manifest.json")) as fh:
        wm = json.load(fh)
    assert "wte" in wm and "l0.e0.w1" in wm
    for name, shape in wm.items():
        path = os.path.join(ART, "weights", f"{name}.bin")
        data = np.fromfile(path, dtype=np.float32)
        assert data.size == int(np.prod(shape)), name
        assert np.isfinite(data).all(), name
