"""L2: the JAX MoE transformer, assembled from the L1 Pallas kernels.

The model is deliberately *stage-split*: each serving stage (embedding,
attention block, gating, expert FFN) is its own jittable function with
weights as runtime arguments, because on the serverless platform each stage
runs as a separate function with parameters fetched from external storage.
`aot.py` lowers each stage once per shape bucket to HLO text; the Rust
coordinator composes them at request time (Python never serves).

Tiny-MoE config (matches `ModelPreset::TinyMoe` on the Rust side):
  H=64, F=256, E=4 experts x L=2 MoE layers, vocab 1024, seq <= 64, top-1.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.expert_ffn import expert_ffn
from .kernels.gating import gating
from .kernels import ref


@dataclass(frozen=True)
class TinyMoeConfig:
    hidden: int = 64
    ffn_dim: int = 256
    experts: int = 4
    moe_layers: int = 2
    vocab: int = 1024
    max_seq: int = 64
    top_k: int = 1


CONFIG = TinyMoeConfig()


# ---------------------------------------------------------------- stages --
def embed(ids, wte, wpe):
    """Embedding stage. ids: [S] int32, wte: [V, H], wpe: [Smax, H]."""
    s = ids.shape[0]
    pos = jnp.arange(s)
    return wte[ids] + wpe[pos]


def attention_block(x, wq, wk, wv, wo):
    """Non-MoE block: fused attention (Pallas) + attention-source argmax."""
    return attention(x, wq, wk, wv, wo)


def gating_stage(x, wg):
    """Gating stage: expert probabilities (Pallas softmax kernel)."""
    return gating(x, wg)


def expert_stage(x, w1, b1, w2, b2):
    """One expert function's computation over its routed tokens (Pallas)."""
    return expert_ffn(x, w1, b1, w2, b2)


# ------------------------------------------------------------- reference --
def init_weights(cfg: TinyMoeConfig = CONFIG, seed: int = 0):
    """Deterministic weight pytree for the tiny model."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4 + cfg.moe_layers * (5 + 4 * cfg.experts))
    it = iter(range(len(ks)))

    def nxt(shape, scale):
        return (jax.random.normal(ks[next(it)], shape) * scale).astype(jnp.float32)

    h, f = cfg.hidden, cfg.ffn_dim
    w = {
        "wte": nxt((cfg.vocab, h), 0.02),
        "wpe": nxt((cfg.max_seq, h), 0.02),
        "layers": [],
    }
    for _ in range(cfg.moe_layers):
        layer = {
            "wq": nxt((h, h), h**-0.5),
            "wk": nxt((h, h), h**-0.5),
            "wv": nxt((h, h), h**-0.5),
            "wo": nxt((h, h), h**-0.5),
            "wg": nxt((h, cfg.experts), 0.15),
            "experts": [
                (
                    nxt((h, f), h**-0.5),
                    nxt((f,), 0.01),
                    nxt((f, h), f**-0.5),
                    nxt((h,), 0.01),
                )
                for _ in range(cfg.experts)
            ],
        }
        w["layers"].append(layer)
    return w


def forward_reference(ids, weights, cfg: TinyMoeConfig = CONFIG):
    """Whole-model dense reference (pure jnp) — the oracle the Rust serving
    path is validated against end to end. Returns the final hidden states.
    """
    x = ref.embed_ref(ids, weights["wte"], weights["wpe"])
    for layer in weights["layers"]:
        y, _scores = ref.attention_ref(
            x, layer["wq"], layer["wk"], layer["wv"], layer["wo"]
        )
        moe_out = ref.moe_layer_ref(y, layer["wg"], layer["experts"], cfg.top_k)
        x = y + moe_out
    return x


def forward_kernels(ids, weights, cfg: TinyMoeConfig = CONFIG):
    """Whole-model forward via the Pallas kernels, dense routing combine —
    used to validate kernel composition against `forward_reference`.
    """
    x = embed(ids, weights["wte"], weights["wpe"])
    for layer in weights["layers"]:
        y, _amax = attention_block(x, layer["wq"], layer["wk"], layer["wv"], layer["wo"])
        probs = gating_stage(y, layer["wg"])
        idx = jnp.argsort(-probs, axis=-1)[:, : cfg.top_k]
        out = jnp.zeros_like(y)
        for i in range(cfg.experts):
            sel = (idx == i).any(axis=-1)
            wgt = probs[:, i] * sel
            out = out + expert_stage(y, *layer["experts"][i]) * wgt[:, None]
        mass = jnp.take_along_axis(probs, idx, axis=-1).sum(axis=-1, keepdims=True)
        x = y + out / jnp.maximum(mass, 1e-9)
    return x
