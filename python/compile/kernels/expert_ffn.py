"""L1 Pallas kernel: the expert FFN — the MoE compute hot-spot.

TPU-minded tiling (DESIGN.md §Hardware-Adaptation): the token dimension is
split into MXU-friendly tiles via the grid; each grid step keeps one token
tile plus both weight matrices resident in VMEM (BlockSpec expresses the
HBM↔VMEM schedule the GPU original would do with threadblocks). Runs in
interpret mode on CPU — real-TPU lowering would emit a Mosaic custom-call
the CPU PJRT plugin cannot execute.

VMEM footprint per grid step (f32):
    tile·H (x) + H·F (w1) + F (b1) + F·H (w2) + H (b2) + tile·F (hidden)
For the tiny config (H=64, F=256, tile=128): ≈ 0.40 MB — far under the
~16 MB VMEM budget, leaving room to scale H/F ~6× per dimension.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import gelu

# Token-dimension tile: one MXU-major block per grid step.
TILE_T = 128


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One token tile through the whole FFN (both matmuls fused in VMEM)."""
    x = x_ref[...]
    h = gelu(
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...]
    )
    o_ref[...] = (
        jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...]
    )


@functools.partial(jax.jit, static_argnames=())
def expert_ffn(x, w1, b1, w2, b2):
    """Pallas expert FFN. x: [T, H] with T a multiple of TILE_T or smaller.

    Weights are broadcast to every grid step (index_map pins block 0);
    tokens are tiled along the grid.
    """
    t, h = x.shape
    f = w1.shape[1]
    if t <= TILE_T:
        # Single block — no grid.
        return pl.pallas_call(
            _ffn_kernel,
            out_shape=jax.ShapeDtypeStruct((t, h), x.dtype),
            interpret=True,
        )(x, w1, b1, w2, b2)
    assert t % TILE_T == 0, f"token count {t} not a multiple of {TILE_T}"
    grid = (t // TILE_T,)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_T, h), lambda i: (i, 0)),
            pl.BlockSpec((h, f), lambda i: (0, 0)),
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_T, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, h), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)


def vmem_bytes(tile_t: int, hidden: int, ffn_dim: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of one grid step (see module docstring)."""
    return dtype_bytes * (
        tile_t * hidden  # x tile
        + hidden * ffn_dim  # w1
        + ffn_dim  # b1
        + ffn_dim * hidden  # w2
        + hidden  # b2
        + tile_t * ffn_dim  # hidden activations
        + tile_t * hidden  # output tile
    )


def mxu_utilization_estimate(tile_t: int, hidden: int, ffn_dim: int) -> float:
    """Fraction of MXU-shaped work: both matmuls are dense [tile,H]x[H,F];
    with tile ≥ 128 and H,F multiples of 64 the systolic array is fully fed
    except for the GELU epilogue (VPU). Returns FLOPs(matmul)/FLOPs(total).
    """
    matmul = 2 * tile_t * hidden * ffn_dim * 2  # two matmuls
    epilogue = tile_t * ffn_dim * 10  # gelu ~10 flops/elem
    return matmul / (matmul + epilogue)
