"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: pytest asserts the Pallas kernels
(interpret mode) match these to float tolerance, and the Rust runtime's
numerics are validated against HLO lowered from the same functions.
"""

import jax
import jax.numpy as jnp


def gelu(x):
    """tanh-approximation GELU (matches jax.nn.gelu(approximate=True))."""
    return 0.5 * x * (1.0 + jnp.tanh(jnp.sqrt(2.0 / jnp.pi) * (x + 0.044715 * x**3)))


def expert_ffn_ref(x, w1, b1, w2, b2):
    """Expert FFN: GELU(x @ W1 + b1) @ W2 + b2.

    x: [T, H], w1: [H, F], b1: [F], w2: [F, H], b2: [H] -> [T, H]
    """
    h = gelu(x @ w1 + b1)
    return h @ w2 + b2


def gating_ref(x, wg):
    """Gating network: softmax(x @ Wg) over experts.

    x: [T, H], wg: [H, E] -> probs [T, E]
    """
    logits = x @ wg
    logits = logits - logits.max(axis=-1, keepdims=True)
    e = jnp.exp(logits)
    return e / e.sum(axis=-1, keepdims=True)


def attention_ref(x, wq, wk, wv, wo):
    """Single-head self-attention block with residual.

    x: [S, H]; wq/wk/wv/wo: [H, H].
    Returns (y [S, H], scores [S, S]) where scores are the softmax attention
    weights; row t's argmax defines token t's attention ID (§III-B).
    """
    q = x @ wq
    k = x @ wk
    v = x @ wv
    scale = 1.0 / jnp.sqrt(jnp.asarray(x.shape[-1], dtype=x.dtype))
    logits = (q @ k.T) * scale
    logits = logits - logits.max(axis=-1, keepdims=True)
    e = jnp.exp(logits)
    scores = e / e.sum(axis=-1, keepdims=True)
    y = (scores @ v) @ wo + x
    return y, scores


def attention_id_ref(scores, token_ids):
    """Attention ID: for each query position, the token ID of the source
    position receiving its highest attention weight.

    scores: [S, S] (rows = queries), token_ids: [S] -> [S]
    """
    best_src = jnp.argmax(scores, axis=-1)
    return token_ids[best_src]


def moe_layer_ref(x, wg, experts, top_k=1):
    """Full MoE layer: gate, route top-k, weighted-combine expert outputs.

    x: [T, H]; wg: [H, E]; experts: list of (w1, b1, w2, b2) tuples.
    Dense reference (every expert computes every token, then masks) — the
    serving system computes only routed tokens; results must match.
    """
    probs = gating_ref(x, wg)
    e_count = probs.shape[-1]
    idx = jnp.argsort(-probs, axis=-1)[:, :top_k]  # [T, k]
    out = jnp.zeros_like(x)
    for i in range(e_count):
        sel = (idx == i).any(axis=-1)  # [T]
        w = probs[:, i] * sel
        y = expert_ffn_ref(x, *experts[i])
        out = out + y * w[:, None]
    mass = jnp.take_along_axis(probs, idx, axis=-1).sum(axis=-1, keepdims=True)
    return out / jnp.maximum(mass, 1e-9)


def embed_ref(ids, wte, wpe):
    """Token + position embedding. ids: [S] int32 -> [S, H]."""
    pos = jnp.arange(ids.shape[0])
    return wte[ids] + wpe[pos]


__all__ = [
    "gelu",
    "expert_ffn_ref",
    "gating_ref",
    "attention_ref",
    "attention_id_ref",
    "moe_layer_ref",
    "embed_ref",
]

_ = jax  # re-exported convenience for tests
