"""L1 Pallas kernel: fused single-head self-attention with residual, plus
the per-query argmax attention source needed for the attention-ID feature
(§III-B).

The whole [S, S] score matrix for the tiny model's sequence lengths fits in
VMEM, so the kernel fuses QKV projection, softmax, context matmul, output
projection and residual in one pass, and emits the argmax source position as
a second output (the rust side maps positions to token IDs).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(x_ref, wq_ref, wk_ref, wv_ref, wo_ref, y_ref, amax_ref):
    x = x_ref[...]
    q = jnp.dot(x, wq_ref[...], preferred_element_type=jnp.float32)
    k = jnp.dot(x, wk_ref[...], preferred_element_type=jnp.float32)
    v = jnp.dot(x, wv_ref[...], preferred_element_type=jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(x.shape[-1], dtype=x.dtype))
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    scores = e / jnp.sum(e, axis=-1, keepdims=True)
    ctx = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    y_ref[...] = jnp.dot(ctx, wo_ref[...], preferred_element_type=jnp.float32) + x
    amax_ref[...] = jnp.argmax(scores, axis=-1).astype(jnp.int32)


def attention(x, wq, wk, wv, wo):
    """Fused attention. x: [S, H] -> (y [S, H], argmax_src [S] int32)."""
    s, h = x.shape
    return pl.pallas_call(
        _attn_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((s, h), x.dtype),
            jax.ShapeDtypeStruct((s,), jnp.int32),
        ),
        interpret=True,
    )(x, wq, wk, wv, wo)
