"""L1 Pallas kernel: the gating network (scores + softmax), fused in VMEM.

The gate is a single linear layer over the hidden state followed by softmax
over the (small) expert dimension — one VMEM-resident block per token tile.
Top-k extraction happens in the jnp wrapper (dynamic gather lowers poorly
inside a kernel and costs nothing outside it).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_T = 128


def _gating_kernel(x_ref, wg_ref, o_ref):
    logits = jnp.dot(x_ref[...], wg_ref[...], preferred_element_type=jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def gating(x, wg):
    """Pallas gating probs. x: [T, H], wg: [H, E] -> [T, E]."""
    t, h = x.shape
    e = wg.shape[1]
    if t <= TILE_T:
        return pl.pallas_call(
            _gating_kernel,
            out_shape=jax.ShapeDtypeStruct((t, e), x.dtype),
            interpret=True,
        )(x, wg)
    assert t % TILE_T == 0, f"token count {t} not a multiple of {TILE_T}"
    return pl.pallas_call(
        _gating_kernel,
        grid=(t // TILE_T,),
        in_specs=[
            pl.BlockSpec((TILE_T, h), lambda i: (i, 0)),
            pl.BlockSpec((h, e), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_T, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t, e), x.dtype),
        interpret=True,
    )(x, wg)


def gating_topk(x, wg, k: int):
    """Gating probs + top-k expert indices. Returns (probs [T,E], idx [T,k])."""
    probs = gating(x, wg)
    idx = jnp.argsort(-probs, axis=-1)[:, :k]
    return probs, idx.astype(jnp.int32)
