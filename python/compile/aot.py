"""AOT lowering: JAX stages -> HLO *text* artifacts + raw weight blobs.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (under --out-dir, default ../artifacts):
  <stage>_<bucket>.hlo.txt      one per stage x token-count bucket
  manifest.json                 stage -> {file, args: [(name, shape, dtype)]}
  weights/<name>.bin            raw little-endian f32 blobs
  weights/manifest.json         name -> shape

Python runs ONCE at build time; the Rust binary is self-contained after.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import CONFIG, embed, attention_block, gating_stage, expert_stage, init_weights

# Token-count buckets compiled for token-parallel stages. The Rust batcher
# pads each expert's routed minibatch up to the nearest bucket.
TOKEN_BUCKETS = [16, 64, 128, 256]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_stage(fn, example_args):
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def main() -> None:
    parser = argparse.ArgumentParser()
    parser.add_argument("--out", default=None, help="(legacy) single-file sentinel")
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--seed", type=int, default=0)
    args = parser.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or out_dir
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)

    cfg = CONFIG
    h, f, e, v, s = cfg.hidden, cfg.ffn_dim, cfg.experts, cfg.vocab, cfg.max_seq
    manifest = {
        "config": {
            "hidden": h,
            "ffn_dim": f,
            "experts": e,
            "moe_layers": cfg.moe_layers,
            "vocab": v,
            "max_seq": s,
            "top_k": cfg.top_k,
        },
        "token_buckets": TOKEN_BUCKETS,
        "stages": {},
    }

    def emit(name, fn, example_args, arg_desc):
        text = lower_stage(fn, example_args)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as fh:
            fh.write(text)
        manifest["stages"][name] = {
            "file": fname,
            "args": [
                {"name": n, "shape": list(a.shape), "dtype": str(a.dtype)}
                for n, a in zip(arg_desc, example_args)
            ],
        }
        print(f"  wrote {fname} ({len(text)} chars)")

    print("lowering stages...")
    # Embedding: full sequence.
    emit(
        f"embed_s{s}",
        embed,
        (spec([s], jnp.int32), spec([v, h]), spec([s, h])),
        ["ids", "wte", "wpe"],
    )
    # Attention block: full sequence.
    emit(
        f"attention_s{s}",
        attention_block,
        (spec([s, h]), spec([h, h]), spec([h, h]), spec([h, h]), spec([h, h])),
        ["x", "wq", "wk", "wv", "wo"],
    )
    # Gating + expert FFN: one HLO per token bucket.
    for t in TOKEN_BUCKETS:
        emit(
            f"gating_t{t}",
            gating_stage,
            (spec([t, h]), spec([h, e])),
            ["x", "wg"],
        )
        emit(
            f"expert_ffn_t{t}",
            expert_stage,
            (spec([t, h]), spec([h, f]), spec([f]), spec([f, h]), spec([h])),
            ["x", "w1", "b1", "w2", "b2"],
        )

    # Weights.
    print("exporting weights...")
    weights = init_weights(cfg, args.seed)
    wmanifest = {}

    def dump(name, arr):
        arr = np.asarray(arr, dtype=np.float32)
        arr.tofile(os.path.join(out_dir, "weights", f"{name}.bin"))
        wmanifest[name] = list(arr.shape)

    dump("wte", weights["wte"])
    dump("wpe", weights["wpe"])
    for li, layer in enumerate(weights["layers"]):
        for wn in ["wq", "wk", "wv", "wo", "wg"]:
            dump(f"l{li}.{wn}", layer[wn])
        for ei, (w1, b1, w2, b2) in enumerate(layer["experts"]):
            dump(f"l{li}.e{ei}.w1", w1)
            dump(f"l{li}.e{ei}.b1", b1)
            dump(f"l{li}.e{ei}.w2", w2)
            dump(f"l{li}.e{ei}.b2", b2)

    with open(os.path.join(out_dir, "weights", "manifest.json"), "w") as fh:
        json.dump(wmanifest, fh, indent=2, sort_keys=True)

    # Golden end-to-end output: the Rust serving path must reproduce the
    # dense reference forward on this input (cross-layer validation).
    from .model import forward_reference

    rng = np.random.RandomState(1234)
    golden_ids = rng.randint(0, v, size=s).astype(np.int32)
    hidden = np.asarray(forward_reference(jnp.asarray(golden_ids), weights))
    golden = {
        "ids": golden_ids.tolist(),
        "hidden_norm": float(np.linalg.norm(hidden)),
        "hidden_head": hidden.reshape(-1)[:16].tolist(),
        "shape": list(hidden.shape),
    }
    with open(os.path.join(out_dir, "golden.json"), "w") as fh:
        json.dump(golden, fh, indent=2)
    with open(os.path.join(out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2, sort_keys=True)
    # Legacy sentinel for the Makefile dependency.
    if args.out is not None:
        with open(args.out, "w") as fh:
            fh.write("# see manifest.json; stages are split per shape bucket\n")
    print(f"done: {len(manifest['stages'])} stages, {len(wmanifest)} weight blobs -> {out_dir}")


if __name__ == "__main__":
    main()
