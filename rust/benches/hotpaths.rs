//! Hot-path micro-benches for the §Perf pass (EXPERIMENTS.md):
//!  - Bayesian posterior prediction throughput (tokens/s)
//!  - per-expert option enumeration + layer candidate generation
//!  - fixed-method MIQCP solve and full ODS
//!  - GP surrogate fit+predict
//!  - PJRT expert-FFN invocation throughput (when artifacts exist)
//!
//! `cargo bench --bench hotpaths`

use serverless_moe::config::workload::CorpusPreset;
use serverless_moe::config::Config;
use serverless_moe::deploy::miqcp::solve_fixed_method;
use serverless_moe::deploy::ods::ods_full;
use serverless_moe::experiments::common::ExpContext;
use serverless_moe::model::ModelPreset;
use serverless_moe::predictor::ExpertPredictor;
use std::time::Instant;

fn timeit<T>(name: &str, reps: usize, mut f: impl FnMut() -> T) -> f64 {
    // Warm-up.
    let _ = f();
    let t0 = Instant::now();
    for _ in 0..reps {
        std::hint::black_box(f());
    }
    let per = t0.elapsed().as_secs_f64() / reps as f64;
    println!("{name:<44} {:>12}/iter  ({reps} reps)", serverless_moe::util::table::ftime(per));
    per
}

fn main() {
    println!("== hot-path micro benches ==\n");
    let mut ctx = ExpContext::new(
        ModelPreset::BertMoe { experts: 4, top_k: 1 },
        CorpusPreset::Enwik8,
        true,
    );
    let batch = ctx.eval_batch();
    let bayes = ctx.bayes();
    let tokens: Vec<(u32, u32)> = batch.tokens().map(|(t, p, _)| (t, p)).collect();

    // Posterior prediction throughput.
    let per = timeit("bayes predict_counts (1 layer, batch)", 10, || {
        bayes.predict_counts(0, 4, &tokens, 1)
    });
    println!(
        "{:<44} {:>12.0} tokens/s",
        "  -> prediction throughput",
        tokens.len() as f64 / per
    );

    // Lina baseline for comparison.
    let per_lina = timeit("lina predict_counts (1 layer, batch)", 10, || {
        ctx.profile.lina.predict_counts(0, 4, &tokens, 1)
    });
    println!(
        "{:<44} {:>12.0} tokens/s",
        "  -> lina throughput",
        tokens.len() as f64 / per_lina
    );

    // Deployment machinery.
    let counts = ctx.real_counts(&batch);
    let problem = ctx.problem(counts.clone(), 3000.0);
    timeit("layer candidates (indirect, 1 layer)", 20, || {
        serverless_moe::deploy::layer_opt::layer_candidates(
            &ctx.config.platform,
            &ctx.spec,
            0,
            &problem.tokens[0],
            serverless_moe::comm::CommMethod::Indirect,
            &problem.beta_grid,
            8,
            true,
        )
    });
    timeit("solve_fixed_method (indirect, 12 layers)", 5, || {
        solve_fixed_method(&problem, serverless_moe::comm::CommMethod::Indirect, 5.0)
    });
    timeit("ods_full (3 solves + Alg.1)", 3, || ods_full(&problem, 5.0));

    // GP surrogate.
    let vars: Vec<serverless_moe::bo::BoVar> = {
        let mut rng = serverless_moe::util::rng::Rng::new(3);
        let experts = vec![4usize; 12];
        let hist: Vec<serverless_moe::bo::TrialRecord> = vec![];
        let lim: Vec<u32> = vec![];
        let mut pctx = serverless_moe::bo::ProposeCtx {
            history: &hist,
            limited_tokens: &lim,
            vocab: 16_384,
            experts_per_layer: &experts,
            q: 256,
            trial: 0,
            rng: &mut rng,
        };
        (0..256).map(|_| pctx.random_var()).collect()
    };
    timeit("gp embed (256 vars, 16 dims)", 200, || {
        serverless_moe::bo::gp::embed(&vars, 16)
    });
    let xs: Vec<Vec<f64>> = (0..40)
        .map(|i| {
            (0..16)
                .map(|d| ((i * 7 + d * 3) % 13) as f64 / 13.0)
                .collect()
        })
        .collect();
    let ys: Vec<f64> = (0..40).map(|i| (i % 9) as f64).collect();
    timeit("gp fit (40 points, 16 dims)", 50, || {
        serverless_moe::bo::gp::Gp::fit(xs.clone(), &ys, 0.5, 1e-4)
    });

    // Real PJRT path.
    if serverless_moe::runtime::artifacts_available() {
        let platform = Config::default().platform;
        let mut svc = serverless_moe::coordinator::MoeService::new(
            &serverless_moe::runtime::default_artifacts_dir(),
            platform,
        )
        .unwrap();
        svc.engine.load_all().unwrap();
        let ids: Vec<u32> = (0..64).map(|i| (i * 13) % 1024).collect();
        let per = timeit("pjrt serve_sequence (64 tokens, 2 layers)", 10, || {
            svc.serve_sequence(&ids).unwrap()
        });
        println!(
            "{:<44} {:>12.0} tokens/s",
            "  -> pjrt serving throughput",
            64.0 / per
        );
    } else {
        println!("(artifacts missing — skipping PJRT benches)");
    }
}
