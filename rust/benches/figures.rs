//! End-to-end benches: one timed run per paper table/figure (quick scale),
//! printing the regenerated rows. `cargo bench --bench figures`.
//!
//! Criterion is not in the offline vendor set; this is a plain
//! harness=false bench with wall-clock timing and N repeats for stability.

use serverless_moe::experiments;
use std::time::Instant;

fn bench_one(id: &str) {
    // Warm-up run (also prints the table once — the paper rows).
    let t0 = Instant::now();
    let tables = experiments::run(id, true).expect("experiment runs");
    let first = t0.elapsed().as_secs_f64();
    for t in &tables {
        t.print();
    }
    // Timed repeats.
    let reps = 3;
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let _ = experiments::run(id, true).unwrap();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / reps as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "bench {id:>9}: first {first:.3}s, repeat mean {mean:.3}s, min {min:.3}s\n"
    );
}

fn main() {
    println!("== figure-regeneration benches (quick scale) ==\n");
    for id in experiments::ALL {
        bench_one(id);
    }
}
