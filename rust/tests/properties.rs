//! Property-based tests (in-repo `util::check` harness) on the coordinator
//! invariants: routing conservation, batching, timing monotonicity, billing
//! non-negativity, ODS bounds, ε-schedule ordering.

use serverless_moe::comm::{layer_cost, layer_latency, CommMethod, ExpertPlan, LayerPlan};
use serverless_moe::config::PlatformConfig;
use serverless_moe::gating::{SimGate, TokenFeature};
use serverless_moe::model::ModelPreset;
use serverless_moe::util::check::{ensure, forall, forall_default, Config};
use serverless_moe::util::rng::Rng;

fn rand_plan(rng: &mut Rng, method: CommMethod) -> (LayerPlan, PlatformConfig) {
    let cfg = PlatformConfig::default();
    let n = 1 + rng.index(8);
    let experts = (0..n)
        .map(|_| ExpertPlan {
            mem_mb: *rng.choose(&cfg.memory_options_mb.clone()),
            replicas: 1 + rng.index(8),
            tokens: rng.below(5000),
        })
        .collect();
    (
        LayerPlan {
            method,
            beta: 1 + rng.index(2048),
            experts,
        },
        cfg,
    )
}

#[test]
fn prop_costs_and_latencies_nonnegative_finite() {
    let spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
    for method in CommMethod::ALL {
        forall_default(
            |rng| rand_plan(rng, method).0,
            |plan| {
                let cfg = PlatformConfig::default();
                let c = layer_cost(&cfg, &spec, 0, plan, true);
                let l = layer_latency(&cfg, &spec, 0, plan, true);
                ensure(c.is_finite() && c >= 0.0, format!("cost {c}"))?;
                ensure(l.is_finite() && l >= 0.0, format!("latency {l}"))
            },
        );
    }
}

#[test]
fn prop_cost_monotone_in_tokens() {
    let spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
    forall_default(
        |rng| {
            let (mut plan, cfg) = rand_plan(rng, CommMethod::Indirect);
            let extra = 1 + rng.below(2000);
            (plan.clone(), {
                for ep in plan.experts.iter_mut() {
                    ep.tokens += extra;
                }
                plan
            }, cfg)
        },
        |(small, big, cfg)| {
            let c_small = layer_cost(cfg, &spec, 0, small, true);
            let c_big = layer_cost(cfg, &spec, 0, big, true);
            ensure(
                c_big >= c_small - 1e-12,
                format!("more tokens cheaper?! {c_small} -> {c_big}"),
            )
        },
    );
}

#[test]
fn prop_warm_never_slower_than_cold() {
    let spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
    for method in CommMethod::ALL {
        forall_default(
            |rng| rand_plan(rng, method).0,
            |plan| {
                let cfg = PlatformConfig::default();
                let warm = layer_latency(&cfg, &spec, 0, plan, true);
                let cold = layer_latency(&cfg, &spec, 0, plan, false);
                ensure(warm <= cold + 1e-9, format!("warm {warm} > cold {cold}"))
            },
        );
    }
}

#[test]
fn prop_routing_conserves_tokens() {
    let spec = ModelPreset::BertMoe { experts: 8, top_k: 2 }.spec();
    let gate = SimGate::new(&spec, 99);
    forall(
        Config { cases: 50, ..Default::default() },
        |rng| {
            (0..200u32)
                .map(|i| TokenFeature {
                    token_id: rng.below(30_000) as u32,
                    position_id: i,
                    attention_id: rng.below(30_000) as u32,
                })
                .collect::<Vec<_>>()
        },
        |tokens| {
            let mut counts = vec![0u64; 8];
            for f in tokens {
                let sel = gate.route_token(3, f);
                ensure(sel.len() == 2, "top-2 must select 2")?;
                ensure(sel[0] != sel[1], "distinct experts")?;
                for &e in &sel {
                    counts[e as usize] += 1;
                }
            }
            ensure(
                counts.iter().sum::<u64>() == tokens.len() as u64 * 2,
                "token conservation",
            )
        },
    );
}

#[test]
fn prop_batcher_chunks_conserve() {
    use serverless_moe::coordinator::batcher::chunks;
    forall_default(
        |rng| (rng.below(100_000) as usize, 1 + rng.index(4096)),
        |&(n, max)| {
            let cs = chunks(n, max);
            ensure(cs.iter().sum::<usize>() == n, "chunks must sum to n")?;
            ensure(cs.iter().all(|&c| c > 0 && c <= max), "chunk bounds")
        },
    );
}

#[test]
fn prop_replicas_never_increase_straggler_time() {
    let spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
    let cfg = PlatformConfig::default();
    forall_default(
        |rng| (rng.below(20_000) + 1, 1 + rng.index(7)),
        |&(tokens, g)| {
            let one = ExpertPlan { mem_mb: 3072, replicas: 1, tokens };
            let many = ExpertPlan { mem_mb: 3072, replicas: g + 1, tokens };
            let t1 = serverless_moe::comm::replica_time(
                &cfg, &spec, 0, &one, CommMethod::Indirect, 1, true,
            );
            let tg = serverless_moe::comm::replica_time(
                &cfg, &spec, 0, &many, CommMethod::Indirect, 1, true,
            );
            ensure(tg <= t1 + 1e-9, format!("replicas slower: {t1} -> {tg}"))
        },
    );
}

#[test]
fn prop_eps_schedule_ordering_and_decay() {
    use serverless_moe::bo::eps_greedy::{EpsSchedule, FeedbackCase};
    use serverless_moe::config::BoConfig;
    forall_default(
        |rng| (rng.index(50), rng.index(1000)),
        |&(tau, dim)| {
            let cfg = BoConfig::default();
            let s = EpsSchedule::new(&cfg);
            let e_now = s.eps(dim, tau);
            let e_later = s.eps(dim, tau + 10);
            ensure(e_now <= 1.0 && e_now >= 0.0, "eps in range")?;
            ensure(e_later <= e_now + 1e-12, "eps decays")?;
            // Case ordering under feedback.
            let mut a = EpsSchedule::new(&cfg);
            let mut b = EpsSchedule::new(&cfg);
            a.apply_feedback(FeedbackCase::MemoryShortfall, tau.max(1));
            b.apply_feedback(FeedbackCase::Feasible, tau.max(1));
            ensure(a.eps(0, tau) >= b.eps(0, tau), "case-i slows decay most")
        },
    );
}

#[test]
fn prop_json_roundtrip_fuzz() {
    use serverless_moe::util::json::Json;
    forall(
        Config { cases: 300, ..Default::default() },
        |rng| {
            // random JSON tree
            fn gen(rng: &mut Rng, depth: usize) -> Json {
                match if depth > 3 { rng.index(4) } else { rng.index(6) } {
                    0 => Json::Null,
                    1 => Json::Bool(rng.chance(0.5)),
                    2 => Json::Num((rng.f64() - 0.5) * 1e6),
                    3 => Json::Str(format!("s{}-\"quote\ntab\t{}", rng.below(100), rng.below(10))),
                    4 => Json::Arr((0..rng.index(5)).map(|_| gen(rng, depth + 1)).collect()),
                    _ => {
                        let mut m = std::collections::BTreeMap::new();
                        for i in 0..rng.index(5) {
                            m.insert(format!("k{i}"), gen(rng, depth + 1));
                        }
                        Json::Obj(m)
                    }
                }
            }
            gen(rng, 0)
        },
        |v| {
            let compact = Json::parse(&v.to_string_compact()).map_err(|e| e.to_string())?;
            let pretty = Json::parse(&v.to_string_pretty()).map_err(|e| e.to_string())?;
            // Numbers may lose precision only via formatting — we format with
            // full precision, so equality must hold.
            ensure(&compact == v, "compact roundtrip")?;
            ensure(&pretty == v, "pretty roundtrip")
        },
    );
}
