//! Integration: the full simulator pipeline — workload → profile → predict
//! → deploy (ODS) → serve-with-real-counts — composes and satisfies the
//! paper's directional claims at quick scale.

use serverless_moe::bo::feedback::serve_with_real_counts;
use serverless_moe::config::workload::CorpusPreset;
use serverless_moe::deploy::baselines::lambdaml_policy;
use serverless_moe::deploy::ods::ods_full;
use serverless_moe::experiments::common::ExpContext;
use serverless_moe::model::ModelPreset;
use serverless_moe::platform::CpuCluster;
use serverless_moe::predictor::eval::{evaluate, predicted_counts};

fn pipeline(preset: ModelPreset) -> (f64, f64, f64) {
    let mut ctx = ExpContext::new(preset, CorpusPreset::Enwik8, true);
    ctx.generator.target_tokens = 4096;
    let batch = ctx.eval_batch();
    let bayes = ctx.bayes();
    let pred = predicted_counts(&ctx.gate, &bayes, &batch);
    let real = ctx.real_counts(&batch);
    let problem = ctx.problem(pred, 3000.0);
    let ods = ods_full(&problem, 2.0).expect("deployable");
    let served = serve_with_real_counts(&ctx.config.platform, &ctx.spec, &ods.policy, &real, true);
    let lam = lambdaml_policy(&problem).total_cost(&ctx.config.platform, &ctx.spec, true);
    let cpu = CpuCluster::new(ctx.config.cpu_cluster.clone(), false)
        .serve(&ctx.spec, &real, batch.total_tokens)
        .billed_cost;
    (served.cost, lam, cpu)
}

#[test]
fn bert_pipeline_headline_directions() {
    let (ours, lambdaml, cpu) = pipeline(ModelPreset::BertMoe { experts: 4, top_k: 1 });
    assert!(ours > 0.0);
    assert!(ours < lambdaml, "ours {ours} vs lambdaml {lambdaml}");
    assert!(ours < cpu * 0.25, "ours {ours} vs cpu {cpu} (>=75% saving)");
}

#[test]
fn gpt2_pipeline_headline_directions() {
    let (ours, lambdaml, cpu) = pipeline(ModelPreset::Gpt2Moe { top_k: 1 });
    assert!(ours < lambdaml * 1.02, "ours {ours} vs lambdaml {lambdaml}");
    assert!(ours < cpu, "ours {ours} vs cpu {cpu}");
}

#[test]
fn prediction_quality_transfers_to_cost() {
    // Deploying on Bayes predictions must not cost meaningfully more than
    // deploying on the oracle (real) distribution.
    let mut ctx = ExpContext::new(
        ModelPreset::BertMoe { experts: 4, top_k: 1 },
        CorpusPreset::Enwik8,
        true,
    );
    ctx.generator.target_tokens = 4096;
    let batch = ctx.eval_batch();
    let bayes = ctx.bayes();
    let e = evaluate(&ctx.gate, &bayes, &batch);
    assert!(e.overall.is_finite());
    let pred = predicted_counts(&ctx.gate, &bayes, &batch);
    let real = ctx.real_counts(&batch);

    let p_pred = ctx.problem(pred, 3000.0);
    let p_real = ctx.problem(real.clone(), 3000.0);
    let ods_pred = ods_full(&p_pred, 2.0).unwrap();
    let ods_real = ods_full(&p_real, 2.0).unwrap();
    let served_pred =
        serve_with_real_counts(&ctx.config.platform, &ctx.spec, &ods_pred.policy, &real, true);
    let served_real =
        serve_with_real_counts(&ctx.config.platform, &ctx.spec, &ods_real.policy, &real, true);
    assert!(
        served_pred.cost <= served_real.cost * 1.6,
        "pred-deploy {} vs oracle-deploy {}",
        served_pred.cost,
        served_real.cost
    );
}

#[test]
fn tighter_slo_never_cheaper() {
    let mut ctx = ExpContext::new(
        ModelPreset::BertMoe { experts: 4, top_k: 1 },
        CorpusPreset::Enwik8,
        true,
    );
    ctx.generator.target_tokens = 4096;
    let batch = ctx.eval_batch();
    let real = ctx.real_counts(&batch);
    let mut prev_cost = 0.0;
    for t_limit in [3000.0, 1200.0, 700.0] {
        let problem = ctx.problem(real.clone(), t_limit);
        if let Some(ods) = ods_full(&problem, 2.0) {
            if ods.feasible {
                assert!(
                    ods.total_cost >= prev_cost - 1e-9,
                    "cost must not drop as SLO tightens: {} then {}",
                    prev_cost,
                    ods.total_cost
                );
                prev_cost = ods.total_cost;
            }
        }
    }
}
