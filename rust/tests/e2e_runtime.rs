//! Integration: the Rust PJRT serving path must reproduce the python dense
//! reference end to end (golden.json emitted by aot.py), and the threaded
//! server must serve concurrent clients.

use serverless_moe::config::PlatformConfig;
use serverless_moe::coordinator::{MoeService, Server};
use serverless_moe::runtime::{default_artifacts_dir, serving_available};
use serverless_moe::util::json::Json;

fn golden() -> Option<(Vec<u32>, f64, Vec<f64>)> {
    let path = default_artifacts_dir().join("golden.json");
    let j = Json::read_file(&path).ok()?;
    let ids: Vec<u32> = j
        .get("ids")?
        .as_arr()?
        .iter()
        .filter_map(|x| x.as_u64().map(|v| v as u32))
        .collect();
    let norm = j.get_f64("hidden_norm")?;
    let head: Vec<f64> = j
        .get("hidden_head")?
        .as_arr()?
        .iter()
        .filter_map(Json::as_f64)
        .collect();
    Some((ids, norm, head))
}

#[test]
fn serving_matches_python_reference() {
    if !serving_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`) or no real PJRT backend");
        return;
    }
    let (ids, want_norm, want_head) = golden().expect("golden.json present");
    let mut svc = MoeService::new(&default_artifacts_dir(), PlatformConfig::default()).unwrap();
    let res = svc.serve_sequence(&ids).unwrap();
    let norm: f64 = res
        .hidden
        .data
        .iter()
        .map(|&x| (x as f64) * (x as f64))
        .sum::<f64>()
        .sqrt();
    assert!(
        (norm - want_norm).abs() / want_norm < 1e-3,
        "norm {norm} vs golden {want_norm}"
    );
    for (i, (&got, &want)) in res.hidden.data.iter().zip(&want_head).enumerate() {
        assert!(
            (got as f64 - want).abs() < 1e-2 + want.abs() * 1e-3,
            "elem {i}: {got} vs {want}"
        );
    }
    // Features extracted for every layer, every position.
    assert_eq!(res.features.len(), 2);
    assert_eq!(res.features[0].len(), 64);
    // Expert counts cover all routed tokens (top-1 → exactly S assignments).
    for counts in &res.expert_counts {
        assert_eq!(counts.iter().sum::<u64>(), 64);
    }
    // Billing was metered.
    assert!(svc.metrics.billed_cost > 0.0);
    assert!(svc.metrics.invocations > 0);
}

#[test]
fn serving_is_deterministic() {
    if !serving_available() {
        return;
    }
    let (ids, _, _) = golden().unwrap();
    let mut svc = MoeService::new(&default_artifacts_dir(), PlatformConfig::default()).unwrap();
    let a = svc.serve_sequence(&ids).unwrap();
    let b = svc.serve_sequence(&ids).unwrap();
    assert_eq!(a.hidden.data, b.hidden.data);
    assert_eq!(a.expert_counts, b.expert_counts);
}

#[test]
fn threaded_server_serves_concurrent_clients() {
    if !serving_available() {
        return;
    }
    let server = Server::start(default_artifacts_dir(), PlatformConfig::default()).unwrap();
    let server = std::sync::Arc::new(server);
    let mut handles = Vec::new();
    for c in 0..4u32 {
        let s = server.clone();
        handles.push(std::thread::spawn(move || {
            let ids: Vec<u32> = (0..64).map(|i| (i * 7 + c * 131) % 1024).collect();
            s.serve(ids).unwrap()
        }));
    }
    let mut norms = Vec::new();
    for h in handles {
        let resp = h.join().unwrap();
        assert!(resp.output_norm.is_finite() && resp.output_norm > 0.0);
        assert!(resp.latency > 0.0);
        norms.push(resp.output_norm);
    }
    // Different inputs → different outputs.
    assert!(norms.windows(2).any(|w| (w[0] - w[1]).abs() > 1e-9));
    let server = std::sync::Arc::try_unwrap(server).ok().unwrap();
    let metrics = server.shutdown();
    assert_eq!(metrics.request_latencies.len(), 4);
    assert!(metrics.throughput_tps() > 0.0);
}

#[test]
fn routed_sparse_equals_dense_reference_routing() {
    // The service's top-1 routing must agree with gating probs argmax.
    if !serving_available() {
        return;
    }
    let mut svc = MoeService::new(&default_artifacts_dir(), PlatformConfig::default()).unwrap();
    let ids: Vec<u32> = (0..64).map(|i| (i * 13) % 1024).collect();
    let res = svc.serve_sequence(&ids).unwrap();
    // At least two experts used somewhere (skew exists but not degenerate
    // for this seed/model).
    let used: usize = res.expert_counts[0].iter().filter(|&&c| c > 0).count();
    assert!(used >= 2, "counts: {:?}", res.expert_counts);
}
