//! Scenario-API integration tests: the committed reference scenario files
//! under `rust/tests/data/scenarios/` must load under strict parsing,
//! round-trip through serialization losslessly, and — run twice (file-loaded
//! vs re-serialized, and file-loaded vs builder-constructed) — produce
//! byte-identical `SimReport` JSON. Plus the typed-error contract: unknown
//! fields and invalid values are rejected with matchable variants.

use serverless_moe::config::workload::CorpusPreset;
use serverless_moe::traffic::scenario::{Baseline, Scenario, TrafficSource};
use serverless_moe::traffic::trace::{Trace, TraceRequest};
use serverless_moe::traffic::{ScenarioError, TrafficConfig};
use serverless_moe::util::json::Json;
use std::path::{Path, PathBuf};

fn scenario_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/data/scenarios")
        .join(name)
}

// ------------------------------------------------------- committed files

/// Every committed scenario file parses strictly and survives
/// serialize → parse → serialize with byte-identical canonical JSON.
#[test]
fn committed_scenarios_load_and_roundtrip_canonically() {
    for name in [
        "drift_bert_quick.json",
        "tiny_trace_lambdaml.json",
        "chat_decode.json",
    ] {
        let s = Scenario::load(&scenario_path(name)).unwrap_or_else(|e| {
            panic!("committed scenario {name} must load: {e}");
        });
        let text = s.to_json().to_string_pretty();
        let back = Scenario::from_json(&Json::parse(&text).expect("canonical JSON parses"))
            .unwrap_or_else(|e| panic!("{name}: canonical form must re-parse: {e}"));
        assert_eq!(
            back.to_json().to_string_pretty(),
            text,
            "{name}: serialization must be a fixed point"
        );
    }
}

/// The solver-free committed scenario (LambdaML baseline: closed-form
/// policy, `reoptimize` off — no wall-clock-limited search anywhere on the
/// path): JSON → `Scenario` → `run()` must produce a `SimReport` that is
/// byte-identical across (a) the file-loaded scenario, (b) its
/// deserialized re-serialization, and (c) the builder-constructed
/// equivalent written in Rust.
#[test]
fn tiny_scenario_runs_byte_identical_through_json_and_builder() {
    let path = scenario_path("tiny_trace_lambdaml.json");
    let from_file = Scenario::load(&path).expect("scenario loads");
    let a = from_file.run().expect("file scenario runs").report;
    assert!(a.requests == 6 && a.total_cost > 0.0, "sane run: {a:?}");

    // (b) serialize → deserialize → re-run.
    let text = from_file.to_json().to_string_pretty();
    let reparsed = Scenario::from_json(&Json::parse(&text).unwrap()).unwrap();
    let b = reparsed.run().expect("reparsed scenario runs").report;
    assert_eq!(
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty(),
        "roundtripped scenario must reproduce the report byte-for-byte"
    );

    // (c) the builder-constructed equivalent.
    let built = Scenario::builder("tiny-trace-lambdaml")
        .model("tiny")
        .unwrap()
        .seed(77)
        .gate_seed(9)
        .corpus(CorpusPreset::Enwik8)
        .profile(4, 256)
        .traffic(TrafficSource::Inline {
            trace: Trace {
                requests: vec![
                    TraceRequest { time: 0.0, tokens: 256, seed: 1 },
                    TraceRequest { time: 0.5, tokens: 512, seed: 2 },
                    TraceRequest { time: 1.5, tokens: 256, seed: 3 },
                    TraceRequest { time: 40.0, tokens: 1024, seed: 4 },
                    TraceRequest { time: 41.0, tokens: 256, seed: 5 },
                    TraceRequest { time: 90.0, tokens: 512, seed: 6 },
                ],
            },
        })
        .config(TrafficConfig {
            epoch_secs: 30.0,
            keep_alive: 60.0,
            concurrency: Some(1),
            autoscale: serverless_moe::traffic::AutoscalePolicy::QueueDepth {
                max_wait: 2.0,
                idle_below: 0.2,
            },
            prewarm: true,
            reoptimize: false,
            ..TrafficConfig::default()
        })
        .baseline(Baseline::LambdaML)
        .build()
        .expect("builder equivalent is valid");
    assert_eq!(
        built.to_json().to_string_pretty(),
        text,
        "builder must construct the identical scenario"
    );
    let c = built.run().expect("builder scenario runs").report;
    assert_eq!(
        a.to_json().to_string_pretty(),
        c.to_json().to_string_pretty(),
        "builder-constructed equivalent must reproduce the report byte-for-byte"
    );
}

/// The flagship drift scenario re-runs deterministically through the round
/// trip: aggregates within 1e-9 relative error and integer counters exactly
/// (its ODS solves are wall-clock *limited*, so byte-identity is pinned on
/// the solver-free scenario above instead — same policy as the golden
/// fixtures).
#[test]
fn drift_scenario_roundtrip_reproduces_reports() {
    let s = Scenario::load(&scenario_path("drift_bert_quick.json")).expect("scenario loads");
    let a = s.run().expect("drift scenario runs").report;
    assert!(a.requests > 10, "drift scenario must serve real traffic");
    let reparsed =
        Scenario::from_json(&Json::parse(&s.to_json().to_string_pretty()).unwrap()).unwrap();
    let b = reparsed.run().expect("reparsed scenario runs").report;
    if let Err(e) = a.close_to(&b, 1e-9) {
        panic!("roundtripped drift scenario drifted: {e}");
    }
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.epochs, b.epochs);
    assert_eq!(a.redeploys, b.redeploys);
    assert_eq!(a.warm_invocations, b.warm_invocations);
    assert_eq!(a.cold_invocations, b.cold_invocations);
}

// ------------------------------------------------------------ typed errors

#[test]
fn unknown_fields_are_rejected_everywhere() {
    let cases = [
        r#"{"name": "x", "modle": "bert"}"#,
        r#"{"name": "x", "config": {"epoch_sec": 60}}"#,
        r#"{"name": "x", "traffic": {"kind": "drift", "fast": true}}"#,
        r#"{"name": "x", "traffic": {"kind": "synthetic", "process": {"kind": "poisson", "rate": 1, "burst": 2}, "duration": 10}}"#,
        r#"{"name": "x", "config": {"autoscale": {"kind": "off", "target": 0.5}}}"#,
        r#"{"name": "x", "platform": {"cold_starts": 2.0}}"#,
        r#"{"name": "x", "traffic": {"kind": "inline", "trace": {"requests": [{"time": 0, "tokens": 8, "size": 1}]}}}"#,
        // Typo inside the failure-injection block (strictness recurses).
        r#"{"name": "x", "config": {"faults": {"crash_probability": 0.1}}}"#,
    ];
    for case in cases {
        let err = Scenario::from_json(&Json::parse(case).unwrap())
            .expect_err(&format!("must reject: {case}"));
        assert!(
            matches!(err, ScenarioError::UnknownField { .. }),
            "{case}: expected UnknownField, got {err:?}"
        );
    }
}

#[test]
fn invalid_values_are_rejected_with_typed_errors() {
    let invalid = [
        r#"{"name": "x", "config": {"epoch_secs": -5}}"#,
        r#"{"name": "x", "config": {"ema_alpha": 2.0}}"#,
        r#"{"name": "x", "config": {"epoch_secs": "fast"}}"#,
        r#"{"name": "x", "seed": 9007199254740992}"#,
        r#"{"name": "x", "traffic": {"kind": "synthetic", "process": {"kind": "poisson", "rate": -1}, "duration": 10}}"#,
        r#"{"name": "x", "traffic": {"kind": "synthetic", "process": {"kind": "poisson", "rate": 1}}}"#,
        r#"{"name": "x", "version": 2}"#,
        // Negative keep-alive (the NaN/negative float checks; NaN itself is
        // inexpressible in JSON and covered by the builder-path unit test).
        r#"{"name": "x", "config": {"keep_alive": -5}}"#,
        // Out-of-range failure-injection knobs.
        r#"{"name": "x", "config": {"faults": {"crash_prob": 2.0}}}"#,
        r#"{"name": "x", "config": {"faults": {"cold_crash_multiplier": 0.5}}}"#,
        r#"{"name": "x", "config": {"faults": {"hedge_quantile": 1.0}}}"#,
        r#"{"name": "x", "config": {"faults": {"timeout": -1.0}}}"#,
        // Hedging needs at least one service-time observation to quantile.
        r#"{"name": "x", "config": {"faults": {"hedge_min_obs": 0}}}"#,
        // Chat traffic requires the pipelined event engine, a positive
        // prompt budget, and a well-formed decode-length model.
        r#"{"name": "x", "traffic": {"kind": "chat", "process": {"kind": "poisson", "rate": 1}, "duration": 10, "decode": {"kind": "fixed", "steps": 4}}, "config": {"engine": {"kind": "event", "pipeline": false}}}"#,
        r#"{"name": "x", "traffic": {"kind": "chat", "process": {"kind": "poisson", "rate": 1}, "duration": 10, "prompt_tokens": 0, "decode": {"kind": "fixed", "steps": 4}}}"#,
        r#"{"name": "x", "traffic": {"kind": "chat", "process": {"kind": "poisson", "rate": 1}, "duration": 10, "decode": {"kind": "geometric", "mean": 8.0, "cap": 0}}}"#,
        // Decode batching is an event-pipeline feature and refuses faults.
        r#"{"name": "x", "config": {"decode_batch_window": -0.5}}"#,
        r#"{"name": "x", "config": {"decode_batch_window": 0.05, "engine": {"kind": "legacy"}}}"#,
        r#"{"name": "x", "config": {"decode_batch_window": 0.05, "faults": {"crash_prob": 0.1}}}"#,
        // Faults ride the per-layer event heap: the legacy loop and the
        // unpipelined (monolithic) event engine are rejected.
        r#"{"name": "x", "config": {"engine": {"kind": "legacy"}, "faults": {"crash_prob": 0.1}}}"#,
        r#"{"name": "x", "config": {"engine": {"kind": "event", "pipeline": false}, "faults": {"crash_prob": 0.1}}}"#,
    ];
    for case in invalid {
        let err = Scenario::from_json(&Json::parse(case).unwrap())
            .expect_err(&format!("must reject: {case}"));
        assert!(
            matches!(err, ScenarioError::Invalid { .. }),
            "{case}: expected Invalid, got {err:?}"
        );
    }
    let unknown_names = [
        r#"{"name": "x", "model": "bert-9000"}"#,
        r#"{"name": "x", "baseline": "theirs"}"#,
        r#"{"name": "x", "corpus": "wikipedia"}"#,
        r#"{"name": "x", "config": {"metrics": "approximate"}}"#,
        r#"{"name": "x", "traffic": {"kind": "replay"}}"#,
    ];
    for case in unknown_names {
        let err = Scenario::from_json(&Json::parse(case).unwrap())
            .expect_err(&format!("must reject: {case}"));
        assert!(
            matches!(err, ScenarioError::UnknownName { .. }),
            "{case}: expected UnknownName, got {err:?}"
        );
    }
    // Missing file surfaces as a typed Io error, malformed JSON as Parse —
    // not a panic either way.
    assert!(matches!(
        Scenario::load(&scenario_path("no_such_scenario.json")),
        Err(ScenarioError::Io { .. })
    ));
    let dir = std::env::temp_dir().join("smoe_scenario_test");
    std::fs::create_dir_all(&dir).unwrap();
    let bad = dir.join("bad.json");
    std::fs::write(&bad, "{not json").unwrap();
    assert!(matches!(
        Scenario::load(&bad),
        Err(ScenarioError::Parse { .. })
    ));
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------------- fleet scenarios

/// Strict-parse rejection matrix for the fleet schema: unknown fields at
/// both the fleet and tenant level, zero/negative weights, duplicate and
/// empty tenant names, empty fleets, bad arbitration/cap encodings, and
/// fleet-ineligible tenant scenarios (legacy engine, cpu-cluster baseline).
#[test]
fn fleet_unknown_fields_and_invalid_values_rejected() {
    use serverless_moe::traffic::fleet::FleetScenario;
    let tenant = |extra: &str| {
        format!(
            r#"{{"name": "a", "weight": 1.0{extra}, "scenario": {{"name": "t", "model": "tiny"}}}}"#
        )
    };
    let fleet = |tenants: &str| format!(r#"{{"name": "f", "account_cap": 2, "tenants": [{tenants}]}}"#);

    let unknown_fields = [
        // Fleet-level typo.
        format!(r#"{{"name": "f", "cap": 2, "tenants": [{}]}}"#, tenant("")),
        // Tenant-level typo.
        fleet(&tenant(r#", "wieght": 2.0"#)),
        // Typo inside an inline tenant scenario (strictness recurses).
        fleet(r#"{"name": "a", "scenario": {"name": "t", "modle": "tiny"}}"#),
    ];
    for case in &unknown_fields {
        let err = FleetScenario::from_json(&Json::parse(case).unwrap())
            .expect_err(&format!("must reject: {case}"));
        assert!(
            matches!(err, ScenarioError::UnknownField { .. }),
            "{case}: expected UnknownField, got {err:?}"
        );
    }

    let invalid = [
        // Zero and negative tenant weight.
        fleet(r#"{"name": "a", "weight": 0.0, "scenario": {"name": "t", "model": "tiny"}}"#),
        fleet(r#"{"name": "a", "weight": -1.5, "scenario": {"name": "t", "model": "tiny"}}"#),
        // Duplicate tenant name.
        fleet(&format!("{}, {}", tenant(""), tenant(""))),
        // Empty tenant name and empty tenant list.
        fleet(r#"{"name": "", "scenario": {"name": "t", "model": "tiny"}}"#),
        fleet(""),
        // Non-positive SLO.
        fleet(r#"{"name": "a", "slo_p95": 0.0, "scenario": {"name": "t", "model": "tiny"}}"#),
        // Legacy engine cannot join a fleet; nor can the cpu-cluster baseline.
        fleet(
            r#"{"name": "a", "scenario": {"name": "t", "model": "tiny", "config": {"engine": {"kind": "legacy"}}}}"#,
        ),
        fleet(r#"{"name": "a", "scenario": {"name": "t", "model": "tiny", "baseline": "cpu-cluster"}}"#),
        // Per-tenant decode batching defers to the fleet's own batch_window.
        fleet(
            r#"{"name": "a", "scenario": {"name": "t", "model": "tiny", "config": {"decode_batch_window": 0.05}}}"#,
        ),
        // Unsupported version.
        format!(r#"{{"name": "f", "version": 2, "tenants": [{}]}}"#, tenant("")),
        // Out-of-range fleet-level fault knob.
        format!(
            r#"{{"name": "f", "faults": {{"throttle_prob": -0.2}}, "tenants": [{}]}}"#,
            tenant("")
        ),
        // Fleet-level faults do not compose with cross-tenant batching.
        format!(
            r#"{{"name": "f", "share_experts": true, "batch_window": 0.25, "faults": {{"crash_prob": 0.1}}, "tenants": [{}]}}"#,
            tenant("")
        ),
        // Fleet-level faults require every tenant on the pipelined engine.
        format!(
            r#"{{"name": "f", "faults": {{"crash_prob": 0.1}}, "tenants": [{}]}}"#,
            r#"{"name": "a", "scenario": {"name": "t", "model": "tiny", "config": {"engine": {"kind": "event", "pipeline": false}}}}"#
        ),
    ];
    for case in &invalid {
        let err = FleetScenario::from_json(&Json::parse(case).unwrap())
            .expect_err(&format!("must reject: {case}"));
        assert!(
            matches!(err, ScenarioError::Invalid { .. }),
            "{case}: expected Invalid, got {err:?}"
        );
    }

    // Unknown arbitration name is a typed UnknownName.
    let bad_arb = format!(
        r#"{{"name": "f", "arbitration": "round-robin", "tenants": [{}]}}"#,
        tenant("")
    );
    assert!(matches!(
        FleetScenario::from_json(&Json::parse(&bad_arb).unwrap()),
        Err(ScenarioError::UnknownName { .. })
    ));

    // Missing tenants section is a typed MissingField.
    assert!(matches!(
        FleetScenario::from_json(&Json::parse(r#"{"name": "f"}"#).unwrap()),
        Err(ScenarioError::MissingField { .. })
    ));

    // And the happy path still parses: cap 0 decodes as unbounded, the
    // arbitration default is weighted-fair.
    let ok = format!(r#"{{"name": "f", "account_cap": 0, "tenants": [{}]}}"#, tenant(""));
    let parsed = FleetScenario::from_json(&Json::parse(&ok).unwrap()).expect("valid fleet parses");
    assert_eq!(parsed.account_cap, None);
    assert_eq!(
        parsed.arbitration,
        serverless_moe::traffic::FleetArbitration::WeightedFair
    );

    // PR 7 regression: an explicit `"slo_p95": null` is the schema's
    // encoding of "no SLO" (the PR 4 null-means-absent convention) and
    // must parse like an omitted key — the pre-fix code rejected it with
    // a type error.
    let null_slo = fleet(&tenant(r#", "slo_p95": null"#));
    let parsed =
        FleetScenario::from_json(&Json::parse(&null_slo).unwrap()).expect("null slo_p95 parses");
    assert_eq!(parsed.tenants[0].slo_p95, None);
    let omitted =
        FleetScenario::from_json(&Json::parse(&fleet(&tenant(""))).unwrap()).expect("omitted ok");
    assert_eq!(omitted.tenants[0].slo_p95, None);

    // The PR 7 churn/batching knobs. A shareable tenant (lambdaml forces
    // re-optimization off) with a well-formed `[start, end)` activity
    // window, on a shared-expert fleet with a batching window:
    let shared_tenant = |extra: &str| {
        format!(
            r#"{{"name": "a", "weight": 1.0{extra}, "scenario": {{"name": "t", "model": "tiny", "baseline": "lambdaml"}}}}"#
        )
    };
    let churn = format!(
        r#"{{"name": "f", "share_experts": true, "batch_window": 0.25, "tenants": [{}]}}"#,
        shared_tenant(r#", "active": [0.0, 10.0]"#)
    );
    let parsed =
        FleetScenario::from_json(&Json::parse(&churn).unwrap()).expect("churn fleet parses");
    assert_eq!(parsed.batch_window, 0.25);
    assert_eq!(parsed.tenants[0].active, Some((0.0, 10.0)));
    // `"active": null` is the always-on default, per the same convention.
    let always =
        FleetScenario::from_json(&Json::parse(&fleet(&tenant(r#", "active": null"#))).unwrap())
            .expect("null active parses");
    assert_eq!(always.tenants[0].active, None);
    // Malformed churn/batching shapes are rejected: wrong type, wrong
    // arity, non-numeric endpoints, an empty window, a batching window
    // without a shared pool to merge on, and a negative batching window.
    let bad_churn = [
        fleet(&tenant(r#", "active": 5.0"#)),
        fleet(&tenant(r#", "active": [1.0]"#)),
        fleet(&tenant(r#", "active": ["a", "b"]"#)),
        fleet(&tenant(r#", "active": [10.0, 10.0]"#)),
        format!(
            r#"{{"name": "f", "batch_window": 0.25, "tenants": [{}]}}"#,
            shared_tenant("")
        ),
        format!(
            r#"{{"name": "f", "share_experts": true, "batch_window": -1.0, "tenants": [{}]}}"#,
            shared_tenant("")
        ),
    ];
    for case in &bad_churn {
        FleetScenario::from_json(&Json::parse(case).unwrap())
            .expect_err(&format!("must reject: {case}"));
    }
}

// ----------------------------------------------------------- run artifacts

/// The façade exposes everything callers previously dug out of
/// `EpochSimulator` fields: deployment history, redeploy times, autoscale
/// events and per-request latencies.
#[test]
fn run_artifacts_expose_history_without_touching_the_engine() {
    let s = Scenario::load(&scenario_path("tiny_trace_lambdaml.json")).expect("scenario loads");
    let outcome = s.run().expect("scenario runs");
    let art = &outcome.artifacts;
    assert_eq!(
        art.policy_history.len(),
        1,
        "no reoptimize: only the initial (LambdaML) deployment"
    );
    assert!(art.redeploy_times.is_empty());
    assert!(art.final_policy.is_some());
    assert_eq!(art.latencies.len() as u64, outcome.report.requests);
    assert!(art.latencies.iter().all(|l| l.is_finite() && *l >= 0.0));
    // CPU-cluster baseline: a plain report, no serverless artifacts.
    let scn = s.materialize().expect("materializes");
    let cpu = scn.run(&s.cfg, Baseline::CpuCluster);
    assert!(cpu.artifacts.policy_history.is_empty());
    assert!(cpu.artifacts.final_policy.is_none());
    assert!(cpu.report.total_cost > 0.0);
}
