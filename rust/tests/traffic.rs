//! Traffic-subsystem integration tests:
//!
//!  - property tests (in-repo `util::check` harness) on the arrival
//!    generators, the trace format, and the per-instance FIFO queueing
//!    model (work conservation, FIFO order, capacity, Little's-law
//!    consistency and an M/M/1 cross-validation on Poisson traffic);
//!  - cross-validation that the epoch simulator degenerates to the seed
//!    single-batch pipeline (`serve_with_real_counts` at 1e-6 relative
//!    error, `platform::events::simulate_layer` within modeling slack) and
//!    that with unbounded concurrency + autoscaling off it reproduces the
//!    PR 1 `serve_with_warmness` serving loop;
//!  - golden-regression fixtures: committed queue-schedule numbers
//!    (`golden_queueing.json`, exact) plus expected `SimReport` numbers per
//!    scenario (`golden_traffic.json`; self-initializing on first run — CI
//!    runs the suite twice so the second pass regresses against the first);
//!    the golden runs drive the simulator through the `Scenario` front door;
//!  - the drift claim (online re-optimization beats the static initial
//!    deployment on cumulative billed cost under a skew-shifting MMPP
//!    workload) and the autoscaling claim (lower p95 latency at
//!    equal-or-lower billed cost under a bursty overload).
//!
//! The engine cross-validation and dominance tests below construct
//! `EpochSimulator` directly — they compare engine internals (shared
//! policies, per-request latency vectors) that the scenario façade
//! intentionally does not expose; they are the sanctioned "shim tests".

use serverless_moe::bo::feedback::{serve_with_real_counts, serve_with_warmness};
use serverless_moe::comm::{CommMethod, ExpertPlan, LayerPlan};
use serverless_moe::config::workload::CorpusPreset;
use serverless_moe::config::PlatformConfig;
use serverless_moe::deploy::DeploymentPolicy;
use serverless_moe::gating::SimGate;
use serverless_moe::model::ModelPreset;
use serverless_moe::platform::events::simulate_layer;
use serverless_moe::platform::{InstancePool, WarmPool};
use serverless_moe::predictor::eval::real_counts;
use serverless_moe::predictor::profile::profile_batches;
use serverless_moe::predictor::BayesPredictor;
use serverless_moe::gating::TokenFeature;
use serverless_moe::traffic::epoch::EpochSimulator;
use serverless_moe::traffic::scenario::{
    drift_scenario, scenario_config, scenario_config_queued, Baseline, Scenario, TrafficSource,
};
use serverless_moe::traffic::{
    ArrivalGen, ArrivalProcess, AutoscalePolicy, DecodeLengthModel, MetricsMode, SimEngine,
    SimReport, Trace, TrafficConfig,
};
use serverless_moe::util::check::{ensure, forall, forall_default, Config};
use serverless_moe::util::json::Json;
use serverless_moe::util::rng::Rng;
use serverless_moe::util::stats::LogHistogram;
use serverless_moe::util::MB;
use serverless_moe::workload::{Batch, Corpus, RequestGenerator, Sequence, TimedBatch};
use std::path::{Path, PathBuf};

fn data_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/data")
        .join(name)
}

// ---------------------------------------------------------------- arrivals

#[test]
fn prop_interarrival_gaps_nonnegative_finite() {
    forall_default(
        |rng| {
            let kind = rng.index(3);
            let rate = rng.range_f64(0.5, 50.0);
            let rate1 = rng.range_f64(0.05, 5.0);
            let hold0 = rng.range_f64(1.0, 60.0);
            let hold1 = rng.range_f64(1.0, 60.0);
            let process = match kind {
                0 => ArrivalProcess::Deterministic { rate },
                1 => ArrivalProcess::Poisson { rate },
                _ => ArrivalProcess::Mmpp {
                    rate0: rate,
                    rate1,
                    hold0,
                    hold1,
                },
            };
            (process, rng.next_u64())
        },
        |&(process, seed)| {
            let mut gen = ArrivalGen::new(process, seed);
            for _ in 0..200 {
                let g = gen.next_gap();
                ensure(g.is_finite(), format!("{process:?}: non-finite gap {g}"))?;
                ensure(g >= 0.0, format!("{process:?}: negative gap {g}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_empirical_mean_rate_matches_configured() {
    // Seeds and tolerance validated against an independent reimplementation
    // of the RNG + MMPP algorithm (worst observed relative error 0.087).
    let cases = [
        ArrivalProcess::Poisson { rate: 8.0 },
        ArrivalProcess::Poisson { rate: 2.0 },
        ArrivalProcess::Mmpp {
            rate0: 20.0,
            rate1: 2.0,
            hold0: 5.0,
            hold1: 5.0,
        },
        ArrivalProcess::Mmpp {
            rate0: 12.0,
            rate1: 4.0,
            hold0: 3.0,
            hold1: 7.0,
        },
        ArrivalProcess::Deterministic { rate: 5.0 },
    ];
    let duration = 2000.0;
    for process in cases {
        for seed in 0x7AFF1Cu64..0x7AFF1C + 8 {
            let n = ArrivalGen::new(process, seed).arrivals_until(duration).len();
            let empirical = n as f64 / duration;
            let want = process.mean_rate();
            let rel = (empirical - want).abs() / want;
            assert!(
                rel < 0.15,
                "{process:?} seed={seed:#x}: empirical {empirical:.3}/s vs {want:.3}/s (rel {rel:.3})"
            );
        }
    }
}

// ------------------------------------------------------------------ traces

#[test]
fn prop_trace_json_roundtrip_preserves_everything() {
    forall(
        Config {
            cases: 100,
            ..Default::default()
        },
        |rng| {
            let n = rng.index(20);
            let mut t = 0.0;
            let requests = (0..n)
                .map(|_| {
                    t += rng.range_f64(0.0, 10.0);
                    serverless_moe::traffic::TraceRequest {
                        time: t,
                        tokens: 1 + rng.index(5000),
                        seed: rng.next_u64() >> 12,
                    }
                })
                .collect();
            Trace { requests }
        },
        |trace| {
            let text = trace.to_json().to_string_pretty();
            let back = Trace::from_json(&Json::parse(&text).map_err(|e| e.to_string())?)
                .map_err(|e| e.to_string())?;
            ensure(&back == trace, "roundtrip mismatch")?;
            ensure(
                back.total_tokens() == trace.total_tokens(),
                "token count changed",
            )?;
            ensure(
                back.requests.windows(2).all(|w| w[0].time <= w[1].time),
                "order lost",
            )
        },
    );
}

#[test]
fn committed_trace_replays_in_order_with_token_targets() {
    let trace = Trace::load(&data_path("trace_small.json")).expect("committed trace parses");
    assert_eq!(trace.requests.len(), 12);
    assert_eq!(trace.total_tokens(), 6848);
    assert_eq!(trace.duration(), 300.0);
    let corpus = Corpus::new(CorpusPreset::Enwik8, 3);
    let batches = trace.replay(&corpus, 7);
    assert_eq!(batches.len(), trace.requests.len());
    for (tb, r) in batches.iter().zip(&trace.requests) {
        assert_eq!(tb.at, r.time, "timestamp order must be preserved");
        assert!(
            tb.batch.total_tokens >= r.tokens,
            "batch {} smaller than its target {}",
            tb.batch.total_tokens,
            r.tokens
        );
    }
    assert!(batches.windows(2).all(|w| w[0].at <= w[1].at));
    // Replay is deterministic.
    let again = trace.replay(&corpus, 7);
    for (a, b) in batches.iter().zip(&again) {
        assert_eq!(a.batch.sequences[0].tokens, b.batch.sequences[0].tokens);
    }
}

// ---------------------------------------------------------- FIFO queueing

/// Work conservation, FIFO order and slot capacity of the per-instance
/// queue, for random job streams and concurrency limits 1..=3.
#[test]
fn prop_instance_queue_work_conserving_fifo() {
    forall_default(
        |rng| {
            let c = 1 + rng.index(3);
            let n = 1 + rng.index(40);
            let mut t = 0.0;
            let jobs: Vec<(f64, f64)> = (0..n)
                .map(|_| {
                    t += rng.range_f64(0.0, 2.0);
                    (t, rng.range_f64(0.0, 3.0))
                })
                .collect();
            (c, jobs)
        },
        |(c, jobs)| {
            let mut pool = WarmPool::with_concurrency(f64::INFINITY, Some(*c));
            let key = (0, 0, 0);
            // (arrival, start, finish) in admission order.
            let mut sched: Vec<(f64, f64, f64)> = Vec::new();
            for &(arrival, service) in jobs {
                let peek = pool.earliest_start(key, arrival);
                let start = pool.admit(key, arrival, service);
                ensure(peek == start, format!("peek {peek} != admitted {start}"))?;
                ensure(start >= arrival, "job started before it arrived")?;
                sched.push((arrival, start, start + service));
            }
            // FIFO: starts are non-decreasing in arrival order.
            ensure(
                sched.windows(2).all(|w| w[0].1 <= w[1].1),
                "FIFO start order broken",
            )?;
            for (i, &(arrival, start, _)) in sched.iter().enumerate() {
                // Capacity: at a job's start at most c-1 earlier jobs still run.
                let running = sched[..i].iter().filter(|&&(_, _, f)| f > start).count();
                ensure(
                    running + 1 <= *c,
                    format!("job {i}: {running} other jobs running at start, cap {c}"),
                )?;
                // Work conservation: a job only waits while every slot is
                // occupied — i.e. at least c earlier jobs finish at or after
                // its start (the instance was never idle with a queue).
                if start > arrival {
                    let occupied =
                        sched[..i].iter().filter(|&&(_, _, f)| f >= start).count();
                    ensure(
                        occupied >= *c,
                        format!("job {i} waited while only {occupied}/{c} slots were busy"),
                    )?;
                }
            }
            // Concurrency 1: service windows are disjoint, so one instance
            // can never be more than 100% utilized.
            if *c == 1 {
                ensure(
                    sched.windows(2).all(|w| w[1].1 >= w[0].2),
                    "c=1 service windows overlap",
                )?;
            }
            Ok(())
        },
    );
}

/// Little's-law consistency and an analytic M/M/1 cross-validation of the
/// FIFO queue on Poisson traffic (λ = 0.8, μ = 1.25, ρ = 0.64): the
/// time-average number of waiting jobs must match arrival rate × mean wait,
/// and the mean wait itself must match the closed form W_q = ρ/(μ−λ).
#[test]
fn prop_queue_littles_law_and_mm1_cross_validation() {
    let lambda = 0.8;
    let mu = 1.25;
    let n = 20_000;
    let mut rng = Rng::new(0xFA1FA);
    let mut pool = WarmPool::with_concurrency(f64::INFINITY, Some(1));
    let key = (0, 0, 0);
    let mut arrivals: Vec<f64> = Vec::with_capacity(n);
    let mut starts: Vec<f64> = Vec::with_capacity(n);
    let mut t = 0.0;
    for _ in 0..n {
        t += rng.exponential(lambda);
        let service = rng.exponential(mu);
        let start = pool.admit(key, t, service);
        arrivals.push(t);
        starts.push(start);
    }
    let horizon = t;
    let lam_hat = n as f64 / horizon;
    let w_hat = arrivals
        .iter()
        .zip(&starts)
        .map(|(&a, &s)| s - a)
        .sum::<f64>()
        / n as f64;
    assert!(w_hat > 0.0, "overloadable queue must actually wait");

    // Little's law: L_q ≈ λ·W_q, with L_q estimated by sampling the
    // waiting-count step function at evenly spaced times.
    let samples = 2000;
    let mut acc = 0.0;
    for j in 0..samples {
        let s = horizon * (j as f64 + 0.5) / samples as f64;
        acc += arrivals
            .iter()
            .zip(&starts)
            .filter(|&(&a, &st)| a <= s && s < st)
            .count() as f64;
    }
    let l_hat = acc / samples as f64;
    let little = lam_hat * w_hat;
    let rel = (l_hat - little).abs() / little.max(1e-9);
    assert!(
        rel < 0.15,
        "Little's law violated: L={l_hat:.3} vs λW={little:.3} (rel {rel:.3})"
    );

    // M/M/1: W_q = ρ/(μ−λ).
    let rho = lambda / mu;
    let wq = rho / (mu - lambda);
    let relq = (w_hat - wq).abs() / wq;
    assert!(
        relq < 0.3,
        "M/M/1 cross-validation failed: simulated W_q {w_hat:.3} vs analytic {wq:.3} (rel {relq:.3})"
    );
}

/// Committed queue-schedule numbers (exactly representable binary fractions,
/// so the comparison is bit-exact): replaying the fixture's job streams
/// through the instance queue must reproduce every start/finish time.
#[test]
fn golden_queueing_schedule_matches_committed_fixture() {
    let j = Json::read_file(&data_path("golden_queueing.json")).expect("fixture parses");
    let cases = j.get("cases").and_then(Json::as_arr).expect("cases array");
    assert_eq!(cases.len(), 2, "fixture covers c=1 and c=2");
    for case in cases {
        let name = case.get_str("name").unwrap_or("?").to_string();
        let c = case.get_usize("concurrency").expect("concurrency");
        let nums = |k: &str| -> Vec<f64> {
            case.get(k)
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_f64).collect())
                .unwrap_or_default()
        };
        let arrivals = nums("arrivals");
        let services = nums("services");
        let starts = nums("starts");
        let finishes = nums("finishes");
        assert_eq!(arrivals.len(), services.len(), "{name}");
        assert_eq!(arrivals.len(), starts.len(), "{name}");
        assert_eq!(arrivals.len(), finishes.len(), "{name}");
        assert!(!arrivals.is_empty(), "{name}: empty case");
        let mut pool = WarmPool::with_concurrency(f64::INFINITY, Some(c));
        let key = (0, 0, 0);
        for (i, (&a, &s)) in arrivals.iter().zip(&services).enumerate() {
            let start = pool.admit(key, a, s);
            assert_eq!(start, starts[i], "{name}: job {i} start");
            assert_eq!(start + s, finishes[i], "{name}: job {i} finish");
        }
        assert_eq!(
            pool.total_queue_wait,
            case.get_f64("total_wait").expect("total_wait"),
            "{name}: total wait"
        );
        assert_eq!(
            pool.total_busy_secs(),
            case.get_f64("busy_secs").expect("busy_secs"),
            "{name}: busy seconds"
        );
    }
}

// -------------------------------------------------------- cross-validation

/// One epoch, all-warm never-expiring pool, no re-optimization: the traffic
/// simulator must reproduce the seed single-batch pipeline.
#[test]
fn degenerate_sim_matches_flat_pipeline_and_event_model() {
    let scn = drift_scenario(ModelPreset::BertMoe { experts: 4, top_k: 1 }, true, 0xC0DE);
    let traffic = vec![scn.traffic[0].clone()];
    let mut cfg = TrafficConfig::degenerate();
    cfg.t_limit = scenario_config(true).t_limit;
    let mut sim = EpochSimulator::new(&scn.platform, &scn.spec, &scn.gate, scn.predictor(), cfg);
    let report = sim.run(&traffic);
    let policy = sim.last_policy.clone().expect("policy recorded");
    let real = real_counts(&scn.gate, &traffic[0].batch);

    // (a) Analytic pipeline: 1e-6 relative error on cost AND latency.
    let flat = serve_with_real_counts(&scn.platform, &scn.spec, &policy, &real, true);
    let rel_cost = (report.total_cost - flat.cost).abs() / flat.cost;
    assert!(
        rel_cost < 1e-6,
        "sim cost {} vs flat {} (rel {rel_cost})",
        report.total_cost,
        flat.cost
    );
    let rel_lat = (report.p50_latency - flat.latency).abs() / flat.latency;
    assert!(
        rel_lat < 1e-6,
        "sim latency {} vs flat {} (rel {rel_lat})",
        report.p50_latency,
        flat.latency
    );

    // (b) Event-level model: same plan with the real token counts, summed
    // over layers, within modeling slack (stage-1 concurrency is the
    // paper's own approximation).
    let mut ev_cost = 0.0;
    let mut ev_lat = 0.0;
    for (l, plan) in policy.layers.iter().enumerate() {
        let mut real_plan = plan.clone();
        for (i, ep) in real_plan.experts.iter_mut().enumerate() {
            ep.tokens = real[l][i];
        }
        let out = simulate_layer(&scn.platform, &scn.spec, l, &real_plan, true);
        ev_cost += out.billed_cost;
        ev_lat += out.latency;
    }
    let rel_ev_cost = (report.total_cost - ev_cost).abs() / ev_cost.max(report.total_cost);
    let rel_ev_lat = (report.p50_latency - ev_lat).abs() / ev_lat.max(report.p50_latency);
    assert!(
        rel_ev_cost < 0.35,
        "sim cost {} vs event model {} (rel {rel_ev_cost})",
        report.total_cost,
        ev_cost
    );
    assert!(
        rel_ev_lat < 0.35,
        "sim latency {} vs event model {} (rel {rel_ev_lat})",
        report.p50_latency,
        ev_lat
    );
}

/// With unbounded concurrency and autoscaling off, the queued epoch loop
/// must reproduce the PR 1 serving loop — re-implemented here verbatim on
/// `serve_with_warmness` + a plain `WarmPool` — within 1e-6 relative error
/// (same pattern as the degenerate checks above, but over a multi-request
/// stream with finite keep-alive, so warm/cold transitions are exercised).
#[test]
fn unbounded_concurrency_reproduces_pr1_serving_loop() {
    let scn = drift_scenario(ModelPreset::BertMoe { experts: 4, top_k: 1 }, true, 0xAB1E);
    let traffic: Vec<TimedBatch> = scn.traffic.iter().take(12).cloned().collect();
    let cfg = TrafficConfig {
        concurrency: None,
        autoscale: AutoscalePolicy::Off,
        reoptimize: false,
        prewarm: true,
        keep_alive: 30.0,
        t_limit: scenario_config(true).t_limit,
        ..TrafficConfig::default()
    };
    let mut sim = EpochSimulator::new(&scn.platform, &scn.spec, &scn.gate, scn.predictor(), cfg);
    let policy = sim.initial_policy(&traffic);
    let report = sim.run_with_policy(policy.clone(), &traffic);

    // PR 1 reference loop: serve each request independently at its arrival
    // time, warmness judged at the request start.
    let mut pool = WarmPool::new(30.0);
    pool.prewarm_plan(&policy.layers);
    let mut total_cost = 0.0;
    let mut latencies: Vec<f64> = Vec::new();
    for tb in &traffic {
        let start = tb.at;
        let real = real_counts(&scn.gate, &tb.batch);
        let outcome = serve_with_warmness(
            &scn.platform,
            &scn.spec,
            &policy,
            &real,
            &mut |l, e, g| pool.is_warm((l, e, g), start),
        );
        let finish = start + outcome.latency;
        for (l, lp) in policy.layers.iter().enumerate() {
            for (i, ep) in lp.experts.iter().enumerate() {
                if real[l][i] == 0 {
                    continue;
                }
                for g in 0..ep.replicas {
                    pool.invoke((l, i, g), start, finish);
                }
            }
        }
        total_cost += outcome.cost;
        latencies.push(finish - tb.at);
    }

    let rel_cost = (report.total_cost - total_cost).abs() / total_cost;
    assert!(
        rel_cost < 1e-6,
        "queued loop (unbounded) cost {} vs PR 1 loop {} (rel {rel_cost})",
        report.total_cost,
        total_cost
    );
    let p95_ref = serverless_moe::util::stats::percentile(&latencies, 95.0);
    let rel_p95 = (report.p95_latency - p95_ref).abs() / p95_ref;
    assert!(
        rel_p95 < 1e-6,
        "queued loop (unbounded) p95 {} vs PR 1 loop {} (rel {rel_p95})",
        report.p95_latency,
        p95_ref
    );
    assert_eq!(report.requests, traffic.len() as u64);
    assert_eq!(report.mean_queue_delay, 0.0, "unbounded pools never queue");
    assert_eq!(report.queued_invocations, 0);
    assert_eq!(
        report.warm_invocations + report.cold_invocations,
        pool.warm_hits + pool.cold_starts
    );
}

/// Acceptance criterion: with concurrency 1 under an overload trace the
/// reported queue delay is positive and no instance exceeds 100%
/// utilization — while the billed cost is unchanged from the unbounded run
/// (billing meters busy time, which queueing only shifts later; the
/// all-warm never-expiring pool keeps service times identical).
#[test]
fn overload_queueing_positive_delay_bounded_utilization() {
    let platform = PlatformConfig::default();
    let spec = ModelPreset::TinyMoe.spec();
    let gate = SimGate::new(&spec, 3);
    let corpus = Corpus::new(CorpusPreset::Enwik8, 5);
    let mut gen = RequestGenerator::new(corpus, 6, 1024);
    // 20 requests/s: far above the per-replica service rate (the warm head
    // time alone is ~0.13 s), so the bounded pool must queue.
    let arrivals = ArrivalGen::new(ArrivalProcess::Deterministic { rate: 20.0 }, 1)
        .arrivals_until(0.8);
    let traffic = gen.timed_batches(&arrivals);
    assert!(traffic.len() >= 12);
    let profile = profile_batches(&gate, &gen.profile_set(4));
    let base = TrafficConfig {
        reoptimize: false,
        prewarm: true,
        keep_alive: f64::INFINITY,
        epoch_secs: f64::INFINITY,
        ..TrafficConfig::default()
    };

    let cfg_q = TrafficConfig { concurrency: Some(1), ..base.clone() };
    let mut sim_q = EpochSimulator::new(
        &platform,
        &spec,
        &gate,
        BayesPredictor::new(profile.table.clone(), profile.prior.clone()),
        cfg_q,
    );
    let policy = sim_q.initial_policy(&traffic);
    let queued = sim_q.run_with_policy(policy.clone(), &traffic);

    let cfg_u = TrafficConfig { concurrency: None, ..base };
    let mut sim_u = EpochSimulator::new(
        &platform,
        &spec,
        &gate,
        BayesPredictor::new(profile.table.clone(), profile.prior.clone()),
        cfg_u,
    );
    let unbounded = sim_u.run_with_policy(policy, &traffic);

    assert!(queued.mean_queue_delay > 0.0, "overload must produce queue delay");
    assert!(queued.max_queue_delay >= queued.p95_queue_delay);
    assert!(queued.p95_queue_delay >= queued.mean_queue_delay * 0.5);
    assert!(queued.queued_invocations > 0);
    assert!(
        queued.max_utilization > 0.0 && queued.max_utilization <= 1.0 + 1e-9,
        "utilization must stay within [0, 1]: {}",
        queued.max_utilization
    );
    assert!(queued.busy_secs > 0.0);
    assert!(queued.p95_latency >= unbounded.p95_latency);
    assert!(queued.mean_latency > unbounded.mean_latency);
    let rel = (queued.total_cost - unbounded.total_cost).abs() / unbounded.total_cost;
    assert!(
        rel < 1e-9,
        "queueing must not change all-warm billed cost: {} vs {}",
        queued.total_cost,
        unbounded.total_cost
    );
    assert_eq!(unbounded.mean_queue_delay, 0.0);
}

// --------------------------------------------- event engine cross-validation

/// Acceptance criterion of the event-engine PR: with pipelining disabled
/// the event engine must reproduce the PR 2 queued loop within 1e-6 on the
/// golden scenario traces — both the unbounded re-optimizing configuration
/// and the queued + autoscaled one. Integer counters (epochs, redeploys,
/// warm/cold/queued invocations, scale actions) must match exactly.
#[test]
fn event_engine_monolithic_reproduces_legacy_loop_on_golden_traces() {
    for (label, base_cfg) in [
        ("unbounded", scenario_config(true)),
        ("queued+autoscaled", scenario_config_queued(true)),
    ] {
        let scn = drift_scenario(ModelPreset::BertMoe { experts: 4, top_k: 1 }, true, 0x601D);
        let mut legacy_cfg = base_cfg.clone();
        legacy_cfg.engine = SimEngine::Legacy;
        let mut event_cfg = base_cfg.clone();
        event_cfg.engine = SimEngine::Event { pipeline: false };

        let mut sim_l =
            EpochSimulator::new(&scn.platform, &scn.spec, &scn.gate, scn.predictor(), legacy_cfg);
        let policy = sim_l.initial_policy(&scn.traffic);
        let legacy = sim_l.run_with_policy(policy.clone(), &scn.traffic);

        let mut sim_e =
            EpochSimulator::new(&scn.platform, &scn.spec, &scn.gate, scn.predictor(), event_cfg);
        let event = sim_e.run_with_policy(policy, &scn.traffic);

        if let Err(e) = event.close_to(&legacy, 1e-6) {
            panic!("{label}: event engine (pipeline off) drifted from legacy loop: {e}");
        }
        assert_eq!(event.requests, legacy.requests, "{label}");
        assert_eq!(event.epochs, legacy.epochs, "{label}");
        assert_eq!(event.redeploys, legacy.redeploys, "{label}");
        assert_eq!(event.warm_invocations, legacy.warm_invocations, "{label}");
        assert_eq!(event.cold_invocations, legacy.cold_invocations, "{label}");
        assert_eq!(event.queued_invocations, legacy.queued_invocations, "{label}");
        assert_eq!(event.violation_batches, legacy.violation_batches, "{label}");
        assert_eq!(event.scale_outs, legacy.scale_outs, "{label}");
        assert_eq!(event.scale_ins, legacy.scale_ins, "{label}");
        let close = |name: &str, a: f64, b: f64| {
            let rel = (a - b).abs() / b.abs().max(1e-12);
            assert!(rel < 1e-9, "{label}/{name}: {a} vs {b} (rel {rel})");
        };
        close("mean_latency", event.mean_latency, legacy.mean_latency);
        close("p50_latency", event.p50_latency, legacy.p50_latency);
        close("p99_latency", event.p99_latency, legacy.p99_latency);
        close("busy_secs", event.busy_secs, legacy.busy_secs);
        close("max_utilization", event.max_utilization, legacy.max_utilization);
        close("max_queue_delay", event.max_queue_delay, legacy.max_queue_delay);
        // Per-request latencies match too, not just the aggregates.
        assert_eq!(sim_l.last_latencies.len(), sim_e.last_latencies.len());
        for (i, (a, b)) in sim_e.last_latencies.iter().zip(&sim_l.last_latencies).enumerate() {
            let rel = (a - b).abs() / b.abs().max(1e-12);
            assert!(rel < 1e-9, "{label}: request {i}: event {a} vs legacy {b}");
        }
    }
}

/// A batch of `n` identical tokens — routes every token to one expert per
/// layer, giving the dominance tests full control over contention.
fn uniform_batch(token: u32, n: usize) -> Batch {
    Batch::from_sequences(vec![Sequence {
        tokens: vec![token; n],
        positions: vec![0; n],
        attention_ids: vec![token; n],
    }])
}

/// Hand-built two-layer single-replica deployment on the tiny model.
fn two_layer_policy() -> DeploymentPolicy {
    DeploymentPolicy {
        layers: (0..2)
            .map(|_| LayerPlan {
                method: CommMethod::Indirect,
                beta: 1,
                experts: vec![ExpertPlan { mem_mb: 1152, replicas: 1, tokens: 512 }; 4],
            })
            .collect(),
    }
}

fn pipeline_test_config(engine: SimEngine) -> TrafficConfig {
    TrafficConfig {
        concurrency: Some(1),
        prewarm: true,
        keep_alive: f64::INFINITY,
        epoch_secs: f64::INFINITY,
        reoptimize: false,
        autoscale: AutoscalePolicy::Off,
        engine,
        ..TrafficConfig::default()
    }
}

fn run_pipeline_case(
    engine: SimEngine,
    traffic: &[TimedBatch],
) -> (SimReport, Vec<f64>) {
    let platform = PlatformConfig::default();
    let spec = ModelPreset::TinyMoe.spec();
    let gate = SimGate::new(&spec, 0x9A7E);
    let corpus = Corpus::new(CorpusPreset::Enwik8, 1);
    let mut gen = RequestGenerator::new(corpus, 2, 256);
    let profile = profile_batches(&gate, &gen.profile_set(2));
    let mut sim = EpochSimulator::new(
        &platform,
        &spec,
        &gate,
        BayesPredictor::new(profile.table, profile.prior),
        pipeline_test_config(engine),
    );
    let report = sim.run_with_policy(two_layer_policy(), traffic);
    (report, sim.last_latencies.clone())
}

/// Satellite claim, part 1 — the constructed two-layer contention case the
/// paper's pipelining argument is about: request A is heavy at both layers,
/// request B (arriving just after, on a different layer-0 expert but the
/// same layer-1 expert) is light. Monolithic dispatch reserves A's layer-1
/// instance at A's ready time, so B queues behind the whole of A; pipelined
/// dispatch only occupies layer 1 when A actually reaches it, and B — whose
/// layer-0 finishes long before A's — slips in and out first. B must finish
/// strictly earlier, A no later, and billed cost must be identical (busy
/// time is only shifted, never changed, on an all-warm pool).
#[test]
fn pipelined_dispatch_beats_monolithic_on_two_layer_contention() {
    let spec = ModelPreset::TinyMoe.spec();
    let gate = SimGate::new(&spec, 0x9A7E);
    // Find two tokens sharing a layer-1 expert but differing at layer 0
    // (position 0, attention = self, so each batch is one feature class).
    let route = |tk: u32, layer: usize| {
        let f = TokenFeature { token_id: tk, position_id: 0, attention_id: tk };
        gate.route_token(layer, &f)[0] as usize
    };
    let mut pair = None;
    'search: for j in 0..4usize {
        let mut by_l0: [Option<u32>; 4] = [None; 4];
        for tk in 0..1024u32 {
            if route(tk, 1) == j {
                let e0 = route(tk, 0);
                if by_l0[e0].is_none() {
                    by_l0[e0] = Some(tk);
                }
            }
            let found: Vec<u32> = by_l0.iter().flatten().copied().collect();
            if found.len() >= 2 {
                pair = Some((found[0], found[1]));
                break 'search;
            }
        }
    }
    let (tok_a, tok_b) = pair.expect("gate must offer two l0-distinct tokens sharing an l1 expert");

    // A: 60k tokens (its layer 0 runs for seconds); B: 100 tokens at +50 ms.
    let traffic = vec![
        TimedBatch { at: 0.0, batch: uniform_batch(tok_a, 60_000) },
        TimedBatch { at: 0.05, batch: uniform_batch(tok_b, 100) },
    ];
    let (mono_r, mono) = run_pipeline_case(SimEngine::Legacy, &traffic);
    let (pipe_r, pipe) = run_pipeline_case(SimEngine::Event { pipeline: true }, &traffic);
    assert_eq!(mono.len(), 2);
    assert_eq!(pipe.len(), 2);
    for i in 0..2 {
        assert!(
            pipe[i] <= mono[i] * (1.0 + 1e-9),
            "request {i}: pipelined {} later than monolithic {}",
            pipe[i],
            mono[i]
        );
    }
    assert!(
        pipe[1] < 0.5 * mono[1],
        "contended light request must finish far earlier pipelined: {} vs {}",
        pipe[1],
        mono[1]
    );
    let rel = (pipe_r.total_cost - mono_r.total_cost).abs() / mono_r.total_cost;
    assert!(
        rel < 1e-9,
        "pipelining must not change all-warm billed cost: {} vs {}",
        pipe_r.total_cost,
        mono_r.total_cost
    );
}

/// Satellite claim, part 2 — on a homogeneous trace (identical requests
/// through one shared instance chain) the pipeline is saturated and every
/// request finishes at the same time under both dispatch disciplines: the
/// bottleneck layer governs. Pinned per request at 1e-7 relative error.
#[test]
fn pipelined_dispatch_matches_monolithic_on_homogeneous_trace() {
    let spec = ModelPreset::TinyMoe.spec();
    let gate = SimGate::new(&spec, 0x9A7E);
    let tok = (0..1024u32)
        .find(|&tk| {
            let f = TokenFeature { token_id: tk, position_id: 0, attention_id: tk };
            gate.route_token(0, &f)[0] < 4
        })
        .unwrap();
    let traffic: Vec<TimedBatch> = (0..10)
        .map(|i| TimedBatch { at: i as f64 * 0.25, batch: uniform_batch(tok, 1000) })
        .collect();
    let (_, mono) = run_pipeline_case(SimEngine::Legacy, &traffic);
    let (_, pipe) = run_pipeline_case(SimEngine::Event { pipeline: true }, &traffic);
    assert_eq!(mono.len(), pipe.len());
    for (i, (p, m)) in pipe.iter().zip(&mono).enumerate() {
        let rel = (p - m).abs() / m.abs().max(1e-12);
        assert!(rel < 1e-7, "request {i}: pipelined {p} vs monolithic {m} (rel {rel})");
    }
}

/// Streaming metrics: same engine, same trace — histogram percentiles land
/// within one bucket of the exact ones, exact-by-construction fields match
/// bit-for-bit, and the cost timeline is dropped (the O(1)-memory mode).
#[test]
fn streaming_metrics_match_exact_within_one_bucket() {
    let scn = drift_scenario(ModelPreset::BertMoe { experts: 4, top_k: 1 }, true, 0xFEED);
    let mk_cfg = |metrics: MetricsMode| TrafficConfig {
        reoptimize: false,
        concurrency: Some(1),
        metrics,
        ..scenario_config(true)
    };
    let mut sim_x = EpochSimulator::new(
        &scn.platform,
        &scn.spec,
        &scn.gate,
        scn.predictor(),
        mk_cfg(MetricsMode::Exact),
    );
    let policy = sim_x.initial_policy(&scn.traffic);
    let exact = sim_x.run_with_policy(policy.clone(), &scn.traffic);
    let mut sim_s = EpochSimulator::new(
        &scn.platform,
        &scn.spec,
        &scn.gate,
        scn.predictor(),
        mk_cfg(MetricsMode::Streaming),
    );
    let streamed = sim_s.run_with_policy(policy, &scn.traffic);

    assert_eq!(streamed.requests, exact.requests);
    assert_eq!(streamed.total_cost, exact.total_cost, "cost is metric-mode independent");
    assert_eq!(streamed.busy_secs, exact.busy_secs);
    assert_eq!(streamed.warm_invocations, exact.warm_invocations);
    let rel_mean = (streamed.mean_latency - exact.mean_latency).abs() / exact.mean_latency;
    assert!(rel_mean < 1e-12, "histogram mean must be exact: {rel_mean}");
    // Streaming percentiles must land within one bucket of the exact order
    // statistic at the same rank (the exact run's per-request latencies are
    // the ground truth; `stats::percentile` interpolates between ranks, so
    // it is only an upper bound for a bucketed estimator).
    let h = LogHistogram::latency_default();
    let mut lats = sim_x.last_latencies.clone();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert_eq!(lats.len() as u64, exact.requests);
    for (name, p, s) in [
        ("p50", 50.0, streamed.p50_latency),
        ("p95", 95.0, streamed.p95_latency),
        ("p99", 99.0, streamed.p99_latency),
    ] {
        let rank = (p / 100.0) * (lats.len() - 1) as f64;
        let stat = lats[rank.floor() as usize];
        assert!(
            h.within_one_bucket(s, stat),
            "{name}: streaming {s} vs exact order stat {stat} beyond one bucket"
        );
        assert!(s <= exact.p99_latency * 1.06 + 1e-9, "{name}: runaway estimate {s}");
    }
    // Queue-delay p95: the floor-rank estimate can undershoot the
    // interpolated exact value, but never overshoot it past one bucket.
    assert!(
        streamed.p95_queue_delay <= exact.p95_queue_delay * 1.06 + 1e-9,
        "streaming queue-delay p95 {} overshoots exact {}",
        streamed.p95_queue_delay,
        exact.p95_queue_delay
    );
    let rel_mq =
        (streamed.mean_queue_delay - exact.mean_queue_delay).abs()
            / exact.mean_queue_delay.max(1e-12);
    assert!(rel_mq < 1e-12, "queue-delay mean must be exact");
    assert_eq!(streamed.max_queue_delay, exact.max_queue_delay, "max is tracked exactly");
    assert!(streamed.cost_timeline.is_empty(), "streaming mode keeps no timeline");
    assert!(sim_s.last_latencies.is_empty(), "streaming mode keeps no per-request vector");
}

// ------------------------------------------------------- golden regression

fn golden_run(preset: ModelPreset, mut cfg: TrafficConfig) -> SimReport {
    cfg.reoptimize = true;
    cfg.bo_round_iters = 0;
    Scenario::builder("golden")
        .model_preset(preset)
        .seed(0x601D)
        .traffic(TrafficSource::Drift { quick: true })
        .config(cfg)
        .build()
        .expect("golden scenario is valid")
        .run()
        .expect("golden scenario runs")
        .report
}

/// Committed expected `SimReport` numbers per scenario at a fixed RNG seed
/// (the PR 1 unbounded-concurrency runs plus a queueing-enabled run). On
/// first run (or after deleting the fixture) the file is initialized from
/// the current implementation and the test asks for a rerun; afterwards any
/// drift in cost/throughput/p95/queue-delay beyond 1e-6 relative error
/// fails with a diff. CI runs the suite twice so a freshly initialized
/// fixture is still regressed within one workflow run.
#[test]
fn golden_regression_fixed_seed_reports() {
    let path = data_path("golden_traffic.json");
    let mut golden = Json::read_file(&path).unwrap_or_else(|_| Json::obj());
    let mut initialized: Vec<&str> = Vec::new();
    for (key, preset, cfg) in [
        (
            "bert-moe",
            ModelPreset::BertMoe { experts: 4, top_k: 1 },
            scenario_config(true),
        ),
        ("gpt2-moe", ModelPreset::Gpt2Moe { top_k: 1 }, scenario_config(true)),
        (
            "bert-moe-queued",
            ModelPreset::BertMoe { experts: 4, top_k: 1 },
            scenario_config_queued(true),
        ),
    ] {
        let report = golden_run(preset, cfg.clone());
        assert!(report.requests > 10, "{key}: degenerate scenario");
        assert!(report.total_cost > 0.0 && report.total_cost.is_finite());
        assert!(report.p50_latency <= report.p95_latency);
        assert!(report.p95_latency <= report.p99_latency);
        // Determinism: an immediate re-run must reproduce the numbers.
        let again = golden_run(preset, cfg);
        if let Err(e) = report.close_to(&again, 1e-9) {
            panic!("{key}: simulator is nondeterministic across reruns: {e}");
        }
        match golden.get(key) {
            Some(g) => {
                let want = SimReport::from_json(g).expect("golden entry parses");
                if let Err(e) = report.close_to(&want, 1e-6) {
                    panic!(
                        "{key}: golden regression: {e}\n\
                         (if this change is intentional, delete {path:?} and rerun to re-baseline)"
                    );
                }
            }
            None => {
                golden.set(key, report.to_json());
                initialized.push(key);
            }
        }
    }
    if !initialized.is_empty() {
        golden.write_file(&path).expect("golden fixture written");
        eprintln!(
            "initialized golden fixture for {initialized:?} at {path:?}; rerun to verify against it"
        );
    }
}

// ------------------------------------------------------------ drift claim

/// Under a bursty MMPP workload whose expert popularity drifts mid-run, the
/// online BO re-optimization loop must end up cheaper than serving the
/// whole stream on the static initial deployment.
#[test]
fn reoptimization_beats_static_deployment_under_drift() {
    // One compiled scenario, two baselines — the Scenario-API shape of the
    // claim (each run starts from the same profiled predictor state).
    let scn = drift_scenario(ModelPreset::BertMoe { experts: 4, top_k: 1 }, true, 0x5EED);

    let mut cfg_ours = scenario_config(true);
    cfg_ours.reoptimize = true;
    cfg_ours.bo_round_iters = 1;
    let ours = scn.run(&cfg_ours, Baseline::Ours);
    let stat = scn.run(&scenario_config(true), Baseline::Static).report;

    assert!(
        ours.report.redeploys >= 1,
        "drift must trigger at least one re-optimization (tv threshold too high?)"
    );
    assert_eq!(stat.redeploys, 0);
    assert!(
        ours.report.total_cost < stat.total_cost,
        "online re-optimization must cut cumulative billed cost: ours {} vs static {}",
        ours.report.total_cost,
        stat.total_cost
    );
    // The gap is availability, not free lunch: the shared pre-drift
    // requests bound ours' tail latency from below.
    assert!(ours.report.p99_latency >= stat.p99_latency * 0.5);
    // The artifacts mirror the report: one policy per redeploy on top of
    // the initial deployment, stamped with the redeploy times.
    let art = &ours.artifacts;
    assert_eq!(
        art.policy_history.len() as u64,
        1 + ours.report.redeploys,
        "policy history = initial + one per redeploy"
    );
    assert_eq!(art.redeploy_times.len() as u64, ours.report.redeploys);
    assert!(art.final_policy.is_some());
    assert_eq!(art.latencies.len() as u64, ours.report.requests);
}

// --------------------------------------------- queueing + autoscaling claims

/// One fully-seeded autoscaled run: bursty MMPP traffic on the tiny model
/// with concurrency 1 and the target-utilization policy. The deployment is
/// hand-built (no ODS call) so the whole path is free of wall-clock-limited
/// search — byte-identical output is then a hard guarantee, not luck.
fn autoscaled_tiny_run() -> SimReport {
    let platform = PlatformConfig::default();
    let spec = ModelPreset::TinyMoe.spec();
    let gate = SimGate::new(&spec, 0xD0);
    let corpus = Corpus::new(CorpusPreset::Enwik8, 0xD1);
    let mut gen = RequestGenerator::new(corpus, 0xD2, 2048);
    let profile = profile_batches(&gate, &gen.profile_set(4));
    let arrivals = ArrivalGen::new(
        ArrivalProcess::Mmpp { rate0: 5.0, rate1: 0.1, hold0: 30.0, hold1: 30.0 },
        0xD3,
    )
    .arrivals_until(150.0);
    let traffic = gen.timed_batches(&arrivals);
    let policy = DeploymentPolicy {
        layers: (0..spec.num_moe_layers())
            .map(|_| LayerPlan {
                method: CommMethod::Indirect,
                beta: 1,
                experts: vec![ExpertPlan { mem_mb: 1152, replicas: 1, tokens: 512 }; 4],
            })
            .collect(),
    };
    let cfg = TrafficConfig {
        reoptimize: false,
        concurrency: Some(1),
        autoscale: AutoscalePolicy::TargetUtilization { target: 0.6 },
        epoch_secs: 20.0,
        ..TrafficConfig::default()
    };
    let mut sim = EpochSimulator::new(
        &platform,
        &spec,
        &gate,
        BayesPredictor::new(profile.table, profile.prior),
        cfg,
    );
    sim.run_with_policy(policy, &traffic)
}

/// Deterministic-seed regression: two fully independent runs (fresh gate,
/// corpus, generator, simulator) with the same seeds and an autoscaling
/// policy must produce byte-identical `SimReport` JSON.
#[test]
fn autoscaled_sim_report_is_byte_identical_across_reruns() {
    let a = autoscaled_tiny_run();
    let b = autoscaled_tiny_run();
    assert!(
        a.scale_outs + a.scale_ins > 0,
        "scenario must actually exercise the autoscaler"
    );
    assert!(a.mean_queue_delay > 0.0, "burst phase must queue");
    assert_eq!(a.to_json().to_string_pretty(), b.to_json().to_string_pretty());
}

/// The autoscaling claim under a bursty MMPP overload: a one-replica static
/// deployment whose experts thrash (Alg. 2 case i — the fat runtime leaves
/// ~1280 tokens of headroom at 768 MB while every 8192-token request puts
/// ≥ 2048 tokens on some expert) queues up and pays the 2.5× thrash factor
/// on billed busy time. Scaling out restores memory feasibility and drains
/// the queues: strictly lower p95 latency at equal-or-lower billed cost.
#[test]
fn autoscaler_beats_static_under_bursty_overload() {
    let platform = PlatformConfig::default();
    let mut spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
    spec.layers.truncate(1);
    spec.runtime_overhead_bytes = 720 * MB;
    let gate = SimGate::new(&spec, 0x21);
    let corpus = Corpus::new(CorpusPreset::Enwik8, 0x22);
    let mut gen = RequestGenerator::new(corpus, 0x23, 8192);
    let arrivals = ArrivalGen::new(
        ArrivalProcess::Mmpp { rate0: 0.5, rate1: 0.05, hold0: 40.0, hold1: 40.0 },
        0x24,
    )
    .arrivals_until(200.0);
    let traffic = gen.timed_batches(&arrivals);
    assert!(traffic.len() >= 10, "need sustained traffic, got {}", traffic.len());

    let static_policy = DeploymentPolicy {
        layers: vec![LayerPlan {
            method: CommMethod::Indirect,
            beta: 1,
            experts: vec![ExpertPlan { mem_mb: 768, replicas: 1, tokens: 2048 }; 4],
        }],
    };
    let profile = profile_batches(&gate, &gen.profile_set(2));

    let run = |autoscale: AutoscalePolicy| -> SimReport {
        let cfg = TrafficConfig {
            epoch_secs: 15.0,
            keep_alive: 900.0,
            concurrency: Some(1),
            autoscale,
            prewarm: true,
            reoptimize: false,
            max_replicas: 8,
            ..TrafficConfig::default()
        };
        let predictor = BayesPredictor::new(profile.table.clone(), profile.prior.clone());
        let mut sim = EpochSimulator::new(&platform, &spec, &gate, predictor, cfg);
        sim.run_with_policy(static_policy.clone(), &traffic)
    };

    let stat = run(AutoscalePolicy::Off);
    let auto = run(AutoscalePolicy::TargetUtilization { target: 0.7 });

    assert!(
        stat.violation_batches > 0,
        "the one-replica static deployment must hit memory thrash"
    );
    assert!(stat.mean_queue_delay > 0.0, "overload must queue on the static deployment");
    assert_eq!(stat.scale_outs, 0);
    assert!(auto.scale_outs >= 1, "autoscaler must scale out under overload");
    assert!(
        auto.p95_latency < stat.p95_latency,
        "autoscaling must cut tail latency: {} vs static {}",
        auto.p95_latency,
        stat.p95_latency
    );
    assert!(
        auto.total_cost <= stat.total_cost,
        "autoscaling must not bill more than thrashing: {} vs static {}",
        auto.total_cost,
        stat.total_cost
    );
    assert!(auto.max_utilization <= 1.0 + 1e-9);
    assert!(stat.max_utilization <= 1.0 + 1e-9);
}

// ------------------------------------------------ autoregressive workloads

/// A chat scenario on the tiny model with the given decode schedule,
/// arrival pacing and engine knobs — LambdaML deployment (closed-form, no
/// solver anywhere on the path), so every run is byte-deterministic.
fn chat_scenario(
    name: &str,
    rate: f64,
    requests: usize,
    decode: DecodeLengthModel,
    decode_tokens: usize,
    keep_alive: f64,
    window: f64,
) -> Scenario {
    Scenario::builder(name)
        .model("tiny")
        .expect("tiny preset exists")
        .seed(0xC4A7)
        .profile(2, 128)
        .traffic(TrafficSource::Chat {
            process: ArrivalProcess::Deterministic { rate },
            duration: None,
            requests: Some(requests),
            prompt_tokens: 96,
            decode,
            decode_tokens,
        })
        .config(TrafficConfig {
            concurrency: Some(1),
            prewarm: true,
            keep_alive,
            epoch_secs: f64::INFINITY,
            reoptimize: false,
            autoscale: AutoscalePolicy::Off,
            decode_batch_window: window,
            ..TrafficConfig::default()
        })
        .baseline(Baseline::LambdaML)
        .build()
        .expect("chat scenario is valid by construction")
}

/// The decode off-switch: a chat scenario with a fixed decode length of 0
/// serves pure prompts and must reproduce the equivalent `synthetic`
/// scenario byte-for-byte — same corpus, generator and arrival seed
/// derivations, no decode machinery on the path. Pinned on both reference
/// engine configurations (plain queued, and queue-depth autoscaled — the
/// two shapes the committed reference scenarios exercise).
#[test]
fn decode_zero_chat_reproduces_synthetic_byte_for_byte() {
    for (label, autoscale, keep_alive) in [
        ("queued", AutoscalePolicy::Off, f64::INFINITY),
        (
            "autoscaled",
            AutoscalePolicy::QueueDepth { max_wait: 2.0, idle_below: 0.2 },
            10.0,
        ),
    ] {
        let process = ArrivalProcess::Poisson { rate: 2.0 };
        let cfg = TrafficConfig {
            concurrency: Some(1),
            prewarm: true,
            keep_alive,
            epoch_secs: 5.0,
            reoptimize: false,
            autoscale,
            ..TrafficConfig::default()
        };
        let chat = Scenario::builder("decode-zero")
            .model("tiny")
            .expect("tiny preset exists")
            .seed(0x0FF)
            .profile(2, 128)
            .traffic(TrafficSource::Chat {
                process,
                duration: None,
                requests: Some(10),
                prompt_tokens: 96,
                decode: DecodeLengthModel::Fixed { steps: 0 },
                decode_tokens: 8,
            })
            .config(cfg)
            .baseline(Baseline::LambdaML)
            .build()
            .expect("decode-0 chat scenario is valid");
        let mut synth = chat.clone();
        synth.source = TrafficSource::Synthetic {
            process,
            duration: None,
            requests: Some(10),
            tokens_per_request: 96,
        };
        let a = chat.run().expect("chat scenario runs").report;
        let b = synth.run().expect("synthetic scenario runs").report;
        assert_eq!(a.requests, 10, "{label}");
        assert_eq!(a.output_tokens, 0, "{label}: decode 0 emits nothing");
        assert_eq!(a.kv_evictions, 0, "{label}");
        assert_eq!(a.re_prefills, 0, "{label}");
        assert_eq!(a.time_per_output_token, 0.0, "{label}");
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "{label}: decode-0 chat must reproduce synthetic byte-for-byte"
        );
    }
}

/// Work conservation of continuous batching: staggered chat requests that
/// never overlap give the batching window no merge partner, the dispatch
/// gate keeps every lone decode step on the serial path, and the report is
/// byte-identical to batching off — no decode step completes later than it
/// would unbatched on an uncontended replica.
#[test]
fn prop_decode_batching_is_work_conserving_without_contention() {
    // 20 s apart: each request prefills and fully decodes long before the
    // next arrives, so `decode_inflight` never exceeds 1.
    let model = DecodeLengthModel::Fixed { steps: 6 };
    let run = |window: f64| {
        chat_scenario("chat-conserving", 0.05, 4, model.clone(), 8, f64::INFINITY, window)
            .run()
            .expect("chat scenario runs")
            .report
    };
    let off = run(0.0);
    let on = run(0.05);
    assert_eq!(off.requests, 4);
    assert_eq!(off.output_tokens, 4 * 6 * 8, "decode must actually run");
    assert!(off.decode_p50 > 0.0 && off.decode_p95 >= off.decode_p50);
    assert!(off.prefill_p50 > 0.0);
    assert!(off.time_per_output_token > 0.0);
    assert_eq!(off.re_prefills, 0, "infinite keep-alive holds every KV pin");
    assert_eq!(
        on.to_json().to_string_pretty(),
        off.to_json().to_string_pretty(),
        "an open window with no merge partner must change nothing"
    );
}

/// KV-state affinity end-to-end: a short keep-alive expires prefill-pinned
/// instances the sparse decode steps do not revisit, so the ledger must
/// count evictions and the engine must serve billed re-prefills — and still
/// finish every request, deterministically.
#[test]
fn kv_loss_forces_billed_reprefill() {
    // 2-token decode steps touch at most two experts per layer while the
    // 96-token prompt pins (nearly) all of them; at keep-alive 0.3 s an
    // unrevisited pinned instance expires within a step or two.
    let model = DecodeLengthModel::Fixed { steps: 16 };
    let run = || {
        chat_scenario("chat-kv-loss", 0.02, 2, model.clone(), 2, 0.3, 0.0)
            .run()
            .expect("chat scenario runs")
            .report
    };
    let a = run();
    assert_eq!(a.requests, 2, "KV losses must never lose the request");
    assert_eq!(a.output_tokens, 2 * 16 * 2, "every decode step still completes");
    assert!(a.kv_evictions > 0, "short keep-alive must lose KV state");
    assert_eq!(
        a.kv_evictions, a.re_prefills,
        "each loss forces exactly one billed re-prefill"
    );
    assert!(a.time_per_output_token > 0.0);
    let b = run();
    assert_eq!(
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty(),
        "re-prefill runs must be deterministic"
    );
}

/// The PR 9 payoff claim: on a seeded chat workload of co-resident decoding
/// requests, continuous batching (merging same-iteration decode steps into
/// one invocation, cost split by token share) beats per-step serial
/// dispatch on time-per-output-token AND billed cost — the merged
/// invocation pays the per-invocation head time and price once where the
/// serial path pays them per request — deterministically across re-runs.
#[test]
fn continuous_batching_beats_serial_decode_on_tpot_and_cost() {
    // 10 ms apart: all eight requests are in flight together, so their
    // decode steps co-reside and the window always has merge partners.
    let model = DecodeLengthModel::Fixed { steps: 8 };
    let run = |window: f64| {
        chat_scenario("chat-batched", 100.0, 8, model.clone(), 8, f64::INFINITY, window)
            .run()
            .expect("chat scenario runs")
            .report
    };
    let serial = run(0.0);
    let batched = run(0.05);

    // Identical workload both ways.
    assert_eq!(serial.requests, 8);
    assert_eq!(batched.requests, 8);
    assert_eq!(serial.output_tokens, 8 * 8 * 8);
    assert_eq!(batched.output_tokens, serial.output_tokens);
    assert!(serial.time_per_output_token > 0.0);
    assert_eq!(serial.re_prefills, 0);
    assert_eq!(batched.re_prefills, 0);

    // The mechanism: strictly fewer invocations...
    assert!(
        batched.warm_invocations + batched.cold_invocations
            < serial.warm_invocations + serial.cold_invocations,
        "batching must merge invocations: {} vs {}",
        batched.warm_invocations + batched.cold_invocations,
        serial.warm_invocations + serial.cold_invocations
    );
    // ...and the claim: better time-per-output-token at a lower bill.
    assert!(
        batched.time_per_output_token < serial.time_per_output_token,
        "batching must cut time-per-output-token: {} vs {}",
        batched.time_per_output_token,
        serial.time_per_output_token
    );
    assert!(
        batched.total_cost < serial.total_cost,
        "batching must bill less: {} vs {}",
        batched.total_cost,
        serial.total_cost
    );

    // Deterministic under re-run, byte-for-byte.
    let again = run(0.05);
    assert_eq!(
        again.to_json().to_string_pretty(),
        batched.to_json().to_string_pretty(),
        "batched chat runs must be deterministic"
    );
}

/// The committed chat fixture (CI smokes it through `serve_traffic
/// --scenario`): strict load, canonical round-trip, a real decode phase in
/// the report, and byte-identical reports across two runs.
#[test]
fn committed_chat_scenario_loads_and_decodes_deterministically() {
    let s = Scenario::load(&data_path("scenarios/chat_decode.json"))
        .unwrap_or_else(|e| panic!("committed chat scenario must load: {e}"));
    let a = s.run().expect("chat fixture runs").report;
    assert_eq!(a.requests, 12);
    assert!(a.output_tokens > 0, "the fixture exists to exercise decode");
    assert!(a.time_per_output_token > 0.0);
    assert!(a.prefill_p95 >= a.prefill_p50);
    assert!(a.decode_p95 >= a.decode_p50);
    let b = s.run().expect("chat fixture re-runs").report;
    assert_eq!(
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty(),
        "chat fixture runs must be deterministic"
    );
}

/// The drift loop hears decode routing (the ROADMAP direction-3
/// follow-on): an autoregressive workload served under `ours` with
/// re-optimization on absorbs every decode step's realized routing into
/// the predictor table and the drift EMA at staging time (the structural
/// half — decode strictly growing the dataset mass — is pinned by
/// `traffic::sim`'s unit tests), so a drift-armed epoch boundary
/// re-deploys on a chat-only workload. Decode steps used to route through
/// the memo without ever updating the signal the reoptimizer watches.
#[test]
fn chat_decode_drift_triggers_redeploy() {
    let scenario = Scenario::builder("chat-drift")
        .model("tiny")
        .expect("tiny preset exists")
        .seed(0xD21F7)
        .profile(2, 128)
        .traffic(TrafficSource::Chat {
            process: ArrivalProcess::Poisson { rate: 2.0 },
            duration: None,
            requests: Some(24),
            prompt_tokens: 32,
            decode: DecodeLengthModel::Geometric { mean: 6.0, cap: 16 },
            decode_tokens: 8,
        })
        .config(TrafficConfig {
            reoptimize: true,
            // Sub-zero threshold: any absorbed routing counts as drift, so
            // the first armed boundary re-deploys — the arming idiom the
            // epoch-level drift tests use.
            drift_threshold: -1.0,
            solver_time_limit: 0.2,
            epoch_secs: 6.0,
            prewarm: false,
            ..TrafficConfig::default()
        })
        .baseline(Baseline::Ours)
        .build()
        .expect("chat drift scenario is valid");
    let out = scenario.run().expect("chat drift scenario runs");
    let report = out.report;
    assert!(report.output_tokens > 0, "the workload must actually decode");
    assert!(
        report.redeploys >= 1,
        "drift-armed chat workload must re-deploy (got {})",
        report.redeploys
    );
    assert_eq!(
        out.artifacts.policy_history.len() as u64,
        1 + report.redeploys,
        "one history entry per redeploy beyond the initial deployment"
    );
}
