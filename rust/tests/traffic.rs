//! Traffic-subsystem integration tests:
//!
//!  - property tests (in-repo `util::check` harness) on the arrival
//!    generators and the trace format;
//!  - cross-validation that the epoch simulator degenerates to the seed
//!    single-batch pipeline (`serve_with_real_counts` at 1e-6 relative
//!    error, `platform::events::simulate_layer` within modeling slack);
//!  - golden-regression fixtures (committed JSON trace + expected
//!    `SimReport` numbers; self-initializing on first run) so future perf
//!    PRs can't silently change serving semantics;
//!  - the drift claim: online re-optimization beats the static initial
//!    deployment on cumulative billed cost under a skew-shifting MMPP
//!    workload.

use serverless_moe::bo::feedback::serve_with_real_counts;
use serverless_moe::config::workload::CorpusPreset;
use serverless_moe::experiments::traffic::{drift_scenario, scenario_config};
use serverless_moe::model::ModelPreset;
use serverless_moe::platform::events::simulate_layer;
use serverless_moe::predictor::eval::real_counts;
use serverless_moe::traffic::{ArrivalGen, ArrivalProcess, EpochSimulator, Trace, TrafficConfig};
use serverless_moe::util::check::{ensure, forall, forall_default, Config};
use serverless_moe::util::json::Json;
use serverless_moe::workload::Corpus;
use std::path::{Path, PathBuf};

fn data_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/data")
        .join(name)
}

// ---------------------------------------------------------------- arrivals

#[test]
fn prop_interarrival_gaps_nonnegative_finite() {
    forall_default(
        |rng| {
            let kind = rng.index(3);
            let rate = rng.range_f64(0.5, 50.0);
            let rate1 = rng.range_f64(0.05, 5.0);
            let hold0 = rng.range_f64(1.0, 60.0);
            let hold1 = rng.range_f64(1.0, 60.0);
            let process = match kind {
                0 => ArrivalProcess::Deterministic { rate },
                1 => ArrivalProcess::Poisson { rate },
                _ => ArrivalProcess::Mmpp {
                    rate0: rate,
                    rate1,
                    hold0,
                    hold1,
                },
            };
            (process, rng.next_u64())
        },
        |&(process, seed)| {
            let mut gen = ArrivalGen::new(process, seed);
            for _ in 0..200 {
                let g = gen.next_gap();
                ensure(g.is_finite(), format!("{process:?}: non-finite gap {g}"))?;
                ensure(g >= 0.0, format!("{process:?}: negative gap {g}"))?;
            }
            Ok(())
        },
    );
}

#[test]
fn prop_empirical_mean_rate_matches_configured() {
    // Seeds and tolerance validated against an independent reimplementation
    // of the RNG + MMPP algorithm (worst observed relative error 0.087).
    let cases = [
        ArrivalProcess::Poisson { rate: 8.0 },
        ArrivalProcess::Poisson { rate: 2.0 },
        ArrivalProcess::Mmpp {
            rate0: 20.0,
            rate1: 2.0,
            hold0: 5.0,
            hold1: 5.0,
        },
        ArrivalProcess::Mmpp {
            rate0: 12.0,
            rate1: 4.0,
            hold0: 3.0,
            hold1: 7.0,
        },
        ArrivalProcess::Deterministic { rate: 5.0 },
    ];
    let duration = 2000.0;
    for process in cases {
        for seed in 0x7AFF1Cu64..0x7AFF1C + 8 {
            let n = ArrivalGen::new(process, seed).arrivals_until(duration).len();
            let empirical = n as f64 / duration;
            let want = process.mean_rate();
            let rel = (empirical - want).abs() / want;
            assert!(
                rel < 0.15,
                "{process:?} seed={seed:#x}: empirical {empirical:.3}/s vs {want:.3}/s (rel {rel:.3})"
            );
        }
    }
}

// ------------------------------------------------------------------ traces

#[test]
fn prop_trace_json_roundtrip_preserves_everything() {
    forall(
        Config {
            cases: 100,
            ..Default::default()
        },
        |rng| {
            let n = rng.index(20);
            let mut t = 0.0;
            let requests = (0..n)
                .map(|_| {
                    t += rng.range_f64(0.0, 10.0);
                    serverless_moe::traffic::TraceRequest {
                        time: t,
                        tokens: 1 + rng.index(5000),
                        seed: rng.next_u64() >> 12,
                    }
                })
                .collect();
            Trace { requests }
        },
        |trace| {
            let text = trace.to_json().to_string_pretty();
            let back = Trace::from_json(&Json::parse(&text).map_err(|e| e.to_string())?)
                .map_err(|e| e.to_string())?;
            ensure(&back == trace, "roundtrip mismatch")?;
            ensure(
                back.total_tokens() == trace.total_tokens(),
                "token count changed",
            )?;
            ensure(
                back.requests.windows(2).all(|w| w[0].time <= w[1].time),
                "order lost",
            )
        },
    );
}

#[test]
fn committed_trace_replays_in_order_with_token_targets() {
    let trace = Trace::load(&data_path("trace_small.json")).expect("committed trace parses");
    assert_eq!(trace.requests.len(), 12);
    assert_eq!(trace.total_tokens(), 6848);
    assert_eq!(trace.duration(), 300.0);
    let corpus = Corpus::new(CorpusPreset::Enwik8, 3);
    let batches = trace.replay(&corpus, 7);
    assert_eq!(batches.len(), trace.requests.len());
    for (tb, r) in batches.iter().zip(&trace.requests) {
        assert_eq!(tb.at, r.time, "timestamp order must be preserved");
        assert!(
            tb.batch.total_tokens >= r.tokens,
            "batch {} smaller than its target {}",
            tb.batch.total_tokens,
            r.tokens
        );
    }
    assert!(batches.windows(2).all(|w| w[0].at <= w[1].at));
    // Replay is deterministic.
    let again = trace.replay(&corpus, 7);
    for (a, b) in batches.iter().zip(&again) {
        assert_eq!(a.batch.sequences[0].tokens, b.batch.sequences[0].tokens);
    }
}

// -------------------------------------------------------- cross-validation

/// One epoch, all-warm never-expiring pool, no re-optimization: the traffic
/// simulator must reproduce the seed single-batch pipeline.
#[test]
fn degenerate_sim_matches_flat_pipeline_and_event_model() {
    let scn = drift_scenario(ModelPreset::BertMoe { experts: 4, top_k: 1 }, true, 0xC0DE);
    let traffic = vec![scn.traffic[0].clone()];
    let mut cfg = TrafficConfig::degenerate();
    cfg.t_limit = scenario_config(true).t_limit;
    let mut sim = EpochSimulator::new(&scn.platform, &scn.spec, &scn.gate, scn.predictor(), cfg);
    let report = sim.run(&traffic);
    let policy = sim.last_policy.clone().expect("policy recorded");
    let real = real_counts(&scn.gate, &traffic[0].batch);

    // (a) Analytic pipeline: 1e-6 relative error on cost AND latency.
    let flat = serve_with_real_counts(&scn.platform, &scn.spec, &policy, &real, true);
    let rel_cost = (report.total_cost - flat.cost).abs() / flat.cost;
    assert!(
        rel_cost < 1e-6,
        "sim cost {} vs flat {} (rel {rel_cost})",
        report.total_cost,
        flat.cost
    );
    let rel_lat = (report.p50_latency - flat.latency).abs() / flat.latency;
    assert!(
        rel_lat < 1e-6,
        "sim latency {} vs flat {} (rel {rel_lat})",
        report.p50_latency,
        flat.latency
    );

    // (b) Event-level model: same plan with the real token counts, summed
    // over layers, within modeling slack (stage-1 concurrency is the
    // paper's own approximation).
    let mut ev_cost = 0.0;
    let mut ev_lat = 0.0;
    for (l, plan) in policy.layers.iter().enumerate() {
        let mut real_plan = plan.clone();
        for (i, ep) in real_plan.experts.iter_mut().enumerate() {
            ep.tokens = real[l][i];
        }
        let out = simulate_layer(&scn.platform, &scn.spec, l, &real_plan, true);
        ev_cost += out.billed_cost;
        ev_lat += out.latency;
    }
    let rel_ev_cost = (report.total_cost - ev_cost).abs() / ev_cost.max(report.total_cost);
    let rel_ev_lat = (report.p50_latency - ev_lat).abs() / ev_lat.max(report.p50_latency);
    assert!(
        rel_ev_cost < 0.35,
        "sim cost {} vs event model {} (rel {rel_ev_cost})",
        report.total_cost,
        ev_cost
    );
    assert!(
        rel_ev_lat < 0.35,
        "sim latency {} vs event model {} (rel {rel_ev_lat})",
        report.p50_latency,
        ev_lat
    );
}

// ------------------------------------------------------- golden regression

fn golden_run(preset: ModelPreset) -> serverless_moe::traffic::SimReport {
    let scn = drift_scenario(preset, true, 0x601D);
    let mut cfg = scenario_config(true);
    cfg.reoptimize = true;
    cfg.bo_round_iters = 0;
    let mut sim = EpochSimulator::new(&scn.platform, &scn.spec, &scn.gate, scn.predictor(), cfg);
    sim.run(&scn.traffic)
}

/// Committed expected `SimReport` numbers per model preset at a fixed RNG
/// seed. On first run (or after deleting the fixture) the file is
/// initialized from the current implementation and the test asks for a
/// rerun; afterwards any drift in cost/throughput/p95 beyond 1e-6 relative
/// error fails with a diff.
#[test]
fn golden_regression_fixed_seed_reports() {
    use serverless_moe::traffic::SimReport;
    let path = data_path("golden_traffic.json");
    let mut golden = Json::read_file(&path).unwrap_or_else(|_| Json::obj());
    let mut initialized: Vec<&str> = Vec::new();
    for (key, preset) in [
        ("bert-moe", ModelPreset::BertMoe { experts: 4, top_k: 1 }),
        ("gpt2-moe", ModelPreset::Gpt2Moe { top_k: 1 }),
    ] {
        let report = golden_run(preset);
        assert!(report.requests > 10, "{key}: degenerate scenario");
        assert!(report.total_cost > 0.0 && report.total_cost.is_finite());
        assert!(report.p50_latency <= report.p95_latency);
        assert!(report.p95_latency <= report.p99_latency);
        // Determinism: an immediate re-run must reproduce the numbers.
        let again = golden_run(preset);
        if let Err(e) = report.close_to(&again, 1e-9) {
            panic!("{key}: simulator is nondeterministic across reruns: {e}");
        }
        match golden.get(key) {
            Some(g) => {
                let want = SimReport::from_json(g).expect("golden entry parses");
                if let Err(e) = report.close_to(&want, 1e-6) {
                    panic!(
                        "{key}: golden regression: {e}\n\
                         (if this change is intentional, delete {path:?} and rerun to re-baseline)"
                    );
                }
            }
            None => {
                golden.set(key, report.to_json());
                initialized.push(key);
            }
        }
    }
    if !initialized.is_empty() {
        golden.write_file(&path).expect("golden fixture written");
        eprintln!(
            "initialized golden fixture for {initialized:?} at {path:?}; rerun to verify against it"
        );
    }
}

// ------------------------------------------------------------ drift claim

/// Under a bursty MMPP workload whose expert popularity drifts mid-run, the
/// online BO re-optimization loop must end up cheaper than serving the
/// whole stream on the static initial deployment.
#[test]
fn reoptimization_beats_static_deployment_under_drift() {
    let scn = drift_scenario(ModelPreset::BertMoe { experts: 4, top_k: 1 }, true, 0x5EED);

    let ours = {
        let mut cfg_ours = scenario_config(true);
        cfg_ours.reoptimize = true;
        cfg_ours.bo_round_iters = 1;
        let mut sim =
            EpochSimulator::new(&scn.platform, &scn.spec, &scn.gate, scn.predictor(), cfg_ours);
        sim.run(&scn.traffic)
    };

    let stat = {
        let mut cfg_static = scenario_config(true);
        cfg_static.reoptimize = false;
        let mut sim = EpochSimulator::new(
            &scn.platform,
            &scn.spec,
            &scn.gate,
            scn.predictor(),
            cfg_static,
        );
        sim.run(&scn.traffic)
    };

    assert!(
        ours.redeploys >= 1,
        "drift must trigger at least one re-optimization (tv threshold too high?)"
    );
    assert_eq!(stat.redeploys, 0);
    assert!(
        ours.total_cost < stat.total_cost,
        "online re-optimization must cut cumulative billed cost: ours {} vs static {}",
        ours.total_cost,
        stat.total_cost
    );
    // The gap is availability, not free lunch: the shared pre-drift
    // requests bound ours' tail latency from below.
    assert!(ours.p99_latency >= stat.p99_latency * 0.5);
}
