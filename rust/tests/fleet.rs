//! Multi-tenant fleet integration tests:
//!
//!  - **reproduction pin**: with one tenant and no account cap, the fleet
//!    engine reproduces `Scenario::run()` — byte-identically on the
//!    solver-free committed reference scenario, and within 1e-9 + exact
//!    integer counters on the ODS-bearing drift reference (its solves are
//!    wall-clock *limited*, so byte identity cannot be promised even for
//!    two `Scenario::run()` calls against each other — the same policy the
//!    golden fixtures use). This extends the PR 1→4 cross-validation
//!    chain: flat pipeline → legacy loop → event engine → fleet driver.
//!  - **shared-beats-isolated claim**: two tenants with anti-correlated
//!    MMPP bursts behind a shared account cap are served at strictly lower
//!    total billed cost and equal-or-lower p95 than the isolation baseline
//!    (each tenant alone on its weighted cap share). The construction is
//!    self-calibrating: it measures the tenant's all-warm request latency
//!    L, drives the burst at 3 requests per L (saturating the isolated
//!    share hard and the shared pool mildly), and picks a keep-alive
//!    between the shared pool's per-instance revisit gap (~L/2) and the
//!    isolated share's (~L), so cap-serialization pushes the isolated
//!    run's invocations past keep-alive into billed cold starts while the
//!    shared pool's stay warm. Everything on the path is closed-form
//!    (LambdaML deployments, no solver), so the outcome is deterministic.
//!  - **shared-experts-beats-private claim at 100 tenants**: same-preset
//!    tenants drawing on one refcounted warm replica pool
//!    (`share_experts`) cold-start strictly less and bill strictly less
//!    than tenants with private pools, on a staggered two-sweep workload
//!    where each tenant alone is too sparse to stay warm but the fleet
//!    collectively is not.
//!  - **cross-tenant batching claim**: staggered tenants with `active`
//!    churn windows and a coincident revisit wave are served, under a
//!    `batch_window`, with strictly fewer invocations and strictly lower
//!    billed cost at a fleet p95 no worse than the unbatched baseline —
//!    merged dispatches pay the per-invocation head time and price once.
//!  - **committed fixtures**: the two-tenant, hundred-tenant and
//!    churn+batching fleet files load strictly, round-trip canonically,
//!    and run deterministically end-to-end.
//!  - **parallel driver pins**: every committed fleet fixture and a
//!    constructed genuinely multi-shard fleet serve byte-identically
//!    under `FleetDriver::Parallel` at 1, 2, 4 and 8 threads — the
//!    conservative-window protocol's determinism contract.

use serverless_moe::traffic::fleet::{FleetScenario, PreparedFleet, TenantSource, TenantSpec};
use serverless_moe::traffic::scenario::{Baseline, Scenario, TrafficSource};
use serverless_moe::traffic::trace::{Trace, TraceRequest};
use serverless_moe::traffic::{
    arrival_seed, ArrivalGen, ArrivalProcess, CapGranularity, FaultSpec, FleetArbitration,
    FleetDriver, FleetReport, TrafficConfig,
};
use std::path::{Path, PathBuf};

fn scenario_path(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/data/scenarios")
        .join(name)
}

fn single_tenant_fleet(s: Scenario) -> FleetScenario {
    FleetScenario {
        name: format!("pin-{}", s.name),
        account_cap: None,
        arbitration: FleetArbitration::Fifo,
        cap_granularity: CapGranularity::Execution,
        share_experts: false,
        slo_feedback: false,
        batch_window: 0.0,
        faults: FaultSpec::off(),
        driver: FleetDriver::Heap,
        tenants: vec![TenantSpec::inline("only", s)],
    }
}

// --------------------------------------------------------- reproduction pin

/// Solver-free committed scenario: the fleet engine with one tenant and no
/// cap must reproduce `Scenario::run()` byte-for-byte — report, cost
/// timeline, and artifacts.
#[test]
fn single_tenant_uncapped_fleet_is_byte_identical_to_scenario_run() {
    let s = Scenario::load(&scenario_path("tiny_trace_lambdaml.json")).expect("scenario loads");
    let solo = s.run().expect("scenario runs");
    let fleet = single_tenant_fleet(s).run().expect("fleet runs");

    assert_eq!(fleet.report.tenants.len(), 1);
    let tenant = &fleet.report.tenants[0];
    assert_eq!(
        tenant.report.to_json().to_string_pretty(),
        solo.report.to_json().to_string_pretty(),
        "fleet-of-one must reproduce the standalone report byte-for-byte"
    );
    // PartialEq covers what the JSON omits (the cost timeline), exactly.
    assert_eq!(tenant.report, solo.report);
    assert_eq!(tenant.capped_requests, 0, "no cap, no parking");
    assert_eq!(tenant.mean_cap_delay, 0.0);
    assert_eq!(fleet.report.total_cost, solo.report.total_cost);
    assert_eq!(fleet.report.capped_requests, 0);
    assert_eq!(fleet.report.fairness, 1.0, "one tenant is trivially fair");

    // Artifacts mirror the standalone run too.
    let fa = &fleet.artifacts[0];
    assert_eq!(fa.latencies, solo.artifacts.latencies);
    assert_eq!(fa.redeploy_times, solo.artifacts.redeploy_times);
    assert_eq!(fa.autoscale_events, solo.artifacts.autoscale_events);
    assert_eq!(fa.policy_history.len(), solo.artifacts.policy_history.len());
    assert!(fa.final_policy.is_some());
}

/// The ODS-bearing drift reference: 1e-9 relative on the float aggregates,
/// exact on every integer counter (the wall-clock-limited solver precludes
/// a byte pin — same tolerance policy as `drift_scenario_roundtrip_*`).
#[test]
fn single_tenant_uncapped_fleet_reproduces_drift_reference() {
    let s = Scenario::load(&scenario_path("drift_bert_quick.json")).expect("scenario loads");
    let solo = s.run().expect("scenario runs").report;
    let fleet = single_tenant_fleet(s).run().expect("fleet runs");
    let t = &fleet.report.tenants[0].report;
    if let Err(e) = t.close_to(&solo, 1e-9) {
        panic!("fleet-of-one drifted from Scenario::run on the drift reference: {e}");
    }
    assert_eq!(t.requests, solo.requests);
    assert_eq!(t.epochs, solo.epochs);
    assert_eq!(t.redeploys, solo.redeploys);
    assert_eq!(t.warm_invocations, solo.warm_invocations);
    assert_eq!(t.cold_invocations, solo.cold_invocations);
    assert_eq!(t.queued_invocations, solo.queued_invocations);
    assert_eq!(t.violation_batches, solo.violation_batches);
    assert_eq!(t.scale_outs, solo.scale_outs);
    assert_eq!(t.scale_ins, solo.scale_ins);
}

// ------------------------------------------------- shared beats isolated

/// A claim tenant: tiny model, LambdaML deployment (closed-form — nothing
/// wall-clock-bound anywhere), bursty two-state MMPP.
fn claim_tenant(
    name: &str,
    seed: u64,
    process: ArrivalProcess,
    duration: f64,
    keep_alive: f64,
) -> TenantSpec {
    let scenario = Scenario::builder(name)
        .model("tiny")
        .expect("tiny preset exists")
        .seed(seed)
        .profile(2, 128)
        .traffic(TrafficSource::Synthetic {
            process,
            duration: Some(duration),
            requests: None,
            tokens_per_request: 256,
        })
        .config(TrafficConfig {
            reoptimize: false,
            prewarm: false,
            keep_alive,
            epoch_secs: f64::INFINITY,
            ..TrafficConfig::default()
        })
        .baseline(Baseline::LambdaML)
        .build()
        .expect("claim tenant is valid by construction");
    TenantSpec {
        name: name.to_string(),
        weight: 1.0,
        slo_p95: None,
        active: None,
        source: TenantSource::Inline(scenario),
    }
}

fn count_in(arrivals: &[f64], from: f64, to: f64) -> usize {
    arrivals.iter().filter(|&&t| t >= from && t < to).count()
}

/// MMPP holding times are exponential draws, so whether the realized
/// streams are cleanly anti-correlated depends on the seed. Rather than
/// hope, search (deterministically) for a scenario seed whose realized
/// arrivals satisfy the wanted burst/quiet structure — reproducing the
/// exact arrival stream the scenario will serve (`Scenario::materialize`
/// seeds its `ArrivalGen` with `arrival_seed(seed)`, the documented
/// derivation).
fn pick_seed(
    process: ArrivalProcess,
    duration: f64,
    ok: impl Fn(&[f64]) -> bool,
) -> u64 {
    for seed in 0..10_000u64 {
        let arrivals = ArrivalGen::new(process, arrival_seed(seed)).arrivals_until(duration);
        if ok(&arrivals) {
            return seed;
        }
    }
    panic!("no seed in 0..10000 produced the wanted burst structure");
}

/// All-warm request latency of the claim tenant's deployment, measured by
/// serving one inline-trace request on a pre-warmed, never-expiring pool.
fn calibrate_request_latency() -> f64 {
    let solo = Scenario::builder("calibrate")
        .model("tiny")
        .expect("tiny preset exists")
        .seed(0xCA11)
        .profile(2, 128)
        .traffic(TrafficSource::Inline {
            trace: Trace {
                requests: vec![TraceRequest { time: 0.0, tokens: 256, seed: 1 }],
            },
        })
        .config(TrafficConfig {
            reoptimize: false,
            prewarm: true,
            keep_alive: f64::INFINITY,
            epoch_secs: f64::INFINITY,
            ..TrafficConfig::default()
        })
        .baseline(Baseline::LambdaML)
        .build()
        .expect("calibration scenario is valid")
        .run()
        .expect("calibration scenario runs");
    let l = solo.report.mean_latency;
    assert!(l.is_finite() && l > 0.0, "degenerate calibration latency {l}");
    l
}

/// The two anti-correlated claim processes and seeds whose *realized*
/// streams burst cleanly apart: `early` bursts inside `[0, 15L]` and is
/// silent from `18L` on; `late` is silent before `18L` and bursts after.
/// Burst rate is 3 requests per request-latency: the isolated share
/// (cap 1, capacity 1/L) saturates 3x over, the shared pool (cap 2 while
/// the other tenant is quiet, capacity 2/L) 1.5x. Both backlog, but the
/// isolated share serializes request starts ~L apart where the shared pool
/// keeps them ~L/2 apart — a keep-alive window between those per-instance
/// revisit gaps turns isolation into billed cold starts.
fn claim_processes(l: f64) -> (ArrivalProcess, u64, ArrivalProcess, u64, f64) {
    let burst = 3.0 / l;
    let quiet = 1e-3;
    let duration = 45.0 * l;
    let early = ArrivalProcess::Mmpp {
        rate0: burst,
        rate1: quiet,
        hold0: 12.0 * l,
        hold1: 1000.0 * l,
    };
    let late = ArrivalProcess::Mmpp {
        rate0: quiet,
        rate1: burst,
        hold0: 25.0 * l,
        hold1: 1000.0 * l,
    };
    let early_seed = pick_seed(early, duration, |a| {
        count_in(a, 0.0, 15.0 * l) >= 25 && count_in(a, 18.0 * l, duration) <= 1
    });
    let late_seed = pick_seed(late, duration, |a| {
        count_in(a, 0.0, 18.0 * l) <= 1 && count_in(a, 18.0 * l, duration) >= 25
    });
    (early, early_seed, late, late_seed, duration)
}

fn claim_fleet(l: f64, keep_alive: f64) -> FleetScenario {
    let (early, early_seed, late, late_seed, duration) = claim_processes(l);
    FleetScenario {
        name: "claim-fleet".to_string(),
        account_cap: Some(2),
        arbitration: FleetArbitration::WeightedFair,
        // The PR 5 pin serves under the original per-request accounting:
        // the claim's mechanism (cap-serialized request starts ~L apart)
        // is a property of request-granular slots, so it stays pinned to
        // that mode explicitly.
        cap_granularity: CapGranularity::Request,
        share_experts: false,
        slo_feedback: false,
        batch_window: 0.0,
        faults: FaultSpec::off(),
        driver: FleetDriver::Heap,
        tenants: vec![
            claim_tenant("early", early_seed, early, duration, keep_alive),
            claim_tenant("late", late_seed, late, duration, keep_alive),
        ],
    }
}

fn total_colds(r: &FleetReport) -> u64 {
    r.tenants.iter().map(|t| t.report.cold_invocations).sum()
}

/// The payoff claim of the fleet layer: under anti-correlated bursts, the
/// shared account pool serves the same two tenants at strictly lower total
/// billed cost and equal-or-lower p95 than isolated per-tenant cap shares.
/// The keep-alive is swept over fractions of the measured request latency;
/// the claim must hold at some sweep point (the mechanism — isolation's
/// wider per-instance revisit gaps crossing keep-alive — is additionally
/// pinned via the cold-start counters), and the sweep itself documents the
/// sensitivity of the win to the keep-alive window.
#[test]
fn shared_pool_beats_isolated_shares_under_anticorrelated_bursts() {
    let l = calibrate_request_latency();
    let mut wins = Vec::new();
    let mut diagnostics = Vec::new();
    for frac in [0.75, 0.6, 0.45, 0.3] {
        let fleet = claim_fleet(l, frac * l);
        let shared = fleet.run().expect("shared fleet runs").report;
        let isolated = fleet.run_isolated().expect("isolated baseline runs").report;

        // The cap must actually bind in the shared run, or the comparison
        // is vacuous.
        assert!(
            shared.capped_requests > 0,
            "account cap never bound at keep_alive {frac}L — burst not saturating?"
        );
        let cost_win = shared.total_cost < isolated.total_cost;
        let p95_win = shared.max_p95() <= isolated.max_p95();
        let cold_win = total_colds(&shared) < total_colds(&isolated);
        diagnostics.push(format!(
            "k={frac}L: cost {:.6} vs {:.6}, p95 {:.3} vs {:.3}, colds {} vs {}",
            shared.total_cost,
            isolated.total_cost,
            shared.max_p95(),
            isolated.max_p95(),
            total_colds(&shared),
            total_colds(&isolated),
        ));
        if cost_win && p95_win && cold_win {
            wins.push((frac, shared, isolated));
        }
    }
    assert!(
        !wins.is_empty(),
        "shared pool never beat isolated shares across the keep-alive sweep:\n{}",
        diagnostics.join("\n")
    );
    // At the winning point the mechanism is exactly the advertised one:
    // fewer cold starts (strictly), strictly lower billed cost, and no p95
    // regression — with sane fleet-report plumbing around it.
    let (frac, shared, isolated) = &wins[0];
    assert!(
        shared.total_cost < isolated.total_cost,
        "k={frac}L: shared {} vs isolated {}",
        shared.total_cost,
        isolated.total_cost
    );
    assert!(shared.max_p95() <= isolated.max_p95());
    assert!(shared.fairness > 0.0 && shared.fairness <= 1.0 + 1e-12);
    assert_eq!(
        shared.tenants.iter().map(|t| t.report.requests).sum::<u64>(),
        isolated.tenants.iter().map(|t| t.report.requests).sum::<u64>(),
        "both pools must serve the identical fleet"
    );
    // Determinism: the winning configuration reproduces itself exactly.
    let again = claim_fleet(l, frac * l).run().expect("re-run").report;
    assert_eq!(
        again.to_json().to_string_pretty(),
        shared.to_json().to_string_pretty(),
        "fleet runs must be deterministic"
    );
}

// ------------------------------------------------------ committed fixture

/// The committed two-tenant fleet file: strict load, canonical round-trip,
/// and a full shared-pool run with per-tenant SLO wiring intact.
#[test]
fn committed_fleet_scenario_loads_roundtrips_and_runs() {
    let fleet =
        FleetScenario::load(&scenario_path("fleet_two_tenant.json")).unwrap_or_else(|e| {
            panic!("committed fleet scenario must load: {e}");
        });
    let text = fleet.to_json().to_string_pretty();
    let back = serverless_moe::traffic::fleet::FleetScenario::from_json(
        &serverless_moe::util::json::Json::parse(&text).expect("canonical JSON parses"),
    )
    .expect("canonical form re-parses");
    assert_eq!(
        back.to_json().to_string_pretty(),
        text,
        "fleet serialization must be a fixed point"
    );

    let outcome = fleet.run().expect("committed fleet runs");
    let r = &outcome.report;
    assert_eq!(r.tenants.len(), 2);
    assert_eq!(r.account_cap, Some(2));
    assert!(r.total_cost > 0.0);
    assert!(r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12);
    let chat = r.tenant("chat").expect("chat tenant reported");
    assert_eq!(chat.slo_p95, Some(60.0));
    assert!(chat.report.requests > 0);
    assert_eq!(outcome.artifacts.len(), 2);
    for (art, tr) in outcome.artifacts.iter().zip(&r.tenants) {
        assert_eq!(art.latencies.len() as u64, tr.report.requests);
        assert!(art.final_policy.is_some());
    }
}

// ------------------------------------------- shared experts at 100 tenants

/// 100 identical tiny tenants, each sending two requests: one during a
/// staggered opening sweep (tenant `i` at `i·Δ`) and one in a second sweep a
/// revisit gap `T` later. `T` exceeds the keep-alive window, so every
/// *private* per-tenant pool goes cold before its second request — but the
/// fleet as a whole keeps a steady `Δ`-cadence on the *shared* pool, and the
/// inter-sweep gap `T − 99Δ` stays inside keep-alive, so the shared pool
/// cold-starts exactly once. All tenants use the same scenario seed and
/// request seeds, so routing is identical and every request lands on the
/// same shared replicas.
fn hundred_tenant_claim_fleet(l: f64, share_experts: bool) -> FleetScenario {
    let delta = 4.0 * l;
    let keep_alive = 200.0 * delta;
    // > keep_alive (private pools expire); revisit − 99Δ = 151Δ < keep_alive
    // (the shared pool does not).
    let revisit = 250.0 * delta;
    let tenants = (0..100)
        .map(|i| {
            let name = format!("t{i:03}");
            let first = i as f64 * delta;
            let scenario = Scenario::builder(&name)
                .model("tiny")
                .expect("tiny preset exists")
                .seed(0xF1EE7)
                .profile(2, 128)
                .traffic(TrafficSource::Inline {
                    trace: Trace {
                        requests: vec![
                            TraceRequest { time: first, tokens: 256, seed: 7 },
                            TraceRequest { time: revisit + first, tokens: 256, seed: 7 },
                        ],
                    },
                })
                .config(TrafficConfig {
                    reoptimize: false,
                    prewarm: false,
                    keep_alive,
                    epoch_secs: f64::INFINITY,
                    ..TrafficConfig::default()
                })
                .baseline(Baseline::LambdaML)
                .build()
                .expect("pool-member tenant is valid by construction");
            TenantSpec {
                name,
                weight: 1.0,
                slo_p95: None,
                active: None,
                source: TenantSource::Inline(scenario),
            }
        })
        .collect();
    FleetScenario {
        name: if share_experts { "hundred-shared" } else { "hundred-private" }.to_string(),
        account_cap: None,
        arbitration: FleetArbitration::Fifo,
        cap_granularity: CapGranularity::Execution,
        share_experts,
        slo_feedback: false,
        batch_window: 0.0,
        faults: FaultSpec::off(),
        driver: FleetDriver::Heap,
        tenants,
    }
}

/// The PR's shared-experts claim at fleet scale: 100 same-preset tenants
/// whose individual traffic is far too sparse to keep a private pool warm
/// collectively sustain one shared pool — strictly fewer cold starts,
/// strictly lower billed cost, no p95 regression, deterministically.
#[test]
fn shared_expert_pool_beats_private_pools_at_100_tenants() {
    let l = calibrate_request_latency();
    let shared = hundred_tenant_claim_fleet(l, true).run().expect("shared run").report;
    let private = hundred_tenant_claim_fleet(l, false).run().expect("private run").report;

    assert_eq!(shared.tenants.len(), 100);
    let served: u64 = shared.tenants.iter().map(|t| t.report.requests).sum();
    assert_eq!(served, 200, "every tenant's two requests must be served");
    assert_eq!(
        served,
        private.tenants.iter().map(|t| t.report.requests).sum::<u64>(),
        "both fleets serve the identical workload"
    );
    assert!(
        total_colds(&shared) < total_colds(&private),
        "shared pool must cold-start less: {} vs {}",
        total_colds(&shared),
        total_colds(&private)
    );
    assert!(
        shared.total_cost < private.total_cost,
        "shared pool must bill less: {} vs {}",
        shared.total_cost,
        private.total_cost
    );
    assert!(
        shared.max_p95() <= private.max_p95() + 1e-9,
        "sharing must not regress p95: {} vs {}",
        shared.max_p95(),
        private.max_p95()
    );
    // Determinism at fleet scale: the winning run reproduces itself exactly.
    let again = hundred_tenant_claim_fleet(l, true).run().expect("re-run").report;
    assert_eq!(
        again.to_json().to_string_pretty(),
        shared.to_json().to_string_pretty(),
        "shared-pool fleet runs must be deterministic"
    );
}

// ------------------------------------------- churn + cross-tenant batching

/// The PR 7 claim fleet: four same-preset tenants onboard on a stagger
/// (tenant `i` at `i·Δ`, its activity window opening exactly there), each
/// sends one solo request at onboard time and one at a common revisit
/// instant all windows overlap, then offboards on a stagger (releasing its
/// refcounts on the shared pool). At the revisit the four dispatches land
/// on the same concurrency-1 replica FIFOs within the batching window, so
/// the unbatched baseline serializes four invocations per layer where the
/// batched fleet merges them into one with the combined token count. All
/// tenants share the scenario seed, gate seed, and request seeds, so
/// routing is identical and the merge partners are guaranteed.
fn churn_batching_fleet(l: f64, window: f64) -> FleetScenario {
    let delta = 4.0 * l;
    let revisit = 40.0 * l;
    let tenants = (0..4)
        .map(|i| {
            let first = i as f64 * delta;
            let scenario = Scenario::builder(&format!("churn{i}"))
                .model("tiny")
                .expect("tiny preset exists")
                .seed(0xF1EE7)
                .profile(2, 128)
                .traffic(TrafficSource::Inline {
                    trace: Trace {
                        requests: vec![
                            TraceRequest { time: first, tokens: 256, seed: 7 },
                            TraceRequest { time: revisit, tokens: 256, seed: 7 },
                        ],
                    },
                })
                .config(TrafficConfig {
                    reoptimize: false,
                    prewarm: false,
                    keep_alive: 100.0 * l,
                    concurrency: Some(1),
                    epoch_secs: f64::INFINITY,
                    ..TrafficConfig::default()
                })
                .baseline(Baseline::LambdaML)
                .build()
                .expect("churn tenant is valid by construction");
            TenantSpec {
                name: format!("c{i}"),
                weight: 1.0,
                slo_p95: None,
                active: Some((first, revisit + (i as f64 + 2.0) * delta)),
                source: TenantSource::Inline(scenario),
            }
        })
        .collect();
    FleetScenario {
        name: "churn-batching".to_string(),
        account_cap: None,
        arbitration: FleetArbitration::Fifo,
        cap_granularity: CapGranularity::Execution,
        share_experts: true,
        slo_feedback: false,
        batch_window: window,
        faults: FaultSpec::off(),
        driver: FleetDriver::Heap,
        tenants,
    }
}

/// The PR 7 payoff claim: on the staggered-churn fleet with an overlapping
/// revisit wave, cross-tenant batching serves the identical workload with
/// strictly fewer invocations and strictly lower billed cost, at a fleet
/// p95 no worse than the unbatched baseline. The mechanism: one merged
/// invocation pays the per-invocation head time (warm start + parameter
/// fetch) and the per-invocation price once where the serialized baseline
/// pays them four times per layer — and the baseline's last-in-FIFO tenant
/// queues behind the other three, so its p95 dominates the window delay
/// plus the combined token time the batch pays.
#[test]
fn cross_tenant_batching_beats_unbatched_on_staggered_revisits() {
    let l = calibrate_request_latency();
    let window = 0.05 * l;
    let batched = churn_batching_fleet(l, window).run().expect("batched run").report;
    let unbatched = churn_batching_fleet(l, 0.0).run().expect("unbatched run").report;

    let served = |r: &FleetReport| r.tenants.iter().map(|t| t.report.requests).sum::<u64>();
    assert_eq!(served(&batched), 8, "four tenants, two requests each");
    assert_eq!(served(&batched), served(&unbatched), "identical workload both ways");

    let invocations = |r: &FleetReport| {
        r.tenants
            .iter()
            .map(|t| t.report.warm_invocations + t.report.cold_invocations)
            .sum::<u64>()
    };
    assert!(
        invocations(&batched) < invocations(&unbatched),
        "batching must merge invocations: {} vs {}",
        invocations(&batched),
        invocations(&unbatched)
    );
    assert!(
        batched.total_cost < unbatched.total_cost,
        "batching must bill less: {} vs {}",
        batched.total_cost,
        unbatched.total_cost
    );
    assert!(
        batched.max_p95() <= unbatched.max_p95() + 1e-9,
        "batching must not regress fleet p95: {} vs {}",
        batched.max_p95(),
        unbatched.max_p95()
    );
    let merges: u64 = batched.tenants.iter().map(|t| t.batched_invocations).sum();
    assert!(merges > 0, "the revisit wave must actually merge");
    assert_eq!(
        unbatched.tenants.iter().map(|t| t.batched_invocations).sum::<u64>(),
        0,
        "batching off must never merge"
    );
    // Determinism: the batched run reproduces itself exactly.
    let again = churn_batching_fleet(l, window).run().expect("re-run").report;
    assert_eq!(
        again.to_json().to_string_pretty(),
        batched.to_json().to_string_pretty(),
        "churn+batching fleet runs must be deterministic"
    );
}

/// The committed churn+batching fixture (CI smokes it via the `*fleet*`
/// glob): strict load — including a `"slo_p95": null` and the `active`
/// windows — canonical round-trip, and the structural (timing-free) half
/// of the batching claim: flipping the committed window off serves the
/// same workload with strictly more invocations at strictly higher cost.
#[test]
fn committed_churn_batching_fleet_loads_and_merges() {
    let fleet = FleetScenario::load(&scenario_path("fleet_churn_batching.json"))
        .unwrap_or_else(|e| panic!("committed churn fleet must load: {e}"));
    assert!(fleet.share_experts && fleet.batch_window > 0.0);
    assert_eq!(fleet.tenants[0].slo_p95, None, "explicit null parses as unbounded");
    assert_eq!(fleet.tenants[1].active, Some((2.0, 30.0)));

    let text = fleet.to_json().to_string_pretty();
    let back = FleetScenario::from_json(
        &serverless_moe::util::json::Json::parse(&text).expect("canonical JSON parses"),
    )
    .expect("canonical form re-parses");
    assert_eq!(back.to_json().to_string_pretty(), text, "fixed-point serialization");

    let on = fleet.run().expect("churn fixture runs").report;
    let mut off_fleet = fleet.clone();
    off_fleet.batch_window = 0.0;
    let off = off_fleet.run().expect("unbatched churn fixture runs").report;
    let served = |r: &FleetReport| r.tenants.iter().map(|t| t.report.requests).sum::<u64>();
    assert_eq!(served(&on), 6, "three tenants, two requests each");
    assert_eq!(served(&on), served(&off));
    let invocations = |r: &FleetReport| {
        r.tenants
            .iter()
            .map(|t| t.report.warm_invocations + t.report.cold_invocations)
            .sum::<u64>()
    };
    assert!(invocations(&on) < invocations(&off));
    assert!(on.total_cost < off.total_cost);
    assert!(on.tenants.iter().map(|t| t.batched_invocations).sum::<u64>() > 0);

    let again = fleet.run().expect("churn fixture re-runs").report;
    assert_eq!(
        again.to_json().to_string_pretty(),
        on.to_json().to_string_pretty(),
        "churn fixture runs must be deterministic"
    );
}

/// The committed 100-tenant fleet file (the CI smoke matrix picks it up via
/// its `*fleet*` glob): strict load, shape checks, a full run, and exact
/// reproducibility.
#[test]
fn committed_hundred_tenant_fleet_loads_and_runs() {
    let fleet = FleetScenario::load(&scenario_path("fleet_hundred_tenant.json"))
        .unwrap_or_else(|e| panic!("committed hundred-tenant fleet must load: {e}"));
    assert_eq!(fleet.tenants.len(), 100);
    assert!(fleet.share_experts, "the fixture exists to exercise the shared pool");

    let outcome = fleet.run().expect("hundred-tenant fleet runs");
    let r = &outcome.report;
    assert_eq!(r.tenants.len(), 100);
    assert!(r.total_cost > 0.0);
    assert!(r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12);
    let again = fleet.run().expect("hundred-tenant fleet re-runs");
    assert_eq!(
        again.report.to_json().to_string_pretty(),
        r.to_json().to_string_pretty(),
        "hundred-tenant fleet runs must be deterministic"
    );
}

// ------------------------------------------------- failure injection claims

/// A contended, crashy tenant for the hedging claim: tiny model, LambdaML
/// deployment (closed-form, nothing wall-clock-bound), deterministic
/// arrivals at twice the all-warm service rate so per-instance FIFO
/// backlogs grow over the run and the straggler quantile keeps climbing —
/// exactly the regime speculative hedging exists for. Crashes ride along
/// so hedging is measured *on top of* a working retry loop, not instead
/// of one.
fn crashy_fleet(l: f64, faults: FaultSpec) -> FleetScenario {
    let scenario = Scenario::builder("crashy")
        .model("tiny")
        .expect("tiny preset exists")
        .seed(0xC4A5)
        .profile(2, 128)
        .traffic(TrafficSource::Synthetic {
            process: ArrivalProcess::Deterministic { rate: 2.0 / l },
            duration: Some(40.0 * l),
            requests: None,
            tokens_per_request: 256,
        })
        .config(TrafficConfig {
            reoptimize: false,
            prewarm: true,
            keep_alive: f64::INFINITY,
            epoch_secs: f64::INFINITY,
            ..TrafficConfig::default()
        })
        .baseline(Baseline::LambdaML)
        .build()
        .expect("crashy tenant is valid by construction");
    FleetScenario {
        name: "crashy-fleet".to_string(),
        account_cap: None,
        arbitration: FleetArbitration::Fifo,
        cap_granularity: CapGranularity::Execution,
        share_experts: false,
        slo_feedback: false,
        batch_window: 0.0,
        faults,
        driver: FleetDriver::Heap,
        tenants: vec![TenantSpec::inline("crashy", scenario)],
    }
}

fn crashy_faults(l: f64, hedge_quantile: f64) -> FaultSpec {
    FaultSpec {
        crash_prob: 0.12,
        cold_crash_multiplier: 2.0,
        throttle_prob: 0.0,
        timeout: f64::INFINITY,
        max_retries: 3,
        backoff_base: 0.05 * l,
        hedge_quantile,
        hedge_min_obs: 16,
        drop_after: 0,
    }
}

/// The tentpole payoff claim, pinned: under a seeded crashy contended
/// scenario, hedging+retry beats retry-only on p95 at bounded (< 2x)
/// extra cost — and the faulted runs are deterministic byte-for-byte
/// across two executions.
#[test]
fn hedging_plus_retry_beats_retry_only_on_p95_at_bounded_cost() {
    let l = calibrate_request_latency();
    let retry_only = crashy_fleet(l, crashy_faults(l, 0.0))
        .run()
        .expect("retry-only fleet runs")
        .report;
    let hedged = crashy_fleet(l, crashy_faults(l, 0.85))
        .run()
        .expect("hedged fleet runs")
        .report;

    // Both runs served the identical workload through real fault weather.
    let served = |r: &FleetReport| r.tenants.iter().map(|t| t.report.requests).sum::<u64>();
    assert_eq!(served(&retry_only), served(&hedged), "identical workload both ways");
    assert!(
        retry_only.failed_invocations > 0 && retry_only.retries > 0,
        "crashes and retries must actually fire in the baseline"
    );
    assert!(hedged.failed_invocations > 0 && hedged.retries > 0);
    assert_eq!(retry_only.hedged_invocations, 0, "quantile 0 = hedging off");
    assert!(hedged.hedged_invocations > 0, "stragglers must be hedged");
    assert!(hedged.hedge_wins > 0, "some hedges must win the race");

    // The claim: strictly better p95 at strictly bounded extra cost.
    assert!(
        hedged.max_p95() < retry_only.max_p95(),
        "hedging must cut the tail: {} vs {}",
        hedged.max_p95(),
        retry_only.max_p95()
    );
    assert!(
        hedged.total_cost < 2.0 * retry_only.total_cost,
        "hedging must stay under 2x the retry-only bill: {} vs {}",
        hedged.total_cost,
        retry_only.total_cost
    );

    // Deterministic across two runs, byte-for-byte.
    let again = crashy_fleet(l, crashy_faults(l, 0.85)).run().expect("re-run").report;
    assert_eq!(
        again.to_json().to_string_pretty(),
        hedged.to_json().to_string_pretty(),
        "faulted fleet runs must be deterministic"
    );
}

/// The committed crashy fleet fixture (the CI smoke matrix picks it up via
/// its `*fleet*` glob; the chaos job re-runs it in release mode): strict
/// load, canonical round-trip, byte-identical reports across two runs, and
/// nonzero recovered-request counters — the fault machinery actually ran
/// and the fleet still served every request.
#[test]
fn committed_faults_fleet_is_deterministic_and_recovers() {
    let fleet = FleetScenario::load(&scenario_path("fleet_faults.json"))
        .unwrap_or_else(|e| panic!("committed faults fleet must load: {e}"));
    assert!(fleet.faults.enabled(), "the fixture exists to exercise the fault model");

    let text = fleet.to_json().to_string_pretty();
    let back = FleetScenario::from_json(
        &serverless_moe::util::json::Json::parse(&text).expect("canonical JSON parses"),
    )
    .expect("canonical form re-parses");
    assert_eq!(back.to_json().to_string_pretty(), text, "fixed-point serialization");

    let a = fleet.run().expect("faulted fleet runs").report;
    let b = fleet.run().expect("faulted fleet re-runs").report;
    assert_eq!(
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty(),
        "faulted fleet runs must be byte-identical"
    );

    let served: u64 = a.tenants.iter().map(|t| t.report.requests).sum();
    assert!(served > 0, "the fixture must serve traffic");
    assert!(a.failed_invocations > 0, "crashes must fire");
    assert!(a.retries > 0, "retries must fire");
    assert!(
        a.goodput_requests < served,
        "some requests must have needed recovery: goodput {} of {}",
        a.goodput_requests,
        served
    );
    assert!(a.goodput_requests > 0, "most requests still finish clean");
    assert!(a.retry_cost > 0.0 && a.retry_cost <= a.total_cost + 1e-9);
}

// ---------------------------------------------------- fleet golden fixture

/// Fleet-level golden regression on the committed solver-free fixture
/// (`fleet_golden.json`: one chat tenant decoding autoregressively beside
/// one synthetic batch tenant behind an execution-cap of 3). The expected
/// `FleetReport` lives at `rust/tests/data/golden_fleet.json` as the
/// report's canonical pretty JSON; any byte of drift — cost, fairness,
/// latency quantiles, or the new per-phase decode counters — fails here.
///
/// Self-initializing: if the golden file is absent the test writes it from
/// the current run and passes, so re-baselining after an intentional
/// behavior change is `rm rust/tests/data/golden_fleet.json && cargo test`.
/// CI runs the suite twice, so a fresh file is regressed in the same job.
#[test]
fn fleet_golden_fixture_matches_committed_report() {
    let fleet = FleetScenario::load(&scenario_path("fleet_golden.json"))
        .unwrap_or_else(|e| panic!("committed golden fleet must load: {e}"));

    // The fixture must stay solver-free (LambdaML baselines only): golden
    // numbers cannot depend on wall-clock-limited ODS solves.
    let text = fleet.to_json().to_string_pretty();
    let back = FleetScenario::from_json(
        &serverless_moe::util::json::Json::parse(&text).expect("canonical JSON parses"),
    )
    .expect("canonical form re-parses");
    assert_eq!(back.to_json().to_string_pretty(), text, "fixed-point serialization");

    let report = fleet.run().expect("golden fleet runs").report;
    let again = fleet.run().expect("golden fleet re-runs").report;
    let actual = report.to_json().to_string_pretty();
    assert_eq!(
        again.to_json().to_string_pretty(),
        actual,
        "golden fleet runs must be byte-identical across executions"
    );

    // Sanity on the decode side before pinning: the chat tenant actually
    // exercised the autoregressive path.
    let chat = report.tenant("assistant").expect("chat tenant reported");
    assert!(chat.report.requests > 0);
    assert!(chat.report.output_tokens > 0, "the chat tenant must decode");
    assert!(chat.report.time_per_output_token > 0.0);
    let batch = report.tenant("batch").expect("batch tenant reported");
    assert_eq!(batch.report.output_tokens, 0, "synthetic traffic never decodes");

    let golden_path =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/data/golden_fleet.json");
    match std::fs::read_to_string(&golden_path) {
        Ok(expected) => {
            let canon = serverless_moe::util::json::Json::parse(&expected)
                .expect("committed golden fleet report parses")
                .to_string_pretty();
            assert_eq!(
                actual, canon,
                "fleet report drifted from the committed golden numbers; if the \
                 change is intentional, delete {} and re-run the suite to \
                 re-baseline",
                golden_path.display()
            );
        }
        Err(_) => {
            std::fs::write(&golden_path, &actual).expect("golden fleet report writes");
            eprintln!(
                "initialized {} from this run; re-run the suite to regress it",
                golden_path.display()
            );
        }
    }
}

// ------------------------------------------------- parallel driver pins

/// Serve a prepared fleet under the sequential heap driver and the
/// parallel driver at 1, 2, 4 and 8 threads; every report must be
/// byte-identical JSON (the conservative-window protocol's determinism
/// contract — same materialized traffic, same step sequence per shard).
fn assert_identical_across_thread_counts(prepared: &PreparedFleet, label: &str) {
    let heap = prepared.run_with(FleetDriver::Heap).report.to_json().to_string_pretty();
    for threads in [1, 2, 4, 8] {
        let par = prepared
            .run_with(FleetDriver::Parallel { threads })
            .report
            .to_json()
            .to_string_pretty();
        assert_eq!(par, heap, "{label}: parallel(threads={threads}) diverged from heap");
    }
}

/// Every committed fleet fixture must serve byte-identically under the
/// parallel driver at every tested thread count — including the capped
/// chaos fixture (`fleet_faults.json`: its 1-slot ledger couples all
/// tenants, so the shard planner degenerates to one shard and replays the
/// exact sequential grant order) and the shared-pool churn fixture
/// (`fleet_churn_batching.json`: arena sharers are co-located on one
/// shard, so batch windows never cross a shard boundary). The
/// `fleet_parallel.json` fixture additionally ships with the knob set
/// (`"driver": {"parallel": {"threads": 2}}`), keeping a parallel-declared
/// file in the CI scenario smoke.
#[test]
fn parallel_driver_is_byte_identical_on_every_committed_fixture() {
    for fixture in [
        "fleet_two_tenant.json",
        "fleet_golden.json",
        "fleet_hundred_tenant.json",
        "fleet_churn_batching.json",
        "fleet_faults.json",
        "fleet_parallel.json",
    ] {
        let fleet = FleetScenario::load(&scenario_path(fixture))
            .unwrap_or_else(|e| panic!("{fixture} must load: {e}"));
        let prepared = fleet.prepare().unwrap_or_else(|e| panic!("{fixture} must prepare: {e}"));
        assert_identical_across_thread_counts(&prepared, fixture);
    }
}

/// A genuinely multi-shard fleet: twelve uncapped private-pool tenants are
/// twelve coupling groups, so 2/4/8 threads really do run concurrent
/// shards (the committed fixtures above all collapse to one). Also drives
/// the `driver` knob end-to-end: a fleet *configured* parallel serves
/// through `run()` identically to the heap default.
#[test]
fn parallel_driver_is_byte_identical_on_a_genuinely_sharded_fleet() {
    let mut fleet = FleetScenario {
        name: "sharded".to_string(),
        account_cap: None,
        arbitration: FleetArbitration::Fifo,
        cap_granularity: CapGranularity::Execution,
        share_experts: false,
        slo_feedback: false,
        batch_window: 0.0,
        faults: FaultSpec::off(),
        driver: FleetDriver::Heap,
        tenants: (0..12)
            .map(|i| {
                claim_tenant(
                    &format!("t{i:02}"),
                    0x5AD + i,
                    ArrivalProcess::Poisson { rate: 1.5 },
                    20.0,
                    5.0,
                )
            })
            .collect(),
    };
    let prepared = fleet.prepare().expect("sharded fleet prepares");
    assert_identical_across_thread_counts(&prepared, "sharded-12");

    let heap = fleet.run().expect("heap run").report.to_json().to_string_pretty();
    fleet.driver = FleetDriver::Parallel { threads: 4 };
    let par = fleet.run().expect("parallel run").report.to_json().to_string_pretty();
    assert_eq!(par, heap, "configured driver knob must not change the report");
}
