//! Token features (§III-B): token ID f1, position ID f2, attention ID f3.
//!
//! The attention ID is the token ID with the highest summed softmax attention
//! score across all self-attention heads of the multi-head attention layer
//! preceding the MoE layer. Positions are bucketed when used as a table key
//! (the paper treats the position prior as uniform; bucketing keeps the
//! key-value table compact without losing the positional signal).

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TokenFeature {
    /// f1 — token ID from the tokenizer.
    pub token_id: u32,
    /// f2 — position in the request sequence.
    pub position_id: u32,
    /// f3 — attention ID (token ID with max summed attention score).
    pub attention_id: u32,
}

/// Number of position buckets used in table keys.
pub const POS_BUCKETS: u32 = 16;

/// Bucket a raw position ID (log-ish spacing: early positions get finer
/// buckets, mirroring how positional effects concentrate at sequence heads).
pub fn position_bucket(pos: u32) -> u32 {
    match pos {
        0..=3 => pos,             // 0,1,2,3
        4..=7 => 4,
        8..=15 => 5,
        16..=31 => 6,
        32..=63 => 7,
        64..=95 => 8,
        96..=127 => 9,
        128..=191 => 10,
        192..=255 => 11,
        256..=383 => 12,
        384..=511 => 13,
        512..=1023 => 14,
        _ => 15,
    }
}

/// Table key: (f1, bucketed f2, f3) packed to one u64 for compact hashing.
/// Layout: token_id(24) | pos_bucket(8) | attention_id(24) — vocabularies in
/// this repo are ≤ 2^24.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FeatKey(pub u64);

impl FeatKey {
    pub fn new(f: &TokenFeature) -> FeatKey {
        debug_assert!(f.token_id < (1 << 24) && f.attention_id < (1 << 24));
        FeatKey(
            ((f.token_id as u64) << 32)
                | ((position_bucket(f.position_id) as u64) << 24)
                | f.attention_id as u64,
        )
    }

    pub fn from_parts(token_id: u32, pos_bucket: u32, attention_id: u32) -> FeatKey {
        FeatKey(((token_id as u64) << 32) | ((pos_bucket as u64) << 24) | attention_id as u64)
    }

    pub fn token_id(self) -> u32 {
        (self.0 >> 32) as u32
    }

    pub fn pos_bucket(self) -> u32 {
        ((self.0 >> 24) & 0xFF) as u32
    }

    pub fn attention_id(self) -> u32 {
        (self.0 & 0xFF_FFFF) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_monotone_nondecreasing() {
        let mut prev = 0;
        for pos in 0..2048 {
            let b = position_bucket(pos);
            assert!(b >= prev || b < POS_BUCKETS, "pos={pos} b={b}");
            prev = prev.max(b);
            assert!(b < POS_BUCKETS);
        }
    }

    #[test]
    fn key_roundtrip() {
        let f = TokenFeature {
            token_id: 123_456,
            position_id: 77,
            attention_id: 999_999,
        };
        let k = FeatKey::new(&f);
        assert_eq!(k.token_id(), 123_456);
        assert_eq!(k.pos_bucket(), position_bucket(77));
        assert_eq!(k.attention_id(), 999_999);
    }

    #[test]
    fn distinct_features_distinct_keys() {
        let base = TokenFeature {
            token_id: 10,
            position_id: 0,
            attention_id: 20,
        };
        let k0 = FeatKey::new(&base);
        let k1 = FeatKey::new(&TokenFeature { token_id: 11, ..base });
        let k2 = FeatKey::new(&TokenFeature { position_id: 200, ..base });
        let k3 = FeatKey::new(&TokenFeature { attention_id: 21, ..base });
        assert_ne!(k0, k1);
        assert_ne!(k0, k2);
        assert_ne!(k0, k3);
    }
}
