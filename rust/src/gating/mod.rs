//! Gating / routing ground truth.
//!
//! The paper's predictor learns token→expert mappings produced by a *real*
//! gating network. Two sources are supported:
//!
//!  - [`SimGate`]: a deterministic, feature-conditioned gate used by all
//!    simulator-scale experiments. Expert logits depend on the token ID
//!    (dominant), the position bucket, and the attention ID, plus a
//!    per-expert popularity bias — reproducing the paper's observations:
//!    skewed expert popularity (Fig. 2 setting) and same-token-ID→different-
//!    expert ambiguity (Fig. 3).
//!  - the real tiny-MoE gating network executed via PJRT (see
//!    `runtime`/`coordinator`), which produces mappings for the end-to-end
//!    serving path.
//!
//! We never *modify* routing decisions (the paper explicitly does not); the
//! gate defines ground truth and everything downstream adapts to it.

pub mod features;

pub use features::TokenFeature;

use crate::workload::Batch;

/// Routing outcome of one batch at one MoE layer.
#[derive(Debug, Clone)]
pub struct RoutingOutcome {
    /// For each token (flattened batch order), the selected expert indices
    /// (top-k, k = model.top_k).
    pub assignments: Vec<Vec<u8>>,
    /// Token count routed to each expert (d_{e,i} of the paper).
    pub expert_counts: Vec<u64>,
}

impl RoutingOutcome {
    pub fn total_tokens(&self) -> usize {
        self.assignments.len()
    }
}

/// Deterministic simulated gating network.
#[derive(Debug, Clone)]
pub struct SimGate {
    pub num_layers: usize,
    pub experts_per_layer: Vec<usize>,
    pub top_k: usize,
    /// Per-layer per-expert popularity bias — the source of skew.
    popularity: Vec<Vec<f64>>,
    /// Feature weights: token-ID, position, attention-ID contributions.
    pub w_token: f64,
    pub w_pos: f64,
    pub w_attn: f64,
    seed: u64,
}

fn hash3(a: u64, b: u64, c: u64) -> u64 {
    // A small mix of splitmix-style rounds — deterministic "random" logits.
    let mut z = a
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(b.rotate_left(17))
        .wrapping_add(c.rotate_left(33));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Map a hash to [-1, 1).
fn hash_unit(h: u64) -> f64 {
    (h >> 11) as f64 * (2.0 / (1u64 << 53) as f64) - 1.0
}

impl SimGate {
    pub fn new(spec: &crate::model::MoeModelSpec, seed: u64) -> Self {
        let num_layers = spec.num_moe_layers();
        let experts_per_layer: Vec<usize> =
            (0..num_layers).map(|e| spec.experts_at(e)).collect();
        // Popularity bias: drawn deterministically from the seed; std ~0.9
        // gives the strong-but-not-degenerate skew of Fig. 2/3.
        let mut rng = crate::util::rng::Rng::new(seed ^ 0x6A7E);
        let popularity = experts_per_layer
            .iter()
            .map(|&n| (0..n).map(|_| rng.normal_ms(0.0, 0.9)).collect())
            .collect();
        Self {
            num_layers,
            experts_per_layer,
            top_k: spec.top_k,
            popularity,
            w_token: 2.0,
            w_pos: 0.45,
            w_attn: 0.8,
            seed,
        }
    }

    /// Expert logits for one token at one layer.
    pub fn logits(&self, layer: usize, f: &TokenFeature) -> Vec<f64> {
        let n = self.experts_per_layer[layer];
        let pos_bucket = features::position_bucket(f.position_id);
        (0..n)
            .map(|i| {
                let base = self.popularity[layer][i];
                let ht = hash_unit(hash3(
                    f.token_id as u64 ^ self.seed,
                    (layer * 1009 + i) as u64,
                    0x11,
                ));
                let hp = hash_unit(hash3(
                    (f.token_id as u64) << 20 | pos_bucket as u64,
                    (layer * 1013 + i) as u64 ^ self.seed,
                    0x22,
                ));
                let ha = hash_unit(hash3(
                    (f.token_id as u64) << 24 ^ f.attention_id as u64,
                    (layer * 1019 + i) as u64 ^ self.seed,
                    0x33,
                ));
                base + self.w_token * ht + self.w_pos * hp + self.w_attn * ha
            })
            .collect()
    }

    /// Top-k expert selection for one token at one layer.
    pub fn route_token(&self, layer: usize, f: &TokenFeature) -> Vec<u8> {
        let logits = self.logits(layer, f);
        top_k_indices(&logits, self.top_k)
    }

    /// Route a whole batch at one layer.
    pub fn route_batch(&self, layer: usize, batch: &Batch) -> RoutingOutcome {
        let n_exp = self.experts_per_layer[layer];
        let mut assignments = Vec::with_capacity(batch.total_tokens);
        let mut expert_counts = vec![0u64; n_exp];
        for (t, p, a) in batch.tokens() {
            let f = TokenFeature {
                token_id: t,
                position_id: p,
                attention_id: a,
            };
            let sel = self.route_token(layer, &f);
            for &i in &sel {
                expert_counts[i as usize] += 1;
            }
            assignments.push(sel);
        }
        RoutingOutcome {
            assignments,
            expert_counts,
        }
    }
}

/// Memoized routing for the serving hot path.
///
/// [`SimGate`] logits are a pure function of `(token_id, position bucket,
/// attention_id)` — exactly a [`features::FeatKey`] — so per-layer top-k
/// selections can be cached and replayed bit-for-bit. Natural-language token
/// streams are Zipf-distributed, so a small working set of feature keys
/// covers almost all routed tokens; the event-driven traffic engine uses
/// this to take per-token routing off its million-request critical path
/// (`route_token` allocates two vectors and sorts per call). Counts produced
/// through the cache are identical to [`predictor::eval::real_counts`]:
/// the regression tests pin the equivalence exactly.
///
/// [`predictor::eval::real_counts`]: crate::predictor::eval::real_counts
#[derive(Debug, Clone)]
pub struct RouterCache {
    /// Per-layer memo: feature key → packed top-k expert selection
    /// (expert `j` of the selection in byte `j`, low to high).
    maps: Vec<crate::util::hash::FastMap<features::FeatKey, u32>>,
    top_k: usize,
    pub hits: u64,
    pub misses: u64,
}

impl RouterCache {
    pub fn new(gate: &SimGate) -> RouterCache {
        assert!(gate.top_k <= 4, "packed selections hold at most 4 experts");
        RouterCache {
            maps: (0..gate.num_layers).map(|_| Default::default()).collect(),
            top_k: gate.top_k,
            hits: 0,
            misses: 0,
        }
    }

    /// Top-k selection for one token feature at one layer, memoized.
    #[inline]
    fn select(&mut self, gate: &SimGate, layer: usize, f: &TokenFeature) -> u32 {
        let key = features::FeatKey::new(f);
        if let Some(&packed) = self.maps[layer].get(&key) {
            self.hits += 1;
            return packed;
        }
        self.misses += 1;
        let sel = gate.route_token(layer, f);
        let packed = sel
            .iter()
            .enumerate()
            .fold(0u32, |acc, (j, &e)| acc | ((e as u32) << (8 * j)));
        self.maps[layer].insert(key, packed);
        packed
    }

    /// Visit the memoized top-k selection of every token of `batch` at
    /// `layer`, in batch token order (experts of one token visited low
    /// selection rank first) — selections are bit-identical to
    /// [`SimGate::route_token`] by construction. This is the shared
    /// iteration under [`RouterCache::counts_into`] and the cached
    /// online-absorb path (`predictor::profile::absorb_batch`).
    pub fn route_layer(
        &mut self,
        gate: &SimGate,
        layer: usize,
        batch: &Batch,
        mut visit: impl FnMut(&TokenFeature, u8),
    ) {
        for (t, p, a) in batch.tokens() {
            let f = TokenFeature {
                token_id: t,
                position_id: p,
                attention_id: a,
            };
            let packed = self.select(gate, layer, &f);
            for j in 0..self.top_k {
                visit(&f, ((packed >> (8 * j)) & 0xFF) as u8);
            }
        }
    }

    /// Per-expert token counts of `batch` for every layer, written into
    /// `out` (resized/zeroed as needed) — the cached equivalent of
    /// `real_counts`, bit-identical by construction.
    pub fn counts_into(&mut self, gate: &SimGate, batch: &Batch, out: &mut Vec<Vec<u64>>) {
        out.resize(gate.num_layers, Vec::new());
        for (layer, row) in out.iter_mut().enumerate() {
            let n_exp = gate.experts_per_layer[layer];
            row.clear();
            row.resize(n_exp, 0);
            self.route_layer(gate, layer, batch, |_, expert| row[expert as usize] += 1);
        }
    }

    /// Distinct feature keys cached across all layers.
    pub fn entries(&self) -> usize {
        self.maps.iter().map(|m| m.len()).sum()
    }
}

/// Indices of the k largest values (ties broken by lower index).
pub fn top_k_indices(xs: &[f64], k: usize) -> Vec<u8> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap().then(a.cmp(&b)));
    idx.truncate(k.min(xs.len()));
    idx.into_iter().map(|i| i as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::CorpusPreset;
    use crate::model::ModelPreset;
    use crate::workload::{Corpus, RequestGenerator};

    fn gate() -> SimGate {
        let spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        SimGate::new(&spec, 7)
    }

    fn batch(tokens: usize) -> Batch {
        let c = Corpus::new(CorpusPreset::Enwik8, 1);
        RequestGenerator::new(c, 3, tokens).next_batch()
    }

    #[test]
    fn routing_is_deterministic() {
        let g = gate();
        let b = batch(512);
        let r1 = g.route_batch(2, &b);
        let r2 = g.route_batch(2, &b);
        assert_eq!(r1.assignments, r2.assignments);
    }

    #[test]
    fn counts_match_assignments() {
        let g = gate();
        let b = batch(512);
        let r = g.route_batch(0, &b);
        let total: u64 = r.expert_counts.iter().sum();
        assert_eq!(total as usize, r.total_tokens() * g.top_k);
    }

    #[test]
    fn popularity_is_skewed() {
        let g = gate();
        let b = batch(8192);
        let r = g.route_batch(0, &b);
        let max = *r.expert_counts.iter().max().unwrap() as f64;
        let min = *r.expert_counts.iter().min().unwrap() as f64;
        assert!(max / min.max(1.0) > 1.5, "counts={:?}", r.expert_counts);
    }

    #[test]
    fn same_token_id_can_route_differently() {
        // Fig. 3: with different position/attention features the same token
        // ID reaches different experts at a fixed layer.
        let g = gate();
        let token_id = 5u32;
        use std::collections::HashSet;
        let mut experts = HashSet::new();
        for pos in 0..64 {
            for attn in [1u32, 17, 200, 1032, 9000] {
                let f = TokenFeature {
                    token_id,
                    position_id: pos,
                    attention_id: attn,
                };
                experts.insert(g.route_token(1, &f)[0]);
            }
        }
        assert!(experts.len() > 1, "routing insensitive to non-ID features");
    }

    #[test]
    fn token_id_is_dominant_feature() {
        // The gate must still be largely predictable from the token ID —
        // otherwise no predictor (including the paper's) could work.
        let g = gate();
        let b = batch(4096);
        let r = g.route_batch(0, &b);
        use std::collections::HashMap;
        let mut by_token: HashMap<u32, HashMap<u8, usize>> = HashMap::new();
        for ((t, _, _), sel) in b.tokens().zip(&r.assignments) {
            *by_token.entry(t).or_default().entry(sel[0]).or_default() += 1;
        }
        // For tokens with >= 5 occurrences, the majority expert should carry
        // most of the mass on average.
        let mut agree = 0.0;
        let mut n = 0.0;
        for (_, dist) in by_token.iter().filter(|(_, d)| d.values().sum::<usize>() >= 5) {
            let total: usize = dist.values().sum();
            let maj = *dist.values().max().unwrap();
            agree += maj as f64 / total as f64;
            n += 1.0;
        }
        assert!(n > 10.0);
        assert!(agree / n > 0.55, "majority agreement {}", agree / n);
    }

    #[test]
    fn router_cache_counts_match_uncached_routing() {
        let g = gate();
        let mut cache = RouterCache::new(&g);
        let mut out = Vec::new();
        for seed in [1u64, 2] {
            let c = Corpus::new(CorpusPreset::Enwik8, seed);
            let b = RequestGenerator::new(c, seed ^ 9, 700).next_batch();
            cache.counts_into(&g, &b, &mut out);
            for layer in 0..g.num_layers {
                assert_eq!(
                    out[layer],
                    g.route_batch(layer, &b).expert_counts,
                    "cached counts drift at layer {layer}"
                );
            }
        }
        // Zipf token streams repeat features: the memo must actually hit.
        assert!(cache.hits > 0, "hits {} misses {}", cache.hits, cache.misses);
        assert!(cache.entries() > 0);
    }

    #[test]
    fn router_cache_supports_top2() {
        let spec = ModelPreset::BertMoe { experts: 4, top_k: 2 }.spec();
        let g = SimGate::new(&spec, 7);
        let mut cache = RouterCache::new(&g);
        let c = Corpus::new(CorpusPreset::Enwik8, 3);
        let b = RequestGenerator::new(c, 4, 300).next_batch();
        let mut out = Vec::new();
        cache.counts_into(&g, &b, &mut out);
        for layer in 0..g.num_layers {
            assert_eq!(out[layer], g.route_batch(layer, &b).expert_counts);
            let total: u64 = out[layer].iter().sum();
            assert_eq!(total as usize, b.total_tokens * 2, "top-2 routes two per token");
        }
    }

    #[test]
    fn top_k_selection() {
        assert_eq!(top_k_indices(&[0.1, 0.9, 0.5], 2), vec![1, 2]);
        assert_eq!(top_k_indices(&[1.0, 1.0], 1), vec![0]);
        assert_eq!(top_k_indices(&[0.3], 5), vec![0]);
    }

    #[test]
    fn top2_routes_two_distinct_experts() {
        let spec = ModelPreset::BertMoe { experts: 4, top_k: 2 }.spec();
        let g = SimGate::new(&spec, 7);
        let f = TokenFeature {
            token_id: 10,
            position_id: 3,
            attention_id: 99,
        };
        let sel = g.route_token(0, &f);
        assert_eq!(sel.len(), 2);
        assert_ne!(sel[0], sel[1]);
    }
}
