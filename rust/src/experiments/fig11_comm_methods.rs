//! Fig. 11 — Billed cost of MoE layers and whole-model throughput under the
//! three scatter-gather methods, sweeping the token count (3008MB functions,
//! no replicas). Paper shape: direct wins at 256 tokens; at larger counts
//! direct becomes infeasible and pipelined/non-pipelined indirect trade
//! places; throughput rises with token count (head costs amortize).

use super::common::{throughput, ExpContext};
use crate::comm::{CommMethod, ExpertPlan, LayerPlan};
use crate::config::workload::CorpusPreset;
use crate::deploy::DeploymentPolicy;
use crate::model::ModelPreset;
use crate::util::table::{fcost, fnum, Table};

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for (model_name, preset) in [
        ("Bert MoE", ModelPreset::BertMoe { experts: 4, top_k: 1 }),
        ("GPT2 MoE", ModelPreset::Gpt2Moe { top_k: 1 }),
    ] {
        let token_grid: &[usize] = if quick {
            &[256, 2560]
        } else {
            &[256, 1024, 2560, 10_240]
        };
        let mut t = Table::new(
            &format!("Fig 11 — {model_name}: comm methods vs token count"),
            &["tokens", "method", "beta", "billed cost", "tput (tok/s)"],
        );
        for &tokens in token_grid {
            let mut ctx = ExpContext::new(preset, CorpusPreset::Enwik8, true);
            ctx.generator.target_tokens = tokens;
            let batch = ctx.eval_batch();
            let counts = ctx.real_counts(&batch);
            let mem = ctx.config.platform.max_memory_mb();
            for method in CommMethod::ALL {
                // Best β for the pipelined method by cost.
                let betas: Vec<usize> = if method == CommMethod::PipelinedIndirect {
                    ctx.config.deploy.beta_grid.clone()
                } else {
                    vec![1]
                };
                let mut best: Option<(usize, f64, f64)> = None;
                for beta in betas {
                    let policy = DeploymentPolicy {
                        layers: counts
                            .iter()
                            .map(|layer| LayerPlan {
                                method,
                                beta,
                                experts: layer
                                    .iter()
                                    .map(|&d| ExpertPlan {
                                        mem_mb: mem,
                                        replicas: 1,
                                        tokens: d,
                                    })
                                    .collect(),
                            })
                            .collect(),
                    };
                    if method == CommMethod::Direct {
                        let total: u64 = counts[0].iter().sum();
                        if !crate::comm::timing::direct_gather_feasible(
                            &ctx.config.platform,
                            &ctx.spec,
                            total,
                        ) {
                            continue;
                        }
                    }
                    let cost = policy.total_cost(&ctx.config.platform, &ctx.spec, true);
                    let problem = ctx.problem(counts.clone(), f64::INFINITY);
                    let e2e = policy.end_to_end_time(&problem);
                    if best.map(|(_, c, _)| cost < c).unwrap_or(true) {
                        best = Some((beta, cost, e2e));
                    }
                }
                match best {
                    Some((beta, cost, e2e)) => t.row(vec![
                        tokens.to_string(),
                        method.name().into(),
                        beta.to_string(),
                        fcost(cost),
                        fnum(throughput(batch.total_tokens as u64, e2e)),
                    ]),
                    None => t.row(vec![
                        tokens.to_string(),
                        method.name().into(),
                        "-".into(),
                        "infeasible".into(),
                        "-".into(),
                    ]),
                }
            }
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    #[test]
    fn direct_best_small_infeasible_large() {
        let tables = super::run(true);
        let rows = &tables[0].rows; // Bert
        // At 256 tokens the direct row must be feasible and cheapest.
        let at = |tokens: &str, method: &str| {
            rows.iter()
                .find(|r| r[0] == tokens && r[1] == method)
                .unwrap()
                .clone()
        };
        let d = at("256", "direct");
        assert_ne!(d[3], "infeasible");
        let dc: f64 = d[3].trim_start_matches('$').parse().unwrap();
        let ic: f64 = at("256", "indirect")[3]
            .trim_start_matches('$')
            .parse()
            .unwrap();
        assert!(dc < ic, "direct {dc} vs indirect {ic}");
        // At 2560 tokens direct is ruled out by the gather payload.
        assert_eq!(at("2560", "direct")[3], "infeasible");
    }
}
