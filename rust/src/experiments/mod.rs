//! Experiment generators — one per table/figure of the paper's evaluation
//! (see the index in DESIGN.md). Each returns a [`Table`] whose rows carry
//! the same series the paper plots; `smoe experiment <id>` prints it and
//! the benches time it.

pub mod common;
pub mod fig02_motivation;
pub mod fig03_token_routing;
pub mod fig04_comm_cost;
pub mod fig10_prediction;
pub mod fig11_comm_methods;
pub mod fig12_ods;
pub mod fig13_bo;
pub mod fig14_overall;
pub mod overhead;
pub mod traffic;

use crate::util::table::Table;

/// All experiment ids.
pub const ALL: &[&str] = &[
    "fig2", "fig3", "fig4", "fig10", "fig11", "fig12", "fig13", "fig14", "overhead", "traffic",
];

/// Run one experiment by id (quick=true shrinks workloads for CI/tests).
pub fn run(id: &str, quick: bool) -> anyhow::Result<Vec<Table>> {
    match id {
        "fig2" => Ok(fig02_motivation::run(quick)),
        "fig3" => Ok(fig03_token_routing::run(quick)),
        "fig4" => Ok(fig04_comm_cost::run(quick)),
        "fig10" => Ok(fig10_prediction::run(quick)),
        "fig11" => Ok(fig11_comm_methods::run(quick)),
        "fig12" => Ok(fig12_ods::run(quick)),
        "fig13" => Ok(fig13_bo::run(quick)),
        "fig14" => Ok(fig14_overall::run(quick)),
        "overhead" => Ok(overhead::run(quick)),
        "traffic" => Ok(traffic::run(quick)),
        _ => anyhow::bail!("unknown experiment '{id}' (one of {ALL:?})"),
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn all_ids_dispatch() {
        for id in super::ALL {
            // Existence check only (quick runs are exercised per-module).
            assert!(super::run("nope", true).is_err());
            assert!(super::ALL.contains(id));
        }
    }
}
