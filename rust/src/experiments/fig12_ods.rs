//! Fig. 12 — Billed cost of all MoE layers under the ODS algorithm vs the
//! direct-MIQCP method vs random method selection, across target
//! throughputs (T_limit = 10,240 tokens / target). Paper protocol: MIQCP
//! gets 180 s, ODS's three solvers get 60 s each; at high targets the MIQCP
//! method fails to find good solutions in time.

use super::common::ExpContext;
use crate::config::workload::CorpusPreset;
use crate::deploy::baselines::random_policy;
use crate::deploy::miqcp::solve_joint;
use crate::deploy::ods::ods_full;
use crate::model::ModelPreset;
use crate::util::rng::Rng;
use crate::util::table::{fcost, Table};

pub fn run(quick: bool) -> Vec<Table> {
    let mut ctx = ExpContext::new(
        ModelPreset::BertMoe { experts: 4, top_k: 1 },
        CorpusPreset::Enwik8,
        quick,
    );
    let batch = ctx.eval_batch();
    let counts = ctx.real_counts(&batch);
    let tokens = batch.total_tokens as f64;

    // Time limits (scaled down in quick mode; protocol ratio preserved 3:1).
    let (t_miqcp, t_ods) = if quick { (1.5, 0.5) } else { (180.0, 60.0) };
    let targets: &[f64] = if quick { &[5.0, 20.0] } else { &[5.0, 10.0, 20.0, 40.0] };

    let mut t = Table::new(
        "Fig 12 — deployment algorithms vs target throughput (Bert MoE, 10240 tokens)",
        &["target tput (tok/s)", "T_limit (s)", "ODS", "MIQCP (timeout)", "random"],
    );
    let mut rng = Rng::new(0xF16);
    for &target in targets {
        let t_limit = tokens / target;
        let problem = ctx.problem(counts.clone(), t_limit);

        let ods = ods_full(&problem, t_ods);
        let miqcp = solve_joint(&problem, t_miqcp);
        let rand_pol = random_policy(&problem, &mut rng);
        let rand_cost = rand_pol.total_cost(&ctx.config.platform, &ctx.spec, true);
        let rand_feasible = rand_pol.feasible(&problem);

        let fmt = |cost: f64, feasible: bool| {
            if feasible {
                fcost(cost)
            } else {
                format!("{} (SLO miss)", fcost(cost))
            }
        };
        t.row(vec![
            format!("{target}"),
            format!("{t_limit:.0}"),
            ods.as_ref()
                .map(|o| fmt(o.total_cost, o.feasible))
                .unwrap_or_else(|| "failed".into()),
            miqcp
                .as_ref()
                .map(|m| fmt(m.total_cost, m.feasible))
                .unwrap_or_else(|| "failed".into()),
            fmt(rand_cost, rand_feasible),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    #[test]
    fn ods_never_loses_to_random() {
        let t = &super::run(true)[0];
        for r in &t.rows {
            let parse = |s: &str| -> Option<f64> {
                s.split_whitespace()
                    .next()?
                    .trim_start_matches('$')
                    .parse()
                    .ok()
            };
            let (ods, rand) = (parse(&r[2]), parse(&r[4]));
            if let (Some(o), Some(ra)) = (ods, rand) {
                let rand_feasible = !r[4].contains("SLO miss");
                if rand_feasible {
                    assert!(o <= ra * 1.05, "ods {o} vs random {ra} in {r:?}");
                }
            }
        }
    }
}
