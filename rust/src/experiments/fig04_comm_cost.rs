//! Fig. 4 — Billed cost and end-to-end inference time of the BERT MoE under
//! direct vs indirect transfers, at 256 and 2560 tokens (6 MB payload).
//! Paper shape: direct wins at 256 tokens; at 2560 tokens direct becomes
//! infeasible (payload) and indirect costs grow.

use super::common::ExpContext;
use crate::comm::timing::direct_feasible;
use crate::comm::{CommMethod, ExpertPlan, LayerPlan};
use crate::config::workload::CorpusPreset;
use crate::deploy::DeploymentPolicy;
use crate::model::ModelPreset;
use crate::util::table::{fcost, fnum, Table};

fn policy_for(
    ctx: &ExpContext,
    counts: &[Vec<u64>],
    method: CommMethod,
) -> DeploymentPolicy {
    let mem = ctx.config.platform.max_memory_mb();
    DeploymentPolicy {
        layers: counts
            .iter()
            .map(|layer| LayerPlan {
                method,
                beta: 1,
                experts: layer
                    .iter()
                    .map(|&d| ExpertPlan {
                        mem_mb: mem,
                        replicas: 1,
                        tokens: d,
                    })
                    .collect(),
            })
            .collect(),
    }
}

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    for &tokens in &[256usize, 2560] {
        let mut ctx = ExpContext::new(
            ModelPreset::BertMoe { experts: 4, top_k: 1 },
            CorpusPreset::Enwik8,
            quick,
        );
        ctx.generator.target_tokens = tokens;
        let batch = ctx.eval_batch();
        let counts = ctx.real_counts(&batch);

        let mut t = Table::new(
            &format!("Fig 4 — {tokens}-token batch (payload 6MB)"),
            &["method", "feasible", "billed cost", "e2e time (s)"],
        );
        for method in [CommMethod::Direct, CommMethod::Indirect] {
            let policy = policy_for(&ctx, &counts, method);
            let feasible = method != CommMethod::Direct
                || policy.layers.iter().all(|l| {
                    let total: u64 = l.experts.iter().map(|e| e.tokens).sum();
                    crate::comm::timing::direct_gather_feasible(
                        &ctx.config.platform,
                        &ctx.spec,
                        total,
                    ) && l.experts.iter().all(|ep| {
                        ep.tokens == 0
                            || direct_feasible(&ctx.config.platform, &ctx.spec, ep)
                    })
                });
            let cost = policy.total_cost(&ctx.config.platform, &ctx.spec, true);
            let problem = ctx.problem(counts.clone(), f64::INFINITY);
            let e2e = policy.end_to_end_time(&problem);
            t.row(vec![
                method.name().into(),
                if feasible { "yes".into() } else { "NO (payload)".into() },
                fcost(cost),
                fnum(e2e),
            ]);
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    #[test]
    fn direct_wins_small_and_breaks_large() {
        let tables = super::run(true);
        // 256 tokens: direct feasible and cheaper or similar.
        let small = &tables[0].rows;
        assert_eq!(small[0][1], "yes");
        let d: f64 = small[0][2].trim_start_matches('$').parse().unwrap();
        let i: f64 = small[1][2].trim_start_matches('$').parse().unwrap();
        assert!(d < i, "direct {d} vs indirect {i} at 256 tokens");
        // 2560 tokens: direct infeasible under the skewed real distribution.
        let large = &tables[1].rows;
        assert!(large[0][1].contains("NO"), "{large:?}");
    }
}
