//! Fig. 3 — The same token ID is routed to *different* experts at one MoE
//! layer (layer 2 of the BERT MoE in the paper): token-ID-only features
//! cannot identify routing. We pick the most frequent token in the corpus
//! and histogram its expert assignments at layer 2.

use super::common::ExpContext;
use crate::config::workload::CorpusPreset;
use crate::gating::TokenFeature;
use crate::model::ModelPreset;
use crate::util::table::Table;

pub fn run(quick: bool) -> Vec<Table> {
    let mut ctx = ExpContext::new(
        ModelPreset::BertMoe { experts: 4, top_k: 1 },
        CorpusPreset::Enwik8,
        quick,
    );
    let batch = ctx.eval_batch();
    // The paper picks an illustrative frequent token (ID 10424 for Enwik8):
    // among the 30 most frequent tokens, select the one whose routing is the
    // most context-dependent at layer 2.
    let mut freq = std::collections::HashMap::new();
    for (t, _, _) in batch.tokens() {
        *freq.entry(t).or_insert(0u32) += 1;
    }
    let mut by_freq: Vec<(u32, u32)> = freq.into_iter().map(|(t, c)| (t, c)).collect();
    by_freq.sort_by(|a, b| b.1.cmp(&a.1));

    let layer = 1; // "2nd MoE layer"
    let route_counts = |token: u32| -> Vec<u64> {
        let mut counts = vec![0u64; ctx.spec.experts_at(layer)];
        for (t, p, a) in batch.tokens() {
            if t == token {
                let f = TokenFeature {
                    token_id: t,
                    position_id: p,
                    attention_id: a,
                };
                counts[ctx.gate.route_token(layer, &f)[0] as usize] += 1;
            }
        }
        counts
    };
    let (token, n, counts) = by_freq
        .iter()
        .take(30)
        .map(|&(t, c)| (t, c, route_counts(t)))
        .max_by_key(|(_, _, counts)| {
            let used = counts.iter().filter(|&&c| c > 0).count() as u64;
            let second = {
                let mut s: Vec<u64> = counts.clone();
                s.sort_unstable_by(|a, b| b.cmp(a));
                s.get(1).copied().unwrap_or(0)
            };
            used * 10_000 + second
        })
        .unwrap();

    let mut table = Table::new(
        &format!("Fig 3 — token ID {token} ({n} occurrences) at MoE layer 2"),
        &["expert", "tokens routed"],
    );
    for (i, &c) in counts.iter().enumerate() {
        table.row(vec![format!("expert {i}"), c.to_string()]);
    }
    vec![table]
}

#[cfg(test)]
mod tests {
    #[test]
    fn one_token_id_reaches_multiple_experts() {
        let t = &super::run(true)[0];
        let nonzero = t
            .rows
            .iter()
            .filter(|r| r[1].parse::<u64>().unwrap() > 0)
            .count();
        assert!(nonzero >= 2, "Fig.3 premise violated: {:?}", t.rows);
    }
}
