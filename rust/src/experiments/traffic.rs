//! Traffic experiment — sustained serving under bursty, drifting load:
//! billed cost over time for (1) the online re-optimizing deployment
//! ("ours"), (2) the static initial deployment, (3) LambdaML
//! over-provisioning, and (4) the CPU cluster. This is the serving-dimension
//! counterpart of Fig. 14: the same cost comparison, but accumulated over a
//! request stream whose expert popularity shifts mid-run instead of a
//! single pre-warmed batch.

use crate::config::workload::CorpusPreset;
use crate::config::{CpuClusterConfig, PlatformConfig};
use crate::deploy::baselines::lambdaml_policy;
use crate::deploy::DeploymentPolicy;
use crate::gating::SimGate;
use crate::model::{ModelPreset, MoeModelSpec};
use crate::platform::CpuCluster;
use crate::predictor::bayes::TokenPrior;
use crate::predictor::eval::{predicted_counts, real_counts};
use crate::predictor::profile::profile_batches;
use crate::predictor::{BayesPredictor, DatasetTable};
use crate::traffic::{
    ArrivalGen, ArrivalProcess, AutoscalePolicy, EpochSimulator, SimEngine, SimReport,
    TrafficConfig,
};
use crate::util::table::{fcost, fnum, ftime, Table};
use crate::workload::{Corpus, RequestGenerator, TimedBatch};

/// A fully-built serving scenario: platform, model, gate, a profiled
/// predictor state, and a timestamped request stream.
pub struct TrafficScenario {
    pub platform: PlatformConfig,
    pub cpu: CpuClusterConfig,
    pub spec: MoeModelSpec,
    pub gate: SimGate,
    pub table: DatasetTable,
    pub prior: TokenPrior,
    pub traffic: Vec<TimedBatch>,
}

impl TrafficScenario {
    /// A fresh predictor at the profiled (pre-serving) state — each
    /// simulation run starts from identical beliefs.
    pub fn predictor(&self) -> BayesPredictor {
        BayesPredictor::new(self.table.clone(), self.prior.clone())
    }

    /// LambdaML over-provisioning policy for this scenario's first request.
    pub fn lambdaml(&self, cfg: &TrafficConfig) -> DeploymentPolicy {
        let predictor = self.predictor();
        let counts = match self.traffic.first() {
            Some(tb) => predicted_counts(&self.gate, &predictor, &tb.batch),
            None => (0..self.spec.num_moe_layers())
                .map(|e| vec![1; self.spec.experts_at(e)])
                .collect(),
        };
        let problem = cfg.problem(&self.platform, &self.spec, counts);
        lambdaml_policy(&problem)
    }

    /// Serve the whole stream on the CPU cluster baseline: per-batch
    /// straggler-bound execution, coarse-grained rental billing over the
    /// occupied span.
    pub fn cpu_cluster(&self, better_transformer: bool) -> SimReport {
        let cluster = CpuCluster::new(self.cpu.clone(), better_transformer);
        let mut exec_each: Vec<f64> = Vec::with_capacity(self.traffic.len());
        let mut tokens = 0u64;
        let mut span = 0.0f64;
        for tb in &self.traffic {
            let real = real_counts(&self.gate, &tb.batch);
            let run = cluster.serve(&self.spec, &real, tb.batch.total_tokens);
            exec_each.push(run.exec_secs);
            tokens += tb.batch.total_tokens as u64;
            span = span.max(tb.at + run.exec_secs);
        }
        // No per-request cost timeline: the cluster bills by occupied span
        // (coarse rental periods), so the over-time table queries
        // `cpu.job_cost(t)` directly.
        SimReport::from_samples(&exec_each, tokens, span, self.cpu.job_cost(span.max(1.0)))
    }
}

/// The TrafficConfig used across the scenario runs (and the regression
/// tests, so golden numbers stay pinned to one configuration). Concurrency
/// is left unbounded here — the PR 1 serving semantics the original golden
/// numbers were pinned under; the queueing regime is exercised by
/// [`scenario_config_queued`] and the dedicated comparison table.
pub fn scenario_config(quick: bool) -> TrafficConfig {
    TrafficConfig {
        epoch_secs: 60.0,
        keep_alive: 900.0,
        concurrency: None,
        prewarm: true,
        drift_threshold: 0.15,
        // Tight enough that the heavy phase-A batches force replica/memory
        // upgrades on popular experts — the over-provisioning that goes to
        // waste once traffic drifts light.
        t_limit: if quick { 200.0 } else { 300.0 },
        solver_time_limit: if quick { 0.3 } else { 2.0 },
        ..TrafficConfig::default()
    }
}

/// Queueing-enabled variant pinned by its own golden fixture: Lambda-style
/// per-instance concurrency 1 with the queue-depth autoscaler nudging
/// replica counts between redeploys.
pub fn scenario_config_queued(quick: bool) -> TrafficConfig {
    TrafficConfig {
        concurrency: Some(1),
        autoscale: AutoscalePolicy::QueueDepth { max_wait: 5.0, idle_below: 0.2 },
        ..scenario_config(quick)
    }
}

/// Two-phase drifted traffic: phase A serves heavy requests from one
/// corpus (the deployment gets sized — replicas, memory, β — for that
/// load), then phase B shifts to light requests from a *re-permuted*
/// corpus: a fresh token-rank permutation re-draws which experts are
/// popular under the fixed gate, so the static deployment keeps billing
/// replica head-times and above-saturation memory for experts that are no
/// longer hot. Arrivals come from a bursty two-state MMPP.
pub fn drift_scenario(preset: ModelPreset, quick: bool, seed: u64) -> TrafficScenario {
    let platform = PlatformConfig::default();
    let cpu = CpuClusterConfig::default();
    let spec = preset.spec();
    let gate = SimGate::new(&spec, 0xA11CE);

    // Phase A: heavy requests; profile the predictor on the same corpus.
    let batch_a = if quick { 2048 } else { 4096 };
    let batch_b = if quick { 512 } else { 1024 };
    let corpus_a = Corpus::new(CorpusPreset::Enwik8, seed);
    let mut gen_a = RequestGenerator::new(corpus_a, seed ^ 0x11, batch_a);
    let n_profile = if quick { 6 } else { 24 };
    let profile = profile_batches(&gate, &gen_a.profile_set(n_profile));

    // Bursty arrivals over the horizon.
    let duration = if quick { 600.0 } else { 1500.0 };
    let process = ArrivalProcess::Mmpp {
        rate0: 0.8,
        rate1: 0.1,
        hold0: 40.0,
        hold1: 50.0,
    };
    let arrivals = ArrivalGen::new(process, seed ^ 0x22).arrivals_until(duration);
    let split = arrivals.len() / 4;

    // Phase B: re-permuted corpus (new popular tokens → new popular
    // experts) at 1/8 the request size.
    let corpus_b = Corpus::new(CorpusPreset::Enwik8, seed ^ 0xD21F7);
    let mut gen_b = RequestGenerator::new(corpus_b, seed ^ 0x33, batch_b);
    let mut traffic = gen_a.timed_batches(&arrivals[..split]);
    traffic.extend(gen_b.timed_batches(&arrivals[split..]));

    TrafficScenario {
        platform,
        cpu,
        spec,
        gate,
        table: profile.table,
        prior: profile.prior,
        traffic,
    }
}

/// Cumulative cost at `t` from a report's timeline (0 before the first
/// request).
fn cost_at(report: &SimReport, t: f64) -> f64 {
    report
        .cost_timeline
        .iter()
        .take_while(|(at, _)| *at <= t)
        .last()
        .map(|(_, c)| *c)
        .unwrap_or(0.0)
}

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    let models: Vec<(&str, ModelPreset)> = if quick {
        vec![("Bert MoE", ModelPreset::BertMoe { experts: 4, top_k: 1 })]
    } else {
        vec![
            ("Bert MoE", ModelPreset::BertMoe { experts: 4, top_k: 1 }),
            ("GPT2 MoE", ModelPreset::Gpt2Moe { top_k: 1 }),
        ]
    };

    for (name, preset) in models {
        let scn = drift_scenario(preset, quick, 0x5EED);
        let cfg = scenario_config(quick);

        // Each simulator is scoped so its online-learned table is dropped
        // before the next run starts.

        // (1) ours: online re-optimization with a BO refinement round.
        let ours = {
            let mut cfg_ours = cfg.clone();
            cfg_ours.reoptimize = true;
            cfg_ours.bo_round_iters = 1;
            let mut sim = EpochSimulator::new(
                &scn.platform,
                &scn.spec,
                &scn.gate,
                scn.predictor(),
                cfg_ours,
            );
            sim.run(&scn.traffic)
        };

        // (2) static: the same initial deployment, never re-optimized.
        let stat = {
            let mut cfg_static = cfg.clone();
            cfg_static.reoptimize = false;
            let mut sim = EpochSimulator::new(
                &scn.platform,
                &scn.spec,
                &scn.gate,
                scn.predictor(),
                cfg_static,
            );
            sim.run(&scn.traffic)
        };

        // (3) LambdaML over-provisioning, never re-optimized.
        let lam = {
            let mut cfg_lam = cfg.clone();
            cfg_lam.reoptimize = false;
            let lam_policy = scn.lambdaml(&cfg_lam);
            let mut sim = EpochSimulator::new(
                &scn.platform,
                &scn.spec,
                &scn.gate,
                scn.predictor(),
                cfg_lam,
            );
            sim.run_with_policy(lam_policy, &scn.traffic)
        };

        // (4) CPU cluster.
        let cpu = scn.cpu_cluster(false);

        let mut t = Table::new(
            &format!("Traffic — {name}: sustained serving under drifting MMPP load"),
            &[
                "deployment",
                "billed cost",
                "tput (tok/s)",
                "p95 latency",
                "redeploys",
                "warm frac",
            ],
        );
        let mut row = |label: &str, r: &SimReport| {
            t.row(vec![
                label.into(),
                fcost(r.total_cost),
                fnum(r.throughput_tps),
                ftime(r.p95_latency),
                r.redeploys.to_string(),
                fnum(r.warm_fraction()),
            ]);
        };
        row("ours (online re-opt + BO)", &ours);
        row("static initial deployment", &stat);
        row("LambdaML (max memory)", &lam);
        row("CPU cluster", &cpu);
        tables.push(t);

        // Cost-over-time: the drift story in four checkpoints.
        let horizon = scn
            .traffic
            .last()
            .map(|tb| tb.at)
            .unwrap_or(0.0)
            .max(1.0);
        let mut tt = Table::new(
            &format!("Traffic — {name}: cumulative billed cost over time"),
            &["time", "ours", "static", "LambdaML", "CPU cluster"],
        );
        for frac in [0.25, 0.5, 0.75, 1.0] {
            let at = horizon * frac;
            tt.row(vec![
                format!("{:.0}s", at),
                fcost(cost_at(&ours, at)),
                fcost(cost_at(&stat, at)),
                fcost(cost_at(&lam, at)),
                fcost(scn.cpu.job_cost(at)),
            ]);
        }
        tables.push(tt);

        // Queueing regime: the same stream on the static deployment under
        // unbounded concurrency (PR 1 model), Lambda-style concurrency 1,
        // and concurrency 1 with epoch-level autoscaling.
        let mut qt = Table::new(
            &format!("Traffic — {name}: per-instance queueing + autoscaling (static deployment)"),
            &[
                "regime",
                "billed cost",
                "p95 latency",
                "mean queue delay",
                "max util",
                "scale out/in",
            ],
        );
        for (label, conc, pol) in [
            ("unbounded (PR 1 model)", None, AutoscalePolicy::Off),
            ("concurrency 1", Some(1), AutoscalePolicy::Off),
            (
                "concurrency 1 + autoscale",
                Some(1),
                AutoscalePolicy::TargetUtilization { target: 0.7 },
            ),
        ] {
            let cfg_q = TrafficConfig {
                reoptimize: false,
                concurrency: conc,
                autoscale: pol,
                ..cfg.clone()
            };
            let mut sim = EpochSimulator::new(
                &scn.platform,
                &scn.spec,
                &scn.gate,
                scn.predictor(),
                cfg_q,
            );
            let r = sim.run(&scn.traffic);
            qt.row(vec![
                label.into(),
                fcost(r.total_cost),
                ftime(r.p95_latency),
                ftime(r.mean_queue_delay),
                fnum(r.max_utilization),
                format!("{}/{}", r.scale_outs, r.scale_ins),
            ]);
        }
        tables.push(qt);

        // Dispatch engines on the Lambda-style (concurrency 1) static
        // deployment: the legacy serial loop, the event engine with
        // monolithic dispatch (must reproduce legacy), and the event engine
        // with layer-pipelined dispatch — later layers' queue waits overlap
        // earlier layers' compute, which shows up as lower latency at
        // identical billed cost (billing meters busy time).
        let mut et = Table::new(
            &format!("Traffic — {name}: dispatch engines (concurrency 1, static deployment)"),
            &["engine", "billed cost", "p50 latency", "p95 latency", "mean queue delay"],
        );
        let cfg_eng = TrafficConfig {
            reoptimize: false,
            concurrency: Some(1),
            autoscale: AutoscalePolicy::Off,
            ..cfg.clone()
        };
        // One ODS solve shared by all three rows: the deployment is truly
        // static, so the rows differ only in dispatch discipline.
        let engine_policy = EpochSimulator::new(
            &scn.platform,
            &scn.spec,
            &scn.gate,
            scn.predictor(),
            cfg_eng.clone(),
        )
        .initial_policy(&scn.traffic);
        for (label, engine) in [
            ("legacy serial loop", SimEngine::Legacy),
            ("event, monolithic", SimEngine::Event { pipeline: false }),
            ("event, pipelined", SimEngine::Event { pipeline: true }),
        ] {
            let cfg_e = TrafficConfig { engine, ..cfg_eng.clone() };
            let mut sim = EpochSimulator::new(
                &scn.platform,
                &scn.spec,
                &scn.gate,
                scn.predictor(),
                cfg_e,
            );
            let r = sim.run_with_policy(engine_policy.clone(), &scn.traffic);
            et.row(vec![
                label.into(),
                fcost(r.total_cost),
                ftime(r.p50_latency),
                ftime(r.p95_latency),
                ftime(r.mean_queue_delay),
            ]);
        }
        tables.push(et);
    }
    tables
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_is_two_phase_and_deterministic() {
        let scn = drift_scenario(ModelPreset::BertMoe { experts: 4, top_k: 1 }, true, 1);
        assert!(scn.traffic.len() > 10, "traffic len {}", scn.traffic.len());
        assert!(scn.traffic.windows(2).all(|w| w[0].at <= w[1].at));
        // Phase A requests are heavier than phase B requests.
        let first = scn.traffic.first().unwrap().batch.total_tokens;
        let last = scn.traffic.last().unwrap().batch.total_tokens;
        assert!(first >= last * 4, "A={first} B={last}");
        let scn2 = drift_scenario(ModelPreset::BertMoe { experts: 4, top_k: 1 }, true, 1);
        assert_eq!(scn.traffic.len(), scn2.traffic.len());
        assert_eq!(
            scn.traffic[0].batch.sequences[0].tokens,
            scn2.traffic[0].batch.sequences[0].tokens
        );
    }

    #[test]
    fn ours_beats_lambdaml_under_traffic() {
        let t = &super::run(true)[0];
        let cost = |name: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0].starts_with(name))
                .unwrap()[1]
                .trim_start_matches('$')
                .parse()
                .unwrap()
        };
        let ours = cost("ours");
        let lam = cost("LambdaML");
        let cpu = cost("CPU cluster");
        assert!(ours < lam, "ours {ours} vs lambdaml {lam}");
        assert!(ours < cpu, "ours {ours} vs cpu {cpu}");
    }
}
