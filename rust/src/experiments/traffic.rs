//! Traffic experiment — sustained serving under bursty, drifting load:
//! billed cost over time for (1) the online re-optimizing deployment
//! ("ours"), (2) the static initial deployment, (3) LambdaML
//! over-provisioning, and (4) the CPU cluster. This is the serving-dimension
//! counterpart of Fig. 14: the same cost comparison, but accumulated over a
//! request stream whose expert popularity shifts mid-run instead of a
//! single pre-warmed batch.
//!
//! Everything here drives the simulator through the declarative
//! [`Scenario`] front door: one scenario per model, compiled once
//! ([`Scenario::materialize`]), then served under each [`Baseline`] and
//! engine configuration from identical starting state.

use crate::model::ModelPreset;
use crate::traffic::fleet::{FleetScenario, TenantSource, TenantSpec};
use crate::traffic::scenario::{scenario_config, Baseline, Scenario, TrafficSource};
use crate::traffic::{
    ArrivalProcess, AutoscalePolicy, CapGranularity, FaultSpec, FleetArbitration, FleetDriver,
    FleetReport, SimEngine, SimReport, TrafficConfig,
};
use crate::util::table::{fcost, fnum, ftime, Table};

/// Cumulative cost at `t` from a report's timeline (0 before the first
/// request).
fn cost_at(report: &SimReport, t: f64) -> f64 {
    report
        .cost_timeline
        .iter()
        .take_while(|(at, _)| *at <= t)
        .last()
        .map(|(_, c)| *c)
        .unwrap_or(0.0)
}

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    let models: Vec<(&str, ModelPreset)> = if quick {
        vec![("Bert MoE", ModelPreset::BertMoe { experts: 4, top_k: 1 })]
    } else {
        vec![
            ("Bert MoE", ModelPreset::BertMoe { experts: 4, top_k: 1 }),
            ("GPT2 MoE", ModelPreset::Gpt2Moe { top_k: 1 }),
        ]
    };

    for (name, preset) in models {
        let cfg = scenario_config(quick);
        let scenario = Scenario::builder(name)
            .model_preset(preset)
            .seed(0x5EED)
            .traffic(TrafficSource::Drift { quick })
            .config(cfg.clone())
            .build()
            .expect("drift scenario is valid by construction");
        let scn = scenario.materialize().expect("drift scenario materializes");

        // (1) ours: online re-optimization with a BO refinement round.
        let ours = {
            let mut cfg_ours = cfg.clone();
            cfg_ours.reoptimize = true;
            cfg_ours.bo_round_iters = 1;
            scn.run(&cfg_ours, Baseline::Ours).report
        };
        // (2) static: the same initial deployment, never re-optimized.
        let stat = scn.run(&cfg, Baseline::Static).report;
        // (3) LambdaML over-provisioning, never re-optimized.
        let lam = scn.run(&cfg, Baseline::LambdaML).report;
        // (4) CPU cluster.
        let cpu = scn.run(&cfg, Baseline::CpuCluster).report;

        let mut t = Table::new(
            &format!("Traffic — {name}: sustained serving under drifting MMPP load"),
            &[
                "deployment",
                "billed cost",
                "tput (tok/s)",
                "p95 latency",
                "redeploys",
                "warm frac",
            ],
        );
        let mut row = |label: &str, r: &SimReport| {
            t.row(vec![
                label.into(),
                fcost(r.total_cost),
                fnum(r.throughput_tps),
                ftime(r.p95_latency),
                r.redeploys.to_string(),
                fnum(r.warm_fraction()),
            ]);
        };
        row("ours (online re-opt + BO)", &ours);
        row("static initial deployment", &stat);
        row("LambdaML (max memory)", &lam);
        row("CPU cluster", &cpu);
        tables.push(t);

        // Cost-over-time: the drift story in four checkpoints.
        let horizon = scn
            .traffic
            .last()
            .map(|tb| tb.at)
            .unwrap_or(0.0)
            .max(1.0);
        let mut tt = Table::new(
            &format!("Traffic — {name}: cumulative billed cost over time"),
            &["time", "ours", "static", "LambdaML", "CPU cluster"],
        );
        for frac in [0.25, 0.5, 0.75, 1.0] {
            let at = horizon * frac;
            tt.row(vec![
                format!("{:.0}s", at),
                fcost(cost_at(&ours, at)),
                fcost(cost_at(&stat, at)),
                fcost(cost_at(&lam, at)),
                fcost(scn.cpu.job_cost(at)),
            ]);
        }
        tables.push(tt);

        // Queueing regime: the same stream on the static deployment under
        // unbounded concurrency (PR 1 model), Lambda-style concurrency 1,
        // and concurrency 1 with epoch-level autoscaling.
        let mut qt = Table::new(
            &format!("Traffic — {name}: per-instance queueing + autoscaling (static deployment)"),
            &[
                "regime",
                "billed cost",
                "p95 latency",
                "mean queue delay",
                "max util",
                "scale out/in",
            ],
        );
        for (label, conc, pol) in [
            ("unbounded (PR 1 model)", None, AutoscalePolicy::Off),
            ("concurrency 1", Some(1), AutoscalePolicy::Off),
            (
                "concurrency 1 + autoscale",
                Some(1),
                AutoscalePolicy::TargetUtilization { target: 0.7 },
            ),
        ] {
            let cfg_q = TrafficConfig {
                concurrency: conc,
                autoscale: pol,
                ..cfg.clone()
            };
            let r = scn.run(&cfg_q, Baseline::Static).report;
            qt.row(vec![
                label.into(),
                fcost(r.total_cost),
                ftime(r.p95_latency),
                ftime(r.mean_queue_delay),
                fnum(r.max_utilization),
                format!("{}/{}", r.scale_outs, r.scale_ins),
            ]);
        }
        tables.push(qt);

        // Dispatch engines on the Lambda-style (concurrency 1) static
        // deployment: the legacy serial loop, the event engine with
        // monolithic dispatch (must reproduce legacy), and the event engine
        // with layer-pipelined dispatch — later layers' queue waits overlap
        // earlier layers' compute, which shows up as lower latency at
        // identical billed cost (billing meters busy time).
        let mut et = Table::new(
            &format!("Traffic — {name}: dispatch engines (concurrency 1, static deployment)"),
            &["engine", "billed cost", "p50 latency", "p95 latency", "mean queue delay"],
        );
        let cfg_eng = TrafficConfig {
            reoptimize: false,
            concurrency: Some(1),
            autoscale: AutoscalePolicy::Off,
            ..cfg.clone()
        };
        // One ODS solve shared by all three rows: the deployment is truly
        // static, so the rows differ only in dispatch discipline.
        let engine_policy = scn.initial_policy(&cfg_eng);
        for (label, engine) in [
            ("legacy serial loop", SimEngine::Legacy),
            ("event, monolithic", SimEngine::Event { pipeline: false }),
            ("event, pipelined", SimEngine::Event { pipeline: true }),
        ] {
            let cfg_e = TrafficConfig { engine, ..cfg_eng.clone() };
            let r = scn.run_with_policy(&cfg_e, engine_policy.clone()).report;
            et.row(vec![
                label.into(),
                fcost(r.total_cost),
                ftime(r.p50_latency),
                ftime(r.p95_latency),
                ftime(r.mean_queue_delay),
            ]);
        }
        tables.push(et);
    }

    // Multi-tenant fleet: two tiny tenants with anti-correlated MMPP
    // bursts behind one shared account-level concurrency cap, versus the
    // isolation baseline (each tenant alone on its weighted cap share).
    // Anti-correlation is the point: the bursting tenant borrows the idle
    // tenant's slots, so the shared pool admits bursts the isolated shares
    // must queue.
    let fleet = demo_fleet();
    let shared = fleet.run().expect("demo fleet runs").report;
    let isolated = fleet.run_isolated().expect("isolated baseline runs").report;
    let mut ft = Table::new(
        "Traffic — fleet: shared account pool vs isolated per-tenant shares (cap 2, tiny x2)",
        &FleetReport::comparison_columns(),
    );
    ft.row(shared.comparison_row("shared (weighted-fair)"));
    ft.row(isolated.comparison_row("isolated shares"));
    tables.push(ft);

    tables
}

/// The canned two-tenant demo fleet: tiny models, LambdaML deployments
/// (closed-form — nothing solver-bound on this path), anti-correlated MMPP
/// bursts, a shared cap of 2 split weighted-fair.
fn demo_fleet() -> FleetScenario {
    let tenant = |name: &str, seed: u64, burst_first: bool| {
        let (rate0, rate1) = if burst_first { (2.0, 0.05) } else { (0.05, 2.0) };
        let scenario = Scenario::builder(name)
            .model_preset(ModelPreset::TinyMoe)
            .seed(seed)
            .profile(2, 128)
            .traffic(TrafficSource::Synthetic {
                process: ArrivalProcess::Mmpp { rate0, rate1, hold0: 20.0, hold1: 20.0 },
                duration: Some(40.0),
                requests: None,
                tokens_per_request: 128,
            })
            .config(TrafficConfig { reoptimize: false, ..TrafficConfig::default() })
            .baseline(Baseline::LambdaML)
            .build()
            .expect("demo tenant is valid by construction");
        TenantSpec {
            name: name.to_string(),
            weight: 1.0,
            slo_p95: None,
            active: None,
            source: TenantSource::Inline(scenario),
        }
    };
    FleetScenario {
        name: "demo-fleet".to_string(),
        account_cap: Some(2),
        arbitration: FleetArbitration::WeightedFair,
        // The demo table narrates slot borrowing between whole requests, so
        // it keeps the original request-granular accounting.
        cap_granularity: CapGranularity::Request,
        share_experts: false,
        slo_feedback: false,
        batch_window: 0.0,
        faults: FaultSpec::off(),
        driver: FleetDriver::Heap,
        tenants: vec![tenant("chat", 0xF1, true), tenant("batch", 0xF2, false)],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::scenario::drift_scenario;

    #[test]
    fn scenario_is_two_phase_and_deterministic() {
        let scn = drift_scenario(ModelPreset::BertMoe { experts: 4, top_k: 1 }, true, 1);
        assert!(scn.traffic.len() > 10, "traffic len {}", scn.traffic.len());
        assert!(scn.traffic.windows(2).all(|w| w[0].at <= w[1].at));
        // Phase A requests are heavier than phase B requests.
        let first = scn.traffic.first().unwrap().batch.total_tokens;
        let last = scn.traffic.last().unwrap().batch.total_tokens;
        assert!(first >= last * 4, "A={first} B={last}");
        let scn2 = drift_scenario(ModelPreset::BertMoe { experts: 4, top_k: 1 }, true, 1);
        assert_eq!(scn.traffic.len(), scn2.traffic.len());
        assert_eq!(
            scn.traffic[0].batch.sequences[0].tokens,
            scn2.traffic[0].batch.sequences[0].tokens
        );
    }

    #[test]
    fn ours_beats_lambdaml_under_traffic() {
        let t = &super::run(true)[0];
        let cost = |name: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0].starts_with(name))
                .unwrap()[1]
                .trim_start_matches('$')
                .parse()
                .unwrap()
        };
        let ours = cost("ours");
        let lam = cost("LambdaML");
        let cpu = cost("CPU cluster");
        assert!(ours < lam, "ours {ours} vs lambdaml {lam}");
        assert!(ours < cpu, "ours {ours} vs cpu {cpu}");
    }
}
