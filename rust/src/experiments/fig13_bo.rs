//! Fig. 13 — BO acquisition ablation: the ratio of (a) billed cost and
//! (b) expert-prediction difference achieved by BO with each acquisition
//! function, relative to no BO. Paper shape: multi-dimensional ε-GS attains
//! the lowest cost ratio on both models; its prediction-difference ratio is
//! best for BERT and competitive for GPT-2.

use super::common::ExpContext;
use crate::bo::acquisition::{RandomAcq, SingleEpsGreedy, Tpe};
use crate::bo::algorithm::BoAlgorithm;
use crate::bo::eps_greedy::MultiEpsGreedy;
use crate::bo::Acquisition;
use crate::config::workload::CorpusPreset;
use crate::model::ModelPreset;
use crate::util::table::{fnum, Table};

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    let models: Vec<(&str, ModelPreset)> = if quick {
        vec![("Tiny MoE", ModelPreset::TinyMoe)]
    } else {
        vec![
            ("Bert MoE", ModelPreset::BertMoe { experts: 4, top_k: 1 }),
            ("GPT2 MoE", ModelPreset::Gpt2Moe { top_k: 1 }),
        ]
    };

    for (name, preset) in models {
        let mut ctx = ExpContext::new(preset, CorpusPreset::Enwik8, true);
        let mut bo_cfg = ctx.config.bo.clone();
        if quick {
            bo_cfg.q = 64;
            bo_cfg.max_iters = 5;
        } else {
            bo_cfg.q = 1000;
            bo_cfg.max_iters = 20;
        }
        let eval_batches = vec![ctx.eval_batch(), ctx.eval_batch()];
        let mut deploy_cfg = ctx.config.deploy.clone();
        deploy_cfg.t_limit = 4000.0;

        let build = |ctx: &ExpContext| BayesSetup {
            predictor: ctx.bayes(),
        };
        struct BayesSetup {
            predictor: crate::predictor::BayesPredictor,
        }

        let mut t = Table::new(
            &format!("Fig 13 — {name}: BO acquisition ablation (ratio vs no BO)"),
            &["acquisition", "cost ratio", "pred-diff ratio", "iters", "converged"],
        );

        // No-BO reference.
        let setup = build(&ctx);
        let mut bo = BoAlgorithm {
            platform: &ctx.config.platform,
            deploy_cfg: &deploy_cfg,
            bo_cfg: bo_cfg.clone(),
            spec: &ctx.spec,
            gate: &ctx.gate,
            predictor: setup.predictor,
            eval_batches: eval_batches.clone(),
            solver_time_limit: if quick { 0.3 } else { 2.0 },
        };
        let (no_bo_cost, no_bo_err) = bo.evaluate_no_bo();
        t.row(vec![
            "no BO".into(),
            "1.00".into(),
            "1.00".into(),
            "0".into(),
            "-".into(),
        ]);

        let acqs: Vec<(Box<dyn Acquisition>, bool)> = vec![
            (Box::new(MultiEpsGreedy::new(&bo_cfg)), true),
            (Box::new(SingleEpsGreedy::new(&bo_cfg)), false),
            (Box::new(RandomAcq), false),
            (Box::new(Tpe::new()), false),
        ];
        for (mut acq, use_gp) in acqs {
            let name = acq.name();
            let outcome = bo.run(acq.as_mut(), use_gp, 0xB0 + name.len() as u64);
            t.row(vec![
                name.into(),
                fnum(outcome.best_cost / no_bo_cost),
                fnum(outcome.best_prediction_error / no_bo_err.max(1e-9)),
                outcome.iterations.to_string(),
                outcome.converged.to_string(),
            ]);
        }
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    #[test]
    fn bo_never_worse_than_no_bo() {
        // The running-min construction guarantees ratio <= first trial; with
        // exploitation it must not exceed the no-BO cost meaningfully.
        let tables = super::run(true);
        for t in &tables {
            for r in t.rows.iter().skip(1) {
                let ratio: f64 = r[1].parse().unwrap();
                assert!(ratio <= 1.15, "{} ratio {ratio}", r[0]);
            }
        }
    }

    #[test]
    fn multi_eps_is_competitive() {
        let tables = super::run(true);
        let t = &tables[0];
        let get = |name: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0] == name)
                .map(|r| r[1].parse().unwrap())
                .unwrap()
        };
        let ours = get("multi-eps-gs");
        let rand = get("random");
        assert!(ours <= rand * 1.10, "ours {ours} vs random {rand}");
    }
}
