//! Fig. 2 — Motivation: billed cost of all MoE layers and inference
//! throughput of a GPT-2-based MoE model serving 10,240 Enwik8 tokens, on
//! the serverless platform (3008→3072 MB functions) vs the CPU cluster.
//! Paper shape: serverless cost ≪ cluster cost; serverless throughput
//! ~22.9 tok/s, well above the 3.3 tok/s human reading speed.

use super::common::{throughput, ExpContext};
use crate::comm::{CommMethod, ExpertPlan, LayerPlan};
use crate::config::workload::CorpusPreset;
use crate::deploy::DeploymentPolicy;
use crate::model::ModelPreset;
use crate::platform::CpuCluster;
use crate::util::table::{fcost, fnum, Table};

pub fn run(quick: bool) -> Vec<Table> {
    let mut ctx = ExpContext::new(ModelPreset::Gpt2Moe { top_k: 1 }, CorpusPreset::Enwik8, quick);
    let batch = ctx.eval_batch();
    let counts = ctx.real_counts(&batch);
    let tokens = batch.total_tokens as u64;
    let cfg = &ctx.config.platform;

    // Serverless: every expert at max memory (the Fig. 2 setting), indirect.
    let policy = DeploymentPolicy {
        layers: counts
            .iter()
            .map(|layer| LayerPlan {
                method: CommMethod::Indirect,
                beta: 1,
                experts: layer
                    .iter()
                    .map(|&d| ExpertPlan {
                        mem_mb: cfg.max_memory_mb(),
                        replicas: 1,
                        tokens: d,
                    })
                    .collect(),
            })
            .collect(),
    };
    let sl_cost = policy.total_cost(cfg, &ctx.spec, true);
    let problem = ctx.problem(counts.clone(), f64::INFINITY);
    let sl_e2e = policy.end_to_end_time(&problem);
    let sl_tput = throughput(tokens, sl_e2e);

    // CPU cluster.
    let cluster = CpuCluster::new(ctx.config.cpu_cluster.clone(), false);
    let cl = cluster.serve(&ctx.spec, &counts, tokens as usize);

    let mut t = Table::new(
        "Fig 2 — GPT-2 MoE: serverless (AWS-Lambda model) vs CPU cluster",
        &["deployment", "billed cost", "throughput (tok/s)", "e2e time (s)"],
    );
    t.row(vec![
        "serverless 3072MB".into(),
        fcost(sl_cost),
        fnum(sl_tput),
        fnum(sl_e2e),
    ]);
    t.row(vec![
        "CPU cluster (2x64c EPYC)".into(),
        fcost(cl.billed_cost),
        fnum(cl.throughput_tps),
        fnum(cl.exec_secs),
    ]);
    t.row(vec![
        "human reading speed".into(),
        "-".into(),
        "3.3".into(),
        "-".into(),
    ]);
    vec![t]
}

#[cfg(test)]
mod tests {
    #[test]
    fn serverless_cheaper_than_cluster() {
        let tables = super::run(true);
        let rows = &tables[0].rows;
        let sl: f64 = rows[0][1].trim_start_matches('$').parse().unwrap();
        let cl: f64 = rows[1][1].trim_start_matches('$').parse().unwrap();
        assert!(sl < cl, "serverless {sl} vs cluster {cl}");
        // Paper: >=75.67% cheaper. Directionally stronger here.
        assert!(sl < cl * 0.25, "expected >=75% saving: {sl} vs {cl}");
    }
}
