//! §V-F — Algorithm overhead: wall-clock time of profiling, expert
//! prediction, the ODS algorithm (three MIQCP solves) and a BO iteration.
//! Paper numbers: profiling ≈28.89 s/100 batches, prediction ≈20.31 s/10
//! batches, ODS ≈2.27 s, BO ≈62.15 s/iter, convergence ≈1257.89 s.

use super::common::ExpContext;
use crate::config::workload::CorpusPreset;
use crate::deploy::ods::ods_full;
use crate::model::ModelPreset;
use crate::predictor::eval::predicted_counts;
use crate::predictor::profile::profile_batches;
use crate::util::table::{ftime, Table};
use std::time::Instant;

pub fn run(quick: bool) -> Vec<Table> {
    let preset = if quick {
        ModelPreset::TinyMoe
    } else {
        ModelPreset::BertMoe { experts: 4, top_k: 1 }
    };
    let mut ctx = ExpContext::new(preset, CorpusPreset::Enwik8, quick);
    let n_profile = if quick { 4 } else { 100 };
    let n_predict = if quick { 2 } else { 10 };

    let mut t = Table::new(
        "Sec V-F — algorithm overhead",
        &["stage", "workload", "wall time"],
    );

    // Profiling.
    let batches = ctx.generator.profile_set(n_profile);
    let t0 = Instant::now();
    let prof = profile_batches(&ctx.gate, &batches);
    t.row(vec![
        "profiling".into(),
        format!("{n_profile} batches"),
        ftime(t0.elapsed().as_secs_f64()),
    ]);

    // Prediction.
    let bayes = crate::predictor::BayesPredictor::new(prof.table, prof.prior);
    let eval: Vec<_> = (0..n_predict).map(|_| ctx.generator.next_batch()).collect();
    let t0 = Instant::now();
    let mut counts = Vec::new();
    for b in &eval {
        counts.push(predicted_counts(&ctx.gate, &bayes, b));
    }
    t.row(vec![
        "expert prediction".into(),
        format!("{n_predict} batches"),
        ftime(t0.elapsed().as_secs_f64()),
    ]);

    // ODS (3 MIQCP solves + Alg. 1).
    let problem = ctx.problem(counts.pop().unwrap(), 4000.0);
    let t0 = Instant::now();
    let _ = ods_full(&problem, if quick { 0.5 } else { 60.0 });
    t.row(vec![
        "ODS (3 MIQCP + Alg.1)".into(),
        "1 deployment".into(),
        ftime(t0.elapsed().as_secs_f64()),
    ]);

    // One BO iteration.
    let mut bo_cfg = ctx.config.bo.clone();
    bo_cfg.q = if quick { 32 } else { 1000 };
    bo_cfg.max_iters = 1;
    let mut deploy_cfg = ctx.config.deploy.clone();
    deploy_cfg.t_limit = 4000.0;
    let mut bo = crate::bo::algorithm::BoAlgorithm {
        platform: &ctx.config.platform,
        deploy_cfg: &deploy_cfg,
        bo_cfg: bo_cfg.clone(),
        spec: &ctx.spec,
        gate: &ctx.gate,
        predictor: bayes,
        eval_batches: vec![eval[0].clone()],
        solver_time_limit: if quick { 0.3 } else { 5.0 },
    };
    let mut acq = crate::bo::eps_greedy::MultiEpsGreedy::new(&bo_cfg);
    let t0 = Instant::now();
    let _ = bo.run(&mut acq, true, 1);
    t.row(vec![
        "BO iteration".into(),
        format!("Q={}", bo_cfg.q),
        ftime(t0.elapsed().as_secs_f64()),
    ]);

    vec![t]
}

#[cfg(test)]
mod tests {
    #[test]
    fn overhead_rows_present() {
        let t = &super::run(true)[0];
        assert_eq!(t.rows.len(), 4);
        assert!(t.rows.iter().all(|r| !r[2].is_empty()));
    }
}
