//! Shared experiment scaffolding: a fully-wired context (config, model,
//! corpus, gate, profiled predictor) so each figure module stays small.

use crate::config::workload::CorpusPreset;
use crate::config::Config;
use crate::deploy::DeployProblem;
use crate::gating::SimGate;
use crate::model::{ModelPreset, MoeModelSpec};
use crate::predictor::profile::{profile_batches, ProfileResult};
use crate::predictor::BayesPredictor;
use crate::workload::{Batch, Corpus, RequestGenerator};

pub struct ExpContext {
    pub config: Config,
    pub spec: MoeModelSpec,
    pub gate: SimGate,
    pub generator: RequestGenerator,
    pub profile: ProfileResult,
}

impl ExpContext {
    /// Standard setup: profile `profile_batches` batches, evaluation batches
    /// drawn afterwards from the same corpus (the paper's 95%/5% split).
    pub fn new(preset: ModelPreset, corpus: CorpusPreset, quick: bool) -> ExpContext {
        let config = Config::default();
        let spec = preset.spec();
        let gate = SimGate::new(&spec, 0xA11CE);
        let corpus = Corpus::new(corpus, config.workload.seed);
        let batch_tokens = if quick { 1024 } else { config.workload.batch_tokens };
        let mut generator = RequestGenerator::new(corpus, 17, batch_tokens);
        let n_profile = if quick { 8 } else { 40 };
        let batches = generator.profile_set(n_profile);
        let profile = profile_batches(&gate, &batches);
        ExpContext {
            config,
            spec,
            gate,
            generator,
            profile,
        }
    }

    pub fn bayes(&self) -> BayesPredictor {
        BayesPredictor::new(self.profile.table.clone(), self.profile.prior.clone())
    }

    pub fn eval_batch(&mut self) -> Batch {
        self.generator.next_batch()
    }

    /// Real per-layer expert counts for a batch.
    pub fn real_counts(&self, batch: &Batch) -> Vec<Vec<u64>> {
        crate::predictor::eval::real_counts(&self.gate, batch)
    }

    /// Deployment problem from token counts.
    pub fn problem<'a>(&'a self, tokens: Vec<Vec<u64>>, t_limit: f64) -> DeployProblem<'a> {
        DeployProblem {
            cfg: &self.config.platform,
            spec: &self.spec,
            tokens,
            t_limit,
            max_replicas: self.config.deploy.max_replicas,
            beta_grid: self.config.deploy.beta_grid.clone(),
            warm: true,
        }
    }
}

/// Throughput from batch tokens and E2E seconds.
pub fn throughput(tokens: u64, e2e_secs: f64) -> f64 {
    tokens as f64 / e2e_secs.max(1e-9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn context_builds_quick() {
        let mut ctx = ExpContext::new(ModelPreset::TinyMoe, CorpusPreset::Enwik8, true);
        assert!(ctx.profile.tokens_profiled >= 8 * 1024);
        let b = ctx.eval_batch();
        let counts = ctx.real_counts(&b);
        assert_eq!(counts.len(), ctx.spec.num_moe_layers());
        let p = ctx.problem(counts, 1000.0);
        assert!(p.latency_budget() < 1000.0);
    }
}
