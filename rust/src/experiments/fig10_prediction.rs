//! Fig. 10 — Expert-selection prediction accuracy: average absolute
//! difference per expert between real and predicted token counts, across
//! MoE models, datasets and tasks; ours vs Lina; top-1 vs top-2; 4/8/16
//! experts. Paper shape: ours < Lina everywhere; top-2 improves accuracy;
//! more experts → lower per-expert difference.

use super::common::ExpContext;
use crate::config::workload::CorpusPreset;
use crate::model::ModelPreset;
use crate::predictor::eval::evaluate;
use crate::util::table::{fnum, Table};

pub fn run(quick: bool) -> Vec<Table> {
    let cases: Vec<(&str, ModelPreset, CorpusPreset)> = vec![
        ("Basic Bert MoE", ModelPreset::BertMoe { experts: 4, top_k: 1 }, CorpusPreset::Enwik8),
        ("Bert CCnews", ModelPreset::BertMoe { experts: 4, top_k: 1 }, CorpusPreset::CcNews),
        ("Bert Wmt19", ModelPreset::BertMoe { experts: 4, top_k: 1 }, CorpusPreset::Wmt19),
        ("Bert top-2", ModelPreset::BertMoe { experts: 4, top_k: 2 }, CorpusPreset::Enwik8),
        ("Bert 8 experts", ModelPreset::BertMoe { experts: 8, top_k: 1 }, CorpusPreset::Enwik8),
        ("Bert 16 experts", ModelPreset::BertMoe { experts: 16, top_k: 1 }, CorpusPreset::Enwik8),
        ("Basic GPT2 MoE", ModelPreset::Gpt2Moe { top_k: 1 }, CorpusPreset::Enwik8),
        ("GPT2 Lambda", ModelPreset::Gpt2Moe { top_k: 1 }, CorpusPreset::Lambada),
        ("Basic Bert2Bert MoE", ModelPreset::Bert2BertMoe { top_k: 1 }, CorpusPreset::Enwik8),
    ];

    let mut t = Table::new(
        "Fig 10 — avg |real - predicted| tokens per expert (lower is better)",
        &["case", "ours (Bayes)", "Lina (token-ID)", "uniform"],
    );
    for (name, preset, corpus) in cases {
        let mut ctx = ExpContext::new(preset, corpus, quick);
        let eval_batch = ctx.eval_batch();
        let bayes = ctx.bayes();
        let e_bayes = evaluate(&ctx.gate, &bayes, &eval_batch);
        let e_lina = evaluate(&ctx.gate, &ctx.profile.lina, &eval_batch);
        let uni = crate::predictor::UniformPredictor {
            num_experts: ctx.spec.experts_at(0),
        };
        let e_uni = evaluate(&ctx.gate, &uni, &eval_batch);
        t.row(vec![
            name.into(),
            fnum(e_bayes.overall),
            fnum(e_lina.overall),
            fnum(e_uni.overall),
        ]);
    }
    vec![t]
}

#[cfg(test)]
mod tests {
    #[test]
    fn ours_at_least_matches_lina_on_average() {
        let t = &super::run(true)[0];
        let mut ours = 0.0;
        let mut lina = 0.0;
        for r in &t.rows {
            ours += r[1].parse::<f64>().unwrap_or(0.0);
            lina += r[2].parse::<f64>().unwrap_or(0.0);
        }
        assert!(
            ours <= lina * 1.02,
            "ours total {ours} vs lina total {lina}"
        );
    }
}
