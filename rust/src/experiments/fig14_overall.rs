//! Fig. 14 — Overall comparison: billed cost of all MoE layers and inverse
//! throughput across six deployments: (1) serverless + BO-optimized
//! prediction, (2) serverless + real distribution, (3) serverless +
//! un-adjusted prediction (no BO), (4) LambdaML over-provisioning, (5) CPU
//! cluster, (6) CPU cluster + betterTransformer.
//! Paper headlines: (1) ≥75.67% cheaper than CPU; ≥43.41% cheaper than
//! LambdaML with ≤18.76% throughput loss; (1) close to (2).

use super::common::{throughput, ExpContext};
use crate::bo::algorithm::BoAlgorithm;
use crate::bo::eps_greedy::MultiEpsGreedy;
use crate::config::workload::CorpusPreset;
use crate::deploy::baselines::lambdaml_policy;
use crate::deploy::ods::ods_full;
use crate::model::ModelPreset;
use crate::platform::CpuCluster;
use crate::predictor::eval::predicted_counts;
use crate::util::table::{fcost, fnum, Table};

pub fn run(quick: bool) -> Vec<Table> {
    let mut tables = Vec::new();
    let models: Vec<(&str, ModelPreset)> = if quick {
        vec![("Bert MoE", ModelPreset::BertMoe { experts: 4, top_k: 1 })]
    } else {
        vec![
            ("Bert MoE", ModelPreset::BertMoe { experts: 4, top_k: 1 }),
            ("GPT2 MoE", ModelPreset::Gpt2Moe { top_k: 1 }),
        ]
    };

    for (name, preset) in models {
        let mut ctx = ExpContext::new(preset, CorpusPreset::Enwik8, quick);
        let batch = ctx.eval_batch();
        let real = ctx.real_counts(&batch);
        let tokens = batch.total_tokens as u64;
        let t_limit = if quick { 4000.0 } else { 3000.0 };
        let solver_tl = if quick { 0.5 } else { 10.0 };

        let mut t = Table::new(
            &format!("Fig 14 — {name}: overall cost and throughput (10,240 tokens)"),
            &["deployment", "billed cost", "tput (tok/s)", "1/tput (s/tok)"],
        );

        // (2) real distribution (oracle).
        let problem_real = ctx.problem(real.clone(), t_limit);
        let ods_real = ods_full(&problem_real, solver_tl).expect("real-dist deployment");
        let e2e_real = ods_real.policy.end_to_end_time(&problem_real);

        // (3) predicted, no BO.
        let bayes = ctx.bayes();
        let pred = predicted_counts(&ctx.gate, &bayes, &batch);
        let problem_pred = ctx.problem(pred.clone(), t_limit);
        let ods_pred = ods_full(&problem_pred, solver_tl).expect("pred deployment");
        let out_pred = crate::bo::feedback::serve_with_real_counts(
            &ctx.config.platform,
            &ctx.spec,
            &ods_pred.policy,
            &real,
            true,
        );
        let e2e_pred = problem_pred.fixed_overhead() + out_pred.latency;

        // (1) predicted + BO.
        let mut bo_cfg = ctx.config.bo.clone();
        bo_cfg.q = if quick { 64 } else { 512 };
        bo_cfg.max_iters = if quick { 4 } else { 12 };
        let mut deploy_cfg = ctx.config.deploy.clone();
        deploy_cfg.t_limit = t_limit;
        let mut bo = BoAlgorithm {
            platform: &ctx.config.platform,
            deploy_cfg: &deploy_cfg,
            bo_cfg: bo_cfg.clone(),
            spec: &ctx.spec,
            gate: &ctx.gate,
            predictor: ctx.bayes(),
            eval_batches: vec![batch.clone()],
            solver_time_limit: solver_tl.min(1.0),
        };
        let mut acq = MultiEpsGreedy::new(&bo_cfg);
        let outcome = bo.run(&mut acq, true, 0xF14);
        bo.commit_best(&outcome);
        let pred_bo = predicted_counts(&ctx.gate, &bo.predictor, &batch);
        let problem_bo = ctx.problem(pred_bo, t_limit);
        let ods_bo = ods_full(&problem_bo, solver_tl).expect("bo deployment");
        let out_bo = crate::bo::feedback::serve_with_real_counts(
            &ctx.config.platform,
            &ctx.spec,
            &ods_bo.policy,
            &real,
            true,
        );
        let e2e_bo = problem_bo.fixed_overhead() + out_bo.latency;

        // (4) LambdaML.
        let lam = lambdaml_policy(&problem_real);
        let lam_cost = lam.total_cost(&ctx.config.platform, &ctx.spec, true);
        let lam_e2e = lam.end_to_end_time(&problem_real);

        // (5)/(6) CPU cluster.
        let cl = CpuCluster::new(ctx.config.cpu_cluster.clone(), false).serve(&ctx.spec, &real, tokens as usize);
        let cl_bt = CpuCluster::new(ctx.config.cpu_cluster.clone(), true).serve(&ctx.spec, &real, tokens as usize);

        let mut row = |name: &str, cost: f64, e2e: f64| {
            let tput = throughput(tokens, e2e);
            t.row(vec![
                name.into(),
                fcost(cost),
                fnum(tput),
                fnum(1.0 / tput),
            ]);
        };
        row("serverless BO-predicted (ours)", out_bo.cost, e2e_bo);
        row("serverless real distribution", ods_real.total_cost, e2e_real);
        row("serverless predicted no-BO", out_pred.cost, e2e_pred);
        row("LambdaML (max memory)", lam_cost, lam_e2e);
        row("CPU cluster", cl.billed_cost, cl.exec_secs);
        row("CPU betterTransformer", cl_bt.billed_cost, cl_bt.exec_secs);
        tables.push(t);
    }
    tables
}

#[cfg(test)]
mod tests {
    #[test]
    fn headline_claims_directionally_hold() {
        let t = &super::run(true)[0];
        let cost = |name: &str| -> f64 {
            t.rows
                .iter()
                .find(|r| r[0].starts_with(name))
                .unwrap()[1]
                .trim_start_matches('$')
                .parse()
                .unwrap()
        };
        let ours = cost("serverless BO-predicted");
        let lam = cost("LambdaML");
        let cpu = cost("CPU cluster");
        assert!(ours < cpu * 0.25, "≥75% vs CPU: ours {ours} cpu {cpu}");
        assert!(ours < lam, "cheaper than LambdaML: ours {ours} lam {lam}");
    }
}
