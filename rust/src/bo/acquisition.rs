//! Acquisition baselines for the Fig. 13 ablation: single-ε greedy, random,
//! and TPE (the Optuna default the paper compares against).

use super::gp::{embed, Gp};
use super::{Acquisition, BoVar, ProposeCtx};
use crate::config::BoConfig;

/// Single-dimension ε-greedy: one shared ε for all Q dimensions, plain decay.
pub struct SingleEpsGreedy {
    pub eps0: f64,
    pub rho: f64,
}

impl SingleEpsGreedy {
    pub fn new(cfg: &BoConfig) -> Self {
        Self {
            eps0: cfg.eps0,
            rho: cfg.rho,
        }
    }
}

impl Acquisition for SingleEpsGreedy {
    fn propose(&mut self, ctx: &mut ProposeCtx) -> Vec<BoVar> {
        let eps = self.eps0 / (1.0 + self.rho * ctx.trial as f64);
        let best: Vec<BoVar> = ctx.best_vars().map(|v| v.to_vec()).unwrap_or_default();
        (0..ctx.q)
            .map(|dim| {
                if ctx.rng.chance(eps) || best.is_empty() {
                    ctx.random_var()
                } else {
                    best[dim.min(best.len() - 1)]
                }
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "single-eps-gs"
    }
}

/// Random search: fresh random variables every trial.
pub struct RandomAcq;

impl Acquisition for RandomAcq {
    fn propose(&mut self, ctx: &mut ProposeCtx) -> Vec<BoVar> {
        (0..ctx.q).map(|_| ctx.random_var()).collect()
    }

    fn name(&self) -> &'static str {
        "random"
    }
}

/// Tree-structured Parzen Estimator (simplified): split history at the γ
/// cost quantile; propose candidate variable sets and keep the one whose
/// embedding maximizes l(x)/g(x) under Gaussian KDEs of good/bad trials.
pub struct Tpe {
    pub gamma: f64,
    pub candidates: usize,
    dim: usize,
}

impl Tpe {
    pub fn new() -> Self {
        Self {
            gamma: 0.25,
            candidates: 8,
            dim: 16,
        }
    }

    fn kde_log_density(points: &[Vec<f64>], x: &[f64], bw: f64) -> f64 {
        if points.is_empty() {
            return 0.0;
        }
        let mut acc = 0.0;
        for p in points {
            let d2: f64 = p.iter().zip(x).map(|(a, b)| (a - b) * (a - b)).sum();
            acc += (-d2 / (2.0 * bw * bw)).exp();
        }
        (acc / points.len() as f64).max(1e-300).ln()
    }
}

impl Default for Tpe {
    fn default() -> Self {
        Self::new()
    }
}

impl Acquisition for Tpe {
    fn propose(&mut self, ctx: &mut ProposeCtx) -> Vec<BoVar> {
        if ctx.history.len() < 3 {
            return (0..ctx.q).map(|_| ctx.random_var()).collect();
        }
        // Split good/bad by cost quantile.
        let mut costs: Vec<f64> = ctx.history.iter().map(|t| t.cost).collect();
        costs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let cut = costs[((costs.len() as f64 * self.gamma) as usize).min(costs.len() - 1)];
        let good: Vec<Vec<f64>> = ctx
            .history
            .iter()
            .filter(|t| t.cost <= cut)
            .map(|t| embed(&t.vars, self.dim))
            .collect();
        let bad: Vec<Vec<f64>> = ctx
            .history
            .iter()
            .filter(|t| t.cost > cut)
            .map(|t| embed(&t.vars, self.dim))
            .collect();
        // Generate candidates by mutating the best trial, score by l/g.
        let best: Vec<BoVar> = ctx.best_vars().unwrap().to_vec();
        let mut best_score = f64::NEG_INFINITY;
        let mut best_cand: Option<Vec<BoVar>> = None;
        for _ in 0..self.candidates {
            let cand: Vec<BoVar> = best
                .iter()
                .map(|v| {
                    if ctx.rng.chance(0.2) {
                        ctx.random_var()
                    } else {
                        *v
                    }
                })
                .collect();
            let x = embed(&cand, self.dim);
            let score = Self::kde_log_density(&good, &x, 0.4)
                - Self::kde_log_density(&bad, &x, 0.4);
            if score > best_score {
                best_score = score;
                best_cand = Some(cand);
            }
        }
        best_cand.unwrap()
    }

    fn name(&self) -> &'static str {
        "tpe"
    }
}

/// GP-guided variant of the multi-ε acquisition used inside Alg. 2: draw S
/// proposals from the base acquisition and keep the one with the lowest GP
/// posterior mean (the "surrogate simulates the billed cost" role, §IV-B).
pub fn gp_filter(
    proposals: Vec<Vec<BoVar>>,
    history: &[super::TrialRecord],
) -> Vec<BoVar> {
    assert!(!proposals.is_empty());
    if history.len() < 3 || proposals.len() == 1 {
        return proposals.into_iter().next().unwrap();
    }
    let dim = 16;
    let xs: Vec<Vec<f64>> = history.iter().map(|t| embed(&t.vars, dim)).collect();
    let ys: Vec<f64> = history.iter().map(|t| t.cost).collect();
    let gp = Gp::fit(xs, &ys, 0.5, 1e-4);
    proposals
        .into_iter()
        .min_by(|a, b| {
            gp.mean(&embed(a, dim))
                .partial_cmp(&gp.mean(&embed(b, dim)))
                .unwrap()
        })
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bo::TrialRecord;
    use crate::util::rng::Rng;

    fn mk_ctx<'a>(
        history: &'a [TrialRecord],
        limited: &'a [u32],
        experts: &'a [usize],
        rng: &'a mut Rng,
    ) -> ProposeCtx<'a> {
        ProposeCtx {
            history,
            limited_tokens: limited,
            vocab: 128,
            experts_per_layer: experts,
            q: 50,
            trial: 2,
            rng,
        }
    }

    fn fake_history(rng: &mut Rng, n: usize) -> Vec<TrialRecord> {
        (0..n)
            .map(|i| {
                let vars: Vec<BoVar> = (0..50)
                    .map(|_| {
                        let mut ctx = ProposeCtx {
                            history: &[],
                            limited_tokens: &[],
                            vocab: 128,
                            experts_per_layer: &[4, 4],
                            q: 50,
                            trial: 0,
                            rng,
                        };
                        ctx.random_var()
                    })
                    .collect();
                TrialRecord {
                    vars,
                    cost: 1.0 + i as f64 * 0.1,
                    prediction_error: 5.0,
                    feasible: true,
                }
            })
            .collect()
    }

    #[test]
    fn all_acquisitions_propose_q() {
        let mut rng = Rng::new(9);
        let history = fake_history(&mut rng, 5);
        let experts = [4usize, 4];
        let limited = [7u32];
        let cfg = crate::config::BoConfig::default();
        let mut acqs: Vec<Box<dyn Acquisition>> = vec![
            Box::new(SingleEpsGreedy::new(&cfg)),
            Box::new(RandomAcq),
            Box::new(Tpe::new()),
            Box::new(super::super::eps_greedy::MultiEpsGreedy::new(&cfg)),
        ];
        for acq in acqs.iter_mut() {
            let mut ctx = mk_ctx(&history, &limited, &experts, &mut rng);
            let vars = acq.propose(&mut ctx);
            assert_eq!(vars.len(), 50, "{}", acq.name());
        }
    }

    #[test]
    fn gp_filter_prefers_lower_predicted_cost() {
        let mut rng = Rng::new(11);
        let history = fake_history(&mut rng, 8);
        // Proposal identical to the cheapest trial should win over random.
        let best = history[0].vars.clone();
        let mut ctx = mk_ctx(&history, &[], &[4, 4], &mut rng);
        let rand: Vec<BoVar> = (0..50).map(|_| ctx.random_var()).collect();
        let picked = gp_filter(vec![rand, best.clone()], &history);
        assert_eq!(picked, best);
    }
}
