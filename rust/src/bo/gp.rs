//! Gaussian-process surrogate (the BO framework's cost simulator).
//!
//! RBF kernel, Cholesky factorization, predictive mean/variance. Trials are
//! embedded into a fixed-dimension feature space (hash-bucketed sums of the
//! Q variable values per layer/expert), since the raw variable space is
//! combinatorial.

use super::BoVar;

/// Embed a variable set into `dim` features: bucketed value mass.
pub fn embed(vars: &[BoVar], dim: usize) -> Vec<f64> {
    let mut f = vec![0.0; dim];
    for v in vars {
        let bucket = (v.key.0 ^ ((v.layer as u64) << 48) ^ ((v.expert as u64) << 56))
            .wrapping_mul(0x9E37_79B9_7F4A_7C15) as usize
            % dim;
        f[bucket] += v.value;
    }
    // Normalize to keep kernel length scales stable.
    let norm = f.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-9);
    for x in f.iter_mut() {
        *x /= norm;
    }
    f
}

/// Dense symmetric positive-definite solver via Cholesky.
/// Returns L (lower) with A = L·Lᵀ. Panics if A is not SPD.
fn cholesky(a: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let n = a.len();
    let mut l = vec![vec![0.0; n]; n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i][j];
            for k in 0..j {
                sum -= l[i][k] * l[j][k];
            }
            if i == j {
                assert!(sum > 0.0, "matrix not SPD (diag {sum} at {i})");
                l[i][j] = sum.sqrt();
            } else {
                l[i][j] = sum / l[j][j];
            }
        }
    }
    l
}

/// Solve L·y = b then Lᵀ·x = y.
fn chol_solve(l: &[Vec<f64>], b: &[f64]) -> Vec<f64> {
    let n = l.len();
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i][k] * y[k];
        }
        y[i] = s / l[i][i];
    }
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in (i + 1)..n {
            s -= l[k][i] * x[k];
        }
        x[i] = s / l[i][i];
    }
    x
}

pub struct Gp {
    xs: Vec<Vec<f64>>,
    l: Vec<Vec<f64>>,
    alpha: Vec<f64>,
    y_mean: f64,
    pub length_scale: f64,
    pub noise: f64,
}

fn rbf(a: &[f64], b: &[f64], ls: f64) -> f64 {
    let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
    (-d2 / (2.0 * ls * ls)).exp()
}

impl Gp {
    /// Fit on (features, target) pairs.
    pub fn fit(xs: Vec<Vec<f64>>, ys: &[f64], length_scale: f64, noise: f64) -> Gp {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let n = xs.len();
        let y_mean = ys.iter().sum::<f64>() / n as f64;
        let yc: Vec<f64> = ys.iter().map(|y| y - y_mean).collect();
        let mut k = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in 0..n {
                k[i][j] = rbf(&xs[i], &xs[j], length_scale);
            }
            k[i][i] += noise;
        }
        let l = cholesky(&k);
        let alpha = chol_solve(&l, &yc);
        Gp {
            xs,
            l,
            alpha,
            y_mean,
            length_scale,
            noise,
        }
    }

    /// Predictive mean at `x`.
    pub fn mean(&self, x: &[f64]) -> f64 {
        let kx: Vec<f64> = self
            .xs
            .iter()
            .map(|xi| rbf(xi, x, self.length_scale))
            .collect();
        self.y_mean + kx.iter().zip(&self.alpha).map(|(k, a)| k * a).sum::<f64>()
    }

    /// Predictive variance at `x`.
    pub fn variance(&self, x: &[f64]) -> f64 {
        let kx: Vec<f64> = self
            .xs
            .iter()
            .map(|xi| rbf(xi, x, self.length_scale))
            .collect();
        let v = chol_solve(&self.l, &kx);
        let kxx = 1.0 + self.noise;
        (kxx - kx.iter().zip(&v).map(|(a, b)| a * b).sum::<f64>()).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolates_training_points() {
        let xs = vec![vec![0.0, 0.0], vec![1.0, 0.0], vec![0.0, 1.0]];
        let ys = [1.0, 3.0, -2.0];
        let gp = Gp::fit(xs.clone(), &ys, 0.7, 1e-6);
        for (x, &y) in xs.iter().zip(&ys) {
            assert!((gp.mean(x) - y).abs() < 1e-2, "{} vs {}", gp.mean(x), y);
        }
    }

    #[test]
    fn variance_grows_away_from_data() {
        let xs = vec![vec![0.0], vec![0.1]];
        let ys = [0.0, 0.1];
        let gp = Gp::fit(xs, &ys, 0.3, 1e-6);
        assert!(gp.variance(&[0.05]) < gp.variance(&[3.0]));
    }

    #[test]
    fn reverts_to_mean_far_away() {
        let xs = vec![vec![0.0], vec![1.0]];
        let ys = [2.0, 4.0];
        let gp = Gp::fit(xs, &ys, 0.2, 1e-6);
        assert!((gp.mean(&[100.0]) - 3.0).abs() < 1e-6);
    }

    #[test]
    fn embed_is_deterministic_and_normalized() {
        use crate::gating::features::FeatKey;
        let vars: Vec<BoVar> = (0..50)
            .map(|i| BoVar {
                layer: i % 3,
                key: FeatKey::from_parts(i as u32, 0, 2 * i as u32),
                expert: (i % 4) as u8,
                value: 1.0 + i as f64,
            })
            .collect();
        let a = embed(&vars, 16);
        let b = embed(&vars, 16);
        assert_eq!(a, b);
        let norm: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((norm - 1.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "not SPD")]
    fn cholesky_rejects_non_spd() {
        let a = vec![vec![0.0, 1.0], vec![1.0, 0.0]];
        let _ = cholesky(&a);
    }
}
