//! Serving-cost evaluation of a deployment under *real* routing — the
//! feedback signal c_τ of Alg. 2 (lines 25-28), plus the per-expert
//! constraint checks driving the feedback cases (lines 11-19).
//!
//! These free functions are the *analytic core* shared by the BO loop and
//! the traffic engines, not the public serving API: drive simulations
//! through [`crate::traffic::scenario::Scenario`] (which runs them behind
//! the epoch/event engines) rather than calling them directly — the
//! cross-validation tests are the intended remaining direct callers.

use crate::comm::timing::{
    direct_feasible, effective_replica_time, memory_feasible, replica_time,
};
use crate::comm::CommMethod;
use crate::config::PlatformConfig;
use crate::deploy::DeploymentPolicy;
use crate::model::MoeModelSpec;

// Historical home of the thrash multiplier; it now lives with the rest of
// the penalty model in `comm::timing` (re-exported here for callers).
pub use crate::comm::timing::MEMORY_THRASH_FACTOR;

#[derive(Debug, Clone)]
pub struct ServeOutcome {
    /// Billed cost of all MoE layers (the BO objective c_τ).
    pub cost: f64,
    /// Σ_e t^lat_e under real loads.
    pub latency: f64,
    /// (layer, expert) pairs that hit case (i): memory shortfall.
    pub memory_violations: Vec<(usize, usize)>,
    /// (layer, expert) pairs that hit case (ii): direct payload overflow.
    pub payload_violations: Vec<(usize, usize)>,
}

impl ServeOutcome {
    pub fn fully_feasible(&self) -> bool {
        self.memory_violations.is_empty() && self.payload_violations.is_empty()
    }
}

/// Evaluate `policy` (sized from *predicted* counts) under the *real* routed
/// counts: replace each expert plan's tokens with the real d_{e,i}, keep the
/// memory/replica/method/β decisions, and re-price. Experts whose real load
/// violates (12c) pay the thrash factor on their run time.
pub fn serve_with_real_counts(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    policy: &DeploymentPolicy,
    real_tokens: &[Vec<u64>],
    warm: bool,
) -> ServeOutcome {
    let mut cost = 0.0;
    let mut latency = 0.0;
    let mut memory_violations = Vec::new();
    let mut payload_violations = Vec::new();

    for (e, plan) in policy.layers.iter().enumerate() {
        let mut real_plan = plan.clone();
        for (i, ep) in real_plan.experts.iter_mut().enumerate() {
            ep.tokens = real_tokens[e][i];
        }
        // Per-expert accounting with violation penalties.
        let mut layer_cost = 0.0;
        let mut max_finish = 0.0f64;
        for (i, ep) in real_plan.experts.iter().enumerate() {
            if ep.tokens == 0 {
                continue;
            }
            let mem_bad = !memory_feasible(spec, e, ep);
            if mem_bad {
                memory_violations.push((e, i));
            }
            let payload_bad =
                plan.method == CommMethod::Direct && !direct_feasible(cfg, spec, ep);
            if payload_bad {
                payload_violations.push((e, i));
            }
            let t_rep = effective_replica_time(
                cfg, spec, e, ep, plan.method, plan.beta, warm, mem_bad, payload_bad,
            );
            layer_cost += cfg.run_cost(ep.mem_mb, ep.replicas as f64 * t_rep)
                + ep.replicas as f64 * cfg.price_per_invocation;
            max_finish = max_finish.max(t_rep);
        }
        cost += layer_cost;
        // Latency: reuse the analytic layer latency on the real plan, then
        // account for thrash on the straggler.
        let base_lat = crate::comm::layer_latency(cfg, spec, e, &real_plan, warm);
        let worst_clean = real_plan
            .experts
            .iter()
            .map(|ep| replica_time(cfg, spec, e, ep, plan.method, plan.beta, warm))
            .fold(0.0, f64::max);
        latency += base_lat + (max_finish - worst_clean).max(0.0);
    }

    ServeOutcome {
        cost,
        latency,
        memory_violations,
        payload_violations,
    }
}

/// Generalization of [`serve_with_real_counts`] to the instance-lifecycle
/// model: each replica's warm/cold start is decided by
/// `warm_of(layer, expert, replica)` — derived from a
/// `platform::lifecycle::WarmPool`'s virtual clock by the traffic simulator
/// — instead of one global flag. With every replica warm this reproduces
/// `serve_with_real_counts(.., warm = true)` to within floating-point
/// rounding (the cross-validation test in `tests/traffic.rs` pins the
/// equivalence at 1e-6 relative error).
///
/// Latency model: the all-warm analytic layer latency is the baseline, and
/// the straggler's excess (cold starts, thrash, payload fallback) is charged
/// on top — mirroring how `serve_with_real_counts` charges penalties.
pub fn serve_with_warmness(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    policy: &DeploymentPolicy,
    real_tokens: &[Vec<u64>],
    warm_of: &mut dyn FnMut(usize, usize, usize) -> bool,
) -> ServeOutcome {
    serve_with_warmness_detailed(cfg, spec, policy, real_tokens, warm_of).outcome
}

/// [`serve_with_warmness`] plus the per-replica execution breakdown the
/// traffic simulator's FIFO instance queues schedule: each invoked replica's
/// busy time, keyed by `(layer, expert, replica)` in deterministic
/// (layer-major) order.
#[derive(Debug, Clone)]
pub struct ReplicaServeOutcome {
    pub outcome: ServeOutcome,
    /// `((layer, expert, replica), execution_secs)` for every replica of
    /// every expert with a non-zero real load.
    pub replica_times: Vec<((usize, usize, usize), f64)>,
}

/// One MoE layer's serving outcome under the instance-lifecycle model — the
/// per-layer decomposition behind [`serve_with_warmness_detailed`] and the
/// unit the event engine's layer-pipelined dispatch schedules: a request's
/// layer *k+1* is dispatched when layer *k*'s `max_service` straggler plus
/// its non-replica `latency` tail have completed.
#[derive(Debug, Clone, Copy)]
pub struct LayerServe {
    /// Billed cost of the layer (busy-time metered across replicas).
    pub cost: f64,
    /// MoE-E2E latency contribution t^lat of the layer.
    pub latency: f64,
    /// Slowest replica's execution time; `latency − max_service` is the
    /// non-replica tail (scatter/gather stages, next-layer load) that rides
    /// after the last replica finish — it is ≥ 0 by construction.
    pub max_service: f64,
}

/// Serve one MoE layer whose expert plans already carry the *real* routed
/// token counts, with per-replica warmness decided by `warm_of` (queried in
/// expert-major, replica-minor order). Appends each invoked replica's
/// `((layer, expert, replica), execution_secs)` to `replica_times` and any
/// constraint violations to the caller's ledgers. The accounting is
/// identical to the flat path: summing `cost`/`latency` over layers
/// reproduces [`serve_with_warmness`] bit-for-bit.
#[allow(clippy::too_many_arguments)]
pub fn serve_layer_with_warmness(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    layer: usize,
    plan: &LayerPlan,
    warm_of: &mut dyn FnMut(usize, usize, usize) -> bool,
    replica_times: &mut Vec<((usize, usize, usize), f64)>,
    memory_violations: &mut Vec<(usize, usize)>,
    payload_violations: &mut Vec<(usize, usize)>,
) -> LayerServe {
    let mut layer_cost = 0.0;
    let mut max_finish = 0.0f64;
    for (i, ep) in plan.experts.iter().enumerate() {
        if ep.tokens == 0 {
            continue;
        }
        // Constraint checks are plan-level, exactly as in the flat path.
        let mem_bad = !memory_feasible(spec, layer, ep);
        if mem_bad {
            memory_violations.push((layer, i));
        }
        let payload_bad =
            plan.method == CommMethod::Direct && !direct_feasible(cfg, spec, ep);
        if payload_bad {
            payload_violations.push((layer, i));
        }
        let mut busy = 0.0;
        for g in 0..ep.replicas {
            let warm = warm_of(layer, i, g);
            let t_rep = effective_replica_time(
                cfg, spec, layer, ep, plan.method, plan.beta, warm, mem_bad, payload_bad,
            );
            busy += t_rep;
            max_finish = max_finish.max(t_rep);
            replica_times.push(((layer, i, g), t_rep));
        }
        layer_cost +=
            cfg.run_cost(ep.mem_mb, busy) + ep.replicas as f64 * cfg.price_per_invocation;
    }
    let base_lat = crate::comm::layer_latency(cfg, spec, layer, plan, true);
    let worst_clean = plan
        .experts
        .iter()
        .map(|ep| replica_time(cfg, spec, layer, ep, plan.method, plan.beta, true))
        .fold(0.0, f64::max);
    LayerServe {
        cost: layer_cost,
        latency: base_lat + (max_finish - worst_clean).max(0.0),
        max_service: max_finish,
    }
}

/// Primary implementation behind [`serve_with_warmness`]: identical
/// accounting, but also returns each replica's execution time so callers
/// (the queued epoch loop) can reserve per-instance busy windows. A thin
/// layer-by-layer fold of [`serve_layer_with_warmness`].
pub fn serve_with_warmness_detailed(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    policy: &DeploymentPolicy,
    real_tokens: &[Vec<u64>],
    warm_of: &mut dyn FnMut(usize, usize, usize) -> bool,
) -> ReplicaServeOutcome {
    let mut cost = 0.0;
    let mut latency = 0.0;
    let mut memory_violations = Vec::new();
    let mut payload_violations = Vec::new();
    let mut replica_times: Vec<((usize, usize, usize), f64)> = Vec::new();

    for (e, plan) in policy.layers.iter().enumerate() {
        let mut real_plan = plan.clone();
        for (i, ep) in real_plan.experts.iter_mut().enumerate() {
            ep.tokens = real_tokens[e][i];
        }
        let ls = serve_layer_with_warmness(
            cfg,
            spec,
            e,
            &real_plan,
            warm_of,
            &mut replica_times,
            &mut memory_violations,
            &mut payload_violations,
        );
        cost += ls.cost;
        latency += ls.latency;
    }

    ReplicaServeOutcome {
        outcome: ServeOutcome {
            cost,
            latency,
            memory_violations,
            payload_violations,
        },
        replica_times,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{ExpertPlan, LayerPlan};
    use crate::model::ModelPreset;

    fn policy(mem: u64, replicas: usize, tokens: u64, method: CommMethod) -> DeploymentPolicy {
        DeploymentPolicy {
            layers: (0..2)
                .map(|_| LayerPlan {
                    method,
                    beta: 64,
                    experts: vec![ExpertPlan { mem_mb: mem, replicas, tokens }; 4],
                })
                .collect(),
        }
    }

    #[test]
    fn matched_prediction_no_violations() {
        let cfg = PlatformConfig::default();
        let mut spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        spec.layers.truncate(2);
        let pol = policy(3072, 1, 1000, CommMethod::Indirect);
        let real = vec![vec![1000u64; 4]; 2];
        let out = serve_with_real_counts(&cfg, &spec, &pol, &real, true);
        assert!(out.fully_feasible());
        assert!(out.cost > 0.0 && out.latency > 0.0);
    }

    #[test]
    fn underprediction_triggers_memory_case() {
        let cfg = PlatformConfig::default();
        let mut spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        spec.layers.truncate(2);
        // Sized for 100 tokens at 768MB, but reality sends 60k tokens:
        // itrm(60k) ≈ 60k·3072·... > 768MB → case (i).
        let pol = policy(768, 1, 100, CommMethod::Indirect);
        let real = vec![vec![60_000u64; 4]; 2];
        let out = serve_with_real_counts(&cfg, &spec, &pol, &real, true);
        assert!(!out.memory_violations.is_empty());
        // Thrash must make it pricier than a correctly-sized run.
        let sized = policy(3072, 8, 60_000, CommMethod::Indirect);
        let out_sized = serve_with_real_counts(&cfg, &spec, &sized, &real, true);
        assert!(out.latency > out_sized.latency);
    }

    #[test]
    fn payload_overflow_detected_under_direct() {
        let cfg = PlatformConfig::default();
        let mut spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        spec.layers.truncate(2);
        let pol = policy(3072, 1, 100, CommMethod::Direct);
        // Real load: 4096 tokens × 3072B × 1.4 > 6MB.
        let real = vec![vec![4096u64; 4]; 2];
        let out = serve_with_real_counts(&cfg, &spec, &pol, &real, true);
        assert!(!out.payload_violations.is_empty());
    }

    #[test]
    fn warmness_all_warm_degenerates_to_flat_path() {
        let cfg = PlatformConfig::default();
        let mut spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        spec.layers.truncate(2);
        let mut pol = policy(3072, 2, 1000, CommMethod::Indirect);
        pol.layers[1].experts[0].replicas = 4;
        let real = vec![vec![1400, 900, 300, 100], vec![2000, 500, 100, 100]];
        let flat = serve_with_real_counts(&cfg, &spec, &pol, &real, true);
        let lifecycle = serve_with_warmness(&cfg, &spec, &pol, &real, &mut |_, _, _| true);
        let rel_c = (flat.cost - lifecycle.cost).abs() / flat.cost;
        let rel_l = (flat.latency - lifecycle.latency).abs() / flat.latency;
        assert!(rel_c < 1e-9, "cost {} vs {}", flat.cost, lifecycle.cost);
        assert!(rel_l < 1e-9, "latency {} vs {}", flat.latency, lifecycle.latency);
    }

    #[test]
    fn cold_replicas_cost_and_delay_more() {
        let cfg = PlatformConfig::default();
        let mut spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        spec.layers.truncate(2);
        let pol = policy(3072, 2, 1000, CommMethod::Indirect);
        let real = vec![vec![1000u64; 4]; 2];
        let warm = serve_with_warmness(&cfg, &spec, &pol, &real, &mut |_, _, _| true);
        let mixed = serve_with_warmness(&cfg, &spec, &pol, &real, &mut |_, _, g| g == 0);
        let cold = serve_with_warmness(&cfg, &spec, &pol, &real, &mut |_, _, _| false);
        assert!(warm.cost < mixed.cost && mixed.cost < cold.cost);
        assert!(warm.latency <= mixed.latency && mixed.latency <= cold.latency);
    }

    #[test]
    fn detailed_breakdown_matches_outcome_and_lists_every_replica() {
        let cfg = PlatformConfig::default();
        let mut spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        spec.layers.truncate(2);
        let mut pol = policy(3072, 2, 1000, CommMethod::Indirect);
        pol.layers[0].experts[3].replicas = 3;
        let real = vec![vec![1400, 900, 0, 100], vec![2000, 500, 100, 100]];
        let mut warm_of = |_: usize, _: usize, g: usize| g == 0;
        let detailed = serve_with_warmness_detailed(&cfg, &spec, &pol, &real, &mut warm_of);
        let flat = serve_with_warmness(&cfg, &spec, &pol, &real, &mut warm_of);
        assert_eq!(detailed.outcome.cost, flat.cost);
        assert_eq!(detailed.outcome.latency, flat.latency);
        // Layer 0: experts 0,1 (2 replicas each) + expert 3 (3 replicas);
        // expert 2 has zero real load. Layer 1: 4 experts × 2 replicas.
        assert_eq!(detailed.replica_times.len(), 2 + 2 + 3 + 8);
        for &((l, e, g), t) in &detailed.replica_times {
            assert!(t > 0.0, "replica ({l},{e},{g}) has non-positive time {t}");
            assert!(real[l][e] > 0);
        }
        // Warm replica (g=0) runs faster than its cold sibling (g=1).
        let time_of = |key: (usize, usize, usize)| {
            detailed
                .replica_times
                .iter()
                .find(|(k, _)| *k == key)
                .map(|(_, t)| *t)
                .unwrap()
        };
        assert!(time_of((0, 0, 0)) < time_of((0, 0, 1)));
    }

    #[test]
    fn layer_decomposition_sums_to_detailed_path_with_nonnegative_tails() {
        let cfg = PlatformConfig::default();
        let mut spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        spec.layers.truncate(2);
        let mut pol = policy(3072, 2, 1000, CommMethod::Indirect);
        pol.layers[1].experts[2].replicas = 3;
        let real = vec![vec![1400, 900, 0, 100], vec![2000, 500, 100, 100]];
        let mut warm_of = |_: usize, _: usize, g: usize| g == 0;
        let whole = serve_with_warmness_detailed(&cfg, &spec, &pol, &real, &mut warm_of);

        let mut cost = 0.0;
        let mut latency = 0.0;
        let mut times = Vec::new();
        let mut mem_v = Vec::new();
        let mut pay_v = Vec::new();
        for (e, plan) in pol.layers.iter().enumerate() {
            let mut real_plan = plan.clone();
            for (i, ep) in real_plan.experts.iter_mut().enumerate() {
                ep.tokens = real[e][i];
            }
            let ls = serve_layer_with_warmness(
                &cfg, &spec, e, &real_plan, &mut warm_of, &mut times, &mut mem_v, &mut pay_v,
            );
            // The pipelining invariant: every layer's non-replica tail
            // (latency − straggler service) is non-negative, so chaining
            // layer completions never moves a completion backwards.
            assert!(
                ls.latency >= ls.max_service,
                "layer {e}: latency {} < max_service {}",
                ls.latency,
                ls.max_service
            );
            cost += ls.cost;
            latency += ls.latency;
        }
        assert_eq!(cost, whole.outcome.cost, "per-layer cost sum drifted");
        assert_eq!(latency, whole.outcome.latency, "per-layer latency sum drifted");
        assert_eq!(times, whole.replica_times);
        assert_eq!(mem_v, whole.outcome.memory_violations);
        assert_eq!(pay_v, whole.outcome.payload_violations);
    }

    #[test]
    fn cost_monotone_in_load() {
        let cfg = PlatformConfig::default();
        let mut spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        spec.layers.truncate(2);
        let pol = policy(3072, 1, 1000, CommMethod::Indirect);
        let light = serve_with_real_counts(&cfg, &spec, &pol, &vec![vec![500; 4]; 2], true);
        let heavy = serve_with_real_counts(&cfg, &spec, &pol, &vec![vec![2000; 4]; 2], true);
        assert!(heavy.cost > light.cost);
    }
}
