//! The BO framework (§IV-B, Alg. 2): learn the key-value dataset table that
//! yields the cheapest deployment, using billed-cost feedback.
//!
//!  - [`gp`]         — Gaussian-process surrogate over trial features.
//!  - [`eps_greedy`] — the paper's multi-dimensional ε-greedy acquisition
//!                     with the decay schedule ε_τ = ε₀/(1+ρτ) and the
//!                     case-dependent slow-downs (ρ₁/ρ₂/ρ₃).
//!  - [`acquisition`]— baselines: single-ε greedy, random, TPE.
//!  - [`feedback`]   — serving-cost evaluation of a deployment under real
//!                     routing (memory-overflow thrash penalty included).
//!  - [`algorithm`]  — Alg. 2 itself.

pub mod acquisition;
pub mod algorithm;
pub mod eps_greedy;
pub mod feedback;
pub mod gp;

pub use algorithm::{BoAlgorithm, BoOutcome, TrialRecord};
pub use eps_greedy::EpsSchedule;

use crate::gating::features::FeatKey;

/// One BO variable: a key-value pair of the dataset table —
/// z = (token features f, MoE layer e, expert i), value v = count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoVar {
    pub layer: usize,
    pub key: FeatKey,
    pub expert: u8,
    pub value: f64,
}

/// Acquisition strategies under comparison (Fig. 13).
pub trait Acquisition {
    /// Propose the next trial's Q variables given the trial history and the
    /// current ranges (𝕃 = limited, ℙ = normal).
    fn propose(
        &mut self,
        ctx: &mut ProposeCtx,
    ) -> Vec<BoVar>;

    /// Receive the trial's feedback case (Alg. 2 line 20). Only the paper's
    /// multi-dimensional ε schedule reacts; baselines ignore it.
    fn feedback(&mut self, _case: eps_greedy::FeedbackCase, _tau: usize) {}

    fn name(&self) -> &'static str;
}

/// Everything an acquisition may draw on.
pub struct ProposeCtx<'a> {
    pub history: &'a [TrialRecord],
    /// Limited range 𝕃: token IDs flagged by prediction feedback this trial.
    pub limited_tokens: &'a [u32],
    /// Normal range ℙ: vocabulary size, position buckets, experts per layer.
    pub vocab: usize,
    pub experts_per_layer: &'a [usize],
    pub q: usize,
    pub trial: usize,
    pub rng: &'a mut crate::util::rng::Rng,
}

impl ProposeCtx<'_> {
    /// Draw a uniformly random variable from the normal range ℙ.
    pub fn random_var(&mut self) -> BoVar {
        let layer = self.rng.index(self.experts_per_layer.len());
        let expert = self.rng.index(self.experts_per_layer[layer]) as u8;
        let token = self.rng.index(self.vocab) as u32;
        let pos_bucket = self.rng.index(crate::gating::features::POS_BUCKETS as usize) as u32;
        let attn = self.rng.index(self.vocab) as u32;
        let value = 1.0 + self.rng.index(16) as f64;
        BoVar {
            layer,
            key: FeatKey::from_parts(token, pos_bucket, attn),
            expert,
            value,
        }
    }

    /// Draw a variable whose token ID is restricted to 𝕃 (values stay in
    /// positive integers, per the paper's range definition).
    pub fn limited_var(&mut self) -> BoVar {
        if self.limited_tokens.is_empty() {
            return self.random_var();
        }
        let token = *self.rng.choose(self.limited_tokens);
        let layer = self.rng.index(self.experts_per_layer.len());
        let expert = self.rng.index(self.experts_per_layer[layer]) as u8;
        let pos_bucket = self.rng.index(crate::gating::features::POS_BUCKETS as usize) as u32;
        let attn = self.rng.index(self.vocab) as u32;
        let value = 1.0 + self.rng.index(32) as f64;
        BoVar {
            layer,
            key: FeatKey::from_parts(token, pos_bucket, attn),
            expert,
            value,
        }
    }

    /// Best historical variable set (exploitation target).
    pub fn best_vars(&self) -> Option<&[BoVar]> {
        self.history
            .iter()
            .min_by(|a, b| a.cost.partial_cmp(&b.cost).unwrap())
            .map(|t| t.vars.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn random_var_in_ranges() {
        let mut rng = Rng::new(1);
        let history = vec![];
        let limited = vec![];
        let experts = vec![4usize; 3];
        let mut ctx = ProposeCtx {
            history: &history,
            limited_tokens: &limited,
            vocab: 100,
            experts_per_layer: &experts,
            q: 10,
            trial: 0,
            rng: &mut rng,
        };
        for _ in 0..100 {
            let v = ctx.random_var();
            assert!(v.layer < 3);
            assert!(v.expert < 4);
            assert!((v.key.token_id() as usize) < 100);
            assert!(v.value >= 1.0);
        }
    }

    #[test]
    fn limited_var_uses_limited_tokens() {
        let mut rng = Rng::new(2);
        let history = vec![];
        let limited = vec![42u32, 77];
        let experts = vec![4usize; 2];
        let mut ctx = ProposeCtx {
            history: &history,
            limited_tokens: &limited,
            vocab: 1000,
            experts_per_layer: &experts,
            q: 10,
            trial: 0,
            rng: &mut rng,
        };
        for _ in 0..50 {
            let v = ctx.limited_var();
            assert!(limited.contains(&v.key.token_id()));
        }
    }
}
