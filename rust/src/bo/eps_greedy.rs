//! The paper's multi-dimensional ε-greedy acquisition.
//!
//! ε ∈ ℝ^Q decays as ε_τ = ε₀ / (1 + ρτ) (Alg. 2 line 3). When feedback
//! flags a problem, the decay of the first μQ dimensions is slowed by
//! multiplying with (1 + ρ'τ), ρ' ∈ {ρ₁, ρ₂, ρ₃} depending on the case
//! (line 20) — memory shortfall slows decay the least aggressively relative
//! to ρ (ρ₁ < ρ), keeping exploration alive where deployments failed.
//! Dimensions 1..μQ explore the limited range 𝕃; dimensions μQ+1..Q explore
//! the normal range ℙ (lines 30–31).

use super::{Acquisition, BoVar, ProposeCtx};
use crate::config::BoConfig;

/// Feedback case from one trial (Alg. 2 lines 13-18).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeedbackCase {
    /// (i) real popularity needed more memory than configured.
    MemoryShortfall,
    /// (ii) direct-transfer payload exceeded.
    PayloadOverflow,
    /// (iii) all constraints satisfied.
    Feasible,
}

/// The per-dimension ε schedule.
#[derive(Debug, Clone)]
pub struct EpsSchedule {
    pub eps0: f64,
    pub rho: f64,
    pub rho1: f64,
    pub rho2: f64,
    pub rho3: f64,
    pub q: usize,
    pub mu: f64,
    /// Accumulated slow-down factor applied to dims 1..μQ.
    slowdown: f64,
}

impl EpsSchedule {
    pub fn new(cfg: &BoConfig) -> Self {
        Self {
            eps0: cfg.eps0,
            rho: cfg.rho,
            rho1: cfg.rho1,
            rho2: cfg.rho2,
            rho3: cfg.rho3,
            q: cfg.q,
            mu: cfg.mu,
            slowdown: 1.0,
        }
    }

    pub fn mu_q(&self) -> usize {
        ((self.q as f64) * self.mu).round() as usize
    }

    /// ε for dimension `dim` at trial `tau`.
    pub fn eps(&self, dim: usize, tau: usize) -> f64 {
        let base = self.eps0 / (1.0 + self.rho * tau as f64);
        if dim < self.mu_q() {
            (base * self.slowdown).min(1.0)
        } else {
            base
        }
    }

    /// Apply one trial's feedback (line 20): ε_{1:μQ} ·= (1 + ρ'τ).
    pub fn apply_feedback(&mut self, case: FeedbackCase, tau: usize) {
        let rho_p = match case {
            FeedbackCase::MemoryShortfall => self.rho1,
            FeedbackCase::PayloadOverflow => self.rho2,
            FeedbackCase::Feasible => self.rho3,
        };
        self.slowdown *= 1.0 + rho_p * tau as f64;
        // Keep the effective ε bounded (the theory only needs ε ≤ ε0 in the
        // tail; unbounded slow-down would stall convergence forever).
        let cap = 1.0 / self.eps0;
        self.slowdown = self.slowdown.min(cap * 4.0);
    }

    /// Theorem 2's convergence horizon: the τ beyond which even the slowest
    /// dimension's ε is below δ.
    pub fn convergence_bound(&self, delta: f64) -> usize {
        // max ε decays at worst as ε0·(1+ρ1·τ)/(1+ρ·τ) → needs
        // τ > (1+ρ)/(ρ-ρ1) · (1 - δ/ε0) approximately (paper Thm 2).
        let frac = (1.0 + self.rho) / (self.rho - self.rho1);
        (frac * (1.0 - delta / self.eps0)).ceil().max(0.0) as usize
    }
}

/// The paper's acquisition: multi-dimensional ε-GS over (𝕃, ℙ).
pub struct MultiEpsGreedy {
    pub schedule: EpsSchedule,
}

impl MultiEpsGreedy {
    pub fn new(cfg: &BoConfig) -> Self {
        Self {
            schedule: EpsSchedule::new(cfg),
        }
    }
}

impl Acquisition for MultiEpsGreedy {
    fn propose(&mut self, ctx: &mut ProposeCtx) -> Vec<BoVar> {
        let q = ctx.q;
        let mu_q = self.schedule.mu_q().min(q);
        let best: Vec<BoVar> = ctx.best_vars().map(|v| v.to_vec()).unwrap_or_default();
        let mut out = Vec::with_capacity(q);
        for dim in 0..q {
            let eps = self.schedule.eps(dim, ctx.trial);
            let explore = ctx.rng.chance(eps);
            if explore || best.is_empty() {
                if dim < mu_q {
                    out.push(ctx.limited_var());
                } else {
                    out.push(ctx.random_var());
                }
            } else {
                // Exploit: keep the best trial's variable for this dim.
                out.push(best[dim.min(best.len() - 1)]);
            }
        }
        out
    }

    fn feedback(&mut self, case: FeedbackCase, tau: usize) {
        self.schedule.apply_feedback(case, tau);
    }

    fn name(&self) -> &'static str {
        "multi-eps-gs"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cfg() -> BoConfig {
        BoConfig::default()
    }

    #[test]
    fn eps_decays_over_trials() {
        let s = EpsSchedule::new(&cfg());
        assert!(s.eps(900, 0) > s.eps(900, 5));
        assert!(s.eps(900, 5) > s.eps(900, 50));
    }

    #[test]
    fn feedback_slows_low_dims_only() {
        let mut s = EpsSchedule::new(&cfg());
        let before_low = s.eps(0, 10);
        let before_high = s.eps(s.q - 1, 10);
        s.apply_feedback(FeedbackCase::MemoryShortfall, 10);
        assert!(s.eps(0, 10) > before_low, "low dims slowed");
        assert_eq!(s.eps(s.q - 1, 10), before_high, "high dims unchanged");
    }

    #[test]
    fn case_ordering_matches_paper() {
        // Memory shortfall slows decay more than payload overflow, which
        // slows more than the feasible case (ρ1 > ρ2 > ρ3 multipliers).
        let tau = 7;
        let mut a = EpsSchedule::new(&cfg());
        let mut b = EpsSchedule::new(&cfg());
        let mut c = EpsSchedule::new(&cfg());
        a.apply_feedback(FeedbackCase::MemoryShortfall, tau);
        b.apply_feedback(FeedbackCase::PayloadOverflow, tau);
        c.apply_feedback(FeedbackCase::Feasible, tau);
        assert!(a.eps(0, tau) > b.eps(0, tau));
        assert!(b.eps(0, tau) > c.eps(0, tau));
    }

    #[test]
    fn convergence_bound_finite_and_positive() {
        let s = EpsSchedule::new(&cfg());
        let bound = s.convergence_bound(0.05);
        assert!(bound > 0 && bound < 100_000, "bound={bound}");
        // ε at the bound decays below δ in the unperturbed schedule.
        assert!(s.eps(s.q - 1, bound.max(1) * 4) < 0.2);
    }

    #[test]
    fn proposes_q_vars() {
        let mut acq = MultiEpsGreedy::new(&cfg());
        let mut rng = Rng::new(5);
        let history = vec![];
        let limited = vec![3u32, 9];
        let experts = vec![4usize; 2];
        let mut ctx = ProposeCtx {
            history: &history,
            limited_tokens: &limited,
            vocab: 64,
            experts_per_layer: &experts,
            q: 100,
            trial: 0,
            rng: &mut rng,
        };
        let vars = acq.propose(&mut ctx);
        assert_eq!(vars.len(), 100);
    }
}
