//! Alg. 2: Bayesian Optimization with multi-dimensional ε-greedy search.
//!
//! Each trial: (1) write the proposed Q key-value pairs into the dataset
//! table, (2) re-predict expert selections (Eq. 2), (3) deploy optimally
//! (three fixed-a MIQCP solves + ODS), (4) serve evaluation batches under
//! real routing to obtain the billed cost c_τ and the feedback cases
//! (i)/(ii)/(iii), (5) update the ε schedule and the limited range 𝕃, and
//! (6) acquire the next trial's variables. Converges when the running
//! minimum changes less than ζ over λ consecutive trials (Theorem 2 bounds
//! the horizon).

use super::acquisition::gp_filter;
use super::eps_greedy::FeedbackCase;
use super::feedback::serve_with_real_counts;
use super::{Acquisition, BoVar};
use crate::config::{BoConfig, DeployConfig, PlatformConfig};
use crate::deploy::ods::ods_full;
use crate::deploy::DeployProblem;
use crate::gating::SimGate;
use crate::model::MoeModelSpec;
use crate::predictor::eval::{predicted_counts, real_counts};
use crate::predictor::BayesPredictor;
use crate::util::rng::Rng;
use crate::workload::Batch;

/// One completed BO trial.
#[derive(Debug, Clone)]
pub struct TrialRecord {
    pub vars: Vec<BoVar>,
    /// Billed cost of all MoE layers averaged over the trial's batches.
    pub cost: f64,
    /// Fig. 10-style prediction error at this trial's table state.
    pub prediction_error: f64,
    pub feasible: bool,
}

/// Final result of a BO run.
#[derive(Debug, Clone)]
pub struct BoOutcome {
    pub best_cost: f64,
    pub best_trial: usize,
    pub best_prediction_error: f64,
    pub history: Vec<TrialRecord>,
    pub converged: bool,
    pub iterations: usize,
}

/// The Alg. 2 driver. Owns the predictor (whose table it adjusts per trial,
/// with undo) and evaluates against the simulated gate's ground truth.
pub struct BoAlgorithm<'a> {
    pub platform: &'a PlatformConfig,
    pub deploy_cfg: &'a DeployConfig,
    pub bo_cfg: BoConfig,
    pub spec: &'a MoeModelSpec,
    pub gate: &'a SimGate,
    pub predictor: BayesPredictor,
    pub eval_batches: Vec<Batch>,
    /// Per-fixed-a solver time limit inside each trial.
    pub solver_time_limit: f64,
}

impl<'a> BoAlgorithm<'a> {
    /// Evaluate the current table state: predict → deploy → serve real.
    /// Returns (cost, prediction_error, feasible, memory/payload cases,
    /// mispredicted token ids).
    fn evaluate(&self) -> EvalResult {
        let mut total_cost = 0.0;
        let mut total_err = 0.0;
        let mut n = 0.0;
        let mut any_mem = false;
        let mut any_payload = false;
        let mut feasible = true;
        let mut limited: Vec<u32> = Vec::new();

        for batch in &self.eval_batches {
            let pred = predicted_counts(self.gate, &self.predictor, batch);
            let real = real_counts(self.gate, batch);
            let problem = DeployProblem {
                cfg: self.platform,
                spec: self.spec,
                tokens: pred.clone(),
                t_limit: self.deploy_cfg.t_limit,
                max_replicas: self.deploy_cfg.max_replicas,
                beta_grid: self.deploy_cfg.beta_grid.clone(),
                warm: true,
            };
            let Some(ods) = ods_full(&problem, self.solver_time_limit) else {
                feasible = false;
                continue;
            };
            let outcome =
                serve_with_real_counts(self.platform, self.spec, &ods.policy, &real, true);
            total_cost += outcome.cost;
            any_mem |= !outcome.memory_violations.is_empty();
            any_payload |= !outcome.payload_violations.is_empty();
            feasible &= ods.feasible && outcome.fully_feasible();

            // Prediction error (Fig. 10 metric) + limited-range collection
            // (Alg. 2 lines 11-12): batches where some expert misses by > α
            // contribute their frequent token ids to 𝕃.
            let mut batch_err = 0.0;
            let mut layers_off = 0usize;
            for (p_l, r_l) in pred.iter().zip(&real) {
                let diff: f64 = p_l
                    .iter()
                    .zip(r_l)
                    .map(|(&p, &r)| (p as f64 - r as f64).abs())
                    .sum::<f64>()
                    / p_l.len() as f64;
                batch_err += diff;
                if p_l
                    .iter()
                    .zip(r_l)
                    .any(|(&p, &r)| (p as f64 - r as f64).abs() > self.bo_cfg.alpha)
                {
                    layers_off += 1;
                }
            }
            total_err += batch_err / pred.len() as f64;
            n += 1.0;
            if layers_off > 0 {
                let mut freq: std::collections::HashMap<u32, u32> = std::collections::HashMap::new();
                for (t, _, _) in batch.tokens() {
                    *freq.entry(t).or_default() += 1;
                }
                let mut ids: Vec<(u32, u32)> = freq.into_iter().collect();
                ids.sort_by(|a, b| b.1.cmp(&a.1));
                limited.extend(ids.into_iter().take(256).map(|(t, _)| t));
            }
        }
        EvalResult {
            cost: if n > 0.0 { total_cost / n } else { f64::INFINITY },
            prediction_error: if n > 0.0 { total_err / n } else { f64::INFINITY },
            feasible,
            any_mem,
            any_payload,
            limited,
        }
    }

    /// Apply a variable set to the table, returning the undo log.
    fn apply_vars(&mut self, vars: &[BoVar]) -> Vec<(usize, crate::gating::features::FeatKey, u8, f64)> {
        let mut undo = Vec::with_capacity(vars.len());
        for v in vars {
            let prev = self.predictor.table.get(v.layer, v.key, v.expert);
            undo.push((v.layer, v.key, v.expert, prev));
            self.predictor.table.set(v.layer, v.key, v.expert, v.value);
        }
        undo
    }

    fn revert(&mut self, undo: Vec<(usize, crate::gating::features::FeatKey, u8, f64)>) {
        // Reverse order so repeated keys restore correctly.
        for (layer, key, expert, prev) in undo.into_iter().rev() {
            self.predictor.table.set(layer, key, expert, prev);
        }
    }

    /// Cost/error of the *unadjusted* predictor (the "no BO" baseline of
    /// Fig. 13).
    pub fn evaluate_no_bo(&self) -> (f64, f64) {
        let r = self.evaluate();
        (r.cost, r.prediction_error)
    }

    /// Run Alg. 2 with the given acquisition. `use_gp_filter` enables the
    /// GP-surrogate screening of proposals (on for the paper's method).
    pub fn run(
        &mut self,
        acq: &mut dyn Acquisition,
        use_gp_filter: bool,
        seed: u64,
    ) -> BoOutcome {
        let mut rng = Rng::new(seed);
        let mut history: Vec<TrialRecord> = Vec::new();
        let mut limited_tokens: Vec<u32> = Vec::new();
        let mut best_cost = f64::INFINITY;
        let mut best_trial = 0usize;
        let mut best_err = f64::INFINITY;
        let mut min_cost_trace: Vec<f64> = Vec::new();
        let mut converged = false;
        let experts_per_layer: Vec<usize> = (0..self.spec.num_moe_layers())
            .map(|e| self.spec.experts_at(e))
            .collect();

        let mut tau = 0usize;
        while tau < self.bo_cfg.max_iters {
            // Lines 30-31: acquire variables (proposals screened by the GP
            // surrogate when enabled).
            let vars = {
                let n_proposals = if use_gp_filter && history.len() >= 3 { 3 } else { 1 };
                let mut proposals = Vec::with_capacity(n_proposals);
                for _ in 0..n_proposals {
                    let mut ctx = super::ProposeCtx {
                        history: &history,
                        limited_tokens: &limited_tokens,
                        vocab: self.spec.vocab,
                        experts_per_layer: &experts_per_layer,
                        q: self.bo_cfg.q,
                        trial: tau,
                        rng: &mut rng,
                    };
                    proposals.push(acq.propose(&mut ctx));
                }
                gp_filter(proposals, &history)
            };

            // Line 4: write the table; lines 5-28: evaluate.
            let undo = self.apply_vars(&vars);
            let result = self.evaluate();
            self.revert(undo);

            // Lines 13-20: feedback case → ε schedule adjustment (only the
            // multi-ε acquisition has the per-case schedule).
            let case = if result.any_mem {
                FeedbackCase::MemoryShortfall
            } else if result.any_payload {
                FeedbackCase::PayloadOverflow
            } else {
                FeedbackCase::Feasible
            };
            acq.feedback(case, tau);
            limited_tokens = result.limited;

            if result.cost < best_cost {
                best_cost = result.cost;
                best_trial = tau;
                best_err = result.prediction_error;
            }
            history.push(TrialRecord {
                vars,
                cost: result.cost,
                prediction_error: result.prediction_error,
                feasible: result.feasible,
            });
            min_cost_trace.push(best_cost);

            // Line 33: convergence over λ consecutive iterations.
            let lam = self.bo_cfg.lambda;
            if min_cost_trace.len() > lam {
                let then = min_cost_trace[min_cost_trace.len() - 1 - lam];
                let now = *min_cost_trace.last().unwrap();
                if (then - now).abs() <= self.bo_cfg.zeta * then.abs().max(1e-12) {
                    converged = true;
                    tau += 1;
                    break;
                }
            }
            tau += 1;
        }

        BoOutcome {
            best_cost,
            best_trial,
            best_prediction_error: best_err,
            history,
            converged,
            iterations: tau,
        }
    }

    /// Materialize the best trial's table adjustment permanently.
    pub fn commit_best(&mut self, outcome: &BoOutcome) {
        if let Some(best) = outcome.history.get(outcome.best_trial) {
            let vars = best.vars.clone();
            let _ = self.apply_vars(&vars);
        }
    }
}

struct EvalResult {
    cost: f64,
    prediction_error: f64,
    feasible: bool,
    any_mem: bool,
    any_payload: bool,
    limited: Vec<u32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bo::acquisition::RandomAcq;
    use crate::config::workload::CorpusPreset;
    use crate::model::ModelPreset;
    use crate::predictor::profile::profile_batches;
    use crate::workload::{Corpus, RequestGenerator};

    fn build<'a>(
        platform: &'a PlatformConfig,
        deploy_cfg: &'a DeployConfig,
        spec: &'a MoeModelSpec,
        gate: &'a SimGate,
    ) -> BoAlgorithm<'a> {
        let corpus = Corpus::new(CorpusPreset::Enwik8, 1);
        let mut gen = RequestGenerator::new(corpus, 5, 768);
        let profile = gen.profile_set(8);
        let r = profile_batches(gate, &profile);
        let eval_batches = vec![gen.next_batch(), gen.next_batch()];
        let mut bo_cfg = BoConfig::default();
        bo_cfg.q = 64;
        bo_cfg.max_iters = 6;
        bo_cfg.batches_per_trial = 2;
        BoAlgorithm {
            platform,
            deploy_cfg,
            bo_cfg,
            spec,
            gate,
            predictor: BayesPredictor::new(r.table, r.prior),
            eval_batches,
            solver_time_limit: 1.0,
        }
    }

    #[test]
    fn bo_runs_and_tracks_best() {
        let platform = PlatformConfig::default();
        let mut deploy_cfg = DeployConfig::default();
        deploy_cfg.t_limit = 2000.0;
        let spec = ModelPreset::TinyMoe.spec();
        let gate = SimGate::new(&spec, 7);
        let mut bo = build(&platform, &deploy_cfg, &spec, &gate);
        let mut acq = crate::bo::eps_greedy::MultiEpsGreedy::new(&bo.bo_cfg);
        let outcome = bo.run(&mut acq, true, 99);
        assert!(!outcome.history.is_empty());
        assert!(outcome.best_cost.is_finite());
        assert!(outcome.best_cost <= outcome.history[0].cost + 1e-12);
        // The running-min trace is non-increasing by construction.
        let mut best = f64::INFINITY;
        for t in &outcome.history {
            best = best.min(t.cost);
        }
        assert_eq!(best, outcome.best_cost);
    }

    #[test]
    fn table_restored_between_trials() {
        let platform = PlatformConfig::default();
        let deploy_cfg = DeployConfig::default();
        let spec = ModelPreset::TinyMoe.spec();
        let gate = SimGate::new(&spec, 7);
        let mut bo = build(&platform, &deploy_cfg, &spec, &gate);
        let before = bo.predictor.table.entries().len();
        let mut acq = RandomAcq;
        let _ = bo.run(&mut acq, false, 3);
        // Undo must leave only zero-valued phantom keys at most; entry count
        // of positive-count entries must be unchanged.
        let after = bo.predictor.table.entries().len();
        assert_eq!(before, after);
    }

    #[test]
    fn commit_best_changes_table() {
        let platform = PlatformConfig::default();
        let deploy_cfg = DeployConfig::default();
        let spec = ModelPreset::TinyMoe.spec();
        let gate = SimGate::new(&spec, 7);
        let mut bo = build(&platform, &deploy_cfg, &spec, &gate);
        let mut acq = RandomAcq;
        let outcome = bo.run(&mut acq, false, 3);
        let before = bo.predictor.table.entries().len();
        bo.commit_best(&outcome);
        assert!(bo.predictor.table.entries().len() >= before);
    }
}
