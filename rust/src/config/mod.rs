//! Typed configuration system with JSON load/save.
//!
//! Defaults reproduce the paper's evaluation setup (§V-A): AWS Lambda pricing
//! and memory options, 6 MB payload, S3-like external storage, the CPU
//! cluster baseline, and the BO hyper-parameters of Alg. 2.

pub mod platform;
pub mod workload;

pub use platform::{CpuClusterConfig, PlatformConfig};
pub use workload::WorkloadConfig;

use crate::util::json::Json;
use std::path::Path;

/// Deployment-optimizer configuration (problem (12) + Alg. 1 protocol).
#[derive(Debug, Clone)]
pub struct DeployConfig {
    /// End-to-end inference time target T_limit (seconds) — the serving SLO
    /// of constraint (12d).
    pub t_limit: f64,
    /// Wall-clock limit for one MIQCP solve (paper: 60 s per fixed-a solve
    /// under ODS, 180 s for the direct MIQCP baseline).
    pub solver_time_limit: f64,
    /// Maximal replica count G per expert (paper: 8).
    pub max_replicas: usize,
    /// Pipeline-degree search grid for β (token counts per minibatch).
    pub beta_grid: Vec<usize>,
}

impl Default for DeployConfig {
    fn default() -> Self {
        Self {
            t_limit: 600.0,
            solver_time_limit: 60.0,
            max_replicas: 8,
            beta_grid: vec![1, 4, 16, 64, 256, 1024, 2048, 4096],
        }
    }
}

impl DeployConfig {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("t_limit", Json::num(self.t_limit)),
            ("solver_time_limit", Json::num(self.solver_time_limit)),
            ("max_replicas", Json::num(self.max_replicas as f64)),
            (
                "beta_grid",
                Json::arr_u64(&self.beta_grid.iter().map(|&b| b as u64).collect::<Vec<_>>()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = Self::default();
        Ok(Self {
            t_limit: j.get_f64("t_limit").unwrap_or(d.t_limit),
            solver_time_limit: j.get_f64("solver_time_limit").unwrap_or(d.solver_time_limit),
            max_replicas: j.get_usize("max_replicas").unwrap_or(d.max_replicas),
            beta_grid: j
                .get("beta_grid")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or(d.beta_grid),
        })
    }
}

/// BO framework hyper-parameters (Alg. 2).
#[derive(Debug, Clone)]
pub struct BoConfig {
    /// Number of key-value pairs adjusted per BO trial (paper: Q = 1000).
    pub q: usize,
    /// Fraction μ of dimensions updated over the limited range 𝕃.
    pub mu: f64,
    /// Initial ε for every dimension.
    pub eps0: f64,
    /// Base decay rate ρ and the feedback-case decay rates ρ1 > ρ2 > ρ3
    /// ordering per the paper: ρ1 < ρ (memory shortfall), ρ2 < ρ1 (payload
    /// overflow), ρ3 < ρ2 (feasible).
    pub rho: f64,
    pub rho1: f64,
    pub rho2: f64,
    pub rho3: f64,
    /// Prediction-vs-real count tolerance α (line 11 of Alg. 2).
    pub alpha: f64,
    /// Convergence window λ and threshold ζ (line 33).
    pub lambda: usize,
    pub zeta: f64,
    /// Number of evaluation batches J per trial.
    pub batches_per_trial: usize,
    /// Hard cap on BO iterations (safety net beyond the ζ/λ rule).
    pub max_iters: usize,
}

impl Default for BoConfig {
    fn default() -> Self {
        Self {
            q: 1000,
            mu: 0.5,
            eps0: 0.9,
            rho: 0.5,
            rho1: 0.2,
            rho2: 0.1,
            rho3: 0.05,
            alpha: 8.0,
            lambda: 5,
            zeta: 1e-4,
            batches_per_trial: 3,
            max_iters: 40,
        }
    }
}

impl BoConfig {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("q", Json::num(self.q as f64)),
            ("mu", Json::num(self.mu)),
            ("eps0", Json::num(self.eps0)),
            ("rho", Json::num(self.rho)),
            ("rho1", Json::num(self.rho1)),
            ("rho2", Json::num(self.rho2)),
            ("rho3", Json::num(self.rho3)),
            ("alpha", Json::num(self.alpha)),
            ("lambda", Json::num(self.lambda as f64)),
            ("zeta", Json::num(self.zeta)),
            ("batches_per_trial", Json::num(self.batches_per_trial as f64)),
            ("max_iters", Json::num(self.max_iters as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = Self::default();
        Ok(Self {
            q: j.get_usize("q").unwrap_or(d.q),
            mu: j.get_f64("mu").unwrap_or(d.mu),
            eps0: j.get_f64("eps0").unwrap_or(d.eps0),
            rho: j.get_f64("rho").unwrap_or(d.rho),
            rho1: j.get_f64("rho1").unwrap_or(d.rho1),
            rho2: j.get_f64("rho2").unwrap_or(d.rho2),
            rho3: j.get_f64("rho3").unwrap_or(d.rho3),
            alpha: j.get_f64("alpha").unwrap_or(d.alpha),
            lambda: j.get_usize("lambda").unwrap_or(d.lambda),
            zeta: j.get_f64("zeta").unwrap_or(d.zeta),
            batches_per_trial: j.get_usize("batches_per_trial").unwrap_or(d.batches_per_trial),
            max_iters: j.get_usize("max_iters").unwrap_or(d.max_iters),
        })
    }

    /// Theorem-2 ordering sanity: ρ > ρ1 > ρ2 > ρ3 > 0.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.rho > self.rho1 && self.rho1 > self.rho2 && self.rho2 > self.rho3 && self.rho3 > 0.0,
            "decay rates must satisfy rho > rho1 > rho2 > rho3 > 0"
        );
        anyhow::ensure!((0.0..=1.0).contains(&self.mu), "mu in [0,1]");
        anyhow::ensure!(self.eps0 > 0.0 && self.eps0 <= 1.0, "eps0 in (0,1]");
        Ok(())
    }
}

/// Top-level configuration bundle.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub platform: PlatformConfig,
    pub cpu_cluster: CpuClusterConfig,
    pub workload: WorkloadConfig,
    pub deploy: DeployConfig,
    pub bo: BoConfig,
}

impl Config {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("platform", self.platform.to_json()),
            ("cpu_cluster", self.cpu_cluster.to_json()),
            ("workload", self.workload.to_json()),
            ("deploy", self.deploy.to_json()),
            ("bo", self.bo.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        Ok(Self {
            platform: j
                .get("platform")
                .map(PlatformConfig::from_json)
                .transpose()?
                .unwrap_or_default(),
            cpu_cluster: j
                .get("cpu_cluster")
                .map(CpuClusterConfig::from_json)
                .transpose()?
                .unwrap_or_default(),
            workload: j
                .get("workload")
                .map(WorkloadConfig::from_json)
                .transpose()?
                .unwrap_or_default(),
            deploy: j
                .get("deploy")
                .map(DeployConfig::from_json)
                .transpose()?
                .unwrap_or_default(),
            bo: j.get("bo").map(BoConfig::from_json).transpose()?.unwrap_or_default(),
        })
    }

    pub fn load(path: &Path) -> anyhow::Result<Self> {
        Self::from_json(&Json::read_file(path)?)
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        self.to_json().write_file(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_via_json() {
        let c = Config {
            deploy: DeployConfig { t_limit: 123.0, ..DeployConfig::default() },
            bo: BoConfig { q: 77, ..BoConfig::default() },
            ..Config::default()
        };
        let j = c.to_json();
        let c2 = Config::from_json(&j).unwrap();
        assert_eq!(c2.deploy.t_limit, 123.0);
        assert_eq!(c2.bo.q, 77);
        assert_eq!(c2.platform.memory_options_mb, c.platform.memory_options_mb);
    }

    #[test]
    fn bo_defaults_valid() {
        BoConfig::default().validate().unwrap();
    }

    #[test]
    fn bo_rejects_bad_ordering() {
        let d = BoConfig::default();
        let b = BoConfig { rho1: d.rho + 1.0, ..d };
        assert!(b.validate().is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("smoe_cfg_test");
        let path = dir.join("config.json");
        let c = Config::default();
        c.save(&path).unwrap();
        let c2 = Config::load(&path).unwrap();
        assert_eq!(c2.bo.q, c.bo.q);
        std::fs::remove_dir_all(&dir).ok();
    }
}
