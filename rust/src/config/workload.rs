//! Workload configuration: which synthetic corpus stands in for which paper
//! dataset, sequence/batch shaping, and profiling-set sizing.

use crate::util::json::Json;

/// Synthetic-corpus presets substituting the paper's datasets (DESIGN.md).
/// Each differs in vocabulary size, Zipf exponent and sequence-length
/// profile, giving distinct token-frequency and expert-popularity skews.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CorpusPreset {
    /// Enwik8 stand-in: character/BPE-ish mix, strong skew.
    Enwik8,
    /// CC-News stand-in: larger vocab, moderate skew.
    CcNews,
    /// WMT19 en-de stand-in: translation pairs, moderate vocab.
    Wmt19,
    /// LAMBADA stand-in: narrative text, long sequences.
    Lambada,
}

impl CorpusPreset {
    pub fn name(self) -> &'static str {
        match self {
            CorpusPreset::Enwik8 => "enwik8",
            CorpusPreset::CcNews => "ccnews",
            CorpusPreset::Wmt19 => "wmt19",
            CorpusPreset::Lambada => "lambada",
        }
    }

    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "enwik8" => Some(CorpusPreset::Enwik8),
            "ccnews" => Some(CorpusPreset::CcNews),
            "wmt19" => Some(CorpusPreset::Wmt19),
            "lambada" => Some(CorpusPreset::Lambada),
            _ => None,
        }
    }

    /// (vocab size, zipf α, typical sequence length)
    pub fn params(self) -> (usize, f64, usize) {
        match self {
            CorpusPreset::Enwik8 => (16_384, 1.15, 128),
            CorpusPreset::CcNews => (32_768, 1.05, 96),
            CorpusPreset::Wmt19 => (24_576, 1.10, 64),
            CorpusPreset::Lambada => (20_480, 1.00, 192),
        }
    }

    pub fn all() -> [CorpusPreset; 4] {
        [
            CorpusPreset::Enwik8,
            CorpusPreset::CcNews,
            CorpusPreset::Wmt19,
            CorpusPreset::Lambada,
        ]
    }
}

#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub corpus: CorpusPreset,
    /// Tokens per serving batch (paper headline: 10,240).
    pub batch_tokens: usize,
    /// Number of profiled samples ("at least 100 samples", §III-A).
    pub profile_samples: usize,
    /// RNG seed for reproducibility.
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            corpus: CorpusPreset::Enwik8,
            batch_tokens: 10_240,
            profile_samples: 100,
            seed: 42,
        }
    }
}

impl WorkloadConfig {
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("corpus", Json::str(self.corpus.name())),
            ("batch_tokens", Json::num(self.batch_tokens as f64)),
            ("profile_samples", Json::num(self.profile_samples as f64)),
            ("seed", Json::num(self.seed as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = Self::default();
        Ok(Self {
            corpus: j
                .get_str("corpus")
                .and_then(CorpusPreset::from_name)
                .unwrap_or(d.corpus),
            batch_tokens: j.get_usize("batch_tokens").unwrap_or(d.batch_tokens),
            profile_samples: j.get_usize("profile_samples").unwrap_or(d.profile_samples),
            seed: j.get("seed").and_then(Json::as_u64).unwrap_or(d.seed),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preset_names_roundtrip() {
        for p in CorpusPreset::all() {
            assert_eq!(CorpusPreset::from_name(p.name()), Some(p));
        }
        assert_eq!(CorpusPreset::from_name("nope"), None);
    }

    #[test]
    fn presets_differ() {
        let ps: Vec<_> = CorpusPreset::all().iter().map(|p| p.params()).collect();
        for i in 0..ps.len() {
            for j in (i + 1)..ps.len() {
                assert_ne!(ps[i], ps[j]);
            }
        }
    }

    #[test]
    fn json_roundtrip() {
        let w = WorkloadConfig {
            corpus: CorpusPreset::Wmt19,
            batch_tokens: 256,
            ..WorkloadConfig::default()
        };
        let w2 = WorkloadConfig::from_json(&w.to_json()).unwrap();
        assert_eq!(w2.corpus, CorpusPreset::Wmt19);
        assert_eq!(w2.batch_tokens, 256);
    }
}
