//! Serverless-platform and CPU-cluster configuration.
//!
//! Defaults model AWS Lambda + S3 as the paper uses them (§V-A):
//!  - published Lambda pricing ($1.66667e-5 / GB-s, $2e-7 / invocation),
//!  - the paper's 14 discrete memory options,
//!  - 6 MB payload limit (Fig. 4 caption),
//!  - cold start ≥5 s, deployment ≥60 s (§II, Challenge 1),
//!  - memory-proportional compute speed ("more memory corresponds to more
//!    virtual CPUs").

use crate::util::json::Json;
use crate::util::MB;

#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Discrete memory size options 𝕄 (MB). Paper §V-A list.
    pub memory_options_mb: Vec<u64>,
    /// Billed price per GB-second of function run time.
    pub price_per_gb_s: f64,
    /// Billed price per function invocation.
    pub price_per_invocation: f64,
    /// Maximal direct-transfer payload size D_p (bytes).
    pub payload_bytes: u64,
    /// Serialization inflation κ on activation payloads (Lambda payloads are
    /// JSON; binary tensors go base64 (+33%) plus framing — κ ≈ 1.4). Applied
    /// to token activations on both storage and direct paths, not to raw
    /// parameter objects.
    pub payload_overhead: f64,
    /// External-storage access delay T_dl (seconds, per object access —
    /// S3 request + first-byte latency from Lambda).
    pub storage_access_delay: f64,
    /// Bandwidth B_s between external storage and a function (bytes/s).
    pub storage_bandwidth: f64,
    /// Bandwidth B_f between functions under direct invocation (bytes/s).
    pub function_bandwidth: f64,
    /// Warm start time T_str (seconds).
    pub warm_start: f64,
    /// Cold start time (first invocation after deployment; seconds).
    pub cold_start: f64,
    /// Function (re)deployment time (seconds) — why dynamic re-deployment
    /// during serving is infeasible (Challenge 1).
    pub deploy_time: f64,
    /// Compute throughput per MB of configured memory (FLOP/s per MB).
    /// U_j = token_flops / (min(mem_mb, cpu_saturation_mb) ·
    /// flops_per_mb_per_sec): calibrated so a ~3 GB function serves the
    /// paper's GPT-2 MoE at ≈23 tokens/s.
    pub flops_per_mb_per_sec: f64,
    /// Memory beyond which more MB buys no more compute for the (single-
    /// threaded) expert inference: Lambda allocates 1 vCPU per ~1769 MB, so
    /// a sequential expert saturates near 1792 MB. This is why LambdaML's
    /// max-memory over-provisioning wastes ~40% billed cost (Fig. 14) —
    /// beyond saturation memory bills without speeding anything up.
    pub cpu_saturation_mb: u64,
    /// Price of external storage per GB-month (S3 standard), used by the
    /// billing ledger for completeness (the paper focuses on function cost).
    pub storage_price_per_gb_month: f64,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        Self {
            memory_options_mb: vec![
                128, 768, 960, 1152, 1344, 1536, 1728, 1920, 2112, 2304, 2496, 2688, 2880, 3072,
            ],
            price_per_gb_s: 0.0000166667,
            price_per_invocation: 0.0000002,
            payload_bytes: 6 * MB,
            payload_overhead: 1.4,
            storage_access_delay: 0.080,
            storage_bandwidth: 90.0e6,
            function_bandwidth: 50.0e6,
            warm_start: 0.05,
            cold_start: 5.0,
            deploy_time: 60.0,
            flops_per_mb_per_sec: 1.7e6,
            cpu_saturation_mb: 1792,
            storage_price_per_gb_month: 0.023,
        }
    }
}

impl PlatformConfig {
    /// Largest configurable memory (MB).
    pub fn max_memory_mb(&self) -> u64 {
        *self.memory_options_mb.iter().max().unwrap()
    }

    /// Per-token compute time U_j (seconds/token) for memory option j given
    /// a per-token FLOP count — Eq. (3)'s U_j.
    pub fn token_time(&self, mem_mb: u64, token_flops: f64) -> f64 {
        let effective = mem_mb.min(self.cpu_saturation_mb) as f64;
        token_flops / (effective * self.flops_per_mb_per_sec)
    }

    /// Billed cost of running `mem_mb` for `secs` seconds (GB-s metering).
    pub fn run_cost(&self, mem_mb: u64, secs: f64) -> f64 {
        (mem_mb as f64 * MB as f64 / crate::util::GB as f64) * secs * self.price_per_gb_s
    }

    /// Transfer time of `bytes` via external storage (one access).
    pub fn storage_transfer(&self, bytes: u64) -> f64 {
        self.storage_access_delay + bytes as f64 / self.storage_bandwidth
    }

    /// Transfer time of `bytes` between functions (direct invocation).
    pub fn direct_transfer(&self, bytes: u64) -> f64 {
        bytes as f64 / self.function_bandwidth
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("memory_options_mb", Json::arr_u64(&self.memory_options_mb)),
            ("price_per_gb_s", Json::num(self.price_per_gb_s)),
            ("price_per_invocation", Json::num(self.price_per_invocation)),
            ("payload_bytes", Json::num(self.payload_bytes as f64)),
            ("payload_overhead", Json::num(self.payload_overhead)),
            ("storage_access_delay", Json::num(self.storage_access_delay)),
            ("storage_bandwidth", Json::num(self.storage_bandwidth)),
            ("function_bandwidth", Json::num(self.function_bandwidth)),
            ("warm_start", Json::num(self.warm_start)),
            ("cold_start", Json::num(self.cold_start)),
            ("deploy_time", Json::num(self.deploy_time)),
            ("flops_per_mb_per_sec", Json::num(self.flops_per_mb_per_sec)),
            ("cpu_saturation_mb", Json::num(self.cpu_saturation_mb as f64)),
            (
                "storage_price_per_gb_month",
                Json::num(self.storage_price_per_gb_month),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = Self::default();
        Ok(Self {
            memory_options_mb: j
                .get("memory_options_mb")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_u64).collect())
                .unwrap_or(d.memory_options_mb),
            price_per_gb_s: j.get_f64("price_per_gb_s").unwrap_or(d.price_per_gb_s),
            price_per_invocation: j
                .get_f64("price_per_invocation")
                .unwrap_or(d.price_per_invocation),
            payload_bytes: j
                .get("payload_bytes")
                .and_then(Json::as_u64)
                .unwrap_or(d.payload_bytes),
            payload_overhead: j.get_f64("payload_overhead").unwrap_or(d.payload_overhead),
            storage_access_delay: j
                .get_f64("storage_access_delay")
                .unwrap_or(d.storage_access_delay),
            storage_bandwidth: j.get_f64("storage_bandwidth").unwrap_or(d.storage_bandwidth),
            function_bandwidth: j
                .get_f64("function_bandwidth")
                .unwrap_or(d.function_bandwidth),
            warm_start: j.get_f64("warm_start").unwrap_or(d.warm_start),
            cold_start: j.get_f64("cold_start").unwrap_or(d.cold_start),
            deploy_time: j.get_f64("deploy_time").unwrap_or(d.deploy_time),
            flops_per_mb_per_sec: j
                .get_f64("flops_per_mb_per_sec")
                .unwrap_or(d.flops_per_mb_per_sec),
            cpu_saturation_mb: j
                .get("cpu_saturation_mb")
                .and_then(Json::as_u64)
                .unwrap_or(d.cpu_saturation_mb),
            storage_price_per_gb_month: j
                .get_f64("storage_price_per_gb_month")
                .unwrap_or(d.storage_price_per_gb_month),
        })
    }
}

/// CPU-cluster baseline: two 64-core AMD EPYC CPUs with 512 GB DRAM (§V-G),
/// billed per hour regardless of utilization — the contrast the paper draws
/// against fine-grained serverless billing.
#[derive(Debug, Clone)]
pub struct CpuClusterConfig {
    pub cores: usize,
    pub dram_gb: u64,
    /// Rental price per hour (on-demand ≈ m7a-class 128 vCPU).
    pub price_per_hour: f64,
    /// Minimum billing granularity in seconds (coarse-grained rental:
    /// the paper bills idle resources over a fixed period; hourly here).
    pub billing_granularity: f64,
    /// Aggregate compute throughput (FLOP/s) with all experts concurrent.
    pub total_flops: f64,
    /// Speedup factor of the betterTransformer-optimized variant (§V-G (6)).
    pub better_transformer_speedup: f64,
}

impl Default for CpuClusterConfig {
    fn default() -> Self {
        Self {
            cores: 128,
            dram_gb: 512,
            price_per_hour: 7.50,
            billing_granularity: 3600.0,
            total_flops: 2.0e11,
            better_transformer_speedup: 1.6,
        }
    }
}

impl CpuClusterConfig {
    /// Billed cost for a job occupying the cluster for `secs` seconds —
    /// rounded *up* to the billing granularity (idle remainder still billed).
    pub fn job_cost(&self, secs: f64) -> f64 {
        let billed = (secs / self.billing_granularity).ceil() * self.billing_granularity;
        billed / 3600.0 * self.price_per_hour
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("cores", Json::num(self.cores as f64)),
            ("dram_gb", Json::num(self.dram_gb as f64)),
            ("price_per_hour", Json::num(self.price_per_hour)),
            ("billing_granularity", Json::num(self.billing_granularity)),
            ("total_flops", Json::num(self.total_flops)),
            (
                "better_transformer_speedup",
                Json::num(self.better_transformer_speedup),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let d = Self::default();
        Ok(Self {
            cores: j.get_usize("cores").unwrap_or(d.cores),
            dram_gb: j.get("dram_gb").and_then(Json::as_u64).unwrap_or(d.dram_gb),
            price_per_hour: j.get_f64("price_per_hour").unwrap_or(d.price_per_hour),
            billing_granularity: j
                .get_f64("billing_granularity")
                .unwrap_or(d.billing_granularity),
            total_flops: j.get_f64("total_flops").unwrap_or(d.total_flops),
            better_transformer_speedup: j
                .get_f64("better_transformer_speedup")
                .unwrap_or(d.better_transformer_speedup),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_memory_options() {
        let p = PlatformConfig::default();
        assert_eq!(p.memory_options_mb.len(), 14);
        assert_eq!(p.memory_options_mb[0], 128);
        assert_eq!(p.max_memory_mb(), 3072);
    }

    #[test]
    fn run_cost_matches_lambda_pricing() {
        let p = PlatformConfig::default();
        // 1 GB for 1 s = one GB-s.
        let one_gbs = p.run_cost(1024, 1.0);
        assert!((one_gbs - 0.0000166667).abs() < 1e-12);
        // 3008 MB for 10 s.
        let c = p.run_cost(3008, 10.0);
        assert!((c - (3008.0 / 1024.0) * 10.0 * 0.0000166667).abs() < 1e-12);
    }

    #[test]
    fn token_time_scales_inverse_with_memory_until_saturation() {
        let p = PlatformConfig::default();
        let t_small = p.token_time(128, 1.0e7);
        let t_mid = p.token_time(1792, 1.0e7);
        assert!((t_small / t_mid - 1792.0 / 128.0).abs() < 1e-9);
        // Beyond saturation more memory buys nothing.
        assert_eq!(p.token_time(3072, 1.0e7), t_mid);
    }

    #[test]
    fn storage_vs_direct_transfer() {
        let p = PlatformConfig::default();
        // Small payloads: direct wins (no access delay).
        assert!(p.direct_transfer(1024) < p.storage_transfer(1024));
    }

    #[test]
    fn cluster_bills_idle_remainder() {
        let c = CpuClusterConfig::default();
        // 10-minute job still billed one hour.
        assert!((c.job_cost(600.0) - 7.50).abs() < 1e-9);
        assert!((c.job_cost(3601.0) - 15.0).abs() < 1e-9);
    }

    #[test]
    fn json_roundtrip() {
        let p = PlatformConfig::default();
        let p2 = PlatformConfig::from_json(&p.to_json()).unwrap();
        assert_eq!(p2.memory_options_mb, p.memory_options_mb);
        assert_eq!(p2.payload_bytes, p.payload_bytes);
        let c = CpuClusterConfig::default();
        let c2 = CpuClusterConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2.cores, c.cores);
    }
}
