//! Analytic timing/cost models of §III-D, Eqs. (3)–(11).
//!
//! All quantities are derived from the platform config (T_str, T_dl, B_s,
//! B_f, D_p, pricing), the model spec (P_{e,i}, token FLOPs, D_in, D_out)
//! and the layer plan (per-expert memory x, replicas y, tokens d, method a,
//! pipeline degree β).

use super::CommMethod;
use crate::config::PlatformConfig;
use crate::model::MoeModelSpec;

/// Per-expert deployment + workload row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExpertPlan {
    /// Configured memory (must be one of cfg.memory_options_mb).
    pub mem_mb: u64,
    /// Replica count g ∈ {1..G}.
    pub replicas: usize,
    /// Tokens routed to this expert across all replicas (d_{e,i}).
    pub tokens: u64,
}

impl ExpertPlan {
    /// Tokens per replica r_{e,i} = d_{e,i} / g (ceiling: the straggler
    /// replica's share).
    pub fn tokens_per_replica(&self) -> u64 {
        self.tokens.div_ceil(self.replicas as u64)
    }
}

/// One MoE layer's full plan.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub method: CommMethod,
    /// Pipeline degree β (max minibatch size; only meaningful for a=1).
    pub beta: usize,
    pub experts: Vec<ExpertPlan>,
}

/// Timing breakdown of one MoE layer.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    /// Per-replica execution time t^rep_{a,e,i} for each expert.
    pub replica_times: Vec<f64>,
    /// Billed cost of the layer c_{a,e} (Eq. 4), experts only.
    pub billed_cost: f64,
    /// MoE-E2E latency t^lat_{a,e} (Eqs. 7/9/11).
    pub latency: f64,
}

/// Head time T^{h,E}_{e,i} (Eq. 6): warm start + model download.
pub fn head_time(cfg: &PlatformConfig, param_bytes: u64, warm: bool) -> f64 {
    let start = if warm { cfg.warm_start } else { cfg.cold_start };
    start + cfg.storage_access_delay + param_bytes as f64 / cfg.storage_bandwidth
}

/// Per-token compute time t^cal (Eq. 3) at a memory option.
pub fn token_cal_time(cfg: &PlatformConfig, spec: &MoeModelSpec, layer: usize, mem_mb: u64) -> f64 {
    cfg.token_time(mem_mb, spec.layers[layer].expert.token_flops)
}

/// Per-replica execution time t^rep_{a,e,i} (Eqs. 6, 8, 10).
pub fn replica_time(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    layer: usize,
    plan: &ExpertPlan,
    method: CommMethod,
    beta: usize,
    warm: bool,
) -> f64 {
    let r = plan.tokens_per_replica();
    if r == 0 {
        return 0.0; // expert not selected: function never invoked (s_{e,i}=0)
    }
    let p_bytes = spec.layers[layer].expert.param_bytes;
    let head = head_time(cfg, p_bytes, warm);
    let t_cal = token_cal_time(cfg, spec, layer, plan.mem_mb);
    // Activation payloads inflate by the serialization factor κ.
    let d_in = spec.token_in_bytes as f64 * cfg.payload_overhead;
    let d_out = spec.token_out_bytes as f64 * cfg.payload_overhead;
    let bs = cfg.storage_bandwidth;
    let t_dl = cfg.storage_access_delay;

    match method {
        CommMethod::PipelinedIndirect => {
            // ⌈r/β⌉ blocks; in each block the download+compute of the current
            // minibatch overlaps the upload of the previous one (Fig. 6a).
            let beta = beta.max(1) as u64;
            let m = r.div_ceil(beta);
            let mut t = head;
            let mut remaining = r;
            for _ in 0..m {
                let b = remaining.min(beta);
                remaining -= b;
                // Worst-case block time t^blk (Eq. 6 inner term).
                let down_and_cal = t_dl + b as f64 * (d_in / bs + t_cal);
                let up_prev = t_dl + b as f64 * (d_out / bs);
                t += down_and_cal.max(up_prev);
            }
            // Upload of the last processed minibatch cannot overlap anything
            // (t^nblk of Eq. 6).
            let last = if r % beta == 0 { beta } else { r % beta };
            t += t_dl + last as f64 * d_out / bs;
            t
        }
        CommMethod::Indirect => {
            // Eq. (8): whole input down, compute, whole output up.
            head + 2.0 * t_dl + r as f64 * ((d_in + d_out) / bs + t_cal)
        }
        CommMethod::Direct => {
            // Eq. (10): input arrives as the invocation payload; output is
            // transferred directly to the next layer at B_f per token.
            head + r as f64 * (d_out / cfg.function_bandwidth + t_cal)
        }
    }
}

/// Per-expert replica accounting under the instance-lifecycle model: with
/// `warm_replicas` of the plan's replicas starting warm and the rest paying
/// the cold start, returns `(straggler_time, total_busy_secs)` — the slowest
/// replica's execution time (the layer barrier term) and the summed busy
/// seconds billed across all replicas (Eq. 5 generalized to mixed starts).
/// `warm_replicas >= plan.replicas` degenerates to the all-warm seed model.
pub fn mixed_replica_times(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    layer: usize,
    plan: &ExpertPlan,
    method: CommMethod,
    beta: usize,
    warm_replicas: usize,
) -> (f64, f64) {
    if plan.tokens == 0 {
        return (0.0, 0.0);
    }
    let g = plan.replicas.max(1);
    let w = warm_replicas.min(g);
    let t_warm = replica_time(cfg, spec, layer, plan, method, beta, true);
    if w == g {
        return (t_warm, g as f64 * t_warm);
    }
    let t_cold = replica_time(cfg, spec, layer, plan, method, beta, false);
    (t_cold, w as f64 * t_warm + (g - w) as f64 * t_cold)
}

/// Thrash multiplier when real load exceeds the configured memory (case (i)
/// of Alg. 2): the function pages/spills (or OOM-retries on a replica),
/// inflating its run time. The paper treats this as a hard feedback signal.
pub const MEMORY_THRASH_FACTOR: f64 = 2.5;

/// Per-replica execution time under *realized* constraint outcomes: applies
/// the memory-thrash multiplier (case i) and, under direct transfer, the
/// payload-overflow fallback to indirect (case ii — pay the slower of the
/// two paths plus a retry's access delay) on top of [`replica_time`]. This
/// is the shared penalty model of both serving paths in `bo::feedback`.
#[allow(clippy::too_many_arguments)]
pub fn effective_replica_time(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    layer: usize,
    plan: &ExpertPlan,
    method: CommMethod,
    beta: usize,
    warm: bool,
    mem_bad: bool,
    payload_bad: bool,
) -> f64 {
    let mut t_rep = replica_time(cfg, spec, layer, plan, method, beta, warm);
    if mem_bad {
        t_rep *= MEMORY_THRASH_FACTOR;
    }
    if payload_bad {
        let t_ind = replica_time(cfg, spec, layer, plan, CommMethod::Indirect, 1, warm);
        t_rep = t_rep.max(t_ind) + cfg.storage_access_delay;
    }
    t_rep
}

/// Direct-transfer feasibility (constraint (12f)): the per-replica payloads
/// must fit within D_p in both directions.
pub fn direct_feasible(cfg: &PlatformConfig, spec: &MoeModelSpec, plan: &ExpertPlan) -> bool {
    let r = plan.tokens_per_replica() as f64;
    let limit = cfg.payload_bytes as f64;
    r * spec.token_in_bytes as f64 * cfg.payload_overhead <= limit
        && r * spec.token_out_bytes as f64 * cfg.payload_overhead <= limit
}

/// Batch-level direct-gather feasibility: the next non-MoE layer is a single
/// stateless function invocation, so under direct transfer the aggregated
/// expert outputs for the whole batch must fit one payload — this is what
/// rules direct transfers out for the paper's 2560-token batches (Fig. 4b)
/// even when every per-expert scatter leg fits (12f).
pub fn direct_gather_feasible(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    total_tokens: u64,
) -> bool {
    total_tokens as f64 * spec.token_out_bytes as f64 * cfg.payload_overhead
        <= cfg.payload_bytes as f64
}

/// Memory-capacity feasibility (constraint (12c)).
pub fn memory_feasible(spec: &MoeModelSpec, layer: usize, plan: &ExpertPlan) -> bool {
    let r = plan.tokens_per_replica() as usize;
    let need = spec.layers[layer].expert.param_bytes
        + spec.runtime_overhead_bytes
        + spec.expert_itrm_bytes(r)
        + r as u64 * (spec.token_in_bytes + spec.token_out_bytes);
    need <= plan.mem_mb * crate::util::MB
}

/// Billed cost c_{a,e} of one MoE layer (Eqs. 4–5): every replica's run time
/// × configured memory × GB-s price, plus invocation fees.
pub fn layer_cost(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    layer: usize,
    plan: &LayerPlan,
    warm: bool,
) -> f64 {
    let mut cost = 0.0;
    for ep in &plan.experts {
        if ep.tokens == 0 {
            continue;
        }
        let t_rep = replica_time(cfg, spec, layer, ep, plan.method, plan.beta, warm);
        // Eq. (5): total execution of all g replicas = g · t^rep.
        let total_secs = ep.replicas as f64 * t_rep;
        cost += cfg.run_cost(ep.mem_mb, total_secs)
            + ep.replicas as f64 * cfg.price_per_invocation;
    }
    cost
}

/// Load time T^load_e of the next non-MoE layer's function (start + its
/// parameter download).
pub fn non_moe_load_time(cfg: &PlatformConfig, spec: &MoeModelSpec, warm: bool) -> f64 {
    head_time(cfg, spec.non_moe_param_bytes, warm)
}

/// MoE-E2E latency t^lat_{a,e} (Eqs. 7, 9, 11).
pub fn layer_latency(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    layer: usize,
    plan: &LayerPlan,
    warm: bool,
) -> f64 {
    let t_load = non_moe_load_time(cfg, spec, warm);
    let total_tokens: u64 = plan.experts.iter().map(|e| e.tokens).sum();
    let d_in = spec.token_in_bytes as f64 * cfg.payload_overhead;
    let d_out = spec.token_out_bytes as f64 * cfg.payload_overhead;
    // Active experts/replicas: every per-replica object pays its own access
    // delay at the gating (scatter) and next-layer (gather) ends.
    let active_objects: usize = plan
        .experts
        .iter()
        .filter(|e| e.tokens > 0)
        .map(|e| e.replicas)
        .sum();

    match plan.method {
        CommMethod::PipelinedIndirect | CommMethod::Indirect => {
            // Stage 1+2: experts run to completion; the gating network's
            // scatter upload proceeds concurrently with expert head times
            // (Fig. 8), so the expert chain dominates unless the upload does.
            // Uploads are per-replica objects (serialized at the gate).
            let scatter_upload = active_objects as f64 * cfg.storage_access_delay
                + total_tokens as f64 * d_in / cfg.storage_bandwidth;
            let expert_finish = plan
                .experts
                .iter()
                .map(|ep| {
                    replica_time(cfg, spec, layer, ep, plan.method, plan.beta, warm)
                })
                .fold(0.0, f64::max);
            let s12 = scatter_upload.max(expert_finish);
            // Stage 3: the next non-MoE layer downloads every replica's
            // processed-result object from external storage.
            let s3 = active_objects as f64 * cfg.storage_access_delay
                + total_tokens as f64 * d_out / cfg.storage_bandwidth;
            s12.max(t_load) + s3
        }
        CommMethod::Direct => {
            // Eq. (11): scatter payload transfer + straggler expert + load.
            let max_r = plan
                .experts
                .iter()
                .map(ExpertPlan::tokens_per_replica)
                .max()
                .unwrap_or(0);
            let scatter = max_r as f64 * d_in / cfg.function_bandwidth;
            let expert_finish = plan
                .experts
                .iter()
                .map(|ep| replica_time(cfg, spec, layer, ep, plan.method, plan.beta, warm))
                .fold(0.0, f64::max);
            scatter + expert_finish + t_load
        }
    }
}

/// Full layer timing bundle.
pub fn layer_timing(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    layer: usize,
    plan: &LayerPlan,
    warm: bool,
) -> LayerTiming {
    LayerTiming {
        replica_times: plan
            .experts
            .iter()
            .map(|ep| replica_time(cfg, spec, layer, ep, plan.method, plan.beta, warm))
            .collect(),
        billed_cost: layer_cost(cfg, spec, layer, plan, warm),
        latency: layer_latency(cfg, spec, layer, plan, warm),
    }
}

/// End-to-end model inference time (constraint (12d) LHS): head + tail +
/// Σ_e (t^lat_e + T^NE_e), where T^NE_e is the non-MoE block compute time.
pub fn end_to_end_time(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    plans: &[LayerPlan],
    total_tokens: u64,
    warm: bool,
) -> f64 {
    let max_mem = cfg.max_memory_mb();
    let t_ne = total_tokens as f64 * cfg.token_time(max_mem, spec.non_moe_token_flops);
    let t_head_tail =
        2.0 * total_tokens as f64 * cfg.token_time(max_mem, spec.head_tail_token_flops)
            + 2.0 * head_time(cfg, spec.non_moe_param_bytes, warm);
    let mut t = t_head_tail;
    for (e, plan) in plans.iter().enumerate() {
        t += layer_latency(cfg, spec, e, plan, warm) + t_ne;
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelPreset;

    fn setup() -> (PlatformConfig, MoeModelSpec) {
        (
            PlatformConfig::default(),
            ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec(),
        )
    }

    fn plan(method: CommMethod, beta: usize, tokens: u64) -> LayerPlan {
        LayerPlan {
            method,
            beta,
            experts: vec![
                ExpertPlan { mem_mb: 3072, replicas: 1, tokens };
                4
            ],
        }
    }

    #[test]
    fn zero_tokens_zero_time_zero_cost() {
        let (cfg, spec) = setup();
        let ep = ExpertPlan { mem_mb: 1024, replicas: 1, tokens: 0 };
        for m in CommMethod::ALL {
            assert_eq!(replica_time(&cfg, &spec, 0, &ep, m, 8, true), 0.0);
        }
        let lp = LayerPlan { method: CommMethod::Indirect, beta: 1, experts: vec![ep] };
        assert_eq!(layer_cost(&cfg, &spec, 0, &lp, true), 0.0);
    }

    #[test]
    fn replicas_split_tokens() {
        let ep1 = ExpertPlan { mem_mb: 1024, replicas: 1, tokens: 100 };
        let ep4 = ExpertPlan { mem_mb: 1024, replicas: 4, tokens: 100 };
        assert_eq!(ep1.tokens_per_replica(), 100);
        assert_eq!(ep4.tokens_per_replica(), 25);
        let ep3 = ExpertPlan { mem_mb: 1024, replicas: 3, tokens: 100 };
        assert_eq!(ep3.tokens_per_replica(), 34); // ceiling
    }

    #[test]
    fn pipelining_beats_plain_indirect_at_scale() {
        // With many tokens and a well-chosen β (upload of one block larger
        // than the per-block access delay), overlap must strictly reduce
        // replica time. β is a *choice* — cf. `tiny_beta_pays_access_delays`.
        let (cfg, spec) = setup();
        let ep = ExpertPlan { mem_mb: 3072, replicas: 1, tokens: 6000 };
        let t_pipe = replica_time(&cfg, &spec, 0, &ep, CommMethod::PipelinedIndirect, 3000, true);
        let t_plain = replica_time(&cfg, &spec, 0, &ep, CommMethod::Indirect, 1, true);
        assert!(
            t_pipe < t_plain,
            "pipelined {t_pipe} should beat plain {t_plain}"
        );
    }

    #[test]
    fn tiny_beta_pays_access_delays() {
        // β=1 at large r pays T_dl per token — worse than no pipelining.
        // This is the paper's point that β must be *chosen*, not maximal.
        let (cfg, spec) = setup();
        let ep = ExpertPlan { mem_mb: 3072, replicas: 1, tokens: 2000 };
        let t_beta1 = replica_time(&cfg, &spec, 0, &ep, CommMethod::PipelinedIndirect, 1, true);
        let t_plain = replica_time(&cfg, &spec, 0, &ep, CommMethod::Indirect, 1, true);
        assert!(t_beta1 > t_plain, "β=1 {t_beta1} vs plain {t_plain}");
    }

    #[test]
    fn direct_fastest_for_small_batches() {
        // Fig. 4(a): at 256 tokens direct wins.
        let (cfg, spec) = setup();
        let per_expert = 64; // 256 tokens over 4 experts
        let lp_direct = plan(CommMethod::Direct, 1, per_expert);
        let lp_ind = plan(CommMethod::Indirect, 1, per_expert);
        let lp_pipe = plan(CommMethod::PipelinedIndirect, 16, per_expert);
        let t_d = layer_latency(&cfg, &spec, 0, &lp_direct, true);
        let t_i = layer_latency(&cfg, &spec, 0, &lp_ind, true);
        let t_p = layer_latency(&cfg, &spec, 0, &lp_pipe, true);
        assert!(t_d < t_i && t_d < t_p, "direct={t_d} indirect={t_i} pipe={t_p}");
    }

    #[test]
    fn direct_infeasible_beyond_payload() {
        // Fig. 4(b): 2560 tokens exceed the 6MB payload for BERT activations?
        // D_in = 3072B → 640 tokens/expert · 3072B ≈ 1.9MB < 6MB, so scale up:
        let (cfg, spec) = setup();
        let big = ExpertPlan { mem_mb: 3072, replicas: 1, tokens: 4096 };
        // 4096 · 3072B = 12MB > 6MB payload.
        assert!(!direct_feasible(&cfg, &spec, &big));
        let small = ExpertPlan { mem_mb: 3072, replicas: 1, tokens: 64 };
        assert!(direct_feasible(&cfg, &spec, &small));
        // Replication restores feasibility (Alg. 2 case ii).
        let replicated = ExpertPlan { mem_mb: 3072, replicas: 4, tokens: 4096 };
        assert!(direct_feasible(&cfg, &spec, &replicated));
    }

    #[test]
    fn memory_constraint_12c() {
        let (_, spec) = setup();
        // BERT expert ≈ 18MB params + 150MB overhead: fits 768MB for small r.
        let ok = ExpertPlan { mem_mb: 768, replicas: 1, tokens: 100 };
        assert!(memory_feasible(&spec, 0, &ok));
        // 128MB cannot even hold the parameters + overhead.
        let tight = ExpertPlan { mem_mb: 128, replicas: 1, tokens: 1 };
        assert!(!memory_feasible(&spec, 0, &tight));
    }

    #[test]
    fn more_memory_costs_more_per_second_but_runs_faster() {
        let (cfg, spec) = setup();
        let slow = ExpertPlan { mem_mb: 768, replicas: 1, tokens: 500 };
        let fast = ExpertPlan { mem_mb: 3072, replicas: 1, tokens: 500 };
        let t_slow = replica_time(&cfg, &spec, 0, &slow, CommMethod::Indirect, 1, true);
        let t_fast = replica_time(&cfg, &spec, 0, &fast, CommMethod::Indirect, 1, true);
        assert!(t_fast < t_slow);
    }

    #[test]
    fn cost_scales_with_replica_count() {
        // Eq. (5): replicas run in parallel (latency↓) but all bill.
        let (cfg, spec) = setup();
        let one = LayerPlan {
            method: CommMethod::Indirect,
            beta: 1,
            experts: vec![ExpertPlan { mem_mb: 3072, replicas: 1, tokens: 1000 }],
        };
        let four = LayerPlan {
            method: CommMethod::Indirect,
            beta: 1,
            experts: vec![ExpertPlan { mem_mb: 3072, replicas: 4, tokens: 1000 }],
        };
        let lat_one = layer_latency(&cfg, &spec, 0, &one, true);
        let lat_four = layer_latency(&cfg, &spec, 0, &four, true);
        assert!(lat_four < lat_one, "replicas cut latency");
        let c_one = layer_cost(&cfg, &spec, 0, &one, true);
        let c_four = layer_cost(&cfg, &spec, 0, &four, true);
        assert!(c_four > c_one, "replicas add head-time cost");
    }

    #[test]
    fn mixed_replica_times_brackets_warm_and_cold() {
        let (cfg, spec) = setup();
        let ep = ExpertPlan { mem_mb: 3072, replicas: 4, tokens: 2000 };
        let t_warm = replica_time(&cfg, &spec, 0, &ep, CommMethod::Indirect, 1, true);
        let t_cold = replica_time(&cfg, &spec, 0, &ep, CommMethod::Indirect, 1, false);
        let (s_all, b_all) = mixed_replica_times(&cfg, &spec, 0, &ep, CommMethod::Indirect, 1, 4);
        assert_eq!(s_all, t_warm);
        assert!((b_all - 4.0 * t_warm).abs() < 1e-12);
        let (s_mix, b_mix) = mixed_replica_times(&cfg, &spec, 0, &ep, CommMethod::Indirect, 1, 3);
        assert_eq!(s_mix, t_cold);
        assert!((b_mix - (3.0 * t_warm + t_cold)).abs() < 1e-12);
        let (s_none, b_none) =
            mixed_replica_times(&cfg, &spec, 0, &ep, CommMethod::Indirect, 1, 0);
        assert_eq!(s_none, t_cold);
        assert!((b_none - 4.0 * t_cold).abs() < 1e-12);
        // Zero tokens: free either way.
        let idle = ExpertPlan { mem_mb: 3072, replicas: 4, tokens: 0 };
        assert_eq!(
            mixed_replica_times(&cfg, &spec, 0, &idle, CommMethod::Indirect, 1, 0),
            (0.0, 0.0)
        );
    }

    #[test]
    fn cold_start_dominates_small_runs() {
        let (cfg, spec) = setup();
        let ep = ExpertPlan { mem_mb: 3072, replicas: 1, tokens: 10 };
        let t_cold = replica_time(&cfg, &spec, 0, &ep, CommMethod::Indirect, 1, false);
        let t_warm = replica_time(&cfg, &spec, 0, &ep, CommMethod::Indirect, 1, true);
        assert!(t_cold - t_warm >= cfg.cold_start - cfg.warm_start - 1e-9);
    }

    #[test]
    fn end_to_end_sums_layers() {
        let (cfg, spec) = setup();
        let plans: Vec<LayerPlan> = (0..spec.num_moe_layers())
            .map(|_| plan(CommMethod::Indirect, 1, 2560))
            .collect();
        let t_all = end_to_end_time(&cfg, &spec, &plans, 10_240, true);
        let t_half = end_to_end_time(&cfg, &spec, &plans[..6], 10_240, true);
        assert!(t_all > t_half);
        assert!(t_all.is_finite() && t_all > 0.0);
    }

    #[test]
    fn latency_includes_gather_stage() {
        let (cfg, spec) = setup();
        let lp = plan(CommMethod::Indirect, 1, 640);
        let lat = layer_latency(&cfg, &spec, 0, &lp, true);
        let max_rep = lp
            .experts
            .iter()
            .map(|ep| replica_time(&cfg, &spec, 0, ep, lp.method, lp.beta, true))
            .fold(0.0, f64::max);
        assert!(lat > max_rep, "latency must add the stage-3 gather");
    }
}
