//! Scatter-gather communication designs for MoE layers on a serverless
//! platform (§III-C), with the timing models of Eqs. (6)–(11):
//!
//!  - `a = 1` — **pipelined indirect**: minibatches of pipeline degree β via
//!    external storage; download+compute of minibatch m overlaps upload of
//!    minibatch m−1.
//!  - `a = 2` — **non-pipelined indirect**: whole inputs/outputs via
//!    external storage.
//!  - `a = 3` — **direct invocation**: payload-limited function-to-function
//!    transfers; infeasible when r_{e,i}·D_in > D_p (constraint (12f)), and
//!    parameters must be reloaded on re-invocation (stateless functions), so
//!    no pipelining is possible.
//!
//! [`timing`] computes per-replica execution time t^rep, per-layer billed
//! cost c_{a,e} (Eq. 4–5) and MoE-E2E latency t^lat (Eqs. 7, 9, 11); the
//! event-level simulation in `coordinator` reproduces the same numbers
//! mechanically for the serving path.
//!
//! Interpretation note: Eq. (6) as printed multiplies the block time by β;
//! consistent with Figs. 6/8 (minibatch count = ⌈r/β⌉ blocks, each covering
//! β tokens) we use ⌈r/β⌉ blocks of β·(per-token time) each — the printed
//! form double-counts β. Documented here per the substitution rules.

pub mod timing;

pub use timing::{
    effective_replica_time, layer_cost, layer_latency, mixed_replica_times, replica_time,
    ExpertPlan, LayerPlan, LayerTiming, MEMORY_THRASH_FACTOR,
};

/// The communication method a_e ∈ 𝔸 = {1, 2, 3}.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CommMethod {
    /// a=1: indirect via external storage, pipelined with degree β.
    PipelinedIndirect,
    /// a=2: indirect via external storage, no pipelining.
    Indirect,
    /// a=3: direct function invocation (payload-limited).
    Direct,
}

impl CommMethod {
    pub const ALL: [CommMethod; 3] = [
        CommMethod::PipelinedIndirect,
        CommMethod::Indirect,
        CommMethod::Direct,
    ];

    /// The paper's index a_e.
    pub fn index(self) -> usize {
        match self {
            CommMethod::PipelinedIndirect => 1,
            CommMethod::Indirect => 2,
            CommMethod::Direct => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CommMethod::PipelinedIndirect => "pipelined-indirect",
            CommMethod::Indirect => "indirect",
            CommMethod::Direct => "direct",
        }
    }

    pub fn from_index(i: usize) -> Option<CommMethod> {
        match i {
            1 => Some(CommMethod::PipelinedIndirect),
            2 => Some(CommMethod::Indirect),
            3 => Some(CommMethod::Direct),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indices_roundtrip() {
        for m in CommMethod::ALL {
            assert_eq!(CommMethod::from_index(m.index()), Some(m));
        }
        assert_eq!(CommMethod::from_index(0), None);
        assert_eq!(CommMethod::from_index(4), None);
    }
}
