//! `smoe` — the serverless-MoE leader binary.
//!
//! Subcommands:
//!   experiment <id>|all [--full]   regenerate a paper figure (DESIGN.md index)
//!   serve [--requests N]           serve the real tiny MoE via PJRT
//!   predict [--model M]            profile + evaluate expert prediction
//!   deploy [--model M] [--tlimit S] run the ODS deployment pipeline once
//!   bo [--iters N]                 run the BO tuning loop (quick scale)
//!   config [--write PATH]          print or write the default config
//!   help

use serverless_moe::config::workload::CorpusPreset;
use serverless_moe::config::Config;
use serverless_moe::deploy::ods::ods_full;
use serverless_moe::experiments;
use serverless_moe::model::ModelPreset;
use serverless_moe::predictor::eval::{evaluate, predicted_counts};
use serverless_moe::util::cli::Args;
use serverless_moe::util::table::fcost;

fn main() {
    serverless_moe::util::log::init_from_env();
    let args = Args::from_env();
    let cmd = args.positional.first().map(String::as_str).unwrap_or("help");
    let result = match cmd {
        "experiment" => cmd_experiment(&args),
        "serve" => cmd_serve(&args),
        "predict" => cmd_predict(&args),
        "deploy" => cmd_deploy(&args),
        "bo" => cmd_bo(&args),
        "config" => cmd_config(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "smoe — serverless MoE inference (paper reproduction)\n\
         \n\
         USAGE: smoe <command> [options]\n\
         \n\
         COMMANDS:\n\
           experiment <id>|all [--full]  regenerate paper figures: {}\n\
           serve [--requests N]          serve the tiny MoE over PJRT\n\
           predict [--model M]           expert-selection prediction accuracy\n\
           deploy [--model M] [--tlimit S] [--tokens N]  one ODS deployment\n\
           bo [--iters N]                BO tuning loop (quick scale)\n\
           config [--write PATH]         dump default config JSON",
        experiments::ALL.join(",")
    );
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("all");
    let quick = !args.flag("full");
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        println!("\n=== experiment {id} (quick={quick}) ===");
        for table in experiments::run(id, quick)? {
            table.print();
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use serverless_moe::coordinator::Server;
    use serverless_moe::runtime::default_artifacts_dir;
    anyhow::ensure!(
        serverless_moe::runtime::serving_available(),
        "real serving unavailable — run `make artifacts` and build with the real xla vendor set"
    );
    let n = args.get_usize("requests", 20);
    let platform = Config::default().platform;
    let server = Server::start(default_artifacts_dir(), platform)?;
    let mut rng = serverless_moe::util::rng::Rng::new(args.get_u64("seed", 1));
    for i in 0..n {
        let ids: Vec<u32> = (0..64).map(|_| rng.below(1024) as u32).collect();
        let resp = server.serve(ids)?;
        println!(
            "request {i}: norm={:.4} latency={:.2}ms experts(l0)={:?}",
            resp.output_norm,
            resp.latency * 1e3,
            resp.expert_counts[0]
        );
    }
    let metrics = server.shutdown();
    println!("\n{}", metrics.summary());
    Ok(())
}

fn cmd_predict(args: &Args) -> anyhow::Result<()> {
    let preset = ModelPreset::from_name(&args.get_or("model", "bert"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let quick = !args.flag("full");
    let mut ctx =
        serverless_moe::experiments::common::ExpContext::new(preset, CorpusPreset::Enwik8, quick);
    let batch = ctx.eval_batch();
    let bayes = ctx.bayes();
    let e_b = evaluate(&ctx.gate, &bayes, &batch);
    let e_l = evaluate(&ctx.gate, &ctx.profile.lina, &batch);
    println!(
        "avg |real-pred| per expert: ours={:.2} lina={:.2} (profiled {} tokens)",
        e_b.overall, e_l.overall, ctx.profile.tokens_profiled
    );
    Ok(())
}

fn cmd_deploy(args: &Args) -> anyhow::Result<()> {
    let preset = ModelPreset::from_name(&args.get_or("model", "bert"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let mut ctx = serverless_moe::experiments::common::ExpContext::new(
        preset,
        CorpusPreset::Enwik8,
        true,
    );
    ctx.generator.target_tokens = args.get_usize("tokens", 10_240);
    let batch = ctx.eval_batch();
    let bayes = ctx.bayes();
    let pred = predicted_counts(&ctx.gate, &bayes, &batch);
    let problem = ctx.problem(pred, args.get_f64("tlimit", 3000.0));
    let ods = ods_full(&problem, args.get_f64("solver-limit", 5.0))
        .ok_or_else(|| anyhow::anyhow!("no feasible deployment"))?;
    println!(
        "deployment: cost={} feasible={} fell_back={}",
        fcost(ods.total_cost),
        ods.feasible,
        ods.fell_back
    );
    for (e, (m, plan)) in ods.methods.iter().zip(&ods.policy.layers).enumerate() {
        let mems: Vec<String> = plan
            .experts
            .iter()
            .map(|ep| format!("{}MB x{}", ep.mem_mb, ep.replicas))
            .collect();
        println!("  layer {e}: {} beta={} [{}]", m.name(), plan.beta, mems.join(", "));
    }
    Ok(())
}

fn cmd_bo(args: &Args) -> anyhow::Result<()> {
    let mut ctx = serverless_moe::experiments::common::ExpContext::new(
        ModelPreset::TinyMoe,
        CorpusPreset::Enwik8,
        true,
    );
    let mut bo_cfg = ctx.config.bo.clone();
    bo_cfg.q = args.get_usize("q", 128);
    bo_cfg.max_iters = args.get_usize("iters", 8);
    let mut deploy_cfg = ctx.config.deploy.clone();
    deploy_cfg.t_limit = 4000.0;
    let eval_batches = vec![ctx.eval_batch(), ctx.eval_batch()];
    let mut bo = serverless_moe::bo::algorithm::BoAlgorithm {
        platform: &ctx.config.platform,
        deploy_cfg: &deploy_cfg,
        bo_cfg: bo_cfg.clone(),
        spec: &ctx.spec,
        gate: &ctx.gate,
        predictor: ctx.bayes(),
        eval_batches,
        solver_time_limit: 0.5,
    };
    let (no_bo_cost, _) = bo.evaluate_no_bo();
    let mut acq = serverless_moe::bo::eps_greedy::MultiEpsGreedy::new(&bo_cfg);
    let outcome = bo.run(&mut acq, true, args.get_u64("seed", 7));
    println!(
        "BO: best cost {} (no-BO {}) ratio {:.3} in {} iters (converged={})",
        fcost(outcome.best_cost),
        fcost(no_bo_cost),
        outcome.best_cost / no_bo_cost,
        outcome.iterations,
        outcome.converged
    );
    for (i, tr) in outcome.history.iter().enumerate() {
        println!("  trial {i}: cost={} err={:.2}", fcost(tr.cost), tr.prediction_error);
    }
    Ok(())
}

fn cmd_config(args: &Args) -> anyhow::Result<()> {
    let cfg = Config::default();
    match args.get("write") {
        Some(path) => {
            cfg.save(std::path::Path::new(path))?;
            println!("wrote {path}");
        }
        None => println!("{}", cfg.to_json().to_string_pretty()),
    }
    Ok(())
}
