//! Discrete-event execution of one MoE layer's scatter-gather — the
//! mechanical counterpart of the closed-form Eqs. (6)–(11) in `comm::timing`.
//!
//! Every transfer and compute step becomes a timed event on a virtual
//! clock: the gating function uploads per-replica objects sequentially, each
//! expert replica starts after its head time AND its (first) input is
//! available, minibatches flow through the pipeline with the
//! download+compute / upload overlap of Fig. 6(a), and the next non-MoE
//! layer gathers when everything has landed. A cross-validation test
//! asserts the event-driven latency matches the analytic model within the
//! modeling slack — catching exactly the class of algebra slips the paper's
//! own Eq. (6) contains (see comm/mod.rs interpretation note).

use crate::comm::{CommMethod, LayerPlan};
use crate::config::PlatformConfig;
use crate::model::MoeModelSpec;

/// Result of event-simulating one layer.
#[derive(Debug, Clone)]
pub struct EventOutcome {
    /// Per-expert-replica (expert, replica, finish_time, busy_time).
    pub replicas: Vec<(usize, usize, f64, f64)>,
    /// Time the next non-MoE layer has all results (MoE-E2E latency).
    pub latency: f64,
    /// Billed cost over all replicas (busy time × memory).
    pub billed_cost: f64,
}

/// Event-simulate one MoE layer under `plan` with one uniform start state:
/// every function (expert replicas and the gathering non-MoE layer) starts
/// warm or cold together. This is the seed API; per-replica start states
/// derived from the instance-lifecycle model go through
/// [`simulate_layer_lifecycle`].
pub fn simulate_layer(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    layer: usize,
    plan: &LayerPlan,
    warm: bool,
) -> EventOutcome {
    let start_t = if warm { cfg.warm_start } else { cfg.cold_start };
    simulate_layer_with(cfg, spec, layer, plan, &mut |_, _| start_t, start_t)
}

/// Event-simulate one MoE layer where `warm_replicas[i]` of expert `i`'s
/// replicas start warm (their state derived from a `WarmPool`'s virtual
/// clock, see `platform::lifecycle`) and the rest pay the cold start. The gather
/// function is assumed warm (it serves every batch, so its keep-alive window
/// rarely lapses; the lifecycle simulator charges its cold starts at the
/// request level).
pub fn simulate_layer_lifecycle(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    layer: usize,
    plan: &LayerPlan,
    warm_replicas: &[usize],
) -> EventOutcome {
    assert_eq!(warm_replicas.len(), plan.experts.len());
    let warm_start = cfg.warm_start;
    let cold_start = cfg.cold_start;
    simulate_layer_with(
        cfg,
        spec,
        layer,
        plan,
        &mut |i, g| {
            if g < warm_replicas[i] {
                warm_start
            } else {
                cold_start
            }
        },
        warm_start,
    )
}

/// Shared event loop: `expert_start(expert, replica)` yields each replica's
/// startup latency; `non_moe_start` is the gathering function's.
fn simulate_layer_with(
    cfg: &PlatformConfig,
    spec: &MoeModelSpec,
    layer: usize,
    plan: &LayerPlan,
    expert_start: &mut dyn FnMut(usize, usize) -> f64,
    non_moe_start: f64,
) -> EventOutcome {
    let d_in = spec.token_in_bytes as f64 * cfg.payload_overhead;
    let d_out = spec.token_out_bytes as f64 * cfg.payload_overhead;
    let bs = cfg.storage_bandwidth;
    let t_dl = cfg.storage_access_delay;
    let p_bytes = spec.layers[layer].expert.param_bytes;

    let mut replicas = Vec::new();
    let mut cost = 0.0;

    // --- Stage 1: the gate scatters per-replica input objects (serial). ---
    // upload_done[i][g] = virtual time replica g of expert i can first read
    // its input (indirect) or receives its payload (direct).
    let mut clock = 0.0f64;
    let mut upload_done: Vec<Vec<f64>> = Vec::new();
    for ep in &plan.experts {
        let r = ep.tokens_per_replica();
        let mut per_rep = Vec::new();
        for _g in 0..ep.replicas {
            if ep.tokens == 0 {
                per_rep.push(0.0);
                continue;
            }
            match plan.method {
                CommMethod::PipelinedIndirect => {
                    // Only the first minibatch gates the expert's start.
                    let b1 = r.min(plan.beta.max(1) as u64);
                    clock += t_dl + b1 as f64 * d_in / bs;
                    per_rep.push(clock);
                    // Remaining minibatches upload afterwards (they overlap
                    // expert compute; modeled as available by demand time —
                    // the gate keeps ahead because its upload per block is
                    // cheaper than download+compute per block).
                    let rest = r - b1;
                    clock += if rest > 0 {
                        rest as f64 * d_in / bs
                    } else {
                        0.0
                    };
                }
                CommMethod::Indirect => {
                    clock += t_dl + r as f64 * d_in / bs;
                    per_rep.push(clock);
                }
                CommMethod::Direct => {
                    let dt = r as f64 * d_in / cfg.function_bandwidth;
                    clock += dt;
                    per_rep.push(clock);
                }
            }
        }
        upload_done.push(per_rep);
    }

    // --- Stage 2: each replica runs. ---
    let mut last_output = 0.0f64;
    for (i, ep) in plan.experts.iter().enumerate() {
        if ep.tokens == 0 {
            continue;
        }
        let r = ep.tokens_per_replica();
        let t_cal = cfg.token_time(ep.mem_mb, spec.layers[layer].expert.token_flops);
        for g in 0..ep.replicas {
            // Head: start + parameter download (params live in storage).
            let fn_start = 0.0; // functions are invoked at t=0 (Fig. 8 stage 1)
            let head_done = fn_start + expert_start(i, g) + t_dl + p_bytes as f64 / bs;
            let input_ready = upload_done[i][g];
            let mut t = head_done.max(input_ready);
            let busy_from = fn_start;
            match plan.method {
                CommMethod::PipelinedIndirect => {
                    let beta = plan.beta.max(1) as u64;
                    let mut remaining = r;
                    let mut pending_upload: f64 = 0.0; // upload duration owed
                    while remaining > 0 {
                        let b = remaining.min(beta);
                        remaining -= b;
                        let down_and_cal = t_dl + b as f64 * (d_in / bs + t_cal);
                        // Overlap: previous block's upload runs concurrently.
                        t += down_and_cal.max(pending_upload);
                        pending_upload = t_dl + b as f64 * d_out / bs;
                    }
                    // Final upload cannot overlap.
                    t += pending_upload;
                }
                CommMethod::Indirect => {
                    t += t_dl + r as f64 * d_in / bs; // download input
                    t += r as f64 * t_cal; // compute
                    t += t_dl + r as f64 * d_out / bs; // upload output
                }
                CommMethod::Direct => {
                    t += r as f64 * t_cal;
                    t += r as f64 * d_out / cfg.function_bandwidth;
                }
            }
            let busy = t - busy_from;
            cost += cfg.run_cost(ep.mem_mb, busy) + cfg.price_per_invocation;
            replicas.push((i, g, t, busy));
            last_output = last_output.max(t);
        }
    }

    // --- Stage 3: the next non-MoE layer loads + gathers. ---
    let load_done = non_moe_start + t_dl + spec.non_moe_param_bytes as f64 / bs;
    let total_tokens: u64 = plan.experts.iter().map(|e| e.tokens).sum();
    let active_objects: usize = plan
        .experts
        .iter()
        .filter(|e| e.tokens > 0)
        .map(|e| e.replicas)
        .sum();
    let latency = match plan.method {
        CommMethod::Direct => last_output.max(load_done) + 0.0,
        _ => {
            let gather = active_objects as f64 * t_dl + total_tokens as f64 * d_out / bs;
            last_output.max(load_done) + gather
        }
    };

    EventOutcome {
        replicas,
        latency,
        billed_cost: cost,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{layer_cost, layer_latency, ExpertPlan};
    use crate::model::ModelPreset;

    fn setup() -> (PlatformConfig, MoeModelSpec) {
        (
            PlatformConfig::default(),
            ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec(),
        )
    }

    fn plan(method: CommMethod, beta: usize, tokens: &[u64]) -> LayerPlan {
        LayerPlan {
            method,
            beta,
            experts: tokens
                .iter()
                .map(|&d| ExpertPlan {
                    mem_mb: 3072,
                    replicas: 1,
                    tokens: d,
                })
                .collect(),
        }
    }

    /// The analytic latency (Eqs. 7/9/11) must agree with the mechanical
    /// event simulation within modeling slack (stage-1 concurrency is the
    /// paper's own approximation) for all three methods.
    #[test]
    fn event_sim_cross_validates_analytic_model() {
        let (cfg, spec) = setup();
        for (method, beta) in [
            (CommMethod::Indirect, 1usize),
            (CommMethod::PipelinedIndirect, 1024),
            (CommMethod::Direct, 1),
        ] {
            for tokens in [[300u64, 200, 100, 50], [1200, 800, 400, 100]] {
                if method == CommMethod::Direct && tokens[0] > 1000 {
                    continue; // payload regime
                }
                let p = plan(method, beta, &tokens);
                let analytic = layer_latency(&cfg, &spec, 0, &p, true);
                let event = simulate_layer(&cfg, &spec, 0, &p, true).latency;
                let rel = (analytic - event).abs() / analytic.max(event);
                assert!(
                    rel < 0.20,
                    "{method:?} tokens={tokens:?}: analytic {analytic:.3}s vs event {event:.3}s (rel {rel:.3})"
                );
            }
        }
    }

    #[test]
    fn event_sim_cost_matches_analytic_cost() {
        let (cfg, spec) = setup();
        let p = plan(CommMethod::Indirect, 1, &[1000, 500, 250, 125]);
        let analytic = layer_cost(&cfg, &spec, 0, &p, true);
        let event = simulate_layer(&cfg, &spec, 0, &p, true).billed_cost;
        let rel = (analytic - event).abs() / analytic;
        assert!(rel < 0.15, "analytic {analytic} vs event {event} (rel {rel})");
    }

    #[test]
    fn stragglers_visible_in_replica_finishes() {
        let (cfg, spec) = setup();
        let p = plan(CommMethod::Indirect, 1, &[4000, 10, 10, 10]);
        let out = simulate_layer(&cfg, &spec, 0, &p, true);
        let finish_of = |expert: usize| {
            out.replicas
                .iter()
                .filter(|(i, _, _, _)| *i == expert)
                .map(|(_, _, f, _)| *f)
                .fold(0.0, f64::max)
        };
        assert!(finish_of(0) > finish_of(1) * 2.0);
        // Latency is gated by the straggler.
        assert!(out.latency > finish_of(0));
    }

    #[test]
    fn replication_cuts_event_latency() {
        let (cfg, spec) = setup();
        let single = plan(CommMethod::Indirect, 1, &[4000, 100, 100, 100]);
        let mut replicated = single.clone();
        replicated.experts[0].replicas = 4;
        let l1 = simulate_layer(&cfg, &spec, 0, &single, true).latency;
        let l4 = simulate_layer(&cfg, &spec, 0, &replicated, true).latency;
        assert!(l4 < l1, "replicas must cut straggler latency: {l1} -> {l4}");
    }

    #[test]
    fn zero_token_experts_free() {
        let (cfg, spec) = setup();
        let p = plan(CommMethod::Indirect, 1, &[1000, 0, 0, 0]);
        let out = simulate_layer(&cfg, &spec, 0, &p, true);
        assert_eq!(out.replicas.len(), 1);
        assert!(out.billed_cost > 0.0);
    }

    #[test]
    fn lifecycle_all_warm_matches_uniform_warm() {
        let (cfg, spec) = setup();
        let p = plan(CommMethod::Indirect, 1, &[800, 400, 200, 100]);
        let uniform = simulate_layer(&cfg, &spec, 0, &p, true);
        let lifecycle = simulate_layer_lifecycle(&cfg, &spec, 0, &p, &[1, 1, 1, 1]);
        assert_eq!(uniform.latency, lifecycle.latency);
        assert_eq!(uniform.billed_cost, lifecycle.billed_cost);
    }

    #[test]
    fn lifecycle_mixed_between_warm_and_cold() {
        let (cfg, spec) = setup();
        let mut p = plan(CommMethod::Indirect, 1, &[2000, 1000, 500, 250]);
        for ep in p.experts.iter_mut() {
            ep.replicas = 2;
        }
        let warm = simulate_layer_lifecycle(&cfg, &spec, 0, &p, &[2, 2, 2, 2]);
        let mixed = simulate_layer_lifecycle(&cfg, &spec, 0, &p, &[1, 1, 1, 1]);
        let cold = simulate_layer_lifecycle(&cfg, &spec, 0, &p, &[0, 0, 0, 0]);
        assert!(warm.billed_cost < mixed.billed_cost);
        assert!(mixed.billed_cost < cold.billed_cost);
        assert!(warm.latency <= mixed.latency);
        assert!(mixed.latency <= cold.latency);
    }
}
