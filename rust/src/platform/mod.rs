//! Serverless-platform substrate (AWS Lambda + S3 stand-in).
//!
//! The paper's testbed is AWS Lambda; this module rebuilds its billing and
//! execution mechanics as a first-class simulator (repro band 0 → substitute
//! per DESIGN.md): function instances with configured memory and
//! memory-proportional compute speed, cold/warm starts, an external object
//! store with access delay + bandwidth, direct invocation with a payload
//! cap, a GB-second billing ledger, a deployment manager, and the
//! CPU-cluster baseline.

pub mod billing;
pub mod cpu_cluster;
pub mod deployer;
pub mod events;
pub mod function;
pub mod lifecycle;
pub mod storage;

pub use billing::Ledger;
pub use cpu_cluster::CpuCluster;
pub use deployer::Deployment;
pub use function::FunctionInstance;
pub use lifecycle::{InstancePool, ReplicaKey, WarmPool};
pub use storage::ExternalStorage;

use crate::config::PlatformConfig;

/// The simulated platform: config + ledger + storage, shared by the comm
/// designs and the serving coordinator.
pub struct Platform {
    pub config: PlatformConfig,
    pub ledger: Ledger,
    pub storage: ExternalStorage,
}

impl Platform {
    pub fn new(config: PlatformConfig) -> Self {
        let storage = ExternalStorage::new(
            config.storage_access_delay,
            config.storage_bandwidth,
        );
        Self {
            config,
            ledger: Ledger::new(),
            storage,
        }
    }

    /// Bill one function execution: `mem_mb` configured memory running for
    /// `secs` of wall time, plus the invocation fee.
    pub fn bill_execution(&mut self, fn_name: &str, mem_mb: u64, secs: f64) -> f64 {
        let cost = self.config.run_cost(mem_mb, secs) + self.config.price_per_invocation;
        self.ledger.record(fn_name, mem_mb, secs, cost);
        cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bill_execution_accumulates() {
        let mut p = Platform::new(PlatformConfig::default());
        let c1 = p.bill_execution("expert-0", 1024, 2.0);
        let c2 = p.bill_execution("expert-1", 3072, 1.0);
        assert!(c1 > 0.0 && c2 > 0.0);
        assert!((p.ledger.total_cost() - (c1 + c2)).abs() < 1e-12);
        assert_eq!(p.ledger.invocations(), 2);
    }
}
