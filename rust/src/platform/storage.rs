//! External object storage (S3 stand-in).
//!
//! Serverless functions are stateless; anything that outlives one invocation
//! — model parameters, scattered minibatches, gathered expert outputs — goes
//! through here. Every access pays the access delay T_dl plus bytes/B_s, the
//! two parameters Eqs. (6)–(9) are written in.

use std::collections::HashMap;

/// A stored object (we track real payloads for the PJRT serving path and
/// just sizes for simulator-scale runs).
#[derive(Debug, Clone)]
pub enum StoredObject {
    /// Size-only record (simulation).
    Size(u64),
    /// Real bytes (end-to-end serving path).
    Bytes(Vec<u8>),
}

impl StoredObject {
    pub fn len(&self) -> u64 {
        match self {
            StoredObject::Size(n) => *n,
            StoredObject::Bytes(b) => b.len() as u64,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[derive(Debug, Clone)]
pub struct ExternalStorage {
    pub access_delay: f64,
    pub bandwidth: f64,
    objects: HashMap<String, StoredObject>,
    /// Counters for diagnostics / billing completeness.
    pub puts: u64,
    pub gets: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

impl ExternalStorage {
    pub fn new(access_delay: f64, bandwidth: f64) -> Self {
        Self {
            access_delay,
            bandwidth,
            objects: HashMap::new(),
            puts: 0,
            gets: 0,
            bytes_in: 0,
            bytes_out: 0,
        }
    }

    /// Time to transfer `bytes` one way (one access).
    pub fn transfer_time(&self, bytes: u64) -> f64 {
        self.access_delay + bytes as f64 / self.bandwidth
    }

    /// Store an object; returns the simulated upload time.
    pub fn put(&mut self, key: &str, obj: StoredObject) -> f64 {
        let bytes = obj.len();
        self.objects.insert(key.to_string(), obj);
        self.puts += 1;
        self.bytes_in += bytes;
        self.transfer_time(bytes)
    }

    /// Size-only put (simulation).
    pub fn put_size(&mut self, key: &str, bytes: u64) -> f64 {
        self.put(key, StoredObject::Size(bytes))
    }

    /// Fetch an object; returns (object, simulated download time).
    pub fn get(&mut self, key: &str) -> Option<(&StoredObject, f64)> {
        self.gets += 1;
        // Borrow-split: compute time from the size first.
        let bytes = self.objects.get(key)?.len();
        self.bytes_out += bytes;
        let t = self.transfer_time(bytes);
        self.objects.get(key).map(|o| (o, t))
    }

    /// Download time without mutating counters (pure timing query).
    pub fn peek_time(&self, key: &str) -> Option<f64> {
        self.objects.get(key).map(|o| self.transfer_time(o.len()))
    }

    pub fn delete(&mut self, key: &str) -> bool {
        self.objects.remove(key).is_some()
    }

    pub fn contains(&self, key: &str) -> bool {
        self.objects.contains_key(key)
    }

    pub fn total_bytes(&self) -> u64 {
        self.objects.values().map(StoredObject::len).sum()
    }

    pub fn num_objects(&self) -> usize {
        self.objects.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn storage() -> ExternalStorage {
        ExternalStorage::new(0.03, 100.0e6)
    }

    #[test]
    fn put_get_roundtrip() {
        let mut s = storage();
        let up = s.put("weights/e0", StoredObject::Bytes(vec![7u8; 1000]));
        assert!((up - (0.03 + 1000.0 / 100.0e6)).abs() < 1e-12);
        let (obj, down) = s.get("weights/e0").unwrap();
        assert_eq!(obj.len(), 1000);
        assert!((down - up).abs() < 1e-12);
        assert_eq!(s.puts, 1);
        assert_eq!(s.gets, 1);
        assert_eq!(s.bytes_in, 1000);
        assert_eq!(s.bytes_out, 1000);
    }

    #[test]
    fn missing_key() {
        let mut s = storage();
        assert!(s.get("nope").is_none());
        assert!(s.peek_time("nope").is_none());
        assert!(!s.delete("nope"));
    }

    #[test]
    fn transfer_time_includes_delay() {
        let s = storage();
        // Zero-byte access still pays the access delay — this is why
        // pipelining gains shrink when T_dl dominates (§III-C).
        assert!((s.transfer_time(0) - 0.03).abs() < 1e-15);
        assert!(s.transfer_time(10_000_000) > s.transfer_time(0));
    }

    #[test]
    fn size_tracking() {
        let mut s = storage();
        s.put_size("a", 500);
        s.put_size("b", 700);
        assert_eq!(s.total_bytes(), 1200);
        s.delete("a");
        assert_eq!(s.total_bytes(), 700);
        assert_eq!(s.num_objects(), 1);
    }

    #[test]
    fn overwrite_replaces() {
        let mut s = storage();
        s.put_size("k", 100);
        s.put_size("k", 900);
        assert_eq!(s.total_bytes(), 900);
    }
}
