//! Serverless function instances.
//!
//! A function is deployed with a fixed memory configuration (the paper's
//! principal performance lever: memory ⇒ vCPU share ⇒ compute speed) and is
//! stateless across invocations: the first invocation after deployment pays
//! a cold start, subsequent warm invocations pay only the warm-start time,
//! and model parameters must be (re)downloaded whenever an invocation cannot
//! reuse a live environment — the reason direct-transfer pipelining is
//! impossible (§II Challenge 2).

use crate::config::PlatformConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FnState {
    /// Deployed but never invoked — next invocation is a cold start.
    Cold,
    /// Live environment: warm start, parameters already in memory.
    Warm,
}

#[derive(Debug, Clone)]
pub struct FunctionInstance {
    pub name: String,
    pub mem_mb: u64,
    /// Bytes of model parameters this function must load from storage.
    pub param_bytes: u64,
    pub state: FnState,
    /// Accumulated billed execution seconds.
    pub billed_secs: f64,
    pub invocations: u64,
}

impl FunctionInstance {
    pub fn new(name: &str, mem_mb: u64, param_bytes: u64) -> Self {
        Self {
            name: name.to_string(),
            mem_mb,
            param_bytes,
            state: FnState::Cold,
            billed_secs: 0.0,
            invocations: 0,
        }
    }

    /// Startup latency of the next invocation (cold or warm), *excluding*
    /// parameter download.
    pub fn startup_time(&self, cfg: &PlatformConfig) -> f64 {
        match self.state {
            FnState::Cold => cfg.cold_start,
            FnState::Warm => cfg.warm_start,
        }
    }

    /// Head time T^{h,E}: startup + parameter download from storage
    /// (T_str + T_dl + P/B_s of Eq. 6). Warm reuse of a live environment
    /// keeps parameters resident, but a *re-invocation* (direct transfer
    /// path) always re-downloads — pass `reload_params` accordingly.
    pub fn head_time(&self, cfg: &PlatformConfig, reload_params: bool) -> f64 {
        let start = self.startup_time(cfg);
        if reload_params || self.state == FnState::Cold {
            start + cfg.storage_access_delay + self.param_bytes as f64 / cfg.storage_bandwidth
        } else {
            start
        }
    }

    /// Per-token compute time at this function's memory configuration
    /// (Eq. 3's U_j for this expert).
    pub fn token_time(&self, cfg: &PlatformConfig, token_flops: f64) -> f64 {
        cfg.token_time(self.mem_mb, token_flops)
    }

    /// Record one invocation running for `secs`; transitions to Warm.
    pub fn complete_invocation(&mut self, secs: f64) {
        self.billed_secs += secs;
        self.invocations += 1;
        self.state = FnState::Warm;
    }

    /// Memory-capacity check (constraint (12c)): parameters + intermediate
    /// activations + in/out buffers must fit in configured memory.
    pub fn fits(&self, itrm_bytes: u64, io_bytes: u64) -> bool {
        self.param_bytes + itrm_bytes + io_bytes <= self.mem_mb * crate::util::MB
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PlatformConfig {
        PlatformConfig::default()
    }

    #[test]
    fn cold_then_warm() {
        let cfg = cfg();
        let mut f = FunctionInstance::new("expert-0", 1024, 10 * crate::util::MB);
        assert_eq!(f.startup_time(&cfg), cfg.cold_start);
        f.complete_invocation(1.0);
        assert_eq!(f.state, FnState::Warm);
        assert_eq!(f.startup_time(&cfg), cfg.warm_start);
        assert_eq!(f.invocations, 1);
        assert_eq!(f.billed_secs, 1.0);
    }

    #[test]
    fn head_time_components() {
        let cfg = cfg();
        let mut f = FunctionInstance::new("e", 1024, 90_000_000);
        // Cold: start + delay + bytes/BW.
        let h = f.head_time(&cfg, false);
        assert!((h - (cfg.cold_start + cfg.storage_access_delay + 1.0)).abs() < 1e-9);
        f.complete_invocation(0.5);
        // Warm without reload: only warm start.
        assert!((f.head_time(&cfg, false) - cfg.warm_start).abs() < 1e-12);
        // Warm with forced reload (direct-transfer re-invocation).
        assert!(f.head_time(&cfg, true) > cfg.warm_start + 0.9);
    }

    #[test]
    fn token_time_uses_memory() {
        let cfg = cfg();
        let small = FunctionInstance::new("s", 128, 0);
        let big = FunctionInstance::new("b", 3072, 0);
        let fl = 1.0e7;
        assert!(small.token_time(&cfg, fl) > big.token_time(&cfg, fl));
    }

    #[test]
    fn capacity_check() {
        let f = FunctionInstance::new("e", 1024, 900 * crate::util::MB);
        assert!(f.fits(100 * crate::util::MB, 10 * crate::util::MB));
        assert!(!f.fits(200 * crate::util::MB, 0));
    }
}
