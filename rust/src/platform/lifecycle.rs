//! Instance-lifecycle model: per-replica warm pools with keep-alive expiry.
//!
//! The seed pipeline threaded a hardcoded `warm: bool` through the timing
//! models — fine for one pre-warmed batch, wrong for sustained traffic where
//! warmness is a *consequence of the request history*. This module derives
//! it from the virtual clock instead: every expert replica is a serverless
//! function instance that stays warm for `keep_alive` seconds after its last
//! invocation finishes (AWS Lambda keeps environments alive on the order of
//! minutes) and is cold otherwise. Redeployment tears every instance down
//! (`reset`), which is exactly why the ≥60 s deployment gap of §II
//! Challenge 1 must be charged against availability by the traffic
//! simulator.

use crate::comm::LayerPlan;
use std::collections::HashMap;

/// Identity of one expert-replica function instance:
/// `(moe_layer, expert, replica)`.
pub type ReplicaKey = (usize, usize, usize);

#[derive(Debug, Clone)]
pub struct WarmPool {
    /// Virtual time until which each instance stays warm. Instances absent
    /// from the map have never been invoked (cold).
    warm_until: HashMap<ReplicaKey, f64>,
    /// Keep-alive window after an invocation finishes (seconds). Use
    /// `f64::INFINITY` for a never-expiring (always-warm-once-touched) pool.
    pub keep_alive: f64,
    /// Invocation counters, split by derived start state.
    pub warm_hits: u64,
    pub cold_starts: u64,
}

impl WarmPool {
    pub fn new(keep_alive: f64) -> WarmPool {
        assert!(keep_alive >= 0.0, "negative keep-alive");
        WarmPool {
            warm_until: HashMap::new(),
            keep_alive,
            warm_hits: 0,
            cold_starts: 0,
        }
    }

    /// Mark one instance warm forever (a warm-up invocation at deploy time,
    /// as the paper's measurements do before Fig. 8).
    pub fn prewarm(&mut self, key: ReplicaKey) {
        self.warm_until.insert(key, f64::INFINITY);
    }

    /// Pre-warm every replica of every expert in a deployment plan.
    pub fn prewarm_plan(&mut self, layers: &[LayerPlan]) {
        for (l, plan) in layers.iter().enumerate() {
            for (e, ep) in plan.experts.iter().enumerate() {
                for g in 0..ep.replicas {
                    self.prewarm((l, e, g));
                }
            }
        }
    }

    /// Whether `key`'s next invocation at virtual time `now` starts warm.
    pub fn is_warm(&self, key: ReplicaKey, now: f64) -> bool {
        self.warm_until.get(&key).is_some_and(|&until| now <= until)
    }

    /// Number of `key = (layer, expert, g)` replicas warm at `now` among
    /// `replicas` total.
    pub fn warm_count(&self, layer: usize, expert: usize, replicas: usize, now: f64) -> usize {
        (0..replicas)
            .filter(|&g| self.is_warm((layer, expert, g), now))
            .count()
    }

    /// Record an invocation of `key` starting at `now` and finishing at
    /// `end`. Returns whether it started warm, and extends the instance's
    /// keep-alive window past `end`.
    pub fn invoke(&mut self, key: ReplicaKey, now: f64, end: f64) -> bool {
        debug_assert!(end >= now, "invocation ends before it starts");
        let warm = self.is_warm(key, now);
        if warm {
            self.warm_hits += 1;
        } else {
            self.cold_starts += 1;
        }
        let until = self.warm_until.entry(key).or_insert(f64::NEG_INFINITY);
        *until = until.max(end + self.keep_alive);
        warm
    }

    /// Tear down every instance (redeployment): everything starts cold.
    pub fn reset(&mut self) {
        self.warm_until.clear();
    }

    /// Fraction of invocations so far that started warm (1.0 before any).
    pub fn warm_fraction(&self) -> f64 {
        let total = self.warm_hits + self.cold_starts;
        if total == 0 {
            1.0
        } else {
            self.warm_hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommMethod, ExpertPlan};

    #[test]
    fn cold_until_invoked_then_keep_alive_window() {
        let mut p = WarmPool::new(100.0);
        let k = (0, 1, 0);
        assert!(!p.is_warm(k, 0.0));
        assert!(!p.invoke(k, 0.0, 5.0)); // first invocation is cold
        assert!(p.is_warm(k, 50.0));
        assert!(p.is_warm(k, 105.0)); // 5.0 + 100.0 keep-alive
        assert!(!p.is_warm(k, 105.1));
        assert!(p.invoke(k, 60.0, 70.0)); // within window: warm
        assert_eq!(p.warm_hits, 1);
        assert_eq!(p.cold_starts, 1);
        assert!((p.warm_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn zero_keep_alive_expires_immediately() {
        let mut p = WarmPool::new(0.0);
        let k = (0, 0, 0);
        p.invoke(k, 0.0, 2.0);
        assert!(p.is_warm(k, 2.0)); // boundary inclusive
        assert!(!p.is_warm(k, 2.0001));
    }

    #[test]
    fn prewarm_never_expires_until_reset() {
        let mut p = WarmPool::new(1.0);
        let plan = vec![LayerPlan {
            method: CommMethod::Indirect,
            beta: 1,
            experts: vec![
                ExpertPlan {
                    mem_mb: 1024,
                    replicas: 3,
                    tokens: 10,
                };
                2
            ],
        }];
        p.prewarm_plan(&plan);
        assert_eq!(p.warm_count(0, 0, 3, 1.0e9), 3);
        assert_eq!(p.warm_count(0, 1, 3, 1.0e9), 3);
        p.reset();
        assert_eq!(p.warm_count(0, 0, 3, 0.0), 0);
    }

    #[test]
    fn invoke_never_shrinks_window() {
        let mut p = WarmPool::new(10.0);
        let k = (1, 2, 3);
        p.invoke(k, 0.0, 100.0); // warm until 110
        p.invoke(k, 50.0, 60.0); // must not shrink to 70
        assert!(p.is_warm(k, 109.0));
    }
}
