//! Instance-lifecycle model: per-replica warm pools with keep-alive expiry
//! and bounded per-instance concurrency (FIFO request queueing).
//!
//! The seed pipeline threaded a hardcoded `warm: bool` through the timing
//! models — fine for one pre-warmed batch, wrong for sustained traffic where
//! warmness is a *consequence of the request history*. This module derives
//! it from the virtual clock instead: every expert replica is a serverless
//! function instance that stays warm for `keep_alive` seconds after its last
//! invocation finishes (AWS Lambda keeps environments alive on the order of
//! minutes) and is cold otherwise. Redeployment tears every instance down
//! (`reset`), which is exactly why the ≥60 s deployment gap of §II
//! Challenge 1 must be charged against availability by the traffic
//! simulator.
//!
//! On top of warmness, each instance has a bounded number of concurrency
//! *slots* (Lambda executes one invocation per environment — `Some(1)`; the
//! PR 1 serving model is `None` = unbounded). Work that arrives while every
//! slot is occupied waits in an implicit FIFO queue: [`WarmPool::admit`]
//! schedules each invocation at the earliest work-conserving start time
//! (`max(arrival, earliest slot release)`), which for admissions issued in
//! non-decreasing arrival order yields per-instance FIFO service. The pool
//! also keeps the busy-seconds and queue-wait ledgers the `SimReport`
//! utilization metrics are built from.

use crate::comm::LayerPlan;
use std::collections::HashMap;

/// Identity of one expert-replica function instance:
/// `(moe_layer, expert, replica)`.
pub type ReplicaKey = (usize, usize, usize);

/// The lifecycle surface the epoch-boundary machinery (autoscaler scale-in,
/// redeployment teardown, pre-warming) needs from an instance pool. Both the
/// legacy [`WarmPool`] and the event engine's flat `traffic::sim::SlotArena`
/// implement it, so the boundary logic is written once and cross-validates
/// bit-for-bit across engines.
pub trait InstancePool {
    /// Per-instance concurrency limit (`None` = unbounded). Queue-driven
    /// autoscaling policies hold on unbounded pools (no FIFO signal).
    fn concurrency_limit(&self) -> Option<usize>;

    /// Whether `key` has no invocation still executing at `t` (its queue has
    /// fully drained) — the autoscaler's scale-in guard.
    fn idle_at(&self, key: ReplicaKey, t: f64) -> bool;

    /// Tear down one instance (scale-in): its warm environment is released.
    fn evict(&mut self, key: ReplicaKey);

    /// Tear down every instance (redeployment).
    fn reset(&mut self);

    /// Mark one instance warm forever (a deploy-time warm-up invocation).
    fn prewarm(&mut self, key: ReplicaKey);

    /// Register one more owner of `key` (cross-tenant expert sharing):
    /// refcounted pools only release the warm environment when the last
    /// owner evicts. Private pools (the default) ignore it.
    fn retain(&mut self, _key: ReplicaKey) {}

    /// Pre-warm every replica of every expert in a deployment plan.
    fn prewarm_plan(&mut self, layers: &[LayerPlan]) {
        for (l, plan) in layers.iter().enumerate() {
            for (e, ep) in plan.experts.iter().enumerate() {
                for g in 0..ep.replicas {
                    self.prewarm((l, e, g));
                }
            }
        }
    }
}

#[derive(Debug, Clone)]
pub struct WarmPool {
    /// Virtual time until which each instance stays warm. Instances absent
    /// from the map have never been invoked (cold).
    warm_until: HashMap<ReplicaKey, f64>,
    /// Keep-alive window after an invocation finishes (seconds). Use
    /// `f64::INFINITY` for a never-expiring (always-warm-once-touched) pool.
    pub keep_alive: f64,
    /// Invocation counters, split by derived start state.
    pub warm_hits: u64,
    pub cold_starts: u64,
    /// Concurrent invocations one instance can execute (`None` = unbounded,
    /// the PR 1 serving model; Lambda's environment semantics are `Some(1)`).
    pub concurrency: Option<usize>,
    /// Release times of each instance's concurrency slots (always exactly
    /// `concurrency` entries once the instance has been touched), kept
    /// sorted ascending so the min-free slot is `slots[0]` — admission is an
    /// O(1) peek plus an ordered re-insert instead of a full rescan.
    slots: HashMap<ReplicaKey, Vec<f64>>,
    /// Cumulative execution seconds admitted per instance (across the whole
    /// run — kept through `reset` so end-of-run utilization stays meaningful).
    busy: HashMap<ReplicaKey, f64>,
    /// Running total of `busy` in admission order (deterministic float sum,
    /// unlike summing the map).
    total_busy: f64,
    /// Admissions that had to wait for a slot, and their summed FIFO wait.
    pub queued_jobs: u64,
    pub total_queue_wait: f64,
}

impl WarmPool {
    pub fn new(keep_alive: f64) -> WarmPool {
        WarmPool::with_concurrency(keep_alive, None)
    }

    /// Pool with a per-instance concurrency limit (`None` = unbounded).
    pub fn with_concurrency(keep_alive: f64, concurrency: Option<usize>) -> WarmPool {
        assert!(keep_alive >= 0.0, "negative keep-alive");
        if let Some(c) = concurrency {
            assert!(c >= 1, "concurrency limit must be >= 1 (got {c})");
        }
        WarmPool {
            warm_until: HashMap::new(),
            keep_alive,
            warm_hits: 0,
            cold_starts: 0,
            concurrency,
            slots: HashMap::new(),
            busy: HashMap::new(),
            total_busy: 0.0,
            queued_jobs: 0,
            total_queue_wait: 0.0,
        }
    }

    /// Mark one instance warm forever (a warm-up invocation at deploy time,
    /// as the paper's measurements do before Fig. 8). Whole-plan pre-warming
    /// lives on the [`InstancePool`] trait (`prewarm_plan`), shared with the
    /// event engine's arena so the two cannot drift apart.
    pub fn prewarm(&mut self, key: ReplicaKey) {
        self.warm_until.insert(key, f64::INFINITY);
    }

    /// Whether `key`'s next invocation at virtual time `now` starts warm.
    pub fn is_warm(&self, key: ReplicaKey, now: f64) -> bool {
        self.warm_until.get(&key).is_some_and(|&until| now <= until)
    }

    /// Number of `key = (layer, expert, g)` replicas warm at `now` among
    /// `replicas` total.
    pub fn warm_count(&self, layer: usize, expert: usize, replicas: usize, now: f64) -> usize {
        (0..replicas)
            .filter(|&g| self.is_warm((layer, expert, g), now))
            .count()
    }

    /// Record an invocation of `key` starting at `now` and finishing at
    /// `end`. Returns whether it started warm, and extends the instance's
    /// keep-alive window past `end`.
    pub fn invoke(&mut self, key: ReplicaKey, now: f64, end: f64) -> bool {
        debug_assert!(end >= now, "invocation ends before it starts");
        let warm = self.is_warm(key, now);
        if warm {
            self.warm_hits += 1;
        } else {
            self.cold_starts += 1;
        }
        let until = self.warm_until.entry(key).or_insert(f64::NEG_INFINITY);
        *until = until.max(end + self.keep_alive);
        warm
    }

    /// Earliest time `key` can begin an invocation that becomes ready at
    /// `arrival`: `arrival` itself when a slot is free, otherwise the
    /// earliest slot-release time (work-conserving FIFO). Pure peek — call
    /// [`WarmPool::admit`] to actually reserve the slot.
    pub fn earliest_start(&self, key: ReplicaKey, arrival: f64) -> f64 {
        if self.concurrency.is_none() {
            return arrival;
        }
        match self.slots.get(&key) {
            None => arrival,
            // Sorted invariant: the min-free slot is always at index 0.
            Some(slots) => arrival.max(slots[0]),
        }
    }

    /// Admit one invocation of `key` that becomes ready at `arrival` and
    /// executes for `service` seconds; returns the scheduled start time
    /// (== [`WarmPool::earliest_start`] for the same state). Records the
    /// busy-seconds and queue-wait ledgers. Admissions must be issued in
    /// non-decreasing `arrival` order for the schedule to be FIFO.
    pub fn admit(&mut self, key: ReplicaKey, arrival: f64, service: f64) -> f64 {
        debug_assert!(service >= 0.0, "negative service time");
        let start = match self.concurrency {
            None => arrival,
            Some(c) => {
                let slots = self
                    .slots
                    .entry(key)
                    .or_insert_with(|| vec![f64::NEG_INFINITY; c]);
                // Take the min-free slot (index 0 by the sorted invariant)
                // and re-insert its new release time in order — no rescan.
                let start = arrival.max(slots[0]);
                let fin = start + service;
                let mut i = 0usize;
                while i + 1 < slots.len() && slots[i + 1] < fin {
                    slots[i] = slots[i + 1];
                    i += 1;
                }
                slots[i] = fin;
                start
            }
        };
        *self.busy.entry(key).or_insert(0.0) += service;
        self.total_busy += service;
        let wait = start - arrival;
        if wait > 0.0 {
            self.queued_jobs += 1;
        }
        self.total_queue_wait += wait;
        start
    }

    /// Whether `key` has no invocation still executing at `t` (its queue has
    /// fully drained) — the autoscaler's scale-in guard. Unbounded pools
    /// don't track slots and always report idle.
    pub fn idle_at(&self, key: ReplicaKey, t: f64) -> bool {
        match self.slots.get(&key) {
            None => true,
            // Sorted invariant: the last slot holds the latest release.
            Some(slots) => slots.last().is_none_or(|&free| free <= t),
        }
    }

    /// Cumulative execution seconds admitted on `key` over the run.
    pub fn busy_secs(&self, key: ReplicaKey) -> f64 {
        self.busy.get(&key).copied().unwrap_or(0.0)
    }

    /// Cumulative execution seconds across all instances (deterministic
    /// admission-order sum).
    pub fn total_busy_secs(&self) -> f64 {
        self.total_busy
    }

    /// Highest single-instance busy fraction of a `horizon`-second run.
    /// With bounded concurrency c this is ≤ c by construction (≤ 1 for the
    /// Lambda `Some(1)` semantics, modulo instances respawned by redeploys).
    pub fn max_utilization(&self, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            return 0.0;
        }
        self.busy.values().fold(0.0f64, |acc, &b| acc.max(b / horizon))
    }

    /// Tear down one instance (autoscaler scale-in): its warm environment
    /// is released, so a later scale-out of the same replica index starts
    /// cold again. The busy/queue ledgers survive.
    pub fn evict(&mut self, key: ReplicaKey) {
        self.warm_until.remove(&key);
        self.slots.remove(&key);
    }

    /// Tear down every instance (redeployment): everything starts cold and
    /// all concurrency slots are released. The busy/queue ledgers survive —
    /// they describe the run, not the current deployment generation.
    pub fn reset(&mut self) {
        self.warm_until.clear();
        self.slots.clear();
    }

    /// Fraction of invocations so far that started warm (1.0 before any).
    pub fn warm_fraction(&self) -> f64 {
        let total = self.warm_hits + self.cold_starts;
        if total == 0 {
            1.0
        } else {
            self.warm_hits as f64 / total as f64
        }
    }
}

impl InstancePool for WarmPool {
    fn concurrency_limit(&self) -> Option<usize> {
        self.concurrency
    }

    fn idle_at(&self, key: ReplicaKey, t: f64) -> bool {
        WarmPool::idle_at(self, key, t)
    }

    fn evict(&mut self, key: ReplicaKey) {
        WarmPool::evict(self, key)
    }

    fn reset(&mut self) {
        WarmPool::reset(self)
    }

    fn prewarm(&mut self, key: ReplicaKey) {
        WarmPool::prewarm(self, key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommMethod, ExpertPlan};

    #[test]
    fn cold_until_invoked_then_keep_alive_window() {
        let mut p = WarmPool::new(100.0);
        let k = (0, 1, 0);
        assert!(!p.is_warm(k, 0.0));
        assert!(!p.invoke(k, 0.0, 5.0)); // first invocation is cold
        assert!(p.is_warm(k, 50.0));
        assert!(p.is_warm(k, 105.0)); // 5.0 + 100.0 keep-alive
        assert!(!p.is_warm(k, 105.1));
        assert!(p.invoke(k, 60.0, 70.0)); // within window: warm
        assert_eq!(p.warm_hits, 1);
        assert_eq!(p.cold_starts, 1);
        assert!((p.warm_fraction() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn zero_keep_alive_expires_immediately() {
        let mut p = WarmPool::new(0.0);
        let k = (0, 0, 0);
        p.invoke(k, 0.0, 2.0);
        assert!(p.is_warm(k, 2.0)); // boundary inclusive
        assert!(!p.is_warm(k, 2.0001));
    }

    #[test]
    fn prewarm_never_expires_until_reset() {
        let mut p = WarmPool::new(1.0);
        let plan = vec![LayerPlan {
            method: CommMethod::Indirect,
            beta: 1,
            experts: vec![
                ExpertPlan {
                    mem_mb: 1024,
                    replicas: 3,
                    tokens: 10,
                };
                2
            ],
        }];
        p.prewarm_plan(&plan);
        assert_eq!(p.warm_count(0, 0, 3, 1.0e9), 3);
        assert_eq!(p.warm_count(0, 1, 3, 1.0e9), 3);
        p.reset();
        assert_eq!(p.warm_count(0, 0, 3, 0.0), 0);
    }

    #[test]
    fn bounded_concurrency_serializes_invocations_fifo() {
        let mut p = WarmPool::with_concurrency(100.0, Some(1));
        let k = (0, 0, 0);
        assert_eq!(p.earliest_start(k, 0.0), 0.0);
        assert_eq!(p.admit(k, 0.0, 5.0), 0.0);
        // Second invocation arrives mid-execution: waits for the slot.
        assert_eq!(p.earliest_start(k, 1.0), 5.0);
        assert_eq!(p.admit(k, 1.0, 2.0), 5.0);
        // Third arrives after the queue drains: starts immediately.
        assert_eq!(p.admit(k, 20.0, 1.0), 20.0);
        assert_eq!(p.queued_jobs, 1);
        assert!((p.total_queue_wait - 4.0).abs() < 1e-12);
        assert!((p.busy_secs(k) - 8.0).abs() < 1e-12);
        assert!((p.total_busy_secs() - 8.0).abs() < 1e-12);
        assert!(!p.idle_at(k, 20.5));
        assert!(p.idle_at(k, 21.0));
        // One instance can never exceed 100% busy over the span it ran in.
        assert!(p.max_utilization(21.0) <= 1.0 + 1e-12);
    }

    #[test]
    fn two_slots_overlap_then_queue() {
        let mut p = WarmPool::with_concurrency(100.0, Some(2));
        let k = (1, 0, 0);
        assert_eq!(p.admit(k, 0.0, 10.0), 0.0);
        assert_eq!(p.admit(k, 1.0, 10.0), 1.0); // second slot free
        // Both slots busy: the third invocation waits for the earlier
        // release (t = 10).
        assert_eq!(p.earliest_start(k, 2.0), 10.0);
        assert_eq!(p.admit(k, 2.0, 1.0), 10.0);
        assert_eq!(p.queued_jobs, 1);
    }

    #[test]
    fn unbounded_pool_never_queues() {
        let mut p = WarmPool::new(100.0);
        let k = (0, 1, 0);
        for i in 0..10 {
            let at = i as f64 * 0.01;
            assert_eq!(p.admit(k, at, 50.0), at);
        }
        assert_eq!(p.queued_jobs, 0);
        assert_eq!(p.total_queue_wait, 0.0);
        assert!((p.total_busy_secs() - 500.0).abs() < 1e-9);
        assert!(p.idle_at(k, 0.0), "unbounded pools track no slots");
    }

    #[test]
    fn reset_releases_slots_but_keeps_ledgers() {
        let mut p = WarmPool::with_concurrency(10.0, Some(1));
        let k = (0, 0, 1);
        p.admit(k, 0.0, 100.0);
        assert_eq!(p.earliest_start(k, 1.0), 100.0);
        p.reset();
        // Fresh deployment generation: the slot is free again...
        assert_eq!(p.earliest_start(k, 1.0), 1.0);
        // ...but the run-level busy ledger survives.
        assert!((p.total_busy_secs() - 100.0).abs() < 1e-12);
    }

    #[test]
    fn sorted_slots_survive_out_of_order_finishes() {
        // A short job admitted after a long one releases *earlier*; the
        // ordered re-insert must keep the min-free slot at index 0 so the
        // next admission still lands on the true earliest release.
        let mut p = WarmPool::with_concurrency(f64::INFINITY, Some(3));
        let k = (2, 1, 0);
        assert_eq!(p.admit(k, 0.0, 100.0), 0.0); // releases at 100
        assert_eq!(p.admit(k, 1.0, 2.0), 1.0); // releases at 3
        assert_eq!(p.admit(k, 2.0, 50.0), 2.0); // releases at 52
        // All slots busy; earliest release is the short job at t=3.
        assert_eq!(p.earliest_start(k, 2.5), 3.0);
        assert_eq!(p.admit(k, 2.5, 1.0), 3.0); // releases at 4
        // Next earliest is now t=4, not 52 or 100.
        assert_eq!(p.earliest_start(k, 0.0), 4.0);
        assert!(!p.idle_at(k, 99.0));
        assert!(p.idle_at(k, 100.0));
    }

    #[test]
    fn invoke_never_shrinks_window() {
        let mut p = WarmPool::new(10.0);
        let k = (1, 2, 3);
        p.invoke(k, 0.0, 100.0); // warm until 110
        p.invoke(k, 50.0, 60.0); // must not shrink to 70
        assert!(p.is_warm(k, 109.0));
    }
}
