//! GB-second billing ledger — the paper's objective is the summed billed
//! cost of all MoE-layer functions, metered exactly like Lambda: configured
//! memory × wall-clock execution time.

/// One billed function execution.
#[derive(Debug, Clone)]
pub struct BillingEntry {
    pub fn_name: String,
    pub mem_mb: u64,
    pub secs: f64,
    pub cost: f64,
}

#[derive(Debug, Clone, Default)]
pub struct Ledger {
    entries: Vec<BillingEntry>,
}

impl Ledger {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, fn_name: &str, mem_mb: u64, secs: f64, cost: f64) {
        debug_assert!(secs >= 0.0 && cost >= 0.0);
        self.entries.push(BillingEntry {
            fn_name: fn_name.to_string(),
            mem_mb,
            secs,
            cost,
        });
    }

    pub fn total_cost(&self) -> f64 {
        self.entries.iter().map(|e| e.cost).sum()
    }

    pub fn total_gb_seconds(&self) -> f64 {
        self.entries
            .iter()
            .map(|e| e.mem_mb as f64 / 1024.0 * e.secs)
            .sum()
    }

    pub fn invocations(&self) -> usize {
        self.entries.len()
    }

    /// Cost filtered by function-name prefix (e.g. all "expert-" functions —
    /// the paper bills only the MoE-layer experts).
    pub fn cost_with_prefix(&self, prefix: &str) -> f64 {
        self.entries
            .iter()
            .filter(|e| e.fn_name.starts_with(prefix))
            .map(|e| e.cost)
            .sum()
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }

    pub fn entries(&self) -> &[BillingEntry] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_and_prefix_filter() {
        let mut l = Ledger::new();
        l.record("expert-0-0", 1024, 1.0, 0.1);
        l.record("expert-0-1", 2048, 2.0, 0.2);
        l.record("gate-0", 512, 1.0, 0.05);
        assert!((l.total_cost() - 0.35).abs() < 1e-12);
        assert!((l.cost_with_prefix("expert-") - 0.3).abs() < 1e-12);
        assert!((l.total_gb_seconds() - (1.0 + 4.0 + 0.5)).abs() < 1e-12);
        assert_eq!(l.invocations(), 3);
        l.clear();
        assert_eq!(l.total_cost(), 0.0);
    }
}
