//! Deployment manager: materializes a deployment policy (per-expert memory
//! size + replica count, per §III-D) into function instances, and accounts
//! for deployment time — the several-minutes cost that makes *dynamic*
//! re-deployment during serving infeasible (§II Challenge 1), motivating the
//! ahead-of-time prediction + optimization pipeline.

use super::function::FunctionInstance;
use crate::config::PlatformConfig;
use crate::model::MoeModelSpec;

/// Per-expert deployment decision (one row of the policy x, y of (12)).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExpertDeployment {
    pub mem_mb: u64,
    pub replicas: usize,
}

/// A full materialized deployment: every expert replica of every MoE layer
/// plus the non-MoE layer functions.
pub struct Deployment {
    /// functions[layer][expert] = replica instances.
    pub experts: Vec<Vec<Vec<FunctionInstance>>>,
    /// Non-MoE (attention) block functions, one per layer, at max memory.
    pub non_moe: Vec<FunctionInstance>,
    /// Total simulated deployment wall time.
    pub deploy_time: f64,
}

impl Deployment {
    /// Deploy `policy[layer][expert]` for `spec`.
    pub fn deploy(
        cfg: &PlatformConfig,
        spec: &MoeModelSpec,
        policy: &[Vec<ExpertDeployment>],
    ) -> Deployment {
        assert_eq!(policy.len(), spec.num_moe_layers());
        let mut experts = Vec::with_capacity(policy.len());
        let mut total_fns = 0usize;
        for (e, layer_policy) in policy.iter().enumerate() {
            assert_eq!(layer_policy.len(), spec.experts_at(e));
            let mut layer_fns = Vec::with_capacity(layer_policy.len());
            for (i, d) in layer_policy.iter().enumerate() {
                assert!(d.replicas >= 1, "expert ({e},{i}) with zero replicas");
                let reps = (0..d.replicas)
                    .map(|g| {
                        total_fns += 1;
                        FunctionInstance::new(
                            &format!("expert-{e}-{i}-r{g}"),
                            d.mem_mb,
                            spec.layers[e].expert.param_bytes,
                        )
                    })
                    .collect();
                layer_fns.push(reps);
            }
            experts.push(layer_fns);
        }
        let non_moe = (0..spec.num_moe_layers())
            .map(|e| {
                total_fns += 1;
                FunctionInstance::new(
                    &format!("nonmoe-{e}"),
                    cfg.max_memory_mb(),
                    spec.non_moe_param_bytes,
                )
            })
            .collect();
        // Functions deploy in parallel on the platform; the wall time is one
        // deployment round (images pushed concurrently), independent of
        // count to first order.
        let deploy_time = cfg.deploy_time * (1.0 + (total_fns as f64).log2() * 0.05);
        Deployment {
            experts,
            non_moe,
            deploy_time,
        }
    }

    pub fn replicas(&self, layer: usize, expert: usize) -> usize {
        self.experts[layer][expert].len()
    }

    pub fn total_functions(&self) -> usize {
        self.experts
            .iter()
            .flat_map(|l| l.iter())
            .map(Vec::len)
            .sum::<usize>()
            + self.non_moe.len()
    }

    /// Mark every function warm (the paper's experiments pre-warm via a
    /// warm-up invocation before measurement — Fig. 8 "short warm start").
    pub fn prewarm(&mut self) {
        for layer in &mut self.experts {
            for ex in layer {
                for f in ex {
                    f.state = super::function::FnState::Warm;
                }
            }
        }
        for f in &mut self.non_moe {
            f.state = super::function::FnState::Warm;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelPreset;

    #[test]
    fn deploy_materializes_replicas() {
        let cfg = PlatformConfig::default();
        let spec = ModelPreset::TinyMoe.spec();
        let policy: Vec<Vec<ExpertDeployment>> = (0..spec.num_moe_layers())
            .map(|e| {
                (0..spec.experts_at(e))
                    .map(|i| ExpertDeployment {
                        mem_mb: 1024,
                        replicas: if i == 0 { 3 } else { 1 },
                    })
                    .collect()
            })
            .collect();
        let d = Deployment::deploy(&cfg, &spec, &policy);
        assert_eq!(d.replicas(0, 0), 3);
        assert_eq!(d.replicas(0, 1), 1);
        assert_eq!(
            d.total_functions(),
            2 * (3 + 1 + 1 + 1) + 2 // experts + non-moe per layer
        );
        assert!(d.deploy_time >= cfg.deploy_time);
    }

    #[test]
    fn prewarm_flips_state() {
        let cfg = PlatformConfig::default();
        let spec = ModelPreset::TinyMoe.spec();
        let policy: Vec<Vec<ExpertDeployment>> = (0..spec.num_moe_layers())
            .map(|e| {
                vec![ExpertDeployment { mem_mb: 512, replicas: 1 }; spec.experts_at(e)]
            })
            .collect();
        let mut d = Deployment::deploy(&cfg, &spec, &policy);
        assert_eq!(d.experts[0][0][0].state, super::super::function::FnState::Cold);
        d.prewarm();
        assert_eq!(d.experts[0][0][0].state, super::super::function::FnState::Warm);
        assert_eq!(d.non_moe[0].state, super::super::function::FnState::Warm);
    }

    #[test]
    #[should_panic(expected = "zero replicas")]
    fn zero_replicas_rejected() {
        let cfg = PlatformConfig::default();
        let spec = ModelPreset::TinyMoe.spec();
        let mut policy: Vec<Vec<ExpertDeployment>> = (0..spec.num_moe_layers())
            .map(|e| {
                vec![ExpertDeployment { mem_mb: 512, replicas: 1 }; spec.experts_at(e)]
            })
            .collect();
        policy[0][0].replicas = 0;
        Deployment::deploy(&cfg, &spec, &policy);
    }
}
