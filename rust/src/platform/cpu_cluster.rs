//! CPU-cluster baseline (§V-G options (5) and (6)).
//!
//! Two 64-core EPYC CPUs, 512 GB DRAM, billed per rental period regardless
//! of utilization. All experts of an MoE layer execute concurrently across
//! cores; the betterTransformer variant applies a fused-kernel speedup. The
//! contrast against serverless is coarse-grained idle billing vs per-ms
//! metering — exactly what Figs. 2 and 14 plot.

use crate::config::CpuClusterConfig;
use crate::model::MoeModelSpec;

pub struct CpuCluster {
    pub config: CpuClusterConfig,
    pub better_transformer: bool,
}

/// Outcome of serving one batch on the cluster.
#[derive(Debug, Clone)]
pub struct ClusterRun {
    pub exec_secs: f64,
    pub billed_cost: f64,
    pub throughput_tps: f64,
}

impl CpuCluster {
    pub fn new(config: CpuClusterConfig, better_transformer: bool) -> Self {
        Self {
            config,
            better_transformer,
        }
    }

    fn speedup(&self) -> f64 {
        if self.better_transformer {
            self.config.better_transformer_speedup
        } else {
            1.0
        }
    }

    /// Serve `total_tokens` with ground-truth per-expert token counts
    /// `expert_counts[layer][expert]`. Experts run concurrently, each on an
    /// equal share of cores; layer time is the straggler expert's time
    /// (the scatter-gather barrier exists on clusters too, cf. DeepSpeed).
    pub fn serve(
        &self,
        spec: &MoeModelSpec,
        expert_counts: &[Vec<u64>],
        total_tokens: usize,
    ) -> ClusterRun {
        let flops_total = self.config.total_flops * self.speedup();
        let mut exec = 0.0;
        for (e, counts) in expert_counts.iter().enumerate() {
            let n = counts.len().max(1);
            let per_expert_flops = flops_total / n as f64;
            // Straggler expert bounds the MoE layer time.
            let moe_time = counts
                .iter()
                .map(|&c| c as f64 * spec.layers[e].expert.token_flops / per_expert_flops)
                .fold(0.0, f64::max);
            // Non-MoE block uses the whole cluster.
            let non_moe_time = total_tokens as f64 * spec.non_moe_token_flops / flops_total;
            exec += moe_time + non_moe_time;
        }
        // Head/tail layers.
        exec += 2.0 * total_tokens as f64 * spec.head_tail_token_flops / flops_total;
        let billed_cost = self.config.job_cost(exec);
        ClusterRun {
            exec_secs: exec,
            billed_cost,
            throughput_tps: total_tokens as f64 / exec,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelPreset;

    fn counts(spec: &MoeModelSpec, per_expert: u64) -> Vec<Vec<u64>> {
        (0..spec.num_moe_layers())
            .map(|e| vec![per_expert; spec.experts_at(e)])
            .collect()
    }

    #[test]
    fn better_transformer_is_faster_not_cheaper_per_hour() {
        let spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        let c = counts(&spec, 2560);
        let base = CpuCluster::new(CpuClusterConfig::default(), false).serve(&spec, &c, 10_240);
        let bt = CpuCluster::new(CpuClusterConfig::default(), true).serve(&spec, &c, 10_240);
        assert!(bt.exec_secs < base.exec_secs);
        assert!(bt.throughput_tps > base.throughput_tps);
        // Both are under an hour → identical billed cost (idle billing).
        assert_eq!(base.billed_cost, bt.billed_cost);
    }

    #[test]
    fn straggler_expert_dominates() {
        let spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        let balanced = counts(&spec, 2560);
        let mut skewed = counts(&spec, 0);
        for l in skewed.iter_mut() {
            l[0] = 4 * 2560; // all tokens on one expert
        }
        let cl = CpuCluster::new(CpuClusterConfig::default(), false);
        let b = cl.serve(&spec, &balanced, 10_240);
        let s = cl.serve(&spec, &skewed, 10_240);
        assert!(s.exec_secs > b.exec_secs, "skew must hurt the cluster too");
    }

    #[test]
    fn cluster_cost_is_coarse() {
        // A tiny job still pays a full billing period — the motivation gap.
        let spec = ModelPreset::TinyMoe.spec();
        let c = counts(&spec, 10);
        let run = CpuCluster::new(CpuClusterConfig::default(), false).serve(&spec, &c, 40);
        assert!(run.exec_secs < 1.0);
        assert!((run.billed_cost - CpuClusterConfig::default().price_per_hour).abs() < 1e-9);
    }
}
