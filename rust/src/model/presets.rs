//! Model presets matching §V-A of the paper:
//!  - Bert MoE:      12-layer encoder, 110 M params, 4/8/16 experts per layer
//!  - GPT-2 MoE:     12-layer decoder, 1.5 B params, 4 experts per layer
//!  - Bert2Bert MoE: 24-layer encoder-decoder, 247 M params, 4 experts
//!  - Tiny MoE:      the actually-compiled PJRT model (artifacts/) for the
//!    real end-to-end serving path.
//!
//! All MLP layers after attention are converted to MoE layers with a linear
//! gating network (paper's conversion recipe).

use super::MoeModelSpec;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelPreset {
    BertMoe { experts: usize, top_k: usize },
    Gpt2Moe { top_k: usize },
    Bert2BertMoe { top_k: usize },
    TinyMoe,
}

impl ModelPreset {
    pub fn spec(self) -> MoeModelSpec {
        match self {
            // BERT-base: H=768, F=3072, 12 layers.
            ModelPreset::BertMoe { experts, top_k } => {
                let mut m =
                    MoeModelSpec::homogeneous("bert-moe", 768, 3072, 30_522, 12, experts, top_k);
                m.name = format!("bert-moe-{experts}e-top{top_k}");
                m
            }
            // Paper's GPT-2 at 1.5 B params over 12 MoE layers → GPT-2-XL
            // dims (H=1600, F=6400).
            ModelPreset::Gpt2Moe { top_k } => {
                let mut m =
                    MoeModelSpec::homogeneous("gpt2-moe", 1600, 6400, 50_257, 12, 4, top_k);
                m.name = format!("gpt2-moe-4e-top{top_k}");
                m
            }
            // Bert2Bert: encoder-decoder, 24 MoE layers, 247 M params.
            ModelPreset::Bert2BertMoe { top_k } => {
                let mut m = MoeModelSpec::homogeneous(
                    "bert2bert-moe",
                    768,
                    3072,
                    30_522,
                    24,
                    4,
                    top_k,
                );
                m.name = format!("bert2bert-moe-4e-top{top_k}");
                m
            }
            // The real compiled model (python/compile/model.py).
            ModelPreset::TinyMoe => {
                MoeModelSpec::homogeneous("tiny-moe", 64, 256, 1024, 2, 4, 1)
            }
        }
    }

    /// Canonical scenario/CLI name of the preset — the inverse of
    /// [`ModelPreset::from_name`] (`None` for parameterizations that name
    /// does not reach; scenario files fall back to the inline spec encoding
    /// for those).
    pub fn canonical_name(self) -> Option<&'static str> {
        match self {
            ModelPreset::BertMoe { experts: 4, top_k: 1 } => Some("bert"),
            ModelPreset::BertMoe { experts: 8, top_k: 1 } => Some("bert8"),
            ModelPreset::BertMoe { experts: 16, top_k: 1 } => Some("bert16"),
            ModelPreset::BertMoe { experts: 4, top_k: 2 } => Some("bert-top2"),
            ModelPreset::Gpt2Moe { top_k: 1 } => Some("gpt2"),
            ModelPreset::Gpt2Moe { top_k: 2 } => Some("gpt2-top2"),
            ModelPreset::Bert2BertMoe { top_k: 1 } => Some("bert2bert"),
            ModelPreset::TinyMoe => Some("tiny"),
            _ => None,
        }
    }

    pub fn from_name(s: &str) -> Option<ModelPreset> {
        match s {
            "bert" | "bert-moe" => Some(ModelPreset::BertMoe { experts: 4, top_k: 1 }),
            "bert8" => Some(ModelPreset::BertMoe { experts: 8, top_k: 1 }),
            "bert16" => Some(ModelPreset::BertMoe { experts: 16, top_k: 1 }),
            "bert-top2" => Some(ModelPreset::BertMoe { experts: 4, top_k: 2 }),
            "gpt2" | "gpt2-moe" => Some(ModelPreset::Gpt2Moe { top_k: 1 }),
            "gpt2-top2" => Some(ModelPreset::Gpt2Moe { top_k: 2 }),
            "bert2bert" => Some(ModelPreset::Bert2BertMoe { top_k: 1 }),
            "tiny" | "tiny-moe" => Some(ModelPreset::TinyMoe),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bert_moe_param_scale() {
        // The MoE-ized BERT should be in the 100M..400M range for 4 experts
        // (dense BERT-base is 110M; expert-parallel copies of the MLP grow it).
        let m = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        let params = m.approx_param_count();
        assert!(params > 100_000_000 && params < 500_000_000, "params={params}");
        assert_eq!(m.num_moe_layers(), 12);
    }

    #[test]
    fn gpt2_moe_param_scale() {
        let m = ModelPreset::Gpt2Moe { top_k: 1 }.spec();
        let params = m.approx_param_count();
        // ~1–1.6B.
        assert!(params > 900_000_000 && params < 1_800_000_000, "params={params}");
    }

    #[test]
    fn bert2bert_layers() {
        let m = ModelPreset::Bert2BertMoe { top_k: 1 }.spec();
        assert_eq!(m.num_moe_layers(), 24);
    }

    #[test]
    fn expert_fits_in_max_lambda_memory() {
        // Every preset's single expert (params + runtime overhead) must fit
        // in the 3072MB max memory option, or no deployment is feasible.
        for p in [
            ModelPreset::BertMoe { experts: 4, top_k: 1 },
            ModelPreset::Gpt2Moe { top_k: 1 },
            ModelPreset::Bert2BertMoe { top_k: 1 },
            ModelPreset::TinyMoe,
        ] {
            let m = p.spec();
            let need = m.layers[0].expert.param_bytes + m.runtime_overhead_bytes;
            assert!(
                need < 3072 * crate::util::MB,
                "{}: expert needs {}",
                m.name,
                crate::util::fmt_bytes(need)
            );
        }
    }

    #[test]
    fn names_resolve() {
        for n in ["bert", "bert8", "bert16", "bert-top2", "gpt2", "bert2bert", "tiny"] {
            assert!(ModelPreset::from_name(n).is_some(), "{n}");
        }
        assert!(ModelPreset::from_name("unknown").is_none());
    }

    #[test]
    fn canonical_name_inverts_from_name() {
        for n in ["bert", "bert8", "bert16", "bert-top2", "gpt2", "gpt2-top2", "bert2bert", "tiny"]
        {
            let p = ModelPreset::from_name(n).unwrap();
            assert_eq!(p.canonical_name(), Some(n));
        }
        assert_eq!(ModelPreset::BertMoe { experts: 32, top_k: 1 }.canonical_name(), None);
    }
}
