//! MoE model metadata: the quantities the deployment problem (12) and the
//! timing models (6)–(11) need — per-expert parameter sizes P_{e,i},
//! intermediate memory M_itrm, per-token FLOPs, token activation sizes
//! D_in/D_out — plus the paper's model presets.

pub mod presets;

pub use presets::ModelPreset;

/// One expert network's static description.
#[derive(Debug, Clone)]
pub struct ExpertSpec {
    /// Parameter bytes P_{e,i} (model download size from external storage).
    pub param_bytes: u64,
    /// FLOPs to process one token through this expert.
    pub token_flops: f64,
}

/// One MoE layer: a gating network plus `num_experts` parallel experts.
#[derive(Debug, Clone)]
pub struct MoeLayerSpec {
    pub num_experts: usize,
    pub expert: ExpertSpec,
}

/// Full MoE model description.
#[derive(Debug, Clone)]
pub struct MoeModelSpec {
    pub name: String,
    /// Hidden (model) dimension H.
    pub hidden: usize,
    /// Expert FFN inner dimension F.
    pub ffn_dim: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Top-k routing fan-out.
    pub top_k: usize,
    /// MoE layers (each preceded by a non-MoE attention block).
    pub layers: Vec<MoeLayerSpec>,
    /// Activation bytes per token entering an expert (D_in).
    pub token_in_bytes: u64,
    /// Activation bytes per token leaving an expert (D_out).
    pub token_out_bytes: u64,
    /// Container/runtime base memory overhead of an expert function (bytes):
    /// interpreter + framework + workspace, independent of the expert.
    pub runtime_overhead_bytes: u64,
    /// FLOPs per token of one non-MoE (attention) block — sets T_e^NE.
    pub non_moe_token_flops: f64,
    /// Parameter bytes of one non-MoE block (download time for T_e^load).
    pub non_moe_param_bytes: u64,
    /// FLOPs per token of the head/tail layers (embedding, LM head).
    pub head_tail_token_flops: f64,
}

impl MoeModelSpec {
    pub fn num_moe_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn experts_at(&self, layer: usize) -> usize {
        self.layers[layer].num_experts
    }

    /// Total expert parameters across all MoE layers (bytes).
    pub fn total_expert_param_bytes(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| l.num_experts as u64 * l.expert.param_bytes)
            .sum()
    }

    /// Total parameter count estimate (experts + non-MoE), in parameters.
    pub fn approx_param_count(&self) -> u64 {
        let expert = self.total_expert_param_bytes() / 4;
        let non_moe = self.layers.len() as u64 * self.non_moe_param_bytes / 4;
        let embed = (self.vocab * self.hidden) as u64;
        expert + non_moe + embed
    }

    /// Intermediate-activation memory M_itrm for an expert serving a batch
    /// of `tokens` tokens (constraint (12c)): the FFN inner activation plus
    /// in/out buffers.
    pub fn expert_itrm_bytes(&self, tokens: usize) -> u64 {
        (tokens * self.ffn_dim * 4) as u64 + (tokens as u64) * (self.token_in_bytes + self.token_out_bytes)
    }

    /// Build the standard expert spec from dims: FFN = Linear(H→F) + GELU +
    /// Linear(F→H), params = 2·H·F + F + H floats, FLOPs = 2·2·H·F per token.
    pub fn standard_expert(hidden: usize, ffn_dim: usize) -> ExpertSpec {
        let params = 2 * hidden * ffn_dim + ffn_dim + hidden;
        ExpertSpec {
            param_bytes: (params * 4) as u64,
            token_flops: (4 * hidden * ffn_dim) as f64,
        }
    }

    /// Construct a homogeneous model (all layers identical).
    #[allow(clippy::too_many_arguments)]
    pub fn homogeneous(
        name: &str,
        hidden: usize,
        ffn_dim: usize,
        vocab: usize,
        num_layers: usize,
        experts_per_layer: usize,
        top_k: usize,
    ) -> Self {
        let expert = Self::standard_expert(hidden, ffn_dim);
        // Attention block: QKVO projections (4·H·H) ≈ 8·H² FLOPs/token (mul+add),
        // plus score/context terms folded into the same constant.
        let non_moe_token_flops = (8 * hidden * hidden) as f64 * 1.5;
        let non_moe_param_bytes = (4 * hidden * hidden * 4) as u64;
        MoeModelSpec {
            name: name.to_string(),
            hidden,
            ffn_dim,
            vocab,
            top_k,
            layers: vec![
                MoeLayerSpec {
                    num_experts: experts_per_layer,
                    expert: expert.clone(),
                };
                num_layers
            ],
            token_in_bytes: (hidden * 4) as u64,
            token_out_bytes: (hidden * 4) as u64,
            runtime_overhead_bytes: 150 * crate::util::MB,
            non_moe_token_flops,
            non_moe_param_bytes,
            head_tail_token_flops: (2 * hidden * vocab) as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_expert_sizes() {
        // H=768, F=3072: 2·768·3072 + 3072 + 768 params.
        let e = MoeModelSpec::standard_expert(768, 3072);
        assert_eq!(e.param_bytes, ((2 * 768 * 3072 + 3072 + 768) * 4) as u64);
        assert_eq!(e.token_flops, (4 * 768 * 3072) as f64);
    }

    #[test]
    fn homogeneous_construction() {
        let m = MoeModelSpec::homogeneous("t", 64, 256, 1024, 2, 4, 1);
        assert_eq!(m.num_moe_layers(), 2);
        assert_eq!(m.experts_at(0), 4);
        assert_eq!(m.token_in_bytes, 256);
        assert!(m.total_expert_param_bytes() > 0);
    }

    #[test]
    fn itrm_scales_with_tokens() {
        let m = MoeModelSpec::homogeneous("t", 64, 256, 1024, 2, 4, 1);
        assert!(m.expert_itrm_bytes(200) > m.expert_itrm_bytes(100));
        assert_eq!(m.expert_itrm_bytes(0), 0);
    }
}
