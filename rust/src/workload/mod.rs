//! Inference workloads: synthetic corpora (the paper-dataset substitutes)
//! and batched token requests.

pub mod corpus;
pub mod requests;

pub use corpus::{Corpus, Sequence};
pub use requests::{Batch, RequestGenerator, TimedBatch};
