//! Batched inference requests over a corpus.

use super::corpus::{Corpus, Sequence};
use crate::util::rng::Rng;

/// One serving batch: a set of sequences totalling ~`target_tokens` tokens.
#[derive(Debug, Clone)]
pub struct Batch {
    pub sequences: Vec<Sequence>,
    pub total_tokens: usize,
}

impl Batch {
    pub fn from_sequences(sequences: Vec<Sequence>) -> Batch {
        let total_tokens = sequences.iter().map(Sequence::len).sum();
        Batch {
            sequences,
            total_tokens,
        }
    }

    /// Flat iterator over (token_id, position_id, attention_id) triples.
    pub fn tokens(&self) -> impl Iterator<Item = (u32, u32, u32)> + '_ {
        self.sequences.iter().flat_map(|s| {
            s.tokens
                .iter()
                .zip(&s.positions)
                .zip(&s.attention_ids)
                .map(|((&t, &p), &a)| (t, p, a))
        })
    }
}

/// A batch stamped with its (virtual) arrival time — the unit of work the
/// traffic simulator serves.
#[derive(Debug, Clone)]
pub struct TimedBatch {
    /// Arrival time on the virtual clock (seconds).
    pub at: f64,
    pub batch: Batch,
}

/// Deterministic stream of batches from a corpus.
pub struct RequestGenerator {
    corpus: Corpus,
    rng: Rng,
    pub target_tokens: usize,
}

impl RequestGenerator {
    pub fn new(corpus: Corpus, seed: u64, target_tokens: usize) -> Self {
        Self {
            corpus,
            rng: Rng::new(seed),
            target_tokens,
        }
    }

    pub fn corpus(&self) -> &Corpus {
        &self.corpus
    }

    pub fn next_batch(&mut self) -> Batch {
        let seqs = self.corpus.sample_tokens(&mut self.rng, self.target_tokens);
        Batch::from_sequences(seqs)
    }

    /// Generate a profiling set of `n` batches (the "at least 100 samples"
    /// the key-value dataset table is built from; §III-A).
    pub fn profile_set(&mut self, n: usize) -> Vec<Batch> {
        (0..n).map(|_| self.next_batch()).collect()
    }

    /// One batch with an explicit token target (trace replay, where each
    /// request carries its own size).
    pub fn batch_with_tokens(&mut self, target_tokens: usize) -> Batch {
        let seqs = self.corpus.sample_tokens(&mut self.rng, target_tokens.max(1));
        Batch::from_sequences(seqs)
    }

    /// One batch per arrival timestamp — how the traffic arrival processes
    /// and trace replay emit timestamped work through the generator.
    pub fn timed_batches(&mut self, arrivals: &[f64]) -> Vec<TimedBatch> {
        arrivals
            .iter()
            .map(|&at| TimedBatch {
                at,
                batch: self.next_batch(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::CorpusPreset;

    #[test]
    fn batch_reaches_target() {
        let c = Corpus::new(CorpusPreset::Enwik8, 1);
        let mut g = RequestGenerator::new(c, 2, 2048);
        let b = g.next_batch();
        assert!(b.total_tokens >= 2048);
        assert_eq!(b.total_tokens, b.tokens().count());
    }

    #[test]
    fn batches_differ() {
        let c = Corpus::new(CorpusPreset::Enwik8, 1);
        let mut g = RequestGenerator::new(c, 2, 512);
        let b1 = g.next_batch();
        let b2 = g.next_batch();
        assert_ne!(
            b1.sequences[0].tokens, b2.sequences[0].tokens,
            "successive batches should not repeat"
        );
    }

    #[test]
    fn generator_deterministic() {
        let mk = || {
            let c = Corpus::new(CorpusPreset::CcNews, 1);
            RequestGenerator::new(c, 9, 512)
        };
        let b1 = mk().next_batch();
        let b2 = mk().next_batch();
        assert_eq!(b1.sequences[0].tokens, b2.sequences[0].tokens);
    }
}
