//! Synthetic corpora substituting the paper's datasets.
//!
//! Natural-language token frequency is Zipf-distributed; expert-popularity
//! skew in MoE models follows from routing a Zipf token stream through a
//! token-conditioned gate. Each `CorpusPreset` (Enwik8/CCnews/Wmt19/Lambada
//! stand-ins) uses a distinct vocabulary size, Zipf exponent and sequence
//! length, producing distinct skews — which is what Fig. 10's cross-dataset
//! comparison exercises.
//!
//! Sequences are generated with first-order structure (bigram affinity) so
//! that the *attention ID* feature (§III-B: the token ID receiving the
//! highest summed attention score) carries real signal: a token's most-
//! attended neighbour is correlated with, but not determined by, its own ID.

use crate::config::workload::CorpusPreset;
use crate::util::rng::{Rng, Zipf};

/// One tokenized sequence plus its derived per-token features.
#[derive(Debug, Clone)]
pub struct Sequence {
    /// Token IDs (f1).
    pub tokens: Vec<u32>,
    /// Position IDs (f2) — just 0..len, kept explicit for clarity.
    pub positions: Vec<u32>,
    /// Attention IDs (f3): for each position, the token ID of the sequence
    /// element with the highest (simulated or measured) summed attention
    /// score. The simulated rule mirrors locality + frequency bias of real
    /// attention; the real path overwrites this from the PJRT attention
    /// kernel output.
    pub attention_ids: Vec<u32>,
}

impl Sequence {
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }
}

/// Synthetic corpus: a Zipf unigram model with bigram affinity.
#[derive(Debug, Clone)]
pub struct Corpus {
    pub preset: CorpusPreset,
    pub vocab: usize,
    pub seq_len: usize,
    zipf: Zipf,
    /// Token-rank permutation: rank→token-id, so frequent tokens are not
    /// simply ids 0..k (mirrors a real tokenizer's arbitrary id order).
    rank_to_id: Vec<u32>,
    id_to_rank: Vec<u32>,
}

impl Corpus {
    pub fn new(preset: CorpusPreset, seed: u64) -> Self {
        let (vocab, alpha, seq_len) = preset.params();
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        let mut rank_to_id: Vec<u32> = (0..vocab as u32).collect();
        rng.shuffle(&mut rank_to_id);
        let mut id_to_rank = vec![0u32; vocab];
        for (rank, &id) in rank_to_id.iter().enumerate() {
            id_to_rank[id as usize] = rank as u32;
        }
        Self {
            preset,
            vocab,
            seq_len,
            zipf: Zipf::new(vocab, alpha),
            rank_to_id,
            id_to_rank,
        }
    }

    /// Empirical frequency of a token ID under the corpus model — this is
    /// the P'(f) prior the posterior calculation (Eq. 1) uses.
    pub fn token_prob(&self, token_id: u32) -> f64 {
        self.zipf.pmf(self.id_to_rank[token_id as usize] as usize)
    }

    /// Draw one sequence. Bigram affinity: with probability `p_repeat` the
    /// next token is drawn near the previous token's rank (topical
    /// coherence); otherwise fresh from the Zipf unigram model.
    pub fn sample_sequence(&self, rng: &mut Rng) -> Sequence {
        let n = self.seq_len;
        let mut tokens = Vec::with_capacity(n);
        let p_repeat = 0.35;
        for t in 0..n {
            let id = if t > 0 && rng.chance(p_repeat) {
                // Perturb the previous token's rank by a small offset.
                let prev_rank = self.id_to_rank[tokens[t - 1] as usize] as i64;
                let delta = rng.range_u64(0, 16) as i64 - 8;
                let rank = (prev_rank + delta).clamp(0, self.vocab as i64 - 1) as usize;
                self.rank_to_id[rank]
            } else {
                self.rank_to_id[self.zipf.sample(rng)]
            };
            tokens.push(id);
        }
        let positions = (0..n as u32).collect();
        let attention_ids = simulated_attention_ids(&tokens, &self.id_to_rank);
        Sequence {
            tokens,
            positions,
            attention_ids,
        }
    }

    /// Sample sequences until at least `min_tokens` tokens are collected.
    pub fn sample_tokens(&self, rng: &mut Rng, min_tokens: usize) -> Vec<Sequence> {
        let mut seqs = Vec::new();
        let mut total = 0;
        while total < min_tokens {
            let s = self.sample_sequence(rng);
            total += s.len();
            seqs.push(s);
        }
        seqs
    }
}

/// Simulated attention-ID rule: each position attends over a local window
/// with weight ∝ token frequency (frequent/"content-hub" tokens accumulate
/// attention mass, mirroring how real attention concentrates). The attention
/// ID of position t is the token ID of the window element with the highest
/// score, excluding t itself when the window has other members.
pub fn simulated_attention_ids(tokens: &[u32], id_to_rank: &[u32]) -> Vec<u32> {
    let n = tokens.len();
    let window = 8usize;
    let mut out = Vec::with_capacity(n);
    for t in 0..n {
        let lo = t.saturating_sub(window);
        let hi = (t + window + 1).min(n);
        let mut best_score = f64::NEG_INFINITY;
        let mut best_id = tokens[t];
        for u in lo..hi {
            if u == t && hi - lo > 1 {
                continue;
            }
            // Score: frequency bias (low rank = frequent) + distance decay.
            let rank = id_to_rank[tokens[u] as usize] as f64;
            let dist = (t as f64 - u as f64).abs();
            let score = -((rank + 1.0).ln()) - 0.15 * dist;
            if score > best_score {
                best_score = score;
                best_id = tokens[u];
            }
        }
        out.push(best_id);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        Corpus::new(CorpusPreset::Enwik8, 1)
    }

    #[test]
    fn sequence_shape() {
        let c = corpus();
        let mut rng = Rng::new(2);
        let s = c.sample_sequence(&mut rng);
        assert_eq!(s.len(), c.seq_len);
        assert_eq!(s.positions.len(), s.tokens.len());
        assert_eq!(s.attention_ids.len(), s.tokens.len());
        assert!(s.tokens.iter().all(|&t| (t as usize) < c.vocab));
    }

    #[test]
    fn deterministic_given_seed() {
        let c = corpus();
        let s1 = c.sample_sequence(&mut Rng::new(7));
        let s2 = c.sample_sequence(&mut Rng::new(7));
        assert_eq!(s1.tokens, s2.tokens);
        assert_eq!(s1.attention_ids, s2.attention_ids);
    }

    #[test]
    fn token_probs_sum_to_one() {
        let c = corpus();
        let total: f64 = (0..c.vocab as u32).map(|id| c.token_prob(id)).sum();
        assert!((total - 1.0).abs() < 1e-6, "total={total}");
    }

    #[test]
    fn zipf_skew_visible() {
        // The most frequent token should appear far more often than median.
        let c = corpus();
        let mut rng = Rng::new(3);
        let mut counts = vec![0usize; c.vocab];
        for _ in 0..200 {
            for &t in &c.sample_sequence(&mut rng).tokens {
                counts[t as usize] += 1;
            }
        }
        let max = *counts.iter().max().unwrap();
        let nonzero = counts.iter().filter(|&&c| c > 0).count();
        assert!(max > 50, "max={max}");
        assert!(nonzero > 100, "nonzero={nonzero}");
    }

    #[test]
    fn sample_tokens_reaches_target() {
        let c = corpus();
        let mut rng = Rng::new(5);
        let seqs = c.sample_tokens(&mut rng, 1000);
        let total: usize = seqs.iter().map(Sequence::len).sum();
        assert!(total >= 1000);
    }

    #[test]
    fn attention_ids_from_window() {
        // Attention IDs must be token IDs occurring inside the sequence.
        let c = corpus();
        let mut rng = Rng::new(11);
        let s = c.sample_sequence(&mut rng);
        for (t, &aid) in s.attention_ids.iter().enumerate() {
            let lo = t.saturating_sub(8);
            let hi = (t + 9).min(s.len());
            assert!(
                s.tokens[lo..hi].contains(&aid),
                "attention id {aid} not in window at {t}"
            );
        }
    }

    #[test]
    fn same_token_id_different_attention_ids() {
        // Fig. 3 precondition: one token ID occurs with *different* attention
        // contexts, so ID alone cannot identify the routing outcome.
        let c = corpus();
        let mut rng = Rng::new(13);
        let seqs = c.sample_tokens(&mut rng, 20_000);
        use std::collections::HashMap;
        let mut ctx: HashMap<u32, std::collections::HashSet<u32>> = HashMap::new();
        for s in &seqs {
            for (i, &t) in s.tokens.iter().enumerate() {
                ctx.entry(t).or_default().insert(s.attention_ids[i]);
            }
        }
        let multi = ctx.values().filter(|set| set.len() > 1).count();
        assert!(multi > 50, "tokens with >1 attention context: {multi}");
    }
}
