//! The MoE serving service: composes the PJRT stages into full-model
//! inference with real token→expert routing and per-function billing.
//!
//! Each expert invocation is treated as one serverless-function execution:
//! its measured wall time × the expert's configured memory is metered into
//! the billed cost, mirroring the platform simulator's pricing (Eq. 4 over
//! *measured* rather than modeled times).

use super::batcher::{chunks, gather_rows, pad_rows, scatter_rows_scaled};
use super::metrics::ServingMetrics;
use crate::config::PlatformConfig;
use crate::gating::TokenFeature;
use crate::runtime::tensor::{i32_literal, literal_to_i32, Tensor};
use crate::runtime::{Engine, WeightStore};
use anyhow::Result;
use std::time::Instant;

/// Per-expert memory configuration (from a deployment policy); defaults to
/// max memory for every expert (the LambdaML setting).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// mem_mb[layer][expert]
    pub expert_mem_mb: Vec<Vec<u64>>,
    pub top_k: usize,
}

impl ServiceConfig {
    pub fn uniform(layers: usize, experts: usize, mem_mb: u64, top_k: usize) -> Self {
        Self {
            expert_mem_mb: vec![vec![mem_mb; experts]; layers],
            top_k,
        }
    }
}

/// Output of serving one sequence.
#[derive(Debug, Clone)]
pub struct SequenceResult {
    /// Final hidden states [S, H].
    pub hidden: Tensor,
    /// Per-layer token features observed during inference (real attention
    /// IDs) — feeds profiling of the *real* model.
    pub features: Vec<Vec<TokenFeature>>,
    /// Per-layer expert assignment counts.
    pub expert_counts: Vec<Vec<u64>>,
    /// Per-layer per-token top-k expert assignments (routing ground truth
    /// from the real gate — profiled into the dataset table).
    pub assignments: Vec<Vec<Vec<u8>>>,
}

pub struct MoeService {
    pub engine: Engine,
    pub weights: WeightStore,
    pub platform: PlatformConfig,
    pub config: ServiceConfig,
    pub metrics: ServingMetrics,
    /// §Perf: weight Literals converted once at startup — re-encoding every
    /// blob per request cost ~35% of serve_sequence wall time.
    literal_cache: std::collections::HashMap<String, xla::Literal>,
}

impl MoeService {
    pub fn new(artifacts_dir: &std::path::Path, platform: PlatformConfig) -> Result<MoeService> {
        let engine = Engine::new(artifacts_dir)?;
        let weights = WeightStore::load(artifacts_dir)?;
        let cfg = &engine.manifest.config;
        let config = ServiceConfig::uniform(
            cfg.moe_layers,
            cfg.experts,
            platform.max_memory_mb(),
            cfg.top_k,
        );
        let mut literal_cache = std::collections::HashMap::new();
        for (name, tensor) in &weights.weights {
            literal_cache.insert(name.clone(), tensor.to_literal()?);
        }
        Ok(MoeService {
            engine,
            weights,
            platform,
            config,
            metrics: ServingMetrics::new(),
            literal_cache,
        })
    }

    /// Cached weight literal (cloning a Literal is a cheap handle copy
    /// relative to re-encoding the host buffer).
    fn wlit(&self, name: &str) -> Result<xla::Literal> {
        self.literal_cache
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("missing weight '{name}'"))
    }

    fn bill(&mut self, stage: &str, mem_mb: u64, secs: f64) {
        self.metrics.record_stage(stage, secs);
        self.metrics.billed_cost +=
            self.platform.run_cost(mem_mb, secs) + self.platform.price_per_invocation;
    }

    /// Serve one token sequence (ids padded/truncated to max_seq).
    pub fn serve_sequence(&mut self, token_ids: &[u32]) -> Result<SequenceResult> {
        let t_start = Instant::now();
        let meta = self.engine.manifest.config.clone();
        let s = meta.max_seq;
        let h = meta.hidden;
        let mut ids: Vec<i32> = token_ids.iter().map(|&t| t as i32).collect();
        ids.resize(s, 0);

        // ---- embed ----
        let t0 = Instant::now();
        let wte = self.wlit("wte")?;
        let wpe = self.wlit("wpe")?;
        let out = self
            .engine
            .execute(&format!("embed_s{s}"), &[i32_literal(&ids), wte, wpe])?;
        let mut x = Tensor::from_literal(&out[0], vec![s, h])?;
        let max_mem = self.platform.max_memory_mb();
        self.bill("embed", max_mem, t0.elapsed().as_secs_f64());

        let mut features: Vec<Vec<TokenFeature>> = Vec::with_capacity(meta.moe_layers);
        let mut expert_counts: Vec<Vec<u64>> = Vec::with_capacity(meta.moe_layers);
        let mut assignments: Vec<Vec<Vec<u8>>> = Vec::with_capacity(meta.moe_layers);

        for l in 0..meta.moe_layers {
            // ---- attention (non-MoE block) + attention IDs ----
            let t0 = Instant::now();
            let args = vec![
                x.to_literal()?,
                self.wlit(&format!("l{l}.wq"))?,
                self.wlit(&format!("l{l}.wk"))?,
                self.wlit(&format!("l{l}.wv"))?,
                self.wlit(&format!("l{l}.wo"))?,
            ];
            let out = self.engine.execute(&format!("attention_s{s}"), &args)?;
            let y = Tensor::from_literal(&out[0], vec![s, h])?;
            let amax = literal_to_i32(&out[1])?;
            self.bill(&format!("nonmoe-{l}"), max_mem, t0.elapsed().as_secs_f64());

            // Real token features: attention ID = token id at argmax source.
            let feats: Vec<TokenFeature> = (0..s)
                .map(|t| TokenFeature {
                    token_id: ids[t] as u32,
                    position_id: t as u32,
                    attention_id: ids[amax[t] as usize] as u32,
                })
                .collect();

            // ---- gating ----
            let t0 = Instant::now();
            let bucket = self.engine.manifest.bucket_for(s);
            let xpad = pad_rows(&y.data, s, h, bucket);
            let gargs = vec![
                Tensor::new(xpad, vec![bucket, h]).to_literal()?,
                self.wlit(&format!("l{l}.wg"))?,
            ];
            let out = self.engine.execute(&format!("gating_t{bucket}"), &gargs)?;
            let probs = Tensor::from_literal(&out[0], vec![bucket, meta.experts])?;
            self.bill(&format!("gate-{l}"), max_mem, t0.elapsed().as_secs_f64());

            // ---- top-k routing (coordinator-side) ----
            let k = self.config.top_k;
            let mut per_expert_idx: Vec<Vec<usize>> = vec![Vec::new(); meta.experts];
            let mut per_expert_w: Vec<Vec<f32>> = vec![Vec::new(); meta.experts];
            let mut layer_assignments: Vec<Vec<u8>> = Vec::with_capacity(s);
            for t in 0..s {
                let row = probs.row(t);
                let sel = crate::gating::top_k_indices(
                    &row.iter().map(|&p| p as f64).collect::<Vec<_>>(),
                    k,
                );
                let mass: f32 = sel.iter().map(|&i| row[i as usize]).sum();
                for &i in &sel {
                    per_expert_idx[i as usize].push(t);
                    per_expert_w[i as usize].push(row[i as usize] / mass.max(1e-9));
                }
                layer_assignments.push(sel);
            }
            assignments.push(layer_assignments);
            expert_counts.push(per_expert_idx.iter().map(|v| v.len() as u64).collect());

            // ---- expert functions (scatter → FFN → gather) ----
            let mut moe_out = vec![0.0f32; s * h];
            for e in 0..meta.experts {
                let idx = &per_expert_idx[e];
                if idx.is_empty() {
                    continue;
                }
                let mem = self.config.expert_mem_mb[l][e];
                let rows = gather_rows(&y.data, h, idx);
                let mut done = 0usize;
                for chunk in chunks(idx.len(), self.engine.manifest.max_bucket()) {
                    let t0 = Instant::now();
                    let bucket = self.engine.manifest.bucket_for(chunk);
                    let xchunk = &rows[done * h..(done + chunk) * h];
                    let xpad = pad_rows(xchunk, chunk, h, bucket);
                    let eargs = vec![
                        Tensor::new(xpad, vec![bucket, h]).to_literal()?,
                        self.wlit(&format!("l{l}.e{e}.w1"))?,
                        self.wlit(&format!("l{l}.e{e}.b1"))?,
                        self.wlit(&format!("l{l}.e{e}.w2"))?,
                        self.wlit(&format!("l{l}.e{e}.b2"))?,
                    ];
                    let out = self
                        .engine
                        .execute(&format!("expert_ffn_t{bucket}"), &eargs)?;
                    let yexp = Tensor::from_literal(&out[0], vec![bucket, h])?;
                    scatter_rows_scaled(
                        &mut moe_out,
                        h,
                        &idx[done..done + chunk],
                        &yexp.data[..chunk * h],
                        &per_expert_w[e][done..done + chunk],
                    );
                    self.bill(
                        &format!("expert-{l}-{e}"),
                        mem,
                        t0.elapsed().as_secs_f64(),
                    );
                    done += chunk;
                }
            }

            // Residual combine: x = y + moe_out.
            let mut next = y.data.clone();
            for (a, &b) in next.iter_mut().zip(&moe_out) {
                *a += b;
            }
            x = Tensor::new(next, vec![s, h]);
            features.push(feats);
        }

        self.metrics
            .record_request(t_start.elapsed().as_secs_f64(), token_ids.len() as u64);
        Ok(SequenceResult {
            hidden: x,
            features,
            expert_counts,
            assignments,
        })
    }
}
