//! Threaded request loop: a leader thread owns the PJRT engine (executables
//! are not shared across threads); clients submit sequences over a channel
//! and receive results over per-request reply channels — the vLLM-router
//! pattern scaled to this repo.

use super::metrics::ServingMetrics;
use super::service::MoeService;
use crate::config::PlatformConfig;
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

pub struct ServeRequest {
    pub token_ids: Vec<u32>,
    pub reply: mpsc::Sender<ServeResponse>,
}

#[derive(Debug, Clone)]
pub struct ServeResponse {
    /// L2 norm of the final hidden states (summary of the model output).
    pub output_norm: f64,
    pub expert_counts: Vec<Vec<u64>>,
    pub latency: f64,
}

pub struct Server {
    tx: mpsc::Sender<ServerMsg>,
    handle: Option<JoinHandle<ServingMetrics>>,
}

enum ServerMsg {
    Request(ServeRequest),
    Shutdown,
}

impl Server {
    /// Start the leader thread; compiles all stages before accepting work.
    pub fn start(artifacts_dir: PathBuf, platform: PlatformConfig) -> anyhow::Result<Server> {
        let (tx, rx) = mpsc::channel::<ServerMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let handle = std::thread::spawn(move || {
            let mut service = match MoeService::new(&artifacts_dir, platform) {
                Ok(mut s) => {
                    let r = s.engine.load_all().map(|_| ());
                    let ok = r.is_ok();
                    ready_tx.send(r).ok();
                    if !ok {
                        return ServingMetrics::new();
                    }
                    s
                }
                Err(e) => {
                    ready_tx.send(Err(e)).ok();
                    return ServingMetrics::new();
                }
            };
            while let Ok(msg) = rx.recv() {
                match msg {
                    ServerMsg::Shutdown => break,
                    ServerMsg::Request(req) => {
                        let t0 = std::time::Instant::now();
                        match service.serve_sequence(&req.token_ids) {
                            Ok(res) => {
                                let norm = res
                                    .hidden
                                    .data
                                    .iter()
                                    .map(|&x| (x as f64) * (x as f64))
                                    .sum::<f64>()
                                    .sqrt();
                                req.reply
                                    .send(ServeResponse {
                                        output_norm: norm,
                                        expert_counts: res.expert_counts,
                                        latency: t0.elapsed().as_secs_f64(),
                                    })
                                    .ok();
                            }
                            Err(e) => {
                                crate::util::log::log(
                                    crate::util::log::Level::Error,
                                    &format!("serve error: {e:#}"),
                                );
                            }
                        }
                    }
                }
            }
            service.metrics
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server thread died during startup"))??;
        Ok(Server {
            tx,
            handle: Some(handle),
        })
    }

    /// Submit a request; blocks for the response.
    pub fn serve(&self, token_ids: Vec<u32>) -> anyhow::Result<ServeResponse> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(ServerMsg::Request(ServeRequest {
                token_ids,
                reply: reply_tx,
            }))
            .map_err(|_| anyhow::anyhow!("server stopped"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("no response (serve error)"))
    }

    /// Stop and return accumulated metrics.
    pub fn shutdown(mut self) -> ServingMetrics {
        self.tx.send(ServerMsg::Shutdown).ok();
        self.handle
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default()
    }
}
