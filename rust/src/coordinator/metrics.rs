//! Serving metrics: per-stage wall times, billed-cost accounting, latency
//! percentiles and throughput.

use crate::util::stats;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct ServingMetrics {
    /// Wall seconds per stage name (embed, attention, gating, expert-l0-e2…).
    pub stage_secs: BTreeMap<String, f64>,
    /// Per-request end-to-end latencies.
    pub request_latencies: Vec<f64>,
    pub tokens_served: u64,
    /// Billed cost accumulated from (memory × measured time) per function.
    pub billed_cost: f64,
    pub invocations: u64,
}

impl ServingMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_stage(&mut self, stage: &str, secs: f64) {
        *self.stage_secs.entry(stage.to_string()).or_default() += secs;
        self.invocations += 1;
    }

    pub fn record_request(&mut self, latency: f64, tokens: u64) {
        self.request_latencies.push(latency);
        self.tokens_served += tokens;
    }

    pub fn throughput_tps(&self) -> f64 {
        let total: f64 = self.request_latencies.iter().sum();
        if total > 0.0 {
            self.tokens_served as f64 / total
        } else {
            0.0
        }
    }

    pub fn p50(&self) -> f64 {
        stats::percentile(&self.request_latencies, 50.0)
    }

    pub fn p95(&self) -> f64 {
        stats::percentile(&self.request_latencies, 95.0)
    }

    pub fn p99(&self) -> f64 {
        stats::percentile(&self.request_latencies, 99.0)
    }

    pub fn merge(&mut self, other: &ServingMetrics) {
        for (k, v) in &other.stage_secs {
            *self.stage_secs.entry(k.clone()).or_default() += v;
        }
        self.request_latencies
            .extend_from_slice(&other.request_latencies);
        self.tokens_served += other.tokens_served;
        self.billed_cost += other.billed_cost;
        self.invocations += other.invocations;
    }

    pub fn summary(&self) -> String {
        format!(
            "requests={} tokens={} tput={:.1} tok/s p50={} p95={} p99={} cost=${:.6} invocations={}",
            self.request_latencies.len(),
            self.tokens_served,
            self.throughput_tps(),
            crate::util::table::ftime(self.p50()),
            crate::util::table::ftime(self.p95()),
            crate::util::table::ftime(self.p99()),
            self.billed_cost,
            self.invocations,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut m = ServingMetrics::new();
        m.record_stage("embed", 0.1);
        m.record_stage("embed", 0.2);
        m.record_request(0.5, 64);
        m.record_request(1.5, 64);
        assert!((m.stage_secs["embed"] - 0.3).abs() < 1e-12);
        assert_eq!(m.tokens_served, 128);
        assert!((m.throughput_tps() - 64.0).abs() < 1e-9);
        assert!((m.p50() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn merge_sums() {
        let mut a = ServingMetrics::new();
        a.record_stage("x", 1.0);
        a.record_request(0.1, 10);
        let mut b = ServingMetrics::new();
        b.record_stage("x", 2.0);
        b.billed_cost = 0.5;
        a.merge(&b);
        assert!((a.stage_secs["x"] - 3.0).abs() < 1e-12);
        assert_eq!(a.billed_cost, 0.5);
    }
}
