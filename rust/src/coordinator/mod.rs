//! L3 serving coordinator: the request path that composes the AOT-compiled
//! stages (embed → attention → gating → expert FFN) into MoE inference,
//! with token→expert routing, bucket batching, scatter-gather accounting
//! against the platform simulator, and a threaded request loop.
//!
//! Python never runs here: every numeric stage is a PJRT executable loaded
//! from `artifacts/`.

pub mod batcher;
pub mod metrics;
pub mod server;
pub mod service;

pub use metrics::ServingMetrics;
pub use server::{ServeRequest, ServeResponse, Server};
pub use service::MoeService;
