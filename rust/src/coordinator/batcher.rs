//! Bucket batching: expert minibatches are padded to the nearest compiled
//! token bucket (executables have static shapes), and oversized loads are
//! chunked at the largest bucket — the pipeline-degree β of the serving
//! path.

/// Split `n` tokens into chunks of at most `max_bucket`.
pub fn chunks(n: usize, max_bucket: usize) -> Vec<usize> {
    assert!(max_bucket > 0);
    let mut out = Vec::new();
    let mut left = n;
    while left > 0 {
        let take = left.min(max_bucket);
        out.push(take);
        left -= take;
    }
    out
}

/// Pad a row-major [n, width] activation to [bucket, width] with zeros.
pub fn pad_rows(data: &[f32], n: usize, width: usize, bucket: usize) -> Vec<f32> {
    assert_eq!(data.len(), n * width);
    assert!(bucket >= n);
    let mut out = Vec::with_capacity(bucket * width);
    out.extend_from_slice(data);
    out.resize(bucket * width, 0.0);
    out
}

/// Gather the rows at `idx` from a [rows, width] tensor.
pub fn gather_rows(data: &[f32], width: usize, idx: &[usize]) -> Vec<f32> {
    let mut out = Vec::with_capacity(idx.len() * width);
    for &i in idx {
        out.extend_from_slice(&data[i * width..(i + 1) * width]);
    }
    out
}

/// Scatter-add rows back: out[idx[j]] += scale[j] * rows[j].
pub fn scatter_rows_scaled(
    out: &mut [f32],
    width: usize,
    idx: &[usize],
    rows: &[f32],
    scale: &[f32],
) {
    assert_eq!(idx.len(), scale.len());
    for (j, &i) in idx.iter().enumerate() {
        let src = &rows[j * width..(j + 1) * width];
        let dst = &mut out[i * width..(i + 1) * width];
        let s = scale[j];
        for (d, &x) in dst.iter_mut().zip(src) {
            *d += s * x;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunking() {
        assert_eq!(chunks(0, 256), Vec::<usize>::new());
        assert_eq!(chunks(100, 256), vec![100]);
        assert_eq!(chunks(600, 256), vec![256, 256, 88]);
        assert_eq!(chunks(512, 256), vec![256, 256]);
    }

    #[test]
    fn padding() {
        let d = vec![1.0, 2.0, 3.0, 4.0];
        let p = pad_rows(&d, 2, 2, 4);
        assert_eq!(p.len(), 8);
        assert_eq!(&p[..4], &d[..]);
        assert!(p[4..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn gather_scatter_roundtrip() {
        // 4 rows of width 2.
        let data: Vec<f32> = (0..8).map(|x| x as f32).collect();
        let idx = [3usize, 1];
        let g = gather_rows(&data, 2, &idx);
        assert_eq!(g, vec![6.0, 7.0, 2.0, 3.0]);
        let mut out = vec![0.0; 8];
        scatter_rows_scaled(&mut out, 2, &idx, &g, &[1.0, 0.5]);
        assert_eq!(out[6], 6.0);
        assert_eq!(out[2], 1.0);
        assert_eq!(out[0], 0.0);
    }
}
