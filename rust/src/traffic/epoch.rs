//! The epoch-based serving loop: serve traffic against the current
//! deployment with warm/cold starts derived from the `WarmPool` virtual
//! clock and per-instance FIFO queueing under bounded concurrency, absorb
//! realized routing into the predictor's dataset table, and at epoch
//! boundaries (a) let the autoscaler nudge per-expert replica counts and
//! (b) re-run ODS (optionally after a BO refinement round) when realized
//! expert popularity has drifted from the distribution the deployment was
//! sized for. Re-deployment is not free: the ≥60 s gap of §II Challenge 1
//! blocks serving, and the fresh instances either start cold or are billed
//! a warm-up pass.
//!
//! Queueing model: a request becomes ready at `max(arrival,
//! redeploy_ready)`; each replica it routes tokens to is dispatched through
//! that instance's FIFO slot queue ([`WarmPool::admit`]), with warm/cold
//! judged at the instance's actual start time. The request completes when
//! its slowest replica finishes plus the non-replica latency tail
//! (scatter/gather stages, next-layer load) of the analytic model. With
//! unbounded concurrency every dispatch starts at the ready time and the
//! loop reproduces the PR 1 serving path bit-for-bit (pinned by the
//! cross-validation tests).
//!
//! Two dispatch engines implement this model (selected by
//! [`TrafficConfig::engine`]): the event-driven, optionally layer-pipelined
//! engine in [`super::sim`] (the default — layer *k+1* of a request is
//! dispatched when layer *k* completes, so later layers' queue waits overlap
//! earlier layers' compute across concurrent requests), and the legacy PR 2
//! serial loop kept here ([`SimEngine::Legacy`]), which dispatches all of a
//! request's layers monolithically at its ready time. With pipelining
//! disabled the event engine reproduces the legacy loop bit-for-bit (pinned
//! at 1e-6 by the cross-validation tests in `rust/tests/traffic.rs`).

pub use super::config::{MetricsMode, SimEngine, TrafficConfig};

use super::autoscale::Autoscaler;
use super::report::SimReport;
use crate::bo::algorithm::BoAlgorithm;
use crate::bo::eps_greedy::MultiEpsGreedy;
use crate::bo::feedback::serve_with_warmness_detailed;
use crate::config::{BoConfig, DeployConfig, PlatformConfig};
use crate::deploy::baselines::lambdaml_policy;
use crate::deploy::ods::ods_full;
use crate::deploy::DeploymentPolicy;
use crate::gating::{RouterCache, SimGate};
use crate::model::MoeModelSpec;
use crate::platform::{InstancePool, ReplicaKey, WarmPool};
use crate::predictor::eval::{predicted_counts, real_counts};
use crate::predictor::profile::absorb_batch;
use crate::predictor::BayesPredictor;
use crate::util::stats;
use crate::workload::{Batch, TimedBatch};
use std::collections::HashMap;

/// The epoch-based traffic simulator. Owns the (online-updated) predictor;
/// borrows the static context.
pub struct EpochSimulator<'a> {
    pub platform: &'a PlatformConfig,
    pub spec: &'a MoeModelSpec,
    pub gate: &'a SimGate,
    pub predictor: BayesPredictor,
    pub cfg: TrafficConfig,
    /// Deployment in effect when the last run finished (cross-validation
    /// hooks compare it against the flat pipeline).
    pub last_policy: Option<DeploymentPolicy>,
    /// Virtual times at which re-deployments were triggered.
    pub redeploy_times: Vec<f64>,
    /// `(virtual time, replicas added (+) / reaped (-))` autoscaler actions
    /// of the last run.
    pub autoscale_events: Vec<(f64, i64)>,
    /// Per-request latency of the last run, indexed in arrival order —
    /// populated under [`MetricsMode::Exact`] (empty under streaming). The
    /// pipelined-vs-monolithic dominance tests compare runs request by
    /// request through this.
    pub last_latencies: Vec<f64>,
    /// Every deployment the last run served under, in order: the initial
    /// policy followed by one entry per drift-triggered re-deployment
    /// (replica-count nudges by the autoscaler mutate the current entry's
    /// successor in place and are tracked via [`Self::autoscale_events`]).
    /// Surfaced to callers as `scenario::RunArtifacts::policy_history`.
    pub policy_history: Vec<DeploymentPolicy>,
    /// Memoized token routing shared by the serving engines and the online
    /// absorb path; persists across runs (the gate is fixed for the
    /// simulator's lifetime, so entries never go stale).
    pub(crate) router: RouterCache,
    /// Autoregressive decode schedule for chat traffic (`None` for the
    /// classic one-pass workloads): per-request decode lengths and per-step
    /// token batches, indexed by arrival order. Consumed by the event
    /// engine; the legacy serial loop ignores it (chat scenarios require
    /// the pipelined event engine at validation time).
    pub(crate) chat: Option<&'a crate::traffic::workload::ChatWorkload>,
}

/// Per-layer popularity fractions (uniform for an all-zero layer).
pub(crate) fn fractions(counts: &[Vec<u64>]) -> Vec<Vec<f64>> {
    let mut out = Vec::new();
    fractions_into(counts, &mut out);
    out
}

/// [`fractions`] into a caller-owned buffer — the event engine's hot
/// arrival/decode path calls this once per routed batch, so reusing the
/// per-lane scratch rows keeps the loop allocation-free after warm-up.
pub(crate) fn fractions_into(counts: &[Vec<u64>], out: &mut Vec<Vec<f64>>) {
    out.resize_with(counts.len(), Vec::new);
    for (row, frac) in counts.iter().zip(out.iter_mut()) {
        frac.clear();
        let total: u64 = row.iter().sum();
        if total == 0 {
            frac.resize(row.len(), 1.0 / row.len().max(1) as f64);
        } else {
            frac.extend(row.iter().map(|&c| c as f64 / total as f64));
        }
    }
}

/// Mean total-variation distance between two per-layer distributions.
fn tv_distance(a: &[Vec<f64>], b: &[Vec<f64>]) -> f64 {
    if a.is_empty() {
        return 0.0;
    }
    let mut acc = 0.0;
    for (la, lb) in a.iter().zip(b) {
        let d: f64 = la.iter().zip(lb).map(|(&x, &y)| (x - y).abs()).sum();
        acc += 0.5 * d;
    }
    acc / a.len() as f64
}

impl<'a> EpochSimulator<'a> {
    pub fn new(
        platform: &'a PlatformConfig,
        spec: &'a MoeModelSpec,
        gate: &'a SimGate,
        predictor: BayesPredictor,
        cfg: TrafficConfig,
    ) -> EpochSimulator<'a> {
        let router = RouterCache::new(gate);
        EpochSimulator {
            platform,
            spec,
            gate,
            predictor,
            cfg,
            last_policy: None,
            redeploy_times: Vec::new(),
            autoscale_events: Vec::new(),
            last_latencies: Vec::new(),
            policy_history: Vec::new(),
            router,
            chat: None,
        }
    }

    /// Size the initial deployment from the predictor's current beliefs on
    /// the first request (LambdaML over-provisioning as the fallback when
    /// ODS finds nothing feasible).
    pub fn initial_policy(&self, traffic: &[TimedBatch]) -> DeploymentPolicy {
        let counts: Vec<Vec<u64>> = match traffic.first() {
            Some(tb) => predicted_counts(self.gate, &self.predictor, &tb.batch),
            None => (0..self.spec.num_moe_layers())
                .map(|e| vec![1; self.spec.experts_at(e)])
                .collect(),
        };
        let problem = self.cfg.problem(self.platform, self.spec, counts);
        match ods_full(&problem, self.cfg.solver_time_limit) {
            Some(o) => o.policy,
            None => lambdaml_policy(&problem),
        }
    }

    /// Deploy from current predictions, then serve the whole traffic stream.
    pub fn run(&mut self, traffic: &[TimedBatch]) -> SimReport {
        let policy = self.initial_policy(traffic);
        self.run_with_policy(policy, traffic)
    }

    /// Serve `traffic` starting from an explicit deployment (used for the
    /// LambdaML and static-deployment baselines). Dispatches to the engine
    /// selected by [`TrafficConfig::engine`]: the event-driven engine
    /// (default, `traffic::sim`) or the legacy PR 2 serial loop.
    pub fn run_with_policy(
        &mut self,
        policy: DeploymentPolicy,
        traffic: &[TimedBatch],
    ) -> SimReport {
        self.begin_run(&policy);
        match self.cfg.engine {
            SimEngine::Legacy => self.run_legacy(policy, traffic),
            SimEngine::Event { pipeline } => self.run_event(policy, traffic, pipeline),
        }
    }

    /// Reset the per-run artifact state and record the starting deployment —
    /// the run prologue shared by [`Self::run_with_policy`] and the fleet
    /// driver (`traffic::fleet`), which drives several simulators' lanes
    /// jointly instead of calling `run_with_policy` per tenant.
    pub(crate) fn begin_run(&mut self, policy: &DeploymentPolicy) {
        assert!(
            self.cfg.epoch_secs > 0.0,
            "epoch_secs must be > 0 (use f64::INFINITY for a single epoch)"
        );
        self.redeploy_times.clear();
        self.autoscale_events.clear();
        self.last_latencies.clear();
        self.policy_history.clear();
        self.policy_history.push(policy.clone());
    }

    /// Shared epoch-boundary machinery of both engines: replica autoscaling,
    /// then (under `reoptimize`) the drift check and full ODS/BO
    /// re-deployment with its ≥60 s availability gap and warm-up billing.
    /// Returns whether the deployment changed (replica counts or a full
    /// redeploy) so the event engine can refresh its scratch plans.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn epoch_boundary(
        &mut self,
        boundary: f64,
        policy: &mut DeploymentPolicy,
        pool: &mut dyn InstancePool,
        autoscaler: &mut Autoscaler,
        last_batch: Option<&Batch>,
        basis: &mut Vec<Vec<f64>>,
        ema: &mut Vec<Vec<f64>>,
        total_cost: &mut f64,
        redeploy_ready: &mut f64,
        redeploys: &mut u64,
    ) -> bool {
        // Replica autoscaling first: the cheap between-redeploy nudge. A
        // successful full re-deployment below overrides whatever it decided.
        let mut changed = autoscaler.rescale(policy, pool, boundary, self.cfg.epoch_secs) > 0;
        if self.cfg.reoptimize {
            if let Some(pb) = last_batch {
                if tv_distance(ema, basis) > self.cfg.drift_threshold {
                    if self.cfg.bo_round_iters > 0 {
                        self.bo_round(pb);
                    }
                    let pred = predicted_counts(self.gate, &self.predictor, pb);
                    let problem = self.cfg.problem(self.platform, self.spec, pred.clone());
                    if let Some(o) = ods_full(&problem, self.cfg.solver_time_limit) {
                        *policy = o.policy;
                        *basis = fractions(&pred);
                        *ema = basis.clone();
                        // Challenge 1: the ≥60 s redeployment gap blocks
                        // serving and tears every instance down. With
                        // `prewarm`, the operator issues warm-up invocations
                        // during the gap (as the paper does before
                        // measuring) — one cold head per replica, billed.
                        pool.reset();
                        autoscaler.reset_epoch();
                        if self.cfg.prewarm {
                            pool.prewarm_plan(&policy.layers);
                            *total_cost += self.warmup_cost(policy);
                        }
                        *redeploy_ready =
                            redeploy_ready.max(boundary + self.platform.deploy_time);
                        self.redeploy_times.push(boundary);
                        self.policy_history.push(policy.clone());
                        *redeploys += 1;
                        changed = true;
                    }
                }
            }
        }
        changed
    }

    /// The PR 2 serial per-request loop ([`SimEngine::Legacy`]): every
    /// request's layers are dispatched monolithically at its ready time.
    fn run_legacy(
        &mut self,
        mut policy: DeploymentPolicy,
        traffic: &[TimedBatch],
    ) -> SimReport {
        let mut pool = WarmPool::with_concurrency(self.cfg.keep_alive, self.cfg.concurrency);
        if self.cfg.prewarm {
            pool.prewarm_plan(&policy.layers);
        }
        let mut autoscaler = Autoscaler::new(self.cfg.autoscale, self.cfg.max_replicas);
        // Popularity the current deployment was sized for, vs realized EMA.
        let plan_counts: Vec<Vec<u64>> = policy
            .layers
            .iter()
            .map(|l| l.experts.iter().map(|ep| ep.tokens).collect())
            .collect();
        let mut basis = fractions(&plan_counts);
        let mut ema = basis.clone();

        let mut total_cost = 0.0f64;
        let mut latencies: Vec<f64> = Vec::with_capacity(traffic.len());
        let mut queue_delays: Vec<f64> = Vec::with_capacity(traffic.len());
        let mut tokens = 0u64;
        let mut violation_batches = 0u64;
        let mut redeploys = 0u64;
        let mut epochs = 0u64;
        let mut redeploy_ready = 0.0f64;
        let mut next_epoch = self.cfg.epoch_secs;
        let mut timeline: Vec<(f64, f64)> = Vec::with_capacity(traffic.len());
        // Borrowed, not cloned: re-optimization only needs to *read* the
        // most recent batch at epoch boundaries, so cloning every batch on
        // the hot path was pure overhead.
        let mut last_batch: Option<&Batch> = None;
        let mut last_finish = 0.0f64;

        for tb in traffic {
            let t = tb.at;

            // ---- epoch boundaries crossed since the previous request ----
            while t >= next_epoch {
                let boundary = next_epoch;
                epochs += 1;
                self.epoch_boundary(
                    boundary,
                    &mut policy,
                    &mut pool,
                    &mut autoscaler,
                    last_batch,
                    &mut basis,
                    &mut ema,
                    &mut total_cost,
                    &mut redeploy_ready,
                    &mut redeploys,
                );
                next_epoch += self.cfg.epoch_secs;
            }

            // ---- serve the request ----
            let ready = t.max(redeploy_ready);
            let real = real_counts(self.gate, &tb.batch);
            // Peek each needed instance's FIFO queue first, so warm/cold is
            // judged at the moment the instance will actually start (an
            // instance that queues past its keep-alive window goes cold).
            // With unbounded concurrency every start is `ready`, so the peek
            // (and its per-request map) is skipped entirely.
            let mut starts: HashMap<ReplicaKey, f64> = HashMap::new();
            if self.cfg.concurrency.is_some() {
                for (l, lp) in policy.layers.iter().enumerate() {
                    for (i, ep) in lp.experts.iter().enumerate() {
                        if real[l][i] == 0 {
                            continue;
                        }
                        for g in 0..ep.replicas {
                            let key = (l, i, g);
                            starts.insert(key, pool.earliest_start(key, ready));
                        }
                    }
                }
            }
            let served = serve_with_warmness_detailed(
                self.platform,
                self.spec,
                &policy,
                &real,
                &mut |l, e, g| {
                    let at = starts.get(&(l, e, g)).copied().unwrap_or(ready);
                    pool.is_warm((l, e, g), at)
                },
            );
            let outcome = &served.outcome;
            // Dispatch each replica's execution through its instance queue
            // (with unbounded concurrency every start is `ready` and this
            // degenerates to the PR 1 path exactly).
            let mut queue_delay = 0.0f64;
            let mut max_service = 0.0f64;
            let mut service_finish = ready;
            for &(key, t_rep) in &served.replica_times {
                let start = pool.admit(key, ready, t_rep);
                debug_assert_eq!(
                    start,
                    starts.get(&key).copied().unwrap_or(ready),
                    "peeked start must match admission"
                );
                queue_delay = queue_delay.max(start - ready);
                max_service = max_service.max(t_rep);
                service_finish = service_finish.max(start + t_rep);
                if autoscaler.enabled() {
                    autoscaler.record(key.0, key.1, t_rep, start - ready);
                }
            }
            // The request's non-replica latency tail (scatter/gather stages,
            // next-layer load) rides on top of the last service finish.
            let tail = (outcome.latency - max_service).max(0.0);
            let finish = service_finish + tail;
            for &(key, _) in &served.replica_times {
                pool.invoke(key, starts.get(&key).copied().unwrap_or(ready), finish);
            }

            total_cost += outcome.cost;
            if !outcome.memory_violations.is_empty() {
                violation_batches += 1;
            }
            latencies.push(finish - t);
            queue_delays.push(queue_delay);
            tokens += tb.batch.total_tokens as u64;
            last_finish = last_finish.max(finish);
            timeline.push((t, total_cost));

            // ---- online feedback: realized routing → table + EMA ----
            absorb_batch(&mut self.predictor.table, self.gate, &mut self.router, &tb.batch);
            let frac = fractions(&real);
            let alpha = self.cfg.ema_alpha;
            for (el, fl) in ema.iter_mut().zip(&frac) {
                for (e, &f) in el.iter_mut().zip(fl) {
                    *e = (1.0 - alpha) * *e + alpha * f;
                }
            }
            last_batch = Some(&tb.batch);
        }

        let mut report = SimReport::from_samples(&latencies, tokens, last_finish, total_cost);
        report.epochs = epochs;
        report.redeploys = redeploys;
        report.warm_invocations = pool.warm_hits;
        report.cold_invocations = pool.cold_starts;
        report.violation_batches = violation_batches;
        report.cost_timeline = timeline;
        report.mean_queue_delay = stats::mean(&queue_delays);
        report.p95_queue_delay = stats::percentile(&queue_delays, 95.0);
        report.max_queue_delay = queue_delays.iter().cloned().fold(0.0, f64::max);
        report.queued_invocations = pool.queued_jobs;
        report.busy_secs = pool.total_busy_secs();
        report.max_utilization = pool.max_utilization(last_finish);
        report.scale_outs = autoscaler.scale_outs;
        report.scale_ins = autoscaler.scale_ins;
        self.autoscale_events = autoscaler.events.clone();
        self.last_policy = Some(policy);
        self.last_latencies = latencies;
        report
    }

    /// Billed cost of warm-up invocations for a fresh deployment: every
    /// replica runs one cold head (start + parameter download).
    fn warmup_cost(&self, policy: &DeploymentPolicy) -> f64 {
        let mut cost = 0.0;
        for (l, lp) in policy.layers.iter().enumerate() {
            let head = crate::comm::timing::head_time(
                self.platform,
                self.spec.layers[l].expert.param_bytes,
                false,
            );
            for ep in &lp.experts {
                cost += self.platform.run_cost(ep.mem_mb, ep.replicas as f64 * head)
                    + ep.replicas as f64 * self.platform.price_per_invocation;
            }
        }
        cost
    }

    /// One online BO refinement round (Alg. 2 at reduced scale): adjust the
    /// dataset table against the most recent batch before re-predicting.
    fn bo_round(&mut self, eval: &crate::workload::Batch) {
        let deploy_cfg = DeployConfig {
            t_limit: self.cfg.t_limit,
            solver_time_limit: self.cfg.solver_time_limit,
            max_replicas: self.cfg.max_replicas,
            beta_grid: self.cfg.beta_grid.clone(),
        };
        let bo_cfg = BoConfig {
            q: 64,
            max_iters: self.cfg.bo_round_iters,
            batches_per_trial: 1,
            ..BoConfig::default()
        };
        let mut bo = BoAlgorithm {
            platform: self.platform,
            deploy_cfg: &deploy_cfg,
            bo_cfg: bo_cfg.clone(),
            spec: self.spec,
            gate: self.gate,
            predictor: BayesPredictor::new(
                self.predictor.table.clone(),
                self.predictor.prior.clone(),
            ),
            eval_batches: vec![eval.clone()],
            solver_time_limit: self.cfg.solver_time_limit,
        };
        let mut acq = MultiEpsGreedy::new(&bo_cfg);
        let outcome = bo.run(&mut acq, true, self.cfg.seed ^ 0xB0);
        bo.commit_best(&outcome);
        self.predictor = bo.predictor;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bo::feedback::serve_with_real_counts;
    use crate::config::workload::CorpusPreset;
    use crate::model::ModelPreset;
    use crate::predictor::profile::profile_batches;
    use crate::workload::{Corpus, RequestGenerator};

    fn setup() -> (PlatformConfig, MoeModelSpec, SimGate, RequestGenerator, BayesPredictor) {
        let platform = PlatformConfig::default();
        let spec = ModelPreset::TinyMoe.spec();
        let gate = SimGate::new(&spec, 7);
        let corpus = Corpus::new(CorpusPreset::Enwik8, 1);
        let mut gen = RequestGenerator::new(corpus, 5, 512);
        let profile = gen.profile_set(6);
        let r = profile_batches(&gate, &profile);
        let predictor = BayesPredictor::new(r.table, r.prior);
        (platform, spec, gate, gen, predictor)
    }

    #[test]
    fn degenerate_single_batch_matches_flat_pipeline() {
        let (platform, spec, gate, mut gen, predictor) = setup();
        let traffic = gen.timed_batches(&[0.0]);
        let mut sim =
            EpochSimulator::new(&platform, &spec, &gate, predictor, TrafficConfig::degenerate());
        let report = sim.run(&traffic);
        assert_eq!(report.requests, 1);
        let policy = sim.last_policy.clone().unwrap();
        let real = real_counts(&gate, &traffic[0].batch);
        let flat = serve_with_real_counts(&platform, &spec, &policy, &real, true);
        let rel = (report.total_cost - flat.cost).abs() / flat.cost;
        assert!(rel < 1e-6, "sim {} vs flat {}", report.total_cost, flat.cost);
        let rel_l = (report.p50_latency - flat.latency).abs() / flat.latency;
        assert!(rel_l < 1e-6, "sim {} vs flat {}", report.p50_latency, flat.latency);
        assert_eq!(report.cold_invocations, 0, "degenerate pool is all-warm");
    }

    #[test]
    fn keep_alive_expiry_causes_cold_starts() {
        let (platform, spec, gate, mut gen, predictor) = setup();
        // Two requests 100 s apart with a 10 s keep-alive and no pre-warm:
        // both must start cold.
        let traffic = gen.timed_batches(&[0.0, 100.0]);
        let mut cfg = TrafficConfig::degenerate();
        cfg.prewarm = false;
        cfg.keep_alive = 10.0;
        let mut sim = EpochSimulator::new(&platform, &spec, &gate, predictor, cfg);
        let report = sim.run(&traffic);
        assert!(report.cold_invocations > 0);
        assert_eq!(report.warm_invocations, 0);
        // Same traffic, generous keep-alive: second request reuses warm
        // instances and total cost drops.
        let (platform2, spec2, gate2, mut gen2, predictor2) = setup();
        let traffic2 = gen2.timed_batches(&[0.0, 100.0]);
        let mut cfg2 = TrafficConfig::degenerate();
        cfg2.prewarm = false;
        cfg2.keep_alive = 1000.0;
        let mut sim2 = EpochSimulator::new(&platform2, &spec2, &gate2, predictor2, cfg2);
        let report2 = sim2.run(&traffic2);
        assert!(report2.warm_invocations > 0);
        assert!(
            report2.total_cost < report.total_cost,
            "warm reuse must be cheaper: {} vs {}",
            report2.total_cost,
            report.total_cost
        );
    }

    #[test]
    fn concurrency_one_queues_overlapping_requests() {
        let (platform, spec, gate, mut gen, predictor) = setup();
        let traffic = gen.timed_batches(&[0.0, 0.1, 0.2]);
        let mut cfg = TrafficConfig::degenerate();
        cfg.concurrency = Some(1);
        let mut sim = EpochSimulator::new(&platform, &spec, &gate, predictor, cfg);
        let policy = sim.initial_policy(&traffic);
        let queued = sim.run_with_policy(policy.clone(), &traffic);

        let (platform2, spec2, gate2, mut gen2, predictor2) = setup();
        let traffic2 = gen2.timed_batches(&[0.0, 0.1, 0.2]);
        let mut sim2 = EpochSimulator::new(
            &platform2,
            &spec2,
            &gate2,
            predictor2,
            TrafficConfig::degenerate(),
        );
        let unbounded = sim2.run_with_policy(policy, &traffic2);

        // Requests 0.1 s apart on instances whose warm head time alone is
        // longer than the gap: the bounded pool must queue.
        assert!(queued.mean_queue_delay > 0.0);
        assert!(queued.queued_invocations > 0);
        assert!(queued.mean_latency > unbounded.mean_latency);
        assert!(queued.max_utilization <= 1.0 + 1e-9);
        assert_eq!(unbounded.mean_queue_delay, 0.0);
        assert_eq!(unbounded.queued_invocations, 0);
        // Billing is busy-time metered: queueing shifts work later but (on
        // an all-warm, never-expiring pool) does not change what is billed.
        let rel = (queued.total_cost - unbounded.total_cost).abs() / unbounded.total_cost;
        assert!(
            rel < 1e-9,
            "queueing must not change all-warm billed cost: {} vs {}",
            queued.total_cost,
            unbounded.total_cost
        );
    }

    #[test]
    fn forced_drift_triggers_redeploy_and_charges_gap() {
        let (platform, spec, gate, mut gen, predictor) = setup();
        let traffic = gen.timed_batches(&[0.0, 10.0, 70.0, 80.0]);
        let mut cfg = TrafficConfig::default();
        cfg.epoch_secs = 60.0;
        cfg.prewarm = false; // no warm-up: post-redeploy instances are cold
        cfg.drift_threshold = -1.0; // any drift (even zero) triggers
        cfg.solver_time_limit = 0.2;
        let mut sim = EpochSimulator::new(&platform, &spec, &gate, predictor, cfg);
        let report = sim.run(&traffic);
        assert!(report.redeploys >= 1, "redeploys: {}", report.redeploys);
        assert_eq!(sim.redeploy_times.len(), report.redeploys as usize);
        // The post-redeploy request waits out the deployment gap: its
        // latency includes (at least) most of deploy_time.
        let post = report.p99_latency;
        assert!(
            post > platform.deploy_time * 0.5,
            "redeploy gap must show up in tail latency: p99={post}"
        );
        // And the torn-down pool causes cold starts afterwards.
        assert!(report.cold_invocations > 0);
    }

    #[test]
    fn prewarmed_redeploy_bills_warmup_not_cold_serving() {
        let (platform, spec, gate, mut gen, predictor) = setup();
        let traffic = gen.timed_batches(&[0.0, 10.0, 70.0, 80.0]);
        let mut cfg = TrafficConfig::default();
        cfg.epoch_secs = 60.0;
        cfg.prewarm = true;
        cfg.drift_threshold = -1.0;
        cfg.solver_time_limit = 0.2;
        let mut sim = EpochSimulator::new(&platform, &spec, &gate, predictor, cfg);
        let report = sim.run(&traffic);
        assert!(report.redeploys >= 1);
        // Warm-up keeps serving warm across the redeploy...
        assert_eq!(report.cold_invocations, 0);
        // ...but the warm-up pass itself is billed: pricier than the same
        // run without any redeploy.
        let (platform2, spec2, gate2, mut gen2, predictor2) = setup();
        let traffic2 = gen2.timed_batches(&[0.0, 10.0, 70.0, 80.0]);
        let mut cfg2 = TrafficConfig::default();
        cfg2.epoch_secs = 60.0;
        cfg2.prewarm = true;
        cfg2.reoptimize = false;
        let mut sim2 = EpochSimulator::new(&platform2, &spec2, &gate2, predictor2, cfg2);
        let baseline = sim2.run(&traffic2);
        assert!(
            report.total_cost > baseline.total_cost,
            "warm-up must be billed: {} vs {}",
            report.total_cost,
            baseline.total_cost
        );
    }

    #[test]
    fn epochs_counted_without_reopt() {
        let (platform, spec, gate, mut gen, predictor) = setup();
        let traffic = gen.timed_batches(&[0.0, 65.0, 130.0]);
        let mut cfg = TrafficConfig::default();
        cfg.reoptimize = false;
        cfg.epoch_secs = 60.0;
        let mut sim = EpochSimulator::new(&platform, &spec, &gate, predictor, cfg);
        let report = sim.run(&traffic);
        assert_eq!(report.epochs, 2);
        assert_eq!(report.redeploys, 0);
        assert_eq!(report.requests, 3);
        assert!(report.total_cost > 0.0);
        assert!(report.throughput_tps > 0.0);
    }
}
