//! Traffic-simulation configuration: epoching, lifecycle, queueing and
//! autoscaling knobs, plus the deployment problem they pose.

use super::autoscale::AutoscalePolicy;
use super::error::{self, ScenarioError};
use crate::config::{DeployConfig, PlatformConfig};
use crate::deploy::DeployProblem;
use crate::model::MoeModelSpec;
use crate::util::json::Json;

/// Which dispatch engine [`super::epoch::EpochSimulator`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimEngine {
    /// The PR 2 serial per-request loop: all of a request's layers are
    /// dispatched at its ready time. Kept reachable as the cross-validation
    /// baseline and the bench harness's reference.
    Legacy,
    /// Event-driven discrete-event engine over a flat replica-slot arena
    /// (`super::sim`). With `pipeline: false` it reproduces the legacy
    /// monolithic dispatch bit-for-bit; with `pipeline: true` each request's
    /// layer *k+1* is dispatched when layer *k* completes, so later layers'
    /// queue waits overlap earlier layers' compute across concurrent
    /// requests — the paper's pipelined scatter-gather at the serving level.
    Event { pipeline: bool },
}

impl SimEngine {
    /// Scenario-file encoding: `{"kind": "legacy"}` or
    /// `{"kind": "event", "pipeline": true}`.
    pub fn to_json(&self) -> Json {
        match *self {
            SimEngine::Legacy => Json::from_pairs(vec![("kind", Json::str("legacy"))]),
            SimEngine::Event { pipeline } => Json::from_pairs(vec![
                ("kind", Json::str("event")),
                ("pipeline", Json::Bool(pipeline)),
            ]),
        }
    }

    /// Strict inverse of [`SimEngine::to_json`] (`pipeline` defaults to
    /// `true` when omitted, matching [`TrafficConfig::default`]).
    pub fn from_json(j: &Json) -> Result<SimEngine, ScenarioError> {
        const SECTION: &str = "config.engine";
        match error::req_str(j, SECTION, "kind")? {
            "legacy" => {
                error::check_keys(j, SECTION, &["kind"])?;
                Ok(SimEngine::Legacy)
            }
            "event" => {
                error::check_keys(j, SECTION, &["kind", "pipeline"])?;
                Ok(SimEngine::Event {
                    pipeline: error::opt_bool(j, SECTION, "pipeline", true)?,
                })
            }
            other => Err(ScenarioError::UnknownName {
                what: "sim engine",
                name: other.to_string(),
                known: "legacy | event",
            }),
        }
    }
}

/// How the engine aggregates per-request metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricsMode {
    /// Exact per-request vectors: sorted percentiles and the cumulative
    /// cost timeline (memory grows with the request count).
    Exact,
    /// O(1)-memory log-scale histograms ([`crate::util::stats::LogHistogram`]):
    /// percentile estimates within one bucket width (5% relative), exact
    /// mean/max, no cost timeline. Event engine only — the legacy loop
    /// always aggregates exactly.
    Streaming,
}

impl MetricsMode {
    pub fn name(&self) -> &'static str {
        match self {
            MetricsMode::Exact => "exact",
            MetricsMode::Streaming => "streaming",
        }
    }

    pub fn from_name(s: &str) -> Result<MetricsMode, ScenarioError> {
        match s {
            "exact" => Ok(MetricsMode::Exact),
            "streaming" => Ok(MetricsMode::Streaming),
            other => Err(ScenarioError::UnknownName {
                what: "metrics mode",
                name: other.to_string(),
                known: "exact | streaming",
            }),
        }
    }
}

/// Traffic-simulation knobs.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Epoch length: how often drift is reviewed and the autoscaler runs
    /// (seconds).
    pub epoch_secs: f64,
    /// Instance keep-alive after an invocation finishes (seconds;
    /// `f64::INFINITY` never expires).
    pub keep_alive: f64,
    /// Concurrent invocations one replica instance can execute. `Some(1)`
    /// is the Lambda semantics (one invocation per environment — the
    /// default); `None` is unbounded, the PR 1 serving model in which
    /// overlapping requests never queue.
    pub concurrency: Option<usize>,
    /// Replica autoscaling between full redeploys (see
    /// [`super::autoscale::Autoscaler`]); `Off` by default.
    pub autoscale: AutoscalePolicy,
    /// Pre-warm every replica of the initial deployment (the paper's
    /// warm-up invocation before measurement).
    pub prewarm: bool,
    /// Enable online re-optimization at epoch boundaries.
    pub reoptimize: bool,
    /// BO refinement iterations per re-optimization (0 = pure ODS re-solve).
    pub bo_round_iters: usize,
    /// Total-variation drift (realized vs deployed-for popularity, averaged
    /// over layers, in [0, 1]) that triggers re-deployment.
    pub drift_threshold: f64,
    /// EMA smoothing factor for realized popularity.
    pub ema_alpha: f64,
    /// Serving SLO T_limit handed to the deployment problem.
    pub t_limit: f64,
    /// Per-fixed-method solver time limit (seconds).
    pub solver_time_limit: f64,
    pub max_replicas: usize,
    pub beta_grid: Vec<usize>,
    pub seed: u64,
    /// Dispatch engine (event-driven and layer-pipelined by default; the
    /// legacy PR 2 loop stays reachable for cross-validation).
    pub engine: SimEngine,
    /// Metric aggregation (exact by default; streaming keeps memory O(1) in
    /// the request count for million-request runs).
    pub metrics: MetricsMode,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        let deploy = DeployConfig::default();
        Self {
            epoch_secs: 60.0,
            keep_alive: 900.0,
            concurrency: Some(1),
            autoscale: AutoscalePolicy::Off,
            prewarm: true,
            reoptimize: true,
            bo_round_iters: 0,
            drift_threshold: 0.2,
            ema_alpha: 0.3,
            t_limit: 3000.0,
            solver_time_limit: 0.5,
            max_replicas: deploy.max_replicas,
            beta_grid: deploy.beta_grid,
            seed: 0x7_1AFF,
            engine: SimEngine::Event { pipeline: true },
            metrics: MetricsMode::Exact,
        }
    }
}

impl TrafficConfig {
    /// Scenario-file encoding: a flat object, every field optional with the
    /// [`TrafficConfig::default`] value. Two conventions inherited from the
    /// rest of the traffic schema: infinite durations (`epoch_secs`,
    /// `keep_alive`) serialize as JSON `null`, and `"concurrency": 0` means
    /// unbounded (`None`), mirroring the CLI flag.
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("epoch_secs", Json::num(self.epoch_secs)),
            ("keep_alive", Json::num(self.keep_alive)),
            (
                "concurrency",
                Json::num(self.concurrency.unwrap_or(0) as f64),
            ),
            ("autoscale", self.autoscale.to_json()),
            ("prewarm", Json::Bool(self.prewarm)),
            ("reoptimize", Json::Bool(self.reoptimize)),
            ("bo_round_iters", Json::num(self.bo_round_iters as f64)),
            ("drift_threshold", Json::num(self.drift_threshold)),
            ("ema_alpha", Json::num(self.ema_alpha)),
            ("t_limit", Json::num(self.t_limit)),
            ("solver_time_limit", Json::num(self.solver_time_limit)),
            ("max_replicas", Json::num(self.max_replicas as f64)),
            (
                "beta_grid",
                Json::arr_u64(&self.beta_grid.iter().map(|&b| b as u64).collect::<Vec<_>>()),
            ),
            ("seed", Json::num(self.seed as f64)),
            ("engine", self.engine.to_json()),
            ("metrics", Json::str(self.metrics.name())),
        ])
    }

    /// Strict inverse of [`TrafficConfig::to_json`]: unknown fields are
    /// rejected, values are range-checked via [`TrafficConfig::validate`].
    pub fn from_json(j: &Json) -> Result<TrafficConfig, ScenarioError> {
        const SECTION: &str = "config";
        error::check_keys(
            j,
            SECTION,
            &[
                "epoch_secs",
                "keep_alive",
                "concurrency",
                "autoscale",
                "prewarm",
                "reoptimize",
                "bo_round_iters",
                "drift_threshold",
                "ema_alpha",
                "t_limit",
                "solver_time_limit",
                "max_replicas",
                "beta_grid",
                "seed",
                "engine",
                "metrics",
            ],
        )?;
        let d = TrafficConfig::default();
        let beta_grid = match j.get("beta_grid") {
            None => d.beta_grid.clone(),
            Some(Json::Arr(items)) => {
                let mut grid = Vec::with_capacity(items.len());
                for item in items {
                    match item.as_u64() {
                        Some(b) if b >= 1 => grid.push(b as usize),
                        _ => {
                            return Err(ScenarioError::invalid(
                                "config.beta_grid",
                                format!("entries must be integers >= 1, got {item:?}"),
                            ))
                        }
                    }
                }
                grid
            }
            Some(other) => {
                return Err(ScenarioError::invalid(
                    "config.beta_grid",
                    format!("expected an array, got {other:?}"),
                ))
            }
        };
        let cfg = TrafficConfig {
            epoch_secs: error::opt_duration(j, SECTION, "epoch_secs", d.epoch_secs)?,
            keep_alive: error::opt_duration(j, SECTION, "keep_alive", d.keep_alive)?,
            concurrency: match error::opt_u64(
                j,
                SECTION,
                "concurrency",
                d.concurrency.unwrap_or(0) as u64,
            )? {
                0 => None,
                c => Some(c as usize),
            },
            autoscale: match j.get("autoscale") {
                None => d.autoscale,
                Some(a) => AutoscalePolicy::from_json(a)?,
            },
            prewarm: error::opt_bool(j, SECTION, "prewarm", d.prewarm)?,
            reoptimize: error::opt_bool(j, SECTION, "reoptimize", d.reoptimize)?,
            bo_round_iters: error::opt_usize(j, SECTION, "bo_round_iters", d.bo_round_iters)?,
            drift_threshold: error::opt_f64(j, SECTION, "drift_threshold", d.drift_threshold)?,
            ema_alpha: error::opt_f64(j, SECTION, "ema_alpha", d.ema_alpha)?,
            t_limit: error::opt_f64(j, SECTION, "t_limit", d.t_limit)?,
            solver_time_limit: error::opt_f64(
                j,
                SECTION,
                "solver_time_limit",
                d.solver_time_limit,
            )?,
            max_replicas: error::opt_usize(j, SECTION, "max_replicas", d.max_replicas)?,
            beta_grid,
            seed: error::opt_u64(j, SECTION, "seed", d.seed)?,
            engine: match j.get("engine") {
                None => d.engine,
                Some(e) => SimEngine::from_json(e)?,
            },
            metrics: match j.get("metrics") {
                None => d.metrics,
                Some(Json::Str(s)) => MetricsMode::from_name(s)?,
                Some(other) => {
                    return Err(ScenarioError::invalid(
                        "config.metrics",
                        format!("expected a string, got {other:?}"),
                    ))
                }
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Range checks shared by the builder and the JSON loader. Keeps the
    /// long-standing panics (`epoch_secs > 0`) out of `run()` by rejecting
    /// bad values at construction time with a typed error.
    pub fn validate(&self) -> Result<(), ScenarioError> {
        let ensure = |ok: bool, field: &str, reason: String| {
            if ok {
                Ok(())
            } else {
                Err(ScenarioError::invalid(format!("config.{field}"), reason))
            }
        };
        ensure(
            self.epoch_secs > 0.0,
            "epoch_secs",
            format!("must be > 0 (null/inf = one epoch), got {}", self.epoch_secs),
        )?;
        ensure(
            self.keep_alive >= 0.0,
            "keep_alive",
            format!("must be >= 0, got {}", self.keep_alive),
        )?;
        if let Some(c) = self.concurrency {
            ensure(c >= 1, "concurrency", format!("limit must be >= 1, got {c}"))?;
        }
        ensure(
            self.ema_alpha > 0.0 && self.ema_alpha <= 1.0,
            "ema_alpha",
            format!("must be in (0, 1], got {}", self.ema_alpha),
        )?;
        ensure(
            self.drift_threshold.is_finite() && self.drift_threshold <= 1.0,
            "drift_threshold",
            format!("must be finite and <= 1 (TV distance), got {}", self.drift_threshold),
        )?;
        ensure(
            self.t_limit > 0.0 && self.t_limit.is_finite(),
            "t_limit",
            format!("must be finite and > 0, got {}", self.t_limit),
        )?;
        ensure(
            self.solver_time_limit > 0.0 && self.solver_time_limit.is_finite(),
            "solver_time_limit",
            format!("must be finite and > 0, got {}", self.solver_time_limit),
        )?;
        ensure(
            self.max_replicas >= 1,
            "max_replicas",
            format!("must be >= 1, got {}", self.max_replicas),
        )?;
        ensure(
            !self.beta_grid.is_empty(),
            "beta_grid",
            "must not be empty".to_string(),
        )?;
        self.autoscale.check()
    }

    /// Degenerate configuration for cross-validation against the seed
    /// single-batch pipeline: one infinite epoch, a pre-warmed pool that
    /// never expires, unbounded concurrency, no autoscaling, no
    /// re-optimization — serving one batch must then reproduce
    /// `serve_with_real_counts(.., warm = true)` exactly.
    pub fn degenerate() -> TrafficConfig {
        TrafficConfig {
            epoch_secs: f64::INFINITY,
            keep_alive: f64::INFINITY,
            concurrency: None,
            autoscale: AutoscalePolicy::Off,
            prewarm: true,
            reoptimize: false,
            bo_round_iters: 0,
            ..TrafficConfig::default()
        }
    }

    /// The deployment problem this configuration poses for a predicted (or
    /// real) token distribution — shared by the epoch loop and the baseline
    /// builders so every run solves the same problem shape.
    pub fn problem<'b>(
        &self,
        platform: &'b PlatformConfig,
        spec: &'b MoeModelSpec,
        tokens: Vec<Vec<u64>>,
    ) -> DeployProblem<'b> {
        DeployProblem {
            cfg: platform,
            spec,
            tokens,
            t_limit: self.t_limit,
            max_replicas: self.max_replicas,
            beta_grid: self.beta_grid.clone(),
            warm: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_default_and_degenerate() {
        for cfg in [TrafficConfig::default(), TrafficConfig::degenerate()] {
            let j = cfg.to_json();
            let back = TrafficConfig::from_json(&Json::parse(&j.to_string_pretty()).unwrap())
                .expect("config roundtrips");
            // No PartialEq on TrafficConfig: canonical JSON is the identity.
            assert_eq!(back.to_json().to_string_pretty(), j.to_string_pretty());
            // Infinite durations survive the null encoding.
            assert_eq!(back.epoch_secs, cfg.epoch_secs);
            assert_eq!(back.keep_alive, cfg.keep_alive);
            assert_eq!(back.concurrency, cfg.concurrency);
            assert_eq!(back.engine, cfg.engine);
            assert_eq!(back.metrics, cfg.metrics);
        }
    }

    #[test]
    fn empty_object_is_all_defaults() {
        let cfg = TrafficConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        let d = TrafficConfig::default();
        assert_eq!(cfg.to_json().to_string_pretty(), d.to_json().to_string_pretty());
    }

    #[test]
    fn strict_parsing_rejects_typos_and_bad_values() {
        let typo = Json::parse(r#"{"epoch_sec": 60}"#).unwrap();
        assert!(matches!(
            TrafficConfig::from_json(&typo),
            Err(ScenarioError::UnknownField { .. })
        ));
        let bad_type = Json::parse(r#"{"epoch_secs": "fast"}"#).unwrap();
        assert!(matches!(
            TrafficConfig::from_json(&bad_type),
            Err(ScenarioError::Invalid { .. })
        ));
        let bad_value = Json::parse(r#"{"ema_alpha": 1.5}"#).unwrap();
        assert!(matches!(
            TrafficConfig::from_json(&bad_value),
            Err(ScenarioError::Invalid { .. })
        ));
        let bad_engine = Json::parse(r#"{"engine": {"kind": "warp"}}"#).unwrap();
        assert!(matches!(
            TrafficConfig::from_json(&bad_engine),
            Err(ScenarioError::UnknownName { .. })
        ));
        let bad_beta = Json::parse(r#"{"beta_grid": [1, 0]}"#).unwrap();
        assert!(TrafficConfig::from_json(&bad_beta).is_err());
    }

    #[test]
    fn validate_catches_out_of_range() {
        let mut cfg = TrafficConfig::default();
        cfg.epoch_secs = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrafficConfig::default();
        cfg.concurrency = Some(0);
        assert!(cfg.validate().is_err());
        let mut cfg = TrafficConfig::default();
        cfg.drift_threshold = -1.0; // forced drift: legal (tests rely on it)
        assert!(cfg.validate().is_ok());
    }
}
