//! Traffic-simulation configuration: epoching, lifecycle, queueing and
//! autoscaling knobs, plus the deployment problem they pose.

use super::autoscale::AutoscalePolicy;
use super::error::{self, ScenarioError};
use crate::config::{DeployConfig, PlatformConfig};
use crate::deploy::DeployProblem;
use crate::model::MoeModelSpec;
use crate::util::json::Json;

/// Which dispatch engine [`super::epoch::EpochSimulator`] runs.
///
/// Orthogonal to the fleet-level step *driver*
/// ([`super::sim::FleetDriver`], the `driver` key on a fleet file): the
/// engine decides how one tenant's requests dispatch, the driver decides
/// how the fleet's event lanes are interleaved (sequential heap/scan or
/// sharded across threads). A single-`Scenario` file has one lane and
/// therefore no `driver` knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimEngine {
    /// The PR 2 serial per-request loop: all of a request's layers are
    /// dispatched at its ready time. Kept reachable as the cross-validation
    /// baseline and the bench harness's reference.
    Legacy,
    /// Event-driven discrete-event engine over a flat replica-slot arena
    /// (`super::sim`). With `pipeline: false` it reproduces the legacy
    /// monolithic dispatch bit-for-bit; with `pipeline: true` each request's
    /// layer *k+1* is dispatched when layer *k* completes, so later layers'
    /// queue waits overlap earlier layers' compute across concurrent
    /// requests — the paper's pipelined scatter-gather at the serving level.
    Event { pipeline: bool },
}

impl SimEngine {
    /// Scenario-file encoding: `{"kind": "legacy"}` or
    /// `{"kind": "event", "pipeline": true}`.
    pub fn to_json(&self) -> Json {
        match *self {
            SimEngine::Legacy => Json::from_pairs(vec![("kind", Json::str("legacy"))]),
            SimEngine::Event { pipeline } => Json::from_pairs(vec![
                ("kind", Json::str("event")),
                ("pipeline", Json::Bool(pipeline)),
            ]),
        }
    }

    /// Strict inverse of [`SimEngine::to_json`] (`pipeline` defaults to
    /// `true` when omitted, matching [`TrafficConfig::default`]).
    pub fn from_json(j: &Json) -> Result<SimEngine, ScenarioError> {
        const SECTION: &str = "config.engine";
        match error::req_str(j, SECTION, "kind")? {
            "legacy" => {
                error::check_keys(j, SECTION, &["kind"])?;
                Ok(SimEngine::Legacy)
            }
            "event" => {
                error::check_keys(j, SECTION, &["kind", "pipeline"])?;
                Ok(SimEngine::Event {
                    pipeline: error::opt_bool(j, SECTION, "pipeline", true)?,
                })
            }
            other => Err(ScenarioError::UnknownName {
                what: "sim engine",
                name: other.to_string(),
                known: "legacy | event",
            }),
        }
    }
}

/// How the engine aggregates per-request metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricsMode {
    /// Exact per-request vectors: sorted percentiles and the cumulative
    /// cost timeline (memory grows with the request count).
    Exact,
    /// O(1)-memory log-scale histograms ([`crate::util::stats::LogHistogram`]):
    /// percentile estimates within one bucket width (5% relative), exact
    /// mean/max, no cost timeline. Event engine only — the legacy loop
    /// always aggregates exactly.
    Streaming,
}

impl MetricsMode {
    pub fn name(&self) -> &'static str {
        match self {
            MetricsMode::Exact => "exact",
            MetricsMode::Streaming => "streaming",
        }
    }

    pub fn from_name(s: &str) -> Result<MetricsMode, ScenarioError> {
        match s {
            "exact" => Ok(MetricsMode::Exact),
            "streaming" => Ok(MetricsMode::Streaming),
            other => Err(ScenarioError::UnknownName {
                what: "metrics mode",
                name: other.to_string(),
                known: "exact | streaming",
            }),
        }
    }
}

/// Deterministic failure-injection knobs. All probabilistic fates draw
/// from a dedicated seeded RNG stream (`arrivals::fault_seed` of the
/// scenario seed), so a faulted run is exactly reproducible. The default
/// ([`FaultSpec::off`]) injects nothing and adds zero work — the serving
/// path with faults off is byte-identical to a build without them.
///
/// Failure semantics follow Lambda: crashed and timed-out invocations are
/// still billed (full duration, or exactly the `timeout` cutoff), throttled
/// admissions surface as retryable 429-class errors, and retries pay the
/// full price of every failed attempt.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Per-replica-invocation crash probability in [0, 1).
    pub crash_prob: f64,
    /// Multiplier (>= 1) on `crash_prob` for cold-start invocations — cold
    /// starts fail more often (init timeouts, sandbox churn).
    pub cold_crash_multiplier: f64,
    /// Probability in [0, 1] that a cap-rejected admission surfaces as a
    /// throttle error (retried with backoff) instead of parking in the
    /// fair-arbitration wait queue.
    pub throttle_prob: f64,
    /// Invocation timeout cutoff (seconds): a replica whose service would
    /// exceed it is killed and billed exactly `timeout` seconds.
    /// `f64::INFINITY` (JSON `null`) disables the cutoff.
    pub timeout: f64,
    /// Bounded retry budget per request layer (and per throttled
    /// admission); 0 = failures are never retried.
    pub max_retries: u32,
    /// Exponential backoff base: attempt `a` (0-indexed) waits
    /// `backoff_base * 2^a` seconds before retrying.
    pub backoff_base: f64,
    /// Straggler-hedging quantile in (0, 1): when a layer's straggler
    /// finish exceeds this quantile of the observed replica-latency
    /// history, a duplicate replica invocation races it and the first
    /// finisher wins (the loser's billing is cut at the winner's finish).
    /// 0 = hedging off.
    pub hedge_quantile: f64,
    /// Minimum number of observed replica latencies before `hedge_quantile`
    /// activates (>= 1) — below it the quantile estimate is too noisy to
    /// hedge on. 16 preserves the pre-knob hard-coded threshold.
    pub hedge_min_obs: u64,
    /// Consecutive-failure threshold after which an expert's replicas are
    /// dropped for the rest of the epoch, its tokens rerouted to the
    /// surviving experts (a quality-proxy penalty the report surfaces);
    /// 0 = never drop.
    pub drop_after: u32,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec::off()
    }
}

impl FaultSpec {
    /// The inert spec: nothing crashes, throttles, times out or hedges.
    pub fn off() -> FaultSpec {
        FaultSpec {
            crash_prob: 0.0,
            cold_crash_multiplier: 1.0,
            throttle_prob: 0.0,
            timeout: f64::INFINITY,
            max_retries: 0,
            backoff_base: 0.0,
            hedge_quantile: 0.0,
            hedge_min_obs: 16,
            drop_after: 0,
        }
    }

    /// Whether any injection is active. `false` keeps the engine on the
    /// fault-free fast path (no RNG, no per-expert bookkeeping).
    pub fn enabled(&self) -> bool {
        self.crash_prob > 0.0
            || self.throttle_prob > 0.0
            || self.timeout.is_finite()
            || self.hedge_quantile > 0.0
    }

    /// Scenario-file encoding: a flat object; the infinite `timeout`
    /// serializes as JSON `null` per the usual duration convention.
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("crash_prob", Json::num(self.crash_prob)),
            ("cold_crash_multiplier", Json::num(self.cold_crash_multiplier)),
            ("throttle_prob", Json::num(self.throttle_prob)),
            (
                "timeout",
                if self.timeout.is_finite() { Json::num(self.timeout) } else { Json::Null },
            ),
            ("max_retries", Json::num(self.max_retries as f64)),
            ("backoff_base", Json::num(self.backoff_base)),
            ("hedge_quantile", Json::num(self.hedge_quantile)),
            ("hedge_min_obs", Json::num(self.hedge_min_obs as f64)),
            ("drop_after", Json::num(self.drop_after as f64)),
        ])
    }

    /// Strict inverse of [`FaultSpec::to_json`]: unknown fields rejected,
    /// every field optional with the [`FaultSpec::off`] value, knobs
    /// range-checked via [`FaultSpec::check`].
    pub fn from_json(j: &Json) -> Result<FaultSpec, ScenarioError> {
        const SECTION: &str = "faults";
        error::check_keys(
            j,
            SECTION,
            &[
                "crash_prob",
                "cold_crash_multiplier",
                "throttle_prob",
                "timeout",
                "max_retries",
                "backoff_base",
                "hedge_quantile",
                "hedge_min_obs",
                "drop_after",
            ],
        )?;
        let d = FaultSpec::off();
        let spec = FaultSpec {
            crash_prob: error::opt_f64(j, SECTION, "crash_prob", d.crash_prob)?,
            cold_crash_multiplier: error::opt_f64(
                j,
                SECTION,
                "cold_crash_multiplier",
                d.cold_crash_multiplier,
            )?,
            throttle_prob: error::opt_f64(j, SECTION, "throttle_prob", d.throttle_prob)?,
            timeout: error::opt_duration(j, SECTION, "timeout", d.timeout)?,
            max_retries: error::opt_u64(j, SECTION, "max_retries", d.max_retries as u64)? as u32,
            backoff_base: error::opt_f64(j, SECTION, "backoff_base", d.backoff_base)?,
            hedge_quantile: error::opt_f64(j, SECTION, "hedge_quantile", d.hedge_quantile)?,
            hedge_min_obs: error::opt_u64(j, SECTION, "hedge_min_obs", d.hedge_min_obs)?,
            drop_after: error::opt_u64(j, SECTION, "drop_after", d.drop_after as u64)? as u32,
        };
        spec.check(SECTION)?;
        Ok(spec)
    }

    /// Range checks shared by the scenario and fleet loaders. NaN fails
    /// every ordered comparison, so non-finite garbage is rejected with the
    /// same typed error as an out-of-range value.
    pub fn check(&self, section: &str) -> Result<(), ScenarioError> {
        let ensure = |ok: bool, field: &str, reason: String| {
            if ok {
                Ok(())
            } else {
                Err(ScenarioError::invalid(format!("{section}.{field}"), reason))
            }
        };
        ensure(
            (0.0..1.0).contains(&self.crash_prob),
            "crash_prob",
            format!("must be in [0, 1), got {}", self.crash_prob),
        )?;
        ensure(
            self.cold_crash_multiplier >= 1.0 && self.cold_crash_multiplier.is_finite(),
            "cold_crash_multiplier",
            format!("must be finite and >= 1, got {}", self.cold_crash_multiplier),
        )?;
        ensure(
            (0.0..=1.0).contains(&self.throttle_prob),
            "throttle_prob",
            format!("must be in [0, 1], got {}", self.throttle_prob),
        )?;
        ensure(
            self.timeout > 0.0,
            "timeout",
            format!("must be > 0 (null = no cutoff), got {}", self.timeout),
        )?;
        ensure(
            self.backoff_base >= 0.0 && self.backoff_base.is_finite(),
            "backoff_base",
            format!("must be finite and >= 0, got {}", self.backoff_base),
        )?;
        ensure(
            (0.0..1.0).contains(&self.hedge_quantile),
            "hedge_quantile",
            format!("must be in [0, 1) (0 = off), got {}", self.hedge_quantile),
        )?;
        ensure(
            self.hedge_min_obs >= 1,
            "hedge_min_obs",
            format!("must be >= 1, got {}", self.hedge_min_obs),
        )?;
        Ok(())
    }
}

/// Traffic-simulation knobs.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Epoch length: how often drift is reviewed and the autoscaler runs
    /// (seconds).
    pub epoch_secs: f64,
    /// Instance keep-alive after an invocation finishes (seconds;
    /// `f64::INFINITY` never expires).
    pub keep_alive: f64,
    /// Concurrent invocations one replica instance can execute. `Some(1)`
    /// is the Lambda semantics (one invocation per environment — the
    /// default); `None` is unbounded, the PR 1 serving model in which
    /// overlapping requests never queue.
    pub concurrency: Option<usize>,
    /// Replica autoscaling between full redeploys (see
    /// [`super::autoscale::Autoscaler`]); `Off` by default.
    pub autoscale: AutoscalePolicy,
    /// Pre-warm every replica of the initial deployment (the paper's
    /// warm-up invocation before measurement).
    pub prewarm: bool,
    /// Enable online re-optimization at epoch boundaries.
    pub reoptimize: bool,
    /// BO refinement iterations per re-optimization (0 = pure ODS re-solve).
    pub bo_round_iters: usize,
    /// Total-variation drift (realized vs deployed-for popularity, averaged
    /// over layers, in [0, 1]) that triggers re-deployment.
    pub drift_threshold: f64,
    /// EMA smoothing factor for realized popularity.
    pub ema_alpha: f64,
    /// Serving SLO T_limit handed to the deployment problem.
    pub t_limit: f64,
    /// Per-fixed-method solver time limit (seconds).
    pub solver_time_limit: f64,
    pub max_replicas: usize,
    pub beta_grid: Vec<usize>,
    pub seed: u64,
    /// Dispatch engine (event-driven and layer-pipelined by default; the
    /// legacy PR 2 loop stays reachable for cross-validation).
    pub engine: SimEngine,
    /// Metric aggregation (exact by default; streaming keeps memory O(1) in
    /// the request count for million-request runs).
    pub metrics: MetricsMode,
    /// Failure injection ([`FaultSpec::off`] by default — JSON `null` or an
    /// omitted key, per the null-means-absent convention).
    pub faults: FaultSpec,
    /// Continuous-batching window for autoregressive decode steps
    /// (seconds): decode steps from different in-flight requests that land
    /// on the same replica FIFO within the window merge into one invocation
    /// per iteration, cost split by token share. `0.0` (the default)
    /// dispatches every decode step serially and keeps the engine
    /// byte-identical to the pre-decode builds. Only meaningful with a
    /// chat traffic source; requires the pipelined event engine.
    pub decode_batch_window: f64,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        let deploy = DeployConfig::default();
        Self {
            epoch_secs: 60.0,
            keep_alive: 900.0,
            concurrency: Some(1),
            autoscale: AutoscalePolicy::Off,
            prewarm: true,
            reoptimize: true,
            bo_round_iters: 0,
            drift_threshold: 0.2,
            ema_alpha: 0.3,
            t_limit: 3000.0,
            solver_time_limit: 0.5,
            max_replicas: deploy.max_replicas,
            beta_grid: deploy.beta_grid,
            seed: 0x7_1AFF,
            engine: SimEngine::Event { pipeline: true },
            metrics: MetricsMode::Exact,
            faults: FaultSpec::off(),
            decode_batch_window: 0.0,
        }
    }
}

impl TrafficConfig {
    /// Scenario-file encoding: a flat object, every field optional with the
    /// [`TrafficConfig::default`] value. Two conventions inherited from the
    /// rest of the traffic schema: infinite durations (`epoch_secs`,
    /// `keep_alive`) serialize as JSON `null`, and `"concurrency": 0` means
    /// unbounded (`None`), mirroring the CLI flag.
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("epoch_secs", Json::num(self.epoch_secs)),
            ("keep_alive", Json::num(self.keep_alive)),
            (
                "concurrency",
                Json::num(self.concurrency.unwrap_or(0) as f64),
            ),
            ("autoscale", self.autoscale.to_json()),
            ("prewarm", Json::Bool(self.prewarm)),
            ("reoptimize", Json::Bool(self.reoptimize)),
            ("bo_round_iters", Json::num(self.bo_round_iters as f64)),
            ("drift_threshold", Json::num(self.drift_threshold)),
            ("ema_alpha", Json::num(self.ema_alpha)),
            ("t_limit", Json::num(self.t_limit)),
            ("solver_time_limit", Json::num(self.solver_time_limit)),
            ("max_replicas", Json::num(self.max_replicas as f64)),
            (
                "beta_grid",
                Json::arr_u64(&self.beta_grid.iter().map(|&b| b as u64).collect::<Vec<_>>()),
            ),
            ("seed", Json::num(self.seed as f64)),
            ("engine", self.engine.to_json()),
            ("metrics", Json::str(self.metrics.name())),
            (
                "faults",
                if self.faults == FaultSpec::off() {
                    Json::Null
                } else {
                    self.faults.to_json()
                },
            ),
            ("decode_batch_window", Json::num(self.decode_batch_window)),
        ])
    }

    /// Strict inverse of [`TrafficConfig::to_json`]: unknown fields are
    /// rejected, values are range-checked via [`TrafficConfig::validate`].
    pub fn from_json(j: &Json) -> Result<TrafficConfig, ScenarioError> {
        const SECTION: &str = "config";
        error::check_keys(
            j,
            SECTION,
            &[
                "epoch_secs",
                "keep_alive",
                "concurrency",
                "autoscale",
                "prewarm",
                "reoptimize",
                "bo_round_iters",
                "drift_threshold",
                "ema_alpha",
                "t_limit",
                "solver_time_limit",
                "max_replicas",
                "beta_grid",
                "seed",
                "engine",
                "metrics",
                "faults",
                "decode_batch_window",
            ],
        )?;
        let d = TrafficConfig::default();
        let beta_grid = match j.get("beta_grid") {
            None => d.beta_grid.clone(),
            Some(Json::Arr(items)) => {
                let mut grid = Vec::with_capacity(items.len());
                for item in items {
                    match item.as_u64() {
                        Some(b) if b >= 1 => grid.push(b as usize),
                        _ => {
                            return Err(ScenarioError::invalid(
                                "config.beta_grid",
                                format!("entries must be integers >= 1, got {item:?}"),
                            ))
                        }
                    }
                }
                grid
            }
            Some(other) => {
                return Err(ScenarioError::invalid(
                    "config.beta_grid",
                    format!("expected an array, got {other:?}"),
                ))
            }
        };
        let cfg = TrafficConfig {
            epoch_secs: error::opt_duration(j, SECTION, "epoch_secs", d.epoch_secs)?,
            keep_alive: error::opt_duration(j, SECTION, "keep_alive", d.keep_alive)?,
            concurrency: match error::opt_u64(
                j,
                SECTION,
                "concurrency",
                d.concurrency.unwrap_or(0) as u64,
            )? {
                0 => None,
                c => Some(c as usize),
            },
            autoscale: match j.get("autoscale") {
                None => d.autoscale,
                Some(a) => AutoscalePolicy::from_json(a)?,
            },
            prewarm: error::opt_bool(j, SECTION, "prewarm", d.prewarm)?,
            reoptimize: error::opt_bool(j, SECTION, "reoptimize", d.reoptimize)?,
            bo_round_iters: error::opt_usize(j, SECTION, "bo_round_iters", d.bo_round_iters)?,
            drift_threshold: error::opt_f64(j, SECTION, "drift_threshold", d.drift_threshold)?,
            ema_alpha: error::opt_f64(j, SECTION, "ema_alpha", d.ema_alpha)?,
            t_limit: error::opt_f64(j, SECTION, "t_limit", d.t_limit)?,
            solver_time_limit: error::opt_f64(
                j,
                SECTION,
                "solver_time_limit",
                d.solver_time_limit,
            )?,
            max_replicas: error::opt_usize(j, SECTION, "max_replicas", d.max_replicas)?,
            beta_grid,
            seed: error::opt_u64(j, SECTION, "seed", d.seed)?,
            engine: match j.get("engine") {
                None => d.engine,
                Some(e) => SimEngine::from_json(e)?,
            },
            metrics: match j.get("metrics") {
                None => d.metrics,
                Some(Json::Str(s)) => MetricsMode::from_name(s)?,
                Some(other) => {
                    return Err(ScenarioError::invalid(
                        "config.metrics",
                        format!("expected a string, got {other:?}"),
                    ))
                }
            },
            faults: match j.get("faults") {
                None | Some(Json::Null) => FaultSpec::off(),
                Some(f) => FaultSpec::from_json(f)?,
            },
            decode_batch_window: error::opt_f64(
                j,
                SECTION,
                "decode_batch_window",
                d.decode_batch_window,
            )?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Range checks shared by the builder and the JSON loader. Keeps the
    /// long-standing panics (`epoch_secs > 0`) out of `run()` by rejecting
    /// bad values at construction time with a typed error.
    pub fn validate(&self) -> Result<(), ScenarioError> {
        let ensure = |ok: bool, field: &str, reason: String| {
            if ok {
                Ok(())
            } else {
                Err(ScenarioError::invalid(format!("config.{field}"), reason))
            }
        };
        ensure(
            self.epoch_secs > 0.0,
            "epoch_secs",
            format!("must be > 0 (null/inf = one epoch), got {}", self.epoch_secs),
        )?;
        ensure(
            self.keep_alive >= 0.0,
            "keep_alive",
            format!("must be >= 0, got {}", self.keep_alive),
        )?;
        if let Some(c) = self.concurrency {
            ensure(c >= 1, "concurrency", format!("limit must be >= 1, got {c}"))?;
        }
        ensure(
            self.ema_alpha > 0.0 && self.ema_alpha <= 1.0,
            "ema_alpha",
            format!("must be in (0, 1], got {}", self.ema_alpha),
        )?;
        ensure(
            self.drift_threshold.is_finite() && self.drift_threshold <= 1.0,
            "drift_threshold",
            format!("must be finite and <= 1 (TV distance), got {}", self.drift_threshold),
        )?;
        ensure(
            self.t_limit > 0.0 && self.t_limit.is_finite(),
            "t_limit",
            format!("must be finite and > 0, got {}", self.t_limit),
        )?;
        ensure(
            self.solver_time_limit > 0.0 && self.solver_time_limit.is_finite(),
            "solver_time_limit",
            format!("must be finite and > 0, got {}", self.solver_time_limit),
        )?;
        ensure(
            self.max_replicas >= 1,
            "max_replicas",
            format!("must be >= 1, got {}", self.max_replicas),
        )?;
        ensure(
            !self.beta_grid.is_empty(),
            "beta_grid",
            "must not be empty".to_string(),
        )?;
        self.faults.check("config.faults")?;
        if self.faults.enabled() {
            // Retry and hedge events ride the per-layer event heap; the
            // legacy loop and monolithic dispatch have no per-layer events
            // to attach them to.
            ensure(
                self.engine == SimEngine::Event { pipeline: true },
                "faults",
                "fault injection requires the pipelined event engine".to_string(),
            )?;
        }
        ensure(
            self.decode_batch_window >= 0.0 && self.decode_batch_window.is_finite(),
            "decode_batch_window",
            format!("must be finite and >= 0, got {}", self.decode_batch_window),
        )?;
        if self.decode_batch_window > 0.0 {
            ensure(
                self.engine == SimEngine::Event { pipeline: true },
                "decode_batch_window",
                "continuous decode batching requires the pipelined event engine".to_string(),
            )?;
            // A merged decode flush is adjudicated once, not per member
            // request — same composition gap as fleet batch_window.
            ensure(
                !self.faults.enabled(),
                "decode_batch_window",
                "decode batching does not compose with fault injection".to_string(),
            )?;
        }
        self.autoscale.check()
    }

    /// Degenerate configuration for cross-validation against the seed
    /// single-batch pipeline: one infinite epoch, a pre-warmed pool that
    /// never expires, unbounded concurrency, no autoscaling, no
    /// re-optimization — serving one batch must then reproduce
    /// `serve_with_real_counts(.., warm = true)` exactly.
    pub fn degenerate() -> TrafficConfig {
        TrafficConfig {
            epoch_secs: f64::INFINITY,
            keep_alive: f64::INFINITY,
            concurrency: None,
            autoscale: AutoscalePolicy::Off,
            prewarm: true,
            reoptimize: false,
            bo_round_iters: 0,
            ..TrafficConfig::default()
        }
    }

    /// The deployment problem this configuration poses for a predicted (or
    /// real) token distribution — shared by the epoch loop and the baseline
    /// builders so every run solves the same problem shape.
    pub fn problem<'b>(
        &self,
        platform: &'b PlatformConfig,
        spec: &'b MoeModelSpec,
        tokens: Vec<Vec<u64>>,
    ) -> DeployProblem<'b> {
        DeployProblem {
            cfg: platform,
            spec,
            tokens,
            t_limit: self.t_limit,
            max_replicas: self.max_replicas,
            beta_grid: self.beta_grid.clone(),
            warm: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_default_and_degenerate() {
        for cfg in [TrafficConfig::default(), TrafficConfig::degenerate()] {
            let j = cfg.to_json();
            let back = TrafficConfig::from_json(&Json::parse(&j.to_string_pretty()).unwrap())
                .expect("config roundtrips");
            // No PartialEq on TrafficConfig: canonical JSON is the identity.
            assert_eq!(back.to_json().to_string_pretty(), j.to_string_pretty());
            // Infinite durations survive the null encoding.
            assert_eq!(back.epoch_secs, cfg.epoch_secs);
            assert_eq!(back.keep_alive, cfg.keep_alive);
            assert_eq!(back.concurrency, cfg.concurrency);
            assert_eq!(back.engine, cfg.engine);
            assert_eq!(back.metrics, cfg.metrics);
        }
    }

    #[test]
    fn empty_object_is_all_defaults() {
        let cfg = TrafficConfig::from_json(&Json::parse("{}").unwrap()).unwrap();
        let d = TrafficConfig::default();
        assert_eq!(cfg.to_json().to_string_pretty(), d.to_json().to_string_pretty());
    }

    #[test]
    fn strict_parsing_rejects_typos_and_bad_values() {
        let typo = Json::parse(r#"{"epoch_sec": 60}"#).unwrap();
        assert!(matches!(
            TrafficConfig::from_json(&typo),
            Err(ScenarioError::UnknownField { .. })
        ));
        let bad_type = Json::parse(r#"{"epoch_secs": "fast"}"#).unwrap();
        assert!(matches!(
            TrafficConfig::from_json(&bad_type),
            Err(ScenarioError::Invalid { .. })
        ));
        let bad_value = Json::parse(r#"{"ema_alpha": 1.5}"#).unwrap();
        assert!(matches!(
            TrafficConfig::from_json(&bad_value),
            Err(ScenarioError::Invalid { .. })
        ));
        let bad_engine = Json::parse(r#"{"engine": {"kind": "warp"}}"#).unwrap();
        assert!(matches!(
            TrafficConfig::from_json(&bad_engine),
            Err(ScenarioError::UnknownName { .. })
        ));
        let bad_beta = Json::parse(r#"{"beta_grid": [1, 0]}"#).unwrap();
        assert!(TrafficConfig::from_json(&bad_beta).is_err());
    }

    #[test]
    fn validate_catches_out_of_range() {
        let mut cfg = TrafficConfig::default();
        cfg.epoch_secs = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = TrafficConfig::default();
        cfg.concurrency = Some(0);
        assert!(cfg.validate().is_err());
        let mut cfg = TrafficConfig::default();
        cfg.drift_threshold = -1.0; // forced drift: legal (tests rely on it)
        assert!(cfg.validate().is_ok());
    }

    /// Builder-path NaN/negative floats (inexpressible in JSON, so only the
    /// builder can smuggle them in) are rejected by `validate` with typed
    /// errors — the JSON rejection matrix lives in `rust/tests/scenario.rs`.
    #[test]
    fn validate_rejects_non_finite_and_negative_floats() {
        let poison: &[fn(&mut TrafficConfig)] = &[
            |c| c.epoch_secs = f64::NAN,
            |c| c.epoch_secs = -60.0,
            |c| c.keep_alive = f64::NAN,
            |c| c.keep_alive = -1.0,
            |c| c.drift_threshold = f64::NAN,
            |c| c.drift_threshold = f64::INFINITY,
            |c| c.ema_alpha = f64::NAN,
            |c| c.t_limit = f64::NAN,
            |c| c.solver_time_limit = -0.5,
        ];
        for (i, p) in poison.iter().enumerate() {
            let mut cfg = TrafficConfig::default();
            p(&mut cfg);
            assert!(
                matches!(cfg.validate(), Err(ScenarioError::Invalid { .. })),
                "poisoned config #{i} must be rejected with a typed Invalid"
            );
        }
    }

    #[test]
    fn fault_spec_roundtrips_and_rejects_bad_knobs() {
        // Off canonicalizes to JSON null and parses back from null/omitted.
        let d = TrafficConfig::default();
        assert_eq!(d.to_json().get("faults"), Some(&Json::Null));
        let back =
            TrafficConfig::from_json(&Json::parse(r#"{"faults": null}"#).unwrap()).unwrap();
        assert_eq!(back.faults, FaultSpec::off());
        assert!(!back.faults.enabled());

        // A live spec roundtrips losslessly (infinite timeout as null).
        let spec = FaultSpec {
            crash_prob: 0.1,
            cold_crash_multiplier: 2.0,
            throttle_prob: 0.5,
            timeout: f64::INFINITY,
            max_retries: 3,
            backoff_base: 0.25,
            hedge_quantile: 0.9,
            hedge_min_obs: 16,
            drop_after: 2,
        };
        assert!(spec.enabled());
        let back = FaultSpec::from_json(&Json::parse(&spec.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.timeout, f64::INFINITY);

        // Strictness: typos and out-of-range knobs are typed errors.
        let typo = Json::parse(r#"{"crash_probe": 0.1}"#).unwrap();
        assert!(matches!(
            FaultSpec::from_json(&typo),
            Err(ScenarioError::UnknownField { .. })
        ));
        for bad in [
            r#"{"crash_prob": 1.0}"#,
            r#"{"crash_prob": -0.1}"#,
            r#"{"cold_crash_multiplier": 0.5}"#,
            r#"{"throttle_prob": 1.5}"#,
            r#"{"timeout": -1.0}"#,
            r#"{"timeout": 0.0}"#,
            r#"{"backoff_base": -0.5}"#,
            r#"{"hedge_quantile": 1.0}"#,
            r#"{"hedge_min_obs": 0}"#,
        ] {
            assert!(
                matches!(
                    FaultSpec::from_json(&Json::parse(bad).unwrap()),
                    Err(ScenarioError::Invalid { .. })
                ),
                "must reject {bad}"
            );
        }

        // Faults require the pipelined event engine.
        let mut cfg = TrafficConfig::default();
        cfg.faults.crash_prob = 0.1;
        assert!(cfg.validate().is_ok());
        cfg.engine = SimEngine::Event { pipeline: false };
        assert!(matches!(cfg.validate(), Err(ScenarioError::Invalid { .. })));
        cfg.engine = SimEngine::Legacy;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn decode_batch_window_roundtrips_and_is_range_checked() {
        let mut cfg = TrafficConfig::default();
        cfg.decode_batch_window = 0.05;
        let back = TrafficConfig::from_json(&Json::parse(&cfg.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back.decode_batch_window, 0.05);

        cfg.decode_batch_window = -0.1;
        assert!(matches!(cfg.validate(), Err(ScenarioError::Invalid { .. })));
        cfg.decode_batch_window = f64::NAN;
        assert!(cfg.validate().is_err());

        // A merged decode flush has no per-member fate, and the monolithic
        // engines have no per-step events to merge — both combos rejected.
        cfg.decode_batch_window = 0.05;
        cfg.engine = SimEngine::Legacy;
        assert!(cfg.validate().is_err());
        cfg.engine = SimEngine::Event { pipeline: true };
        assert!(cfg.validate().is_ok());
        cfg.faults.crash_prob = 0.1;
        assert!(matches!(cfg.validate(), Err(ScenarioError::Invalid { .. })));
    }
}
