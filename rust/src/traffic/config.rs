//! Traffic-simulation configuration: epoching, lifecycle, queueing and
//! autoscaling knobs, plus the deployment problem they pose.

use super::autoscale::AutoscalePolicy;
use crate::config::{DeployConfig, PlatformConfig};
use crate::deploy::DeployProblem;
use crate::model::MoeModelSpec;

/// Which dispatch engine [`super::epoch::EpochSimulator`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimEngine {
    /// The PR 2 serial per-request loop: all of a request's layers are
    /// dispatched at its ready time. Kept reachable as the cross-validation
    /// baseline and the bench harness's reference.
    Legacy,
    /// Event-driven discrete-event engine over a flat replica-slot arena
    /// (`super::sim`). With `pipeline: false` it reproduces the legacy
    /// monolithic dispatch bit-for-bit; with `pipeline: true` each request's
    /// layer *k+1* is dispatched when layer *k* completes, so later layers'
    /// queue waits overlap earlier layers' compute across concurrent
    /// requests — the paper's pipelined scatter-gather at the serving level.
    Event { pipeline: bool },
}

/// How the engine aggregates per-request metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MetricsMode {
    /// Exact per-request vectors: sorted percentiles and the cumulative
    /// cost timeline (memory grows with the request count).
    Exact,
    /// O(1)-memory log-scale histograms ([`crate::util::stats::LogHistogram`]):
    /// percentile estimates within one bucket width (5% relative), exact
    /// mean/max, no cost timeline. Event engine only — the legacy loop
    /// always aggregates exactly.
    Streaming,
}

/// Traffic-simulation knobs.
#[derive(Debug, Clone)]
pub struct TrafficConfig {
    /// Epoch length: how often drift is reviewed and the autoscaler runs
    /// (seconds).
    pub epoch_secs: f64,
    /// Instance keep-alive after an invocation finishes (seconds;
    /// `f64::INFINITY` never expires).
    pub keep_alive: f64,
    /// Concurrent invocations one replica instance can execute. `Some(1)`
    /// is the Lambda semantics (one invocation per environment — the
    /// default); `None` is unbounded, the PR 1 serving model in which
    /// overlapping requests never queue.
    pub concurrency: Option<usize>,
    /// Replica autoscaling between full redeploys (see
    /// [`super::autoscale::Autoscaler`]); `Off` by default.
    pub autoscale: AutoscalePolicy,
    /// Pre-warm every replica of the initial deployment (the paper's
    /// warm-up invocation before measurement).
    pub prewarm: bool,
    /// Enable online re-optimization at epoch boundaries.
    pub reoptimize: bool,
    /// BO refinement iterations per re-optimization (0 = pure ODS re-solve).
    pub bo_round_iters: usize,
    /// Total-variation drift (realized vs deployed-for popularity, averaged
    /// over layers, in [0, 1]) that triggers re-deployment.
    pub drift_threshold: f64,
    /// EMA smoothing factor for realized popularity.
    pub ema_alpha: f64,
    /// Serving SLO T_limit handed to the deployment problem.
    pub t_limit: f64,
    /// Per-fixed-method solver time limit (seconds).
    pub solver_time_limit: f64,
    pub max_replicas: usize,
    pub beta_grid: Vec<usize>,
    pub seed: u64,
    /// Dispatch engine (event-driven and layer-pipelined by default; the
    /// legacy PR 2 loop stays reachable for cross-validation).
    pub engine: SimEngine,
    /// Metric aggregation (exact by default; streaming keeps memory O(1) in
    /// the request count for million-request runs).
    pub metrics: MetricsMode,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        let deploy = DeployConfig::default();
        Self {
            epoch_secs: 60.0,
            keep_alive: 900.0,
            concurrency: Some(1),
            autoscale: AutoscalePolicy::Off,
            prewarm: true,
            reoptimize: true,
            bo_round_iters: 0,
            drift_threshold: 0.2,
            ema_alpha: 0.3,
            t_limit: 3000.0,
            solver_time_limit: 0.5,
            max_replicas: deploy.max_replicas,
            beta_grid: deploy.beta_grid,
            seed: 0x7_1AFF,
            engine: SimEngine::Event { pipeline: true },
            metrics: MetricsMode::Exact,
        }
    }
}

impl TrafficConfig {
    /// Degenerate configuration for cross-validation against the seed
    /// single-batch pipeline: one infinite epoch, a pre-warmed pool that
    /// never expires, unbounded concurrency, no autoscaling, no
    /// re-optimization — serving one batch must then reproduce
    /// `serve_with_real_counts(.., warm = true)` exactly.
    pub fn degenerate() -> TrafficConfig {
        TrafficConfig {
            epoch_secs: f64::INFINITY,
            keep_alive: f64::INFINITY,
            concurrency: None,
            autoscale: AutoscalePolicy::Off,
            prewarm: true,
            reoptimize: false,
            bo_round_iters: 0,
            ..TrafficConfig::default()
        }
    }

    /// The deployment problem this configuration poses for a predicted (or
    /// real) token distribution — shared by the epoch loop and the baseline
    /// builders so every run solves the same problem shape.
    pub fn problem<'b>(
        &self,
        platform: &'b PlatformConfig,
        spec: &'b MoeModelSpec,
        tokens: Vec<Vec<u64>>,
    ) -> DeployProblem<'b> {
        DeployProblem {
            cfg: platform,
            spec,
            tokens,
            t_limit: self.t_limit,
            max_replicas: self.max_replicas,
            beta_grid: self.beta_grid.clone(),
            warm: true,
        }
    }
}
