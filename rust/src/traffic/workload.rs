//! Autoregressive LLM workloads: prefill/decode phases, decode-length
//! models, and the KV-state ledger.
//!
//! The simulator historically pushed each request through the layer stack
//! exactly once — the right model for encoder/batch inference, the wrong
//! one for the chat-style serving that dominates MoE LLM deployments (the
//! regime Remoe and MoEless are built for). This module adds the
//! autoregressive request model on top of the event engine:
//!
//!  - a request serves a **prefill** pass over its prompt tokens, then a
//!    seeded, distribution-drawn number of **decode** steps, each re-routed
//!    through `gating::RouterCache` with fresh tokens at advancing position
//!    offsets — so expert popularity drifts *within* a request, the harder
//!    signal the Bayesian predictor was built to chase;
//!  - a [`KvLedger`] pins a request's decode steps to the replica instances
//!    that served it: if any pinned instance goes cold (keep-alive expiry or
//!    autoscaler scale-in) before the next step, the KV state is lost and
//!    the engine bills a full **re-prefill** before decoding resumes;
//!  - decode steps of co-resident requests can merge into one invocation
//!    per iteration (continuous batching) when
//!    `TrafficConfig::decode_batch_window > 0` — see `traffic::sim`.
//!
//! A decode length of 0 degenerates every request to the classic
//! single-pass model, byte-identical to the pre-decode engine — the same
//! off-switch discipline as `FaultSpec::off` and `batch_window: 0`.

use super::error::{self, ScenarioError};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{Batch, Corpus, Sequence};

/// Which phase of the autoregressive pipeline a request is in.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RequestPhase {
    /// Serving the prompt pass (also a billed re-prefill after KV loss).
    #[default]
    Prefill,
    /// Emitting output tokens one step at a time.
    Decode,
}

/// How many decode steps a request runs — drawn per request from the
/// scenario's dedicated decode RNG stream (`traffic::decode_seed`).
#[derive(Debug, Clone, PartialEq)]
pub enum DecodeLengthModel {
    /// Every request decodes exactly `steps` steps (0 = pure prefill, the
    /// byte-identity degenerate case).
    Fixed { steps: u32 },
    /// Geometric output lengths with the given mean, capped at `cap` steps —
    /// the memoryless "will the model emit EOS next?" model of chat traffic.
    Geometric { mean: f64, cap: u32 },
    /// Trace-given lengths: request `i` decodes `lengths[i % lengths.len()]`
    /// steps (cycled, so a short list covers any request count).
    Given { lengths: Vec<u32> },
}

impl DecodeLengthModel {
    /// Decode length of request `i`. Deterministic given the RNG state:
    /// `Fixed` and `Given` draw nothing, `Geometric` draws one uniform.
    pub fn draw(&self, i: usize, rng: &mut Rng) -> u32 {
        match self {
            DecodeLengthModel::Fixed { steps } => *steps,
            DecodeLengthModel::Geometric { mean, cap } => {
                // Inverse-CDF geometric on {0, 1, 2, ...} with the given
                // mean: p = 1/(mean+1), len = floor(ln(1-u)/ln(1-p)).
                let p = 1.0 / (mean + 1.0);
                let u = rng.f64();
                let len = ((1.0 - u).ln() / (1.0 - p).ln()).floor();
                (len.max(0.0) as u32).min(*cap)
            }
            DecodeLengthModel::Given { lengths } => lengths[i % lengths.len()],
        }
    }

    /// Non-panicking parameter validation, surfaced by the scenario loader.
    pub fn check(&self) -> Result<(), ScenarioError> {
        match self {
            DecodeLengthModel::Fixed { .. } => Ok(()),
            DecodeLengthModel::Geometric { mean, cap } => {
                if !(mean.is_finite() && *mean >= 0.0) {
                    return Err(ScenarioError::invalid(
                        "traffic.decode.mean",
                        format!("must be finite and >= 0, got {mean}"),
                    ));
                }
                if *cap < 1 {
                    return Err(ScenarioError::invalid(
                        "traffic.decode.cap",
                        "must be >= 1 (use kind \"fixed\", steps 0 for no decode)".to_string(),
                    ));
                }
                Ok(())
            }
            DecodeLengthModel::Given { lengths } => {
                if lengths.is_empty() {
                    return Err(ScenarioError::invalid(
                        "traffic.decode.lengths",
                        "must not be empty".to_string(),
                    ));
                }
                Ok(())
            }
        }
    }

    /// Scenario-file encoding: a tagged object, e.g.
    /// `{"kind": "geometric", "mean": 32.0, "cap": 256}`.
    pub fn to_json(&self) -> Json {
        match self {
            DecodeLengthModel::Fixed { steps } => Json::from_pairs(vec![
                ("kind", Json::str("fixed")),
                ("steps", Json::num(*steps as f64)),
            ]),
            DecodeLengthModel::Geometric { mean, cap } => Json::from_pairs(vec![
                ("kind", Json::str("geometric")),
                ("mean", Json::num(*mean)),
                ("cap", Json::num(*cap as f64)),
            ]),
            DecodeLengthModel::Given { lengths } => Json::from_pairs(vec![
                ("kind", Json::str("given")),
                (
                    "lengths",
                    Json::arr_u64(&lengths.iter().map(|&l| l as u64).collect::<Vec<_>>()),
                ),
            ]),
        }
    }

    /// Strict inverse of [`DecodeLengthModel::to_json`]: unknown kinds and
    /// fields rejected, parameters range-checked.
    pub fn from_json(j: &Json) -> Result<DecodeLengthModel, ScenarioError> {
        const SECTION: &str = "traffic.decode";
        let model = match error::req_str(j, SECTION, "kind")? {
            "fixed" => {
                error::check_keys(j, SECTION, &["kind", "steps"])?;
                DecodeLengthModel::Fixed {
                    steps: error::opt_u64(j, SECTION, "steps", 0)? as u32,
                }
            }
            "geometric" => {
                error::check_keys(j, SECTION, &["kind", "mean", "cap"])?;
                DecodeLengthModel::Geometric {
                    mean: error::req_f64(j, SECTION, "mean")?,
                    cap: error::opt_u64(j, SECTION, "cap", 256)? as u32,
                }
            }
            "given" => {
                error::check_keys(j, SECTION, &["kind", "lengths"])?;
                let lengths = match j.get("lengths") {
                    Some(Json::Arr(items)) => {
                        let mut out = Vec::with_capacity(items.len());
                        for item in items {
                            match item.as_u64() {
                                Some(l) => out.push(l as u32),
                                None => {
                                    return Err(ScenarioError::invalid(
                                        "traffic.decode.lengths",
                                        format!("entries must be integers >= 0, got {item:?}"),
                                    ))
                                }
                            }
                        }
                        out
                    }
                    _ => {
                        return Err(ScenarioError::missing(SECTION, "lengths"));
                    }
                };
                DecodeLengthModel::Given { lengths }
            }
            other => {
                return Err(ScenarioError::UnknownName {
                    what: "decode length model",
                    name: other.to_string(),
                    known: "fixed | geometric | given",
                })
            }
        };
        model.check()?;
        Ok(model)
    }
}

/// One decode-step batch: `tokens` fresh corpus tokens at positions starting
/// from `pos_offset` (the autoregressive position of the step's tokens in
/// the growing sequence — position buckets advance across steps, which is
/// what makes routing drift within a request).
fn step_batch(corpus: &Corpus, rng: &mut Rng, tokens: usize, pos_offset: u32) -> Batch {
    let mut toks = Vec::with_capacity(tokens);
    let mut attn = Vec::with_capacity(tokens);
    while toks.len() < tokens {
        let s = corpus.sample_sequence(rng);
        toks.extend_from_slice(&s.tokens);
        attn.extend_from_slice(&s.attention_ids);
    }
    toks.truncate(tokens);
    attn.truncate(tokens);
    let positions = (0..tokens as u32).map(|i| pos_offset + i).collect();
    Batch::from_sequences(vec![Sequence {
        tokens: toks,
        positions,
        attention_ids: attn,
    }])
}

/// The pre-materialized decode schedule of a chat scenario: for request `i`
/// (traffic order), its decode length and the token batch of every decode
/// step. Generated once at scenario materialization, so both fleet drivers
/// and repeated runs see the exact same decode stream.
#[derive(Debug, Clone)]
pub struct ChatWorkload {
    /// Decode steps per request, aligned with the traffic vector.
    pub decode_lens: Vec<u32>,
    /// Per-request, per-step token batches (`steps[i].len() ==
    /// decode_lens[i]`); each step carries `decode_tokens` tokens.
    pub steps: Vec<Vec<Batch>>,
}

impl ChatWorkload {
    /// Materialize the decode schedule for `requests` requests: lengths from
    /// `model` on the seed stream, step batches from an independent fork of
    /// it, positions offset past the prompt so routing drifts across steps.
    pub fn generate(
        corpus: &Corpus,
        seed: u64,
        model: &DecodeLengthModel,
        decode_tokens: usize,
        prompt_tokens: usize,
        requests: usize,
    ) -> ChatWorkload {
        assert!(decode_tokens >= 1, "decode_tokens must be >= 1");
        let mut len_rng = Rng::new(seed);
        let mut tok_rng = Rng::new(seed ^ 0x57E9);
        let mut decode_lens = Vec::with_capacity(requests);
        let mut steps = Vec::with_capacity(requests);
        for i in 0..requests {
            let len = model.draw(i, &mut len_rng);
            let mut req_steps = Vec::with_capacity(len as usize);
            for s in 0..len {
                let off = (prompt_tokens + s as usize * decode_tokens).min(u32::MAX as usize);
                req_steps.push(step_batch(corpus, &mut tok_rng, decode_tokens, off as u32));
            }
            decode_lens.push(len);
            steps.push(req_steps);
        }
        ChatWorkload { decode_lens, steps }
    }

    /// Total decode steps across all requests (the output-token budget of
    /// the run, in steps).
    pub fn total_decode_steps(&self) -> u64 {
        self.decode_lens.iter().map(|&l| l as u64).sum()
    }
}

/// KV-state ledger: which replica instances hold each in-flight request's
/// attention state.
///
/// During a prefill pass the engine pins every instance the request's layers
/// dispatch to; before each decode step it asks whether the pinned set is
/// still warm. Any pinned instance gone cold means the KV state died with
/// its environment — the request must re-prefill (billed in full) before
/// decoding resumes. Slots are recycled with the engine's in-flight arena,
/// so the ledger is indexed by slot id.
#[derive(Debug, Default)]
pub struct KvLedger {
    /// Per-slot pinned arena indices (deduplicated, small sets).
    sets: Vec<Vec<usize>>,
    /// KV states lost to cold instances across the run.
    pub evictions: u64,
    /// Billed re-prefill passes forced by those losses.
    pub re_prefills: u64,
}

impl KvLedger {
    pub fn new() -> KvLedger {
        KvLedger::default()
    }

    /// Start (or restart, after a loss) accumulating a slot's pinned set.
    pub fn begin(&mut self, slot: usize) {
        if self.sets.len() <= slot {
            self.sets.resize_with(slot + 1, Vec::new);
        }
        self.sets[slot].clear();
    }

    /// Pin an arena instance into the slot's KV set (idempotent).
    pub fn pin(&mut self, slot: usize, idx: usize) {
        if self.sets.len() <= slot {
            self.sets.resize_with(slot + 1, Vec::new);
        }
        let set = &mut self.sets[slot];
        if !set.contains(&idx) {
            set.push(idx);
        }
    }

    /// Whether every pinned instance of `slot` still passes `is_warm`.
    /// A never-pinned slot is vacuously intact (nothing to lose).
    pub fn intact(&self, slot: usize, is_warm: impl Fn(usize) -> bool) -> bool {
        self.sets
            .get(slot)
            .map_or(true, |set| set.iter().all(|&idx| is_warm(idx)))
    }

    /// Pinned instances of `slot` (test introspection).
    pub fn pinned(&self, slot: usize) -> &[usize] {
        self.sets.get(slot).map_or(&[], |s| s.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::CorpusPreset;

    #[test]
    fn fixed_and_given_draw_without_rng() {
        let mut rng = Rng::new(1);
        let before = rng.clone().next_u64();
        assert_eq!(DecodeLengthModel::Fixed { steps: 5 }.draw(3, &mut rng), 5);
        let given = DecodeLengthModel::Given { lengths: vec![2, 7] };
        assert_eq!(given.draw(0, &mut rng), 2);
        assert_eq!(given.draw(1, &mut rng), 7);
        assert_eq!(given.draw(2, &mut rng), 2, "lengths cycle");
        assert_eq!(rng.next_u64(), before, "no RNG consumed");
    }

    #[test]
    fn geometric_is_bounded_and_roughly_mean() {
        let model = DecodeLengthModel::Geometric { mean: 8.0, cap: 64 };
        let mut rng = Rng::new(7);
        let n = 20_000;
        let mut total = 0u64;
        for i in 0..n {
            let l = model.draw(i, &mut rng);
            assert!(l <= 64);
            total += l as u64;
        }
        let mean = total as f64 / n as f64;
        assert!((mean - 8.0).abs() < 0.5, "empirical mean {mean}");
    }

    #[test]
    fn decode_model_json_roundtrip_and_rejection() {
        for model in [
            DecodeLengthModel::Fixed { steps: 0 },
            DecodeLengthModel::Fixed { steps: 12 },
            DecodeLengthModel::Geometric { mean: 32.0, cap: 256 },
            DecodeLengthModel::Given { lengths: vec![1, 2, 3] },
        ] {
            let back = DecodeLengthModel::from_json(&model.to_json()).unwrap();
            assert_eq!(back, model);
        }
        let bad_kind = Json::parse(r#"{"kind":"zipf","mean":1}"#).unwrap();
        assert!(matches!(
            DecodeLengthModel::from_json(&bad_kind),
            Err(ScenarioError::UnknownName { .. })
        ));
        let typo = Json::parse(r#"{"kind":"fixed","step":3}"#).unwrap();
        assert!(matches!(
            DecodeLengthModel::from_json(&typo),
            Err(ScenarioError::UnknownField { .. })
        ));
        let neg_mean = Json::parse(r#"{"kind":"geometric","mean":-1.0}"#).unwrap();
        assert!(matches!(
            DecodeLengthModel::from_json(&neg_mean),
            Err(ScenarioError::Invalid { .. })
        ));
        let zero_cap = Json::parse(r#"{"kind":"geometric","mean":4.0,"cap":0}"#).unwrap();
        assert!(DecodeLengthModel::from_json(&zero_cap).is_err());
        let empty = Json::parse(r#"{"kind":"given","lengths":[]}"#).unwrap();
        assert!(DecodeLengthModel::from_json(&empty).is_err());
    }

    #[test]
    fn chat_workload_is_deterministic_and_shaped() {
        let corpus = Corpus::new(CorpusPreset::Enwik8, 3);
        let model = DecodeLengthModel::Geometric { mean: 4.0, cap: 16 };
        let mk = || ChatWorkload::generate(&corpus, 99, &model, 8, 64, 10);
        let a = mk();
        let b = mk();
        assert_eq!(a.decode_lens, b.decode_lens);
        assert_eq!(a.decode_lens.len(), 10);
        assert_eq!(a.steps.len(), 10);
        for (i, req_steps) in a.steps.iter().enumerate() {
            assert_eq!(req_steps.len(), a.decode_lens[i] as usize);
            for (s, batch) in req_steps.iter().enumerate() {
                assert_eq!(batch.total_tokens, 8);
                let b2 = &b.steps[i][s];
                assert_eq!(batch.sequences[0].tokens, b2.sequences[0].tokens);
                // Positions advance past the prompt as the sequence grows.
                assert_eq!(batch.sequences[0].positions[0], 64 + s as u32 * 8);
            }
        }
        // A different decode seed re-rolls the schedule.
        let c = ChatWorkload::generate(&corpus, 100, &model, 8, 64, 10);
        assert!(
            a.decode_lens != c.decode_lens
                || a.steps
                    .iter()
                    .flatten()
                    .zip(c.steps.iter().flatten())
                    .any(|(x, y)| x.sequences[0].tokens != y.sequences[0].tokens)
        );
    }

    #[test]
    fn steps_drift_routing_within_a_request() {
        // The point of per-step re-routing: two steps of one request land
        // different expert counts (drift the predictor must chase).
        use crate::gating::{RouterCache, SimGate};
        use crate::model::ModelPreset;
        let spec = ModelPreset::BertMoe { experts: 4, top_k: 1 }.spec();
        let gate = SimGate::new(&spec, 7);
        let mut router = RouterCache::new(&gate);
        let corpus = Corpus::new(CorpusPreset::Enwik8, 3);
        let model = DecodeLengthModel::Fixed { steps: 6 };
        let w = ChatWorkload::generate(&corpus, 42, &model, 32, 128, 1);
        let mut counts = Vec::new();
        let mut seen = std::collections::HashSet::new();
        for step in &w.steps[0] {
            router.counts_into(&gate, step, &mut counts);
            seen.insert(format!("{:?}", counts[0]));
        }
        assert!(seen.len() > 1, "expert counts identical across all steps");
    }

    #[test]
    fn kv_ledger_semantics() {
        let mut kv = KvLedger::new();
        // Never-pinned slots are vacuously intact.
        assert!(kv.intact(0, |_| false));
        kv.begin(2);
        kv.pin(2, 10);
        kv.pin(2, 11);
        kv.pin(2, 10); // dedup
        assert_eq!(kv.pinned(2), &[10, 11]);
        assert!(kv.intact(2, |idx| idx == 10 || idx == 11));
        assert!(!kv.intact(2, |idx| idx == 10), "one cold pin loses the KV");
        // begin() resets the set when the slot re-prefills or is recycled.
        kv.begin(2);
        assert!(kv.intact(2, |_| false));
        assert_eq!(kv.evictions, 0);
        assert_eq!(kv.re_prefills, 0);
    }
}
