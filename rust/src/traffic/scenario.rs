//! The front door: a declarative, serializable description of a whole
//! simulation — model, platform, traffic source, engine configuration and
//! baseline — with one way in ([`Scenario::run`]) and one way out
//! ([`ScenarioOutcome`]).
//!
//! The paper's contribution is a *pipeline* (predict expert popularity,
//! deploy via ODS/BO, serve with pipelined scatter-gather); before this
//! module every example and experiment hand-wired `ModelPreset` →
//! `MoeModelSpec` → `SimGate` → `BayesPredictor` → `TrafficConfig` →
//! `EpochSimulator` in its own slightly different way. A [`Scenario`]
//! captures that wiring as data:
//!
//! ```json
//! {
//!   "version": 1,
//!   "name": "drift-bert-quick",
//!   "model": "bert",
//!   "traffic": { "kind": "drift", "quick": true },
//!   "config": { "epoch_secs": 60.0, "drift_threshold": 0.15 },
//!   "baseline": "ours"
//! }
//! ```
//!
//! ```no_run
//! use serverless_moe::traffic::scenario::Scenario;
//! let scenario = Scenario::load(std::path::Path::new("scenario.json"))?;
//! let outcome = scenario.run()?;
//! println!("billed cost: {}", outcome.report.total_cost);
//! # Ok::<(), serverless_moe::traffic::ScenarioError>(())
//! ```
//!
//! Construction is validated ([`ScenarioBuilder::build`] /
//! [`Scenario::from_json`] return typed [`ScenarioError`]s, never panics),
//! parsing is *strict* (unknown fields are rejected — a typo in a committed
//! scenario file fails loudly), and a scenario (de)serializes losslessly:
//! the committed fixtures under `rust/tests/data/scenarios/` are pinned by
//! serialize → deserialize → byte-identical-report round-trip tests.
//!
//! [`Scenario::materialize`] compiles the description into a
//! [`TrafficScenario`] (spec, gate, profiled predictor state, timestamped
//! request stream); [`TrafficScenario::run`] serves it under any
//! [`Baseline`] and returns the [`SimReport`] plus [`RunArtifacts`]
//! (deployment history, redeploy/autoscale events, per-request latencies) —
//! callers never reach into `EpochSimulator` fields.

use super::arrivals::{arrival_seed, decode_seed, ArrivalGen, ArrivalProcess};
use super::config::{SimEngine, TrafficConfig};
use super::epoch::EpochSimulator;
use super::error::{self, ScenarioError};
use super::report::SimReport;
use super::trace::Trace;
use super::workload::{ChatWorkload, DecodeLengthModel};
use crate::config::workload::CorpusPreset;
use crate::config::{CpuClusterConfig, PlatformConfig};
use crate::deploy::baselines::lambdaml_policy;
use crate::deploy::DeploymentPolicy;
use crate::gating::SimGate;
use crate::model::{ModelPreset, MoeModelSpec};
use crate::platform::CpuCluster;
use crate::predictor::bayes::TokenPrior;
use crate::predictor::eval::{predicted_counts, real_counts};
use crate::predictor::profile::profile_batches;
use crate::predictor::{BayesPredictor, DatasetTable};
use crate::util::json::Json;
use crate::workload::{Corpus, RequestGenerator, TimedBatch};
use std::path::Path;

// --------------------------------------------------------------- sources

/// Where the model comes from: a named preset or an inline homogeneous
/// spec (every preset is itself homogeneous, so the two encodings are
/// interchangeable; unnamed preset parameterizations serialize inline).
#[derive(Debug, Clone, PartialEq)]
pub enum ModelSource {
    Preset(ModelPreset),
    Homogeneous {
        name: String,
        hidden: usize,
        ffn: usize,
        vocab: usize,
        layers: usize,
        experts: usize,
        top_k: usize,
    },
}

impl ModelSource {
    pub fn spec(&self) -> MoeModelSpec {
        match self {
            ModelSource::Preset(p) => p.spec(),
            ModelSource::Homogeneous {
                name,
                hidden,
                ffn,
                vocab,
                layers,
                experts,
                top_k,
            } => MoeModelSpec::homogeneous(name, *hidden, *ffn, *vocab, *layers, *experts, *top_k),
        }
    }

    fn inline_json(spec: &MoeModelSpec) -> Json {
        Json::from_pairs(vec![
            ("name", Json::str(&spec.name)),
            ("hidden", Json::num(spec.hidden as f64)),
            ("ffn", Json::num(spec.ffn_dim as f64)),
            ("vocab", Json::num(spec.vocab as f64)),
            ("layers", Json::num(spec.num_moe_layers() as f64)),
            ("experts", Json::num(spec.experts_at(0) as f64)),
            ("top_k", Json::num(spec.top_k as f64)),
        ])
    }

    pub fn to_json(&self) -> Json {
        match self {
            ModelSource::Preset(p) => match p.canonical_name() {
                Some(n) => Json::str(n),
                None => Self::inline_json(&p.spec()),
            },
            ModelSource::Homogeneous { .. } => Self::inline_json(&self.spec()),
        }
    }

    pub fn from_json(j: &Json) -> Result<ModelSource, ScenarioError> {
        const SECTION: &str = "model";
        match j {
            Json::Str(s) => match ModelPreset::from_name(s) {
                Some(p) => Ok(ModelSource::Preset(p)),
                None => Err(ScenarioError::UnknownName {
                    what: "model preset",
                    name: s.clone(),
                    known: "bert | bert8 | bert16 | bert-top2 | gpt2 | gpt2-top2 | bert2bert | tiny",
                }),
            },
            Json::Obj(_) => {
                error::check_keys(
                    j,
                    SECTION,
                    &["name", "hidden", "ffn", "vocab", "layers", "experts", "top_k"],
                )?;
                let dim = |key: &str| -> Result<usize, ScenarioError> {
                    if j.get(key).is_none() {
                        return Err(ScenarioError::missing(SECTION, key));
                    }
                    match error::opt_u64(j, SECTION, key, 0)? {
                        0 => Err(ScenarioError::invalid(
                            format!("{SECTION}.{key}"),
                            "must be >= 1",
                        )),
                        v => Ok(v as usize),
                    }
                };
                Ok(ModelSource::Homogeneous {
                    name: error::req_str(j, SECTION, "name")?.to_string(),
                    hidden: dim("hidden")?,
                    ffn: dim("ffn")?,
                    vocab: dim("vocab")?,
                    layers: dim("layers")?,
                    experts: dim("experts")?,
                    top_k: dim("top_k")?,
                })
            }
            other => Err(ScenarioError::invalid(
                SECTION,
                format!("expected a preset name or an inline spec object, got {other:?}"),
            )),
        }
    }

    fn check(&self) -> Result<(), ScenarioError> {
        let spec = self.spec();
        if spec.num_moe_layers() == 0 {
            return Err(ScenarioError::invalid("model.layers", "must be >= 1"));
        }
        let experts = spec.experts_at(0);
        // Expert indices are u8 throughout the gate/router; a larger count
        // would silently truncate, so reject it here instead.
        if !(1..=256).contains(&experts) {
            return Err(ScenarioError::invalid(
                "model.experts",
                format!("must be in 1..=256 (expert indices are u8), got {experts}"),
            ));
        }
        if !(1..=4).contains(&spec.top_k) || spec.top_k > experts {
            return Err(ScenarioError::invalid(
                "model.top_k",
                format!(
                    "must be in 1..=4 and <= experts ({experts}), got {}",
                    spec.top_k
                ),
            ));
        }
        Ok(())
    }
}

/// Where the requests come from.
#[derive(Debug, Clone, PartialEq)]
pub enum TrafficSource {
    /// The canned two-phase drift workload of the paper-style experiments:
    /// heavy phase-A requests from one corpus permutation, then light
    /// phase-B requests from a re-permuted corpus (new popular experts),
    /// under bursty MMPP arrivals. The predictor profiles on phase A.
    Drift { quick: bool },
    /// An arrival process over the scenario corpus; exactly one of
    /// `duration` (seconds) or `requests` (count) bounds the trace.
    Synthetic {
        process: ArrivalProcess,
        duration: Option<f64>,
        requests: Option<usize>,
        tokens_per_request: usize,
    },
    /// A JSON request-trace file (see [`Trace`] for the schema), resolved
    /// against the current working directory at materialization time.
    TracePath { path: String },
    /// A request trace inlined into the scenario itself.
    Inline { trace: Trace },
    /// Chat-style autoregressive traffic: each request is a
    /// `prompt_tokens`-token prompt (materialized exactly like `synthetic`
    /// traffic — a decode length of 0 reproduces it byte-for-byte) followed
    /// by a decode phase whose length is drawn per request from `decode` on
    /// the seeded stream. Every decode step routes `decode_tokens` fresh
    /// tokens through the gate at positions offset past the prompt, so
    /// expert popularity drifts *within* a request. Requires the pipelined
    /// event engine; the CPU-cluster baseline serves the prompts only.
    Chat {
        process: ArrivalProcess,
        duration: Option<f64>,
        requests: Option<usize>,
        prompt_tokens: usize,
        decode: DecodeLengthModel,
        decode_tokens: usize,
    },
}

impl TrafficSource {
    pub fn to_json(&self) -> Json {
        match self {
            TrafficSource::Drift { quick } => Json::from_pairs(vec![
                ("kind", Json::str("drift")),
                ("quick", Json::Bool(*quick)),
            ]),
            TrafficSource::Synthetic {
                process,
                duration,
                requests,
                tokens_per_request,
            } => {
                let mut pairs = vec![
                    ("kind", Json::str("synthetic")),
                    ("process", process.to_json()),
                    ("tokens_per_request", Json::num(*tokens_per_request as f64)),
                ];
                if let Some(d) = duration {
                    pairs.push(("duration", Json::num(*d)));
                }
                if let Some(n) = requests {
                    pairs.push(("requests", Json::num(*n as f64)));
                }
                Json::from_pairs(pairs)
            }
            TrafficSource::TracePath { path } => Json::from_pairs(vec![
                ("kind", Json::str("trace")),
                ("path", Json::str(path)),
            ]),
            TrafficSource::Inline { trace } => Json::from_pairs(vec![
                ("kind", Json::str("inline")),
                ("trace", trace.to_json()),
            ]),
            TrafficSource::Chat {
                process,
                duration,
                requests,
                prompt_tokens,
                decode,
                decode_tokens,
            } => {
                let mut pairs = vec![
                    ("kind", Json::str("chat")),
                    ("process", process.to_json()),
                    ("prompt_tokens", Json::num(*prompt_tokens as f64)),
                    ("decode", decode.to_json()),
                    ("decode_tokens", Json::num(*decode_tokens as f64)),
                ];
                if let Some(d) = duration {
                    pairs.push(("duration", Json::num(*d)));
                }
                if let Some(n) = requests {
                    pairs.push(("requests", Json::num(*n as f64)));
                }
                Json::from_pairs(pairs)
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<TrafficSource, ScenarioError> {
        const SECTION: &str = "traffic";
        let source = match error::req_str(j, SECTION, "kind")? {
            "drift" => {
                error::check_keys(j, SECTION, &["kind", "quick"])?;
                TrafficSource::Drift {
                    quick: error::opt_bool(j, SECTION, "quick", true)?,
                }
            }
            "synthetic" => {
                error::check_keys(
                    j,
                    SECTION,
                    &["kind", "process", "duration", "requests", "tokens_per_request"],
                )?;
                let process = ArrivalProcess::from_json(
                    j.get("process")
                        .ok_or_else(|| ScenarioError::missing(SECTION, "process"))?,
                )?;
                let duration = match j.get("duration") {
                    None => None,
                    Some(_) => Some(error::req_f64(j, SECTION, "duration")?),
                };
                let requests = match j.get("requests") {
                    None => None,
                    Some(_) => Some(error::opt_usize(j, SECTION, "requests", 0)?),
                };
                TrafficSource::Synthetic {
                    process,
                    duration,
                    requests,
                    tokens_per_request: error::opt_usize(j, SECTION, "tokens_per_request", 512)?,
                }
            }
            "trace" => {
                error::check_keys(j, SECTION, &["kind", "path"])?;
                TrafficSource::TracePath {
                    path: error::req_str(j, SECTION, "path")?.to_string(),
                }
            }
            "inline" => {
                error::check_keys(j, SECTION, &["kind", "trace"])?;
                TrafficSource::Inline {
                    trace: Trace::from_json(
                        j.get("trace")
                            .ok_or_else(|| ScenarioError::missing(SECTION, "trace"))?,
                    )?,
                }
            }
            "chat" => {
                error::check_keys(
                    j,
                    SECTION,
                    &[
                        "kind",
                        "process",
                        "duration",
                        "requests",
                        "prompt_tokens",
                        "decode",
                        "decode_tokens",
                    ],
                )?;
                let process = ArrivalProcess::from_json(
                    j.get("process")
                        .ok_or_else(|| ScenarioError::missing(SECTION, "process"))?,
                )?;
                let duration = match j.get("duration") {
                    None => None,
                    Some(_) => Some(error::req_f64(j, SECTION, "duration")?),
                };
                let requests = match j.get("requests") {
                    None => None,
                    Some(_) => Some(error::opt_usize(j, SECTION, "requests", 0)?),
                };
                TrafficSource::Chat {
                    process,
                    duration,
                    requests,
                    prompt_tokens: error::opt_usize(j, SECTION, "prompt_tokens", 512)?,
                    decode: DecodeLengthModel::from_json(
                        j.get("decode")
                            .ok_or_else(|| ScenarioError::missing(SECTION, "decode"))?,
                    )?,
                    decode_tokens: error::opt_usize(j, SECTION, "decode_tokens", 32)?,
                }
            }
            other => {
                return Err(ScenarioError::UnknownName {
                    what: "traffic source",
                    name: other.to_string(),
                    known: "drift | synthetic | trace | inline | chat",
                })
            }
        };
        source.check()?;
        Ok(source)
    }

    fn check(&self) -> Result<(), ScenarioError> {
        match self {
            TrafficSource::Drift { .. } => Ok(()),
            TrafficSource::Synthetic {
                process,
                duration,
                requests,
                tokens_per_request,
            } => {
                process.check()?;
                match (duration, requests) {
                    (Some(d), None) if *d > 0.0 && d.is_finite() => {}
                    (Some(d), None) => {
                        return Err(ScenarioError::invalid(
                            "traffic.duration",
                            format!("must be finite and > 0, got {d}"),
                        ))
                    }
                    (None, Some(n)) if *n > 0 => {}
                    (None, Some(_)) => {
                        return Err(ScenarioError::invalid("traffic.requests", "must be > 0"))
                    }
                    _ => {
                        return Err(ScenarioError::invalid(
                            "traffic",
                            "exactly one of 'duration' or 'requests' must be set",
                        ))
                    }
                }
                if *tokens_per_request == 0 {
                    return Err(ScenarioError::invalid(
                        "traffic.tokens_per_request",
                        "must be > 0",
                    ));
                }
                Ok(())
            }
            TrafficSource::TracePath { path } => {
                if path.is_empty() {
                    Err(ScenarioError::invalid("traffic.path", "must not be empty"))
                } else {
                    Ok(())
                }
            }
            TrafficSource::Inline { trace } => {
                if trace.requests.is_empty() {
                    Err(ScenarioError::EmptyTraffic)
                } else {
                    Ok(())
                }
            }
            TrafficSource::Chat {
                process,
                duration,
                requests,
                prompt_tokens,
                decode,
                decode_tokens,
            } => {
                process.check()?;
                match (duration, requests) {
                    (Some(d), None) if *d > 0.0 && d.is_finite() => {}
                    (Some(d), None) => {
                        return Err(ScenarioError::invalid(
                            "traffic.duration",
                            format!("must be finite and > 0, got {d}"),
                        ))
                    }
                    (None, Some(n)) if *n > 0 => {}
                    (None, Some(_)) => {
                        return Err(ScenarioError::invalid("traffic.requests", "must be > 0"))
                    }
                    _ => {
                        return Err(ScenarioError::invalid(
                            "traffic",
                            "exactly one of 'duration' or 'requests' must be set",
                        ))
                    }
                }
                if *prompt_tokens == 0 {
                    return Err(ScenarioError::invalid("traffic.prompt_tokens", "must be > 0"));
                }
                if *decode_tokens == 0 {
                    return Err(ScenarioError::invalid("traffic.decode_tokens", "must be > 0"));
                }
                decode.check()
            }
        }
    }
}

/// Which deployment strategy serves the scenario (§V's comparison set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    /// The paper's system: ODS initial deployment, then online
    /// re-optimization as configured (`config.reoptimize`,
    /// `config.bo_round_iters`).
    Ours,
    /// The ODS initial deployment, never re-optimized.
    Static,
    /// LambdaML-style over-provisioning (max memory everywhere), never
    /// re-optimized.
    LambdaML,
    /// The rented CPU-cluster baseline (no serverless machinery at all).
    CpuCluster,
}

impl Baseline {
    pub fn name(&self) -> &'static str {
        match self {
            Baseline::Ours => "ours",
            Baseline::Static => "static",
            Baseline::LambdaML => "lambdaml",
            Baseline::CpuCluster => "cpu-cluster",
        }
    }

    pub fn from_name(s: &str) -> Result<Baseline, ScenarioError> {
        match s {
            "ours" => Ok(Baseline::Ours),
            "static" => Ok(Baseline::Static),
            "lambdaml" => Ok(Baseline::LambdaML),
            "cpu-cluster" => Ok(Baseline::CpuCluster),
            other => Err(ScenarioError::UnknownName {
                what: "baseline",
                name: other.to_string(),
                known: "ours | static | lambdaml | cpu-cluster",
            }),
        }
    }
}

/// Predictor profiling pass sizing (ignored by [`TrafficSource::Drift`],
/// which carries its own paper-matched profiling recipe).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProfileSpec {
    /// Profiling batches fed through the gate before serving starts.
    pub batches: usize,
    /// Token target per profiling batch.
    pub tokens: usize,
}

impl Default for ProfileSpec {
    fn default() -> Self {
        ProfileSpec { batches: 6, tokens: 512 }
    }
}

// -------------------------------------------------------------- scenario

/// A complete, serializable simulation description. Construct via
/// [`Scenario::builder`] or load from JSON ([`Scenario::load`]); run via
/// [`Scenario::run`] or compile once with [`Scenario::materialize`] and
/// serve several baselines/configs against the same compiled state.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub model: ModelSource,
    /// Master seed: corpus content, request generation, arrivals and trace
    /// replay all derive from it (the gate has its own seed below).
    pub seed: u64,
    /// Gating-network seed — which experts are popular for which tokens.
    pub gate_seed: u64,
    /// Corpus preset the requests (and the profiling pass) sample from.
    pub corpus: CorpusPreset,
    pub profile: ProfileSpec,
    pub platform: PlatformConfig,
    pub cpu: CpuClusterConfig,
    pub source: TrafficSource,
    pub cfg: TrafficConfig,
    pub baseline: Baseline,
}

impl Scenario {
    pub fn builder(name: &str) -> ScenarioBuilder {
        ScenarioBuilder::new(name)
    }

    /// Validate every section (typed errors; never panics).
    pub fn validate(&self) -> Result<(), ScenarioError> {
        self.model.check()?;
        self.source.check()?;
        self.cfg.validate()?;
        for (field, seed) in [("seed", self.seed), ("gate_seed", self.gate_seed)] {
            if seed >= (1u64 << 53) {
                return Err(ScenarioError::invalid(
                    field,
                    format!("{seed} exceeds the 2^53 JSON-number range"),
                ));
            }
        }
        if self.profile.batches == 0 {
            return Err(ScenarioError::invalid("profile.batches", "must be >= 1"));
        }
        if self.profile.tokens == 0 {
            return Err(ScenarioError::invalid("profile.tokens", "must be >= 1"));
        }
        // Decode passes chain through the event heap; the monolithic paths
        // have no per-pass dispatch state to chain from.
        if matches!(self.source, TrafficSource::Chat { .. })
            && self.cfg.engine != (SimEngine::Event { pipeline: true })
        {
            return Err(ScenarioError::invalid(
                "traffic",
                "chat traffic requires the pipelined event engine \
                 (config.engine = event with pipeline: true)",
            ));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("version", Json::num(1.0)),
            ("name", Json::str(&self.name)),
            ("model", self.model.to_json()),
            ("seed", Json::num(self.seed as f64)),
            ("gate_seed", Json::num(self.gate_seed as f64)),
            ("corpus", Json::str(self.corpus.name())),
            (
                "profile",
                Json::from_pairs(vec![
                    ("batches", Json::num(self.profile.batches as f64)),
                    ("tokens", Json::num(self.profile.tokens as f64)),
                ]),
            ),
            ("platform", self.platform.to_json()),
            ("cpu_cluster", self.cpu.to_json()),
            ("traffic", self.source.to_json()),
            ("config", self.cfg.to_json()),
            ("baseline", Json::str(self.baseline.name())),
        ])
    }

    /// Strict inverse of [`Scenario::to_json`]: unknown fields anywhere in
    /// the scenario-owned schema are rejected, values are validated, and
    /// every section is optional except `name` (defaults match
    /// [`ScenarioBuilder::new`]).
    pub fn from_json(j: &Json) -> Result<Scenario, ScenarioError> {
        const SECTION: &str = "scenario";
        // The step-driver knob is fleet-level (a single scenario has one
        // lane — nothing to shard or arbitrate); a pointed rejection beats
        // the generic unknown-key error for the one foreseeable misplaced
        // field.
        if j.get("driver").is_some() {
            return Err(ScenarioError::invalid(
                "scenario.driver",
                "the step driver is a fleet-level knob; set it on the fleet \
                 file (`\"driver\": {\"parallel\": {\"threads\": N}}`), not \
                 on a single scenario",
            ));
        }
        error::check_keys(
            j,
            SECTION,
            &[
                "version",
                "name",
                "model",
                "seed",
                "gate_seed",
                "corpus",
                "profile",
                "platform",
                "cpu_cluster",
                "traffic",
                "config",
                "baseline",
            ],
        )?;
        let version = error::opt_u64(j, SECTION, "version", 1)?;
        if version != 1 {
            return Err(ScenarioError::invalid(
                "version",
                format!("unsupported scenario version {version} (this build reads 1)"),
            ));
        }
        let defaults = ScenarioBuilder::new(error::req_str(j, SECTION, "name")?).scenario;
        let profile = match j.get("profile") {
            None => defaults.profile,
            Some(p) => {
                error::check_keys(p, "profile", &["batches", "tokens"])?;
                ProfileSpec {
                    batches: error::opt_usize(p, "profile", "batches", defaults.profile.batches)?,
                    tokens: error::opt_usize(p, "profile", "tokens", defaults.profile.tokens)?,
                }
            }
        };
        let platform = match j.get("platform") {
            None => defaults.platform.clone(),
            Some(p) => {
                check_keys_against(p, "platform", &PlatformConfig::default().to_json())?;
                PlatformConfig::from_json(p)
                    .map_err(|e| ScenarioError::invalid("platform", e.to_string()))?
            }
        };
        let cpu = match j.get("cpu_cluster") {
            None => defaults.cpu.clone(),
            Some(c) => {
                check_keys_against(c, "cpu_cluster", &CpuClusterConfig::default().to_json())?;
                CpuClusterConfig::from_json(c)
                    .map_err(|e| ScenarioError::invalid("cpu_cluster", e.to_string()))?
            }
        };
        let scenario = Scenario {
            name: error::req_str(j, SECTION, "name")?.to_string(),
            model: match j.get("model") {
                None => defaults.model.clone(),
                Some(m) => ModelSource::from_json(m)?,
            },
            seed: error::opt_u64(j, SECTION, "seed", defaults.seed)?,
            gate_seed: error::opt_u64(j, SECTION, "gate_seed", defaults.gate_seed)?,
            corpus: match j.get("corpus") {
                None => defaults.corpus,
                Some(Json::Str(s)) => {
                    CorpusPreset::from_name(s).ok_or_else(|| ScenarioError::UnknownName {
                        what: "corpus preset",
                        name: s.clone(),
                        known: "enwik8 | ccnews | wmt19 | lambada",
                    })?
                }
                Some(other) => {
                    return Err(ScenarioError::invalid(
                        "corpus",
                        format!("expected a string, got {other:?}"),
                    ))
                }
            },
            profile,
            platform,
            cpu,
            source: match j.get("traffic") {
                None => defaults.source.clone(),
                Some(t) => TrafficSource::from_json(t)?,
            },
            cfg: match j.get("config") {
                None => defaults.cfg.clone(),
                Some(c) => TrafficConfig::from_json(c)?,
            },
            baseline: match j.get("baseline") {
                None => defaults.baseline,
                Some(Json::Str(s)) => Baseline::from_name(s)?,
                Some(other) => {
                    return Err(ScenarioError::invalid(
                        "baseline",
                        format!("expected a string, got {other:?}"),
                    ))
                }
            },
        };
        scenario.validate()?;
        Ok(scenario)
    }

    pub fn load(path: &Path) -> Result<Scenario, ScenarioError> {
        Self::from_json(&error::read_json(path)?)
    }

    pub fn save(&self, path: &Path) -> Result<(), ScenarioError> {
        self.to_json().write_file(path).map_err(|e| ScenarioError::Io {
            path: path.display().to_string(),
            detail: e.to_string(),
        })
    }

    /// Compile the description: resolve the model spec, seed the gate,
    /// run the profiling pass, and synthesize/replay the request stream.
    /// Deterministic — the same scenario always compiles to the same
    /// traffic, batch for batch.
    pub fn materialize(&self) -> Result<TrafficScenario, ScenarioError> {
        self.validate()?;
        let spec = self.model.spec();
        let gate = SimGate::new(&spec, self.gate_seed);
        let scn = match &self.source {
            TrafficSource::Drift { quick } => self.materialize_drift(spec, gate, *quick),
            TrafficSource::Synthetic {
                process,
                duration,
                requests,
                tokens_per_request,
            } => {
                let profile = self.profile_pass(&gate);
                let corpus = Corpus::new(self.corpus, self.seed);
                let mut gen = RequestGenerator::new(corpus, self.seed ^ 0x33, *tokens_per_request);
                let mut arr = ArrivalGen::new(*process, arrival_seed(self.seed));
                let traffic = match (duration, requests) {
                    (Some(d), None) => {
                        let arrivals = arr.arrivals_until(*d);
                        gen.timed_batches(&arrivals)
                    }
                    (None, Some(n)) => {
                        let mut at = 0.0f64;
                        let mut traffic = Vec::with_capacity(*n);
                        for _ in 0..*n {
                            at += arr.next_gap();
                            traffic.push(TimedBatch { at, batch: gen.next_batch() });
                        }
                        traffic
                    }
                    _ => unreachable!("validated: exactly one of duration/requests"),
                };
                self.assemble(spec, gate, profile.table, profile.prior, traffic)
            }
            TrafficSource::TracePath { path } => {
                let profile = self.profile_pass(&gate);
                let trace = Trace::load(Path::new(path))?;
                let traffic = trace.replay(&Corpus::new(self.corpus, self.seed), self.seed);
                self.assemble(spec, gate, profile.table, profile.prior, traffic)
            }
            TrafficSource::Inline { trace } => {
                let profile = self.profile_pass(&gate);
                let traffic = trace.replay(&Corpus::new(self.corpus, self.seed), self.seed);
                self.assemble(spec, gate, profile.table, profile.prior, traffic)
            }
            TrafficSource::Chat {
                process,
                duration,
                requests,
                prompt_tokens,
                decode,
                decode_tokens,
            } => {
                // Prompts materialize exactly like `synthetic` traffic —
                // same corpus, generator and arrival seed derivations — so
                // a decode length of 0 reproduces it byte-for-byte.
                let profile = self.profile_pass(&gate);
                let corpus = Corpus::new(self.corpus, self.seed);
                let mut gen = RequestGenerator::new(corpus, self.seed ^ 0x33, *prompt_tokens);
                let mut arr = ArrivalGen::new(*process, arrival_seed(self.seed));
                let traffic = match (duration, requests) {
                    (Some(d), None) => {
                        let arrivals = arr.arrivals_until(*d);
                        gen.timed_batches(&arrivals)
                    }
                    (None, Some(n)) => {
                        let mut at = 0.0f64;
                        let mut traffic = Vec::with_capacity(*n);
                        for _ in 0..*n {
                            at += arr.next_gap();
                            traffic.push(TimedBatch { at, batch: gen.next_batch() });
                        }
                        traffic
                    }
                    _ => unreachable!("validated: exactly one of duration/requests"),
                };
                let chat = ChatWorkload::generate(
                    &Corpus::new(self.corpus, self.seed),
                    decode_seed(self.seed),
                    decode,
                    *decode_tokens,
                    *prompt_tokens,
                    traffic.len(),
                );
                let mut scn = self.assemble(spec, gate, profile.table, profile.prior, traffic);
                scn.chat = Some(chat);
                scn
            }
        };
        if scn.traffic.is_empty() {
            return Err(ScenarioError::EmptyTraffic);
        }
        Ok(scn)
    }

    /// Materialize and serve under the scenario's own baseline and config.
    pub fn run(&self) -> Result<ScenarioOutcome, ScenarioError> {
        Ok(self.materialize()?.run(&self.cfg, self.baseline))
    }

    /// The profiling pass of the non-drift sources: a dedicated generator
    /// feeds `profile.batches` batches of `profile.tokens` tokens through
    /// the gate. It samples the *same corpus permutation* the traffic will
    /// serve (only the generator's draw stream differs) — re-seeding the
    /// corpus permutation is how drift is *simulated*, so profiling on a
    /// different permutation would size the initial deployment for the
    /// wrong experts from request one.
    fn profile_pass(&self, gate: &SimGate) -> crate::predictor::profile::ProfileResult {
        let corpus = Corpus::new(self.corpus, self.seed);
        let mut gen = RequestGenerator::new(corpus, self.seed ^ 0x11, self.profile.tokens);
        profile_batches(gate, &gen.profile_set(self.profile.batches))
    }

    /// The canned two-phase drift workload, preserved batch-for-batch from
    /// the pre-scenario `drift_scenario` builder (the golden fixtures pin
    /// its numbers): phase A serves heavy requests from one corpus (the
    /// deployment gets sized for that load), then phase B shifts to light
    /// requests from a *re-permuted* corpus — a fresh token-rank permutation
    /// re-draws which experts are popular under the fixed gate, so a static
    /// deployment keeps paying for experts that are no longer hot. Arrivals
    /// come from a bursty two-state MMPP; the predictor profiles on the
    /// phase-A generator.
    fn materialize_drift(&self, spec: MoeModelSpec, gate: SimGate, quick: bool) -> TrafficScenario {
        let batch_a = if quick { 2048 } else { 4096 };
        let batch_b = if quick { 512 } else { 1024 };
        let corpus_a = Corpus::new(self.corpus, self.seed);
        let mut gen_a = RequestGenerator::new(corpus_a, self.seed ^ 0x11, batch_a);
        let n_profile = if quick { 6 } else { 24 };
        let profile = profile_batches(&gate, &gen_a.profile_set(n_profile));

        let duration = if quick { 600.0 } else { 1500.0 };
        let process = ArrivalProcess::Mmpp {
            rate0: 0.8,
            rate1: 0.1,
            hold0: 40.0,
            hold1: 50.0,
        };
        let arrivals = ArrivalGen::new(process, arrival_seed(self.seed)).arrivals_until(duration);
        let split = arrivals.len() / 4;

        let corpus_b = Corpus::new(self.corpus, self.seed ^ 0xD21F7);
        let mut gen_b = RequestGenerator::new(corpus_b, self.seed ^ 0x33, batch_b);
        let mut traffic = gen_a.timed_batches(&arrivals[..split]);
        traffic.extend(gen_b.timed_batches(&arrivals[split..]));
        self.assemble(spec, gate, profile.table, profile.prior, traffic)
    }

    fn assemble(
        &self,
        spec: MoeModelSpec,
        gate: SimGate,
        table: DatasetTable,
        prior: TokenPrior,
        traffic: Vec<TimedBatch>,
    ) -> TrafficScenario {
        TrafficScenario {
            platform: self.platform.clone(),
            cpu: self.cpu.clone(),
            spec,
            gate,
            table,
            prior,
            traffic,
            chat: None,
        }
    }
}

/// Strict key check for sections whose schema is owned elsewhere
/// (platform, CPU cluster): the allowed keys are whatever the type's own
/// canonical serialization emits.
fn check_keys_against(j: &Json, section: &str, canonical: &Json) -> Result<(), ScenarioError> {
    let allowed: Vec<&str> = canonical
        .as_obj()
        .map(|m| m.keys().map(String::as_str).collect())
        .unwrap_or_default();
    error::check_keys(j, section, &allowed)
}

// --------------------------------------------------------------- builder

/// Validated construction of a [`Scenario`] with sensible defaults: the
/// quick drift workload on the 4-expert Bert MoE, default platform and
/// engine configuration, `ours` baseline.
#[derive(Debug, Clone)]
pub struct ScenarioBuilder {
    scenario: Scenario,
}

impl ScenarioBuilder {
    pub fn new(name: &str) -> ScenarioBuilder {
        ScenarioBuilder {
            scenario: Scenario {
                name: name.to_string(),
                model: ModelSource::Preset(ModelPreset::BertMoe { experts: 4, top_k: 1 }),
                seed: 0x5EED,
                gate_seed: 0xA11CE,
                corpus: CorpusPreset::Enwik8,
                profile: ProfileSpec::default(),
                platform: PlatformConfig::default(),
                cpu: CpuClusterConfig::default(),
                source: TrafficSource::Drift { quick: true },
                cfg: TrafficConfig::default(),
                baseline: Baseline::Ours,
            },
        }
    }

    /// Model by preset name (`bert | gpt2 | tiny | ...`).
    pub fn model(mut self, name: &str) -> Result<ScenarioBuilder, ScenarioError> {
        match ModelPreset::from_name(name) {
            Some(p) => {
                self.scenario.model = ModelSource::Preset(p);
                Ok(self)
            }
            None => Err(ScenarioError::UnknownName {
                what: "model preset",
                name: name.to_string(),
                known: "bert | bert8 | bert16 | bert-top2 | gpt2 | gpt2-top2 | bert2bert | tiny",
            }),
        }
    }

    pub fn model_preset(mut self, preset: ModelPreset) -> ScenarioBuilder {
        self.scenario.model = ModelSource::Preset(preset);
        self
    }

    pub fn model_source(mut self, model: ModelSource) -> ScenarioBuilder {
        self.scenario.model = model;
        self
    }

    pub fn seed(mut self, seed: u64) -> ScenarioBuilder {
        self.scenario.seed = seed;
        self
    }

    pub fn gate_seed(mut self, seed: u64) -> ScenarioBuilder {
        self.scenario.gate_seed = seed;
        self
    }

    pub fn corpus(mut self, corpus: CorpusPreset) -> ScenarioBuilder {
        self.scenario.corpus = corpus;
        self
    }

    pub fn profile(mut self, batches: usize, tokens: usize) -> ScenarioBuilder {
        self.scenario.profile = ProfileSpec { batches, tokens };
        self
    }

    pub fn platform(mut self, platform: PlatformConfig) -> ScenarioBuilder {
        self.scenario.platform = platform;
        self
    }

    pub fn cpu_cluster(mut self, cpu: CpuClusterConfig) -> ScenarioBuilder {
        self.scenario.cpu = cpu;
        self
    }

    pub fn traffic(mut self, source: TrafficSource) -> ScenarioBuilder {
        self.scenario.source = source;
        self
    }

    pub fn config(mut self, cfg: TrafficConfig) -> ScenarioBuilder {
        self.scenario.cfg = cfg;
        self
    }

    pub fn baseline(mut self, baseline: Baseline) -> ScenarioBuilder {
        self.scenario.baseline = baseline;
        self
    }

    /// Validate and finish. Every error is a typed [`ScenarioError`].
    pub fn build(self) -> Result<Scenario, ScenarioError> {
        self.scenario.validate()?;
        Ok(self.scenario)
    }
}

// ----------------------------------------------------------- materialized

/// A compiled scenario: platform, model, gate, the profiled (pre-serving)
/// predictor state, and the timestamped request stream. Compile once with
/// [`Scenario::materialize`], then serve any number of baselines or engine
/// configurations against identical starting state.
pub struct TrafficScenario {
    pub platform: PlatformConfig,
    pub cpu: CpuClusterConfig,
    pub spec: MoeModelSpec,
    pub gate: SimGate,
    pub table: DatasetTable,
    pub prior: TokenPrior,
    pub traffic: Vec<TimedBatch>,
    /// The decode schedule of chat traffic (`None` otherwise): per-request
    /// decode lengths and per-step token batches, aligned with `traffic`.
    pub chat: Option<ChatWorkload>,
}

/// Everything a run produces beyond the [`SimReport`] aggregate — the
/// simulator's internal state, surfaced so callers stop reaching into
/// `EpochSimulator` fields.
#[derive(Debug, Clone, Default)]
pub struct RunArtifacts {
    /// Every deployment the run served under: the initial policy plus one
    /// entry per drift-triggered re-deployment.
    pub policy_history: Vec<DeploymentPolicy>,
    /// The deployment in effect when the run finished (includes any
    /// autoscaler replica-count nudges applied after the last redeploy).
    pub final_policy: Option<DeploymentPolicy>,
    /// Virtual times at which re-deployments were triggered.
    pub redeploy_times: Vec<f64>,
    /// `(virtual time, replicas added (+) / reaped (-))` autoscaler actions.
    pub autoscale_events: Vec<(f64, i64)>,
    /// Per-request latency in arrival order (empty under streaming metrics
    /// and for the CPU-cluster baseline).
    pub latencies: Vec<f64>,
}

/// One run's results: the aggregate report plus the run artifacts.
#[derive(Debug, Clone)]
pub struct ScenarioOutcome {
    pub report: SimReport,
    pub artifacts: RunArtifacts,
}

impl TrafficScenario {
    /// A fresh predictor at the profiled (pre-serving) state — each
    /// simulation run starts from identical beliefs.
    pub fn predictor(&self) -> BayesPredictor {
        BayesPredictor::new(self.table.clone(), self.prior.clone())
    }

    /// LambdaML over-provisioning policy for this scenario's first request.
    pub fn lambdaml(&self, cfg: &TrafficConfig) -> DeploymentPolicy {
        let predictor = self.predictor();
        let counts = match self.traffic.first() {
            Some(tb) => predicted_counts(&self.gate, &predictor, &tb.batch),
            None => (0..self.spec.num_moe_layers())
                .map(|e| vec![1; self.spec.experts_at(e)])
                .collect(),
        };
        let problem = cfg.problem(&self.platform, &self.spec, counts);
        lambdaml_policy(&problem)
    }

    /// The initial deployment the simulator would size from the profiled
    /// predictor state (ODS, LambdaML fallback) — exposed so callers can
    /// share one solve across several [`TrafficScenario::run_with_policy`]
    /// runs that must differ only in dispatch discipline.
    pub fn initial_policy(&self, cfg: &TrafficConfig) -> DeploymentPolicy {
        EpochSimulator::new(&self.platform, &self.spec, &self.gate, self.predictor(), cfg.clone())
            .initial_policy(&self.traffic)
    }

    /// Serve the whole stream on the CPU cluster baseline: per-batch
    /// straggler-bound execution, coarse-grained rental billing over the
    /// occupied span.
    pub fn cpu_cluster(&self, better_transformer: bool) -> SimReport {
        let cluster = CpuCluster::new(self.cpu.clone(), better_transformer);
        let mut exec_each: Vec<f64> = Vec::with_capacity(self.traffic.len());
        let mut tokens = 0u64;
        let mut span = 0.0f64;
        for tb in &self.traffic {
            let real = real_counts(&self.gate, &tb.batch);
            let run = cluster.serve(&self.spec, &real, tb.batch.total_tokens);
            exec_each.push(run.exec_secs);
            tokens += tb.batch.total_tokens as u64;
            span = span.max(tb.at + run.exec_secs);
        }
        // No per-request cost timeline: the cluster bills by occupied span
        // (coarse rental periods), so the over-time table queries
        // `cpu.job_cost(t)` directly.
        SimReport::from_samples(&exec_each, tokens, span, self.cpu.job_cost(span.max(1.0)))
    }

    /// Serve the compiled traffic under `baseline` with `cfg` (each run
    /// starts from the same profiled predictor state). `Static` and
    /// `LambdaML` force `reoptimize` off, as the paper's comparisons do;
    /// `Ours` takes `cfg.reoptimize` as configured, so a scenario file can
    /// still express an ablation.
    pub fn run(&self, cfg: &TrafficConfig, baseline: Baseline) -> ScenarioOutcome {
        match baseline {
            Baseline::CpuCluster => ScenarioOutcome {
                report: self.cpu_cluster(false),
                artifacts: RunArtifacts::default(),
            },
            Baseline::Ours => self.run_sim(cfg.clone(), None),
            Baseline::Static => {
                let mut cfg = cfg.clone();
                cfg.reoptimize = false;
                self.run_sim(cfg, None)
            }
            Baseline::LambdaML => {
                let mut cfg = cfg.clone();
                cfg.reoptimize = false;
                let policy = self.lambdaml(&cfg);
                self.run_sim(cfg, Some(policy))
            }
        }
    }

    /// Serve starting from an explicit deployment (benches and the
    /// engine-comparison tables, where the policy must be shared or
    /// hand-built so no solver runs on the measured path).
    pub fn run_with_policy(
        &self,
        cfg: &TrafficConfig,
        policy: DeploymentPolicy,
    ) -> ScenarioOutcome {
        self.run_sim(cfg.clone(), Some(policy))
    }

    fn run_sim(&self, cfg: TrafficConfig, policy: Option<DeploymentPolicy>) -> ScenarioOutcome {
        let mut sim =
            EpochSimulator::new(&self.platform, &self.spec, &self.gate, self.predictor(), cfg);
        sim.chat = self.chat.as_ref();
        let report = match policy {
            Some(p) => sim.run_with_policy(p, &self.traffic),
            None => sim.run(&self.traffic),
        };
        ScenarioOutcome {
            report,
            artifacts: RunArtifacts {
                policy_history: std::mem::take(&mut sim.policy_history),
                final_policy: sim.last_policy.take(),
                redeploy_times: std::mem::take(&mut sim.redeploy_times),
                autoscale_events: std::mem::take(&mut sim.autoscale_events),
                latencies: std::mem::take(&mut sim.last_latencies),
            },
        }
    }
}

// ------------------------------------------------- canned configurations

/// The `TrafficConfig` used across the drift-scenario runs (and the golden
/// regression tests, so the pinned numbers stay tied to one configuration).
/// Concurrency is left unbounded here — the PR 1 serving semantics the
/// original golden numbers were pinned under; the queueing regime is
/// exercised by [`scenario_config_queued`].
pub fn scenario_config(quick: bool) -> TrafficConfig {
    TrafficConfig {
        epoch_secs: 60.0,
        keep_alive: 900.0,
        concurrency: None,
        prewarm: true,
        drift_threshold: 0.15,
        // Tight enough that the heavy phase-A batches force replica/memory
        // upgrades on popular experts — the over-provisioning that goes to
        // waste once traffic drifts light.
        t_limit: if quick { 200.0 } else { 300.0 },
        solver_time_limit: if quick { 0.3 } else { 2.0 },
        ..TrafficConfig::default()
    }
}

/// Queueing-enabled variant pinned by its own golden fixture: Lambda-style
/// per-instance concurrency 1 with the queue-depth autoscaler nudging
/// replica counts between redeploys.
pub fn scenario_config_queued(quick: bool) -> TrafficConfig {
    TrafficConfig {
        concurrency: Some(1),
        autoscale: super::autoscale::AutoscalePolicy::QueueDepth {
            max_wait: 5.0,
            idle_below: 0.2,
        },
        ..scenario_config(quick)
    }
}

/// Build + compile the canned two-phase drift scenario — the one-call
/// helper the traffic tests (and pre-scenario callers) use.
pub fn drift_scenario(preset: ModelPreset, quick: bool, seed: u64) -> TrafficScenario {
    Scenario::builder("drift")
        .model_preset(preset)
        .seed(seed)
        .traffic(TrafficSource::Drift { quick })
        .config(scenario_config(quick))
        .build()
        .expect("drift scenario is valid by construction")
        .materialize()
        .expect("drift scenario materializes")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_inline() -> Scenario {
        Scenario::builder("tiny-inline")
            .model("tiny")
            .unwrap()
            .seed(7)
            .profile(2, 128)
            .traffic(TrafficSource::Inline {
                trace: Trace {
                    requests: vec![
                        super::super::trace::TraceRequest { time: 0.0, tokens: 64, seed: 1 },
                        super::super::trace::TraceRequest { time: 1.0, tokens: 64, seed: 2 },
                    ],
                },
            })
            .baseline(Baseline::LambdaML)
            .build()
            .unwrap()
    }

    #[test]
    fn builder_defaults_validate() {
        let s = Scenario::builder("defaults").build().unwrap();
        assert_eq!(s.baseline, Baseline::Ours);
        assert_eq!(s.gate_seed, 0xA11CE);
        assert!(matches!(s.source, TrafficSource::Drift { quick: true }));
    }

    #[test]
    fn builder_rejects_invalid() {
        assert!(Scenario::builder("x").model("not-a-model").is_err());
        let mut cfg = TrafficConfig::default();
        cfg.epoch_secs = -1.0;
        assert!(matches!(
            Scenario::builder("x").config(cfg).build(),
            Err(ScenarioError::Invalid { .. })
        ));
        assert!(matches!(
            Scenario::builder("x")
                .traffic(TrafficSource::Synthetic {
                    process: ArrivalProcess::Poisson { rate: 1.0 },
                    duration: Some(10.0),
                    requests: Some(5),
                    tokens_per_request: 64,
                })
                .build(),
            Err(ScenarioError::Invalid { .. })
        ));
        assert!(matches!(
            Scenario::builder("x").seed(1u64 << 53).build(),
            Err(ScenarioError::Invalid { .. })
        ));
    }

    #[test]
    fn scenario_json_roundtrip_is_canonical() {
        for s in [
            Scenario::builder("drift").build().unwrap(),
            tiny_inline(),
            Scenario::builder("synthetic")
                .model("gpt2")
                .unwrap()
                .traffic(TrafficSource::Synthetic {
                    process: ArrivalProcess::Mmpp {
                        rate0: 5.0,
                        rate1: 0.5,
                        hold0: 10.0,
                        hold1: 20.0,
                    },
                    duration: Some(120.0),
                    requests: None,
                    tokens_per_request: 256,
                })
                .baseline(Baseline::Static)
                .build()
                .unwrap(),
        ] {
            let text = s.to_json().to_string_pretty();
            let back = Scenario::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.to_json().to_string_pretty(), text, "{}", s.name);
        }
    }

    #[test]
    fn unnamed_preset_serializes_inline() {
        let s = Scenario::builder("odd")
            .model_preset(ModelPreset::Bert2BertMoe { top_k: 2 })
            .build()
            .unwrap();
        let text = s.to_json().to_string_pretty();
        let back = Scenario::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(matches!(back.model, ModelSource::Homogeneous { .. }));
        // Stable from the first serialization on.
        assert_eq!(back.to_json().to_string_pretty(), text);
        // And the resolved spec is the same model.
        let a = s.model.spec();
        let b = back.model.spec();
        assert_eq!(a.name, b.name);
        assert_eq!(a.num_moe_layers(), b.num_moe_layers());
        assert_eq!(a.top_k, b.top_k);
    }

    #[test]
    fn strict_unknown_fields_rejected_at_every_level() {
        let top = r#"{"name": "x", "extra_knob": 1}"#;
        assert!(matches!(
            Scenario::from_json(&Json::parse(top).unwrap()),
            Err(ScenarioError::UnknownField { .. })
        ));
        let nested = r#"{"name": "x", "traffic": {"kind": "drift", "fast": true}}"#;
        assert!(matches!(
            Scenario::from_json(&Json::parse(nested).unwrap()),
            Err(ScenarioError::UnknownField { .. })
        ));
        let platform = r#"{"name": "x", "platform": {"warm_starts": 0.1}}"#;
        assert!(matches!(
            Scenario::from_json(&Json::parse(platform).unwrap()),
            Err(ScenarioError::UnknownField { .. })
        ));
    }

    #[test]
    fn materialize_is_deterministic_and_seed_sensitive() {
        let s = tiny_inline();
        let a = s.materialize().unwrap();
        let b = s.materialize().unwrap();
        assert_eq!(a.traffic.len(), b.traffic.len());
        assert_eq!(
            a.traffic[0].batch.sequences[0].tokens,
            b.traffic[0].batch.sequences[0].tokens
        );
        let mut s2 = s.clone();
        s2.seed ^= 1;
        let c = s2.materialize().unwrap();
        assert_eq!(a.traffic.len(), c.traffic.len(), "inline trace length is seed-free");
        assert_ne!(
            a.traffic[0].batch.sequences[0].tokens,
            c.traffic[0].batch.sequences[0].tokens,
            "content must track the seed"
        );
    }

    #[test]
    fn drift_materialization_matches_legacy_builder_shape() {
        let scn = drift_scenario(ModelPreset::BertMoe { experts: 4, top_k: 1 }, true, 1);
        assert!(scn.traffic.len() > 10);
        assert!(scn.traffic.windows(2).all(|w| w[0].at <= w[1].at));
        let first = scn.traffic.first().unwrap().batch.total_tokens;
        let last = scn.traffic.last().unwrap().batch.total_tokens;
        assert!(first >= last * 4, "A={first} B={last}");
    }
}
