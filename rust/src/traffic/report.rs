//! Aggregate serving report: billed cost over time, throughput and latency
//! percentiles — the quantity the golden-regression fixtures pin down and
//! the `experiments::traffic` tables print.

use super::error::ScenarioError;
use crate::util::json::Json;
use crate::util::stats::{self, LogHistogram};
use crate::util::table::{fcost, fnum, ftime};

#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    pub requests: u64,
    pub tokens: u64,
    /// Wall-clock span of the simulation (first arrival to last finish).
    pub duration: f64,
    /// Summed billed cost of all MoE layers over the whole run (the paper's
    /// objective, accumulated across requests).
    pub total_cost: f64,
    pub throughput_tps: f64,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    /// Epoch boundaries evaluated and re-deployments performed.
    pub epochs: u64,
    pub redeploys: u64,
    /// Invocation start states derived from the warm pool.
    pub warm_invocations: u64,
    pub cold_invocations: u64,
    /// Batches that hit a memory violation (case (i) of Alg. 2).
    pub violation_batches: u64,
    /// Per-request FIFO queue delay (the longest wait among the replicas a
    /// request needed) under bounded per-instance concurrency. All zero
    /// with unbounded concurrency.
    pub mean_queue_delay: f64,
    pub p95_queue_delay: f64,
    pub max_queue_delay: f64,
    /// Replica invocations that had to wait for a busy instance.
    pub queued_invocations: u64,
    /// Summed execution seconds across all replica invocations.
    pub busy_secs: f64,
    /// Highest single-instance busy fraction of the run span (≤ 1 under
    /// concurrency 1, barring instances respawned mid-run by redeploys).
    pub max_utilization: f64,
    /// Autoscaler actions over the run: replicas added / reaped.
    pub scale_outs: u64,
    pub scale_ins: u64,
    /// Failure injection (all zero with faults off). Replica invocations
    /// that crashed or hit the timeout cutoff — billed per Lambda
    /// semantics (full duration, or exactly the cutoff).
    pub failed_invocations: u64,
    /// Layer dispatches re-executed after a failed attempt (bounded
    /// exponential backoff).
    pub retries: u64,
    /// Speculative duplicate replica invocations launched against
    /// quantile-flagged stragglers, and how many finished first (the
    /// loser's billing is cut at the winner's finish).
    pub hedged_invocations: u64,
    pub hedge_wins: u64,
    /// Cap-rejected admissions surfaced as throttle errors and retried
    /// with backoff instead of parking.
    pub throttled_requests: u64,
    /// Experts dropped for the rest of an epoch after consecutive replica
    /// failures, and the tokens rerouted to surviving experts while
    /// dropped — the quality-proxy penalty of degraded serving.
    pub dropped_experts: u64,
    pub rerouted_tokens: u64,
    /// Requests that finished without a single failed/throttled attempt.
    /// `requests - goodput_requests` recovered only through retries.
    pub goodput_requests: u64,
    /// Billed cost of failed attempts (already included in `total_cost`):
    /// what the fault load added on top of clean serving.
    pub retry_cost: f64,
    /// Autoregressive serving (all zero without a chat workload). Output
    /// tokens emitted across all decode steps.
    pub output_tokens: u64,
    /// Prefill-pass latency percentiles (prompt passes plus billed
    /// re-prefills after KV loss).
    pub prefill_p50: f64,
    pub prefill_p95: f64,
    /// Per-decode-step latency percentiles.
    pub decode_p50: f64,
    pub decode_p95: f64,
    /// Mean seconds of decode time per output token — the chat-serving
    /// latency headline (re-prefill time charged to decode, since the user
    /// is waiting on the next token either way).
    pub time_per_output_token: f64,
    /// KV states lost to cold pinned instances, and the billed re-prefill
    /// passes those losses forced.
    pub kv_evictions: u64,
    pub re_prefills: u64,
    /// (time, cumulative billed cost) at each served request.
    pub cost_timeline: Vec<(f64, f64)>,
}

impl SimReport {
    /// Build from raw per-request samples.
    pub fn from_samples(
        latencies: &[f64],
        tokens: u64,
        duration: f64,
        total_cost: f64,
    ) -> SimReport {
        SimReport {
            requests: latencies.len() as u64,
            tokens,
            duration,
            total_cost,
            throughput_tps: if duration > 0.0 {
                tokens as f64 / duration
            } else {
                0.0
            },
            mean_latency: stats::mean(latencies),
            p50_latency: stats::percentile(latencies, 50.0),
            p95_latency: stats::percentile(latencies, 95.0),
            p99_latency: stats::percentile(latencies, 99.0),
            epochs: 0,
            redeploys: 0,
            warm_invocations: 0,
            cold_invocations: 0,
            violation_batches: 0,
            mean_queue_delay: 0.0,
            p95_queue_delay: 0.0,
            max_queue_delay: 0.0,
            queued_invocations: 0,
            busy_secs: 0.0,
            max_utilization: 0.0,
            scale_outs: 0,
            scale_ins: 0,
            failed_invocations: 0,
            retries: 0,
            hedged_invocations: 0,
            hedge_wins: 0,
            throttled_requests: 0,
            dropped_experts: 0,
            rerouted_tokens: 0,
            goodput_requests: 0,
            retry_cost: 0.0,
            output_tokens: 0,
            prefill_p50: 0.0,
            prefill_p95: 0.0,
            decode_p50: 0.0,
            decode_p95: 0.0,
            time_per_output_token: 0.0,
            kv_evictions: 0,
            re_prefills: 0,
            cost_timeline: Vec::new(),
        }
    }

    /// Build from the event engine's streaming aggregates: mean/max are
    /// exact (tracked alongside the buckets), percentiles are histogram
    /// estimates within one bucket width, and there is no cost timeline —
    /// memory stays O(1) in the request count.
    pub fn from_histograms(
        requests: u64,
        tokens: u64,
        duration: f64,
        total_cost: f64,
        latency: &LogHistogram,
        queue_delay: &LogHistogram,
    ) -> SimReport {
        let mut r = SimReport::from_samples(&[], tokens, duration, total_cost);
        r.requests = requests;
        r.mean_latency = latency.mean();
        r.p50_latency = latency.percentile(50.0);
        r.p95_latency = latency.percentile(95.0);
        r.p99_latency = latency.percentile(99.0);
        r.mean_queue_delay = queue_delay.mean();
        r.p95_queue_delay = queue_delay.percentile(95.0);
        r.max_queue_delay = queue_delay.max();
        r
    }

    /// Fraction of invocations that started warm (1.0 before any).
    pub fn warm_fraction(&self) -> f64 {
        let total = self.warm_invocations + self.cold_invocations;
        if total == 0 {
            1.0
        } else {
            self.warm_invocations as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("requests", Json::num(self.requests as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("duration", Json::num(self.duration)),
            ("total_cost", Json::num(self.total_cost)),
            ("throughput_tps", Json::num(self.throughput_tps)),
            ("mean_latency", Json::num(self.mean_latency)),
            ("p50_latency", Json::num(self.p50_latency)),
            ("p95_latency", Json::num(self.p95_latency)),
            ("p99_latency", Json::num(self.p99_latency)),
            ("epochs", Json::num(self.epochs as f64)),
            ("redeploys", Json::num(self.redeploys as f64)),
            ("warm_invocations", Json::num(self.warm_invocations as f64)),
            ("cold_invocations", Json::num(self.cold_invocations as f64)),
            ("violation_batches", Json::num(self.violation_batches as f64)),
            ("mean_queue_delay", Json::num(self.mean_queue_delay)),
            ("p95_queue_delay", Json::num(self.p95_queue_delay)),
            ("max_queue_delay", Json::num(self.max_queue_delay)),
            ("queued_invocations", Json::num(self.queued_invocations as f64)),
            ("busy_secs", Json::num(self.busy_secs)),
            ("max_utilization", Json::num(self.max_utilization)),
            ("scale_outs", Json::num(self.scale_outs as f64)),
            ("scale_ins", Json::num(self.scale_ins as f64)),
            ("failed_invocations", Json::num(self.failed_invocations as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("hedged_invocations", Json::num(self.hedged_invocations as f64)),
            ("hedge_wins", Json::num(self.hedge_wins as f64)),
            ("throttled_requests", Json::num(self.throttled_requests as f64)),
            ("dropped_experts", Json::num(self.dropped_experts as f64)),
            ("rerouted_tokens", Json::num(self.rerouted_tokens as f64)),
            ("goodput_requests", Json::num(self.goodput_requests as f64)),
            ("retry_cost", Json::num(self.retry_cost)),
            ("output_tokens", Json::num(self.output_tokens as f64)),
            ("prefill_p50", Json::num(self.prefill_p50)),
            ("prefill_p95", Json::num(self.prefill_p95)),
            ("decode_p50", Json::num(self.decode_p50)),
            ("decode_p95", Json::num(self.decode_p95)),
            ("time_per_output_token", Json::num(self.time_per_output_token)),
            ("kv_evictions", Json::num(self.kv_evictions as f64)),
            ("re_prefills", Json::num(self.re_prefills as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SimReport, ScenarioError> {
        let need = |k: &str| {
            j.get_f64(k)
                .ok_or_else(|| ScenarioError::missing("sim report", k))
        };
        // Queueing/autoscaling fields default to zero so pre-queueing golden
        // entries still parse.
        let opt = |k: &str| j.get_f64(k).unwrap_or(0.0);
        Ok(SimReport {
            requests: need("requests")? as u64,
            tokens: need("tokens")? as u64,
            duration: need("duration")?,
            total_cost: need("total_cost")?,
            throughput_tps: need("throughput_tps")?,
            mean_latency: need("mean_latency")?,
            p50_latency: need("p50_latency")?,
            p95_latency: need("p95_latency")?,
            p99_latency: need("p99_latency")?,
            epochs: need("epochs")? as u64,
            redeploys: need("redeploys")? as u64,
            warm_invocations: need("warm_invocations")? as u64,
            cold_invocations: need("cold_invocations")? as u64,
            violation_batches: need("violation_batches")? as u64,
            mean_queue_delay: opt("mean_queue_delay"),
            p95_queue_delay: opt("p95_queue_delay"),
            max_queue_delay: opt("max_queue_delay"),
            queued_invocations: opt("queued_invocations") as u64,
            busy_secs: opt("busy_secs"),
            max_utilization: opt("max_utilization"),
            scale_outs: opt("scale_outs") as u64,
            scale_ins: opt("scale_ins") as u64,
            failed_invocations: opt("failed_invocations") as u64,
            retries: opt("retries") as u64,
            hedged_invocations: opt("hedged_invocations") as u64,
            hedge_wins: opt("hedge_wins") as u64,
            throttled_requests: opt("throttled_requests") as u64,
            dropped_experts: opt("dropped_experts") as u64,
            rerouted_tokens: opt("rerouted_tokens") as u64,
            goodput_requests: opt("goodput_requests") as u64,
            retry_cost: opt("retry_cost"),
            output_tokens: opt("output_tokens") as u64,
            prefill_p50: opt("prefill_p50"),
            prefill_p95: opt("prefill_p95"),
            decode_p50: opt("decode_p50"),
            decode_p95: opt("decode_p95"),
            time_per_output_token: opt("time_per_output_token"),
            kv_evictions: opt("kv_evictions") as u64,
            re_prefills: opt("re_prefills") as u64,
            cost_timeline: Vec::new(),
        })
    }

    /// Golden-fixture comparison: cost, throughput and p95 latency must each
    /// match within `rel_tol` relative error. Returns a human-readable diff
    /// on mismatch so regression failures are actionable.
    pub fn close_to(&self, golden: &SimReport, rel_tol: f64) -> Result<(), String> {
        let check = |name: &str, got: f64, want: f64| -> Result<(), String> {
            let scale = want.abs().max(1e-12);
            if (got - want).abs() / scale <= rel_tol {
                Ok(())
            } else {
                Err(format!(
                    "{name}: got {got:.9} vs golden {want:.9} (rel tol {rel_tol})"
                ))
            }
        };
        check("total_cost", self.total_cost, golden.total_cost)?;
        check("throughput_tps", self.throughput_tps, golden.throughput_tps)?;
        check("p95_latency", self.p95_latency, golden.p95_latency)?;
        check("mean_queue_delay", self.mean_queue_delay, golden.mean_queue_delay)?;
        if self.requests != golden.requests {
            return Err(format!(
                "requests: got {} vs golden {}",
                self.requests, golden.requests
            ));
        }
        Ok(())
    }
}

// ----------------------------------------------------------- fleet report

/// One tenant's slice of a fleet run: its [`SimReport`] plus the
/// account-cap admission statistics and the SLO it was declared with.
#[derive(Debug, Clone)]
pub struct TenantReport {
    pub name: String,
    /// Weighted-fair share weight the tenant was configured with.
    pub weight: f64,
    /// Declared p95 latency SLO (seconds), if any.
    pub slo_p95: Option<f64>,
    pub report: SimReport,
    /// Requests that had to park for an account slot.
    pub capped_requests: u64,
    /// Mean / max admission delay of the parked requests (0 when none).
    pub mean_cap_delay: f64,
    pub max_cap_delay: f64,
    /// Arbitration weight the tenant ended the run with. Equal to `weight`
    /// unless SLO-feedback arbitration adapted it (at epoch boundaries or
    /// the end-of-run tail flush).
    pub effective_weight: f64,
    /// Layer dispatches of this tenant that merged into another open batch
    /// window instead of paying their own invocation (0 when cross-tenant
    /// batching is off).
    pub batched_invocations: u64,
}

impl TenantReport {
    /// Whether the tenant met its declared p95 SLO (vacuously true without
    /// one).
    pub fn slo_met(&self) -> bool {
        self.slo_p95.is_none_or(|slo| self.report.p95_latency <= slo)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(&self.name)),
            ("weight", Json::num(self.weight)),
            ("report", self.report.to_json()),
            ("capped_requests", Json::num(self.capped_requests as f64)),
            ("mean_cap_delay", Json::num(self.mean_cap_delay)),
            ("max_cap_delay", Json::num(self.max_cap_delay)),
            ("effective_weight", Json::num(self.effective_weight)),
            ("batched_invocations", Json::num(self.batched_invocations as f64)),
        ];
        if let Some(slo) = self.slo_p95 {
            pairs.push(("slo_p95", Json::num(slo)));
            pairs.push(("slo_met", Json::Bool(self.slo_met())));
        }
        Json::from_pairs(pairs)
    }
}

/// Aggregate result of a multi-tenant fleet run (`traffic::fleet`): one
/// [`TenantReport`] per tenant plus the fleet-level rollups — total billed
/// cost, cap-induced admission delay, and a weighted fairness index.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Account-level concurrency cap the fleet ran under (`None` =
    /// unbounded).
    pub account_cap: Option<usize>,
    pub tenants: Vec<TenantReport>,
    /// Summed billed cost across tenants — the fleet objective.
    pub total_cost: f64,
    /// Requests (fleet-wide) that parked for an account slot, and their
    /// admission-delay aggregate.
    pub capped_requests: u64,
    pub mean_cap_delay: f64,
    pub max_cap_delay: f64,
    /// Jain's fairness index over per-tenant weighted service (busy seconds
    /// per unit weight), in (0, 1]: 1.0 means capacity use was perfectly
    /// proportional to the weights that actually governed grants — the
    /// *effective* weights, which SLO-feedback arbitration may have adapted
    /// away from the declared ones. Equal to [`FleetReport::fairness_declared`]
    /// whenever no adaptation happened.
    pub fairness: f64,
    /// Jain's index over the *declared* contract weights, kept reachable
    /// for comparison: under SLO feedback, `fairness` high with
    /// `fairness_declared` low means the adaptation deliberately skewed
    /// capacity toward missing tenants.
    pub fairness_declared: f64,
    /// High-water mark of concurrently held account slots over the run.
    /// At most the cap under request-granular accounting; under the
    /// execution-granular default the transient overshoot is bounded by
    /// `cap - 1` plus one request's widest layer fan-out.
    pub peak_concurrency: usize,
    /// Total events executed through the event heap(s) over the run —
    /// layer dispatches, cap releases, batch closes, retries. The
    /// throughput denominator `bench_traffic` reports as events/sec.
    /// Additive across the parallel driver's shards (every event runs in
    /// exactly one shard), so it is byte-identical across drivers and
    /// thread counts like every other field.
    pub events: u64,
    /// Fleet-wide failure-injection rollups (sums of the per-tenant
    /// [`SimReport`] counters; all zero with faults off).
    pub failed_invocations: u64,
    pub retries: u64,
    pub hedged_invocations: u64,
    pub hedge_wins: u64,
    pub throttled_requests: u64,
    pub dropped_experts: u64,
    pub rerouted_tokens: u64,
    pub goodput_requests: u64,
    pub retry_cost: f64,
    /// Fleet-wide autoregressive rollups (zero without chat tenants):
    /// summed output tokens, KV evictions and forced re-prefills, plus the
    /// output-token-weighted mean time per output token across tenants.
    pub output_tokens: u64,
    pub kv_evictions: u64,
    pub re_prefills: u64,
    pub time_per_output_token: f64,
}

impl FleetReport {
    /// Roll per-tenant reports up into the fleet aggregate. The cap-delay
    /// mean recombines exactly from the per-tenant means (each is a plain
    /// average over that tenant's parked requests).
    pub fn from_tenants(
        account_cap: Option<usize>,
        peak_concurrency: usize,
        events: u64,
        tenants: Vec<TenantReport>,
    ) -> FleetReport {
        let total_cost = tenants.iter().map(|t| t.report.total_cost).sum();
        let capped_requests: u64 = tenants.iter().map(|t| t.capped_requests).sum();
        let wait_sum: f64 = tenants
            .iter()
            .map(|t| t.mean_cap_delay * t.capped_requests as f64)
            .sum();
        let mean_cap_delay = if capped_requests > 0 {
            wait_sum / capped_requests as f64
        } else {
            0.0
        };
        let max_cap_delay = tenants.iter().map(|t| t.max_cap_delay).fold(0.0, f64::max);
        let fairness = jain_index(tenants.iter().map(|t| t.report.busy_secs / t.effective_weight));
        let fairness_declared = jain_index(tenants.iter().map(|t| t.report.busy_secs / t.weight));
        let sum = |f: fn(&SimReport) -> u64| tenants.iter().map(|t| f(&t.report)).sum();
        FleetReport {
            account_cap,
            total_cost,
            capped_requests,
            mean_cap_delay,
            max_cap_delay,
            fairness,
            fairness_declared,
            peak_concurrency,
            events,
            failed_invocations: sum(|r| r.failed_invocations),
            retries: sum(|r| r.retries),
            hedged_invocations: sum(|r| r.hedged_invocations),
            hedge_wins: sum(|r| r.hedge_wins),
            throttled_requests: sum(|r| r.throttled_requests),
            dropped_experts: sum(|r| r.dropped_experts),
            rerouted_tokens: sum(|r| r.rerouted_tokens),
            goodput_requests: sum(|r| r.goodput_requests),
            retry_cost: tenants.iter().map(|t| t.report.retry_cost).sum(),
            output_tokens: sum(|r| r.output_tokens),
            kv_evictions: sum(|r| r.kv_evictions),
            re_prefills: sum(|r| r.re_prefills),
            time_per_output_token: {
                let toks: u64 = sum(|r| r.output_tokens);
                let decode_secs: f64 = tenants
                    .iter()
                    .map(|t| t.report.time_per_output_token * t.report.output_tokens as f64)
                    .sum();
                if toks > 0 {
                    decode_secs / toks as f64
                } else {
                    0.0
                }
            },
            tenants,
        }
    }

    /// The named tenant's report, if present.
    pub fn tenant(&self, name: &str) -> Option<&TenantReport> {
        self.tenants.iter().find(|t| t.name == name)
    }

    /// Worst per-tenant p95 latency — the fleet-level tail number the
    /// shared-vs-isolated comparisons report (0 for an empty fleet).
    pub fn max_p95(&self) -> f64 {
        self.tenants
            .iter()
            .map(|t| t.report.p95_latency)
            .fold(0.0, f64::max)
    }

    /// Column headers of the shared-vs-isolated comparison tables printed
    /// by `serve_traffic --fleet` and `experiments traffic` — defined once
    /// beside [`FleetReport::comparison_row`] so the printers cannot drift.
    pub fn comparison_columns() -> [&'static str; 7] {
        ["pool", "billed cost", "max p95", "capped reqs", "mean cap delay", "peak conc", "fairness"]
    }

    /// One comparison-table row for this fleet report. The fairness cell is
    /// the effective-weight index (the weights that governed grants).
    pub fn comparison_row(&self, label: &str) -> Vec<String> {
        vec![
            label.to_string(),
            fcost(self.total_cost),
            ftime(self.max_p95()),
            self.capped_requests.to_string(),
            ftime(self.mean_cap_delay),
            self.peak_concurrency.to_string(),
            fnum(self.fairness),
        ]
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            (
                "account_cap",
                Json::num(self.account_cap.unwrap_or(0) as f64),
            ),
            (
                "tenants",
                Json::Arr(self.tenants.iter().map(TenantReport::to_json).collect()),
            ),
            ("total_cost", Json::num(self.total_cost)),
            ("capped_requests", Json::num(self.capped_requests as f64)),
            ("mean_cap_delay", Json::num(self.mean_cap_delay)),
            ("max_cap_delay", Json::num(self.max_cap_delay)),
            ("fairness", Json::num(self.fairness)),
            ("fairness_declared", Json::num(self.fairness_declared)),
            ("peak_concurrency", Json::num(self.peak_concurrency as f64)),
            ("events", Json::num(self.events as f64)),
            ("failed_invocations", Json::num(self.failed_invocations as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("hedged_invocations", Json::num(self.hedged_invocations as f64)),
            ("hedge_wins", Json::num(self.hedge_wins as f64)),
            ("throttled_requests", Json::num(self.throttled_requests as f64)),
            ("dropped_experts", Json::num(self.dropped_experts as f64)),
            ("rerouted_tokens", Json::num(self.rerouted_tokens as f64)),
            ("goodput_requests", Json::num(self.goodput_requests as f64)),
            ("retry_cost", Json::num(self.retry_cost)),
            ("output_tokens", Json::num(self.output_tokens as f64)),
            ("kv_evictions", Json::num(self.kv_evictions as f64)),
            ("re_prefills", Json::num(self.re_prefills as f64)),
            ("time_per_output_token", Json::num(self.time_per_output_token)),
        ])
    }
}

/// Jain's fairness index `(Σx)² / (n·Σx²)` over non-negative samples;
/// defined as 1.0 for an empty or all-zero population (nothing was unfair).
fn jain_index(xs: impl Iterator<Item = f64>) -> f64 {
    let mut n = 0usize;
    let mut sum = 0.0f64;
    let mut sq = 0.0f64;
    for x in xs {
        n += 1;
        sum += x;
        sq += x * x;
    }
    if n == 0 || sq <= 0.0 {
        1.0
    } else {
        (sum * sum) / (n as f64 * sq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SimReport {
        let mut r = SimReport::from_samples(&[0.5, 1.0, 2.0, 4.0], 4096, 100.0, 0.125);
        r.epochs = 3;
        r.redeploys = 1;
        r.warm_invocations = 30;
        r.cold_invocations = 10;
        r.mean_queue_delay = 0.75;
        r.p95_queue_delay = 2.5;
        r.max_queue_delay = 3.0;
        r.queued_invocations = 7;
        r.busy_secs = 42.0;
        r.max_utilization = 0.8;
        r.scale_outs = 2;
        r.scale_ins = 1;
        r.failed_invocations = 5;
        r.retries = 4;
        r.hedged_invocations = 3;
        r.hedge_wins = 2;
        r.throttled_requests = 1;
        r.dropped_experts = 1;
        r.rerouted_tokens = 64;
        r.goodput_requests = 2;
        r.retry_cost = 0.0625;
        r.output_tokens = 96;
        r.prefill_p50 = 0.4;
        r.prefill_p95 = 0.9;
        r.decode_p50 = 0.05;
        r.decode_p95 = 0.12;
        r.time_per_output_token = 0.06;
        r.kv_evictions = 2;
        r.re_prefills = 2;
        r
    }

    #[test]
    fn percentiles_ordered() {
        let r = sample();
        assert!(r.p50_latency <= r.p95_latency);
        assert!(r.p95_latency <= r.p99_latency);
        assert!((r.throughput_tps - 40.96).abs() < 1e-9);
        assert!((r.warm_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let r = sample();
        let back = SimReport::from_json(&r.to_json()).unwrap();
        assert_eq!(back.requests, r.requests);
        assert_eq!(back.total_cost, r.total_cost);
        assert_eq!(back.p95_latency, r.p95_latency);
        assert_eq!(back.mean_queue_delay, r.mean_queue_delay);
        assert_eq!(back.queued_invocations, r.queued_invocations);
        assert_eq!(back.busy_secs, r.busy_secs);
        assert_eq!(back.max_utilization, r.max_utilization);
        assert_eq!(back.scale_outs, r.scale_outs);
        assert_eq!(back.scale_ins, r.scale_ins);
        assert_eq!(back.failed_invocations, r.failed_invocations);
        assert_eq!(back.retries, r.retries);
        assert_eq!(back.hedged_invocations, r.hedged_invocations);
        assert_eq!(back.hedge_wins, r.hedge_wins);
        assert_eq!(back.throttled_requests, r.throttled_requests);
        assert_eq!(back.dropped_experts, r.dropped_experts);
        assert_eq!(back.rerouted_tokens, r.rerouted_tokens);
        assert_eq!(back.goodput_requests, r.goodput_requests);
        assert_eq!(back.retry_cost, r.retry_cost);
        assert_eq!(back.output_tokens, r.output_tokens);
        assert_eq!(back.prefill_p50, r.prefill_p50);
        assert_eq!(back.prefill_p95, r.prefill_p95);
        assert_eq!(back.decode_p50, r.decode_p50);
        assert_eq!(back.decode_p95, r.decode_p95);
        assert_eq!(back.time_per_output_token, r.time_per_output_token);
        assert_eq!(back.kv_evictions, r.kv_evictions);
        assert_eq!(back.re_prefills, r.re_prefills);
        assert!(back.close_to(&r, 1e-12).is_ok());
    }

    #[test]
    fn close_to_detects_queue_delay_drift() {
        let r = sample();
        let mut off = r.clone();
        off.mean_queue_delay *= 2.0;
        let err = r.close_to(&off, 1e-6).unwrap_err();
        assert!(err.contains("mean_queue_delay"), "{err}");
    }

    fn tenant(name: &str, weight: f64, cost: f64, busy: f64) -> TenantReport {
        let mut r = sample();
        r.total_cost = cost;
        r.busy_secs = busy;
        TenantReport {
            name: name.to_string(),
            weight,
            slo_p95: None,
            report: r,
            capped_requests: 2,
            mean_cap_delay: 1.5,
            max_cap_delay: 3.0,
            effective_weight: weight,
            batched_invocations: 0,
        }
    }

    #[test]
    fn fleet_report_rolls_up_cost_delay_and_fairness() {
        let f = FleetReport::from_tenants(
            Some(4),
            4,
            0,
            vec![tenant("a", 2.0, 1.0, 40.0), tenant("b", 1.0, 0.5, 20.0)],
        );
        assert_eq!(f.total_cost, 1.5);
        assert_eq!(f.capped_requests, 4);
        assert!((f.mean_cap_delay - 1.5).abs() < 1e-12);
        assert_eq!(f.max_cap_delay, 3.0);
        assert_eq!(f.peak_concurrency, 4);
        // busy/weight identical (20.0 each): perfectly weight-fair, and
        // without adaptation the effective and declared indices coincide.
        assert!((f.fairness - 1.0).abs() < 1e-12);
        assert_eq!(f.fairness, f.fairness_declared);
        assert!(f.tenant("a").is_some() && f.tenant("nope").is_none());
        // Skewed service vs weight pulls the index below 1.
        let skew = FleetReport::from_tenants(
            Some(4),
            4,
            0,
            vec![tenant("a", 1.0, 1.0, 40.0), tenant("b", 1.0, 0.5, 4.0)],
        );
        assert!(skew.fairness < 1.0);
        assert!(skew.fairness > 0.0);
    }

    #[test]
    fn fairness_follows_the_weights_that_governed_grants() {
        // SLO feedback quadrupled tenant a's weight and arbitration granted
        // by it: busy is 4:1 — perfectly fair under the effective weights,
        // skewed under the declared ones. Pre-fix the roles were reversed:
        // the index reported "unfair" precisely because the adaptation
        // worked.
        let mut a = tenant("a", 1.0, 1.0, 40.0);
        a.effective_weight = 4.0;
        let b = tenant("b", 1.0, 0.5, 10.0);
        let f = FleetReport::from_tenants(Some(4), 4, 0, vec![a, b]);
        assert!((f.fairness - 1.0).abs() < 1e-12, "effective-weight index: {}", f.fairness);
        assert!(
            f.fairness_declared < 1.0,
            "declared-weight index stays reachable: {}",
            f.fairness_declared
        );
        let j = f.to_json();
        assert_eq!(j.get_f64("fairness"), Some(f.fairness));
        assert_eq!(j.get_f64("fairness_declared"), Some(f.fairness_declared));
        assert_eq!(j.get_f64("peak_concurrency"), Some(4.0));
    }

    #[test]
    fn fleet_report_sums_fault_counters() {
        let mut a = tenant("a", 1.0, 1.0, 10.0);
        a.report.failed_invocations = 3;
        a.report.retries = 2;
        a.report.retry_cost = 0.5;
        a.report.goodput_requests = 1;
        let mut b = tenant("b", 1.0, 1.0, 10.0);
        b.report.failed_invocations = 1;
        b.report.hedged_invocations = 4;
        b.report.hedge_wins = 2;
        b.report.throttled_requests = 5;
        b.report.dropped_experts = 1;
        b.report.rerouted_tokens = 128;
        b.report.goodput_requests = 2;
        let f = FleetReport::from_tenants(None, 0, 0, vec![a, b]);
        assert_eq!(f.failed_invocations, 4);
        assert_eq!(f.retries, 2);
        assert_eq!(f.hedged_invocations, 4);
        assert_eq!(f.hedge_wins, 2);
        assert_eq!(f.throttled_requests, 5);
        assert_eq!(f.dropped_experts, 1);
        assert_eq!(f.rerouted_tokens, 128);
        assert_eq!(f.goodput_requests, 3);
        assert!((f.retry_cost - 0.5).abs() < 1e-12);
        let j = f.to_json();
        assert_eq!(j.get_f64("failed_invocations"), Some(4.0));
        assert_eq!(j.get_f64("goodput_requests"), Some(3.0));
        assert_eq!(j.get_f64("retry_cost"), Some(0.5));
    }

    #[test]
    fn fleet_report_weights_time_per_output_token_by_tokens() {
        let mut a = tenant("a", 1.0, 1.0, 10.0);
        a.report.output_tokens = 300;
        a.report.time_per_output_token = 0.1;
        a.report.kv_evictions = 3;
        a.report.re_prefills = 2;
        let mut b = tenant("b", 1.0, 1.0, 10.0);
        b.report.output_tokens = 100;
        b.report.time_per_output_token = 0.3;
        b.report.kv_evictions = 1;
        b.report.re_prefills = 1;
        let f = FleetReport::from_tenants(None, 0, 0, vec![a, b]);
        assert_eq!(f.output_tokens, 400);
        assert_eq!(f.kv_evictions, 4);
        assert_eq!(f.re_prefills, 3);
        // (300·0.1 + 100·0.3) / 400 = 0.15: weighted by tokens, not tenants.
        assert!((f.time_per_output_token - 0.15).abs() < 1e-12);
        let j = f.to_json();
        assert_eq!(j.get_f64("output_tokens"), Some(400.0));
        assert_eq!(j.get_f64("time_per_output_token"), Some(f.time_per_output_token));
        // No output tokens anywhere: the weighted mean is defined as zero.
        let quiet = FleetReport::from_tenants(None, 0, 0, vec![tenant("q", 1.0, 1.0, 1.0)]);
        assert_eq!(quiet.output_tokens, 96, "sample() emits 96 output tokens");
        let mut z = tenant("z", 1.0, 1.0, 1.0);
        z.report.output_tokens = 0;
        z.report.time_per_output_token = 0.0;
        let zf = FleetReport::from_tenants(None, 0, 0, vec![z]);
        assert_eq!(zf.time_per_output_token, 0.0);
    }

    #[test]
    fn slo_met_checks_p95_against_declared_target() {
        let mut t = tenant("a", 1.0, 1.0, 1.0);
        assert!(t.slo_met(), "no SLO declared is vacuously met");
        t.slo_p95 = Some(t.report.p95_latency + 1.0);
        assert!(t.slo_met());
        t.slo_p95 = Some(t.report.p95_latency * 0.5);
        assert!(!t.slo_met());
        let j = t.to_json();
        assert_eq!(j.get_f64("slo_p95"), t.slo_p95);
        assert_eq!(j.get("slo_met").and_then(Json::as_bool), Some(false));
    }

    #[test]
    fn close_to_detects_drift() {
        let r = sample();
        let mut off = r.clone();
        off.total_cost *= 1.5;
        let err = r.close_to(&off, 1e-6).unwrap_err();
        assert!(err.contains("total_cost"), "{err}");
        assert!(r.close_to(&r, 0.0).is_ok());
    }
}
