//! Aggregate serving report: billed cost over time, throughput and latency
//! percentiles — the quantity the golden-regression fixtures pin down and
//! the `experiments::traffic` tables print.

use super::error::ScenarioError;
use crate::util::json::Json;
use crate::util::stats::{self, LogHistogram};

#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    pub requests: u64,
    pub tokens: u64,
    /// Wall-clock span of the simulation (first arrival to last finish).
    pub duration: f64,
    /// Summed billed cost of all MoE layers over the whole run (the paper's
    /// objective, accumulated across requests).
    pub total_cost: f64,
    pub throughput_tps: f64,
    pub mean_latency: f64,
    pub p50_latency: f64,
    pub p95_latency: f64,
    pub p99_latency: f64,
    /// Epoch boundaries evaluated and re-deployments performed.
    pub epochs: u64,
    pub redeploys: u64,
    /// Invocation start states derived from the warm pool.
    pub warm_invocations: u64,
    pub cold_invocations: u64,
    /// Batches that hit a memory violation (case (i) of Alg. 2).
    pub violation_batches: u64,
    /// Per-request FIFO queue delay (the longest wait among the replicas a
    /// request needed) under bounded per-instance concurrency. All zero
    /// with unbounded concurrency.
    pub mean_queue_delay: f64,
    pub p95_queue_delay: f64,
    pub max_queue_delay: f64,
    /// Replica invocations that had to wait for a busy instance.
    pub queued_invocations: u64,
    /// Summed execution seconds across all replica invocations.
    pub busy_secs: f64,
    /// Highest single-instance busy fraction of the run span (≤ 1 under
    /// concurrency 1, barring instances respawned mid-run by redeploys).
    pub max_utilization: f64,
    /// Autoscaler actions over the run: replicas added / reaped.
    pub scale_outs: u64,
    pub scale_ins: u64,
    /// (time, cumulative billed cost) at each served request.
    pub cost_timeline: Vec<(f64, f64)>,
}

impl SimReport {
    /// Build from raw per-request samples.
    pub fn from_samples(
        latencies: &[f64],
        tokens: u64,
        duration: f64,
        total_cost: f64,
    ) -> SimReport {
        SimReport {
            requests: latencies.len() as u64,
            tokens,
            duration,
            total_cost,
            throughput_tps: if duration > 0.0 {
                tokens as f64 / duration
            } else {
                0.0
            },
            mean_latency: stats::mean(latencies),
            p50_latency: stats::percentile(latencies, 50.0),
            p95_latency: stats::percentile(latencies, 95.0),
            p99_latency: stats::percentile(latencies, 99.0),
            epochs: 0,
            redeploys: 0,
            warm_invocations: 0,
            cold_invocations: 0,
            violation_batches: 0,
            mean_queue_delay: 0.0,
            p95_queue_delay: 0.0,
            max_queue_delay: 0.0,
            queued_invocations: 0,
            busy_secs: 0.0,
            max_utilization: 0.0,
            scale_outs: 0,
            scale_ins: 0,
            cost_timeline: Vec::new(),
        }
    }

    /// Build from the event engine's streaming aggregates: mean/max are
    /// exact (tracked alongside the buckets), percentiles are histogram
    /// estimates within one bucket width, and there is no cost timeline —
    /// memory stays O(1) in the request count.
    pub fn from_histograms(
        requests: u64,
        tokens: u64,
        duration: f64,
        total_cost: f64,
        latency: &LogHistogram,
        queue_delay: &LogHistogram,
    ) -> SimReport {
        let mut r = SimReport::from_samples(&[], tokens, duration, total_cost);
        r.requests = requests;
        r.mean_latency = latency.mean();
        r.p50_latency = latency.percentile(50.0);
        r.p95_latency = latency.percentile(95.0);
        r.p99_latency = latency.percentile(99.0);
        r.mean_queue_delay = queue_delay.mean();
        r.p95_queue_delay = queue_delay.percentile(95.0);
        r.max_queue_delay = queue_delay.max();
        r
    }

    /// Fraction of invocations that started warm (1.0 before any).
    pub fn warm_fraction(&self) -> f64 {
        let total = self.warm_invocations + self.cold_invocations;
        if total == 0 {
            1.0
        } else {
            self.warm_invocations as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("requests", Json::num(self.requests as f64)),
            ("tokens", Json::num(self.tokens as f64)),
            ("duration", Json::num(self.duration)),
            ("total_cost", Json::num(self.total_cost)),
            ("throughput_tps", Json::num(self.throughput_tps)),
            ("mean_latency", Json::num(self.mean_latency)),
            ("p50_latency", Json::num(self.p50_latency)),
            ("p95_latency", Json::num(self.p95_latency)),
            ("p99_latency", Json::num(self.p99_latency)),
            ("epochs", Json::num(self.epochs as f64)),
            ("redeploys", Json::num(self.redeploys as f64)),
            ("warm_invocations", Json::num(self.warm_invocations as f64)),
            ("cold_invocations", Json::num(self.cold_invocations as f64)),
            ("violation_batches", Json::num(self.violation_batches as f64)),
            ("mean_queue_delay", Json::num(self.mean_queue_delay)),
            ("p95_queue_delay", Json::num(self.p95_queue_delay)),
            ("max_queue_delay", Json::num(self.max_queue_delay)),
            ("queued_invocations", Json::num(self.queued_invocations as f64)),
            ("busy_secs", Json::num(self.busy_secs)),
            ("max_utilization", Json::num(self.max_utilization)),
            ("scale_outs", Json::num(self.scale_outs as f64)),
            ("scale_ins", Json::num(self.scale_ins as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SimReport, ScenarioError> {
        let need = |k: &str| {
            j.get_f64(k)
                .ok_or_else(|| ScenarioError::missing("sim report", k))
        };
        // Queueing/autoscaling fields default to zero so pre-queueing golden
        // entries still parse.
        let opt = |k: &str| j.get_f64(k).unwrap_or(0.0);
        Ok(SimReport {
            requests: need("requests")? as u64,
            tokens: need("tokens")? as u64,
            duration: need("duration")?,
            total_cost: need("total_cost")?,
            throughput_tps: need("throughput_tps")?,
            mean_latency: need("mean_latency")?,
            p50_latency: need("p50_latency")?,
            p95_latency: need("p95_latency")?,
            p99_latency: need("p99_latency")?,
            epochs: need("epochs")? as u64,
            redeploys: need("redeploys")? as u64,
            warm_invocations: need("warm_invocations")? as u64,
            cold_invocations: need("cold_invocations")? as u64,
            violation_batches: need("violation_batches")? as u64,
            mean_queue_delay: opt("mean_queue_delay"),
            p95_queue_delay: opt("p95_queue_delay"),
            max_queue_delay: opt("max_queue_delay"),
            queued_invocations: opt("queued_invocations") as u64,
            busy_secs: opt("busy_secs"),
            max_utilization: opt("max_utilization"),
            scale_outs: opt("scale_outs") as u64,
            scale_ins: opt("scale_ins") as u64,
            cost_timeline: Vec::new(),
        })
    }

    /// Golden-fixture comparison: cost, throughput and p95 latency must each
    /// match within `rel_tol` relative error. Returns a human-readable diff
    /// on mismatch so regression failures are actionable.
    pub fn close_to(&self, golden: &SimReport, rel_tol: f64) -> Result<(), String> {
        let check = |name: &str, got: f64, want: f64| -> Result<(), String> {
            let scale = want.abs().max(1e-12);
            if (got - want).abs() / scale <= rel_tol {
                Ok(())
            } else {
                Err(format!(
                    "{name}: got {got:.9} vs golden {want:.9} (rel tol {rel_tol})"
                ))
            }
        };
        check("total_cost", self.total_cost, golden.total_cost)?;
        check("throughput_tps", self.throughput_tps, golden.throughput_tps)?;
        check("p95_latency", self.p95_latency, golden.p95_latency)?;
        check("mean_queue_delay", self.mean_queue_delay, golden.mean_queue_delay)?;
        if self.requests != golden.requests {
            return Err(format!(
                "requests: got {} vs golden {}",
                self.requests, golden.requests
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> SimReport {
        let mut r = SimReport::from_samples(&[0.5, 1.0, 2.0, 4.0], 4096, 100.0, 0.125);
        r.epochs = 3;
        r.redeploys = 1;
        r.warm_invocations = 30;
        r.cold_invocations = 10;
        r.mean_queue_delay = 0.75;
        r.p95_queue_delay = 2.5;
        r.max_queue_delay = 3.0;
        r.queued_invocations = 7;
        r.busy_secs = 42.0;
        r.max_utilization = 0.8;
        r.scale_outs = 2;
        r.scale_ins = 1;
        r
    }

    #[test]
    fn percentiles_ordered() {
        let r = sample();
        assert!(r.p50_latency <= r.p95_latency);
        assert!(r.p95_latency <= r.p99_latency);
        assert!((r.throughput_tps - 40.96).abs() < 1e-9);
        assert!((r.warm_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let r = sample();
        let back = SimReport::from_json(&r.to_json()).unwrap();
        assert_eq!(back.requests, r.requests);
        assert_eq!(back.total_cost, r.total_cost);
        assert_eq!(back.p95_latency, r.p95_latency);
        assert_eq!(back.mean_queue_delay, r.mean_queue_delay);
        assert_eq!(back.queued_invocations, r.queued_invocations);
        assert_eq!(back.busy_secs, r.busy_secs);
        assert_eq!(back.max_utilization, r.max_utilization);
        assert_eq!(back.scale_outs, r.scale_outs);
        assert_eq!(back.scale_ins, r.scale_ins);
        assert!(back.close_to(&r, 1e-12).is_ok());
    }

    #[test]
    fn close_to_detects_queue_delay_drift() {
        let r = sample();
        let mut off = r.clone();
        off.mean_queue_delay *= 2.0;
        let err = r.close_to(&off, 1e-6).unwrap_err();
        assert!(err.contains("mean_queue_delay"), "{err}");
    }

    #[test]
    fn close_to_detects_drift() {
        let r = sample();
        let mut off = r.clone();
        off.total_cost *= 1.5;
        let err = r.close_to(&off, 1e-6).unwrap_err();
        assert!(err.contains("total_cost"), "{err}");
        assert!(r.close_to(&r, 0.0).is_ok());
    }
}
