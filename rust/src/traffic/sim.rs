//! Event-driven simulation core: layer-pipelined dispatch at
//! million-request scale.
//!
//! The PR 2 loop in [`super::epoch`] serves requests one at a time in
//! arrival order and dispatches *all* of a request's layers at its ready
//! time — the abstraction the ROADMAP flagged, because it lets a request's
//! layer-5 work reserve (and occupy) an instance while its layer-0 work is
//! still computing. This module replaces it with a discrete-event engine:
//!
//!  - a [`std::collections::BinaryHeap`] event queue over tenant-tagged
//!    `(time, tenant, seq)` ordered events — request arrivals are consumed
//!    from the (sorted) traffic slice, layer-dispatch events flow through
//!    the heap, and epoch boundaries are evaluated exactly as the legacy
//!    loop does (lazily, as arrivals of the same tenant cross them, after
//!    draining every in-flight event due before the boundary);
//!  - **tenant lanes behind one shared [`AccountCap`]**: the run state is an
//!    [`EventLane`] per tenant (arena, scratch plans, epoch clock, metrics),
//!    and [`drive`] interleaves any number of lanes deterministically over
//!    one [`EventQueue`], racing the event heap against a candidate heap of
//!    per-lane boundary/arrival steps (O(events · log tenants); the linear
//!    scan is kept as [`drive_scan`], the byte-identity baseline). When an
//!    account-level concurrency cap is set (`traffic::fleet`), slots are
//!    charged per concurrent replica *execution* by default — AWS Lambda's
//!    account limit counts executions, so a request fanning out to 8
//!    replicas occupies 8 slots — or per in-flight request under
//!    [`CapGranularity::Request`]; over-cap arrivals park until a release
//!    event grants them admission per the configured arbitration policy.
//!    Lanes reference their [`SlotArena`] by index, so same-preset tenants
//!    can share one warm pool (per-expert refcounts; per-tenant billing by
//!    the lane's own busy-seconds ledger). A single-tenant uncapped run is
//!    exactly one lane and reproduces the pre-fleet engine
//!    operation-for-operation;
//!  - **layer-pipelined dispatch** (`pipeline: true`): a request's layer
//!    *k+1* is enqueued when layer *k* completes (straggler replica plus the
//!    non-replica scatter/gather tail of the analytic model), so later
//!    layers' queue waits overlap earlier layers' compute across concurrent
//!    requests — the paper's pipelined scatter-gather realized at the
//!    serving level. With `pipeline: false` every layer is dispatched at the
//!    request's ready time and the engine reproduces the legacy loop
//!    bit-for-bit (cross-validation pinned at 1e-6 in `tests/traffic.rs`);
//!  - a [`SlotArena`]: replica slot state (warm-until, sorted concurrency
//!    slot releases, busy ledgers) in flat arrays indexed by a precomputed
//!    `(layer, expert, replica) → usize` map, replacing the per-request
//!    `HashMap<ReplicaKey, _>` lookups of [`crate::platform::WarmPool`];
//!  - a [`crate::gating::RouterCache`], so per-token routing is memoized
//!    (bit-identical to the uncached gate) instead of re-sorting logits for
//!    every token of every request;
//!  - optional streaming metrics ([`MetricsMode::Streaming`]): fixed-bucket
//!    log-scale histograms for latency and queue-delay percentiles keep
//!    memory O(1) in the request count (exact mean/max; estimates within
//!    one bucket width of the exact order statistics).
//!
//! Model-fidelity notes. Under pipelining, warm/cold starts are judged at
//! each layer's actual dispatch time and an instance's keep-alive window
//! extends from its *own* execution end (the monolithic dispatch extends
//! every window to the whole request's finish); the ≥60 s redeploy gap
//! blocks in-flight requests' remaining layers too (`blocked_until`), and
//! the cost timeline is stamped at each request's final-layer dispatch time
//! so it stays time-sorted. Pipelining is
//! work-conserving but not a per-request dominance: removing the monolithic
//! model's acausal head start (later layers occupying instances before
//! earlier layers finish) can delay a request that benefited from it; the
//! dominance tests therefore pin equality on homogeneous traces and the
//! strict win on the contended-downstream-instance case the paper's
//! pipelining argument is about. When `reoptimize` is off the engine also
//! skips the predictor-feedback bookkeeping (dataset-table absorption and
//! the popularity EMA) whose outputs nothing would read — the `SimReport`
//! is unaffected; only the predictor's end-of-run state differs from a
//! legacy run.

use super::arrivals::fault_seed;
use super::autoscale::{Autoscaler, CapGranularity, FleetArbitration};
use super::config::{FaultSpec, MetricsMode};
use super::epoch::{fractions, fractions_into, EpochSimulator};
use super::report::SimReport;
use super::workload::{ChatWorkload, KvLedger, RequestPhase};
use crate::bo::feedback::serve_layer_with_warmness;
use crate::comm::LayerPlan;
use crate::config::PlatformConfig;
use crate::deploy::DeploymentPolicy;
use crate::model::MoeModelSpec;
use crate::platform::{InstancePool, ReplicaKey};
use crate::predictor::profile::absorb_batch;
use crate::util::rng::Rng;
use crate::util::stats::{self, LogHistogram};
use crate::workload::{Batch, TimedBatch};
use std::cmp::{Ordering, Reverse};
use std::collections::{BinaryHeap, VecDeque};

// ------------------------------------------------------------- slot arena

/// Flat arena of replica-instance states: the event engine's replacement
/// for [`crate::platform::WarmPool`]'s keyed hash maps. Instance identity is
/// a precomputed dense index `(layer_offset[l] + e) · G + g` with `G` the
/// replica ceiling, so the hot path (peek, admit, invoke) is pure array
/// arithmetic. Semantics match `WarmPool` exactly — same keep-alive rule,
/// same sorted-slot FIFO admission, same busy/queue ledgers — which the
/// parity property test below pins.
#[derive(Debug, Clone)]
pub struct SlotArena {
    /// Per-layer starting offset into the dense expert enumeration.
    layer_off: Vec<usize>,
    /// Replica ceiling G per expert (arena stride).
    pub max_replicas: usize,
    /// Concurrent invocations one instance executes (`None` = unbounded).
    pub concurrency: Option<usize>,
    pub keep_alive: f64,
    /// Virtual time until which each instance stays warm
    /// (`NEG_INFINITY` = cold / never invoked).
    warm_until: Vec<f64>,
    /// Slot release times, `c` per instance, each segment sorted ascending
    /// (empty when unbounded).
    slot_free: Vec<f64>,
    /// Cumulative execution seconds admitted per instance (kept through
    /// `reset`, like the `WarmPool` ledgers).
    busy: Vec<f64>,
    total_busy: f64,
    pub warm_hits: u64,
    pub cold_starts: u64,
    pub queued_jobs: u64,
    pub total_queue_wait: f64,
    /// Per-instance owner counts for cross-tenant sharing (empty = private
    /// pool, the default: evictions always tear the environment down).
    refcount: Vec<u32>,
}

impl SlotArena {
    pub fn new(
        spec: &MoeModelSpec,
        max_replicas: usize,
        keep_alive: f64,
        concurrency: Option<usize>,
    ) -> SlotArena {
        assert!(keep_alive >= 0.0, "negative keep-alive");
        if let Some(c) = concurrency {
            assert!(c >= 1, "concurrency limit must be >= 1 (got {c})");
        }
        let mut layer_off = Vec::with_capacity(spec.num_moe_layers());
        let mut total = 0usize;
        for l in 0..spec.num_moe_layers() {
            layer_off.push(total);
            total += spec.experts_at(l);
        }
        let g = max_replicas.max(1);
        let n = total * g;
        let c = concurrency.unwrap_or(0);
        SlotArena {
            layer_off,
            max_replicas: g,
            concurrency,
            keep_alive,
            warm_until: vec![f64::NEG_INFINITY; n],
            slot_free: vec![f64::NEG_INFINITY; n * c],
            busy: vec![0.0; n],
            total_busy: 0.0,
            warm_hits: 0,
            cold_starts: 0,
            queued_jobs: 0,
            total_queue_wait: 0.0,
            refcount: Vec::new(),
        }
    }

    /// Turn on per-instance owner refcounts (cross-tenant expert sharing):
    /// [`InstancePool::retain`] registers owners and [`InstancePool::evict`]
    /// only tears an environment down when the last owner leaves, so one
    /// tenant's autoscaler scaling in cannot cold-start another tenant.
    pub fn enable_refcounts(&mut self) {
        self.refcount = vec![0; self.warm_until.len()];
    }

    /// Dense index of instance `(layer, expert, replica)`.
    #[inline]
    pub fn index(&self, layer: usize, expert: usize, replica: usize) -> usize {
        debug_assert!(replica < self.max_replicas, "replica {replica} out of arena bounds");
        (self.layer_off[layer] + expert) * self.max_replicas + replica
    }

    /// Whether the instance's next invocation at `now` starts warm.
    #[inline]
    pub fn is_warm_at(&self, idx: usize, now: f64) -> bool {
        now <= self.warm_until[idx]
    }

    /// Earliest work-conserving start for work ready at `arrival` — O(1):
    /// the min-free slot is the head of the sorted segment.
    #[inline]
    pub fn earliest_start(&self, idx: usize, arrival: f64) -> f64 {
        match self.concurrency {
            None => arrival,
            Some(c) => arrival.max(self.slot_free[idx * c]),
        }
    }

    /// Admit one invocation (FIFO when issued in non-decreasing arrival
    /// order); returns the scheduled start and records the ledgers.
    pub fn admit(&mut self, idx: usize, arrival: f64, service: f64) -> f64 {
        debug_assert!(service >= 0.0, "negative service time");
        let start = match self.concurrency {
            None => arrival,
            Some(c) => {
                let s = &mut self.slot_free[idx * c..(idx + 1) * c];
                let start = arrival.max(s[0]);
                let fin = start + service;
                let mut i = 0usize;
                while i + 1 < c && s[i + 1] < fin {
                    s[i] = s[i + 1];
                    i += 1;
                }
                s[i] = fin;
                start
            }
        };
        self.busy[idx] += service;
        self.total_busy += service;
        let wait = start - arrival;
        if wait > 0.0 {
            self.queued_jobs += 1;
        }
        self.total_queue_wait += wait;
        start
    }

    /// Record an invocation `[now, end]`: counts the derived start state and
    /// extends the keep-alive window past `end`.
    pub fn invoke(&mut self, idx: usize, now: f64, end: f64) -> bool {
        debug_assert!(end >= now, "invocation ends before it starts");
        let warm = self.is_warm_at(idx, now);
        if warm {
            self.warm_hits += 1;
        } else {
            self.cold_starts += 1;
        }
        let until = &mut self.warm_until[idx];
        *until = until.max(end + self.keep_alive);
        warm
    }

    pub fn total_busy_secs(&self) -> f64 {
        self.total_busy
    }

    /// Highest single-instance busy fraction of a `horizon`-second run.
    pub fn max_utilization(&self, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            return 0.0;
        }
        self.busy.iter().fold(0.0f64, |acc, &b| acc.max(b / horizon))
    }
}

impl InstancePool for SlotArena {
    fn concurrency_limit(&self) -> Option<usize> {
        self.concurrency
    }

    fn idle_at(&self, key: ReplicaKey, t: f64) -> bool {
        match self.concurrency {
            None => true,
            Some(c) => {
                let idx = self.index(key.0, key.1, key.2);
                // Sorted invariant: the last slot holds the latest release.
                self.slot_free[idx * c + (c - 1)] <= t
            }
        }
    }

    fn evict(&mut self, key: ReplicaKey) {
        let idx = self.index(key.0, key.1, key.2);
        if !self.refcount.is_empty() {
            let rc = &mut self.refcount[idx];
            *rc = rc.saturating_sub(1);
            if *rc > 0 {
                // Another tenant still owns this instance: its warm
                // environment (and queued work) survives the eviction.
                return;
            }
        }
        self.warm_until[idx] = f64::NEG_INFINITY;
        if let Some(c) = self.concurrency {
            self.slot_free[idx * c..(idx + 1) * c].fill(f64::NEG_INFINITY);
        }
    }

    fn reset(&mut self) {
        self.warm_until.fill(f64::NEG_INFINITY);
        self.slot_free.fill(f64::NEG_INFINITY);
    }

    fn prewarm(&mut self, key: ReplicaKey) {
        let idx = self.index(key.0, key.1, key.2);
        self.warm_until[idx] = f64::INFINITY;
    }

    fn retain(&mut self, key: ReplicaKey) {
        if !self.refcount.is_empty() {
            let idx = self.index(key.0, key.1, key.2);
            self.refcount[idx] += 1;
        }
    }
}

// ------------------------------------------------------------ event types

/// One scheduled event: a layer dispatch of an in-flight request, or — when
/// an account-level cap is active and `req == REQ_RELEASE` — the release of
/// a finished request's account slot. Events are tenant-tagged; the total
/// order `(at, tenant, seq)` makes heap pops deterministic across a whole
/// fleet: earlier virtual time first, lower tenant index among ties, FIFO
/// within a tenant. A single-tenant run tags everything tenant 0, which
/// degenerates to the original `(at, seq)` order bit-for-bit.
#[derive(Debug, Clone, Copy)]
struct Ev {
    at: f64,
    tenant: u32,
    seq: u64,
    req: u32,
}

/// Sentinel `req` marking an account-slot release event (one per request
/// under [`CapGranularity::Request`]).
const REQ_RELEASE: u32 = u32::MAX;

/// Sentinel `req` marking the release of one replica *execution*'s account
/// slot ([`CapGranularity::Execution`], the Lambda-accurate default: the
/// account limit counts concurrent function executions, not requests).
const EXEC_RELEASE: u32 = u32::MAX - 1;

/// Tag bit marking a cross-tenant batch-window close event; the low bits
/// carry the open batch's [`BatchPool`] slot id. Checked *after* the release
/// sentinels above (both of which also have the high bit set). In-flight
/// request slots stay far below `2^31`, so plain dispatch events are never
/// misread as batch closes.
const BATCH_MARK: u32 = 1 << 31;

/// Tag bit marking the backoff-delayed retry of a failed layer dispatch;
/// the low bits carry the in-flight slot. Checked after [`BATCH_MARK`]
/// (batch-close ids stay below `2^29`, so the tags never collide).
const RETRY_MARK: u32 = 1 << 30;

/// Tag bit marking the backoff-delayed re-admission of a throttled request
/// (a cap rejection surfaced as a retryable 429-class error); the low bits
/// carry the in-flight slot. In-flight slots stay far below `2^29`.
const THROTTLE_MARK: u32 = 1 << 29;

impl PartialEq for Ev {
    fn eq(&self, other: &Ev) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Ev {}

impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Ev) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Ev {
    fn cmp(&self, other: &Ev) -> Ordering {
        self.at
            .total_cmp(&other.at)
            .then(self.tenant.cmp(&other.tenant))
            .then(self.seq.cmp(&other.seq))
    }
}

/// The shared event heap of one run: a single globally-ordered stream
/// spanning every tenant lane, so the fleet driver interleaves tenants
/// deterministically instead of merging per-tenant heaps ad hoc.
#[derive(Debug, Default)]
pub(crate) struct EventQueue {
    heap: BinaryHeap<Reverse<Ev>>,
    seq: u64,
}

impl EventQueue {
    pub(crate) fn new() -> EventQueue {
        EventQueue { heap: BinaryHeap::new(), seq: 0 }
    }

    fn push(&mut self, at: f64, tenant: u32, req: u32) {
        self.heap.push(Reverse(Ev { at, tenant, seq: self.seq, req }));
        self.seq += 1;
    }

    fn peek(&self) -> Option<Ev> {
        self.heap.peek().map(|r| r.0)
    }

    fn pop(&mut self) -> Option<Ev> {
        self.heap.pop().map(|r| r.0)
    }

    /// Total events ever pushed through this queue — the throughput
    /// denominator the fleet reports as `events` (and benchmarks as
    /// events/sec). Deterministic, and additive across shards: every event
    /// is pushed in exactly one shard, so the per-shard sum equals the
    /// sequential run's count.
    pub(crate) fn pushed(&self) -> u64 {
        self.seq
    }
}

// ----------------------------------------------------- account-level cap

/// One parked request: an in-flight slot of a tenant lane waiting for an
/// account slot, stamped with the virtual time it became ready.
#[derive(Debug, Clone, Copy)]
pub(crate) struct Waiter {
    pub(crate) slot: usize,
    pub(crate) ready: f64,
    seq: u64,
}

/// One ledger transition, recorded when auditing is enabled — the raw
/// material of the conservation property test (`in_use` must equal the
/// number of live slot holds at every event).
#[derive(Debug, Clone, Copy)]
pub(crate) enum CapAudit {
    /// A slot was taken, to be held until `end` (`INFINITY` for a
    /// request-granular hold whose release is a later `Release` record).
    Acquire { end: f64, in_use: usize },
    /// A slot was returned at `at`.
    Release { at: f64, in_use: usize },
}

/// The shared account-level concurrency ledger — the fleet-wide analogue of
/// PR 2's per-instance slots, modeling the account concurrency limit a
/// serverless provider imposes across *all* of an account's functions.
///
/// Under [`CapGranularity::Execution`] (the default — AWS Lambda's account
/// limit counts concurrent function *executions*) every replica execution a
/// request fans out to holds one slot over its own `[start, start + t_rep]`
/// window; admission is still decided per request (a request is admitted
/// when the ledger has headroom and nothing is parked, so a wide fan-out
/// may transiently overshoot the cap by the width of one request — the
/// accounting, which is what the fleet numbers report, is exact). Under
/// [`CapGranularity::Request`] (the pre-fix mode, kept for the PR 5
/// shared-beats-isolated pin) each admitted request holds exactly one slot
/// from its first layer dispatch until its completion. A request arriving
/// while the ledger is full parks FIFO in its tenant's queue and is granted
/// a freed slot according to the [`FleetArbitration`] policy. `cap: None`
/// disables the ledger entirely (no bookkeeping on the single-tenant hot
/// path).
#[derive(Debug, Clone)]
pub struct AccountCap {
    cap: Option<usize>,
    arbitration: FleetArbitration,
    granularity: CapGranularity,
    weights: Vec<f64>,
    in_use: usize,
    in_use_by: Vec<usize>,
    /// High-water mark of `in_use` over the whole run. Under
    /// [`CapGranularity::Request`] this never exceeds the cap (admission is
    /// headroom-checked); under [`CapGranularity::Execution`] it exposes the
    /// documented transient overshoot — bounded by `cap - 1` plus one
    /// request's widest layer fan-out — which was previously invisible.
    peak_in_use: usize,
    waiting: Vec<VecDeque<Waiter>>,
    waiting_total: usize,
    park_seq: u64,
    audit: Option<Vec<CapAudit>>,
}

impl AccountCap {
    pub fn new(
        cap: Option<usize>,
        arbitration: FleetArbitration,
        granularity: CapGranularity,
        weights: &[f64],
    ) -> AccountCap {
        if let Some(c) = cap {
            assert!(c >= 1, "account cap must be >= 1 (use None for unbounded)");
        }
        assert!(
            weights.iter().all(|w| w.is_finite() && *w > 0.0),
            "tenant weights must be finite and > 0"
        );
        AccountCap {
            cap,
            arbitration,
            granularity,
            weights: weights.to_vec(),
            in_use: 0,
            in_use_by: vec![0; weights.len()],
            peak_in_use: 0,
            waiting: vec![VecDeque::new(); weights.len()],
            waiting_total: 0,
            park_seq: 0,
            audit: None,
        }
    }

    /// An inert ledger: every request is admitted immediately.
    pub fn unbounded(tenants: usize) -> AccountCap {
        AccountCap::new(None, FleetArbitration::Fifo, CapGranularity::Request, &vec![1.0; tenants])
    }

    /// Whether slots are charged per replica execution (vs per request).
    pub fn execution_granular(&self) -> bool {
        self.granularity == CapGranularity::Execution
    }

    /// Record every ledger transition from here on (conservation tests).
    pub(crate) fn enable_audit(&mut self) {
        self.audit = Some(Vec::new());
    }

    /// Drain the recorded transitions.
    pub(crate) fn take_audit(&mut self) -> Vec<CapAudit> {
        self.audit.take().unwrap_or_default()
    }

    /// Replace one tenant's arbitration weight (SLO-feedback adaptation).
    pub(crate) fn set_weight(&mut self, tenant: usize, weight: f64) {
        debug_assert!(weight.is_finite() && weight > 0.0, "bad adapted weight");
        self.weights[tenant] = weight;
    }

    pub fn enabled(&self) -> bool {
        self.cap.is_some()
    }

    /// Requests currently holding an account slot (0 when unbounded).
    pub fn in_use(&self) -> usize {
        self.in_use
    }

    /// High-water mark of concurrently held slots over the whole run —
    /// `FleetReport.peak_concurrency`. Exactly `<= cap` under request
    /// granularity; under execution granularity the transient overshoot is
    /// bounded by `cap - 1` plus one request's widest layer fan-out.
    pub fn peak_in_use(&self) -> usize {
        self.peak_in_use
    }

    /// Admit `tenant`'s request if the ledger has headroom *and* no request
    /// is already waiting (a newly arriving request must not jump the parked
    /// queue). Request granularity takes the request's slot here; execution
    /// granularity only decides admission — the request's replica executions
    /// each take their own slot at dispatch ([`AccountCap::acquire_exec`]).
    pub(crate) fn try_acquire(&mut self, tenant: usize) -> bool {
        match self.cap {
            None => true,
            Some(c) => {
                if self.in_use < c && self.waiting_total == 0 {
                    if self.granularity == CapGranularity::Request {
                        self.in_use += 1;
                        self.in_use_by[tenant] += 1;
                        self.peak_in_use = self.peak_in_use.max(self.in_use);
                        if let Some(log) = &mut self.audit {
                            log.push(CapAudit::Acquire {
                                end: f64::INFINITY,
                                in_use: self.in_use,
                            });
                        }
                    }
                    true
                } else {
                    false
                }
            }
        }
    }

    /// Take one slot for a replica execution held until `end` (execution
    /// granularity only). Called at dispatch time, after the request was
    /// admitted, so it never blocks — the transient overshoot this allows
    /// is bounded by one request's widest layer fan-out.
    pub(crate) fn acquire_exec(&mut self, tenant: usize, end: f64) {
        debug_assert_eq!(self.granularity, CapGranularity::Execution);
        self.in_use += 1;
        self.in_use_by[tenant] += 1;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        if let Some(log) = &mut self.audit {
            log.push(CapAudit::Acquire { end, in_use: self.in_use });
        }
    }

    /// Park a staged request until a slot frees.
    pub(crate) fn park(&mut self, tenant: usize, slot: usize, ready: f64) {
        self.waiting[tenant].push_back(Waiter { slot, ready, seq: self.park_seq });
        self.park_seq += 1;
        self.waiting_total += 1;
    }

    /// Return a finished hold's slot to the pool at virtual time `at`.
    pub(crate) fn release(&mut self, tenant: usize, at: f64) {
        debug_assert!(self.in_use > 0 && self.in_use_by[tenant] > 0, "release without acquire");
        self.in_use -= 1;
        self.in_use_by[tenant] -= 1;
        if let Some(log) = &mut self.audit {
            log.push(CapAudit::Release { at, in_use: self.in_use });
        }
    }

    /// Grant a free slot to the next waiter per the arbitration policy;
    /// `None` when the ledger is full or nothing waits.
    pub(crate) fn grant(&mut self) -> Option<(usize, Waiter)> {
        let c = self.cap?;
        if self.in_use >= c || self.waiting_total == 0 {
            return None;
        }
        let tenant = match self.arbitration {
            // Park order is the global arrival order, so the front seqs
            // give strict fleet-wide FIFO.
            FleetArbitration::Fifo => (0..self.waiting.len())
                .filter(|&t| !self.waiting[t].is_empty())
                .min_by_key(|&t| self.waiting[t].front().expect("non-empty queue").seq)
                .expect("waiting_total > 0"),
            // Least capacity in use relative to weight; ties break by the
            // earliest park seq (fleet-wide FIFO among the tied tenants —
            // breaking toward the lower index would structurally starve
            // higher-index tenants under symmetric load), FIFO within a
            // tenant.
            FleetArbitration::WeightedFair => {
                let mut best = usize::MAX;
                let mut best_key = f64::INFINITY;
                let mut best_seq = u64::MAX;
                for (t, queue) in self.waiting.iter().enumerate() {
                    let Some(front) = queue.front() else { continue };
                    let key = self.in_use_by[t] as f64 / self.weights[t];
                    if key < best_key || (key == best_key && front.seq < best_seq) {
                        best_key = key;
                        best_seq = front.seq;
                        best = t;
                    }
                }
                best
            }
        };
        let w = self.waiting[tenant].pop_front().expect("selected tenant has a waiter");
        self.waiting_total -= 1;
        // Request granularity: the granted request takes the freed slot
        // right here. Execution granularity: the grant only un-parks the
        // request — its replica executions take their own slots as they
        // dispatch (`acquire_exec`), so nothing is charged yet. The grant
        // loop still terminates: every grant pops one waiter.
        if self.granularity == CapGranularity::Request {
            self.in_use += 1;
            self.in_use_by[tenant] += 1;
            self.peak_in_use = self.peak_in_use.max(self.in_use);
            if let Some(log) = &mut self.audit {
                log.push(CapAudit::Acquire { end: f64::INFINITY, in_use: self.in_use });
            }
        }
        Some((tenant, w))
    }
}

// ------------------------------------------------- cross-tenant batching

/// One request's contribution to an open batch: which lane/in-flight slot
/// to resume when the merged invocation completes, when its layer became
/// ready (the batch wait is charged to its queue delay), and its token
/// count (the billing split key).
#[derive(Debug, Clone, Copy)]
struct BatchMember {
    tenant: u32,
    slot: usize,
    ready: f64,
    tokens: u64,
}

/// One open batch window: merged per-expert token counts plus the member
/// requests riding the eventual invocation. The first member is the
/// *opener* — the merged dispatch runs through its lane's scratch plan and
/// autoscaler, and its close event (`BATCH_MARK | id`) drives execution.
#[derive(Debug)]
struct OpenBatch {
    arena_id: usize,
    layer: usize,
    close_at: f64,
    counts: Vec<u64>,
    members: Vec<BatchMember>,
}

/// The per-replica batch-merge buffer of one fleet run: when two same-pool
/// tenants' layer dispatches land on the same shared replica FIFO within
/// `window` seconds, their tokens merge into *one* invocation — one
/// cold/warm judgment per replica, one `t_rep` priced from the combined
/// token count, per-tenant billing split by token share (FaaSMoE's
/// multiplexing taken from sharing instances to sharing invocations).
/// `window == 0.0` disables batching entirely: `admit` is never called and
/// the dispatch path is bit-identical to the unbatched engine.
#[derive(Debug, Default)]
pub(crate) struct BatchPool {
    window: f64,
    /// The currently open batch per `(arena, layer)` merge point.
    open: std::collections::BTreeMap<(usize, usize), usize>,
    slots: Vec<Option<OpenBatch>>,
    free: Vec<usize>,
}

impl BatchPool {
    pub(crate) fn new(window: f64) -> BatchPool {
        debug_assert!(window.is_finite() && window >= 0.0, "bad batch window");
        BatchPool { window, ..BatchPool::default() }
    }

    pub(crate) fn enabled(&self) -> bool {
        self.window > 0.0
    }

    /// Merge a layer dispatch into the open batch for `(arena, layer)` if
    /// its window is still open at `now`; otherwise open a new batch.
    /// Returns `Some((id, close_at))` when a batch was opened — the caller
    /// schedules the close event — and `None` for a join.
    fn admit(
        &mut self,
        arena_id: usize,
        layer: usize,
        now: f64,
        counts: &[u64],
        tenant: u32,
        slot: usize,
    ) -> Option<(usize, f64)> {
        let tokens: u64 = counts.iter().sum();
        let member = BatchMember { tenant, slot, ready: now, tokens };
        if let Some(&id) = self.open.get(&(arena_id, layer)) {
            if let Some(b) = self.slots[id].as_mut() {
                // A redeploy-gap clamp can move a dispatch past the open
                // window before the close event fires; such stragglers open
                // a fresh batch (the stale `open` entry is overwritten, and
                // `take`'s id check keeps the close events independent).
                if now <= b.close_at {
                    for (acc, &c) in b.counts.iter_mut().zip(counts) {
                        *acc += c;
                    }
                    b.members.push(member);
                    return None;
                }
            }
        }
        let id = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(None);
                self.slots.len() - 1
            }
        };
        let close_at = now + self.window;
        self.slots[id] = Some(OpenBatch {
            arena_id,
            layer,
            close_at,
            counts: counts.to_vec(),
            members: vec![member],
        });
        self.open.insert((arena_id, layer), id);
        Some((id, close_at))
    }

    /// Remove and return a closing batch (the close event's payload).
    fn take(&mut self, id: usize) -> OpenBatch {
        let b = self.slots[id].take().expect("close event addresses a live batch");
        if self.open.get(&(b.arena_id, b.layer)) == Some(&id) {
            self.open.remove(&(b.arena_id, b.layer));
        }
        self.free.push(id);
        b
    }
}

/// An admitted request whose layers are still being dispatched (pipelined
/// mode only). Slots are recycled through a free list, so live memory is
/// O(concurrent in-flight requests), not O(trace length).
#[derive(Debug, Default)]
struct InFlight {
    traffic_idx: usize,
    arrival: f64,
    counts: Vec<Vec<u64>>,
    next_layer: usize,
    queue_delay: f64,
    violated: bool,
    /// Consecutive failed attempts of the current layer (or of admission,
    /// while throttled) — the bounded retry budget's cursor.
    attempt: u32,
    /// Whether the request has seen no failed or throttled attempt so far
    /// (what the goodput counter tallies at finalize).
    clean: bool,
    // ---- autoregressive (chat) state; inert at `decode_len == 0` ----
    /// Decode steps this request owes after its prefill pass (0 = classic
    /// one-pass request: every field below stays untouched).
    decode_len: u32,
    /// Next decode step to run (cursor into `decode_counts`).
    decode_next: usize,
    /// Which pass `counts`/`next_layer` currently describe.
    phase: RequestPhase,
    /// Virtual time the current pass started dispatching (per-phase
    /// latency histograms measure pass durations from here).
    pass_start: f64,
    /// The current prefill pass is a KV-loss re-prefill, not the prompt
    /// pass (its duration is charged against decode time).
    reprefill: bool,
    /// The prompt's routed counts, kept for billed re-prefills.
    prompt_counts: Vec<Vec<u64>>,
    /// Pre-routed per-layer expert counts of each decode step — routed at
    /// arrival (the dispatch path has no router access), so popularity
    /// drift *within* the request is fixed by the seed, not by engine
    /// interleaving.
    decode_counts: Vec<Vec<Vec<u64>>>,
    /// Token count of each decode step (the output-token meter).
    decode_tokens: Vec<u64>,
}

/// Reusable per-dispatch scratch buffers (cleared per layer dispatch).
#[derive(Debug, Default)]
struct DispatchBufs {
    starts: Vec<f64>,
    idxs: Vec<usize>,
    replica: Vec<(ReplicaKey, f64)>,
    mem_v: Vec<(usize, usize)>,
    pay_v: Vec<(usize, usize)>,
    /// Per-replica failure fates of the current dispatch (fault path only).
    fates: Vec<bool>,
}

/// Reusable per-lane hot-loop buffers, one tier above [`DispatchBufs`]:
/// these live across *events* rather than within one layer dispatch. Each
/// is cleared and refilled at its use site, so after the first few events a
/// lane's steady-state arrival/decode/batch path allocates nothing.
#[derive(Debug, Default)]
struct Scratch {
    /// Routed per-layer expert counts of one decode step
    /// ([`EventLane::stage_chat`] pre-routes every step of a request).
    routed: Vec<Vec<u64>>,
    /// Popularity fractions of one routed batch (the EMA update under
    /// `reoptimize`).
    frac: Vec<Vec<f64>>,
    /// Arena indices of one merged batch's replicas, for KV pinning of
    /// chat members ([`execute_batch`]; meaningful on opener lanes only).
    pinned: Vec<usize>,
}

/// Metric sink: exact per-request vectors or O(1) streaming histograms.
#[derive(Debug)]
struct Metrics {
    exact: bool,
    latencies: Vec<f64>,
    queue_delays: Vec<f64>,
    timeline: Vec<(f64, f64)>,
    lat_hist: LogHistogram,
    qd_hist: LogHistogram,
}

impl Metrics {
    fn new(exact: bool, n: usize) -> Metrics {
        Metrics {
            exact,
            latencies: if exact { vec![0.0; n] } else { Vec::new() },
            queue_delays: if exact { vec![0.0; n] } else { Vec::new() },
            timeline: Vec::with_capacity(if exact { n } else { 0 }),
            lat_hist: LogHistogram::latency_default(),
            qd_hist: LogHistogram::latency_default(),
        }
    }

    fn record(&mut self, idx: usize, latency: f64, queue_delay: f64, at: f64, total_cost: f64) {
        if self.exact {
            self.latencies[idx] = latency;
            self.queue_delays[idx] = queue_delay;
            self.timeline.push((at, total_cost));
        } else {
            self.lat_hist.add(latency);
            self.qd_hist.add(queue_delay);
        }
    }

    fn build_report(&mut self, requests: u64, tokens: u64, duration: f64, cost: f64) -> SimReport {
        if self.exact {
            let mut r = SimReport::from_samples(&self.latencies, tokens, duration, cost);
            r.mean_queue_delay = stats::mean(&self.queue_delays);
            r.p95_queue_delay = stats::percentile(&self.queue_delays, 95.0);
            r.max_queue_delay = self.queue_delays.iter().cloned().fold(0.0, f64::max);
            r.cost_timeline = std::mem::take(&mut self.timeline);
            r
        } else {
            SimReport::from_histograms(
                requests,
                tokens,
                duration,
                cost,
                &self.lat_hist,
                &self.qd_hist,
            )
        }
    }
}

/// Per-tenant attribution ledger. With private pools this mirrors the
/// arena's own counters bitwise (same accumulation, same order); with a
/// shared arena it is what keeps billing per-tenant — the arena's counters
/// become pool-wide totals, and each lane's busy-seconds / warm / cold /
/// queued numbers come from here.
#[derive(Debug, Default)]
struct LaneLedger {
    busy_secs: f64,
    warm_hits: u64,
    cold_starts: u64,
    queued_jobs: u64,
}

// ------------------------------------------------------------ fault state

/// One lane's fault-injection state: the seeded crash/throttle RNG, the
/// per-expert consecutive-failure streaks behind the epoch-scoped drop
/// rule, the replica-latency history feeding the hedge quantile, and the
/// failure counters the report surfaces. `None` on a lane with faults off —
/// the fault-free path executes zero extra operations, which is what keeps
/// every committed fixture byte-identical.
#[derive(Debug)]
struct LaneFaults {
    spec: FaultSpec,
    rng: Rng,
    /// Per-layer starting offset into the dense `(layer, expert)` indexing
    /// of `fail_streak` / `dropped` (expert counts are policy-constant).
    layer_off: Vec<usize>,
    /// Consecutive dispatches in which any replica of the expert failed.
    fail_streak: Vec<u32>,
    /// Experts dropped for the rest of the epoch (tokens rerouted).
    dropped: Vec<bool>,
    /// Number of currently dropped experts per layer (O(1) mask check).
    layer_drops: Vec<u32>,
    /// Observed per-replica wait + service latencies — the hedge threshold
    /// is a quantile of this history.
    svc_hist: LogHistogram,
    failed_invocations: u64,
    retries: u64,
    hedged: u64,
    hedge_wins: u64,
    throttled: u64,
    dropped_experts: u64,
    rerouted_tokens: u64,
    good_requests: u64,
    retry_cost: f64,
}

impl LaneFaults {
    fn new(spec: FaultSpec, seed: u64, policy: &DeploymentPolicy) -> LaneFaults {
        let mut layer_off = Vec::with_capacity(policy.layers.len());
        let mut total = 0usize;
        for l in &policy.layers {
            layer_off.push(total);
            total += l.experts.len();
        }
        LaneFaults {
            spec,
            rng: Rng::new(seed),
            layer_off,
            fail_streak: vec![0; total],
            dropped: vec![false; total],
            layer_drops: vec![0; policy.layers.len()],
            svc_hist: LogHistogram::latency_default(),
            failed_invocations: 0,
            retries: 0,
            hedged: 0,
            hedge_wins: 0,
            throttled: 0,
            dropped_experts: 0,
            rerouted_tokens: 0,
            good_requests: 0,
            retry_cost: 0.0,
        }
    }

    fn idx(&self, layer: usize, expert: usize) -> usize {
        self.layer_off[layer] + expert
    }

    /// Epoch boundary: dropped experts come back and streaks reset — the
    /// drop rule is scoped to the epoch that observed the failures.
    fn reset_epoch(&mut self) {
        self.fail_streak.iter_mut().for_each(|s| *s = 0);
        self.dropped.iter_mut().for_each(|d| *d = false);
        self.layer_drops.iter_mut().for_each(|d| *d = 0);
    }

    /// Exponential-backoff delay of 0-indexed attempt `a`.
    fn backoff(&self, attempt: u32) -> f64 {
        self.spec.backoff_base * 2f64.powi(attempt.min(1024) as i32)
    }

    /// Zero dropped experts' token counts and redistribute them over the
    /// surviving experts of the layer, proportionally by largest remainder
    /// (ties to the lower expert index) — deterministic, and total tokens
    /// are conserved. The rerouted mass is the report's quality-proxy
    /// penalty. This masks the *serving* counts only; routing decisions
    /// (the gating memo) are never modified.
    fn mask_dropped(&mut self, layer: usize, counts: &mut [u64]) {
        let mut moved = 0u64;
        let mut surviving = 0u64;
        for (e, c) in counts.iter_mut().enumerate() {
            if self.dropped[self.layer_off[layer] + e] {
                moved += *c;
                *c = 0;
            } else {
                surviving += *c;
            }
        }
        if moved == 0 {
            return;
        }
        self.rerouted_tokens += moved;
        if surviving == 0 {
            // No surviving expert routed anything: park the mass on the
            // first undropped expert (one always survives — the drop rule
            // never drops a layer's last expert).
            let first = counts
                .iter()
                .enumerate()
                .position(|(e, _)| !self.dropped[self.layer_off[layer] + e])
                .expect("a layer always keeps one surviving expert");
            counts[first] += moved;
            return;
        }
        // Largest-remainder apportionment of `moved` over survivors.
        let mut assigned = 0u64;
        let mut rems: Vec<(u64, usize)> = Vec::new();
        for (e, c) in counts.iter_mut().enumerate() {
            if self.dropped[self.layer_off[layer] + e] || *c == 0 {
                continue;
            }
            let share = (moved as u128 * *c as u128 / surviving as u128) as u64;
            let rem = (moved as u128 * *c as u128 % surviving as u128) as u64;
            *c += share;
            assigned += share;
            rems.push((rem, e));
        }
        // Ties break to the lower index: sort by (remainder desc, index asc).
        rems.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        for &(_, e) in rems.iter().take((moved - assigned) as usize) {
            counts[e] += 1;
        }
    }
}

// ---------------------------------------------------------- layer dispatch

/// Outcome of dispatching one layer of one request at one ready time.
struct LayerDispatch {
    cost: f64,
    latency: f64,
    max_service: f64,
    /// `max(start + service)` over the layer's replicas
    /// (`NEG_INFINITY` if the layer routed no tokens).
    service_finish: f64,
    queue_delay: f64,
    violated: bool,
    /// Whether any replica invocation crashed or timed out (fault path
    /// only): the attempt is billed but must be retried or given up on.
    failed: bool,
}

/// Dispatch one layer: write the real token counts into the scratch plan,
/// peek each needed instance's FIFO start (warm/cold is judged at that
/// start), price the layer via the shared per-layer serving decomposition,
/// then admit every replica. Appends `(arena idx, start, service)` to
/// `pending` so the caller decides the keep-alive end (request finish under
/// monolithic dispatch, own execution end under pipelining).
///
/// With `faults` present, replica fates (crash / timeout), straggler
/// hedging and expert-failure streaks are adjudicated *between* pricing
/// and admission — truncated services and the hedge replica then flow
/// through the ordinary admit / invoke / cap machinery, so arena busy
/// time, billing and the account ledger stay conserved by construction.
#[allow(clippy::too_many_arguments)]
fn dispatch_layer(
    platform: &PlatformConfig,
    spec: &MoeModelSpec,
    arena: &mut SlotArena,
    autoscaler: &mut Autoscaler,
    plan: &mut LayerPlan,
    layer: usize,
    counts: &[u64],
    ready: f64,
    pending: &mut Vec<(usize, f64, f64)>,
    bufs: &mut DispatchBufs,
    ledger: &mut LaneLedger,
    faults: Option<&mut LaneFaults>,
) -> LayerDispatch {
    let DispatchBufs { starts, idxs, replica, mem_v, pay_v, fates } = bufs;
    starts.clear();
    idxs.clear();
    replica.clear();
    mem_v.clear();
    pay_v.clear();
    fates.clear();

    for (ep, &c) in plan.experts.iter_mut().zip(counts) {
        ep.tokens = c;
    }
    for (i, ep) in plan.experts.iter().enumerate() {
        if ep.tokens == 0 {
            continue;
        }
        for g in 0..ep.replicas {
            let idx = arena.index(layer, i, g);
            idxs.push(idx);
            starts.push(arena.earliest_start(idx, ready));
        }
    }

    // The serving decomposition queries warmness in exactly the
    // expert-major, replica-minor order the peek loop above filled.
    let arena_ro: &SlotArena = arena;
    let mut k = 0usize;
    let ls = serve_layer_with_warmness(
        platform,
        spec,
        layer,
        plan,
        &mut |_l, _e, _g| {
            let warm = arena_ro.is_warm_at(idxs[k], starts[k]);
            k += 1;
            warm
        },
        replica,
        mem_v,
        pay_v,
    );
    debug_assert_eq!(k, idxs.len(), "peek/serve replica order diverged");

    // Fault adjudication sits between pricing and admission: no instance
    // state has changed since the peek, so truncating a service or adding
    // the hedge replica here keeps every peeked start valid.
    let mut cost = ls.cost;
    let mut failed = false;
    if let Some(f) = faults {
        // Billed busy-seconds before any fate is applied — the denominator
        // of the proportional cost adjustment below.
        let full_busy: f64 = replica.iter().map(|r| r.1).sum();

        // Per-replica fates: timeout cutoff (killed and billed exactly the
        // cutoff), then the crash draw (billed in full, per Lambda error
        // semantics), with the cold-start multiplier applied to replicas
        // judged cold at their peeked start.
        for j in 0..replica.len() {
            let mut rep_failed = false;
            if replica[j].1 > f.spec.timeout {
                replica[j].1 = f.spec.timeout;
                rep_failed = true;
            } else if f.spec.crash_prob > 0.0 {
                let warm = arena.is_warm_at(idxs[j], starts[j]);
                let mult = if warm { 1.0 } else { f.spec.cold_crash_multiplier };
                if f.rng.f64() < (f.spec.crash_prob * mult).min(1.0) {
                    rep_failed = true;
                }
            }
            if rep_failed {
                f.failed_invocations += 1;
                failed = true;
            }
            fates.push(rep_failed);
        }

        // Expert streak bookkeeping over the expert-major replica runs: any
        // failed replica counts against the expert; `drop_after` consecutive
        // failing dispatches drop it for the epoch — but never the layer's
        // last surviving expert.
        let mut j = 0usize;
        while j < replica.len() {
            let e = replica[j].0 .1;
            let mut any = false;
            while j < replica.len() && replica[j].0 .1 == e {
                any |= fates[j];
                j += 1;
            }
            let ix = f.idx(layer, e);
            if !any {
                f.fail_streak[ix] = 0;
                continue;
            }
            f.fail_streak[ix] += 1;
            if f.spec.drop_after > 0
                && !f.dropped[ix]
                && f.fail_streak[ix] >= f.spec.drop_after
                && (f.layer_drops[layer] as usize) + 1 < plan.experts.len()
            {
                f.dropped[ix] = true;
                f.layer_drops[layer] += 1;
                f.dropped_experts += 1;
            }
        }

        // Straggler hedging (successful attempts only): when the slowest
        // replica's finish exceeds the history quantile, race a duplicate
        // invocation on the expert's first undeployed replica slot and take
        // the first finisher; the loser is billed only up to the winner's
        // finish. The threshold is read before this dispatch's samples are
        // absorbed into the history.
        if f.spec.hedge_quantile > 0.0 && !failed && !replica.is_empty() {
            let threshold = if f.svc_hist.count() >= f.spec.hedge_min_obs {
                f.svc_hist.percentile(f.spec.hedge_quantile * 100.0)
            } else {
                f64::INFINITY
            };
            let mut js = 0usize;
            for j in 1..replica.len() {
                if starts[j] + replica[j].1 > starts[js] + replica[js].1 {
                    js = j;
                }
            }
            for j in 0..replica.len() {
                f.svc_hist.add((starts[j] - ready).max(0.0) + replica[j].1);
            }
            let (key, svc) = replica[js];
            let g1 = plan.experts[key.1].replicas;
            if starts[js] + svc - ready > threshold && g1 < arena.max_replicas {
                let idx_h = arena.index(layer, key.1, g1);
                let start_h = arena.earliest_start(idx_h, ready);
                let straggler_finish = starts[js] + svc;
                let winner = straggler_finish.min(start_h + svc);
                if start_h + svc < straggler_finish {
                    replica[js].1 = (winner - starts[js]).max(0.0);
                    f.hedge_wins += 1;
                }
                idxs.push(idx_h);
                starts.push(start_h);
                replica.push(((layer, key.1, g1), (winner - start_h).max(0.0).min(svc)));
                f.hedged += 1;
            }
        }

        // Deterministic cost proxy: billed busy-seconds (truncated losers,
        // timeout cutoffs, the hedge duplicate) scale the priced layer cost.
        let billed_busy: f64 = replica.iter().map(|r| r.1).sum();
        if full_busy > 0.0 {
            cost = ls.cost * (billed_busy / full_busy);
        }
    }

    let mut service_finish = f64::NEG_INFINITY;
    let mut queue_delay = 0.0f64;
    let enabled = autoscaler.enabled();
    for (j, &(key, t_rep)) in replica.iter().enumerate() {
        let idx = idxs[j];
        let start = arena.admit(idx, ready, t_rep);
        debug_assert_eq!(start, starts[j], "peeked start must match admission");
        // Tenant-attributed mirror of the arena ledger arithmetic.
        ledger.busy_secs += t_rep;
        if start - ready > 0.0 {
            ledger.queued_jobs += 1;
        }
        queue_delay = queue_delay.max(start - ready);
        service_finish = service_finish.max(start + t_rep);
        if enabled {
            autoscaler.record(key.0, key.1, t_rep, start - ready);
        }
        pending.push((idx, start, t_rep));
    }

    LayerDispatch {
        cost,
        latency: ls.latency,
        max_service: ls.max_service,
        service_finish,
        queue_delay,
        // `SimReport::violation_batches` counts memory violations (Alg. 2
        // case (i)) only, exactly as the legacy loop does.
        violated: !mem_v.is_empty(),
        failed,
    }
}

// ----------------------------------------------------------------- lanes

/// One tenant's complete run state: the event-engine dispatch machinery
/// (slot arena, scratch plans, in-flight requests, metric sinks) plus the
/// epoch-loop bookkeeping that used to live as locals of the single-tenant
/// run loop (popularity basis/EMA, epoch clock, redeploy gap, counters).
/// The single-tenant engine is exactly one lane driven to completion; the
/// fleet driver (`traffic::fleet`) runs many lanes against one shared
/// [`EventQueue`] and [`AccountCap`].
pub(crate) struct EventLane<'a, 't> {
    tenant: u32,
    pipeline: bool,
    /// Whether an account cap is active: requests (or their executions,
    /// under execution granularity) then hold ledger slots, and release
    /// events close the loop.
    capped: bool,
    /// Execution-granular cap: each replica execution holds its own
    /// account slot over `[start, start + t_rep]`.
    cap_exec: bool,
    platform: &'a PlatformConfig,
    spec: &'a MoeModelSpec,
    num_layers: usize,
    /// Index of this lane's arena in the driver's arena slice — several
    /// lanes share one arena under cross-tenant expert sharing.
    pub(crate) arena_id: usize,
    /// Tenant-attributed busy/warm/cold/queued counters (see [`LaneLedger`]).
    ledger: LaneLedger,
    autoscaler: Autoscaler,
    /// Policy layer plans with per-request token counts scribbled in;
    /// refreshed whenever the policy changes at an epoch boundary.
    plans: Vec<LayerPlan>,
    /// Reusable hot-loop buffers (routed decode counts, EMA fractions,
    /// merged-batch pin lists) — cleared and refilled per event instead of
    /// reallocated, so the steady-state loop is allocation-free.
    scratch: Scratch,
    inflight: Vec<InFlight>,
    free: Vec<usize>,
    pending: Vec<(usize, f64, f64)>,
    bufs: DispatchBufs,
    metrics: Metrics,
    total_cost: f64,
    violation_batches: u64,
    last_finish: f64,
    /// Virtual time before which no layer may dispatch: the ≥60 s redeploy
    /// gap blocks *all* serving, including the remaining layers of requests
    /// already in flight when the re-deployment fires (layer-0 admission is
    /// clamped via the ready time; chained layer events are clamped here).
    blocked_until: f64,
    // ---- epoch-loop state ----
    policy: DeploymentPolicy,
    traffic: &'t [TimedBatch],
    cursor: usize,
    counts_buf: Vec<Vec<u64>>,
    basis: Vec<Vec<f64>>,
    ema: Vec<Vec<f64>>,
    tokens: u64,
    redeploys: u64,
    epochs: u64,
    redeploy_ready: f64,
    next_epoch: f64,
    last_batch: Option<&'t Batch>,
    // ---- tenant churn ----
    /// The tenant's `[start, end)` activity window (`None` = whole run).
    /// Outside it the lane produces no candidates in the driver's step
    /// race; onboarding retains the shared arena's replicas at `start`,
    /// offboarding releases them (idle ones scale in) at `end`.
    active: Option<(f64, f64)>,
    /// Whether the onboard step ran (always-active lanes start onboarded).
    onboarded: bool,
    /// Whether the offboard step ran (terminal; the lane is then inert).
    offboarded: bool,
    // ---- cross-tenant batching ----
    /// Whether this lane's layer dispatches route through the fleet's
    /// [`BatchPool`] (shared arena, `batch_window > 0`, pipelined engine).
    batchable: bool,
    /// Layer dispatches of this tenant merged into an already-open batch —
    /// each one an invocation the tenant did not pay for separately.
    pub(crate) batched: u64,
    // ---- account-cap bookkeeping ----
    /// Cap-induced admission delay of each parked request, in grant order
    /// (empty when the run is uncapped or the cap never filled).
    pub(crate) cap_waits: Vec<f64>,
    // ---- SLO-feedback arbitration ----
    /// Adapt this lane's arbitration weight from its per-epoch SLO verdict.
    slo_feedback: bool,
    slo_p95: Option<f64>,
    /// The declared weight (the adaptation floor) and the adapted weight.
    base_weight: f64,
    pub(crate) eff_weight: f64,
    /// Latencies of requests finished since the last epoch boundary.
    epoch_hist: LogHistogram,
    // ---- failure injection ----
    /// Fault-injection state (`None` with faults off: the fault-free path
    /// executes zero extra operations — byte identity of every pin).
    faults: Option<LaneFaults>,
    // ---- autoregressive (chat) serving ----
    /// The lane's decode schedule (`None` for classic one-pass traffic:
    /// every chat branch below is dead and the engine is byte-identical to
    /// the pre-chat build).
    chat: Option<&'a ChatWorkload>,
    /// Which instances hold each in-flight request's KV state (pinned as
    /// prefill layers dispatch; a cold pin at a decode step's start means
    /// the state was reaped with the instance — billed re-prefill).
    kv: KvLedger,
    /// Whether decode steps of co-resident requests merge through the
    /// [`BatchPool`] (`decode_batch_window > 0` on a chat lane).
    decode_batching: bool,
    /// Requests currently past their prompt pass and not yet finalized —
    /// a lone decode step has nobody to merge with and dispatches serially
    /// (work conservation on an uncontended replica by construction).
    decode_inflight: usize,
    prefill_hist: LogHistogram,
    decode_hist: LogHistogram,
    /// Total seconds spent in decode passes (plus KV re-prefills), the
    /// numerator of time-per-output-token.
    decode_time: f64,
    output_tokens: u64,
}

/// Per-lane wiring the fleet driver decides: identity, arena assignment,
/// cap mode, and SLO-feedback configuration.
#[derive(Debug, Clone, Copy)]
pub(crate) struct LaneOpts {
    pub(crate) tenant: u32,
    pub(crate) arena_id: usize,
    pub(crate) capped: bool,
    pub(crate) cap_exec: bool,
    pub(crate) slo_feedback: bool,
    pub(crate) slo_p95: Option<f64>,
    pub(crate) weight: f64,
    pub(crate) active: Option<(f64, f64)>,
    pub(crate) batchable: bool,
}

impl LaneOpts {
    /// The single-tenant engine's wiring: one uncapped lane, one arena.
    pub(crate) fn solo() -> LaneOpts {
        LaneOpts {
            tenant: 0,
            arena_id: 0,
            capped: false,
            cap_exec: false,
            slo_feedback: false,
            slo_p95: None,
            weight: 1.0,
            active: None,
            batchable: false,
        }
    }
}

/// Largest replica count a hand-built policy deploys anywhere — the arena
/// stride must cover it even when it exceeds `cfg.max_replicas` (the
/// autoscaler's own ceiling).
pub(crate) fn policy_stride(policy: &DeploymentPolicy) -> usize {
    policy
        .layers
        .iter()
        .flat_map(|l| l.experts.iter().map(|e| e.replicas))
        .max()
        .unwrap_or(1)
}

/// Fold one routed batch's popularity fractions into the drift EMA — the
/// same exponential update for top-level arrivals and (under `reoptimize`)
/// per decode step.
fn ema_update(ema: &mut [Vec<f64>], frac: &[Vec<f64>], alpha: f64) {
    for (el, fl) in ema.iter_mut().zip(frac) {
        for (e, &f) in el.iter_mut().zip(fl) {
            *e = (1.0 - alpha) * *e + alpha * f;
        }
    }
}

impl<'a, 't> EventLane<'a, 't> {
    /// Build one lane. The caller owns the arena (shared arenas span
    /// several lanes) and is responsible for sizing it to at least
    /// [`policy_stride`] and pre-warming the plan when `cfg.prewarm` is on.
    pub(crate) fn new(
        sim: &EpochSimulator<'a>,
        policy: DeploymentPolicy,
        traffic: &'t [TimedBatch],
        pipeline: bool,
        opts: LaneOpts,
    ) -> EventLane<'a, 't> {
        let spec = sim.spec;
        let num_layers = spec.num_moe_layers();
        debug_assert_eq!(policy.layers.len(), num_layers);
        // Popularity the current deployment was sized for, vs realized EMA.
        let plan_counts: Vec<Vec<u64>> = policy
            .layers
            .iter()
            .map(|l| l.experts.iter().map(|ep| ep.tokens).collect())
            .collect();
        let basis = fractions(&plan_counts);
        let ema = basis.clone();
        let exact = sim.cfg.metrics == MetricsMode::Exact;
        // The fault RNG derives from the tenant's own master seed through
        // the pinned helper, decorrelated from the arrival stream.
        let faults = if sim.cfg.faults.enabled() {
            Some(LaneFaults::new(sim.cfg.faults, fault_seed(sim.cfg.seed), &policy))
        } else {
            None
        };
        EventLane {
            tenant: opts.tenant,
            pipeline,
            capped: opts.capped,
            cap_exec: opts.cap_exec,
            platform: sim.platform,
            spec,
            num_layers,
            arena_id: opts.arena_id,
            ledger: LaneLedger::default(),
            autoscaler: Autoscaler::new(sim.cfg.autoscale, sim.cfg.max_replicas),
            plans: policy.layers.clone(),
            scratch: Scratch::default(),
            inflight: Vec::new(),
            free: Vec::new(),
            pending: Vec::new(),
            bufs: DispatchBufs::default(),
            metrics: Metrics::new(exact, traffic.len()),
            total_cost: 0.0,
            violation_batches: 0,
            last_finish: 0.0,
            blocked_until: 0.0,
            policy,
            traffic,
            cursor: 0,
            counts_buf: Vec::new(),
            basis,
            ema,
            tokens: 0,
            redeploys: 0,
            epochs: 0,
            redeploy_ready: 0.0,
            next_epoch: sim.cfg.epoch_secs,
            last_batch: None,
            active: opts.active,
            onboarded: opts.active.is_none(),
            offboarded: false,
            batchable: opts.batchable,
            batched: 0,
            cap_waits: Vec::new(),
            slo_feedback: opts.slo_feedback,
            slo_p95: opts.slo_p95,
            base_weight: opts.weight,
            eff_weight: opts.weight,
            epoch_hist: LogHistogram::latency_default(),
            faults,
            chat: sim.chat,
            kv: KvLedger::new(),
            decode_batching: sim.cfg.decode_batch_window > 0.0 && sim.chat.is_some(),
            decode_inflight: 0,
            prefill_hist: LogHistogram::latency_default(),
            decode_hist: LogHistogram::latency_default(),
            decode_time: 0.0,
            output_tokens: 0,
        }
    }

    /// The lane's next arrival time, if any traffic remains.
    fn next_arrival(&self) -> Option<f64> {
        self.traffic.get(self.cursor).map(|tb| tb.at)
    }

    /// The lane's next epoch boundary, if its next arrival crosses it —
    /// the lazy-boundary rule of the single-tenant loop preserved per lane:
    /// boundaries fire only because a later arrival of the *same tenant*
    /// crosses them, and never after the tenant's last arrival.
    fn boundary_due(&self) -> Option<f64> {
        match self.next_arrival() {
            Some(a) if a >= self.next_epoch => Some(self.next_epoch),
            _ => None,
        }
    }

    /// Process the epoch boundary at `next_epoch`: replica autoscaling and
    /// (under `reoptimize`) the drift check + full redeploy, via the
    /// engine-shared machinery on the owning simulator; then, under
    /// SLO-feedback arbitration, re-weight this tenant from its epoch's
    /// realized p95.
    fn on_boundary(
        &mut self,
        sim: &mut EpochSimulator<'a>,
        arena: &mut SlotArena,
        cap: &mut AccountCap,
    ) {
        let boundary = self.next_epoch;
        self.epochs += 1;
        let changed = sim.epoch_boundary(
            boundary,
            &mut self.policy,
            arena,
            &mut self.autoscaler,
            self.last_batch,
            &mut self.basis,
            &mut self.ema,
            &mut self.total_cost,
            &mut self.redeploy_ready,
            &mut self.redeploys,
        );
        if changed {
            self.plans.clone_from(&self.policy.layers);
        }
        // A redeploy blocks all serving for the gap — including the
        // remaining layers of requests already in flight.
        self.blocked_until = self.redeploy_ready;
        self.next_epoch += sim.cfg.epoch_secs;
        // SLO-feedback arbitration: a tenant that missed its p95 target
        // this epoch doubles its grant weight (capped at 8× the declared
        // weight); one that met it decays back toward the declared floor.
        // Multiplicative-increase keeps the adaptation scale-free and the
        // floor keeps a persistently-happy tenant at its contract weight.
        if self.adapt_slo_weight() {
            cap.set_weight(self.tenant as usize, self.eff_weight);
        }
        // Dropped experts come back at the boundary: the degradation rule
        // is scoped to the epoch that observed the failure streaks.
        if let Some(f) = self.faults.as_mut() {
            f.reset_epoch();
        }
    }

    /// Apply one SLO-feedback weight adaptation over the latencies
    /// accumulated since the last evaluation; returns whether a verdict was
    /// applied (the boundary path then propagates the new weight to the
    /// live arbitration ledger; the end-of-run flush has no ledger left to
    /// update). No-op on non-SLO lanes, so every byte-identity pin — all
    /// non-SLO — is untouched.
    fn adapt_slo_weight(&mut self) -> bool {
        if !self.slo_feedback || self.epoch_hist.count() == 0 {
            return false;
        }
        let Some(slo) = self.slo_p95 else { return false };
        let p95 = self.epoch_hist.percentile(95.0);
        self.eff_weight = if p95 > slo {
            (self.eff_weight * 2.0).min(self.base_weight * 8.0)
        } else {
            (self.eff_weight * 0.5).max(self.base_weight)
        };
        self.epoch_hist = LogHistogram::latency_default();
        true
    }

    /// The tenant's onboarding step at `active.start`: register this
    /// tenant's ownership of every replica its policy deploys, so a shared
    /// (refcounted) pool another tenant scales in under keeps the warm
    /// environments this tenant now relies on. A no-op on private pools
    /// (`retain` ignores unrefcounted arenas), matching the upfront retain
    /// the fleet driver performs for always-active tenants.
    fn on_onboard(&mut self, arena: &mut SlotArena) {
        debug_assert!(!self.onboarded, "double onboard");
        self.onboarded = true;
        for (l, lp) in self.policy.layers.iter().enumerate() {
            for (e, ep) in lp.experts.iter().enumerate() {
                for g in 0..ep.replicas {
                    arena.retain((l, e, g));
                }
            }
        }
    }

    /// The tenant's offboarding step at `active.end`: release every replica
    /// ownership the onboard step took and scale idle instances in (a
    /// shared instance another tenant still owns survives with its warm
    /// state; busy instances are skipped exactly as autoscale scale-in
    /// skips them). Straggler in-flight layers of this tenant dispatched
    /// after `end` simply cold-start. The lane is terminal afterwards: it
    /// produces no further candidates in the driver's step race.
    fn on_offboard(&mut self, arena: &mut SlotArena, now: f64) {
        debug_assert!(!self.offboarded, "double offboard");
        self.offboarded = true;
        self.autoscaler.depart(&self.policy, arena, now);
    }

    /// Admit the next arrival: route the batch, feed the predictor, then
    /// either take an account slot and start serving or park until one
    /// frees. Operation order is identical to the single-tenant loop.
    fn on_arrival(
        &mut self,
        sim: &mut EpochSimulator<'a>,
        q: &mut EventQueue,
        cap: &mut AccountCap,
        arena: &mut SlotArena,
        batch: &mut BatchPool,
    ) {
        let traffic = self.traffic;
        let tb = &traffic[self.cursor];
        let ri = self.cursor;
        self.cursor += 1;
        let t = tb.at;
        let ready = t.max(self.redeploy_ready);
        sim.router.counts_into(sim.gate, &tb.batch, &mut self.counts_buf);
        self.tokens += tb.batch.total_tokens as u64;

        if sim.cfg.reoptimize {
            // Online feedback: realized routing → table + EMA, absorbed
            // through the same routing memo serving uses. Skipped entirely
            // when re-optimization is off — nothing downstream reads it
            // and the report is unaffected.
            absorb_batch(&mut sim.predictor.table, sim.gate, &mut sim.router, &tb.batch);
            fractions_into(&self.counts_buf, &mut self.scratch.frac);
            ema_update(&mut self.ema, &self.scratch.frac, sim.cfg.ema_alpha);
        }
        self.last_batch = Some(&tb.batch);

        if !cap.try_acquire(self.tenant as usize) {
            // Account saturated: hold the routed request until a slot
            // frees; the driver restarts it from the release event —
            // unless the rejection surfaces as a throttle error, in which
            // case the request itself backs off and retries admission.
            let slot = self.stage_request(ri, t);
            self.stage_chat(sim, slot);
            if !self.maybe_throttle(q, slot, ready) {
                cap.park(self.tenant as usize, slot, ready);
            }
        } else if self.pipeline {
            let slot = self.stage_request(ri, t);
            self.stage_chat(sim, slot);
            if ready > t {
                q.push(ready, self.tenant, slot as u32);
            } else {
                self.dispatch(q, cap, arena, batch, slot, ready);
            }
        } else {
            let counts = std::mem::take(&mut self.counts_buf);
            let finish = self.serve_monolithic(q, cap, arena, ri, t, ready, &counts, t);
            self.counts_buf = counts;
            if self.capped && !self.cap_exec {
                q.push(finish, self.tenant, REQ_RELEASE);
            }
        }
    }

    /// Take (or grow) an in-flight slot and move the routed counts into it.
    /// Slots are recycled through the free list, so live memory stays
    /// O(concurrent in-flight requests).
    fn stage_request(&mut self, ri: usize, t: f64) -> usize {
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.inflight.push(InFlight::default());
                self.inflight.len() - 1
            }
        };
        let fl = &mut self.inflight[slot];
        fl.traffic_idx = ri;
        fl.arrival = t;
        fl.next_layer = 0;
        fl.queue_delay = 0.0;
        fl.violated = false;
        fl.attempt = 0;
        fl.clean = true;
        // Recycled-slot hygiene for the chat state machine (scalar writes
        // only; the vectors are refilled by `stage_chat` when they matter).
        fl.decode_len = 0;
        fl.decode_next = 0;
        fl.phase = RequestPhase::Prefill;
        fl.pass_start = t;
        fl.reprefill = false;
        std::mem::swap(&mut fl.counts, &mut self.counts_buf);
        slot
    }

    /// Arm the chat state machine for a freshly staged request: pre-route
    /// every decode step's token batch through the shared routing memo (the
    /// dispatch path has no router access) and open its KV ledger entry.
    /// A no-op for non-chat lanes and for requests the decode-length model
    /// assigned zero steps — those run the classic one-pass path untouched.
    ///
    /// Under `reoptimize`, each decode step's realized routing also feeds
    /// the drift signal — absorbed into the predictor's dataset table and
    /// folded into the popularity EMA exactly like a top-level arrival —
    /// so a chat workload whose *within-request* routing drifts away from
    /// the deployed basis triggers a redeploy (the ROADMAP direction-3
    /// follow-on: decode steps used to route through the memo without ever
    /// updating the signal the reoptimizer watches).
    fn stage_chat(&mut self, sim: &mut EpochSimulator<'a>, slot: usize) {
        let Some(chat) = self.chat else { return };
        let ri = self.inflight[slot].traffic_idx;
        let len = chat.decode_lens[ri];
        if len == 0 {
            return;
        }
        {
            let fl = &mut self.inflight[slot];
            fl.decode_len = len;
            fl.prompt_counts.clone_from(&fl.counts);
            fl.decode_counts.clear();
            fl.decode_tokens.clear();
        }
        for step in &chat.steps[ri] {
            sim.router.counts_into(sim.gate, step, &mut self.scratch.routed);
            if sim.cfg.reoptimize {
                absorb_batch(&mut sim.predictor.table, sim.gate, &mut sim.router, step);
                fractions_into(&self.scratch.routed, &mut self.scratch.frac);
                ema_update(&mut self.ema, &self.scratch.frac, sim.cfg.ema_alpha);
            }
            let fl = &mut self.inflight[slot];
            fl.decode_counts.push(self.scratch.routed.clone());
            fl.decode_tokens.push(step.total_tokens as u64);
        }
        self.kv.begin(slot);
    }

    /// Fault path of a cap-rejected admission: with probability
    /// `throttle_prob` (and remaining retry budget) the rejection surfaces
    /// as a retryable 429-class throttle error — the request backs off
    /// exponentially and re-attempts admission itself instead of parking in
    /// the fair-arbitration wait queue. Returns whether it throttled.
    fn maybe_throttle(&mut self, q: &mut EventQueue, slot: usize, ready: f64) -> bool {
        let Some(f) = self.faults.as_mut() else { return false };
        let fl = &mut self.inflight[slot];
        if f.spec.throttle_prob <= 0.0
            || fl.attempt >= f.spec.max_retries
            || f.rng.f64() >= f.spec.throttle_prob
        {
            return false;
        }
        f.throttled += 1;
        fl.clean = false;
        let delay = f.backoff(fl.attempt);
        fl.attempt += 1;
        debug_assert!(slot < THROTTLE_MARK as usize, "in-flight slot id overflow");
        q.push(ready + delay, self.tenant, THROTTLE_MARK | slot as u32);
        true
    }

    /// A throttled request's backoff expired: re-attempt admission. On a
    /// grant the retry budget resets (layer retries get the full budget);
    /// on another rejection the throttle die rolls again, and an exhausted
    /// or unlucky request falls back to the ordinary cap parking queue.
    fn on_throttle_retry(
        &mut self,
        q: &mut EventQueue,
        cap: &mut AccountCap,
        arena: &mut SlotArena,
        batch: &mut BatchPool,
        slot: usize,
        at: f64,
    ) {
        if cap.try_acquire(self.tenant as usize) {
            self.inflight[slot].attempt = 0;
            // Fault injection requires the pipelined engine (validated at
            // parse time), so a granted retry dispatches layer 0 directly.
            self.dispatch(q, cap, arena, batch, slot, at);
            return;
        }
        if !self.maybe_throttle(q, slot, at) {
            cap.park(self.tenant as usize, slot, at);
        }
    }

    /// Start a granted (previously cap-parked) request at virtual time
    /// `at`: first layer dispatch under pipelining, whole-request monolithic
    /// service otherwise. Only reachable under an active cap.
    fn start_request(
        &mut self,
        q: &mut EventQueue,
        cap: &mut AccountCap,
        arena: &mut SlotArena,
        batch: &mut BatchPool,
        slot: usize,
        at: f64,
    ) {
        if self.pipeline {
            if self.faults.is_some() {
                // A request may arrive here with throttle attempts spent;
                // layer retries get the full budget.
                self.inflight[slot].attempt = 0;
            }
            self.dispatch(q, cap, arena, batch, slot, at);
        } else {
            let at = at.max(self.blocked_until);
            let counts = std::mem::take(&mut self.inflight[slot].counts);
            let ri = self.inflight[slot].traffic_idx;
            let arrival = self.inflight[slot].arrival;
            let finish = self.serve_monolithic(q, cap, arena, ri, arrival, at, &counts, at);
            self.inflight[slot].counts = counts;
            self.free.push(slot);
            if !self.cap_exec {
                q.push(finish, self.tenant, REQ_RELEASE);
            }
        }
    }

    /// Dispatch the next layer of an in-flight request at `now` (clamped
    /// past any redeploy gap); chain the following layer at this layer's
    /// completion, or finalize the request. On a batchable lane the layer
    /// routes into the fleet's [`BatchPool`] instead: the first dispatch of
    /// a `(pool, layer)` merge point opens a window and schedules its close
    /// event; later same-window dispatches just merge their tokens — the
    /// whole batch executes as one invocation when the window closes
    /// ([`execute_batch`]).
    fn dispatch(
        &mut self,
        q: &mut EventQueue,
        cap: &mut AccountCap,
        arena: &mut SlotArena,
        batch: &mut BatchPool,
        slot: usize,
        now: f64,
    ) {
        let now = now.max(self.blocked_until);
        let l = self.inflight[slot].next_layer;
        // Continuous batching: a decode step with at least one other
        // decode-phase request in flight merges through the pool exactly
        // like a batchable fleet dispatch; a lone decode step has nobody to
        // wait for and dispatches serially, so an uncontended replica never
        // pays the window (work conservation by construction).
        if self.batchable
            || (self.decode_batching
                && self.inflight[slot].phase == RequestPhase::Decode
                && self.decode_inflight > 1)
        {
            let counts = &self.inflight[slot].counts[l];
            match batch.admit(self.arena_id, l, now, counts, self.tenant, slot) {
                Some((id, close_at)) => {
                    debug_assert!(id < BATCH_MARK as usize, "batch pool id overflow");
                    q.push(close_at, self.tenant, BATCH_MARK | id as u32);
                }
                None => self.batched += 1,
            }
            return;
        }
        // Graceful degradation: tokens routed to experts dropped this epoch
        // are rerouted onto the survivors before dispatch. The mask touches
        // only the serving counts — routing decisions (the gating memo) are
        // never modified.
        if let Some(f) = self.faults.as_mut() {
            if f.layer_drops[l] > 0 {
                f.mask_dropped(l, &mut self.inflight[slot].counts[l]);
            }
        }
        self.pending.clear();
        let d = dispatch_layer(
            self.platform,
            self.spec,
            arena,
            &mut self.autoscaler,
            &mut self.plans[l],
            l,
            &self.inflight[slot].counts[l],
            now,
            &mut self.pending,
            &mut self.bufs,
            &mut self.ledger,
            self.faults.as_mut(),
        );
        // Keep-alive runs from each replica's own execution end.
        for &(idx, start, t_rep) in &self.pending {
            if arena.invoke(idx, start, start + t_rep) {
                self.ledger.warm_hits += 1;
            } else {
                self.ledger.cold_starts += 1;
            }
        }
        // KV affinity: every instance a prefill layer touches holds a shard
        // of the request's KV state — decode steps are pinned to this set.
        if self.inflight[slot].decode_len > 0
            && self.inflight[slot].phase == RequestPhase::Prefill
        {
            for &(idx, _, _) in &self.pending {
                self.kv.pin(slot, idx);
            }
        }
        // Execution-granular cap: every replica execution of this layer
        // holds one account slot over its own busy window.
        if self.cap_exec {
            for &(_, start, t_rep) in &self.pending {
                cap.acquire_exec(self.tenant as usize, start + t_rep);
                q.push(start + t_rep, self.tenant, EXEC_RELEASE);
            }
        }
        self.total_cost += d.cost;
        let completion = d.service_finish.max(now) + (d.latency - d.max_service).max(0.0);
        let fl = &mut self.inflight[slot];
        fl.queue_delay = fl.queue_delay.max(d.queue_delay);
        fl.violated |= d.violated;
        if d.failed {
            // The failed attempt is fully billed (Lambda error semantics)
            // and its replicas occupied their instances; the layer retries
            // after exponential backoff — riding the same event heap — or,
            // with the budget exhausted, the platform hands the work to a
            // fresh healthy sandbox and serving continues degraded (the
            // request completes, but is not counted as goodput).
            let f = self.faults.as_mut().expect("failed dispatch only with faults on");
            f.retry_cost += d.cost;
            fl.clean = false;
            if fl.attempt < f.spec.max_retries {
                let delay = f.backoff(fl.attempt);
                fl.attempt += 1;
                f.retries += 1;
                debug_assert!(slot < THROTTLE_MARK as usize, "in-flight slot id overflow");
                q.push(d.service_finish.max(now) + delay, self.tenant, RETRY_MARK | slot as u32);
                return;
            }
        }
        fl.attempt = 0;
        fl.next_layer += 1;
        if fl.next_layer < self.num_layers {
            q.push(completion, self.tenant, slot as u32);
        } else {
            self.complete_pass(q, arena, slot, now, completion);
        }
    }

    /// A request's last layer completed at `finish`: classic one-pass
    /// requests finalize, a chat request advances its prefill/decode state
    /// machine instead — record the finished pass in the per-phase
    /// histograms, then chain the next decode step or finalize after the
    /// last output token.
    fn complete_pass(
        &mut self,
        q: &mut EventQueue,
        arena: &SlotArena,
        slot: usize,
        now: f64,
        finish: f64,
    ) {
        if self.inflight[slot].decode_len == 0 {
            self.finalize(q, slot, now, finish);
            return;
        }
        let dur = (finish - self.inflight[slot].pass_start).max(0.0);
        if self.inflight[slot].phase == RequestPhase::Prefill {
            self.prefill_hist.add(dur);
            if self.inflight[slot].reprefill {
                // The user was waiting on the next token either way, so a
                // KV re-prefill's time is charged against decode.
                self.inflight[slot].reprefill = false;
                self.decode_time += dur;
            } else {
                self.decode_inflight += 1;
            }
            self.inflight[slot].phase = RequestPhase::Decode;
            self.start_decode_step(q, arena, slot, finish);
            return;
        }
        // One decode step done: its tokens are emitted output.
        self.decode_hist.add(dur);
        self.decode_time += dur;
        let step = self.inflight[slot].decode_next;
        let toks = self.inflight[slot].decode_tokens[step];
        self.output_tokens += toks;
        self.tokens += toks;
        self.inflight[slot].decode_next += 1;
        if self.inflight[slot].decode_next >= self.inflight[slot].decode_len as usize {
            self.decode_inflight -= 1;
            self.finalize(q, slot, now, finish);
        } else {
            self.start_decode_step(q, arena, slot, finish);
        }
    }

    /// Launch decode step `decode_next` at `at`. If any instance pinned by
    /// the KV ledger went cold, the state was reaped with it: count the
    /// eviction, clear the pins, and run a billed re-prefill pass of the
    /// prompt (re-pinning as its layers dispatch) before decoding resumes.
    /// Otherwise the step's pre-routed counts load into the dispatch state.
    /// Either way the next pass rides the ordinary event heap.
    fn start_decode_step(&mut self, q: &mut EventQueue, arena: &SlotArena, slot: usize, at: f64) {
        if !self.kv.intact(slot, |idx| arena.is_warm_at(idx, at)) {
            self.kv.evictions += 1;
            self.kv.re_prefills += 1;
            self.kv.begin(slot);
            let fl = &mut self.inflight[slot];
            fl.phase = RequestPhase::Prefill;
            fl.reprefill = true;
            fl.counts.clone_from(&fl.prompt_counts);
            fl.next_layer = 0;
            fl.attempt = 0;
            fl.pass_start = at;
            debug_assert!(slot < THROTTLE_MARK as usize, "in-flight slot id overflow");
            q.push(at, self.tenant, slot as u32);
            return;
        }
        let fl = &mut self.inflight[slot];
        let step = fl.decode_next;
        fl.counts.clone_from(&fl.decode_counts[step]);
        fl.next_layer = 0;
        fl.attempt = 0;
        fl.pass_start = at;
        debug_assert!(slot < THROTTLE_MARK as usize, "in-flight slot id overflow");
        q.push(at, self.tenant, slot as u32);
    }

    /// Close out a finished request. `now` is the final layer's dispatch
    /// time — dispatches happen in nondecreasing virtual-time order, so
    /// stamping the cost timeline with it (all of the request's cost has
    /// accrued by then) keeps the timeline time-sorted, which
    /// `cost_at`-style consumers rely on; `finish` (the request completion,
    /// later than `now`) is what latency is measured to and when the
    /// account slot is released.
    fn finalize(&mut self, q: &mut EventQueue, slot: usize, now: f64, finish: f64) {
        if let Some(f) = self.faults.as_mut() {
            if self.inflight[slot].clean {
                f.good_requests += 1;
            }
        }
        let fl = &self.inflight[slot];
        let latency = finish - fl.arrival;
        let queue_delay = fl.queue_delay;
        let idx = fl.traffic_idx;
        let violated = fl.violated;
        self.metrics.record(idx, latency, queue_delay, now, self.total_cost);
        if self.slo_feedback {
            self.epoch_hist.add(latency);
        }
        if violated {
            self.violation_batches += 1;
        }
        self.last_finish = self.last_finish.max(finish);
        self.free.push(slot);
        if self.capped && !self.cap_exec {
            q.push(finish, self.tenant, REQ_RELEASE);
        }
    }

    /// Monolithic dispatch of a whole request at `ready` — the exact PR 2
    /// accounting (same peek order, same max/tail arithmetic, keep-alive
    /// extended to the request finish), over the arena. Returns the request
    /// finish time (the account slot's release point under a cap). The cost
    /// timeline is stamped at `stamp`: the arrival for immediate dispatches
    /// (matching the legacy loop bit-for-bit) and the grant time for
    /// cap-parked ones, so the timeline stays time-sorted.
    #[allow(clippy::too_many_arguments)]
    fn serve_monolithic(
        &mut self,
        q: &mut EventQueue,
        cap: &mut AccountCap,
        arena: &mut SlotArena,
        ri: usize,
        t: f64,
        ready: f64,
        counts: &[Vec<u64>],
        stamp: f64,
    ) -> f64 {
        self.pending.clear();
        let mut queue_delay = 0.0f64;
        let mut max_service = 0.0f64;
        let mut service_finish = ready;
        let mut latency_sum = 0.0f64;
        let mut cost_sum = 0.0f64;
        let mut violated = false;
        for l in 0..self.num_layers {
            let d = dispatch_layer(
                self.platform,
                self.spec,
                arena,
                &mut self.autoscaler,
                &mut self.plans[l],
                l,
                &counts[l],
                ready,
                &mut self.pending,
                &mut self.bufs,
                &mut self.ledger,
                // Fault injection requires the pipelined engine (validated),
                // so monolithic dispatch never adjudicates fates.
                None,
            );
            queue_delay = queue_delay.max(d.queue_delay);
            max_service = max_service.max(d.max_service);
            service_finish = service_finish.max(d.service_finish);
            latency_sum += d.latency;
            cost_sum += d.cost;
            violated |= d.violated;
        }
        // The request's non-replica latency tail rides on top of the last
        // service finish (identical arithmetic to the legacy loop).
        let tail = (latency_sum - max_service).max(0.0);
        let finish = service_finish + tail;
        for &(idx, start, _) in &self.pending {
            if arena.invoke(idx, start, finish) {
                self.ledger.warm_hits += 1;
            } else {
                self.ledger.cold_starts += 1;
            }
        }
        // Execution-granular cap: monolithic dispatch admits every layer's
        // replicas up front, so each execution's slot is held over its own
        // scheduled busy window exactly as in the pipelined path.
        if self.cap_exec {
            for &(_, start, t_rep) in &self.pending {
                cap.acquire_exec(self.tenant as usize, start + t_rep);
                q.push(start + t_rep, self.tenant, EXEC_RELEASE);
            }
        }
        self.total_cost += cost_sum;
        if violated {
            self.violation_batches += 1;
        }
        self.metrics.record(ri, finish - t, queue_delay, stamp, self.total_cost);
        if self.slo_feedback {
            self.epoch_hist.add(finish - t);
        }
        self.last_finish = self.last_finish.max(finish);
        finish
    }

    /// Assemble the lane's report and hand the run artifacts back to its
    /// simulator — the single-tenant engine epilogue, per lane. A hard
    /// assert in every build profile: a driver bug that dropped arrivals
    /// would otherwise silently truncate the trace and report rosy numbers.
    fn finish(&mut self, sim: &mut EpochSimulator<'a>, arena: &SlotArena) -> SimReport {
        assert_eq!(self.cursor, self.traffic.len(), "lane finished with pending arrivals");
        // Tail-epoch SLO flush: `boundary_due` never fires after the lane's
        // last arrival, so latencies accumulated since the final boundary
        // would otherwise be discarded — misses concentrated in the tail
        // epoch never adapted `eff_weight`. One last verdict here closes
        // that gap; there is no live arbitration ledger left to re-weight,
        // only the reported `effective_weight`.
        self.adapt_slo_weight();
        let requests = self.traffic.len() as u64;
        let mut report =
            self.metrics
                .build_report(requests, self.tokens, self.last_finish, self.total_cost);
        report.epochs = self.epochs;
        report.redeploys = self.redeploys;
        // Invocation/busy counters come from the lane's own attribution
        // ledger (identical to the arena's for a private pool; the
        // per-tenant split of it for a shared pool).
        report.warm_invocations = self.ledger.warm_hits;
        report.cold_invocations = self.ledger.cold_starts;
        report.violation_batches = self.violation_batches;
        report.queued_invocations = self.ledger.queued_jobs;
        report.busy_secs = self.ledger.busy_secs;
        // Utilization is a property of the instances themselves, so it
        // stays arena-derived — pool-wide under sharing, by design.
        report.max_utilization = arena.max_utilization(self.last_finish);
        report.scale_outs = self.autoscaler.scale_outs;
        report.scale_ins = self.autoscaler.scale_ins;
        // Autoregressive rollups: all zero without a chat workload, which
        // keeps the report equal to the pre-chat engine's field for field.
        report.output_tokens = self.output_tokens;
        report.kv_evictions = self.kv.evictions;
        report.re_prefills = self.kv.re_prefills;
        if self.prefill_hist.count() > 0 {
            report.prefill_p50 = self.prefill_hist.percentile(50.0);
            report.prefill_p95 = self.prefill_hist.percentile(95.0);
        }
        if self.decode_hist.count() > 0 {
            report.decode_p50 = self.decode_hist.percentile(50.0);
            report.decode_p95 = self.decode_hist.percentile(95.0);
        }
        if self.output_tokens > 0 {
            report.time_per_output_token = self.decode_time / self.output_tokens as f64;
        }
        if let Some(f) = &self.faults {
            report.failed_invocations = f.failed_invocations;
            report.retries = f.retries;
            report.hedged_invocations = f.hedged;
            report.hedge_wins = f.hedge_wins;
            report.throttled_requests = f.throttled;
            report.dropped_experts = f.dropped_experts;
            report.rerouted_tokens = f.rerouted_tokens;
            report.goodput_requests = f.good_requests;
            report.retry_cost = f.retry_cost;
        }
        sim.autoscale_events = self.autoscaler.events.clone();
        sim.last_policy =
            Some(std::mem::replace(&mut self.policy, DeploymentPolicy { layers: Vec::new() }));
        sim.last_latencies = std::mem::take(&mut self.metrics.latencies);
        report
    }
}

// ------------------------------------------------------------- run loop

/// Step kinds at equal virtual time: pending layer events dispatch first
/// (they were due at or before the boundary/arrival), then epoch
/// boundaries, then the arrival itself — the exact operation order of the
/// single-tenant loop, generalized to many lanes by ordering every step on
/// `(time, tenant, kind)`. Churn steps slot around them: onboarding runs
/// before any same-instant boundary or arrival of the tenant (its arrivals
/// start at or after `active.start`), offboarding after the last arrival
/// (it is only ever the lane's final candidate). The relative order of the
/// pre-churn kinds is unchanged, so runs without `active` windows execute
/// the identical step sequence.
const KIND_EVENT: u8 = 0;
const KIND_ONBOARD: u8 = 1;
const KIND_BOUNDARY: u8 = 2;
const KIND_ARRIVAL: u8 = 3;
const KIND_OFFBOARD: u8 = 4;

/// Which step-selection loop drives the lanes. All three execute the
/// identical operation sequence (pinned byte-identical on every committed
/// scenario): the heap is the sequential default, the scan is kept as the
/// cross-validation baseline, and the parallel driver shards lanes across
/// worker threads along coupling-group boundaries (see
/// [`Shard`] and the planner in `traffic::fleet`) while replaying exactly
/// the sequential step order within each shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetDriver {
    /// Candidate heap over `(time, tenant, kind)`: O(events · log tenants).
    Heap,
    /// The PR 5 per-step linear scan of every lane: O(tenants × events).
    Scan,
    /// Sharded lanes on `threads` worker threads, advanced in lock-step
    /// conservative time windows (fleet scenarios only; byte-identical to
    /// [`FleetDriver::Heap`] at every thread count).
    Parallel {
        /// Worker-thread count (>= 1). More threads than coupling groups
        /// leaves the surplus idle; `1` runs the sequential order on one
        /// worker and is the degenerate cross-check.
        threads: usize,
    },
}

/// One lane's next non-event step, ordered `(at, tenant, kind)` — the same
/// total step order the scan driver applies, so the two drivers pop
/// identical step sequences.
#[derive(Debug, Clone, Copy)]
struct Cand {
    at: f64,
    tenant: u32,
    kind: u8,
}

impl PartialEq for Cand {
    fn eq(&self, other: &Cand) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Cand {}

impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Cand) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Cand {
    fn cmp(&self, other: &Cand) -> Ordering {
        self.at
            .total_cmp(&other.at)
            .then(self.tenant.cmp(&other.tenant))
            .then(self.kind.cmp(&other.kind))
    }
}

impl EventLane<'_, '_> {
    /// The lane's next non-event candidate for the driver's step race.
    /// Depends only on `(cursor, next_epoch, onboarded, offboarded)`, all of
    /// which change exclusively in this lane's own candidate steps
    /// (`on_arrival`/`on_boundary`/`on_onboard`/`on_offboard`) — the
    /// invariant that lets the heap driver keep at most one live candidate
    /// per lane. A lane with an `active` window onboards first, then runs
    /// its boundary/arrival schedule, then offboards once; after that it is
    /// inert.
    fn candidate(&self) -> Option<Cand> {
        if !self.onboarded {
            let (start, _) = self.active.expect("un-onboarded lane has a window");
            return Some(Cand { at: start, tenant: self.tenant, kind: KIND_ONBOARD });
        }
        match (self.boundary_due(), self.next_arrival()) {
            (Some(b), _) => Some(Cand { at: b, tenant: self.tenant, kind: KIND_BOUNDARY }),
            (None, Some(a)) => Some(Cand { at: a, tenant: self.tenant, kind: KIND_ARRIVAL }),
            (None, None) => match self.active {
                Some((_, end)) if !self.offboarded => {
                    Some(Cand { at: end, tenant: self.tenant, kind: KIND_OFFBOARD })
                }
                _ => None,
            },
        }
    }
}

/// Close one batch window: dispatch the merged token counts as a single
/// invocation through the opener lane's machinery (its scratch plan,
/// autoscaler, and redeploy clamp), then split the outcome back across the
/// member requests — one cold/warm judgment per replica, one `t_rep`
/// priced from the combined token count, per-tenant cost and busy-seconds
/// split by token share. Integer invocation counters (warm/cold/queued and
/// any execution-granular cap slots) cannot be fractionally split and stay
/// with the opener, which is what "the joiner rides for free" means: the
/// join is recorded in the joiner's `batched` counter instead.
fn execute_batch<'a>(
    lanes: &mut [EventLane<'a, '_>],
    arenas: &mut [SlotArena],
    q: &mut EventQueue,
    cap: &mut AccountCap,
    pool: &mut BatchPool,
    id: usize,
    at: f64,
) {
    let b = pool.take(id);
    let l = b.layer;
    let oi = b.members[0].tenant as usize;
    let arena = &mut arenas[b.arena_id];
    let mut merged = LaneLedger::default();
    let (now, cost, completion, queue_delay, violated) = {
        let olane = &mut lanes[oi];
        let now = at.max(olane.blocked_until);
        olane.pending.clear();
        let d = dispatch_layer(
            olane.platform,
            olane.spec,
            arena,
            &mut olane.autoscaler,
            &mut olane.plans[l],
            l,
            &b.counts,
            now,
            &mut olane.pending,
            &mut olane.bufs,
            &mut merged,
            // Faults do not compose with cross-tenant batching (rejected at
            // fleet validation), so a merged dispatch never adjudicates.
            None,
        );
        for &(idx, start, t_rep) in &olane.pending {
            if arena.invoke(idx, start, start + t_rep) {
                olane.ledger.warm_hits += 1;
            } else {
                olane.ledger.cold_starts += 1;
            }
        }
        if olane.cap_exec {
            for &(_, start, t_rep) in &olane.pending {
                cap.acquire_exec(oi, start + t_rep);
                q.push(start + t_rep, olane.tenant, EXEC_RELEASE);
            }
        }
        olane.ledger.queued_jobs += merged.queued_jobs;
        let completion = d.service_finish.max(now) + (d.latency - d.max_service).max(0.0);
        (now, d.cost, completion, d.queue_delay, d.violated)
    };
    let total: u64 = b.members.iter().map(|m| m.tokens).sum();
    // The merged invocation's instances, captured for KV pinning of any
    // chat member still in its prefill pass. The buffer is taken out of the
    // opener lane (the member loop needs `lanes` mutable) and restored
    // after, so the steady state reallocates nothing.
    let mut pinned = std::mem::take(&mut lanes[oi].scratch.pinned);
    pinned.clear();
    pinned.extend(lanes[oi].pending.iter().map(|p| p.0));
    for m in &b.members {
        let share = if total > 0 {
            m.tokens as f64 / total as f64
        } else {
            1.0 / b.members.len() as f64
        };
        let lane = &mut lanes[m.tenant as usize];
        // Cost must land before a possible `finalize` below: the member's
        // cost-timeline sample reads the lane's running total.
        lane.total_cost += share * cost;
        lane.ledger.busy_secs += share * merged.busy_secs;
        let fl = &mut lane.inflight[m.slot];
        // The member waited from its own layer-ready time for the window to
        // close, on top of whatever replica queueing the merged dispatch
        // itself saw.
        fl.queue_delay = fl.queue_delay.max((now - m.ready).max(0.0) + queue_delay);
        fl.violated |= violated;
        if fl.decode_len > 0 && fl.phase == RequestPhase::Prefill {
            for &idx in &pinned {
                lane.kv.pin(m.slot, idx);
            }
        }
        let fl = &mut lane.inflight[m.slot];
        fl.next_layer += 1;
        if fl.next_layer < lane.num_layers {
            q.push(completion, m.tenant, m.slot as u32);
        } else {
            lane.complete_pass(q, arena, m.slot, now, completion);
        }
    }
    lanes[oi].scratch.pinned = pinned;
}

/// Execute one selected step — identical for both drivers, so they can
/// only differ in *selection*, which the identity tests pin to be the same.
fn run_step<'a>(
    sims: &mut [EpochSimulator<'a>],
    lanes: &mut [EventLane<'a, '_>],
    arenas: &mut [SlotArena],
    q: &mut EventQueue,
    cap: &mut AccountCap,
    batch: &mut BatchPool,
    tenant: u32,
    kind: u8,
) {
    let ti = tenant as usize;
    match kind {
        KIND_EVENT => {
            let ev = q.pop().expect("peeked event is still there");
            if ev.req == REQ_RELEASE || ev.req == EXEC_RELEASE {
                // A finished hold frees its account slot; the arbitration
                // policy picks who gets it.
                cap.release(ev.tenant as usize, ev.at);
                while let Some((wt, w)) = cap.grant() {
                    lanes[wt].cap_waits.push((ev.at - w.ready).max(0.0));
                    let aid = lanes[wt].arena_id;
                    lanes[wt].start_request(q, cap, &mut arenas[aid], batch, w.slot, ev.at);
                }
            } else if ev.req & BATCH_MARK != 0 {
                // A batch window closed: run the merged invocation and
                // resume every member request.
                execute_batch(lanes, arenas, q, cap, batch, (ev.req & !BATCH_MARK) as usize, ev.at);
            } else if ev.req & RETRY_MARK != 0 {
                // A failed layer's backoff expired: re-dispatch the layer.
                let aid = lanes[ti].arena_id;
                let slot = (ev.req & !RETRY_MARK) as usize;
                lanes[ti].dispatch(q, cap, &mut arenas[aid], batch, slot, ev.at);
            } else if ev.req & THROTTLE_MARK != 0 {
                // A throttled request's backoff expired: retry admission.
                let aid = lanes[ti].arena_id;
                let slot = (ev.req & !THROTTLE_MARK) as usize;
                lanes[ti].on_throttle_retry(q, cap, &mut arenas[aid], batch, slot, ev.at);
            } else {
                let aid = lanes[ti].arena_id;
                lanes[ti].dispatch(q, cap, &mut arenas[aid], batch, ev.req as usize, ev.at);
            }
        }
        KIND_ONBOARD => {
            let aid = lanes[ti].arena_id;
            lanes[ti].on_onboard(&mut arenas[aid]);
        }
        KIND_BOUNDARY => {
            let aid = lanes[ti].arena_id;
            lanes[ti].on_boundary(&mut sims[ti], &mut arenas[aid], cap);
        }
        KIND_OFFBOARD => {
            let aid = lanes[ti].arena_id;
            let at = lanes[ti].active.expect("offboarding lane has a window").1;
            lanes[ti].on_offboard(&mut arenas[aid], at);
        }
        _ => {
            let aid = lanes[ti].arena_id;
            lanes[ti].on_arrival(&mut sims[ti], q, cap, &mut arenas[aid], batch);
        }
    }
}

/// The next step of one (event-queue, candidate-heap) pair in the global
/// `(time, tenant, kind)` order, without consuming it — the single step
/// selection all drivers share. An event at the same `(time, tenant)`
/// always runs before a boundary/arrival: `KIND_EVENT` is the smallest
/// kind.
fn peek_step(q: &EventQueue, cands: &BinaryHeap<Reverse<Cand>>) -> Option<(f64, u32, u8)> {
    match (q.peek(), cands.peek().map(|r| r.0)) {
        (None, None) => None,
        (Some(ev), None) => Some((ev.at, ev.tenant, KIND_EVENT)),
        (None, Some(c)) => Some((c.at, c.tenant, c.kind)),
        (Some(ev), Some(c)) => {
            let ec = Cand { at: ev.at, tenant: ev.tenant, kind: KIND_EVENT };
            if c < ec {
                Some((c.at, c.tenant, c.kind))
            } else {
                Some((ev.at, ev.tenant, KIND_EVENT))
            }
        }
    }
}

/// Drive every lane to completion against one shared event queue and
/// account ledger, returning one report per lane (in lane order). With a
/// single uncapped lane this reproduces the pre-fleet single-tenant engine
/// operation-for-operation — the reproduction pin the fleet tests hold.
///
/// Step selection races the event-heap head against a candidate heap
/// holding each lane's next boundary/arrival, both ordered
/// `(time, tenant, kind)` with `kind[event] < kind[boundary] <
/// kind[arrival]` — O(log tenants) per step instead of the scan driver's
/// O(tenants). A lane's candidate is recomputed only after one of its own
/// candidate steps ran (event steps never move a lane's cursor or epoch
/// clock), so the heap never holds stale entries.
pub(crate) fn drive<'a>(
    sims: &mut [EpochSimulator<'a>],
    lanes: &mut [EventLane<'a, '_>],
    arenas: &mut [SlotArena],
    q: &mut EventQueue,
    cap: &mut AccountCap,
    batch: &mut BatchPool,
) -> Vec<SimReport> {
    debug_assert_eq!(sims.len(), lanes.len(), "one simulator per lane");
    let mut cands: BinaryHeap<Reverse<Cand>> = BinaryHeap::with_capacity(lanes.len());
    for lane in lanes.iter() {
        if let Some(c) = lane.candidate() {
            cands.push(Reverse(c));
        }
    }
    loop {
        let Some((_, tenant, kind)) = peek_step(q, &cands) else { break };
        if kind != KIND_EVENT {
            cands.pop();
        }
        run_step(sims, lanes, arenas, q, cap, batch, tenant, kind);
        if kind != KIND_EVENT {
            // Only the lane's own candidate step moved its cursor/epoch
            // clock; refresh its (single) heap entry.
            if let Some(c) = lanes[tenant as usize].candidate() {
                cands.push(Reverse(c));
            }
        }
    }
    lanes
        .iter_mut()
        .zip(sims.iter_mut())
        .map(|(lane, sim)| {
            let arena = &arenas[lane.arena_id];
            lane.finish(sim, arena)
        })
        .collect()
}

/// The PR 5 linear-scan driver, kept verbatim as the byte-identity
/// baseline for [`drive`]: every step re-scans all lanes for the minimal
/// `(time, tenant, kind)` candidate.
pub(crate) fn drive_scan<'a>(
    sims: &mut [EpochSimulator<'a>],
    lanes: &mut [EventLane<'a, '_>],
    arenas: &mut [SlotArena],
    q: &mut EventQueue,
    cap: &mut AccountCap,
    batch: &mut BatchPool,
) -> Vec<SimReport> {
    debug_assert_eq!(sims.len(), lanes.len(), "one simulator per lane");
    loop {
        // The globally next step: the heap head (already the minimal event
        // by `(at, tenant, seq)`) raced against each lane's due boundary
        // or next arrival.
        let mut best: Option<(f64, u32, u8)> = None;
        if let Some(ev) = q.peek() {
            best = Some((ev.at, ev.tenant, KIND_EVENT));
        }
        for lane in lanes.iter() {
            let cand = match lane.candidate() {
                Some(c) => (c.at, c.tenant, c.kind),
                None => continue,
            };
            let better = match best {
                None => true,
                Some(cur) => {
                    cand.0 < cur.0 || (cand.0 == cur.0 && (cand.1, cand.2) < (cur.1, cur.2))
                }
            };
            if better {
                best = Some(cand);
            }
        }
        let Some((_, tenant, kind)) = best else { break };
        run_step(sims, lanes, arenas, q, cap, batch, tenant, kind);
    }
    lanes
        .iter_mut()
        .zip(sims.iter_mut())
        .map(|(lane, sim)| {
            let arena = &arenas[lane.arena_id];
            lane.finish(sim, arena)
        })
        .collect()
}

// --------------------------------------------------------- parallel shards

/// One worker thread's self-contained slice of a fleet: its lanes with
/// their own event queue, candidate heap, arenas, cap ledger, and batch
/// pool. The shard planner in `traffic::fleet` only splits along *coupling
/// group* boundaries — tenants that can touch the same mutable state (a
/// shared `share_experts` arena, the batch windows keyed on it, or an
/// enabled account cap) are always co-located on one shard — so a shard's
/// step sequence is exactly the subsequence of the sequential run's steps
/// that belongs to its tenants, and the merged result is byte-identical to
/// [`FleetDriver::Heap`] by construction, independent of window width.
///
/// Tenant ids inside a shard are *local*: dense, assigned in ascending
/// global tenant order. That renumbering is order-isomorphic, so every
/// `(time, tenant, kind)` and `(time, tenant, seq)` comparison resolves
/// the same way it would have under the global ids.
pub(crate) struct Shard<'a, 't> {
    pub(crate) sims: Vec<EpochSimulator<'a>>,
    pub(crate) lanes: Vec<EventLane<'a, 't>>,
    pub(crate) arenas: Vec<SlotArena>,
    pub(crate) q: EventQueue,
    pub(crate) cap: AccountCap,
    pub(crate) batch: BatchPool,
    cands: BinaryHeap<Reverse<Cand>>,
}

// Shards move onto worker threads (`std::thread::scope`); the whole lane
// stack must stay `Send`. Compile-time check, no runtime cost.
const _: () = {
    fn assert_send<T: Send>() {}
    fn _check<'a, 't>() {
        assert_send::<Shard<'a, 't>>();
    }
};

impl<'a, 't> Shard<'a, 't> {
    pub(crate) fn new(
        sims: Vec<EpochSimulator<'a>>,
        lanes: Vec<EventLane<'a, 't>>,
        arenas: Vec<SlotArena>,
        cap: AccountCap,
        batch: BatchPool,
    ) -> Shard<'a, 't> {
        debug_assert_eq!(sims.len(), lanes.len(), "one simulator per lane");
        let mut cands = BinaryHeap::with_capacity(lanes.len());
        for lane in &lanes {
            if let Some(c) = lane.candidate() {
                cands.push(Reverse(c));
            }
        }
        Shard { sims, lanes, arenas, q: EventQueue::new(), cap, batch, cands }
    }

    /// Virtual time of the shard's next pending step (`None` = exhausted).
    pub(crate) fn next_time(&self) -> Option<f64> {
        peek_step(&self.q, &self.cands).map(|(at, _, _)| at)
    }

    /// Run every step strictly before `horizon` (the conservative-window
    /// barrier) in the same `(time, tenant, kind)` order [`drive`] uses,
    /// then report the next pending step time. `horizon = INFINITY` is
    /// exactly the sequential drive loop over this shard's lanes.
    pub(crate) fn drive_until(&mut self, horizon: f64) -> Option<f64> {
        loop {
            let (at, tenant, kind) = peek_step(&self.q, &self.cands)?;
            if at >= horizon {
                return Some(at);
            }
            if kind != KIND_EVENT {
                self.cands.pop();
            }
            run_step(
                &mut self.sims,
                &mut self.lanes,
                &mut self.arenas,
                &mut self.q,
                &mut self.cap,
                &mut self.batch,
                tenant,
                kind,
            );
            if kind != KIND_EVENT {
                // Only the lane's own candidate step moved its cursor or
                // epoch clock; refresh its (single) heap entry.
                if let Some(c) = self.lanes[tenant as usize].candidate() {
                    self.cands.push(Reverse(c));
                }
            }
        }
    }

    /// Finalize every lane (identical to the tail of [`drive`]) and return
    /// the per-lane reports in local lane order.
    pub(crate) fn finish(&mut self) -> Vec<SimReport> {
        self.lanes
            .iter_mut()
            .zip(self.sims.iter_mut())
            .map(|(lane, sim)| {
                let arena = &self.arenas[lane.arena_id];
                lane.finish(sim, arena)
            })
            .collect()
    }
}

impl EpochSimulator<'_> {
    /// The event-driven engine behind [`EpochSimulator::run_with_policy`]
    /// (see the module docs): one uncapped lane driven to completion.
    /// `pipeline: false` reproduces the legacy loop; `pipeline: true`
    /// chains each request's layers through the event heap.
    pub(crate) fn run_event(
        &mut self,
        policy: DeploymentPolicy,
        traffic: &[TimedBatch],
        pipeline: bool,
    ) -> SimReport {
        let mut q = EventQueue::new();
        let mut cap = AccountCap::unbounded(1);
        // Arena stride: the autoscaler caps at cfg.max_replicas, but a
        // hand-built initial policy may exceed it.
        let mut arena = SlotArena::new(
            self.spec,
            self.cfg.max_replicas.max(policy_stride(&policy)),
            self.cfg.keep_alive,
            self.cfg.concurrency,
        );
        if self.cfg.prewarm {
            arena.prewarm_plan(&policy.layers);
        }
        let mut arenas = [arena];
        // `decode_batch_window: 0` builds the inert pool — nothing ever
        // admits into it and the dispatch path is byte-identical.
        let mut batch = BatchPool::new(self.cfg.decode_batch_window);
        let mut lanes = [EventLane::new(self, policy, traffic, pipeline, LaneOpts::solo())];
        drive(std::slice::from_mut(self), &mut lanes, &mut arenas, &mut q, &mut cap, &mut batch)
            .pop()
            .expect("one lane yields one report")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelPreset;
    use crate::platform::WarmPool;
    use crate::util::check::{ensure, forall_default};

    #[test]
    fn arena_index_is_dense_and_unique() {
        let spec = ModelPreset::TinyMoe.spec();
        let a = SlotArena::new(&spec, 3, 10.0, Some(1));
        let mut seen = std::collections::HashSet::new();
        for l in 0..spec.num_moe_layers() {
            for e in 0..spec.experts_at(l) {
                for g in 0..3 {
                    assert!(seen.insert(a.index(l, e, g)), "index collision at ({l},{e},{g})");
                }
            }
        }
        let n = seen.len();
        assert!(seen.iter().all(|&i| i < n), "indices not dense");
    }

    /// The arena must reproduce `WarmPool` exactly: same admission starts,
    /// same warm/cold judgments, same ledgers — on random job streams over
    /// random keys, with prewarm/evict/reset events mixed in.
    #[test]
    fn prop_arena_matches_warm_pool() {
        let spec = ModelPreset::TinyMoe.spec();
        forall_default(
            |rng| {
                let conc = match rng.index(3) {
                    0 => None,
                    1 => Some(1),
                    _ => Some(2),
                };
                let keep_alive = rng.range_f64(0.0, 20.0);
                let n = 1 + rng.index(60);
                let mut t = 0.0;
                let jobs: Vec<(usize, usize, usize, f64, f64, u8)> = (0..n)
                    .map(|_| {
                        t += rng.range_f64(0.0, 1.5);
                        (
                            rng.index(2),
                            rng.index(4),
                            rng.index(2),
                            t,
                            rng.range_f64(0.0, 4.0),
                            rng.index(12) as u8,
                        )
                    })
                    .collect();
                (conc, keep_alive, jobs)
            },
            |(conc, keep_alive, jobs)| {
                let mut pool = WarmPool::with_concurrency(*keep_alive, *conc);
                let mut arena = SlotArena::new(&spec, 2, *keep_alive, *conc);
                for &(l, e, g, at, service, action) in jobs {
                    let key = (l, e, g);
                    let idx = arena.index(l, e, g);
                    match action {
                        0 => {
                            InstancePool::prewarm(&mut pool, key);
                            InstancePool::prewarm(&mut arena, key);
                        }
                        1 => {
                            InstancePool::evict(&mut pool, key);
                            InstancePool::evict(&mut arena, key);
                        }
                        2 => {
                            InstancePool::reset(&mut pool);
                            InstancePool::reset(&mut arena);
                        }
                        _ => {
                            let peek_p = pool.earliest_start(key, at);
                            let peek_a = arena.earliest_start(idx, at);
                            ensure(peek_p == peek_a, format!("peek {peek_p} vs {peek_a}"))?;
                            let s_p = pool.admit(key, at, service);
                            let s_a = arena.admit(idx, at, service);
                            ensure(s_p == s_a, format!("start {s_p} vs {s_a}"))?;
                            let end = s_p + service;
                            let w_p = pool.invoke(key, s_p, end);
                            let w_a = arena.invoke(idx, s_a, end);
                            ensure(w_p == w_a, format!("warmness {w_p} vs {w_a}"))?;
                        }
                    }
                    ensure(
                        pool.idle_at(key, at) == InstancePool::idle_at(&arena, key, at),
                        "idle_at diverged",
                    )?;
                }
                ensure(pool.warm_hits == arena.warm_hits, "warm hits diverged")?;
                ensure(pool.cold_starts == arena.cold_starts, "cold starts diverged")?;
                ensure(pool.queued_jobs == arena.queued_jobs, "queued jobs diverged")?;
                ensure(
                    pool.total_queue_wait == arena.total_queue_wait,
                    "queue wait diverged",
                )?;
                ensure(
                    pool.total_busy_secs() == arena.total_busy_secs(),
                    "busy ledger diverged",
                )
            },
        );
    }

    #[test]
    fn event_order_is_time_then_tenant_then_seq() {
        let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
        heap.push(Reverse(Ev { at: 2.0, tenant: 0, seq: 0, req: 0 }));
        heap.push(Reverse(Ev { at: 1.0, tenant: 1, seq: 1, req: 1 }));
        heap.push(Reverse(Ev { at: 1.0, tenant: 0, seq: 3, req: 2 }));
        heap.push(Reverse(Ev { at: 1.0, tenant: 0, seq: 2, req: 3 }));
        let order: Vec<u32> = std::iter::from_fn(|| heap.pop().map(|Reverse(e)| e.req)).collect();
        // Time first, then tenant index, then FIFO within the tenant.
        assert_eq!(order, vec![3, 2, 1, 0]);
    }

    #[test]
    fn account_cap_fifo_and_release_grant_cycle() {
        let mut cap = AccountCap::new(
            Some(2),
            FleetArbitration::Fifo,
            CapGranularity::Request,
            &[1.0, 1.0],
        );
        assert!(cap.enabled());
        assert!(cap.try_acquire(0));
        assert!(cap.try_acquire(1));
        assert_eq!(cap.in_use(), 2);
        // Full: arrivals park instead of acquiring.
        assert!(!cap.try_acquire(0));
        cap.park(0, 7, 3.0);
        cap.park(1, 8, 3.5);
        // Nothing free yet: no grant.
        assert!(cap.grant().is_none());
        // One release → the earliest-parked waiter (tenant 0) is granted.
        cap.release(1, 4.0);
        let (t, w) = cap.grant().expect("a slot freed with waiters parked");
        assert_eq!((t, w.slot, w.ready), (0, 7, 3.0));
        assert!(cap.grant().is_none(), "ledger full again");
        cap.release(0, 5.0);
        let (t, w) = cap.grant().expect("second waiter granted");
        assert_eq!((t, w.slot), (1, 8));
        assert_eq!(cap.in_use(), 2);
    }

    #[test]
    fn account_cap_weighted_fair_prefers_underweighted_tenant() {
        let mut cap = AccountCap::new(
            Some(3),
            FleetArbitration::WeightedFair,
            CapGranularity::Request,
            &[2.0, 1.0],
        );
        // Tenant 0 holds two slots, tenant 1 one: in_use/weight = 1.0 each.
        assert!(cap.try_acquire(0));
        assert!(cap.try_acquire(0));
        assert!(cap.try_acquire(1));
        // Both tenants have waiters.
        cap.park(1, 5, 1.0);
        cap.park(0, 6, 2.0);
        cap.release(1, 2.0);
        // Keys: tenant 0 = 2/2 = 1.0, tenant 1 = 0/1 = 0.0 → tenant 1 wins.
        let (t, _) = cap.grant().expect("grant");
        assert_eq!(t, 1);
        // Tenant 1 parks again, tenant 0 releases one slot.
        cap.park(1, 9, 3.0);
        cap.release(0, 3.0);
        // Keys: tenant 0 = 1/2 = 0.5, tenant 1 = 1/1 = 1.0 → tenant 0 wins
        // even though tenant 1's waiter parked first (weighted, not FIFO).
        let (t, w) = cap.grant().expect("grant");
        assert_eq!((t, w.slot), (0, 6));
    }

    #[test]
    fn weighted_fair_breaks_ties_by_earliest_park_not_tenant_index() {
        // Two perfectly symmetric tenants: equal weights, equal in-use.
        // The higher-index tenant parked first, so it must win the tied
        // grant — the pre-fix behavior handed every tie to tenant 0,
        // structurally starving tenant 1 under symmetric load.
        let mut cap = AccountCap::new(
            Some(2),
            FleetArbitration::WeightedFair,
            CapGranularity::Request,
            &[1.0, 1.0],
        );
        assert!(cap.try_acquire(0));
        assert!(cap.try_acquire(1));
        cap.park(1, 11, 1.0); // tenant 1 parks first...
        cap.park(0, 10, 2.0); // ...then tenant 0
        cap.release(0, 3.0);
        cap.release(1, 3.5);
        // Dead tie (in_use_by = [0, 0], equal weights): the earliest park
        // seq — tenant 1's — must win, not the lower index.
        let (t, w) = cap.grant().expect("grant");
        assert_eq!((t, w.slot), (1, 11), "earliest park seq wins the tie");
        let (t, w) = cap.grant().expect("grant");
        assert_eq!((t, w.slot), (0, 10));
        // Mirror image: tenant 0 parks first this time and wins the same
        // dead tie — the break is FIFO, not index order in either direction.
        cap.park(0, 20, 4.0);
        cap.park(1, 21, 5.0);
        cap.release(0, 6.0);
        cap.release(1, 6.5);
        let (t, w) = cap.grant().expect("grant");
        assert_eq!((t, w.slot), (0, 20));
        let (t, w) = cap.grant().expect("grant");
        assert_eq!((t, w.slot), (1, 21));
    }

    #[test]
    fn execution_granular_cap_charges_per_execution_with_conserved_ledger() {
        let mut cap = AccountCap::new(
            Some(4),
            FleetArbitration::Fifo,
            CapGranularity::Execution,
            &[1.0],
        );
        cap.enable_audit();
        assert!(cap.execution_granular());
        // Admission is a pure headroom check: nothing is charged yet.
        assert!(cap.try_acquire(0));
        assert_eq!(cap.in_use(), 0);
        // The request fans out to 3 replica executions.
        cap.acquire_exec(0, 2.0);
        cap.acquire_exec(0, 3.0);
        cap.acquire_exec(0, 2.5);
        assert_eq!(cap.in_use(), 3);
        // A second request sees 1 free slot and is admitted; its single
        // execution fills the ledger, so a third request parks.
        assert!(cap.try_acquire(0));
        cap.acquire_exec(0, 4.0);
        assert!(!cap.try_acquire(0));
        cap.park(0, 7, 1.5);
        // Executions release individually, in end order.
        cap.release(0, 2.0);
        let (t, w) = cap.grant().expect("headroom frees the parked request");
        assert_eq!((t, w.slot), (0, 7));
        // The grant itself charged nothing (the request's executions will).
        assert_eq!(cap.in_use(), 3);
        cap.release(0, 2.5);
        cap.release(0, 3.0);
        cap.release(0, 4.0);
        assert_eq!(cap.in_use(), 0);
        // Replay the audit: the running count must equal the recorded
        // in_use at every transition and close at zero.
        let log = cap.take_audit();
        assert_eq!(log.len(), 8, "4 acquires + 4 releases");
        let mut live = 0usize;
        for entry in &log {
            match *entry {
                CapAudit::Acquire { in_use, .. } => {
                    live += 1;
                    assert_eq!(live, in_use);
                }
                CapAudit::Release { in_use, .. } => {
                    live -= 1;
                    assert_eq!(live, in_use);
                }
            }
        }
        assert_eq!(live, 0);
    }

    #[test]
    fn refcounted_arena_survives_eviction_until_last_owner_leaves() {
        let spec = ModelPreset::TinyMoe.spec();
        let mut a = SlotArena::new(&spec, 2, 100.0, Some(1));
        a.enable_refcounts();
        let key = (0, 0, 0);
        InstancePool::retain(&mut a, key);
        InstancePool::retain(&mut a, key);
        let idx = a.index(0, 0, 0);
        a.admit(idx, 0.0, 5.0);
        a.invoke(idx, 0.0, 5.0);
        assert!(a.is_warm_at(idx, 50.0));
        // First eviction: the co-owner keeps the environment warm.
        InstancePool::evict(&mut a, key);
        assert!(a.is_warm_at(idx, 50.0), "shared instance must survive one owner's scale-in");
        // Last owner leaves: now it really tears down.
        InstancePool::evict(&mut a, key);
        assert!(!a.is_warm_at(idx, 50.0));
        // Without refcounts the old semantics are untouched.
        let mut b = SlotArena::new(&spec, 2, 100.0, Some(1));
        let bidx = b.index(0, 0, 0);
        b.invoke(bidx, 0.0, 5.0);
        InstancePool::evict(&mut b, key);
        assert!(!b.is_warm_at(bidx, 50.0));
    }

    #[test]
    fn unbounded_cap_is_inert() {
        let mut cap = AccountCap::unbounded(3);
        assert!(!cap.enabled());
        for tenant in 0..3 {
            for _ in 0..100 {
                assert!(cap.try_acquire(tenant));
            }
        }
        assert_eq!(cap.in_use(), 0, "no bookkeeping without a cap");
        assert!(cap.grant().is_none());
    }

    /// Decode-step routing must land in the predictor's dataset table —
    /// the signal `reoptimize` re-solves over — so two runs identical up
    /// to decode length must differ in absorbed mass. Guards the
    /// `stage_chat` absorption: decode steps used to route through the
    /// memo without ever updating what the reoptimizer watches.
    #[test]
    fn decode_steps_feed_the_predictor_dataset() {
        use crate::traffic::arrivals::ArrivalProcess;
        use crate::traffic::config::TrafficConfig;
        use crate::traffic::scenario::{Baseline, Scenario, TrafficSource};
        use crate::traffic::workload::DecodeLengthModel;

        let absorbed_mass = |steps: u32| -> f64 {
            let s = Scenario::builder("decode-absorb")
                .model_preset(ModelPreset::TinyMoe)
                .seed(11)
                .profile(2, 128)
                .traffic(TrafficSource::Chat {
                    process: ArrivalProcess::Poisson { rate: 2.0 },
                    duration: None,
                    requests: Some(6),
                    prompt_tokens: 48,
                    decode: DecodeLengthModel::Fixed { steps },
                    decode_tokens: 4,
                })
                .config(TrafficConfig {
                    // Absorption is gated on `reoptimize`; an infinite
                    // epoch means no boundary ever fires, so the run
                    // stays closed-form (no wall-clock-limited solve).
                    reoptimize: true,
                    epoch_secs: f64::INFINITY,
                    ..TrafficConfig::default()
                })
                .baseline(Baseline::Ours)
                .build()
                .expect("chat scenario is valid by construction");
            let scn = s.materialize().expect("chat scenario materializes");
            let mut sim = EpochSimulator::new(
                &scn.platform,
                &scn.spec,
                &scn.gate,
                scn.predictor(),
                s.cfg.clone(),
            );
            sim.chat = scn.chat.as_ref();
            // Closed-form LambdaML deployment: deterministic, solver-free.
            let policy = scn.lambdaml(&s.cfg);
            sim.run_with_policy(policy, &scn.traffic);
            sim.predictor.table.entries().iter().map(|e| e.3).sum()
        };

        let with_decode = absorbed_mass(5);
        let without = absorbed_mass(0);
        assert!(without > 0.0, "prefill passes absorb on their own");
        assert!(
            with_decode > without,
            "decode routing must add dataset mass: {with_decode} vs {without} without decode"
        );
    }
}
