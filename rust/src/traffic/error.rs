//! Typed errors for the scenario front door.
//!
//! The traffic subsystem's library surface reports failures through
//! [`ScenarioError`] instead of `anyhow` — callers can match on the variant
//! (the strict-parsing tests do), and binaries still get ergonomic `?`
//! propagation because the enum implements [`std::error::Error`] (the
//! vendored `anyhow` shim converts any such error).
//!
//! Parsing is *strict*: unknown fields in any scenario-owned JSON object are
//! rejected ([`ScenarioError::UnknownField`]) so a typo in a committed
//! scenario file fails loudly instead of silently falling back to a default.

use crate::util::json::Json;
use std::fmt;

/// Everything that can go wrong building, parsing or running a scenario.
#[derive(Debug, Clone, PartialEq)]
pub enum ScenarioError {
    /// Reading or writing a scenario/trace file failed.
    Io { path: String, detail: String },
    /// The file was not valid JSON.
    Parse { detail: String },
    /// A required field was absent.
    MissingField { section: String, field: String },
    /// Strict parsing: a field not in the schema (typo guard).
    UnknownField { section: String, field: String },
    /// A field parsed but its value is out of range or of the wrong type.
    Invalid { field: String, reason: String },
    /// A name did not resolve (model preset, corpus, baseline, ...).
    UnknownName {
        what: &'static str,
        name: String,
        known: &'static str,
    },
    /// The traffic source materialized zero requests.
    EmptyTraffic,
}

impl fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ScenarioError::Io { path, detail } => {
                write!(f, "scenario i/o error at {path}: {detail}")
            }
            ScenarioError::Parse { detail } => write!(f, "scenario parse error: {detail}"),
            ScenarioError::MissingField { section, field } => {
                write!(f, "scenario: missing required field '{field}' in {section}")
            }
            ScenarioError::UnknownField { section, field } => {
                write!(f, "scenario: unknown field '{field}' in {section}")
            }
            ScenarioError::Invalid { field, reason } => {
                write!(f, "scenario: invalid value for '{field}': {reason}")
            }
            ScenarioError::UnknownName { what, name, known } => {
                write!(f, "scenario: unknown {what} '{name}' (known: {known})")
            }
            ScenarioError::EmptyTraffic => {
                write!(f, "scenario: traffic source materialized zero requests")
            }
        }
    }
}

impl std::error::Error for ScenarioError {}

impl ScenarioError {
    pub(crate) fn invalid(field: impl Into<String>, reason: impl Into<String>) -> ScenarioError {
        ScenarioError::Invalid {
            field: field.into(),
            reason: reason.into(),
        }
    }

    pub(crate) fn missing(section: impl Into<String>, field: impl Into<String>) -> ScenarioError {
        ScenarioError::MissingField {
            section: section.into(),
            field: field.into(),
        }
    }
}

// ---------------------------------------------------- strict JSON helpers

/// Read and parse a JSON file with the two failure modes kept apart:
/// unreadable file → [`ScenarioError::Io`]; malformed JSON →
/// [`ScenarioError::Parse`].
pub(crate) fn read_json(path: &std::path::Path) -> Result<Json, ScenarioError> {
    let text = std::fs::read_to_string(path).map_err(|e| ScenarioError::Io {
        path: path.display().to_string(),
        detail: e.to_string(),
    })?;
    Json::parse(&text).map_err(|e| ScenarioError::Parse {
        detail: format!("{}: {e}", path.display()),
    })
}

/// The object under `j`, or a typed error naming `section`.
pub(crate) fn as_obj<'a>(
    j: &'a Json,
    section: &str,
) -> Result<&'a std::collections::BTreeMap<String, Json>, ScenarioError> {
    j.as_obj()
        .ok_or_else(|| ScenarioError::invalid(section, "expected a JSON object"))
}

/// Strict parsing: every key of the `section` object must be in `allowed`.
pub(crate) fn check_keys(j: &Json, section: &str, allowed: &[&str]) -> Result<(), ScenarioError> {
    for key in as_obj(j, section)?.keys() {
        if !allowed.contains(&key.as_str()) {
            return Err(ScenarioError::UnknownField {
                section: section.to_string(),
                field: key.clone(),
            });
        }
    }
    Ok(())
}

/// Optional finite number with a default; present-but-not-a-number is an
/// error (strict), as is a non-finite value.
pub(crate) fn opt_f64(
    j: &Json,
    section: &str,
    key: &str,
    default: f64,
) -> Result<f64, ScenarioError> {
    match j.get(key) {
        None => Ok(default),
        Some(Json::Num(x)) if x.is_finite() => Ok(*x),
        Some(other) => Err(ScenarioError::invalid(
            format!("{section}.{key}"),
            format!("expected a finite number, got {other:?}"),
        )),
    }
}

/// Optional duration with a default: JSON `null` encodes `f64::INFINITY`
/// (JSON has no Inf literal; the serializer emits `null` for it).
pub(crate) fn opt_duration(
    j: &Json,
    section: &str,
    key: &str,
    default: f64,
) -> Result<f64, ScenarioError> {
    match j.get(key) {
        Some(Json::Null) => Ok(f64::INFINITY),
        // In-memory values that never went through text keep the raw Inf.
        Some(Json::Num(x)) if x.is_infinite() && *x > 0.0 => Ok(f64::INFINITY),
        _ => opt_f64(j, section, key, default),
    }
}

/// Optional non-negative integer with a default (strict about type and about
/// the 2^53 JSON-number precision limit, like the trace seeds).
pub(crate) fn opt_u64(
    j: &Json,
    section: &str,
    key: &str,
    default: u64,
) -> Result<u64, ScenarioError> {
    match j.get(key) {
        None => Ok(default),
        Some(Json::Num(x)) if *x >= 0.0 && x.fract() == 0.0 && *x < (1u64 << 53) as f64 => {
            Ok(*x as u64)
        }
        Some(other) => Err(ScenarioError::invalid(
            format!("{section}.{key}"),
            format!("expected an integer in [0, 2^53), got {other:?}"),
        )),
    }
}

pub(crate) fn opt_usize(
    j: &Json,
    section: &str,
    key: &str,
    default: usize,
) -> Result<usize, ScenarioError> {
    opt_u64(j, section, key, default as u64).map(|v| v as usize)
}

pub(crate) fn opt_bool(
    j: &Json,
    section: &str,
    key: &str,
    default: bool,
) -> Result<bool, ScenarioError> {
    match j.get(key) {
        None => Ok(default),
        Some(Json::Bool(b)) => Ok(*b),
        Some(other) => Err(ScenarioError::invalid(
            format!("{section}.{key}"),
            format!("expected a bool, got {other:?}"),
        )),
    }
}

/// Required finite number.
pub(crate) fn req_f64(j: &Json, section: &str, key: &str) -> Result<f64, ScenarioError> {
    if j.get(key).is_none() {
        return Err(ScenarioError::missing(section, key));
    }
    opt_f64(j, section, key, 0.0)
}

/// Required string.
pub(crate) fn req_str<'a>(j: &'a Json, section: &str, key: &str) -> Result<&'a str, ScenarioError> {
    match j.get(key) {
        None => Err(ScenarioError::missing(section, key)),
        Some(Json::Str(s)) => Ok(s),
        Some(other) => Err(ScenarioError::invalid(
            format!("{section}.{key}"),
            format!("expected a string, got {other:?}"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_actionable() {
        let e = ScenarioError::UnknownField {
            section: "config".into(),
            field: "epoch_sec".into(),
        };
        let s = e.to_string();
        assert!(s.contains("epoch_sec") && s.contains("config"), "{s}");
        let e = ScenarioError::UnknownName {
            what: "baseline",
            name: "cpu".into(),
            known: "ours | static | lambdaml | cpu-cluster",
        };
        assert!(e.to_string().contains("cpu-cluster"));
    }

    #[test]
    fn strict_helpers_reject_bad_types() {
        let j = Json::parse(r#"{"a": 1.5, "b": "x", "c": null, "d": true, "e": -1}"#).unwrap();
        assert_eq!(opt_f64(&j, "t", "a", 0.0).unwrap(), 1.5);
        assert!(opt_f64(&j, "t", "b", 0.0).is_err());
        assert_eq!(opt_f64(&j, "t", "missing", 7.0).unwrap(), 7.0);
        assert_eq!(opt_duration(&j, "t", "c", 0.0).unwrap(), f64::INFINITY);
        assert!(opt_bool(&j, "t", "d", false).unwrap());
        assert!(opt_u64(&j, "t", "a", 0).is_err(), "fractional int rejected");
        assert!(opt_u64(&j, "t", "e", 0).is_err(), "negative int rejected");
        assert!(matches!(
            req_str(&j, "t", "nope"),
            Err(ScenarioError::MissingField { .. })
        ));
        assert!(check_keys(&j, "t", &["a", "b", "c", "d", "e"]).is_ok());
        assert!(matches!(
            check_keys(&j, "t", &["a"]),
            Err(ScenarioError::UnknownField { .. })
        ));
    }
}
