//! Epoch-level replica autoscaling between full ODS redeploys.
//!
//! A full re-deployment (new memory sizes, communication methods, β) costs
//! the ≥60 s gap of §II Challenge 1, so it is reserved for genuine
//! popularity drift. Between redeploys the serving layer can still adjust
//! the *replica count* of each expert cheaply — the knob Remoe
//! (arXiv 2512.18674) and FaaSMoE (arXiv 2604.26881) show dominates tail
//! latency and cost under bursty serverless traffic:
//!
//!  - **scale out** launches fresh instances; they join the pool cold, so
//!    their first invocation pays the cold start through the existing
//!    lifecycle accounting (no separate billing path);
//!  - **scale in** stops routing to the highest-indexed replicas; only
//!    instances whose FIFO queue has drained are reaped (busy ones finish
//!    their backlog first), and reaping evicts the instance's warm
//!    environment — scaling the same index back out later starts cold
//!    again.
//!
//! Policies are pluggable via [`AutoscalePolicy`]; decisions are evaluated
//! once per epoch from the per-expert stats of the epoch that just ended.
//!
//! Autoscaler state is strictly per-lane: each tenant's [`Autoscaler`]
//! reads only that tenant's epoch stats and instance pool, never another
//! tenant's. The parallel fleet driver
//! ([`super::sim::FleetDriver::Parallel`]) relies on this — lanes shard
//! across worker threads with their autoscalers, and no cross-shard
//! exchange is needed for scaling decisions.

use super::error::{self, ScenarioError};
use crate::deploy::DeploymentPolicy;
use crate::platform::InstancePool;
use crate::util::json::Json;
use std::collections::HashMap;

/// Pluggable replica-scaling policy evaluated at epoch boundaries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AutoscalePolicy {
    /// Fixed replica counts (the PR 1 behavior).
    Off,
    /// Keep per-expert utilization (busy seconds per replica per epoch
    /// second) near `target`: scale out proportionally when above it, scale
    /// in one replica per epoch when the shrunk pool would stay below it.
    TargetUtilization { target: f64 },
    /// Scale out one replica when the mean per-invocation FIFO wait over the
    /// last epoch exceeds `max_wait` seconds; scale in one when the epoch
    /// saw no queueing and utilization stayed below `idle_below`. Requires
    /// bounded concurrency: on an unbounded pool there is no FIFO signal, so
    /// the policy holds replica counts rather than ratcheting them down.
    QueueDepth { max_wait: f64, idle_below: f64 },
}

impl AutoscalePolicy {
    /// Scenario-file encoding: a tagged object, e.g.
    /// `{"kind": "queue-depth", "max_wait": 5.0, "idle_below": 0.2}`.
    pub fn to_json(&self) -> Json {
        match *self {
            AutoscalePolicy::Off => Json::from_pairs(vec![("kind", Json::str("off"))]),
            AutoscalePolicy::TargetUtilization { target } => Json::from_pairs(vec![
                ("kind", Json::str("target-utilization")),
                ("target", Json::num(target)),
            ]),
            AutoscalePolicy::QueueDepth { max_wait, idle_below } => Json::from_pairs(vec![
                ("kind", Json::str("queue-depth")),
                ("max_wait", Json::num(max_wait)),
                ("idle_below", Json::num(idle_below)),
            ]),
        }
    }

    /// Strict inverse of [`AutoscalePolicy::to_json`].
    pub fn from_json(j: &Json) -> Result<AutoscalePolicy, ScenarioError> {
        const SECTION: &str = "config.autoscale";
        let policy = match error::req_str(j, SECTION, "kind")? {
            "off" => {
                error::check_keys(j, SECTION, &["kind"])?;
                AutoscalePolicy::Off
            }
            "target-utilization" => {
                error::check_keys(j, SECTION, &["kind", "target"])?;
                AutoscalePolicy::TargetUtilization {
                    target: error::req_f64(j, SECTION, "target")?,
                }
            }
            "queue-depth" => {
                error::check_keys(j, SECTION, &["kind", "max_wait", "idle_below"])?;
                AutoscalePolicy::QueueDepth {
                    max_wait: error::req_f64(j, SECTION, "max_wait")?,
                    idle_below: error::req_f64(j, SECTION, "idle_below")?,
                }
            }
            other => {
                return Err(ScenarioError::UnknownName {
                    what: "autoscale policy",
                    name: other.to_string(),
                    known: "off | target-utilization | queue-depth",
                })
            }
        };
        policy.check()?;
        Ok(policy)
    }

    /// CLI shorthand shared by the examples:
    /// `off | util:<target> | queue:<max_wait_secs>`.
    pub fn parse_cli(spec: &str) -> Result<AutoscalePolicy, ScenarioError> {
        let policy = if spec == "off" {
            AutoscalePolicy::Off
        } else if let Some(target) = spec.strip_prefix("util:") {
            AutoscalePolicy::TargetUtilization {
                target: target.parse().map_err(|_| {
                    ScenarioError::invalid("autoscale", format!("bad utilization '{target}'"))
                })?,
            }
        } else if let Some(max_wait) = spec.strip_prefix("queue:") {
            AutoscalePolicy::QueueDepth {
                max_wait: max_wait.parse().map_err(|_| {
                    ScenarioError::invalid("autoscale", format!("bad max wait '{max_wait}'"))
                })?,
                idle_below: 0.2,
            }
        } else {
            return Err(ScenarioError::UnknownName {
                what: "autoscale policy",
                name: spec.to_string(),
                known: "off | util:<target> | queue:<max_wait_secs>",
            });
        };
        policy.check()?;
        Ok(policy)
    }

    /// Parameter validation as a typed error (scenario builder surface).
    pub fn check(&self) -> Result<(), ScenarioError> {
        match *self {
            AutoscalePolicy::Off => Ok(()),
            AutoscalePolicy::TargetUtilization { target } => {
                if target > 0.0 && target <= 1.0 {
                    Ok(())
                } else {
                    Err(ScenarioError::invalid(
                        "config.autoscale.target",
                        format!("utilization target must be in (0, 1], got {target}"),
                    ))
                }
            }
            AutoscalePolicy::QueueDepth { max_wait, idle_below } => {
                if !(max_wait >= 0.0 && max_wait.is_finite()) {
                    return Err(ScenarioError::invalid(
                        "config.autoscale.max_wait",
                        format!("must be finite and >= 0, got {max_wait}"),
                    ));
                }
                if (0.0..=1.0).contains(&idle_below) {
                    Ok(())
                } else {
                    Err(ScenarioError::invalid(
                        "config.autoscale.idle_below",
                        format!("must be in [0, 1], got {idle_below}"),
                    ))
                }
            }
        }
    }
}

/// How freed account-cap slots are granted to waiting tenants in a
/// multi-tenant fleet (`traffic::fleet`). Per-tenant replica autoscaling
/// (the policies above) keeps running unchanged *under* this arbitration:
/// arbitration decides which tenant's request gets an account slot, the
/// tenant's own [`AutoscalePolicy`] decides how many replicas serve it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetArbitration {
    /// Strict arrival order across the whole fleet: the request parked
    /// earliest (ties by tenant index) gets the next freed slot.
    Fifo,
    /// Weighted-fair: the waiting tenant with the least account capacity in
    /// use relative to its configured weight gets the next freed slot (ties
    /// by earliest park — fleet-wide FIFO among the tied tenants; FIFO
    /// within a tenant). A bursting tenant can borrow the whole idle cap,
    /// but never starves a lighter tenant past its weighted share.
    WeightedFair,
}

/// What one account-cap ledger slot stands for (`traffic::fleet`'s
/// `cap_granularity` knob).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CapGranularity {
    /// One slot per concurrent replica *execution*, held over that
    /// execution's own busy window — AWS Lambda's accounting (the account
    /// concurrency limit counts executions, so a request fanning out to 8
    /// expert replicas occupies 8 slots). The default.
    #[default]
    Execution,
    /// One slot per in-flight request, from first layer dispatch to request
    /// completion — the pre-fix accounting, kept for the PR 5
    /// shared-beats-isolated pin and for comparison studies.
    Request,
}

impl CapGranularity {
    pub fn name(&self) -> &'static str {
        match self {
            CapGranularity::Execution => "execution",
            CapGranularity::Request => "request",
        }
    }

    pub fn from_name(s: &str) -> Result<CapGranularity, ScenarioError> {
        match s {
            "execution" => Ok(CapGranularity::Execution),
            "request" => Ok(CapGranularity::Request),
            other => Err(ScenarioError::UnknownName {
                what: "cap granularity",
                name: other.to_string(),
                known: "execution | request",
            }),
        }
    }
}

impl FleetArbitration {
    pub fn name(&self) -> &'static str {
        match self {
            FleetArbitration::Fifo => "fifo",
            FleetArbitration::WeightedFair => "weighted-fair",
        }
    }

    pub fn from_name(s: &str) -> Result<FleetArbitration, ScenarioError> {
        match s {
            "fifo" => Ok(FleetArbitration::Fifo),
            "weighted-fair" => Ok(FleetArbitration::WeightedFair),
            other => Err(ScenarioError::UnknownName {
                what: "fleet arbitration",
                name: other.to_string(),
                known: "fifo | weighted-fair",
            }),
        }
    }
}

/// Per-expert serving statistics accumulated over one epoch.
#[derive(Debug, Clone, Copy, Default)]
pub struct ExpertEpochStats {
    /// Replica invocations admitted (one per replica per request served).
    pub invocations: u64,
    /// Summed execution seconds across the expert's replicas.
    pub busy_secs: f64,
    /// Summed FIFO queue wait across those invocations.
    pub queue_wait: f64,
}

/// Accumulates per-expert epoch stats and applies the scaling policy at
/// epoch boundaries.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    pub policy: AutoscalePolicy,
    /// Hard replica ceiling (the deployment problem's G).
    pub max_replicas: usize,
    stats: HashMap<(usize, usize), ExpertEpochStats>,
    /// `(virtual time, replicas added (+) or reaped (-))` per decision.
    pub events: Vec<(f64, i64)>,
    pub scale_outs: u64,
    pub scale_ins: u64,
}

impl Autoscaler {
    pub fn new(policy: AutoscalePolicy, max_replicas: usize) -> Autoscaler {
        Autoscaler {
            policy,
            max_replicas: max_replicas.max(1),
            stats: HashMap::new(),
            events: Vec::new(),
            scale_outs: 0,
            scale_ins: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.policy != AutoscalePolicy::Off
    }

    /// Record one admitted replica invocation of `(layer, expert)` with its
    /// execution time and FIFO wait.
    pub fn record(&mut self, layer: usize, expert: usize, service: f64, wait: f64) {
        let st = self.stats.entry((layer, expert)).or_default();
        st.invocations += 1;
        st.busy_secs += service;
        st.queue_wait += wait;
    }

    /// Drop the accumulated stats (fresh epoch, or a full redeploy made
    /// them describe a deployment that no longer exists).
    pub fn reset_epoch(&mut self) {
        self.stats.clear();
    }

    /// Apply the policy to `policy`'s replica counts at epoch boundary
    /// `now`, then start a fresh stats window. Scale-in only reaps replicas
    /// whose queue in `pool` has drained — and evicts their warm
    /// environments, so scaling the same index back out later starts cold.
    /// Returns the number of experts whose replica count changed. Generic
    /// over the pool so the legacy `WarmPool` and the event engine's flat
    /// `SlotArena` share one scaling implementation.
    pub fn rescale<P: InstancePool + ?Sized>(
        &mut self,
        policy: &mut DeploymentPolicy,
        pool: &mut P,
        now: f64,
        epoch_secs: f64,
    ) -> usize {
        if !self.enabled() || !epoch_secs.is_finite() || epoch_secs <= 0.0 {
            return 0;
        }
        // An unbounded pool produces no FIFO-wait signal; queue-driven
        // decisions must not fire on it (they could only ever scale in).
        let queue_signals = pool.concurrency_limit().is_some();
        let mut changes = 0usize;
        for (l, lp) in policy.layers.iter_mut().enumerate() {
            for (i, ep) in lp.experts.iter_mut().enumerate() {
                let st = self.stats.get(&(l, i)).copied().unwrap_or_default();
                let g = ep.replicas.max(1);
                let util = st.busy_secs / (g as f64 * epoch_secs);
                let mean_wait = if st.invocations > 0 {
                    st.queue_wait / st.invocations as f64
                } else {
                    0.0
                };
                let desired = match self.policy {
                    AutoscalePolicy::Off => g,
                    AutoscalePolicy::TargetUtilization { target } => {
                        let t = target.max(1e-6);
                        if util > t {
                            ((g as f64 * util / t).ceil() as usize).min(self.max_replicas)
                        } else if g > 1
                            && util < 0.5 * t
                            && st.busy_secs / ((g - 1) as f64 * epoch_secs) < t
                        {
                            g - 1
                        } else {
                            g
                        }
                    }
                    AutoscalePolicy::QueueDepth { max_wait, idle_below } => {
                        if !queue_signals {
                            g
                        } else if mean_wait > max_wait {
                            (g + 1).min(self.max_replicas)
                        } else if g > 1 && st.queue_wait <= 0.0 && util < idle_below {
                            g - 1
                        } else {
                            g
                        }
                    }
                };
                if desired > g {
                    // Scale out: fresh instances join cold — their first
                    // invocation pays the cold start via the warm pool.
                    // Refcounted (shared) pools track the new owner so a
                    // co-tenant's later scale-in can't tear it down.
                    for gg in g..desired {
                        pool.retain((l, i, gg));
                    }
                    self.events.push((now, (desired - g) as i64));
                    self.scale_outs += (desired - g) as u64;
                    ep.replicas = desired;
                    changes += 1;
                } else if desired < g {
                    // Scale in: reap idle replicas from the top index down;
                    // a replica still draining its queue stays for now.
                    let mut shrunk = g;
                    while shrunk > desired && pool.idle_at((l, i, shrunk - 1), now) {
                        shrunk -= 1;
                    }
                    if shrunk < g {
                        // Evict the reaped instances' warm environments so a
                        // later scale-out of the same index starts cold.
                        for gg in shrunk..g {
                            pool.evict((l, i, gg));
                        }
                        self.events.push((now, -((g - shrunk) as i64)));
                        self.scale_ins += (g - shrunk) as u64;
                        ep.replicas = shrunk;
                        changes += 1;
                    }
                }
            }
        }
        self.reset_epoch();
        changes
    }

    /// Tenant-departure scale-in (`active` window end): release every
    /// replica ownership the tenant's policy holds in `pool` and count the
    /// idle ones reaped as scale-in events. Unlike [`Autoscaler::rescale`]
    /// this runs regardless of the autoscale policy — offboarding is a
    /// churn event, not a utilization decision — and leaves the policy's
    /// replica counts untouched (straggler in-flight layers may still
    /// dispatch against the policy shape, paying cold starts). A replica
    /// still draining its FIFO at `now` is skipped, exactly as epoch
    /// scale-in skips it; on a refcounted (shared) pool the evict only
    /// tears the environment down when the last owning tenant leaves.
    pub fn depart<P: InstancePool + ?Sized>(
        &mut self,
        policy: &DeploymentPolicy,
        pool: &mut P,
        now: f64,
    ) {
        let mut reaped = 0i64;
        for (l, lp) in policy.layers.iter().enumerate() {
            for (i, ep) in lp.experts.iter().enumerate() {
                for g in 0..ep.replicas {
                    if pool.idle_at((l, i, g), now) {
                        pool.evict((l, i, g));
                        reaped += 1;
                    }
                }
            }
        }
        if reaped > 0 {
            self.events.push((now, -reaped));
            self.scale_ins += reaped as u64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::{CommMethod, ExpertPlan, LayerPlan};
    use crate::platform::WarmPool;

    fn one_layer_policy(replicas0: usize, replicas1: usize) -> DeploymentPolicy {
        DeploymentPolicy {
            layers: vec![LayerPlan {
                method: CommMethod::Indirect,
                beta: 1,
                experts: vec![
                    ExpertPlan { mem_mb: 1024, replicas: replicas0, tokens: 100 },
                    ExpertPlan { mem_mb: 1024, replicas: replicas1, tokens: 100 },
                ],
            }],
        }
    }

    #[test]
    fn overloaded_expert_scales_out_proportionally() {
        let mut auto = Autoscaler::new(AutoscalePolicy::TargetUtilization { target: 0.5 }, 8);
        let mut policy = one_layer_policy(1, 1);
        let mut pool = WarmPool::with_concurrency(100.0, Some(1));
        // Expert 0: 30 busy seconds in a 10 s epoch → util 3.0 → wants
        // ceil(1 * 3.0 / 0.5) = 6 replicas. Expert 1 idle: stays at 1.
        auto.record(0, 0, 30.0, 12.0);
        let changed = auto.rescale(&mut policy, &mut pool, 10.0, 10.0);
        assert_eq!(changed, 1);
        assert_eq!(policy.layers[0].experts[0].replicas, 6);
        assert_eq!(policy.layers[0].experts[1].replicas, 1);
        assert_eq!(auto.scale_outs, 5);
        assert_eq!(auto.events, vec![(10.0, 5)]);
    }

    #[test]
    fn scale_out_respects_max_replicas() {
        let mut auto = Autoscaler::new(AutoscalePolicy::TargetUtilization { target: 0.1 }, 4);
        let mut policy = one_layer_policy(2, 1);
        let mut pool = WarmPool::with_concurrency(100.0, Some(1));
        auto.record(0, 0, 500.0, 0.0);
        auto.rescale(&mut policy, &mut pool, 10.0, 10.0);
        assert_eq!(policy.layers[0].experts[0].replicas, 4);
    }

    #[test]
    fn idle_expert_scales_in_one_replica_per_epoch() {
        let mut auto = Autoscaler::new(
            AutoscalePolicy::QueueDepth { max_wait: 1.0, idle_below: 0.3 },
            8,
        );
        let mut policy = one_layer_policy(3, 1);
        let mut pool = WarmPool::with_concurrency(100.0, Some(1));
        auto.record(0, 0, 0.5, 0.0); // util 0.5/(3*10) ≈ 0.017, no queueing
        auto.rescale(&mut policy, &mut pool, 10.0, 10.0);
        assert_eq!(policy.layers[0].experts[0].replicas, 2);
        assert_eq!(auto.scale_ins, 1);
        // Stats were reset: the next epoch decides from fresh numbers.
        auto.rescale(&mut policy, &mut pool, 20.0, 10.0);
        assert_eq!(policy.layers[0].experts[0].replicas, 1);
        assert_eq!(policy.layers[0].experts[1].replicas, 1, "floor is one replica");
        assert_eq!(auto.scale_ins, 2);
    }

    #[test]
    fn queue_depth_scales_out_on_waits_and_skips_busy_reaps() {
        let mut auto = Autoscaler::new(
            AutoscalePolicy::QueueDepth { max_wait: 0.5, idle_below: 0.3 },
            8,
        );
        let mut policy = one_layer_policy(1, 1);
        let mut pool = WarmPool::with_concurrency(100.0, Some(1));
        auto.record(0, 1, 2.0, 4.0); // mean wait 4 s > 0.5 s
        auto.rescale(&mut policy, &mut pool, 10.0, 10.0);
        assert_eq!(policy.layers[0].experts[1].replicas, 2);

        // Scale-in must not reap a replica whose queue hasn't drained.
        let mut busy_pool = WarmPool::with_concurrency(100.0, Some(1));
        busy_pool.admit((0, 1, 1), 0.0, 1000.0); // busy far past the boundary
        auto.rescale(&mut policy, &mut busy_pool, 20.0, 10.0);
        assert_eq!(policy.layers[0].experts[1].replicas, 2, "busy replica kept");
        // Expert 0 (idle, replicas 1) is already at the floor.
        assert_eq!(policy.layers[0].experts[0].replicas, 1);
    }

    #[test]
    fn reaped_replicas_are_evicted_and_rejoin_cold() {
        let mut auto = Autoscaler::new(
            AutoscalePolicy::QueueDepth { max_wait: 0.5, idle_below: 0.3 },
            8,
        );
        let mut policy = one_layer_policy(2, 1);
        let mut pool = WarmPool::with_concurrency(900.0, Some(1));
        pool.prewarm_plan(&policy.layers);
        assert!(pool.is_warm((0, 0, 1), 50.0));
        // Idle epoch: expert 0 scales 2 → 1 and the reaped instance's warm
        // environment is evicted, not left warm-forever from the prewarm.
        auto.rescale(&mut policy, &mut pool, 10.0, 10.0);
        assert_eq!(policy.layers[0].experts[0].replicas, 1);
        assert!(
            !pool.is_warm((0, 0, 1), 50.0),
            "a reaped replica must not rejoin warm on a later scale-out"
        );
        assert!(pool.is_warm((0, 0, 0), 50.0), "surviving replica stays warm");
    }

    #[test]
    fn queue_depth_holds_on_unbounded_pool() {
        // Without bounded concurrency there is no FIFO-wait signal: the
        // queue-depth policy must hold replica counts, not ratchet them
        // down one idle epoch at a time.
        let mut auto = Autoscaler::new(
            AutoscalePolicy::QueueDepth { max_wait: 0.5, idle_below: 0.3 },
            8,
        );
        let mut policy = one_layer_policy(3, 2);
        let mut pool = WarmPool::new(900.0); // unbounded
        assert_eq!(auto.rescale(&mut policy, &mut pool, 10.0, 10.0), 0);
        assert_eq!(policy.layers[0].experts[0].replicas, 3);
        assert_eq!(policy.layers[0].experts[1].replicas, 2);
    }

    #[test]
    fn policy_json_and_cli_roundtrip() {
        for p in [
            AutoscalePolicy::Off,
            AutoscalePolicy::TargetUtilization { target: 0.7 },
            AutoscalePolicy::QueueDepth { max_wait: 5.0, idle_below: 0.2 },
        ] {
            assert_eq!(AutoscalePolicy::from_json(&p.to_json()).unwrap(), p);
        }
        assert_eq!(AutoscalePolicy::parse_cli("off").unwrap(), AutoscalePolicy::Off);
        assert_eq!(
            AutoscalePolicy::parse_cli("util:0.7").unwrap(),
            AutoscalePolicy::TargetUtilization { target: 0.7 }
        );
        assert_eq!(
            AutoscalePolicy::parse_cli("queue:5").unwrap(),
            AutoscalePolicy::QueueDepth { max_wait: 5.0, idle_below: 0.2 }
        );
        assert!(AutoscalePolicy::parse_cli("utilization").is_err());
        assert!(AutoscalePolicy::parse_cli("util:2.0").is_err(), "target > 1 rejected");
        let typo = crate::util::json::Json::parse(r#"{"kind":"off","extra":1}"#).unwrap();
        assert!(matches!(
            AutoscalePolicy::from_json(&typo),
            Err(ScenarioError::UnknownField { .. })
        ));
    }

    #[test]
    fn fleet_arbitration_names_roundtrip() {
        for a in [FleetArbitration::Fifo, FleetArbitration::WeightedFair] {
            assert_eq!(FleetArbitration::from_name(a.name()).unwrap(), a);
        }
        assert!(matches!(
            FleetArbitration::from_name("round-robin"),
            Err(ScenarioError::UnknownName { .. })
        ));
    }

    #[test]
    fn disabled_policy_never_changes_anything() {
        let mut auto = Autoscaler::new(AutoscalePolicy::Off, 8);
        let mut policy = one_layer_policy(2, 2);
        let mut pool = WarmPool::with_concurrency(100.0, Some(1));
        auto.record(0, 0, 1000.0, 1000.0);
        assert_eq!(auto.rescale(&mut policy, &mut pool, 10.0, 10.0), 0);
        assert_eq!(policy.layers[0].experts[0].replicas, 2);
        assert!(!auto.enabled());
    }
}
