//! Multi-tenant fleet serving: several models behind one shared
//! account-level concurrency pool.
//!
//! The paper minimizes billed cost for *one* MoE model, but a real
//! serverless account serves many models at once under a shared account
//! concurrency limit — the multi-tenant setting FaaSMoE (arXiv 2604.26881)
//! targets, and where MoEless-style function pooling pays off most because
//! load skew *across* tenants is even stronger than skew within one model.
//! A [`FleetScenario`] names a set of tenants (each an ordinary
//! [`Scenario`], inline or referenced by file), gives each a weighted-fair
//! share of an account-level concurrency cap and an optional p95 SLO, and
//! serves them **jointly**: every tenant runs as one event-engine lane
//! (`traffic::sim::EventLane`) against a single globally-ordered event
//! queue, with requests admitted through the shared
//! [`AccountCap`](super::sim::AccountCap) ledger and granted to parked
//! requests per the [`FleetArbitration`] policy. What one ledger slot
//! stands for is the [`CapGranularity`] knob: per concurrent replica
//! *execution* (AWS Lambda's accounting — the default) or per in-flight
//! request (the pre-fix mode, kept for comparison studies). Per-tenant
//! machinery (deployment policies, epoch clocks, drift re-optimization,
//! replica autoscaling) is untouched and runs *under* the fleet
//! arbitration.
//!
//! Two fleet-scale levers ride on top:
//!
//!  - **`share_experts`** — tenants serving the same model preset under
//!    the same keep-alive/concurrency run against *one* warm replica pool
//!    (per-instance owner refcounts in [`SlotArena`], so one tenant's
//!    scale-in cannot cold-start another); billing stays attributed per
//!    tenant by the busy-seconds each lane admitted. The cross-tenant
//!    version of the paper's skew argument: interleaved tenants keep the
//!    shared instances inside keep-alive where private pools would go
//!    cold between each tenant's sparse revisits.
//!  - **`slo_feedback`** — under `weighted-fair` arbitration each tenant's
//!    grant weight adapts at its epoch boundaries from its realized p95
//!    vs its declared SLO (multiplicative increase up to 8x the declared
//!    weight on a miss, decay back to it on a met epoch); the weight each
//!    tenant ended with is reported as `effective_weight`.
//!
//! Determinism: lanes interleave on the `(time, tenant, seq)` event order,
//! so a fleet run is exactly reproducible; with a single tenant and no cap
//! the fleet engine reproduces [`Scenario::run`] byte-for-byte (pinned by
//! `rust/tests/fleet.rs`). Step selection is the candidate heap of
//! [`super::sim::drive`] — O(log tenants) per step, pinned byte-identical
//! to the PR 5 linear-scan driver on every committed scenario, which keeps
//! thousand-tenant fleets tractable.
//!
//! ```no_run
//! use serverless_moe::traffic::fleet::FleetScenario;
//! let fleet = FleetScenario::load(std::path::Path::new("fleet.json"))?;
//! let outcome = fleet.run()?;
//! println!("fleet billed cost: {}", outcome.report.total_cost);
//! # Ok::<(), serverless_moe::traffic::ScenarioError>(())
//! ```
//!
//! The isolation baseline ([`FleetScenario::run_isolated`]) serves each
//! tenant alone on its weighted share of the cap — what per-tenant account
//! reservations would buy — and is what the shared-beats-isolated claim
//! test compares against: under anti-correlated bursts the shared pool
//! serves the same fleet at lower billed cost and no worse p95, the
//! cross-tenant version of the paper's core skew argument.

use super::autoscale::{CapGranularity, FleetArbitration};
use super::config::{FaultSpec, SimEngine};
use super::epoch::EpochSimulator;
use super::error::{self, ScenarioError};
use super::report::{FleetReport, SimReport, TenantReport};
use super::scenario::{Baseline, ModelSource, RunArtifacts, Scenario, TrafficScenario};
use super::sim::{
    drive, drive_scan, policy_stride, AccountCap, BatchPool, CapAudit, EventLane, LaneOpts, Shard,
    SlotArena,
};
use crate::deploy::DeploymentPolicy;
use crate::platform::InstancePool;
use crate::util::json::Json;
use crate::util::stats;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Barrier;

pub use super::sim::FleetDriver;

/// Where a tenant's scenario comes from.
#[derive(Debug, Clone)]
pub enum TenantSource {
    /// The tenant's full scenario inlined into the fleet file.
    Inline(Scenario),
    /// A reference to a scenario JSON file, resolved against the current
    /// working directory at materialization time (like
    /// [`super::scenario::TrafficSource::TracePath`]).
    Ref(String),
}

/// One named tenant of a fleet.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    /// Weighted-fair share of the account cap (finite, > 0; defaults to 1).
    pub weight: f64,
    /// Optional p95 latency SLO (seconds) recorded per tenant in the
    /// [`FleetReport`].
    pub slo_p95: Option<f64>,
    /// Optional `[start, end)` activity window (seconds of virtual time;
    /// `None` = active for the whole run). A windowed tenant *onboards* at
    /// `start` — retaining the shared arena's replicas it relies on — and
    /// *offboards* at `end`, releasing them and scaling idle instances in;
    /// outside the window its lane produces no steps at all. Every arrival
    /// of the tenant's traffic must fall inside the window (checked at run
    /// time, once traffic is materialized).
    pub active: Option<(f64, f64)>,
    pub source: TenantSource,
}

impl TenantSpec {
    /// A tenant wrapping an inline scenario with weight 1, no SLO, and no
    /// activity window (active the whole run).
    pub fn inline(name: &str, scenario: Scenario) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            weight: 1.0,
            slo_p95: None,
            active: None,
            source: TenantSource::Inline(scenario),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(&self.name)),
            ("weight", Json::num(self.weight)),
        ];
        if let Some(slo) = self.slo_p95 {
            pairs.push(("slo_p95", Json::num(slo)));
        }
        if let Some((start, end)) = self.active {
            pairs.push(("active", Json::Arr(vec![Json::num(start), Json::num(end)])));
        }
        pairs.push((
            "scenario",
            match &self.source {
                TenantSource::Inline(s) => s.to_json(),
                TenantSource::Ref(p) => Json::str(p),
            },
        ));
        Json::from_pairs(pairs)
    }

    pub fn from_json(j: &Json, idx: usize) -> Result<TenantSpec, ScenarioError> {
        let section = format!("tenants[{idx}]");
        error::check_keys(j, &section, &["name", "weight", "slo_p95", "active", "scenario"])?;
        let name = error::req_str(j, &section, "name")?.to_string();
        let weight = error::opt_f64(j, &section, "weight", 1.0)?;
        // `null` encodes absent/unbounded throughout the scenario schema
        // (the PR 4 convention `opt_duration` set); an explicit
        // `"slo_p95": null` therefore reads as "no SLO", not a type error.
        let slo_p95 = match j.get("slo_p95") {
            None | Some(Json::Null) => None,
            Some(_) => Some(error::req_f64(j, &section, "slo_p95")?),
        };
        let active = match j.get("active") {
            None | Some(Json::Null) => None,
            Some(Json::Arr(pair)) => {
                let nums: Option<Vec<f64>> = pair.iter().map(Json::as_f64).collect();
                match nums.as_deref() {
                    Some([start, end]) => Some((*start, *end)),
                    _ => {
                        return Err(ScenarioError::invalid(
                            format!("{section}.active"),
                            "expected a [start, end] pair of numbers",
                        ))
                    }
                }
            }
            Some(other) => {
                return Err(ScenarioError::invalid(
                    format!("{section}.active"),
                    format!("expected a [start, end] pair or null, got {other:?}"),
                ))
            }
        };
        let source = match j.get("scenario") {
            None => return Err(ScenarioError::missing(&*section, "scenario")),
            Some(Json::Str(p)) => TenantSource::Ref(p.clone()),
            Some(obj) => TenantSource::Inline(Scenario::from_json(obj)?),
        };
        Ok(TenantSpec { name, weight, slo_p95, active, source })
    }
}

/// A complete, serializable multi-tenant simulation description: named
/// tenants, the shared account-level concurrency cap, and the arbitration
/// policy that splits it. Construct in code (fields are public) or load
/// from JSON ([`FleetScenario::load`], strict parsing); run with
/// [`FleetScenario::run`].
#[derive(Debug, Clone)]
pub struct FleetScenario {
    pub name: String,
    /// Account-level concurrency cap: how many requests the whole fleet may
    /// have in flight at once (`None` = unbounded — the provider's account
    /// limit lifted). Serialized as `0` for `None`, mirroring the
    /// `concurrency` convention.
    pub account_cap: Option<usize>,
    pub arbitration: FleetArbitration,
    /// What one cap slot stands for: a concurrent replica execution
    /// (default — honest Lambda accounting) or an in-flight request (the
    /// pre-fix mode, kept so comparison studies and the PR 5 pin still
    /// run). JSON key `cap_granularity`, `"execution"` / `"request"`.
    pub cap_granularity: CapGranularity,
    /// Serve same-preset tenants (same model preset, keep-alive and
    /// per-instance concurrency) from one shared warm replica pool with
    /// per-instance owner refcounts, instead of one private pool each.
    /// Incompatible with re-optimizing tenants: a redeploy resets its
    /// tenant's pool, which must never clobber a co-tenant's warm state.
    pub share_experts: bool,
    /// Adapt each tenant's weighted-fair grant weight from its realized
    /// p95 vs its declared SLO at its epoch boundaries (requires
    /// `weighted-fair` arbitration; tenants without an SLO keep their
    /// declared weight).
    pub slo_feedback: bool,
    /// Cross-tenant invocation batching window (seconds; `0.0` = off, the
    /// default). When positive — requires `share_experts` — layer
    /// dispatches of same-pool tenants landing on the same shared replica
    /// FIFO within the window merge into *one* invocation: one cold/warm
    /// judgment, one execution priced from the combined token count,
    /// per-tenant billing split by token share. Joins are reported per
    /// tenant as `batched_invocations`.
    pub batch_window: f64,
    /// Fleet-wide failure injection ([`FaultSpec`]; off by default, JSON
    /// `null` = off per the usual convention). When enabled it applies to
    /// *every* tenant lane, overriding any per-tenant `config.faults` —
    /// account-level fault weather (crashes, throttles, timeouts) hits the
    /// whole account, not one tenant. Faults do not compose with
    /// cross-tenant batching (`batch_window > 0` is rejected); every
    /// tenant must run the pipelined event engine.
    pub faults: FaultSpec,
    /// Step driver serving the fleet: the candidate-heap sequential engine
    /// (`"heap"`, the default), the linear-scan reference (`"scan"`), or
    /// the sharded parallel engine (`{"parallel": {"threads": N}}`) —
    /// lanes partitioned across `N` worker threads along coupling-group
    /// boundaries and advanced in lock-step conservative time windows,
    /// byte-identical to `"heap"` at every thread count (pinned by
    /// `rust/tests/fleet.rs`). A fleet-level knob only: single-`Scenario`
    /// runs reject it — one tenant has nothing to shard.
    pub driver: FleetDriver,
    pub tenants: Vec<TenantSpec>,
}

/// One fleet run's results: the aggregate [`FleetReport`] plus per-tenant
/// [`RunArtifacts`] in tenant order.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    pub report: FleetReport,
    pub artifacts: Vec<RunArtifacts>,
}

/// A validated fleet with every tenant resolved and all traffic
/// materialized — [`FleetScenario::prepare`]'s output. Serving it
/// ([`PreparedFleet::run`] / [`PreparedFleet::run_with`]) re-runs only the
/// engine, so byte-identity comparisons across drivers and thread counts
/// compare the same materialized arrivals, and driver benchmarks time the
/// engine alone.
pub struct PreparedFleet {
    fleet: FleetScenario,
    scenarios: Vec<Scenario>,
    compiled: Vec<TrafficScenario>,
}

impl PreparedFleet {
    /// Serve the prepared fleet with its configured driver.
    pub fn run(&self) -> FleetOutcome {
        self.run_with(self.fleet.driver)
    }

    /// Serve the prepared fleet under `driver`, ignoring the configured
    /// knob — the determinism pins' and `bench_traffic`'s entry point.
    pub fn run_with(&self, driver: FleetDriver) -> FleetOutcome {
        self.fleet.run_compiled(&self.scenarios, &self.compiled, driver, false).0
    }
}

impl FleetScenario {
    /// Validate the fleet description: at least one tenant, unique
    /// non-empty names, positive finite weights and SLOs, and — for inline
    /// tenants — a valid scenario the fleet engine can serve (event engine,
    /// serverless baseline). Referenced scenario files are checked at
    /// [`FleetScenario::run`] time, after loading.
    pub fn validate(&self) -> Result<(), ScenarioError> {
        if self.tenants.is_empty() {
            return Err(ScenarioError::invalid(
                "fleet.tenants",
                "must name at least one tenant",
            ));
        }
        if self.account_cap == Some(0) {
            return Err(ScenarioError::invalid(
                "fleet.account_cap",
                "must be >= 1 (use None / 0-in-JSON for unbounded)",
            ));
        }
        if self.slo_feedback && self.arbitration != FleetArbitration::WeightedFair {
            return Err(ScenarioError::invalid(
                "fleet.slo_feedback",
                "SLO feedback adapts weighted-fair grant weights; \
                 it requires arbitration = \"weighted-fair\"",
            ));
        }
        if !(self.batch_window.is_finite() && self.batch_window >= 0.0) {
            return Err(ScenarioError::invalid(
                "fleet.batch_window",
                format!("must be finite and >= 0 (0 = off), got {}", self.batch_window),
            ));
        }
        if self.batch_window > 0.0 && !self.share_experts {
            return Err(ScenarioError::invalid(
                "fleet.batch_window",
                "cross-tenant batching merges dispatches on a *shared* replica pool; \
                 it requires share_experts = true",
            ));
        }
        if let FleetDriver::Parallel { threads } = self.driver {
            if threads == 0 {
                return Err(ScenarioError::invalid(
                    "fleet.driver",
                    "parallel driver needs threads >= 1",
                ));
            }
        }
        self.faults.check("fleet.faults")?;
        if self.faults.enabled() && self.batch_window > 0.0 {
            return Err(ScenarioError::invalid(
                "fleet.faults",
                "failure injection does not compose with cross-tenant batching \
                 (batched dispatches are adjudicated per merged flush, not per \
                 tenant); set batch_window = 0 or faults = null",
            ));
        }
        let mut seen = std::collections::BTreeSet::new();
        for (i, t) in self.tenants.iter().enumerate() {
            if t.name.is_empty() {
                return Err(ScenarioError::invalid(
                    format!("tenants[{i}].name"),
                    "must not be empty",
                ));
            }
            if !seen.insert(t.name.as_str()) {
                return Err(ScenarioError::invalid(
                    format!("tenants[{i}].name"),
                    format!("duplicate tenant name '{}'", t.name),
                ));
            }
            if !(t.weight.is_finite() && t.weight > 0.0) {
                return Err(ScenarioError::invalid(
                    format!("tenants[{i}].weight"),
                    format!("must be finite and > 0, got {}", t.weight),
                ));
            }
            if let Some(slo) = t.slo_p95 {
                if !(slo.is_finite() && slo > 0.0) {
                    return Err(ScenarioError::invalid(
                        format!("tenants[{i}].slo_p95"),
                        format!("must be finite and > 0, got {slo}"),
                    ));
                }
            }
            if let Some((start, end)) = t.active {
                if !(start.is_finite() && end.is_finite() && start >= 0.0 && start < end) {
                    return Err(ScenarioError::invalid(
                        format!("tenants[{i}].active"),
                        format!("window must satisfy 0 <= start < end, got [{start}, {end})"),
                    ));
                }
            }
            match &t.source {
                TenantSource::Inline(s) => {
                    s.validate()?;
                    check_tenant_scenario(i, s, self)?;
                }
                TenantSource::Ref(p) => {
                    if p.is_empty() {
                        return Err(ScenarioError::invalid(
                            format!("tenants[{i}].scenario"),
                            "referenced scenario path must not be empty",
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("version", Json::num(1.0)),
            ("name", Json::str(&self.name)),
            (
                "account_cap",
                Json::num(self.account_cap.unwrap_or(0) as f64),
            ),
            ("arbitration", Json::str(self.arbitration.name())),
            ("cap_granularity", Json::str(self.cap_granularity.name())),
            ("share_experts", Json::Bool(self.share_experts)),
            ("slo_feedback", Json::Bool(self.slo_feedback)),
            ("batch_window", Json::num(self.batch_window)),
            (
                "faults",
                if self.faults == FaultSpec::off() {
                    Json::Null
                } else {
                    self.faults.to_json()
                },
            ),
            ("driver", driver_to_json(self.driver)),
            (
                "tenants",
                Json::Arr(self.tenants.iter().map(TenantSpec::to_json).collect()),
            ),
        ])
    }

    /// Strict inverse of [`FleetScenario::to_json`]: unknown fields
    /// anywhere in the fleet-owned schema (including each tenant entry and
    /// inline tenant scenarios) are rejected, values validated.
    pub fn from_json(j: &Json) -> Result<FleetScenario, ScenarioError> {
        const SECTION: &str = "fleet";
        error::check_keys(
            j,
            SECTION,
            &[
                "version",
                "name",
                "account_cap",
                "arbitration",
                "cap_granularity",
                "share_experts",
                "slo_feedback",
                "batch_window",
                "faults",
                "driver",
                "tenants",
            ],
        )?;
        let version = error::opt_u64(j, SECTION, "version", 1)?;
        if version != 1 {
            return Err(ScenarioError::invalid(
                "version",
                format!("unsupported fleet version {version} (this build reads 1)"),
            ));
        }
        let name = error::req_str(j, SECTION, "name")?.to_string();
        let account_cap = match error::opt_u64(j, SECTION, "account_cap", 0)? {
            0 => None,
            c => Some(c as usize),
        };
        let arbitration = match j.get("arbitration") {
            None => FleetArbitration::WeightedFair,
            Some(Json::Str(s)) => FleetArbitration::from_name(s)?,
            Some(other) => {
                return Err(ScenarioError::invalid(
                    "fleet.arbitration",
                    format!("expected a string, got {other:?}"),
                ))
            }
        };
        let cap_granularity = match j.get("cap_granularity") {
            None => CapGranularity::default(),
            Some(Json::Str(s)) => CapGranularity::from_name(s)?,
            Some(other) => {
                return Err(ScenarioError::invalid(
                    "fleet.cap_granularity",
                    format!("expected a string, got {other:?}"),
                ))
            }
        };
        let share_experts = opt_bool(j, SECTION, "share_experts", false)?;
        let slo_feedback = opt_bool(j, SECTION, "slo_feedback", false)?;
        let batch_window = error::opt_f64(j, SECTION, "batch_window", 0.0)?;
        let faults = match j.get("faults") {
            None | Some(Json::Null) => FaultSpec::off(),
            Some(fj) => FaultSpec::from_json(fj)?,
        };
        let driver = match j.get("driver") {
            None => FleetDriver::Heap,
            Some(dj) => driver_from_json(dj)?,
        };
        let tenant_entries = j
            .get("tenants")
            .and_then(Json::as_arr)
            .ok_or_else(|| ScenarioError::missing(SECTION, "tenants"))?;
        let mut tenants = Vec::with_capacity(tenant_entries.len());
        for (i, tj) in tenant_entries.iter().enumerate() {
            tenants.push(TenantSpec::from_json(tj, i)?);
        }
        let fleet = FleetScenario {
            name,
            account_cap,
            arbitration,
            cap_granularity,
            share_experts,
            slo_feedback,
            batch_window,
            faults,
            driver,
            tenants,
        };
        fleet.validate()?;
        Ok(fleet)
    }

    pub fn load(path: &Path) -> Result<FleetScenario, ScenarioError> {
        Self::from_json(&error::read_json(path)?)
    }

    pub fn save(&self, path: &Path) -> Result<(), ScenarioError> {
        self.to_json()
            .write_file(path)
            .map_err(|e| ScenarioError::Io {
                path: path.display().to_string(),
                detail: e.to_string(),
            })
    }

    /// Resolve every tenant to a concrete [`Scenario`] (loading `Ref`
    /// sources) and re-check fleet eligibility on the loaded files.
    fn resolved(&self) -> Result<Vec<Scenario>, ScenarioError> {
        self.tenants
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let s = match &t.source {
                    TenantSource::Inline(s) => s.clone(),
                    TenantSource::Ref(p) => Scenario::load(Path::new(p))?,
                };
                check_tenant_scenario(i, &s, self)?;
                Ok(s)
            })
            .collect()
    }

    /// Serve the whole fleet jointly under the shared account cap, with
    /// the configured step [`FleetDriver`]. Each tenant keeps its own
    /// baseline semantics (the exact cfg munging of
    /// [`TrafficScenario::run`]): `static`/`lambdaml` force re-optimization
    /// off, `ours` takes the tenant's config as written.
    pub fn run(&self) -> Result<FleetOutcome, ScenarioError> {
        Ok(self.prepare()?.run())
    }

    /// Validate, resolve every tenant and materialize all traffic once,
    /// returning a [`PreparedFleet`] that can be served repeatedly — and
    /// under different drivers ([`PreparedFleet::run_with`]) — without
    /// re-paying (or re-seeding) resolution and arrival generation. The
    /// determinism pins and `bench_traffic`'s driver sweep run through
    /// this so every compared run serves the *same* materialized traffic.
    pub fn prepare(&self) -> Result<PreparedFleet, ScenarioError> {
        self.validate()?;
        let scenarios = self.resolved()?;
        let compiled = scenarios
            .iter()
            .map(Scenario::materialize)
            .collect::<Result<Vec<_>, _>>()?;
        self.check_active_traffic(&compiled)?;
        Ok(PreparedFleet { fleet: self.clone(), scenarios, compiled })
    }

    /// A windowed tenant's traffic must lie inside its `[start, end)`
    /// activity window — an arrival before onboarding or after offboarding
    /// would be served by a lane that no longer (or does not yet) exist.
    /// Checkable only here, once traffic is materialized.
    fn check_active_traffic(&self, compiled: &[TrafficScenario]) -> Result<(), ScenarioError> {
        for (i, t) in self.tenants.iter().enumerate() {
            let Some((start, end)) = t.active else { continue };
            if let Some(tb) = compiled[i].traffic.iter().find(|tb| tb.at < start || tb.at >= end) {
                return Err(ScenarioError::invalid(
                    format!("tenants[{i}].active"),
                    format!(
                        "arrival at t={} falls outside the [{start}, {end}) activity window",
                        tb.at
                    ),
                ));
            }
        }
        Ok(())
    }

    /// The isolation baseline: every tenant served *alone* on its
    /// weighted-fair reservation of the account cap — what per-tenant
    /// reserved concurrency would buy instead of the shared pool. The
    /// reservations partition the cap *exactly* (largest-remainder
    /// apportionment by weight, at least one slot each; a fleet with more
    /// tenants than cap slots cannot be isolated and is a typed error), so
    /// the baseline never models more concurrency than the account owns.
    /// Uncapped fleets isolate to uncapped single runs. Tenants are
    /// resolved and materialized once, not per single run.
    pub fn run_isolated(&self) -> Result<FleetOutcome, ScenarioError> {
        self.validate()?;
        let weights: Vec<f64> = self.tenants.iter().map(|t| t.weight).collect();
        let shares = isolated_shares(self.account_cap, &weights)?;
        let scenarios = self.resolved()?;
        let compiled = scenarios
            .iter()
            .map(Scenario::materialize)
            .collect::<Result<Vec<_>, _>>()?;
        self.check_active_traffic(&compiled)?;
        let mut tenants = Vec::with_capacity(self.tenants.len());
        let mut artifacts = Vec::with_capacity(self.tenants.len());
        // Isolated reservations run concurrently in real life, so the
        // fleet-level peak is the sum of the single-tenant peaks; likewise
        // the event count sums the per-single event heaps.
        let mut peak = 0usize;
        let mut events = 0u64;
        for (i, t) in self.tenants.iter().enumerate() {
            let single = FleetScenario {
                name: format!("{}/{}", self.name, t.name),
                account_cap: shares[i],
                arbitration: self.arbitration,
                cap_granularity: self.cap_granularity,
                // A single-tenant fleet has nobody to share with or adapt
                // against; the isolation baseline carries the flags anyway
                // so its semantics track the shared run's knob-for-knob.
                share_experts: self.share_experts,
                slo_feedback: self.slo_feedback,
                batch_window: self.batch_window,
                faults: self.faults,
                // One tenant has nothing to shard; singles always run the
                // sequential heap driver.
                driver: FleetDriver::Heap,
                tenants: vec![t.clone()],
            };
            let mut out = single
                .run_compiled(&scenarios[i..=i], &compiled[i..=i], FleetDriver::Heap, false)
                .0;
            peak += out.report.peak_concurrency;
            events += out.report.events;
            tenants.push(out.report.tenants.pop().expect("single-tenant fleet"));
            artifacts.push(out.artifacts.pop().expect("single-tenant fleet"));
        }
        Ok(FleetOutcome {
            report: FleetReport::from_tenants(self.account_cap, peak, events, tenants),
            artifacts,
        })
    }

    /// The joint run over already-resolved, already-materialized tenants:
    /// one simulator + one event lane per tenant, driven to completion
    /// against one shared event queue and account ledger by the selected
    /// step driver. `audit` records every cap-ledger transition (the
    /// conservation property test); the returned log is empty otherwise.
    fn run_compiled(
        &self,
        scenarios: &[Scenario],
        compiled: &[TrafficScenario],
        driver: FleetDriver,
        audit: bool,
    ) -> (FleetOutcome, Vec<CapAudit>) {
        if let FleetDriver::Parallel { threads } = driver {
            return self.run_parallel(scenarios, compiled, threads, audit);
        }
        let members: Vec<usize> = (0..compiled.len()).collect();
        let mut shard = self.build_shard(scenarios, compiled, &members, audit);
        let reports = match driver {
            FleetDriver::Heap => drive(
                &mut shard.sims,
                &mut shard.lanes,
                &mut shard.arenas,
                &mut shard.q,
                &mut shard.cap,
                &mut shard.batch,
            ),
            FleetDriver::Scan => drive_scan(
                &mut shard.sims,
                &mut shard.lanes,
                &mut shard.arenas,
                &mut shard.q,
                &mut shard.cap,
                &mut shard.batch,
            ),
            FleetDriver::Parallel { .. } => unreachable!("dispatched above"),
        };
        let mut tenants = Vec::with_capacity(reports.len());
        let mut artifacts = Vec::with_capacity(reports.len());
        for (i, report) in reports.into_iter().enumerate() {
            let (t, a) = self.collect_tenant(i, report, &shard.lanes[i], &mut shard.sims[i]);
            tenants.push(t);
            artifacts.push(a);
        }
        let outcome = FleetOutcome {
            report: FleetReport::from_tenants(
                self.account_cap,
                shard.cap.peak_in_use(),
                shard.q.pushed(),
                tenants,
            ),
            artifacts,
        };
        (outcome, shard.cap.take_audit())
    }

    /// Build one shard: the simulators, lanes, arenas, cap ledger and batch
    /// pool for `members` (global tenant indices, ascending) — exactly the
    /// construction the sequential driver runs over the whole fleet,
    /// restricted to the members, with tenants and arenas renumbered to
    /// dense local ids in member order. The restriction is exact because
    /// the parallel planner only splits along coupling-group boundaries:
    /// every `share_experts` arena group lies wholly inside one shard (so
    /// strides, owners, refcounts and the prewarm/retain order all match
    /// the whole-fleet plan's), and an enabled account cap forces a single
    /// all-tenant shard whose local ids equal the global ones.
    fn build_shard<'c>(
        &self,
        scenarios: &[Scenario],
        compiled: &'c [TrafficScenario],
        members: &[usize],
        audit: bool,
    ) -> Shard<'c, 'c> {
        let mut sims: Vec<EpochSimulator<'c>> = Vec::with_capacity(members.len());
        let mut policies: Vec<DeploymentPolicy> = Vec::with_capacity(members.len());
        let mut pipelines: Vec<bool> = Vec::with_capacity(members.len());
        for &i in members {
            let (s, scn) = (&scenarios[i], &compiled[i]);
            let mut cfg = s.cfg.clone();
            // Fleet-level fault weather overrides any per-tenant spec:
            // crashes and throttles hit the whole account.
            if self.faults.enabled() {
                cfg.faults = self.faults;
            }
            let forced = match s.baseline {
                Baseline::Ours => None,
                Baseline::Static => {
                    cfg.reoptimize = false;
                    None
                }
                Baseline::LambdaML => {
                    cfg.reoptimize = false;
                    Some(scn.lambdaml(&cfg))
                }
                Baseline::CpuCluster => unreachable!("rejected by validate()"),
            };
            let pipeline = match cfg.engine {
                SimEngine::Event { pipeline } => pipeline,
                SimEngine::Legacy => unreachable!("rejected by validate()"),
            };
            let mut sim =
                EpochSimulator::new(&scn.platform, &scn.spec, &scn.gate, scn.predictor(), cfg);
            let policy = match forced {
                Some(p) => p,
                None => sim.initial_policy(&scn.traffic),
            };
            sim.begin_run(&policy);
            sim.chat = scn.chat.as_ref();
            sims.push(sim);
            policies.push(policy);
            pipelines.push(pipeline);
        }

        // Arena plan: by default every tenant gets a private pool; under
        // `share_experts`, tenants serving the same named preset with the
        // same keep-alive and per-instance concurrency are grouped onto one
        // shared pool (first-seen order, so arena ids are deterministic).
        // The stride is the widest member's, and shared pools turn on
        // per-instance owner refcounts so one tenant's scale-in cannot
        // tear down an environment a co-tenant still owns.
        let mut arena_of = vec![0usize; members.len()];
        let mut strides: Vec<usize> = Vec::new();
        let mut member_count: Vec<usize> = Vec::new();
        let mut owner: Vec<usize> = Vec::new();
        let mut groups: std::collections::BTreeMap<(&str, u64, usize), usize> =
            std::collections::BTreeMap::new();
        for (k, policy) in policies.iter().enumerate() {
            let cfg = &sims[k].cfg;
            let stride = cfg.max_replicas.max(policy_stride(policy));
            let key = match (self.share_experts, &scenarios[members[k]].model) {
                (true, ModelSource::Preset(p)) => p.canonical_name().map(|name| {
                    (name, cfg.keep_alive.to_bits(), cfg.concurrency.unwrap_or(0))
                }),
                _ => None,
            };
            let aid = match key.and_then(|g| groups.get(&g).copied()) {
                Some(a) => a,
                None => {
                    let a = strides.len();
                    if let Some(g) = key {
                        groups.insert(g, a);
                    }
                    strides.push(0);
                    member_count.push(0);
                    owner.push(k);
                    a
                }
            };
            arena_of[k] = aid;
            strides[aid] = strides[aid].max(stride);
            member_count[aid] += 1;
        }
        let mut arenas: Vec<SlotArena> = (0..strides.len())
            .map(|a| {
                let o = owner[a];
                let cfg = &sims[o].cfg;
                let mut arena = SlotArena::new(
                    &compiled[members[o]].spec,
                    strides[a],
                    cfg.keep_alive,
                    cfg.concurrency,
                );
                if member_count[a] > 1 {
                    arena.enable_refcounts();
                }
                arena
            })
            .collect();
        // Prewarm and ownership registration, in tenant order: each tenant
        // pre-warms its own plan (when its config asks for it) and retains
        // every replica its deployment starts with — a no-op on private
        // pools, a refcount on shared ones. A tenant with an `active`
        // window defers its retains to its onboard step at `active.start`
        // (the lane registers ownership itself); prewarming stays upfront —
        // it models provisioned environments, which exist before the
        // tenant's first request either way.
        for (k, policy) in policies.iter().enumerate() {
            let arena = &mut arenas[arena_of[k]];
            if sims[k].cfg.prewarm {
                arena.prewarm_plan(&policy.layers);
            }
            if self.tenants[members[k]].active.is_some() {
                continue;
            }
            for (l, layer) in policy.layers.iter().enumerate() {
                for (e, ep) in layer.experts.iter().enumerate() {
                    for g in 0..ep.replicas {
                        arena.retain((l, e, g));
                    }
                }
            }
        }

        let weights: Vec<f64> = members.iter().map(|&i| self.tenants[i].weight).collect();
        let mut cap =
            AccountCap::new(self.account_cap, self.arbitration, self.cap_granularity, &weights);
        if audit {
            cap.enable_audit();
        }
        let capped = cap.enabled();
        // Cross-tenant batching only has a merge partner on a shared pool
        // (several lanes on one arena) and only the pipelined dispatch path
        // routes per-layer; a lane not meeting both serves unbatched even
        // when the fleet's window is open.
        let batch = BatchPool::new(self.batch_window);
        let lanes: Vec<EventLane<'c, 'c>> = policies
            .into_iter()
            .enumerate()
            .map(|(k, policy)| {
                let i = members[k];
                EventLane::new(
                    &sims[k],
                    policy,
                    &compiled[i].traffic,
                    pipelines[k],
                    LaneOpts {
                        tenant: k as u32,
                        arena_id: arena_of[k],
                        capped,
                        cap_exec: capped
                            && self.cap_granularity == CapGranularity::Execution,
                        slo_feedback: self.slo_feedback,
                        slo_p95: self.tenants[i].slo_p95,
                        weight: self.tenants[i].weight,
                        active: self.tenants[i].active,
                        batchable: batch.enabled()
                            && member_count[arena_of[k]] > 1
                            && pipelines[k],
                    },
                )
            })
            .collect();
        Shard::new(sims, lanes, arenas, cap, batch)
    }

    /// One tenant's fleet-report row and run artifacts, read out of its
    /// finished lane and simulator — shared by the sequential collector
    /// and the parallel shard workers. `i` is the *global* tenant index.
    fn collect_tenant(
        &self,
        i: usize,
        report: SimReport,
        lane: &EventLane<'_, '_>,
        sim: &mut EpochSimulator<'_>,
    ) -> (TenantReport, RunArtifacts) {
        (
            TenantReport {
                name: self.tenants[i].name.clone(),
                weight: self.tenants[i].weight,
                slo_p95: self.tenants[i].slo_p95,
                report,
                capped_requests: lane.cap_waits.len() as u64,
                mean_cap_delay: stats::mean(&lane.cap_waits),
                max_cap_delay: lane.cap_waits.iter().cloned().fold(0.0, f64::max),
                effective_weight: lane.eff_weight,
                batched_invocations: lane.batched,
            },
            RunArtifacts {
                policy_history: std::mem::take(&mut sim.policy_history),
                final_policy: sim.last_policy.take(),
                redeploy_times: std::mem::take(&mut sim.redeploy_times),
                autoscale_events: std::mem::take(&mut sim.autoscale_events),
                latencies: std::mem::take(&mut sim.last_latencies),
            },
        )
    }

    /// The parallel driver: partition tenants across `threads` worker
    /// threads along coupling-group boundaries, advance all shards in
    /// lock-step conservative time windows, and recombine the shard results
    /// into the one fleet report the sequential driver would have produced.
    ///
    /// **Coupling groups.** Two tenants are coupled when a step of one can
    /// read or write state a step of the other touches: the shared account
    /// ledger (any enabled `account_cap` — slot grants are adjudicated
    /// across the whole fleet), or a shared `share_experts` replica pool
    /// and the batch windows keyed on it. A capped fleet is therefore one
    /// single group (the run degenerates to one shard — correct, and
    /// documented in the README rather than refused); an uncapped fleet
    /// groups tenants by shared-arena equivalence, with private-pool
    /// tenants each a singleton. This is the "co-locate sharers on one
    /// shard" resolution of shared pools: co-tenants' dispatches never
    /// cross a shard boundary, so the barrier exchange set is empty and
    /// byte-identity holds for *any* window width.
    ///
    /// **Windows.** Shards still advance in lock-step windows — the
    /// conservative-synchronization protocol proper: at each barrier every
    /// shard publishes its next pending step time, the leader sets the
    /// window end `horizon = min(next) + Δ` (`Δ` from [`window_delta`]),
    /// and every shard then runs exactly its steps with `t < horizon`.
    /// With no cross-shard state inside a window the windows only bound
    /// skew (keeping per-shard memory and virtual-time divergence flat);
    /// an exhausted fleet drives `horizon` to infinity, which is the
    /// agreed stop signal.
    fn run_parallel(
        &self,
        scenarios: &[Scenario],
        compiled: &[TrafficScenario],
        threads: usize,
        audit: bool,
    ) -> (FleetOutcome, Vec<CapAudit>) {
        let n = compiled.len();
        // Coupling-group ids, dense in first-appearance (tenant) order.
        let group_of: Vec<usize> = if self.account_cap.is_some() {
            vec![0; n]
        } else {
            let mut groups: std::collections::BTreeMap<(&str, u64, usize), usize> =
                std::collections::BTreeMap::new();
            let mut ids = Vec::with_capacity(n);
            let mut next = 0usize;
            for i in 0..n {
                // keep_alive / concurrency are untouched by the per-tenant
                // baseline munging, so grouping on the declared cfg matches
                // the arena plan `build_shard` derives from the munged one.
                let cfg = &scenarios[i].cfg;
                let key = match (self.share_experts, &scenarios[i].model) {
                    (true, ModelSource::Preset(p)) => p.canonical_name().map(|name| {
                        (name, cfg.keep_alive.to_bits(), cfg.concurrency.unwrap_or(0))
                    }),
                    _ => None,
                };
                let g = match key.and_then(|k| groups.get(&k).copied()) {
                    Some(g) => g,
                    None => {
                        let g = next;
                        next += 1;
                        if let Some(k) = key {
                            groups.insert(k, g);
                        }
                        g
                    }
                };
                ids.push(g);
            }
            ids
        };
        let n_groups = group_of.iter().copied().max().map_or(1, |m| m + 1);
        let n_shards = threads.min(n_groups).max(1);
        // Whole groups round-robin onto shards in group-id order; members
        // stay in ascending global order inside each shard (the local
        // renumbering `Shard` documents).
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
        for (i, &g) in group_of.iter().enumerate() {
            members[g % n_shards].push(i);
        }

        let delta = window_delta(compiled);
        let barrier = Barrier::new(n_shards);
        let next_times: Vec<AtomicU64> =
            (0..n_shards).map(|_| AtomicU64::new(f64::INFINITY.to_bits())).collect();
        let horizon = AtomicU64::new(f64::INFINITY.to_bits());
        let shard_outs: Vec<ShardOut> = std::thread::scope(|scope| {
            let handles: Vec<_> = members
                .iter()
                .enumerate()
                .map(|(w, mine)| {
                    let (barrier, next_times, horizon) = (&barrier, &next_times, &horizon);
                    scope.spawn(move || {
                        self.run_shard(
                            scenarios, compiled, mine, audit, w, delta, barrier, next_times,
                            horizon,
                        )
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
        });

        // Recombine: scatter per-tenant rows back to global order; the peak
        // is the max over shards (the ledger is wholly inside one shard
        // when capped, and identically zero when not), events and audit
        // logs are additive (every event ran in exactly one shard).
        let mut tenants: Vec<Option<TenantReport>> = (0..n).map(|_| None).collect();
        let mut artifacts: Vec<Option<RunArtifacts>> = (0..n).map(|_| None).collect();
        let mut peak = 0usize;
        let mut events = 0u64;
        let mut audits = Vec::new();
        for out in shard_outs {
            peak = peak.max(out.peak);
            events += out.events;
            audits.extend(out.audit);
            for (i, t, a) in out.rows {
                tenants[i] = Some(t);
                artifacts[i] = Some(a);
            }
        }
        let tenants: Vec<TenantReport> =
            tenants.into_iter().map(|t| t.expect("every tenant on exactly one shard")).collect();
        let artifacts: Vec<RunArtifacts> =
            artifacts.into_iter().map(|a| a.expect("every tenant on exactly one shard")).collect();
        let outcome = FleetOutcome {
            report: FleetReport::from_tenants(self.account_cap, peak, events, tenants),
            artifacts,
        };
        (outcome, audits)
    }

    /// One worker thread's life: build the shard for `mine`, publish its
    /// next-step time, then loop the two-phase window barrier — (1) wait
    /// for every shard's published time, leader derives the next horizon;
    /// (2) wait for the horizon to be visible, run all local steps before
    /// it, publish the new next time — until the leader reports the whole
    /// fleet exhausted (infinite horizon).
    #[allow(clippy::too_many_arguments)]
    fn run_shard(
        &self,
        scenarios: &[Scenario],
        compiled: &[TrafficScenario],
        mine: &[usize],
        audit: bool,
        w: usize,
        delta: f64,
        barrier: &Barrier,
        next_times: &[AtomicU64],
        horizon: &AtomicU64,
    ) -> ShardOut {
        let mut shard = self.build_shard(scenarios, compiled, mine, audit);
        next_times[w].store(time_bits(shard.next_time()), Ordering::SeqCst);
        loop {
            // Barrier 1 doubles as the construction barrier on the first
            // round: every shard's next-step time is published before the
            // leader reads them.
            if barrier.wait().is_leader() {
                let earliest = next_times
                    .iter()
                    .map(|b| f64::from_bits(b.load(Ordering::SeqCst)))
                    .fold(f64::INFINITY, f64::min);
                let h = if earliest.is_finite() { earliest + delta } else { f64::INFINITY };
                horizon.store(h.to_bits(), Ordering::SeqCst);
            }
            // Barrier 2: the leader's horizon is visible to every worker.
            barrier.wait();
            let h = f64::from_bits(horizon.load(Ordering::SeqCst));
            if h.is_infinite() {
                break; // every shard exhausted
            }
            let next = shard.drive_until(h);
            next_times[w].store(time_bits(next), Ordering::SeqCst);
        }
        let reports = shard.finish();
        let mut rows = Vec::with_capacity(reports.len());
        for (k, report) in reports.into_iter().enumerate() {
            let (t, a) = self.collect_tenant(mine[k], report, &shard.lanes[k], &mut shard.sims[k]);
            rows.push((mine[k], t, a));
        }
        ShardOut {
            rows,
            peak: shard.cap.peak_in_use(),
            events: shard.q.pushed(),
            audit: shard.cap.take_audit(),
        }
    }
}

/// What one parallel shard worker hands back for recombination.
struct ShardOut {
    /// `(global tenant index, report row, artifacts)` per member tenant.
    rows: Vec<(usize, TenantReport, RunArtifacts)>,
    peak: usize,
    events: u64,
    audit: Vec<CapAudit>,
}

/// Width Δ of one conservative synchronization window: the arrival span of
/// the busiest tenant over 256 — a few hundred windows per run, wide
/// enough that barrier crossings are a rounding error against the step
/// work inside one, narrow enough to bound cross-shard virtual-time skew.
/// Correctness does not depend on the choice (see
/// [`FleetScenario::run`]'s driver docs); the floor keeps zero-length
/// traffic from degenerating to zero-width windows.
fn window_delta(compiled: &[TrafficScenario]) -> f64 {
    let span = compiled
        .iter()
        .filter_map(|scn| scn.traffic.last().map(|tb| tb.at))
        .fold(0.0f64, f64::max);
    (span / 256.0).max(1e-3)
}

/// A shard's next-step time as atomically publishable bits (`None` =
/// exhausted = `INFINITY`, which drops out of the leader's `min`).
fn time_bits(t: Option<f64>) -> u64 {
    t.unwrap_or(f64::INFINITY).to_bits()
}

/// Serialize the step-driver knob: `"heap"`, `"scan"`, or
/// `{"parallel": {"threads": N}}`.
fn driver_to_json(driver: FleetDriver) -> Json {
    match driver {
        FleetDriver::Heap => Json::str("heap"),
        FleetDriver::Scan => Json::str("scan"),
        FleetDriver::Parallel { threads } => Json::from_pairs(vec![(
            "parallel",
            Json::from_pairs(vec![("threads", Json::num(threads as f64))]),
        )]),
    }
}

/// Strict inverse of [`driver_to_json`]: unknown driver names, unknown
/// keys inside the `parallel` object, and non-integer or zero thread
/// counts are all typed errors.
fn driver_from_json(j: &Json) -> Result<FleetDriver, ScenarioError> {
    match j {
        Json::Str(s) if s == "heap" => Ok(FleetDriver::Heap),
        Json::Str(s) if s == "scan" => Ok(FleetDriver::Scan),
        Json::Str(s) => Err(ScenarioError::invalid(
            "fleet.driver",
            format!(
                "unknown driver '{s}' (expected \"heap\", \"scan\", or \
                 {{\"parallel\": {{\"threads\": N}}}})"
            ),
        )),
        Json::Obj(_) => {
            error::check_keys(j, "fleet.driver", &["parallel"])?;
            let pj = j
                .get("parallel")
                .ok_or_else(|| ScenarioError::missing("fleet.driver", "parallel"))?;
            error::check_keys(pj, "fleet.driver.parallel", &["threads"])?;
            let threads = error::opt_u64(pj, "fleet.driver.parallel", "threads", 0)?;
            if threads == 0 {
                return Err(ScenarioError::invalid(
                    "fleet.driver.parallel.threads",
                    "must be an integer >= 1",
                ));
            }
            Ok(FleetDriver::Parallel { threads: threads as usize })
        }
        other => Err(ScenarioError::invalid(
            "fleet.driver",
            format!("expected a driver name or {{\"parallel\": ...}}, got {other:?}"),
        )),
    }
}

/// Partition `cap` into per-tenant isolation reservations: at least one
/// slot each, the spare slots apportioned by weight with largest-remainder
/// rounding (ties toward the lower tenant index), summing to exactly `cap`.
/// `None` (unbounded) isolates to unbounded singles.
fn isolated_shares(
    cap: Option<usize>,
    weights: &[f64],
) -> Result<Vec<Option<usize>>, ScenarioError> {
    let n = weights.len();
    let Some(c) = cap else {
        return Ok(vec![None; n]);
    };
    if c < n {
        return Err(ScenarioError::invalid(
            "fleet.account_cap",
            format!(
                "the isolation baseline needs at least one reserved slot per tenant \
                 ({n} tenants, cap {c})"
            ),
        ));
    }
    let total: f64 = weights.iter().sum();
    let spare = (c - n) as f64;
    let quotas: Vec<f64> = weights.iter().map(|w| spare * w / total).collect();
    let mut shares: Vec<usize> = quotas.iter().map(|q| 1 + q.floor() as usize).collect();
    let mut assigned: usize = shares.iter().sum();
    // Largest remainder: the leftover slots go to the biggest fractional
    // quotas, deterministically (remainder desc, then tenant index asc).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let ra = quotas[a] - quotas[a].floor();
        let rb = quotas[b] - quotas[b].floor();
        rb.partial_cmp(&ra).expect("finite remainders").then(a.cmp(&b))
    });
    for &i in &order {
        if assigned >= c {
            break;
        }
        shares[i] += 1;
        assigned += 1;
    }
    debug_assert_eq!(shares.iter().sum::<usize>(), c, "shares must partition the cap");
    Ok(shares.into_iter().map(Some).collect())
}

/// Fleet-eligibility checks on one tenant's scenario: the fleet engine
/// interleaves event lanes, so the legacy serial engine cannot participate,
/// and the CPU-cluster baseline has no serverless pool to share. Under
/// `share_experts` the tenant must not re-optimize: a drift redeploy resets
/// the tenant's instance pool, which must never clobber a shared arena
/// co-tenants are warm in. (`static`/`lambdaml` tenants force
/// re-optimization off at run time, so only `ours` can trip this.)
fn check_tenant_scenario(
    i: usize,
    s: &Scenario,
    fleet: &FleetScenario,
) -> Result<(), ScenarioError> {
    let share_experts = fleet.share_experts;
    if !matches!(s.cfg.engine, SimEngine::Event { .. }) {
        return Err(ScenarioError::invalid(
            format!("tenants[{i}].scenario.config.engine"),
            "fleet serving runs on the event engine (legacy is single-tenant only)",
        ));
    }
    if s.baseline == Baseline::CpuCluster {
        return Err(ScenarioError::invalid(
            format!("tenants[{i}].scenario.baseline"),
            "cpu-cluster has no serverless pool to share; run it as a standalone scenario",
        ));
    }
    if fleet.faults.enabled() && s.cfg.engine != (SimEngine::Event { pipeline: true }) {
        return Err(ScenarioError::invalid(
            format!("tenants[{i}].scenario.config.engine"),
            "fleet-level failure injection adjudicates per pipelined layer \
             dispatch; every tenant must run engine = event with pipelining on",
        ));
    }
    if fleet.batch_window > 0.0 && s.cfg.faults.enabled() {
        return Err(ScenarioError::invalid(
            format!("tenants[{i}].scenario.config.faults"),
            "per-tenant failure injection does not compose with cross-tenant \
             batching; set batch_window = 0 or faults = null",
        ));
    }
    if s.cfg.decode_batch_window > 0.0 {
        return Err(ScenarioError::invalid(
            format!("tenants[{i}].scenario.config.decode_batch_window"),
            "the fleet's own batch_window governs invocation merging; set the \
             tenant's decode_batch_window to 0",
        ));
    }
    if share_experts && s.baseline == Baseline::Ours && s.cfg.reoptimize {
        return Err(ScenarioError::invalid(
            format!("tenants[{i}].scenario.config.reoptimize"),
            "a re-optimizing tenant redeploys (resetting its pool) and cannot share \
             experts; disable reoptimize or share_experts",
        ));
    }
    Ok(())
}

/// Optional strict-boolean field (the fleet schema's `share_experts` /
/// `slo_feedback` knobs).
fn opt_bool(j: &Json, section: &str, key: &str, default: bool) -> Result<bool, ScenarioError> {
    match j.get(key) {
        None => Ok(default),
        Some(Json::Bool(b)) => Ok(*b),
        Some(other) => Err(ScenarioError::invalid(
            format!("{section}.{key}"),
            format!("expected true or false, got {other:?}"),
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::arrivals::ArrivalProcess;
    use crate::traffic::scenario::TrafficSource;
    use crate::traffic::TrafficConfig;

    fn tiny_tenant_scenario(seed: u64) -> Scenario {
        Scenario::builder("tiny-tenant")
            .model("tiny")
            .unwrap()
            .seed(seed)
            .profile(2, 64)
            .traffic(TrafficSource::Synthetic {
                process: ArrivalProcess::Poisson { rate: 1.0 },
                duration: Some(5.0),
                requests: None,
                tokens_per_request: 64,
            })
            .config(TrafficConfig { reoptimize: false, ..TrafficConfig::default() })
            .baseline(Baseline::LambdaML)
            .build()
            .unwrap()
    }

    fn two_tenant_fleet() -> FleetScenario {
        FleetScenario {
            name: "test-fleet".into(),
            account_cap: Some(2),
            arbitration: FleetArbitration::WeightedFair,
            cap_granularity: CapGranularity::Execution,
            share_experts: false,
            slo_feedback: false,
            batch_window: 0.0,
            faults: FaultSpec::off(),
            driver: FleetDriver::Heap,
            tenants: vec![
                TenantSpec {
                    name: "a".into(),
                    weight: 2.0,
                    slo_p95: Some(30.0),
                    active: None,
                    source: TenantSource::Inline(tiny_tenant_scenario(1)),
                },
                TenantSpec::inline("b", tiny_tenant_scenario(2)),
            ],
        }
    }

    #[test]
    fn fleet_json_roundtrip_is_canonical() {
        let mut f = two_tenant_fleet();
        f.cap_granularity = CapGranularity::Request;
        f.share_experts = true;
        let text = f.to_json().to_string_pretty();
        let back = FleetScenario::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string_pretty(), text);
        assert_eq!(back.tenants.len(), 2);
        assert_eq!(back.account_cap, Some(2));
        assert_eq!(back.arbitration, FleetArbitration::WeightedFair);
        assert_eq!(back.cap_granularity, CapGranularity::Request);
        assert!(back.share_experts);
        assert!(!back.slo_feedback);
        assert_eq!(back.tenants[0].slo_p95, Some(30.0));
        // A fleet file written before the PR 6/7 knobs existed parses to
        // the defaults: execution-granular accounting, private pools,
        // static weights, batching off, the sequential heap driver.
        let mut fields = match two_tenant_fleet().to_json() {
            Json::Obj(fields) => fields,
            _ => unreachable!("fleet serializes to an object"),
        };
        for k in [
            "cap_granularity",
            "share_experts",
            "slo_feedback",
            "batch_window",
            "faults",
            "driver",
        ] {
            fields.remove(k);
        }
        let old = FleetScenario::from_json(&Json::Obj(fields)).unwrap();
        assert_eq!(old.cap_granularity, CapGranularity::Execution);
        assert!(!old.share_experts && !old.slo_feedback);
        assert_eq!(old.batch_window, 0.0);
        assert_eq!(old.faults, FaultSpec::off());
        assert_eq!(old.driver, FleetDriver::Heap);
    }

    #[test]
    fn driver_knob_parses_strictly_and_roundtrips() {
        for driver in [
            FleetDriver::Heap,
            FleetDriver::Scan,
            FleetDriver::Parallel { threads: 4 },
        ] {
            let mut f = two_tenant_fleet();
            f.driver = driver;
            let text = f.to_json().to_string_pretty();
            let back = FleetScenario::from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.driver, driver);
            assert_eq!(back.to_json().to_string_pretty(), text, "canonical fixed point");
        }
        for bad in [
            "\"parallel\"",                          // threads are not optional
            "\"turbo\"",                             // unknown name
            "7",                                     // wrong type
            "{\"parallel\": {\"threads\": 0}}",      // zero threads
            "{\"parallel\": {\"threads\": 2.5}}",    // non-integer
            "{\"parallel\": {\"thread\": 2}}",       // unknown key inside
            "{\"parallel\": {\"threads\": 2}, \"x\": 1}", // unknown key beside
        ] {
            let err = driver_from_json(&Json::parse(bad).unwrap());
            assert!(err.is_err(), "driver {bad} must be rejected");
        }
        // The validate()-level guard catches a hand-built zero too.
        let mut f = two_tenant_fleet();
        f.driver = FleetDriver::Parallel { threads: 0 };
        let err = f.validate().unwrap_err();
        assert!(err.to_string().contains("threads"), "{err}");
    }

    #[test]
    fn parallel_driver_reproduces_heap_on_capped_and_uncapped_fleets() {
        // Capped: the ledger couples every tenant, so the planner
        // degenerates to one all-tenant shard whose local ids equal the
        // global ones — the documented single-coupling-group case.
        let capped = two_tenant_fleet();
        // Uncapped private pools: every tenant is its own coupling group,
        // so threads > 1 genuinely runs multiple shards.
        let mut free = two_tenant_fleet();
        free.account_cap = None;
        for fleet in [capped, free] {
            let (scenarios, compiled) = materialized(&fleet);
            let heap = fleet.run_compiled(&scenarios, &compiled, FleetDriver::Heap, false).0;
            for threads in [1, 2, 8] {
                let par = fleet
                    .run_compiled(&scenarios, &compiled, FleetDriver::Parallel { threads }, false)
                    .0;
                assert_eq!(
                    par.report.to_json().to_string_pretty(),
                    heap.report.to_json().to_string_pretty(),
                    "fleet {} at threads={threads}",
                    fleet.name,
                );
            }
        }
    }

    #[test]
    fn fleet_validation_rejects_bad_shapes() {
        let base = two_tenant_fleet();

        let mut empty = base.clone();
        empty.tenants.clear();
        assert!(matches!(empty.validate(), Err(ScenarioError::Invalid { .. })));

        let mut dup = base.clone();
        dup.tenants[1].name = "a".into();
        let err = dup.validate().unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");

        let mut zero_w = base.clone();
        zero_w.tenants[0].weight = 0.0;
        assert!(matches!(zero_w.validate(), Err(ScenarioError::Invalid { .. })));

        let mut legacy = base.clone();
        if let TenantSource::Inline(s) = &mut legacy.tenants[0].source {
            s.cfg.engine = SimEngine::Legacy;
        }
        let err = legacy.validate().unwrap_err();
        assert!(err.to_string().contains("engine"), "{err}");

        let mut feedback = base.clone();
        feedback.arbitration = FleetArbitration::Fifo;
        feedback.slo_feedback = true;
        let err = feedback.validate().unwrap_err();
        assert!(err.to_string().contains("weighted-fair"), "{err}");

        // Sharing is fine for lambdaml tenants (re-optimization forced
        // off), but a re-optimizing `ours` tenant would reset the shared
        // pool on redeploy.
        let mut share = base.clone();
        share.share_experts = true;
        assert!(share.validate().is_ok());
        if let TenantSource::Inline(s) = &mut share.tenants[0].source {
            s.baseline = Baseline::Ours;
            s.cfg.reoptimize = true;
        }
        let err = share.validate().unwrap_err();
        assert!(err.to_string().contains("share"), "{err}");

        let mut cpu = base;
        if let TenantSource::Inline(s) = &mut cpu.tenants[1].source {
            s.baseline = Baseline::CpuCluster;
        }
        assert!(matches!(cpu.validate(), Err(ScenarioError::Invalid { .. })));
    }

    #[test]
    fn isolated_shares_partition_the_cap_exactly() {
        // Equal weights, one spare slot: largest-remainder tie breaks to
        // the lower tenant index.
        assert_eq!(
            isolated_shares(Some(4), &[1.0, 1.0, 1.0]).unwrap(),
            vec![Some(2), Some(1), Some(1)]
        );
        // Heavy skew must not oversubscribe: the old max(1, floor) scheme
        // would have handed out 3+1+1 = 5 slots of a 4-slot account.
        assert_eq!(
            isolated_shares(Some(4), &[10.0, 1.0, 1.0]).unwrap(),
            vec![Some(2), Some(1), Some(1)]
        );
        assert_eq!(
            isolated_shares(Some(6), &[2.0, 1.0]).unwrap(),
            vec![Some(4), Some(2)]
        );
        // Unbounded fleets isolate to unbounded singles.
        assert_eq!(isolated_shares(None, &[1.0, 1.0]).unwrap(), vec![None, None]);
        // More tenants than slots: isolation is impossible, typed error.
        assert!(matches!(
            isolated_shares(Some(2), &[1.0, 1.0, 1.0]),
            Err(ScenarioError::Invalid { .. })
        ));
    }

    #[test]
    fn ref_tenant_missing_file_is_typed_io_error() {
        let f = FleetScenario {
            name: "refs".into(),
            account_cap: None,
            arbitration: FleetArbitration::Fifo,
            cap_granularity: CapGranularity::Execution,
            share_experts: false,
            slo_feedback: false,
            batch_window: 0.0,
            faults: FaultSpec::off(),
            driver: FleetDriver::Heap,
            tenants: vec![TenantSpec {
                name: "ghost".into(),
                weight: 1.0,
                slo_p95: None,
                active: None,
                source: TenantSource::Ref("no/such/scenario.json".into()),
            }],
        };
        assert!(f.validate().is_ok(), "path existence is a run-time concern");
        assert!(matches!(f.run(), Err(ScenarioError::Io { .. })));
    }

    fn committed(name: &str) -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("rust/tests/data/scenarios")
            .join(name)
    }

    fn materialized(fleet: &FleetScenario) -> (Vec<Scenario>, Vec<TrafficScenario>) {
        let scenarios = fleet.resolved().unwrap();
        let compiled = scenarios.iter().map(|s| s.materialize().unwrap()).collect();
        (scenarios, compiled)
    }

    /// Wrap a plain committed scenario as an uncapped single-tenant fleet,
    /// so the step drivers can be raced on it.
    fn solo_fleet(s: Scenario) -> FleetScenario {
        FleetScenario {
            name: format!("solo-{}", s.name),
            account_cap: None,
            arbitration: FleetArbitration::Fifo,
            cap_granularity: CapGranularity::Execution,
            share_experts: false,
            slo_feedback: false,
            batch_window: 0.0,
            faults: FaultSpec::off(),
            driver: FleetDriver::Heap,
            tenants: vec![TenantSpec::inline("solo", s)],
        }
    }

    /// Tentpole pin: the candidate-heap driver and the PR 5 linear-scan
    /// driver execute the identical step sequence. Byte-identical fleet
    /// reports on every solver-free committed file; the ODS-bearing drift
    /// reference compares within 1e-9 + exact integer counters (its solves
    /// are wall-clock limited, so byte identity cannot be promised between
    /// *any* two runs — the same caveat the reproduction pin documents).
    #[test]
    fn heap_driver_matches_scan_driver_on_committed_files() {
        let mut exact = vec![FleetScenario::load(&committed("fleet_two_tenant.json")).unwrap()];
        // The churn+batching fixture races the PR 7 paths too: staggered
        // onboard/offboard steps and merged batch dispatches must replay
        // identically under both drivers.
        exact.push(FleetScenario::load(&committed("fleet_churn_batching.json")).unwrap());
        // The PR 9 golden fixture rides along: the fleet-report numbers it
        // pins must not depend on the driver either.
        exact.push(FleetScenario::load(&committed("fleet_golden.json")).unwrap());
        exact.push(solo_fleet(
            Scenario::load(&committed("tiny_trace_lambdaml.json")).unwrap(),
        ));
        for fleet in &exact {
            let (scenarios, compiled) = materialized(fleet);
            let (heap, _) = fleet.run_compiled(&scenarios, &compiled, FleetDriver::Heap, false);
            let (scan, _) = fleet.run_compiled(&scenarios, &compiled, FleetDriver::Scan, false);
            assert_eq!(
                heap.report.to_json().to_string_pretty(),
                scan.report.to_json().to_string_pretty(),
                "drivers diverged on {}",
                fleet.name
            );
        }

        let drift = solo_fleet(Scenario::load(&committed("drift_bert_quick.json")).unwrap());
        let (scenarios, compiled) = materialized(&drift);
        let (heap, _) = drift.run_compiled(&scenarios, &compiled, FleetDriver::Heap, false);
        let (scan, _) = drift.run_compiled(&scenarios, &compiled, FleetDriver::Scan, false);
        for (h, s) in heap.report.tenants.iter().zip(&scan.report.tenants) {
            h.report.close_to(&s.report, 1e-9).unwrap_or_else(|e| {
                panic!("drivers diverged on {}: {e}", drift.name);
            });
            assert_eq!(h.report.warm_invocations, s.report.warm_invocations);
            assert_eq!(h.report.cold_invocations, s.report.cold_invocations);
            assert_eq!(h.report.queued_invocations, s.report.queued_invocations);
            assert_eq!(h.report.epochs, s.report.epochs);
            assert_eq!(h.report.redeploys, s.report.redeploys);
            assert_eq!(h.capped_requests, s.capped_requests);
        }
    }

    /// The PR 9 off-switch, pinned under both step drivers: chat traffic
    /// with a fixed decode length of 0 degenerates to pure prefill and must
    /// reproduce the equivalent `synthetic` scenario byte-for-byte — same
    /// prompts, same arrivals, no decode machinery on the path. All four
    /// runs (chat-0 and synthetic, each under Heap and Scan) must agree.
    #[test]
    fn decode_zero_chat_matches_synthetic_under_both_drivers() {
        use crate::traffic::workload::DecodeLengthModel;
        let process = ArrivalProcess::Poisson { rate: 1.0 };
        let chat = Scenario::builder("decode-zero")
            .model("tiny")
            .unwrap()
            .seed(21)
            .profile(2, 64)
            .traffic(TrafficSource::Chat {
                process,
                duration: Some(5.0),
                requests: None,
                prompt_tokens: 64,
                decode: DecodeLengthModel::Fixed { steps: 0 },
                decode_tokens: 8,
            })
            .config(TrafficConfig { reoptimize: false, ..TrafficConfig::default() })
            .baseline(Baseline::LambdaML)
            .build()
            .unwrap();
        let mut synth = chat.clone();
        synth.source = TrafficSource::Synthetic {
            process,
            duration: Some(5.0),
            requests: None,
            tokens_per_request: 64,
        };
        let mut reports = Vec::new();
        for s in [chat, synth] {
            let fleet = solo_fleet(s);
            let (scenarios, compiled) = materialized(&fleet);
            for driver in [FleetDriver::Heap, FleetDriver::Scan] {
                let (out, _) = fleet.run_compiled(&scenarios, &compiled, driver, false);
                let t = &out.report.tenants[0].report;
                assert!(t.requests > 0, "the identity must be over real traffic");
                assert_eq!(t.output_tokens, 0, "decode 0 emits nothing");
                assert_eq!(t.kv_evictions, 0);
                assert_eq!(t.re_prefills, 0);
                reports.push(t.to_json().to_string_pretty());
            }
        }
        assert!(
            reports.windows(2).all(|w| w[0] == w[1]),
            "decode-0 chat must be byte-identical to synthetic under both drivers"
        );
    }

    /// Replay an execution-granular audit log and assert the conservation
    /// property: the recorded `in_use` equals the number of live slot
    /// holds at every transition, every hold is released exactly at its
    /// declared end, and the ledger charged exactly one slot per replica
    /// execution the fleet ran. Returns the replayed peak occupancy.
    fn assert_ledger_conserves(out: &FleetOutcome, audit: &[CapAudit]) -> usize {
        assert!(!audit.is_empty(), "execution-capped run must touch the ledger");
        let mut live = 0usize;
        let mut peak = 0usize;
        let mut acquires = 0u64;
        let mut ends = Vec::new();
        let mut releases = Vec::new();
        for tr in audit {
            match *tr {
                CapAudit::Acquire { end, in_use } => {
                    live += 1;
                    acquires += 1;
                    peak = peak.max(live);
                    assert_eq!(live, in_use, "in_use diverged from live holds");
                    assert!(end.is_finite(), "execution holds have finite ends");
                    ends.push(end);
                }
                CapAudit::Release { at, in_use } => {
                    live -= 1;
                    assert_eq!(live, in_use, "in_use diverged from live holds");
                    releases.push(at);
                }
            }
        }
        assert_eq!(live, 0, "every hold released by the end of the run");
        ends.sort_by(f64::total_cmp);
        releases.sort_by(f64::total_cmp);
        assert_eq!(ends, releases, "each hold released exactly at its declared end");
        let executions: u64 = out
            .report
            .tenants
            .iter()
            .map(|t| t.report.warm_invocations + t.report.cold_invocations)
            .sum();
        assert_eq!(acquires, executions, "one slot per replica execution");
        peak
    }

    /// Widest layer fan-out any tenant deployed: the documented bound on
    /// execution-granular cap overshoot (one request's layer dispatch is
    /// admitted atomically once the first slot is granted).
    fn widest_fan_out(out: &FleetOutcome) -> usize {
        out.artifacts
            .iter()
            .filter_map(|a| a.final_policy.as_ref())
            .flat_map(|p| &p.layers)
            .map(|l| l.experts.iter().map(|e| e.replicas).sum::<usize>())
            .max()
            .unwrap_or(0)
    }

    #[test]
    fn execution_cap_ledger_conserves_slots() {
        let fleet = FleetScenario {
            name: "conserve".into(),
            account_cap: Some(2),
            arbitration: FleetArbitration::WeightedFair,
            cap_granularity: CapGranularity::Execution,
            share_experts: false,
            slo_feedback: false,
            batch_window: 0.0,
            faults: FaultSpec::off(),
            driver: FleetDriver::Heap,
            tenants: vec![
                TenantSpec::inline("a", tiny_tenant_scenario(11)),
                TenantSpec::inline("b", tiny_tenant_scenario(12)),
            ],
        };
        let (scenarios, compiled) = materialized(&fleet);
        let (out, audit) = fleet.run_compiled(&scenarios, &compiled, FleetDriver::Heap, true);
        let peak = assert_ledger_conserves(&out, &audit);
        assert_eq!(
            out.report.peak_concurrency, peak,
            "reported peak must match the audit replay"
        );
        // Execution-granular overshoot is bounded by the widest dispatched
        // layer fan-out: once a request holds one slot, the rest of its
        // layer's replicas are admitted without re-checking headroom.
        let cap = fleet.account_cap.unwrap();
        assert!(
            out.report.peak_concurrency <= cap - 1 + widest_fan_out(&out),
            "peak {} exceeds cap {} - 1 + widest fan-out {}",
            out.report.peak_concurrency,
            cap,
            widest_fan_out(&out)
        );
    }

    /// The audit conservation property must also hold when the ledger's
    /// weights adapt mid-run (slo_feedback) and the tenants share one
    /// expert arena — PR 6 only ever audited a private-pool static fleet.
    #[test]
    fn execution_cap_ledger_conserves_slots_on_shared_slo_fleet() {
        let fleet = FleetScenario {
            name: "conserve-shared".into(),
            account_cap: Some(2),
            arbitration: FleetArbitration::WeightedFair,
            cap_granularity: CapGranularity::Execution,
            share_experts: true,
            slo_feedback: true,
            batch_window: 0.0,
            faults: FaultSpec::off(),
            driver: FleetDriver::Heap,
            tenants: vec![paced_tenant(31, Some(1e-9)), paced_tenant(32, None)],
        };
        let (scenarios, compiled) = materialized(&fleet);
        let (out, audit) = fleet.run_compiled(&scenarios, &compiled, FleetDriver::Heap, true);
        let peak = assert_ledger_conserves(&out, &audit);
        assert_eq!(out.report.peak_concurrency, peak);
        let cap = fleet.account_cap.unwrap();
        assert!(out.report.peak_concurrency <= cap - 1 + widest_fan_out(&out));
    }

    /// The conservation property must also survive the failure machinery:
    /// crashed attempts, backoff retries, throttle re-admissions and hedge
    /// duplicates each acquire exactly one slot per replica execution and
    /// release it at its declared (possibly truncated) end — nothing the
    /// fault model does may leak cap slots or busy-seconds.
    #[test]
    fn execution_cap_ledger_conserves_slots_under_faults() {
        let fleet = FleetScenario {
            name: "conserve-faults".into(),
            account_cap: Some(1),
            arbitration: FleetArbitration::WeightedFair,
            cap_granularity: CapGranularity::Execution,
            share_experts: false,
            slo_feedback: false,
            batch_window: 0.0,
            faults: FaultSpec {
                crash_prob: 0.25,
                cold_crash_multiplier: 2.0,
                throttle_prob: 0.5,
                timeout: f64::INFINITY,
                max_retries: 3,
                backoff_base: 0.25,
                hedge_quantile: 0.9,
                hedge_min_obs: 16,
                drop_after: 4,
            },
            driver: FleetDriver::Heap,
            // Deterministic rate-1 tenants arrive in lockstep, so the
            // 1-slot cap rejects (and throttle-retries) a request nearly
            // every tick while crashes drive layer retries underneath.
            tenants: vec![paced_tenant(51, None), paced_tenant(52, Some(1e6))],
        };
        let (scenarios, compiled) = materialized(&fleet);
        let (out, audit) = fleet.run_compiled(&scenarios, &compiled, FleetDriver::Heap, true);
        let peak = assert_ledger_conserves(&out, &audit);
        assert_eq!(out.report.peak_concurrency, peak);
        // Overshoot bound gains one slot: a hedged dispatch admits the
        // duplicate replica inside the same atomic layer admission.
        let cap = fleet.account_cap.unwrap();
        assert!(out.report.peak_concurrency <= cap - 1 + widest_fan_out(&out) + 1);
        // The weather actually blew — the recovery paths under audit ran.
        assert!(out.report.failed_invocations > 0, "crashes injected");
        assert!(out.report.retries > 0, "layer retries exercised");
        assert!(out.report.throttled_requests > 0, "cap throttles exercised");
        // Billing stayed conserved alongside the ledger: failed-attempt
        // cost is part of (never more than) the total bill, and goodput
        // can only count a subset of completed requests.
        assert!(out.report.retry_cost > 0.0);
        assert!(out.report.retry_cost <= out.report.total_cost + 1e-9);
        let requests: u64 = out.report.tenants.iter().map(|t| t.report.requests).sum();
        assert!(out.report.goodput_requests <= requests);
    }

    /// Request-granular admission checks headroom before every grant, so
    /// the peak can never exceed the cap — not even transiently.
    #[test]
    fn request_cap_peak_never_exceeds_the_cap() {
        let fleet = FleetScenario {
            name: "req-peak".into(),
            account_cap: Some(2),
            arbitration: FleetArbitration::WeightedFair,
            cap_granularity: CapGranularity::Request,
            share_experts: false,
            slo_feedback: false,
            batch_window: 0.0,
            faults: FaultSpec::off(),
            driver: FleetDriver::Heap,
            tenants: vec![
                TenantSpec::inline("a", tiny_tenant_scenario(11)),
                TenantSpec::inline("b", tiny_tenant_scenario(12)),
            ],
        };
        let (scenarios, compiled) = materialized(&fleet);
        let (out, _) = fleet.run_compiled(&scenarios, &compiled, FleetDriver::Heap, false);
        assert!(out.report.peak_concurrency >= 1, "a served fleet occupies slots");
        assert!(
            out.report.peak_concurrency <= fleet.account_cap.unwrap(),
            "request-granular peak {} exceeded the cap",
            out.report.peak_concurrency
        );
    }

    fn paced_tenant(seed: u64, slo: Option<f64>) -> TenantSpec {
        let s = Scenario::builder("paced")
            .model("tiny")
            .unwrap()
            .seed(seed)
            .profile(2, 64)
            .traffic(TrafficSource::Synthetic {
                process: ArrivalProcess::Deterministic { rate: 1.0 },
                duration: Some(10.0),
                requests: None,
                tokens_per_request: 64,
            })
            .config(TrafficConfig {
                reoptimize: false,
                epoch_secs: 2.0,
                ..TrafficConfig::default()
            })
            .baseline(Baseline::LambdaML)
            .build()
            .unwrap();
        TenantSpec {
            name: if slo.is_some() { "miss" } else { "ok" }.into(),
            weight: 1.0,
            slo_p95: slo.or(Some(1e6)),
            active: None,
            source: TenantSource::Inline(s),
        }
    }

    /// SLO-feedback arbitration: a tenant missing its p95 every epoch
    /// climbs toward (and never past) 8x its declared weight; a tenant
    /// meeting its SLO keeps its declared weight. The adapted weight is
    /// surfaced as `effective_weight` in the tenant report and its JSON.
    #[test]
    fn slo_feedback_adapts_weights_within_bounds() {
        let fleet = FleetScenario {
            name: "feedback".into(),
            account_cap: Some(2),
            arbitration: FleetArbitration::WeightedFair,
            cap_granularity: CapGranularity::Execution,
            share_experts: false,
            slo_feedback: true,
            batch_window: 0.0,
            faults: FaultSpec::off(),
            driver: FleetDriver::Heap,
            tenants: vec![paced_tenant(21, Some(1e-9)), paced_tenant(22, None)],
        };
        let out = fleet.run().unwrap();
        let miss = out.report.tenant("miss").unwrap();
        let ok = out.report.tenant("ok").unwrap();
        assert!(
            miss.effective_weight > miss.weight,
            "an always-missed SLO must raise the grant weight (got {})",
            miss.effective_weight
        );
        assert!(
            miss.effective_weight <= 8.0 * miss.weight,
            "adaptation is capped at 8x the declared weight (got {})",
            miss.effective_weight
        );
        assert_eq!(ok.effective_weight, ok.weight, "a met SLO keeps the declared weight");
        assert_eq!(
            miss.to_json().get_f64("effective_weight"),
            Some(miss.effective_weight)
        );
        // Deterministic: the adaptation replays identically.
        let again = fleet.run().unwrap();
        assert_eq!(
            out.report.to_json().to_string_pretty(),
            again.report.to_json().to_string_pretty()
        );
    }

    /// Regression (PR 7): misses concentrated after the last epoch
    /// boundary an arrival crosses must still adapt the weight. With an
    /// epoch longer than the whole run, no boundary ever fires — the
    /// pre-fix code discarded every accumulated verdict and reported the
    /// declared weight; the tail flush in `EventLane::finish` now applies
    /// exactly one final evaluation (a doubling for an all-miss tenant).
    #[test]
    fn slo_feedback_evaluates_the_tail_epoch() {
        fn tail_tenant(seed: u64, slo: Option<f64>) -> TenantSpec {
            let s = Scenario::builder("tail")
                .model("tiny")
                .unwrap()
                .seed(seed)
                .profile(2, 64)
                .traffic(TrafficSource::Synthetic {
                    process: ArrivalProcess::Deterministic { rate: 1.0 },
                    duration: Some(10.0),
                    requests: None,
                    tokens_per_request: 64,
                })
                .config(TrafficConfig {
                    reoptimize: false,
                    // One epoch outlives the run: every sample lands in
                    // the tail, after the last boundary.
                    epoch_secs: 100.0,
                    ..TrafficConfig::default()
                })
                .baseline(Baseline::LambdaML)
                .build()
                .unwrap();
            TenantSpec {
                name: if slo.is_some() { "miss" } else { "ok" }.into(),
                weight: 1.0,
                slo_p95: slo.or(Some(1e6)),
                active: None,
                source: TenantSource::Inline(s),
            }
        }
        let fleet = FleetScenario {
            name: "tail-epoch".into(),
            account_cap: Some(2),
            arbitration: FleetArbitration::WeightedFair,
            cap_granularity: CapGranularity::Execution,
            share_experts: false,
            slo_feedback: true,
            batch_window: 0.0,
            faults: FaultSpec::off(),
            driver: FleetDriver::Heap,
            tenants: vec![tail_tenant(41, Some(1e-9)), tail_tenant(42, None)],
        };
        let out = fleet.run().unwrap();
        let miss = out.report.tenant("miss").unwrap();
        let ok = out.report.tenant("ok").unwrap();
        assert_eq!(
            miss.effective_weight,
            2.0 * miss.weight,
            "the tail flush applies exactly one all-miss doubling"
        );
        assert_eq!(ok.effective_weight, ok.weight, "a met tail epoch keeps the weight");
    }

    fn kilo_member(seed: u64) -> Scenario {
        Scenario::builder("member")
            .model("tiny")
            .unwrap()
            .seed(seed)
            .profile(2, 64)
            .traffic(TrafficSource::Synthetic {
                process: ArrivalProcess::Poisson { rate: 1.0 },
                duration: None,
                requests: Some(3),
                tokens_per_request: 64,
            })
            .config(TrafficConfig { reoptimize: false, ..TrafficConfig::default() })
            .baseline(Baseline::LambdaML)
            .build()
            .unwrap()
    }

    /// The thousand-tenant scale target: a 1000-tenant shared-expert fleet
    /// runs to completion, deterministically (two heap runs byte-identical)
    /// and driver-agnostically (heap == scan), with every tenant reported.
    #[test]
    fn thousand_tenant_fleet_is_deterministic_and_driver_agnostic() {
        let tenants: Vec<TenantSpec> = (0..1000)
            .map(|i| TenantSpec {
                name: format!("t{i:04}"),
                weight: 1.0 + (i % 4) as f64,
                slo_p95: None,
                active: None,
                source: TenantSource::Inline(kilo_member(1 + i as u64)),
            })
            .collect();
        let fleet = FleetScenario {
            name: "kilo".into(),
            account_cap: Some(64),
            arbitration: FleetArbitration::WeightedFair,
            cap_granularity: CapGranularity::Execution,
            share_experts: true,
            slo_feedback: false,
            batch_window: 0.0,
            faults: FaultSpec::off(),
            driver: FleetDriver::Heap,
            tenants,
        };
        let (scenarios, compiled) = materialized(&fleet);
        let (a, _) = fleet.run_compiled(&scenarios, &compiled, FleetDriver::Heap, false);
        let (b, _) = fleet.run_compiled(&scenarios, &compiled, FleetDriver::Heap, false);
        let (c, _) = fleet.run_compiled(&scenarios, &compiled, FleetDriver::Scan, false);
        let ja = a.report.to_json().to_string_pretty();
        assert_eq!(ja, b.report.to_json().to_string_pretty(), "re-run diverged");
        assert_eq!(
            ja,
            c.report.to_json().to_string_pretty(),
            "scan driver diverged at 1000 tenants"
        );
        assert_eq!(a.report.tenants.len(), 1000);
        assert_eq!(
            a.report.tenants.iter().map(|t| t.report.requests).sum::<u64>(),
            3000
        );
    }
}
