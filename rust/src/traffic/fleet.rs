//! Multi-tenant fleet serving: several models behind one shared
//! account-level concurrency pool.
//!
//! The paper minimizes billed cost for *one* MoE model, but a real
//! serverless account serves many models at once under a shared account
//! concurrency limit — the multi-tenant setting FaaSMoE (arXiv 2604.26881)
//! targets, and where MoEless-style function pooling pays off most because
//! load skew *across* tenants is even stronger than skew within one model.
//! A [`FleetScenario`] names a set of tenants (each an ordinary
//! [`Scenario`], inline or referenced by file), gives each a weighted-fair
//! share of an account-level concurrency cap and an optional p95 SLO, and
//! serves them **jointly**: every tenant runs as one event-engine lane
//! (`traffic::sim::EventLane`) against a single globally-ordered event
//! queue, with requests admitted through the shared
//! [`AccountCap`](super::sim::AccountCap) ledger — one slot per in-flight
//! request, freed at request completion, granted to parked requests per the
//! [`FleetArbitration`] policy. Per-tenant machinery (deployment policies,
//! epoch clocks, drift re-optimization, replica autoscaling) is untouched
//! and runs *under* the fleet arbitration.
//!
//! Determinism: lanes interleave on the `(time, tenant, seq)` event order,
//! so a fleet run is exactly reproducible; with a single tenant and no cap
//! the fleet engine reproduces [`Scenario::run`] byte-for-byte (pinned by
//! `rust/tests/fleet.rs`).
//!
//! ```no_run
//! use serverless_moe::traffic::fleet::FleetScenario;
//! let fleet = FleetScenario::load(std::path::Path::new("fleet.json"))?;
//! let outcome = fleet.run()?;
//! println!("fleet billed cost: {}", outcome.report.total_cost);
//! # Ok::<(), serverless_moe::traffic::ScenarioError>(())
//! ```
//!
//! The isolation baseline ([`FleetScenario::run_isolated`]) serves each
//! tenant alone on its weighted share of the cap — what per-tenant account
//! reservations would buy — and is what the shared-beats-isolated claim
//! test compares against: under anti-correlated bursts the shared pool
//! serves the same fleet at lower billed cost and no worse p95, the
//! cross-tenant version of the paper's core skew argument.

use super::autoscale::FleetArbitration;
use super::config::SimEngine;
use super::epoch::EpochSimulator;
use super::error::{self, ScenarioError};
use super::report::{FleetReport, TenantReport};
use super::scenario::{Baseline, RunArtifacts, Scenario, TrafficScenario};
use super::sim::{drive, AccountCap, EventLane, EventQueue};
use crate::deploy::DeploymentPolicy;
use crate::util::json::Json;
use crate::util::stats;
use std::path::Path;

/// Where a tenant's scenario comes from.
#[derive(Debug, Clone)]
pub enum TenantSource {
    /// The tenant's full scenario inlined into the fleet file.
    Inline(Scenario),
    /// A reference to a scenario JSON file, resolved against the current
    /// working directory at materialization time (like
    /// [`super::scenario::TrafficSource::TracePath`]).
    Ref(String),
}

/// One named tenant of a fleet.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    /// Weighted-fair share of the account cap (finite, > 0; defaults to 1).
    pub weight: f64,
    /// Optional p95 latency SLO (seconds) recorded per tenant in the
    /// [`FleetReport`].
    pub slo_p95: Option<f64>,
    pub source: TenantSource,
}

impl TenantSpec {
    /// A tenant wrapping an inline scenario with weight 1 and no SLO.
    pub fn inline(name: &str, scenario: Scenario) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            weight: 1.0,
            slo_p95: None,
            source: TenantSource::Inline(scenario),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(&self.name)),
            ("weight", Json::num(self.weight)),
        ];
        if let Some(slo) = self.slo_p95 {
            pairs.push(("slo_p95", Json::num(slo)));
        }
        pairs.push((
            "scenario",
            match &self.source {
                TenantSource::Inline(s) => s.to_json(),
                TenantSource::Ref(p) => Json::str(p),
            },
        ));
        Json::from_pairs(pairs)
    }

    pub fn from_json(j: &Json, idx: usize) -> Result<TenantSpec, ScenarioError> {
        let section = format!("tenants[{idx}]");
        error::check_keys(j, &section, &["name", "weight", "slo_p95", "scenario"])?;
        let name = error::req_str(j, &section, "name")?.to_string();
        let weight = error::opt_f64(j, &section, "weight", 1.0)?;
        let slo_p95 = match j.get("slo_p95") {
            None => None,
            Some(_) => Some(error::req_f64(j, &section, "slo_p95")?),
        };
        let source = match j.get("scenario") {
            None => return Err(ScenarioError::missing(&*section, "scenario")),
            Some(Json::Str(p)) => TenantSource::Ref(p.clone()),
            Some(obj) => TenantSource::Inline(Scenario::from_json(obj)?),
        };
        Ok(TenantSpec { name, weight, slo_p95, source })
    }
}

/// A complete, serializable multi-tenant simulation description: named
/// tenants, the shared account-level concurrency cap, and the arbitration
/// policy that splits it. Construct in code (fields are public) or load
/// from JSON ([`FleetScenario::load`], strict parsing); run with
/// [`FleetScenario::run`].
#[derive(Debug, Clone)]
pub struct FleetScenario {
    pub name: String,
    /// Account-level concurrency cap: how many requests the whole fleet may
    /// have in flight at once (`None` = unbounded — the provider's account
    /// limit lifted). Serialized as `0` for `None`, mirroring the
    /// `concurrency` convention.
    pub account_cap: Option<usize>,
    pub arbitration: FleetArbitration,
    pub tenants: Vec<TenantSpec>,
}

/// One fleet run's results: the aggregate [`FleetReport`] plus per-tenant
/// [`RunArtifacts`] in tenant order.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    pub report: FleetReport,
    pub artifacts: Vec<RunArtifacts>,
}

impl FleetScenario {
    /// Validate the fleet description: at least one tenant, unique
    /// non-empty names, positive finite weights and SLOs, and — for inline
    /// tenants — a valid scenario the fleet engine can serve (event engine,
    /// serverless baseline). Referenced scenario files are checked at
    /// [`FleetScenario::run`] time, after loading.
    pub fn validate(&self) -> Result<(), ScenarioError> {
        if self.tenants.is_empty() {
            return Err(ScenarioError::invalid(
                "fleet.tenants",
                "must name at least one tenant",
            ));
        }
        if self.account_cap == Some(0) {
            return Err(ScenarioError::invalid(
                "fleet.account_cap",
                "must be >= 1 (use None / 0-in-JSON for unbounded)",
            ));
        }
        let mut seen = std::collections::BTreeSet::new();
        for (i, t) in self.tenants.iter().enumerate() {
            if t.name.is_empty() {
                return Err(ScenarioError::invalid(
                    format!("tenants[{i}].name"),
                    "must not be empty",
                ));
            }
            if !seen.insert(t.name.as_str()) {
                return Err(ScenarioError::invalid(
                    format!("tenants[{i}].name"),
                    format!("duplicate tenant name '{}'", t.name),
                ));
            }
            if !(t.weight.is_finite() && t.weight > 0.0) {
                return Err(ScenarioError::invalid(
                    format!("tenants[{i}].weight"),
                    format!("must be finite and > 0, got {}", t.weight),
                ));
            }
            if let Some(slo) = t.slo_p95 {
                if !(slo.is_finite() && slo > 0.0) {
                    return Err(ScenarioError::invalid(
                        format!("tenants[{i}].slo_p95"),
                        format!("must be finite and > 0, got {slo}"),
                    ));
                }
            }
            match &t.source {
                TenantSource::Inline(s) => {
                    s.validate()?;
                    check_tenant_scenario(i, s)?;
                }
                TenantSource::Ref(p) => {
                    if p.is_empty() {
                        return Err(ScenarioError::invalid(
                            format!("tenants[{i}].scenario"),
                            "referenced scenario path must not be empty",
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("version", Json::num(1.0)),
            ("name", Json::str(&self.name)),
            (
                "account_cap",
                Json::num(self.account_cap.unwrap_or(0) as f64),
            ),
            ("arbitration", Json::str(self.arbitration.name())),
            (
                "tenants",
                Json::Arr(self.tenants.iter().map(TenantSpec::to_json).collect()),
            ),
        ])
    }

    /// Strict inverse of [`FleetScenario::to_json`]: unknown fields
    /// anywhere in the fleet-owned schema (including each tenant entry and
    /// inline tenant scenarios) are rejected, values validated.
    pub fn from_json(j: &Json) -> Result<FleetScenario, ScenarioError> {
        const SECTION: &str = "fleet";
        error::check_keys(
            j,
            SECTION,
            &["version", "name", "account_cap", "arbitration", "tenants"],
        )?;
        let version = error::opt_u64(j, SECTION, "version", 1)?;
        if version != 1 {
            return Err(ScenarioError::invalid(
                "version",
                format!("unsupported fleet version {version} (this build reads 1)"),
            ));
        }
        let name = error::req_str(j, SECTION, "name")?.to_string();
        let account_cap = match error::opt_u64(j, SECTION, "account_cap", 0)? {
            0 => None,
            c => Some(c as usize),
        };
        let arbitration = match j.get("arbitration") {
            None => FleetArbitration::WeightedFair,
            Some(Json::Str(s)) => FleetArbitration::from_name(s)?,
            Some(other) => {
                return Err(ScenarioError::invalid(
                    "fleet.arbitration",
                    format!("expected a string, got {other:?}"),
                ))
            }
        };
        let tenant_entries = j
            .get("tenants")
            .and_then(Json::as_arr)
            .ok_or_else(|| ScenarioError::missing(SECTION, "tenants"))?;
        let mut tenants = Vec::with_capacity(tenant_entries.len());
        for (i, tj) in tenant_entries.iter().enumerate() {
            tenants.push(TenantSpec::from_json(tj, i)?);
        }
        let fleet = FleetScenario { name, account_cap, arbitration, tenants };
        fleet.validate()?;
        Ok(fleet)
    }

    pub fn load(path: &Path) -> Result<FleetScenario, ScenarioError> {
        Self::from_json(&error::read_json(path)?)
    }

    pub fn save(&self, path: &Path) -> Result<(), ScenarioError> {
        self.to_json()
            .write_file(path)
            .map_err(|e| ScenarioError::Io {
                path: path.display().to_string(),
                detail: e.to_string(),
            })
    }

    /// Resolve every tenant to a concrete [`Scenario`] (loading `Ref`
    /// sources) and re-check fleet eligibility on the loaded files.
    fn resolved(&self) -> Result<Vec<Scenario>, ScenarioError> {
        self.tenants
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let s = match &t.source {
                    TenantSource::Inline(s) => s.clone(),
                    TenantSource::Ref(p) => Scenario::load(Path::new(p))?,
                };
                check_tenant_scenario(i, &s)?;
                Ok(s)
            })
            .collect()
    }

    /// Serve the whole fleet jointly under the shared account cap. Each
    /// tenant keeps its own baseline semantics (the exact cfg munging of
    /// [`TrafficScenario::run`]): `static`/`lambdaml` force re-optimization
    /// off, `ours` takes the tenant's config as written.
    pub fn run(&self) -> Result<FleetOutcome, ScenarioError> {
        self.validate()?;
        let scenarios = self.resolved()?;
        let compiled = scenarios
            .iter()
            .map(Scenario::materialize)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(self.run_compiled(&scenarios, &compiled))
    }

    /// The isolation baseline: every tenant served *alone* on its
    /// weighted-fair reservation of the account cap — what per-tenant
    /// reserved concurrency would buy instead of the shared pool. The
    /// reservations partition the cap *exactly* (largest-remainder
    /// apportionment by weight, at least one slot each; a fleet with more
    /// tenants than cap slots cannot be isolated and is a typed error), so
    /// the baseline never models more concurrency than the account owns.
    /// Uncapped fleets isolate to uncapped single runs. Tenants are
    /// resolved and materialized once, not per single run.
    pub fn run_isolated(&self) -> Result<FleetOutcome, ScenarioError> {
        self.validate()?;
        let weights: Vec<f64> = self.tenants.iter().map(|t| t.weight).collect();
        let shares = isolated_shares(self.account_cap, &weights)?;
        let scenarios = self.resolved()?;
        let compiled = scenarios
            .iter()
            .map(Scenario::materialize)
            .collect::<Result<Vec<_>, _>>()?;
        let mut tenants = Vec::with_capacity(self.tenants.len());
        let mut artifacts = Vec::with_capacity(self.tenants.len());
        for (i, t) in self.tenants.iter().enumerate() {
            let single = FleetScenario {
                name: format!("{}/{}", self.name, t.name),
                account_cap: shares[i],
                arbitration: self.arbitration,
                tenants: vec![t.clone()],
            };
            let mut out = single.run_compiled(&scenarios[i..=i], &compiled[i..=i]);
            tenants.push(out.report.tenants.pop().expect("single-tenant fleet"));
            artifacts.push(out.artifacts.pop().expect("single-tenant fleet"));
        }
        Ok(FleetOutcome {
            report: FleetReport::from_tenants(self.account_cap, tenants),
            artifacts,
        })
    }

    /// The joint run over already-resolved, already-materialized tenants:
    /// one simulator + one event lane per tenant, driven to completion
    /// against one shared event queue and account ledger.
    fn run_compiled(&self, scenarios: &[Scenario], compiled: &[TrafficScenario]) -> FleetOutcome {
        let mut sims: Vec<EpochSimulator<'_>> = Vec::with_capacity(compiled.len());
        let mut policies: Vec<DeploymentPolicy> = Vec::with_capacity(compiled.len());
        let mut pipelines: Vec<bool> = Vec::with_capacity(compiled.len());
        for (s, scn) in scenarios.iter().zip(compiled) {
            let mut cfg = s.cfg.clone();
            let forced = match s.baseline {
                Baseline::Ours => None,
                Baseline::Static => {
                    cfg.reoptimize = false;
                    None
                }
                Baseline::LambdaML => {
                    cfg.reoptimize = false;
                    Some(scn.lambdaml(&cfg))
                }
                Baseline::CpuCluster => unreachable!("rejected by validate()"),
            };
            let pipeline = match cfg.engine {
                SimEngine::Event { pipeline } => pipeline,
                SimEngine::Legacy => unreachable!("rejected by validate()"),
            };
            let mut sim =
                EpochSimulator::new(&scn.platform, &scn.spec, &scn.gate, scn.predictor(), cfg);
            let policy = match forced {
                Some(p) => p,
                None => sim.initial_policy(&scn.traffic),
            };
            sim.begin_run(&policy);
            sims.push(sim);
            policies.push(policy);
            pipelines.push(pipeline);
        }

        let weights: Vec<f64> = self.tenants.iter().map(|t| t.weight).collect();
        let mut cap = AccountCap::new(self.account_cap, self.arbitration, &weights);
        let capped = cap.enabled();
        let mut q = EventQueue::new();
        let mut lanes: Vec<EventLane<'_, '_>> = policies
            .into_iter()
            .enumerate()
            .map(|(i, policy)| {
                EventLane::new(
                    &sims[i],
                    policy,
                    &compiled[i].traffic,
                    pipelines[i],
                    i as u32,
                    capped,
                )
            })
            .collect();
        let reports = drive(&mut sims, &mut lanes, &mut q, &mut cap);

        let mut tenants = Vec::with_capacity(reports.len());
        let mut artifacts = Vec::with_capacity(reports.len());
        for (i, report) in reports.into_iter().enumerate() {
            let lane = &lanes[i];
            let sim = &mut sims[i];
            tenants.push(TenantReport {
                name: self.tenants[i].name.clone(),
                weight: self.tenants[i].weight,
                slo_p95: self.tenants[i].slo_p95,
                report,
                capped_requests: lane.cap_waits.len() as u64,
                mean_cap_delay: stats::mean(&lane.cap_waits),
                max_cap_delay: lane.cap_waits.iter().cloned().fold(0.0, f64::max),
            });
            artifacts.push(RunArtifacts {
                policy_history: std::mem::take(&mut sim.policy_history),
                final_policy: sim.last_policy.take(),
                redeploy_times: std::mem::take(&mut sim.redeploy_times),
                autoscale_events: std::mem::take(&mut sim.autoscale_events),
                latencies: std::mem::take(&mut sim.last_latencies),
            });
        }
        FleetOutcome {
            report: FleetReport::from_tenants(self.account_cap, tenants),
            artifacts,
        }
    }
}

/// Partition `cap` into per-tenant isolation reservations: at least one
/// slot each, the spare slots apportioned by weight with largest-remainder
/// rounding (ties toward the lower tenant index), summing to exactly `cap`.
/// `None` (unbounded) isolates to unbounded singles.
fn isolated_shares(
    cap: Option<usize>,
    weights: &[f64],
) -> Result<Vec<Option<usize>>, ScenarioError> {
    let n = weights.len();
    let Some(c) = cap else {
        return Ok(vec![None; n]);
    };
    if c < n {
        return Err(ScenarioError::invalid(
            "fleet.account_cap",
            format!(
                "the isolation baseline needs at least one reserved slot per tenant \
                 ({n} tenants, cap {c})"
            ),
        ));
    }
    let total: f64 = weights.iter().sum();
    let spare = (c - n) as f64;
    let quotas: Vec<f64> = weights.iter().map(|w| spare * w / total).collect();
    let mut shares: Vec<usize> = quotas.iter().map(|q| 1 + q.floor() as usize).collect();
    let mut assigned: usize = shares.iter().sum();
    // Largest remainder: the leftover slots go to the biggest fractional
    // quotas, deterministically (remainder desc, then tenant index asc).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let ra = quotas[a] - quotas[a].floor();
        let rb = quotas[b] - quotas[b].floor();
        rb.partial_cmp(&ra).expect("finite remainders").then(a.cmp(&b))
    });
    for &i in &order {
        if assigned >= c {
            break;
        }
        shares[i] += 1;
        assigned += 1;
    }
    debug_assert_eq!(shares.iter().sum::<usize>(), c, "shares must partition the cap");
    Ok(shares.into_iter().map(Some).collect())
}

/// Fleet-eligibility checks on one tenant's scenario: the fleet engine
/// interleaves event lanes, so the legacy serial engine cannot participate,
/// and the CPU-cluster baseline has no serverless pool to share.
fn check_tenant_scenario(i: usize, s: &Scenario) -> Result<(), ScenarioError> {
    if !matches!(s.cfg.engine, SimEngine::Event { .. }) {
        return Err(ScenarioError::invalid(
            format!("tenants[{i}].scenario.config.engine"),
            "fleet serving runs on the event engine (legacy is single-tenant only)",
        ));
    }
    if s.baseline == Baseline::CpuCluster {
        return Err(ScenarioError::invalid(
            format!("tenants[{i}].scenario.baseline"),
            "cpu-cluster has no serverless pool to share; run it as a standalone scenario",
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::traffic::arrivals::ArrivalProcess;
    use crate::traffic::scenario::TrafficSource;
    use crate::traffic::TrafficConfig;

    fn tiny_tenant_scenario(seed: u64) -> Scenario {
        Scenario::builder("tiny-tenant")
            .model("tiny")
            .unwrap()
            .seed(seed)
            .profile(2, 64)
            .traffic(TrafficSource::Synthetic {
                process: ArrivalProcess::Poisson { rate: 1.0 },
                duration: Some(5.0),
                requests: None,
                tokens_per_request: 64,
            })
            .config(TrafficConfig { reoptimize: false, ..TrafficConfig::default() })
            .baseline(Baseline::LambdaML)
            .build()
            .unwrap()
    }

    fn two_tenant_fleet() -> FleetScenario {
        FleetScenario {
            name: "test-fleet".into(),
            account_cap: Some(2),
            arbitration: FleetArbitration::WeightedFair,
            tenants: vec![
                TenantSpec {
                    name: "a".into(),
                    weight: 2.0,
                    slo_p95: Some(30.0),
                    source: TenantSource::Inline(tiny_tenant_scenario(1)),
                },
                TenantSpec::inline("b", tiny_tenant_scenario(2)),
            ],
        }
    }

    #[test]
    fn fleet_json_roundtrip_is_canonical() {
        let f = two_tenant_fleet();
        let text = f.to_json().to_string_pretty();
        let back = FleetScenario::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string_pretty(), text);
        assert_eq!(back.tenants.len(), 2);
        assert_eq!(back.account_cap, Some(2));
        assert_eq!(back.arbitration, FleetArbitration::WeightedFair);
        assert_eq!(back.tenants[0].slo_p95, Some(30.0));
    }

    #[test]
    fn fleet_validation_rejects_bad_shapes() {
        let base = two_tenant_fleet();

        let mut empty = base.clone();
        empty.tenants.clear();
        assert!(matches!(empty.validate(), Err(ScenarioError::Invalid { .. })));

        let mut dup = base.clone();
        dup.tenants[1].name = "a".into();
        let err = dup.validate().unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");

        let mut zero_w = base.clone();
        zero_w.tenants[0].weight = 0.0;
        assert!(matches!(zero_w.validate(), Err(ScenarioError::Invalid { .. })));

        let mut legacy = base.clone();
        if let TenantSource::Inline(s) = &mut legacy.tenants[0].source {
            s.cfg.engine = SimEngine::Legacy;
        }
        let err = legacy.validate().unwrap_err();
        assert!(err.to_string().contains("engine"), "{err}");

        let mut cpu = base;
        if let TenantSource::Inline(s) = &mut cpu.tenants[1].source {
            s.baseline = Baseline::CpuCluster;
        }
        assert!(matches!(cpu.validate(), Err(ScenarioError::Invalid { .. })));
    }

    #[test]
    fn isolated_shares_partition_the_cap_exactly() {
        // Equal weights, one spare slot: largest-remainder tie breaks to
        // the lower tenant index.
        assert_eq!(
            isolated_shares(Some(4), &[1.0, 1.0, 1.0]).unwrap(),
            vec![Some(2), Some(1), Some(1)]
        );
        // Heavy skew must not oversubscribe: the old max(1, floor) scheme
        // would have handed out 3+1+1 = 5 slots of a 4-slot account.
        assert_eq!(
            isolated_shares(Some(4), &[10.0, 1.0, 1.0]).unwrap(),
            vec![Some(2), Some(1), Some(1)]
        );
        assert_eq!(
            isolated_shares(Some(6), &[2.0, 1.0]).unwrap(),
            vec![Some(4), Some(2)]
        );
        // Unbounded fleets isolate to unbounded singles.
        assert_eq!(isolated_shares(None, &[1.0, 1.0]).unwrap(), vec![None, None]);
        // More tenants than slots: isolation is impossible, typed error.
        assert!(matches!(
            isolated_shares(Some(2), &[1.0, 1.0, 1.0]),
            Err(ScenarioError::Invalid { .. })
        ));
    }

    #[test]
    fn ref_tenant_missing_file_is_typed_io_error() {
        let f = FleetScenario {
            name: "refs".into(),
            account_cap: None,
            arbitration: FleetArbitration::Fifo,
            tenants: vec![TenantSpec {
                name: "ghost".into(),
                weight: 1.0,
                slo_p95: None,
                source: TenantSource::Ref("no/such/scenario.json".into()),
            }],
        };
        assert!(f.validate().is_ok(), "path existence is a run-time concern");
        assert!(matches!(f.run(), Err(ScenarioError::Io { .. })));
    }
}
