//! JSON request traces: a portable serving workload description.
//!
//! Schema (see `rust/tests/data/trace_small.json` for a committed example):
//!
//! ```json
//! {
//!   "version": 1,
//!   "requests": [
//!     { "time": 0.0,  "tokens": 512, "seed": 1 },
//!     { "time": 1.25, "tokens": 2048 }
//!   ]
//! }
//! ```
//!
//! `time` is the arrival timestamp in seconds (non-decreasing), `tokens`
//! the request's target token count, and `seed` (optional, defaults to the
//! request index) makes the synthesized batch content reproducible per
//! request. Replay materializes each request into a timestamped `Batch`
//! through the corpus model, preserving order and token targets.

use super::arrivals::{ArrivalGen, ArrivalProcess};
use super::error::ScenarioError;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workload::{Batch, Corpus, TimedBatch};
use std::path::Path;

/// One traced request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRequest {
    /// Arrival time (seconds, non-decreasing across the trace).
    pub time: f64,
    /// Target token count of the request's batch.
    pub tokens: usize,
    /// Content seed (reproducible batch synthesis).
    pub seed: u64,
}

/// A full request trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    pub fn from_json(j: &Json) -> Result<Trace, ScenarioError> {
        super::error::check_keys(j, "trace", &["version", "requests"])?;
        let arr = j
            .get("requests")
            .and_then(Json::as_arr)
            .ok_or_else(|| ScenarioError::missing("trace", "requests"))?;
        let mut requests = Vec::with_capacity(arr.len());
        for (i, r) in arr.iter().enumerate() {
            let section = format!("trace request {i}");
            super::error::check_keys(r, &section, &["time", "tokens", "seed"])?;
            let time = r
                .get_f64("time")
                .ok_or_else(|| ScenarioError::missing(&*section, "time"))?;
            let tokens = r
                .get_usize("tokens")
                .ok_or_else(|| ScenarioError::missing(&*section, "tokens"))?;
            if !(time.is_finite() && time >= 0.0) {
                return Err(ScenarioError::invalid(
                    format!("{section}.time"),
                    format!("must be finite and >= 0, got {time}"),
                ));
            }
            if tokens == 0 {
                return Err(ScenarioError::invalid(
                    format!("{section}.tokens"),
                    "must be > 0".to_string(),
                ));
            }
            let seed = r.get("seed").and_then(Json::as_u64).unwrap_or(i as u64);
            // Seeds travel as JSON numbers (f64): values at or above 2^53
            // would silently round, so reject them loudly instead.
            if seed >= (1u64 << 53) {
                return Err(ScenarioError::invalid(
                    format!("{section}.seed"),
                    format!("{seed} exceeds the 2^53 JSON-number range"),
                ));
            }
            requests.push(TraceRequest { time, tokens, seed });
        }
        if !requests.windows(2).all(|w| w[0].time <= w[1].time) {
            return Err(ScenarioError::invalid(
                "trace.requests",
                "timestamps must be non-decreasing".to_string(),
            ));
        }
        Ok(Trace { requests })
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("version", Json::num(1.0)),
            (
                "requests",
                Json::Arr(
                    self.requests
                        .iter()
                        .map(|r| {
                            Json::from_pairs(vec![
                                ("time", Json::num(r.time)),
                                ("tokens", Json::num(r.tokens as f64)),
                                ("seed", Json::num(r.seed as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn load(path: &Path) -> Result<Trace, ScenarioError> {
        Self::from_json(&super::error::read_json(path)?)
    }

    pub fn save(&self, path: &Path) -> Result<(), ScenarioError> {
        self.to_json().write_file(path).map_err(|e| ScenarioError::Io {
            path: path.display().to_string(),
            detail: e.to_string(),
        })
    }

    pub fn total_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.tokens).sum()
    }

    /// Time of the last request (0 for an empty trace).
    pub fn duration(&self) -> f64 {
        self.requests.last().map(|r| r.time).unwrap_or(0.0)
    }

    /// Synthesize a trace from an arrival process with a fixed per-request
    /// token target.
    pub fn synthesize(
        process: ArrivalProcess,
        seed: u64,
        duration: f64,
        tokens_per_request: usize,
    ) -> Trace {
        let arrivals = ArrivalGen::new(process, seed).arrivals_until(duration);
        Trace {
            requests: arrivals
                .iter()
                .enumerate()
                .map(|(i, &time)| TraceRequest {
                    time,
                    tokens: tokens_per_request,
                    // Masked to 53 bits so the trace survives its own JSON
                    // serialization exactly.
                    seed: seed.wrapping_add(i as u64) & ((1 << 53) - 1),
                })
                .collect(),
        }
    }

    /// Materialize the trace into timestamped batches over `corpus`: each
    /// request becomes a batch of at least `tokens` tokens whose content is
    /// determined by `(base_seed, request.seed)` — replay preserves both the
    /// timestamp order and every request's token target.
    pub fn replay(&self, corpus: &Corpus, base_seed: u64) -> Vec<TimedBatch> {
        self.requests
            .iter()
            .map(|r| {
                let mut rng = Rng::new(base_seed ^ r.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let seqs = corpus.sample_tokens(&mut rng, r.tokens.max(1));
                TimedBatch {
                    at: r.time,
                    batch: Batch::from_sequences(seqs),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::workload::CorpusPreset;

    fn small() -> Trace {
        Trace {
            requests: vec![
                TraceRequest { time: 0.0, tokens: 128, seed: 1 },
                TraceRequest { time: 0.5, tokens: 256, seed: 2 },
                TraceRequest { time: 2.0, tokens: 128, seed: 3 },
            ],
        }
    }

    #[test]
    fn json_roundtrip_exact() {
        let t = small();
        let j = t.to_json();
        let back = Trace::from_json(&j).unwrap();
        assert_eq!(back, t);
        // Text-level roundtrip too (what the committed fixture exercises).
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(Trace::from_json(&parsed).unwrap(), t);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Trace::from_json(&Json::parse("{}").unwrap()).is_err());
        let unsorted = r#"{"requests":[{"time":5,"tokens":8},{"time":1,"tokens":8}]}"#;
        assert!(Trace::from_json(&Json::parse(unsorted).unwrap()).is_err());
        let zero = r#"{"requests":[{"time":0,"tokens":0}]}"#;
        assert!(Trace::from_json(&Json::parse(zero).unwrap()).is_err());
        let neg = r#"{"requests":[{"time":-1,"tokens":4}]}"#;
        assert!(Trace::from_json(&Json::parse(neg).unwrap()).is_err());
    }

    #[test]
    fn replay_preserves_order_and_token_targets() {
        let t = small();
        let corpus = Corpus::new(CorpusPreset::Enwik8, 9);
        let batches = t.replay(&corpus, 77);
        assert_eq!(batches.len(), t.requests.len());
        for (tb, r) in batches.iter().zip(&t.requests) {
            assert_eq!(tb.at, r.time);
            assert!(tb.batch.total_tokens >= r.tokens);
        }
        assert!(batches.windows(2).all(|w| w[0].at <= w[1].at));
        // Deterministic: same (corpus, base_seed) reproduces content.
        let again = t.replay(&corpus, 77);
        assert_eq!(
            batches[1].batch.sequences[0].tokens,
            again[1].batch.sequences[0].tokens
        );
    }

    #[test]
    fn synthesize_matches_process() {
        let t = Trace::synthesize(ArrivalProcess::Deterministic { rate: 2.0 }, 5, 10.0, 64);
        assert_eq!(t.requests.len(), 19);
        assert_eq!(t.total_tokens(), 19 * 64);
        assert!(t.duration() < 10.0);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("smoe_trace_test");
        let path = dir.join("t.json");
        let t = small();
        t.save(&path).unwrap();
        assert_eq!(Trace::load(&path).unwrap(), t);
        std::fs::remove_dir_all(&dir).ok();
    }
}
