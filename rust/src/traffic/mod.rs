//! Epoch-based traffic simulation (the serving dimension the single-batch
//! seed lacked) behind one declarative front door: [`scenario::Scenario`].
//!
//! The paper's headline numbers are measured under *sustained* request
//! traffic on AWS Lambda; reproducing them needs an arrival process, a
//! cold/warm instance lifecycle across requests, and the online feedback
//! loop in which the predictor re-learns expert popularity as traffic
//! shifts (§IV, Alg. 1). This subsystem provides all three:
//!
//!  - [`scenario`]  — **the public entry point**: a serde-style
//!    (de)serializable [`scenario::Scenario`] describing model, platform,
//!    traffic source, engine configuration and baseline;
//!    [`scenario::Scenario::run`] returns the [`report::SimReport`] plus
//!    [`scenario::RunArtifacts`] (deployment history, redeploy/autoscale
//!    events, latencies). Examples, experiments and the CLI all drive
//!    simulations through it; errors are typed ([`error::ScenarioError`]),
//!    parsing is strict (unknown fields rejected);
//!  - [`arrivals`]  — deterministic-rate, Poisson and two-state MMPP arrival
//!    generators producing timestamped requests;
//!  - [`trace`]     — a JSON request-trace format with replay (schema
//!    documented on [`trace::Trace`]);
//!  - [`config`]    — the [`config::TrafficConfig`] knobs (epoching,
//!    keep-alive, per-instance concurrency, autoscaling policy), JSON
//!    round-trippable as the scenario's `config` section;
//!  - [`epoch`]     — the epoch loop: serve a traffic window against the
//!    current deployment with warmness derived from the
//!    `platform::lifecycle::WarmPool` virtual clock and overlapping
//!    requests queued FIFO per instance under bounded concurrency, feed
//!    realized expert counts back into the predictor's dataset table, and
//!    re-run ODS (optionally after a BO refinement round) when realized
//!    popularity drifts past a threshold — charging the ≥60 s redeployment
//!    gap against availability (§II Challenge 1);
//!  - [`autoscale`] — epoch-level replica autoscaling between redeploys
//!    (target-utilization and queue-depth policies; scale-out lands cold,
//!    scale-in reaps idle instances and evicts their warm environments);
//!  - [`sim`]      — the event-driven engine (default): a `BinaryHeap`
//!    event queue with layer-pipelined dispatch (a request's layer k+1 is
//!    enqueued when layer k completes), a flat [`sim::SlotArena`] replacing
//!    per-request hash lookups, memoized routing, and optional O(1)-memory
//!    streaming metrics — built for million-request traces (see
//!    `examples/bench_traffic.rs`); the legacy serial loop stays reachable
//!    via [`config::SimEngine::Legacy`] and is reproduced bit-for-bit when
//!    pipelining is disabled;
//!  - [`workload`]  — autoregressive LLM workloads: the
//!    [`workload::RequestPhase`] prefill/decode model, seeded decode-length
//!    distributions ([`workload::DecodeLengthModel`]), per-step token
//!    batches that drift expert routing *within* a request, and the
//!    [`workload::KvLedger`] pinning decode steps to the instances holding
//!    KV state (a cold pin forces a billed re-prefill); decode steps of
//!    co-resident requests can merge into one invocation per iteration
//!    (continuous batching, `config.decode_batch_window`);
//!  - [`report`]    — the [`report::SimReport`] aggregate (billed cost over
//!    time, throughput, latency and queue-delay percentiles, utilization)
//!    used by the golden-regression fixtures and the `experiments::traffic`
//!    scenario runner, plus the fleet rollups ([`report::FleetReport`],
//!    [`report::TenantReport`]);
//!  - [`fleet`]     — multi-tenant fleet serving: a serializable
//!    [`fleet::FleetScenario`] naming several tenants (each an ordinary
//!    [`scenario::Scenario`]) served *jointly* behind one shared
//!    account-level concurrency cap ([`sim::AccountCap`]) with
//!    weighted-fair slot arbitration ([`autoscale::FleetArbitration`]).
//!    Cap slots count concurrent replica *executions* by default
//!    ([`autoscale::CapGranularity`]); same-preset tenants can share one
//!    warm replica pool (`share_experts`, refcounted in
//!    [`sim::SlotArena`]); grant weights can adapt to per-tenant SLO
//!    verdicts (`slo_feedback`). Lanes are driven by a candidate heap —
//!    O(events · log tenants), sized for thousand-tenant fleets — and
//!    with one tenant and no cap the engine reproduces
//!    [`scenario::Scenario::run`] byte-for-byte. The fleet-level
//!    `driver` knob can instead shard lanes across worker threads
//!    ([`sim::FleetDriver::Parallel`]) advanced in lock-step conservative
//!    time windows, byte-identical to the sequential heap driver at every
//!    thread count.
//!
//! [`epoch::EpochSimulator`] remains the engine *behind* the scenario
//! façade; construct simulations through [`scenario::Scenario`] /
//! [`fleet::FleetScenario`] instead of wiring it by hand (the engine
//! cross-validation tests that need simulator internals import it from
//! [`epoch`] directly).

pub mod arrivals;
pub mod autoscale;
pub mod config;
pub mod epoch;
pub mod error;
pub mod fleet;
pub mod report;
pub mod scenario;
pub mod sim;
pub mod trace;
pub mod workload;

pub use arrivals::{arrival_seed, decode_seed, fault_seed, ArrivalGen, ArrivalProcess};
pub use autoscale::{AutoscalePolicy, Autoscaler, CapGranularity, FleetArbitration};
pub use config::{FaultSpec, MetricsMode, SimEngine, TrafficConfig};
pub use error::ScenarioError;
pub use fleet::{FleetOutcome, FleetScenario, PreparedFleet, TenantSource, TenantSpec};
pub use report::{FleetReport, SimReport, TenantReport};
pub use scenario::{
    Baseline, ModelSource, RunArtifacts, Scenario, ScenarioBuilder, ScenarioOutcome,
    TrafficScenario, TrafficSource,
};
pub use sim::{AccountCap, FleetDriver, SlotArena};
pub use trace::{Trace, TraceRequest};
pub use workload::{ChatWorkload, DecodeLengthModel, KvLedger, RequestPhase};
