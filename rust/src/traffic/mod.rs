//! Epoch-based traffic simulation (the serving dimension the single-batch
//! seed lacked).
//!
//! The paper's headline numbers are measured under *sustained* request
//! traffic on AWS Lambda; reproducing them needs an arrival process, a
//! cold/warm instance lifecycle across requests, and the online feedback
//! loop in which the predictor re-learns expert popularity as traffic
//! shifts (§IV, Alg. 1). This subsystem provides all three:
//!
//!  - [`arrivals`] — deterministic-rate, Poisson and two-state MMPP arrival
//!    generators producing timestamped requests;
//!  - [`trace`]    — a JSON request-trace format with replay (schema
//!    documented on [`trace::Trace`]);
//!  - [`epoch`]    — the epoch loop: serve a traffic window against the
//!    current deployment with warmness derived from the
//!    `platform::lifecycle::WarmPool` virtual clock, feed realized expert
//!    counts back into the predictor's dataset table, and re-run ODS
//!    (optionally after a BO refinement round) when realized popularity
//!    drifts past a threshold — charging the ≥60 s redeployment gap against
//!    availability (§II Challenge 1);
//!  - [`report`]   — the [`report::SimReport`] aggregate (billed cost over
//!    time, throughput, latency percentiles) used by the golden-regression
//!    fixtures and the `experiments::traffic` scenario runner.

pub mod arrivals;
pub mod epoch;
pub mod report;
pub mod trace;

pub use arrivals::{ArrivalGen, ArrivalProcess};
pub use epoch::{EpochSimulator, TrafficConfig};
pub use report::SimReport;
pub use trace::{Trace, TraceRequest};
